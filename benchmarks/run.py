"""Benchmark harness: one function per paper table/figure.

Default mode prints ``name,us_per_call,derived`` CSV.  Roofline rows require
the dry-run JSONs (python -m repro.launch.dryrun); other benches are
self-contained.

``--json`` instead runs the serving benchmark (tinyllama reduced, `pq` vs
`exact` cache policy through `repro.launch.serve.ServeRun`) and *appends* a
timestamped record to ``BENCH_serve.json`` (``{"runs": [...]}``), so the
serving perf trajectory accumulates across PRs instead of overwriting.
Each record carries the axes that now exist (`cache_layout` / `scheduler` /
`kv_block_size`, plus the git SHA) and a ``tiered`` section: a forced-spill
trace through the tiered (device+host) engine per policy, reporting the
`TransferLedger` tier-boundary bytes — the paper's compressed-vs-raw
communication claim as a measured quantity (`pq_vs_exact_raw_spill` is the
pq spill traffic as a fraction of exact raw spill traffic on an identical
trace).

Since PR 5 every policy row also records per-step decode latency
percentiles (p50/p99) and a ``decode_kernels`` section: the paged engine
driven under `--decode-kernel xla` vs `pallas-interpret` on one trace,
asserting greedy-token identity and recording the modeled decode HBM bytes
per step — dense gather->decode->scatter vs block-table-native pool reads
(`pq_block_native_dense_bytes` must be 0: the kernels read paged storage in
place).

Since PR 8 a ``packed`` section measures the sub-byte KV codecs: q4/q8
spill traffic vs int8 on the forced-spill trace, and the resident-q4
exact policy's pool footprint + kernel-vs-XLA greedy-token identity; the
trajectory file keeps only the newest ``BENCH_HISTORY_KEEP`` records.

Since PR 9 a ``recovery`` section measures fault-tolerant serving: SLO
shedding vs stalling goodput on an overload trace, corrupted-spill-page
recovery (survivor tokens bit-identical to the fault-free oracle), and
prefix-cache snapshot/restore (a restarted engine's warm hit tokens beat
cold).  The trajectory file itself is written atomically (temp +
``os.replace``) so a crashed bench never leaves a torn history.
"""
import argparse
import json
import os
import subprocess
import sys
import time


def run_csv() -> int:
  from benchmarks import (
      fig10_tradeoff,
      fig11_13_latency_model,
      table2_table3_sweeps,
      table4_ablation,
      table5_indirection,
  )
  from benchmarks import roofline

  print("name,us_per_call,derived")
  modules = [
      ("table2/3", table2_table3_sweeps),
      ("table4", table4_ablation),
      ("fig10", fig10_tradeoff),
      ("fig11-13", fig11_13_latency_model),
      ("table5", table5_indirection),
      ("roofline", roofline),
  ]
  failures = 0
  for name, mod in modules:
    try:
      for line in mod.run():
        print(line)
    except Exception as e:  # noqa: BLE001
      failures += 1
      print(f"{name}_ERROR,0.0,{type(e).__name__}:{e}")
  return 1 if failures else 0


def _load_history(out_path: str) -> list:
  """Existing run records; a legacy single-record file becomes run 0.

  An unparseable file is moved aside (never silently dropped — it is the
  accumulated perf trajectory this mode exists to preserve)."""
  if not os.path.exists(out_path):
    return []
  try:
    with open(out_path) as f:
      prev = json.load(f)
  except (OSError, ValueError) as e:
    backup = out_path + ".corrupt"
    os.replace(out_path, backup)
    print(f"WARNING: could not parse {out_path} ({e}); "
          f"moved it to {backup} and starting a fresh trajectory")
    return []
  if isinstance(prev, dict) and isinstance(prev.get("runs"), list):
    return prev["runs"]
  if isinstance(prev, dict) and prev:
    return [prev]
  return []


def _git_sha() -> str:
  try:
    return subprocess.check_output(
        ["git", "rev-parse", "--short", "HEAD"],
        cwd=os.path.dirname(os.path.abspath(__file__)),
        text=True, stderr=subprocess.DEVNULL).strip()
  except Exception:  # noqa: BLE001  (not a git checkout / no git binary)
    return "unknown"


def run_tiered_transfer(arch: str = "tinyllama-1.1b", prompt_len: int = 352,
                        gen: int = 48, block: int = 16, num_blocks: int = 46,
                        host_blocks: int = 192) -> dict:
  """Forced-spill trace through the tiered engine, per policy.

  The pool is sized to co-admit two long requests but not their decode
  growth, so each run swaps one victim to the host tier and fetches it
  back — making the tier-boundary bytes a measured, not modeled, quantity.
  Identical traffic for both policies; only the spilled representation
  differs (PQ code rows + resident rings/codebooks vs raw exact KV).
  """
  import dataclasses
  from repro.configs import get_arch
  from repro.launch.engine import ServeEngine

  out = {"cache_layout": "tiered", "scheduler": "tiered",
         "kv_block_size": block, "num_blocks": num_blocks,
         "host_blocks": host_blocks, "batch": 2, "prompt_len": prompt_len,
         "gen": gen, "policies": {}}
  for policy in ("pq", "exact"):
    cfg = dataclasses.replace(
        get_arch(arch, reduced=True), cache_policy=policy,
        dtype_str="bfloat16", cache_layout="tiered", scheduler="tiered",
        kv_block_size=block)
    eng = ServeEngine(cfg, context_len=prompt_len + gen, max_batch=2,
                      prompt_capacity=prompt_len, num_blocks=num_blocks,
                      host_blocks=host_blocks)
    for i in range(2):
      eng.submit([7 + i] * (prompt_len - 8 * i), max_new_tokens=gen)
    eng.run_to_completion()
    led = eng.layout.ledger
    by = eng.layout.bytes()
    out["policies"][policy] = {
        "spills": eng.stats.spills, "fetches": eng.stats.fetches,
        "prefetches": eng.stats.prefetches,
        "spill_bytes": led.spill_bytes,
        "spill_raw_bytes": led.spill_raw_bytes,
        "fetch_bytes": led.fetch_bytes,
        "modeled_pcie_s": round(led.modeled_pcie_s, 6),
        "layout_bytes": by,
    }
    print(f"tiered[{policy}]: {eng.stats.spills} spills "
          f"({led.spill_bytes} B), {eng.stats.fetches} fetches "
          f"({led.fetch_bytes} B)")
  exact_raw = out["policies"]["exact"]["spill_raw_bytes"]
  pq_bytes = out["policies"]["pq"]["spill_bytes"]
  out["pq_vs_exact_raw_spill"] = (
      round(pq_bytes / exact_raw, 4) if exact_raw else None)
  print(f"tiered: pq spill traffic = "
        f"{out['pq_vs_exact_raw_spill']} of exact raw")
  return out


def run_prefix_trace(arch: str = "tinyllama-1.1b", prompt_len: int = 64,
                     gen: int = 16, block: int = 16, num_blocks: int = 24,
                     users: int = 4, repeats: int = 2) -> dict:
  """Shared-prefix serving trace through the prefix cache, per policy.

  N users share one long system prompt with distinct short suffixes, plus
  `repeats` exact resubmissions (retry/regenerate traffic).  Each policy
  runs the identical trace twice — prefix cache off, then on — asserting
  token-identical outputs and recording what the cache saved: prefill
  tokens computed, hit rate, peak *mapped* KV blocks (a shared block counts
  once), dedup bytes, and COW forks.  `pq_vs_exact_block_bytes` is the
  footprint of one shared-prefix block under AQPIM PQ codes vs exact KV —
  the reason one cached prefix serves many more users inside the same
  device pool.
  """
  import dataclasses
  from repro.configs import get_arch
  from repro.launch.engine import ServeEngine

  sys_prompt = list(range(3, 3 + prompt_len - block))   # whole shared blocks
  trace = [(sys_prompt + [997 - 7 * u] * (block // 2), gen)
           for u in range(users)]
  trace += [trace[u % users] for u in range(repeats)]   # exact resubmits
  out = {"cache_layout": "paged", "kv_block_size": block,
         "num_blocks": num_blocks, "batch": 2, "prompt_len": prompt_len,
         "gen": gen, "users": users, "repeats": repeats, "policies": {}}
  for policy in ("pq", "exact"):
    cfg = dataclasses.replace(
        get_arch(arch, reduced=True), cache_policy=policy,
        dtype_str="bfloat16", cache_layout="paged", kv_block_size=block)
    off = ServeEngine(cfg, context_len=prompt_len + gen, max_batch=2,
                      prompt_capacity=prompt_len, num_blocks=num_blocks,
                      scheduler="paged")
    on = ServeEngine(cfg, context_len=prompt_len + gen, max_batch=2,
                     prompt_capacity=prompt_len, num_blocks=num_blocks,
                     scheduler="prefix", prefix_cache=True,
                     params=off.params)
    want = [off.submit(p, max_new_tokens=m) for p, m in trace]
    got = [on.submit(p, max_new_tokens=m) for p, m in trace]
    off.run_to_completion()
    on.run_to_completion()
    identical = all(w.tokens == g.tokens for w, g in zip(want, got))
    by_on = on.layout.bytes()
    by_off = off.layout.bytes()
    saved = 1.0 - (on.stats.prefill_tokens
                   / max(off.stats.prefill_tokens, 1))
    out["policies"][policy] = {
        "tokens_identical": identical,
        "prefill_tokens_nocache": off.stats.prefill_tokens,
        "prefill_tokens": on.stats.prefill_tokens,
        "prefill_tokens_saved_frac": round(saved, 4),
        "prefix_hits": on.stats.prefix_hits,
        "prefix_full_hits": on.stats.prefix_full_hits,
        "prefix_hit_rate": round(on.stats.prefix_hit_rate, 4),
        "forked_blocks": on.stats.forked_blocks,
        "dedup_bytes": on.stats.dedup_bytes,
        "block_bytes": by_on["block_bytes"],
        "peak_mapped_blocks": by_on["peak_mapped_blocks"],
        "peak_mapped_blocks_nocache": by_off["peak_mapped_blocks"],
        "peak_mapped_bytes": by_on["peak_mapped_bytes"],
        "peak_mapped_bytes_nocache": by_off["peak_mapped_bytes"],
    }
    print(f"prefix[{policy}]: prefill tokens {off.stats.prefill_tokens} -> "
          f"{on.stats.prefill_tokens} ({100 * saved:.0f}% saved), hit rate "
          f"{on.stats.prefix_hit_rate:.2f}, peak mapped blocks "
          f"{by_off['peak_mapped_blocks']} -> {by_on['peak_mapped_blocks']}"
          f"{'' if identical else '  TOKENS DIVERGED'}")
  exact_bb = out["policies"]["exact"]["block_bytes"]
  pq_bb = out["policies"]["pq"]["block_bytes"]
  out["pq_vs_exact_block_bytes"] = (round(pq_bb / exact_bb, 4)
                                    if exact_bb else None)
  print(f"prefix: pq shared-prefix block footprint = "
        f"{out['pq_vs_exact_block_bytes']} of exact")
  return out


def run_decode_kernels(arch: str = "tinyllama-1.1b", prompt_len: int = 32,
                       gen: int = 16, block: int = 16) -> dict:
  """Paged-engine decode trace per policy x decode kernel.

  Runs the identical staggered trace through the paged engine under the
  `xla` dispatch (dense gather->decode->scatter) and `pallas-interpret`
  (block-table-native kernels), asserting greedy-token identity and
  recording per-step latency percentiles plus the modeled decode HBM bytes
  (`CacheLayout.decode_traffic_model`).  The headline figure: under the
  block-native path the paged pq decode's dense-materialization bytes are 0
  — the kernel streams table-mapped pool blocks in place.  (Interpret-mode
  wall clock is not meaningful perf — the model figures are the comparison;
  on TPU the same record carries compiled-kernel numbers.)
  """
  import dataclasses
  from repro.common.timing import Stopwatch
  from repro.configs import get_arch
  from repro.launch.engine import ServeEngine

  out = {"cache_layout": "paged", "scheduler": "paged",
         "kv_block_size": block, "batch": 2, "prompt_len": prompt_len,
         "gen": gen, "policies": {}}
  trace = [(list(range(3, 3 + prompt_len - 4 * i)), gen) for i in range(4)]
  for policy in ("pq", "exact"):
    out["policies"][policy] = {}
    params = None
    toks = {}
    for kern in ("xla", "pallas-interpret"):
      cfg = dataclasses.replace(
          get_arch(arch, reduced=True), cache_policy=policy,
          dtype_str="bfloat16", cache_layout="paged", scheduler="paged",
          kv_block_size=block, decode_kernel=kern)
      eng = ServeEngine(cfg, context_len=prompt_len + gen, max_batch=2,
                        prompt_capacity=prompt_len, params=params)
      params = eng.params
      eng.submit([1] * 8, max_new_tokens=2)      # absorb the compiles
      eng.run_to_completion()
      eng.reset_stats()
      handles = [eng.submit(p, max_new_tokens=m) for p, m in trace]
      with Stopwatch() as sw:
        eng.run_to_completion()
      toks[kern] = [h.tokens for h in handles]
      n_tok = sum(len(t) for t in toks[kern])
      lat = eng.stats.decode_latency()
      out["policies"][policy][kern] = {
          "tok_per_s": round(n_tok / max(sw.seconds, 1e-9), 2),
          "decode_step_p50_ms": lat["p50_ms"],
          "decode_step_p99_ms": lat["p99_ms"],
          "block_native": bool(eng.layout.block_native),
          "decode_traffic": eng.layout.decode_traffic,
      }
      print(f"decode[{policy}/{kern}]: {n_tok} tok in {sw.seconds:.2f}s, "
            f"step p50 {lat['p50_ms']} ms, "
            f"path {eng.layout.decode_traffic['decode_path']} "
            f"(dense materialized "
            f"{eng.layout.decode_traffic['dense_materialized_bytes_per_step']}"
            f" B/step)")
    out["policies"][policy]["tokens_identical"] = (
        toks["xla"] == toks["pallas-interpret"])
    if not out["policies"][policy]["tokens_identical"]:
      print(f"decode[{policy}]: TOKENS DIVERGED across decode kernels")
  native = out["policies"]["pq"]["pallas-interpret"]["decode_traffic"]
  out["pq_block_native_dense_bytes"] = (
      native["dense_materialized_bytes_per_step"])
  dense = out["policies"]["pq"]["xla"]["decode_traffic"]
  out["pq_dense_gather_bytes"] = dense["dense_materialized_bytes_per_step"]
  print(f"decode: paged pq dense-materialized bytes/step "
        f"{out['pq_dense_gather_bytes']} (xla) -> "
        f"{out['pq_block_native_dense_bytes']} (block-native)")
  return out


def run_workload(arch: str = "tinyllama-1.1b", n_requests: int = 12,
                 seed: int = 3, pcie_gbps: float = 0.002) -> dict:
  """Trace-driven serving under the virtual clock, per policy x arrival.

  Each cell runs the identical seeded trace twice — overlapped spill/fetch
  vs the serialized fallback — asserting bit-identical greedy tokens and
  recording the SLO view (TTFT/TPOT percentiles, goodput, queueing) plus
  the stall attribution both ways; `transfer_stall_ratio` < 1 is the
  overlap win.  The pool is sized so the trace forces spills (the same
  pressure the tiered tests apply) and the link is slowed to ~MB/s so
  transfer time is visible against the fixed decode-step budget — at the
  real 16 GB/s these reduced-config payloads drain in microseconds and
  every mode looks identical.  A final re-run of one cell checks
  end-to-end determinism (same seed -> same token streams)."""
  import dataclasses
  from repro.configs import get_arch
  from repro.launch import workload as wl
  from repro.launch.engine import ServeEngine

  # per-policy sizing: pq needs sink+recent headroom and longer requests to
  # pressure the pool (its streaming window retires blocks as it decodes)
  sizing = {
      "exact": dict(context_len=64, prompt_capacity=32, num_blocks=5,
                    host_blocks=24, prompt_len=(20, 30), gen=(10, 16)),
      "pq": dict(context_len=96, prompt_capacity=64, num_blocks=7,
                 host_blocks=32, prompt_len=(42, 58), gen=(12, 24)),
  }
  out = {"cache_layout": "tiered", "scheduler": "tiered", "batch": 2,
         "kv_block_size": 16, "n_requests": n_requests, "seed": seed,
         "pcie_gbps": pcie_gbps, "policies": {}}
  params_by_policy: dict = {}

  def one(policy: str, arrival: str, overlap: bool):
    sz = sizing[policy]
    cfg = dataclasses.replace(
        get_arch(arch, reduced=True), cache_policy=policy,
        dtype_str="bfloat16", cache_layout="tiered", scheduler="tiered",
        kv_block_size=16)
    eng = ServeEngine(cfg, context_len=sz["context_len"], max_batch=2,
                      prompt_capacity=sz["prompt_capacity"],
                      num_blocks=sz["num_blocks"],
                      host_blocks=sz["host_blocks"],
                      params=params_by_policy.get(policy),
                      clock=wl.VirtualClock(overlap=overlap))
    params_by_policy[policy] = eng.params
    eng.layout.ledger.pcie_gbps = pcie_gbps
    spec = wl.WorkloadSpec(
        arrival=arrival, rate=400.0, burstiness=6.0, n_requests=n_requests,
        seed=seed, tenants=(wl.TenantSpec(prompt_len=sz["prompt_len"],
                                          max_new_tokens=sz["gen"]),))
    return eng, wl.WorkloadDriver(eng, spec).run()

  for policy in ("pq", "exact"):
    out["policies"][policy] = {}
    for arrival in ("poisson", "bursty"):
      eng_o, res_o = one(policy, arrival, True)
      eng_s, res_s = one(policy, arrival, False)
      identical = res_o.token_streams == res_s.token_streams
      rep = res_o.report
      stall_o = rep["stall"]["transfer_stall_s"]
      stall_s = res_s.report["stall"]["transfer_stall_s"]
      out["policies"][policy][arrival] = {
          "tokens_identical": identical,
          "requests": rep["requests"],
          "goodput_frac": rep["goodput_frac"],
          "goodput_tok_s": rep["goodput_tok_s"],
          "deadline_met_frac": rep["deadline_met_frac"],
          "ttft_p50_s": rep["ttft"]["p50_s"],
          "ttft_p99_s": rep["ttft"]["p99_s"],
          "tpot_p50_s": rep["tpot"]["p50_s"],
          "tpot_p99_s": rep["tpot"]["p99_s"],
          "queue_p99_s": rep["queue"]["p99_s"],
          "spills": eng_o.stats.spills, "fetches": eng_o.stats.fetches,
          "prefetches": eng_o.stats.prefetches,
          "stall": rep["stall"],
          "stall_serialized": res_s.report["stall"],
          "transfer_stall_ratio": (round(stall_o / stall_s, 4)
                                   if stall_s else None),
      }
      print(f"workload[{policy}/{arrival}]: goodput "
            f"{100 * rep['goodput_frac']:.0f}%, ttft p99 "
            f"{rep['ttft']['p99_s']} s, transfer stall {stall_o:.4f} s "
            f"overlapped vs {stall_s:.4f} s serialized"
            f"{'' if identical else '  TOKENS DIVERGED'}")
  # end-to-end determinism: the same (spec, seed) cell twice -> identical
  # token streams and SLO report
  _, a = one("exact", "poisson", True)
  _, b = one("exact", "poisson", True)
  out["determinism_ok"] = (a.token_streams == b.token_streams
                           and a.report == b.report)
  print(f"workload: determinism_ok={out['determinism_ok']}")
  return out


# One mesh cell, run in a fresh interpreter: the bench process's jax is
# already initialized with a single CPU device, and
# --xla_force_host_platform_device_count only takes effect before the first
# jax import — so every cell (mesh=1 included, same numerics baseline) is a
# subprocess with the flag in its environment.  Prints one JSON line.
_MESH_PROBE = r'''
import dataclasses, json, sys
import jax
from repro.common.timing import Stopwatch
from repro.configs import get_arch
from repro.launch.engine import ServeEngine

arch, policy, mesh_model = sys.argv[1], sys.argv[2], int(sys.argv[3])
cfg = dataclasses.replace(
    get_arch(arch, reduced=True), cache_policy=policy, dtype_str="bfloat16",
    cache_layout="paged", scheduler="paged", kv_block_size=16,
    # every probed mesh size must divide the kv heads for heads-mode
    # identity; the reduced configs ship 2 kv heads, so widen to 4x4 (g=1)
    n_heads=4, n_kv_heads=4)
eng = ServeEngine(cfg, context_len=48, max_batch=2, prompt_capacity=32,
                  mesh_model=mesh_model)
eng.submit([1] * 8, max_new_tokens=2)          # absorb the compiles
eng.run_to_completion()
eng.reset_stats()
trace = [(list(range(3, 35 - 5 * i)), 12) for i in range(4)]
hs = [eng.submit(p, max_new_tokens=m) for p, m in trace]
with Stopwatch() as sw:
  eng.run_to_completion()
n_tok = sum(len(h.tokens) for h in hs)
mi = eng.mesh_info()
ps = mi.get("per_shard")
if ps is None:                                 # mesh=1: no plan, pool local
  total = sum(l.nbytes for l in jax.tree_util.tree_leaves(eng.layout.storage))
  ps = {"bytes_per_shard": total, "total_bytes": total}
print(json.dumps({
    "tok_per_s": round(n_tok / max(sw.seconds, 1e-9), 2),
    "tokens": [h.tokens for h in hs],
    "mode": mi["mode"],
    "bytes_per_shard": ps["bytes_per_shard"],
    "total_bytes": ps["total_bytes"],
}))
'''


def run_mesh(arch: str = "tinyllama-1.1b", sizes=(1, 2, 4)) -> dict:
  """Sharded-serving scaling: tok/s and per-shard pool bytes vs mesh size.

  Each (policy, mesh) cell replays the identical staggered trace through the
  paged engine on a forced 8-host-device CPU mesh (see `_MESH_PROBE` for why
  each cell is a subprocess) and the record asserts greedy-token identity
  against the mesh=1 cell.  On CPU the tok/s column measures overhead, not
  speedup — the scaling claim needs real devices; the byte column is the
  capacity-wall figure: heads-mode pool bytes per shard drop ~1/N.
  """
  out = {"devices_forced": 8, "cache_layout": "paged", "scheduler": "paged",
         "batch": 2, "prompt_len": 32, "gen": 12, "sizes": list(sizes),
         "policies": {}}
  root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
  env = dict(os.environ,
             XLA_FLAGS="--xla_force_host_platform_device_count=8",
             JAX_PLATFORMS="cpu")
  env["PYTHONPATH"] = os.pathsep.join(
      [os.path.join(root, "src")]
      + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
  for policy in ("pq", "exact"):
    cells = {}
    for m in sizes:
      proc = subprocess.run(
          [sys.executable, "-c", _MESH_PROBE, arch, policy, str(m)],
          env=env, capture_output=True, text=True, timeout=1200)
      if proc.returncode != 0:
        raise RuntimeError(
            f"mesh probe {policy}/mesh={m} failed:\n{proc.stderr[-2000:]}")
      cells[m] = json.loads(proc.stdout.strip().splitlines()[-1])
    ref = cells[sizes[0]]["tokens"]
    identical = all(cells[m]["tokens"] == ref for m in sizes)
    out["policies"][policy] = {
        "tokens_identical": identical,
        "mesh": {str(m): {k: cells[m][k] for k in
                          ("tok_per_s", "mode", "bytes_per_shard",
                           "total_bytes")} for m in sizes},
    }
    line = ", ".join(
        f"x{m}: {cells[m]['tok_per_s']} tok/s "
        f"{cells[m]['bytes_per_shard']} B/shard ({cells[m]['mode']})"
        for m in sizes)
    print(f"mesh[{policy}]: {line}"
          f"{'' if identical else '  TOKENS DIVERGED'}")
  return out


def run_packed_codecs(arch: str = "tinyllama-1.1b", prompt_len: int = 352,
                      gen: int = 48, block: int = 16, num_blocks: int = 46,
                      host_blocks: int = 192) -> dict:
  """Packed KV codec measurements (kernels/packing.py), two levels.

  Spill: the PR 3 forced-spill trace through the tiered engine with the
  exact policy under spill codec int8 vs q4/q8 — identical traffic, only
  the host-tier representation differs.  `q4_vs_int8_spill_bytes` is the
  headline: the sub-byte group layout (f16 scale/min per 32 values +
  nibble codes) roughly halves int8's per-row f32-header layout.

  Resident: a short decode trace with the exact policy, dense fp32 store
  vs q4 packed resident store, each under `xla` vs `pallas-interpret` and
  across {paged, tiered} — asserting greedy-token identity between the
  packed block-native kernel and the dequantizing XLA reference (they
  share one dequant formula), and recording the pool capacity ratio
  (`resident_q4_vs_fp32_bytes`, ~0.19 at head_dim 16).
  """
  import dataclasses
  from repro.configs import get_arch
  from repro.launch.engine import ServeEngine

  out = {"kv_block_size": block, "batch": 2, "prompt_len": prompt_len,
         "gen": gen, "spill": {}, "resident": {}}
  for codec in ("int8", "q4", "q8"):
    cfg = dataclasses.replace(
        get_arch(arch, reduced=True), cache_policy="exact",
        dtype_str="bfloat16", cache_layout="tiered", scheduler="tiered",
        kv_block_size=block, spill_codec=codec)
    eng = ServeEngine(cfg, context_len=prompt_len + gen, max_batch=2,
                      prompt_capacity=prompt_len, num_blocks=num_blocks,
                      host_blocks=host_blocks)
    for i in range(2):
      eng.submit([7 + i] * (prompt_len - 8 * i), max_new_tokens=gen)
    eng.run_to_completion()
    led = eng.layout.ledger
    out["spill"][codec] = {
        "spills": eng.stats.spills, "fetches": eng.stats.fetches,
        "spill_bytes": led.spill_bytes,
        "spill_raw_bytes": led.spill_raw_bytes,
        "fetch_bytes": led.fetch_bytes,
        "modeled_pcie_s": round(led.modeled_pcie_s, 6),
    }
    print(f"packed-spill[{codec}]: {eng.stats.spills} spills "
          f"({led.spill_bytes} B post-codec, {led.spill_raw_bytes} B raw)")
  int8_b = out["spill"]["int8"]["spill_bytes"]
  for codec in ("q4", "q8"):
    out[f"{codec}_vs_int8_spill_bytes"] = (
        round(out["spill"][codec]["spill_bytes"] / int8_b, 4)
        if int8_b else None)
  print(f"packed: q4 spill traffic = {out['q4_vs_int8_spill_bytes']} of "
        f"int8 (q8 = {out['q8_vs_int8_spill_bytes']})")

  trace = [(list(range(3, 3 + 32 - 4 * i)), 16) for i in range(4)]
  params = None
  for layout in ("paged", "tiered"):
    toks = {}
    cap = {}
    for codec in ("none", "q4"):
      for kern in ("xla", "pallas-interpret"):
        cfg = dataclasses.replace(
            get_arch(arch, reduced=True), cache_policy="exact",
            dtype_str="float32", cache_layout=layout, scheduler=layout,
            kv_block_size=block, decode_kernel=kern,
            kv_resident_codec=codec)
        eng = ServeEngine(cfg, context_len=48, max_batch=2,
                          prompt_capacity=32, params=params)
        params = eng.params
        hs = [eng.submit(p, max_new_tokens=m) for p, m in trace]
        eng.run_to_completion()
        toks[(codec, kern)] = [h.tokens for h in hs]
        cap[codec] = eng.kv_bytes()["capacity_bytes"]
    cell = {
        "tokens_identical_q4": (toks[("q4", "xla")]
                                == toks[("q4", "pallas-interpret")]),
        "tokens_identical_fp32": (toks[("none", "xla")]
                                  == toks[("none", "pallas-interpret")]),
        "capacity_bytes_fp32": cap["none"],
        "capacity_bytes_q4": cap["q4"],
    }
    out["resident"][layout] = cell
    print(f"packed-resident[{layout}]: pool {cap['none']} B fp32 -> "
          f"{cap['q4']} B q4; kernel==xla tokens "
          f"q4={cell['tokens_identical_q4']} "
          f"fp32={cell['tokens_identical_fp32']}")
  fp32_cap = out["resident"]["paged"]["capacity_bytes_fp32"]
  out["resident_q4_vs_fp32_bytes"] = (
      round(out["resident"]["paged"]["capacity_bytes_q4"] / fp32_cap, 4)
      if fp32_cap else None)
  print(f"packed: resident q4 pool = {out['resident_q4_vs_fp32_bytes']} "
        f"of fp32")
  return out


def run_recovery(arch: str = "tinyllama-1.1b", n_requests: int = 16,
                 seed: int = 3, pcie_gbps: float = 0.002) -> dict:
  """Fault-tolerant serving measurements (PR 9), three legs.

  Shedding: an overload trace (tight SLOs against the fixed virtual-clock
  decode budget, small device pool) through the SLO-enforcing engine
  (`--scheduler slo --slo-enforce`) vs the stalling baseline on the
  identical trace.  The headline is `shed_vs_stall_goodput`: shedding
  doomed requests early must *raise* goodput tok/s — the survivors make
  their deadlines instead of everyone missing together.

  Faults: the corrupt-spill plan (checksummed spill frames, recovery via
  recompute-prefill) over the forced-spill trace, asserting surviving
  requests' greedy tokens match the fault-free oracle bit for bit.

  Restore: a shared-prefix trace served, the prefix cache snapshotted
  (checkpoint/ckpt.py), and a *fresh* engine restored from it replaying
  the trace — warm prefix hit-tokens must beat the cold engine's, with
  bit-identical token streams.
  """
  import dataclasses
  import tempfile
  from repro.configs import get_arch
  from repro.launch import slo as slo_lib
  from repro.launch import workload as wl
  from repro.launch.engine import ServeEngine
  from repro.runtime.fault_tolerance import make_fault_plan

  sz = dict(context_len=64, prompt_capacity=32, num_blocks=5,
            host_blocks=24, prompt_len=(20, 30), gen=(10, 16))
  cfg = dataclasses.replace(
      get_arch(arch, reduced=True), cache_policy="exact",
      dtype_str="bfloat16", cache_layout="tiered", scheduler="tiered",
      kv_block_size=16)
  params_box: dict = {}

  def tiered(scheduler="tiered", **kw):
    c = dataclasses.replace(cfg, scheduler=scheduler)
    eng = ServeEngine(c, context_len=sz["context_len"], max_batch=2,
                      prompt_capacity=sz["prompt_capacity"],
                      num_blocks=sz["num_blocks"],
                      host_blocks=sz["host_blocks"],
                      params=params_box.get("p"),
                      clock=wl.VirtualClock(), **kw)
    params_box["p"] = eng.params
    eng.layout.ledger.pcie_gbps = pcie_gbps
    return eng

  out = {"cache_layout": "tiered", "batch": 2, "kv_block_size": 16,
         "n_requests": n_requests, "seed": seed, "pcie_gbps": pcie_gbps}

  # --- shedding vs stalling under overload -------------------------------
  tight = slo_lib.SLOSpec(ttft_s=0.02, tpot_s=0.002)
  tenant = wl.TenantSpec(prompt_len=sz["prompt_len"],
                         max_new_tokens=sz["gen"], slo=tight)
  over = wl.WorkloadSpec(arrival="poisson", rate=400.0, burstiness=6.0,
                         n_requests=n_requests, seed=seed, tenants=(tenant,))
  shed_eng = tiered(scheduler="slo", slo_enforce=True)
  r_shed = wl.WorkloadDriver(shed_eng, over).run()
  stall_eng = tiered()
  r_stall = wl.WorkloadDriver(stall_eng, over).run()
  out["shedding"] = {
      "scheduler": "slo",
      "shed_requests": shed_eng.stats.shed_requests,
      "degradation_state": shed_eng.stats.degradation_state,
      "degradation_transitions": len(shed_eng.stats.degradation_transitions),
      "goodput_tok_s": r_shed.report["goodput_tok_s"],
      "goodput_frac": r_shed.report["goodput_frac"],
      "goodput_tok_s_no_shedding": r_stall.report["goodput_tok_s"],
      "goodput_frac_no_shedding": r_stall.report["goodput_frac"],
      "shed_vs_stall_goodput": (
          round(r_shed.report["goodput_tok_s"]
                / r_stall.report["goodput_tok_s"], 4)
          if r_stall.report["goodput_tok_s"] else None),
  }
  print(f"recovery[shedding]: goodput {r_shed.report['goodput_tok_s']} "
        f"tok/s shedding ({shed_eng.stats.shed_requests} shed) vs "
        f"{r_stall.report['goodput_tok_s']} tok/s stalling")

  # --- corrupted spill pages: survivors bit-identical to the oracle ------
  base = wl.WorkloadSpec(
      arrival="poisson", rate=400.0, burstiness=6.0, n_requests=8,
      seed=seed, tenants=(wl.TenantSpec(prompt_len=sz["prompt_len"],
                                        max_new_tokens=sz["gen"]),))
  oracle_eng = tiered()
  r_oracle = wl.WorkloadDriver(oracle_eng, base).run()
  fault_eng = tiered(fault_injector=make_fault_plan(
      "corrupt-spill", 1.0, seed=seed, max_failures=2))
  r_fault = wl.WorkloadDriver(fault_eng, base).run()
  survivors_ok = all(
      toks == r_oracle.token_streams[i]
      for i, toks in r_fault.token_streams.items()
      if i not in r_fault.failed_indices)
  out["faults"] = {
      "kind": "corrupt-spill",
      "corrupt_pages": fault_eng.stats.corrupt_pages,
      "failed": len(r_fault.failed_indices),
      "survivor_tokens_identical": survivors_ok,
  }
  print(f"recovery[faults]: {fault_eng.stats.corrupt_pages} corrupt pages "
        f"recovered, survivors identical={survivors_ok}")

  # --- snapshot/restore: warm prefix hits after a restart ----------------
  def paged(snapshot_dir=None):
    c = dataclasses.replace(cfg, cache_layout="paged", scheduler="paged")
    return ServeEngine(c, context_len=sz["context_len"], max_batch=2,
                       prompt_capacity=sz["prompt_capacity"], num_blocks=10,
                       prefix_cache=True, params=params_box.get("p"),
                       clock=wl.VirtualClock(), snapshot_dir=snapshot_dir)

  shared = wl.WorkloadSpec(
      arrival="poisson", rate=200.0, n_requests=6, seed=seed + 2,
      tenants=(wl.TenantSpec(prompt_len=(20, 28), max_new_tokens=(6, 10),
                             shared_prefix_len=16),))
  with tempfile.TemporaryDirectory() as snap_dir:
    e1 = paged(snapshot_dir=snap_dir)
    wl.WorkloadDriver(e1, shared).run()
    e1.save_snapshot(step=1)
    warm = paged(snapshot_dir=snap_dir)
    r_warm = wl.WorkloadDriver(warm, shared).run()
    cold = paged()
    r_cold = wl.WorkloadDriver(cold, shared).run()
  tokens_ok = r_warm.token_streams == r_cold.token_streams
  out["restore"] = {
      "restored_prefix_blocks": warm.stats.restored_prefix_blocks,
      "warm_hit_tokens": warm.layout.prefix_index.hit_tokens,
      "cold_hit_tokens": cold.layout.prefix_index.hit_tokens,
      "tokens_identical": tokens_ok,
  }
  print(f"recovery[restore]: {warm.stats.restored_prefix_blocks} blocks "
        f"restored, hit tokens {cold.layout.prefix_index.hit_tokens} cold "
        f"-> {warm.layout.prefix_index.hit_tokens} warm, "
        f"tokens identical={tokens_ok}")

  # --- shard loss: host-mirror restore vs abort-and-recompute ------------
  out["shard"] = run_shard_recovery(arch, seed=seed)
  return out


# One shard-recovery cell in a fresh interpreter: like `_MESH_PROBE`, the
# 4-way mesh needs 8 forced host devices, which only takes effect before
# the first jax import.  A seeded shard-loss plan kills one shard mid-run;
# the cell serves the identical workload under one --shard-redundancy mode
# and prints its goodput + recovery counters as one JSON line.
_SHARD_PROBE = r'''
import dataclasses, json, sys
import jax
from repro.configs import get_arch
from repro.launch import workload as wl
from repro.launch.engine import ServeEngine
from repro.runtime import fault_tolerance as ft

arch, redundancy, seed = sys.argv[1], sys.argv[2], int(sys.argv[3])
assert len(jax.devices()) == 8, jax.devices()
cfg = dataclasses.replace(
    get_arch(arch, reduced=True), cache_policy="exact", dtype_str="bfloat16",
    cache_layout="tiered", scheduler="tiered", kv_block_size=16,
    # 4 kv heads so the 4-way mesh runs heads mode (a dead shard then voids
    # a kv-head slice of every block — the case redundancy exists for)
    n_heads=4, n_kv_heads=4)
plan = ft.make_fault_plan("shard-loss", 0.05, seed=seed, max_failures=1)
eng = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                  num_blocks=5, host_blocks=24, mesh_model=4,
                  shard_redundancy=redundancy, fault_injector=plan,
                  clock=wl.VirtualClock())
spec = wl.WorkloadSpec(arrival="poisson", rate=400.0, burstiness=6.0,
                       n_requests=12, seed=seed,
                       tenants=(wl.TenantSpec(prompt_len=(20, 30),
                                              max_new_tokens=(10, 16)),))
r = wl.WorkloadDriver(eng, spec).run()
print(json.dumps({
    "goodput_tok_s": r.report["goodput_tok_s"],
    "goodput_frac": r.report["goodput_frac"],
    "served_tok_s": r.report["served_tok_s"],
    "losses": eng.stats.shard_losses,
    "replans": eng.stats.shard_replans,
    "mirror_restores": eng.stats.shard_mirror_restores,
    "recovered_requests": eng.stats.shard_recovered_requests,
    "preempts": eng.stats.preempts,
    "failed": len(r.failed_indices),
    "tokens": {str(k): list(v) for k, v in sorted(r.token_streams.items())},
}))
'''


def run_shard_recovery(arch: str = "tinyllama-1.1b", seed: int = 3) -> dict:
  """Shard-loss recovery (PR 10): `--shard-redundancy host-mirror` vs
  `none` on the identical seeded kill.

  Both cells replay the same workload on a 4-way heads mesh and lose the
  same shard at the same step; `none` recovers every resident request by
  abort-and-recompute (PR 9's recompute-prefill path), `host-mirror` by
  checksummed host-copy fetch + re-scatter under the replanned mesh.  The
  headline is `mirror_vs_recompute_goodput` > 1: restoring KV beats
  regenerating it.  Token streams must agree across the two modes (greedy
  decode: recovery changes *when* tokens appear, never *which*)."""
  root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
  env = dict(os.environ,
             XLA_FLAGS="--xla_force_host_platform_device_count=8",
             JAX_PLATFORMS="cpu")
  env["PYTHONPATH"] = os.pathsep.join(
      [os.path.join(root, "src")]
      + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
  cells = {}
  for redundancy in ("none", "host-mirror"):
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_PROBE, arch, redundancy, str(seed)],
        env=env, capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
      raise RuntimeError(
          f"shard recovery probe {redundancy} failed:\n{proc.stderr[-2000:]}")
    cells[redundancy] = json.loads(proc.stdout.strip().splitlines()[-1])
  none_c, mirror_c = cells["none"], cells["host-mirror"]
  tokens_ok = none_c["tokens"] == mirror_c["tokens"]
  out = {
      "devices_forced": 8, "mesh_model": 4, "mode": "heads", "seed": seed,
      "tokens_identical": tokens_ok,
      "none": {k: none_c[k] for k in
               ("goodput_tok_s", "goodput_frac", "served_tok_s", "losses",
                "replans", "mirror_restores", "recovered_requests",
                "preempts", "failed")},
      "host_mirror": {k: mirror_c[k] for k in
                      ("goodput_tok_s", "goodput_frac", "served_tok_s",
                       "losses", "replans", "mirror_restores",
                       "recovered_requests", "preempts", "failed")},
      "mirror_vs_recompute_goodput": (
          round(mirror_c["goodput_tok_s"] / none_c["goodput_tok_s"], 4)
          if none_c["goodput_tok_s"] else None),
  }
  print(f"recovery[shard]: goodput {mirror_c['goodput_tok_s']} tok/s "
        f"host-mirror ({mirror_c['mirror_restores']} restores) vs "
        f"{none_c['goodput_tok_s']} tok/s recompute "
        f"({none_c['preempts']} preempts), tokens identical={tokens_ok}")
  return out


#: --json keeps this many newest run records; the trajectory file was
#: growing ~400 lines per PR unbounded.  Legacy records (including a
#: pre-trajectory single-record file, migrated by _load_history) are
#: preserved until they age past the window.
BENCH_HISTORY_KEEP = 50


def run_serve_json(out_path: str, arch: str = "tinyllama-1.1b",
                   batch: int = 2, prompt_len: int = 64, gen: int = 16) -> int:
  from repro.launch.serve import ServeRun

  record = {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "git_sha": _git_sha(), "arch": arch, "reduced": True,
            "batch": batch, "prompt_len": prompt_len, "gen": gen,
            # the timed loop decodes fixed-batch over contiguous slabs; the
            # tiered section below carries the pooled-layout axes
            "cache_layout": "contiguous", "scheduler": "fixed-batch",
            "kv_block_size": 0, "policies": {}}
  for policy in ("pq", "exact"):
    run = ServeRun(arch=arch, reduced=True, batch=batch,
                   prompt_len=prompt_len, gen=gen, cache_policy=policy)
    res = run.run()
    record["policies"][policy] = {
        "tok_per_s": round(res["tok_per_s"], 2),
        "prefill_s": round(res["prefill_s"], 4),
        "decode_s": round(res["decode_s"], 4),
        "decode_step_p50_ms": res["decode_step_p50_ms"],
        "decode_step_p99_ms": res["decode_step_p99_ms"],
        "decode_kernel": res["decode_kernel"],
    }
    print(f"serve[{policy}]: {res['tok_per_s']:.1f} tok/s "
          f"(prefill {res['prefill_s']:.2f}s, decode {res['decode_s']:.2f}s, "
          f"step p50 {res['decode_step_p50_ms']:.2f} / p99 "
          f"{res['decode_step_p99_ms']:.2f} ms)")
  from repro.configs import get_arch
  if get_arch(arch, reduced=True).family in ("dense", "moe"):
    record["tiered"] = run_tiered_transfer(arch)
  else:
    # ServeEngine (and therefore the tiered trace) rejects recurrent/modal
    # families; keep the timed record instead of dying on the extra section
    record["tiered"] = None
    print(f"tiered: skipped ({arch} family not engine-servable)")
  if get_arch(arch, reduced=True).family == "dense":
    record["prefix"] = run_prefix_trace(arch)
  else:
    # chain sharing needs causal per-position prefill (dense family)
    record["prefix"] = None
    print(f"prefix: skipped ({arch} family has no chunked suffix prefill)")
  if get_arch(arch, reduced=True).family in ("dense", "moe"):
    record["decode_kernels"] = run_decode_kernels(arch)
  else:
    record["decode_kernels"] = None
    print(f"decode kernels: skipped ({arch} family not engine-servable)")
  if get_arch(arch, reduced=True).family in ("dense", "moe"):
    record["workload"] = run_workload(arch)
  else:
    record["workload"] = None
    print(f"workload: skipped ({arch} family not engine-servable)")
  if get_arch(arch, reduced=True).family in ("dense", "moe"):
    record["mesh"] = run_mesh(arch)
  else:
    record["mesh"] = None
    print(f"mesh: skipped ({arch} family not engine-servable)")
  if get_arch(arch, reduced=True).family in ("dense", "moe"):
    record["packed"] = run_packed_codecs(arch)
  else:
    record["packed"] = None
    print(f"packed codecs: skipped ({arch} family not engine-servable)")
  if get_arch(arch, reduced=True).family == "dense":
    record["recovery"] = run_recovery(arch)
  else:
    # the restore leg needs the prefix cache's chunked suffix prefill
    record["recovery"] = None
    print(f"recovery: skipped ({arch} family has no prefix cache)")
  history = _load_history(out_path)
  history.append(record)
  dropped = len(history) - BENCH_HISTORY_KEEP
  if dropped > 0:
    history = history[-BENCH_HISTORY_KEEP:]
    print(f"pruned {dropped} oldest run record(s); keeping the newest "
          f"{BENCH_HISTORY_KEEP}")
  from repro.launch.serve import write_json_atomic
  write_json_atomic(out_path, {"runs": history})
  print(f"appended run {len(history)} to {out_path}")
  return 0


def main() -> None:
  ap = argparse.ArgumentParser(description=__doc__)
  ap.add_argument("--json", action="store_true",
                  help="run the serve benchmark and append a timestamped "
                       "record to the JSON trajectory")
  ap.add_argument("--out", default="BENCH_serve.json",
                  help="output path for --json mode")
  ap.add_argument("--arch", default="tinyllama-1.1b")
  ap.add_argument("--batch", type=int, default=2)
  ap.add_argument("--prompt-len", type=int, default=64)
  ap.add_argument("--gen", type=int, default=16)
  args = ap.parse_args()
  if args.json:
    sys.exit(run_serve_json(args.out, arch=args.arch, batch=args.batch,
                            prompt_len=args.prompt_len, gen=args.gen))
  sys.exit(run_csv())


if __name__ == '__main__':
  main()
