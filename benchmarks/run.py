"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.  Roofline rows require the dry-run
JSONs (python -m repro.launch.dryrun); other benches are self-contained."""
import sys


def main() -> None:
  from benchmarks import (
      fig10_tradeoff,
      fig11_13_latency_model,
      table2_table3_sweeps,
      table4_ablation,
      table5_indirection,
  )
  from benchmarks import roofline

  print("name,us_per_call,derived")
  modules = [
      ("table2/3", table2_table3_sweeps),
      ("table4", table4_ablation),
      ("fig10", fig10_tradeoff),
      ("fig11-13", fig11_13_latency_model),
      ("table5", table5_indirection),
      ("roofline", roofline),
  ]
  failures = 0
  for name, mod in modules:
    try:
      for line in mod.run():
        print(line)
    except Exception as e:  # noqa: BLE001
      failures += 1
      print(f"{name}_ERROR,0.0,{type(e).__name__}:{e}")
  if failures:
    sys.exit(1)


if __name__ == '__main__':
  main()
