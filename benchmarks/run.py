"""Benchmark harness: one function per paper table/figure.

Default mode prints ``name,us_per_call,derived`` CSV.  Roofline rows require
the dry-run JSONs (python -m repro.launch.dryrun); other benches are
self-contained.

``--json`` instead runs the serving benchmark (tinyllama reduced, `pq` vs
`exact` cache policy through `repro.launch.serve.ServeRun`) and writes a
``BENCH_serve.json`` with tok/s — the start of the serving perf trajectory.
"""
import argparse
import json
import sys


def run_csv() -> int:
  from benchmarks import (
      fig10_tradeoff,
      fig11_13_latency_model,
      table2_table3_sweeps,
      table4_ablation,
      table5_indirection,
  )
  from benchmarks import roofline

  print("name,us_per_call,derived")
  modules = [
      ("table2/3", table2_table3_sweeps),
      ("table4", table4_ablation),
      ("fig10", fig10_tradeoff),
      ("fig11-13", fig11_13_latency_model),
      ("table5", table5_indirection),
      ("roofline", roofline),
  ]
  failures = 0
  for name, mod in modules:
    try:
      for line in mod.run():
        print(line)
    except Exception as e:  # noqa: BLE001
      failures += 1
      print(f"{name}_ERROR,0.0,{type(e).__name__}:{e}")
  return 1 if failures else 0


def run_serve_json(out_path: str, arch: str = "tinyllama-1.1b",
                   batch: int = 2, prompt_len: int = 64, gen: int = 16) -> int:
  from repro.launch.serve import ServeRun

  results = {"arch": arch, "reduced": True, "batch": batch,
             "prompt_len": prompt_len, "gen": gen, "policies": {}}
  for policy in ("pq", "exact"):
    run = ServeRun(arch=arch, reduced=True, batch=batch,
                   prompt_len=prompt_len, gen=gen, cache_policy=policy)
    res = run.run()
    results["policies"][policy] = {
        "tok_per_s": round(res["tok_per_s"], 2),
        "prefill_s": round(res["prefill_s"], 4),
        "decode_s": round(res["decode_s"], 4),
    }
    print(f"serve[{policy}]: {res['tok_per_s']:.1f} tok/s "
          f"(prefill {res['prefill_s']:.2f}s, decode {res['decode_s']:.2f}s)")
  with open(out_path, "w") as f:
    json.dump(results, f, indent=2)
    f.write("\n")
  print(f"wrote {out_path}")
  return 0


def main() -> None:
  ap = argparse.ArgumentParser(description=__doc__)
  ap.add_argument("--json", action="store_true",
                  help="run the serve benchmark and write a JSON summary")
  ap.add_argument("--out", default="BENCH_serve.json",
                  help="output path for --json mode")
  ap.add_argument("--arch", default="tinyllama-1.1b")
  ap.add_argument("--batch", type=int, default=2)
  ap.add_argument("--prompt-len", type=int, default=64)
  ap.add_argument("--gen", type=int, default=16)
  args = ap.parse_args()
  if args.json:
    sys.exit(run_serve_json(args.out, arch=args.arch, batch=args.batch,
                            prompt_len=args.prompt_len, gen=args.gen))
  sys.exit(run_csv())


if __name__ == '__main__':
  main()
