"""Paper Table V: intra-row indirection at BankPE vs gather at BufferPE.

TPU analogue: gather *inside* the Pallas kernel (table pinned in VMEM, index
blocks read from HBM once) vs gather *outside* the kernel (XLA take on
HBM-resident tables: the inner-product table is written to HBM and re-read,
plus a full (N, m) gathered matrix materializes).  We report the bytes each
variant moves through HBM — the quantity row-activations proxy on PIM — plus
wall-clock of both on this host (indicative only on CPU)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import pq_attention as pqa
from repro.kernels import ops


def run(n: int = 4096, d: int = 128, m: int = 32, k: int = 512, g: int = 4
        ) -> list:
  rng = np.random.default_rng(0)
  dsub = d // m
  kcb = jnp.asarray(rng.normal(size=(1, 1, m, k, dsub)), jnp.float32)
  vcb = jnp.asarray(rng.normal(size=(1, 1, m, k, dsub)), jnp.float32)
  kix = jnp.asarray(rng.integers(0, k, size=(1, 1, n, m)), jnp.int32)
  vix = jnp.asarray(rng.integers(0, k, size=(1, 1, n, m)), jnp.int32)
  q = jnp.asarray(rng.normal(size=(1, 1, g, d)), jnp.float32)
  length = jnp.full((1, 1), n, jnp.int32)
  scale = 1 / np.sqrt(d)

  # in-kernel (VMEM) gather — the AQPIM co-design path
  def kernel_path():
    out, mx, dn = ops.pq_decode_attention(
        q, kcb, vcb, kix, vix, length, scale, blk=512)
    return out
  us_kernel = common.time_us(kernel_path, iters=3)

  # out-of-kernel gather: tables and gathered scores round-trip HBM
  def xla_path():
    table = pqa.inner_product_table(q[0, 0], kcb[0, 0])
    s = pqa.lookup_scores(table, kix[0, 0]) * scale
    p = jax.nn.softmax(s, axis=-1)
    buckets = pqa.bucket_accumulate(p, vix[0, 0], k)
    return pqa.output_from_buckets(buckets, vcb[0, 0])
  us_xla = common.time_us(jax.jit(xla_path), iters=3)

  # HBM byte accounting (per decode step, per head)
  idx_bytes = n * m * 2 * 2                     # int16 K+V indices, read once
  cb_bytes = 2 * m * k * dsub * 2               # codebooks, read once
  in_kernel = idx_bytes + cb_bytes
  # outside: + table write/read + gathered (N, m) matrix write/read (f32)
  table_rt = 2 * (g * m * k * 4) * 2
  gathered_rt = 2 * (n * m * 4) * 2
  outside = in_kernel + table_rt + gathered_rt

  lines = [
      common.csv_line(
          "table5_gather_in_kernel", us_kernel,
          f"hbm_bytes={in_kernel};(indices+codebook, one pass)"),
      common.csv_line(
          "table5_gather_outside", us_xla,
          f"hbm_bytes={outside};overhead={outside / in_kernel:.2f}x"),
      common.csv_line(
          "table5_paper_claim", 0.0,
          "key 33089 vs 37185 cycles; value 7373 vs 181875 (BankPE wins)"),
  ]
  return lines


if __name__ == "__main__":
  for line in run():
    print(line)
