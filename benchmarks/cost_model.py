"""Implementation-aware analytic cost model for the roofline analysis.

WHY ANALYTIC: XLA's HloCostAnalysis on this backend counts while-loop bodies
ONCE (not x trip count).  Our models scan over layers and attention blocks, so
compiled `cost_analysis()` under-reports flops/bytes by the loop trip counts
(verified empirically: flops are L-independent).  We therefore derive the
roofline terms from this analytic model of OUR implementation, and VALIDATE it
against compiled HLO on small unrolled configs (tests/test_cost_model.py).
The dry-run JSONs still contribute the ground-truth per-device memory analysis
and the collective-op schedule.

Conventions: FLOPs count multiply-adds as 2; bf16 = 2 bytes; f32 = 4.
All numbers are GLOBAL (whole step, all chips); roofline.py divides by chips.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig

BF16 = 2
F32 = 4


# ---------------------------------------------------------------------------
# forward FLOPs
# ---------------------------------------------------------------------------

def _attn_linear_flops_per_tok(cfg: ModelConfig) -> float:
  """QKV + output projections."""
  d, hd = cfg.d_model, cfg.head_dim
  return 2 * d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)


def _ffn_flops_per_tok(cfg: ModelConfig) -> float:
  d = cfg.d_model
  if cfg.n_experts > 0:
    routed = 3 * 2 * d * cfg.moe_d_ff * cfg.top_k
    shared = 3 * 2 * d * cfg.moe_d_ff * cfg.n_shared_experts
    router = 2 * d * cfg.n_experts
    return routed + shared + router
  return 3 * 2 * d * cfg.d_ff


def _rwkv_flops_per_tok(cfg: ModelConfig) -> float:
  d, hd = cfg.d_model, cfg.head_dim
  proj = 2 * d * d * 6            # r/k/v/g/o + loras(~1x d*d total)
  wkv = 6 * d * hd                # kv outer + state update + readout
  cm = 2 * 2 * d * cfg.d_ff + 2 * d * d
  return proj + wkv + cm


def _ssm_flops_per_tok(cfg: ModelConfig) -> float:
  d, di, n = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
  proj = 2 * d * 2 * di + 2 * di * d
  scan = 6 * di * n + 2 * di * (2 * n) + 2 * di * (d // 16)
  conv = 2 * 4 * di
  return proj + scan + conv


def _attn_quad_flops(cfg: ModelConfig, b: int, s: int) -> float:
  """Causal full attention: scores + values, per layer."""
  return 2 * (2 * b * s * s * cfg.n_heads * cfg.head_dim) / 2  # causal half


def forward_flops(cfg: ModelConfig, b: int, s: int) -> float:
  """One full-sequence forward pass (training/prefill compute)."""
  tok = b * s
  if cfg.family == "ssm":
    per_layer = _rwkv_flops_per_tok(cfg) * tok
    core = cfg.n_layers * per_layer
  else:
    per_layer = (_attn_linear_flops_per_tok(cfg)
                 + _ffn_flops_per_tok(cfg)) * tok
    per_layer += _attn_quad_flops(cfg, b, s)
    if cfg.hybrid:
      per_layer += _ssm_flops_per_tok(cfg) * tok
    core = cfg.n_layers * per_layer
    if cfg.cross_attn_period:
      n_cross = cfg.n_layers // cfg.cross_attn_period
      cross = (_attn_linear_flops_per_tok(cfg) * tok
               + 2 * 2 * b * s * cfg.n_modal_tokens * cfg.n_heads
               * cfg.head_dim
               + 3 * 2 * cfg.d_model * cfg.d_ff * tok)
      core += n_cross * cross
  head = 2 * cfg.d_model * cfg.vocab_size * tok
  return core + head


def clustering_flops(cfg: ModelConfig, b: int, s: int) -> float:
  """PQ codebook generation at prefill (the work PIM hides): weighted k-means,
  4 iterations, per (layer, batch, kv-head), K & V."""
  if cfg.resolved_cache_policy() != "pq":
    return 0.0
  iters = 4
  n = max(s - cfg.pq_sink - cfg.pq_recent, 1)
  hd = cfg.head_dim
  # assign: 2*N*K*hd ; update one-hot matmul: 2*N*K*hd  (per head, all m subvecs)
  per_head = iters * 2 * (2 * n * cfg.pq_k * hd)
  # importance weights: t trailing queries vs all keys
  per_head += 2 * cfg.pq_recent * s * hd
  return cfg.n_layers * b * cfg.n_kv_heads * 2 * per_head


def train_step_flops(cfg: ModelConfig, b: int, s: int) -> float:
  """fwd + bwd(2x) + full remat(+1x fwd) + optimizer (negligible)."""
  mult = 4.0 if cfg.remat else 3.0
  return mult * forward_flops(cfg, b, s)


def decode_step_flops(cfg: ModelConfig, b: int, n_ctx: int) -> float:
  """One-token decode against a cache of n_ctx."""
  tok = b
  if cfg.family == "ssm":
    core = cfg.n_layers * _rwkv_flops_per_tok(cfg) * tok
    return core + 2 * cfg.d_model * cfg.vocab_size * tok
  per_layer = (_attn_linear_flops_per_tok(cfg)
               + _ffn_flops_per_tok(cfg)) * tok
  if cfg.hybrid:
    per_layer += _ssm_flops_per_tok(cfg) * tok
  pq = cfg.pq_cache_config(n_ctx)
  h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
  if pq is None:
    attn = 4 * n_ctx * h * hd * tok
  else:
    k_cent, m = pq.pq.k, pq.pq.m
    table = 2 * 2 * h * k_cent * hd            # key table + value combine
    lookup = 2 * h * n_ctx * m                 # score gather+add
    bucket = 2 * h * n_ctx * m                 # prob scatter-add
    exact_part = 4 * (pq.sink + pq.recent) * h * hd
    encode = 2 * 2 * hkv * k_cent * hd         # evicted-token encode (K & V)
    attn = (table + lookup + bucket + exact_part + encode) * tok
  per_layer += attn
  core = cfg.n_layers * per_layer
  if cfg.cross_attn_period:
    n_cross = cfg.n_layers // cfg.cross_attn_period
    core += n_cross * (
        _attn_linear_flops_per_tok(cfg) * tok
        + 2 * cfg.n_modal_tokens * cfg.d_model * cfg.head_dim * 0  # cached
        + 4 * cfg.n_modal_tokens * h * hd * tok
        + 3 * 2 * cfg.d_model * cfg.d_ff * tok)
  return core + 2 * cfg.d_model * cfg.vocab_size * tok


# ---------------------------------------------------------------------------
# HBM bytes
# ---------------------------------------------------------------------------

def param_bytes(cfg: ModelConfig) -> float:
  """bf16 storage, or int8 + per-channel scales when weight_quant='int8'."""
  if getattr(cfg, "weight_quant", "none") == "int8":
    return cfg.total_params() * 1.02   # int8 + ~2% scale overhead
  return cfg.total_params() * BF16


def kv_cache_bytes(cfg: ModelConfig, b: int, n_ctx: int) -> float:
  """Decode-attention context bytes actually read per step."""
  if cfg.family == "ssm":
    hd = cfg.head_dim
    return cfg.n_layers * b * cfg.n_heads * hd * hd * F32
  pq = cfg.pq_cache_config(n_ctx)
  hkv, hd = cfg.n_kv_heads, cfg.head_dim
  per_head_layer_batch = (
      n_ctx * hd * BF16 * 2 if pq is None else
      n_ctx * pq.pq.m * pq.pq.index_bytes() * 2
      + pq.n_windows * pq.pq.m * pq.pq.k * (hd // pq.pq.m) * BF16 * 2
      + (pq.sink + pq.recent) * hd * BF16 * 2)
  total = cfg.n_layers * b * hkv * per_head_layer_batch
  if cfg.hybrid:
    total += cfg.n_layers * b * cfg.ssm_d_inner * cfg.ssm_state * F32
  return total


def train_step_bytes(cfg: ModelConfig, b: int, s: int) -> float:
  p = cfg.total_params()
  # params: fwd read + bwd read + grad write; opt: master/mu/nu read+write f32
  par = p * (3 * BF16 + 6 * F32)   # training always bf16 weights
  # activations: ~12 tensor passes of (B,S,D) per layer (remat recompute incl.)
  act = cfg.n_layers * 12 * b * s * cfg.d_model * BF16
  # flash streaming re-reads: K,V per q-block pass
  n_blk = max(s // cfg.attn_block, 1)
  act += cfg.n_layers * 2 * n_blk * b * s * cfg.n_kv_heads * cfg.head_dim * BF16
  return par + act


def prefill_step_bytes(cfg: ModelConfig, b: int, s: int) -> float:
  par = param_bytes(cfg)
  if getattr(cfg, "context_parallel", False):
    par = par * 1.0   # replicated reads count once per chip (roofline.py /chips
                      # then under-divides; keep conservative: same as sharded)
  act = cfg.n_layers * 8 * b * s * cfg.d_model * BF16
  n_blk = max(s // cfg.attn_block, 1)
  act += cfg.n_layers * 2 * n_blk * b * s * cfg.n_kv_heads * cfg.head_dim * BF16
  # clustering passes: 4 iters x (read body K/V per subvector sweep)
  if cfg.resolved_cache_policy() == "pq":
    act += cfg.n_layers * b * cfg.n_kv_heads * 2 * 4 * s * cfg.head_dim * F32
  # cache write
  act += kv_cache_bytes(cfg, b, s)
  return par + act


def decode_step_bytes(cfg: ModelConfig, b: int, n_ctx: int) -> float:
  return (param_bytes(cfg) + kv_cache_bytes(cfg, b, n_ctx)
          + cfg.n_layers * 8 * b * cfg.d_model * BF16)


# ---------------------------------------------------------------------------
# collective bytes (per-chip egress, ring algorithms)
# ---------------------------------------------------------------------------

def train_collective_bytes(cfg: ModelConfig, b: int, s: int,
                           n_data: int, n_model: int,
                           compress_grads: bool = False) -> float:
  """Per-chip: DP gradient all-reduce + TP activation all-reduces."""
  p = cfg.total_params()
  grad_bytes = 1 if compress_grads else F32   # int8+EF wire format (optim/)
  grad_ar = 2 * (p / max(n_model, 1)) * grad_bytes if n_data > 1 else 0.0
  b_local = b / max(n_data, 1)
  # Megatron f/g: 2 ARs fwd + 2 bwd per layer of (B_local, S, D) bf16
  # (factor = n_ARs x 2 for ring egress).  EP MoE layers have no MLP-region
  # AR (the all-to-all replaces it); parallel_block fuses the regions.
  is_ep_moe = cfg.n_experts > 0 and cfg.n_experts % n_model == 0
  if getattr(cfg, "parallel_block", False) or is_ep_moe:
    ar_per_layer = 4          # attention region only (1 fwd + 1 bwd) x ring 2
  else:
    ar_per_layer = 8
  tp_ar = (ar_per_layer * cfg.n_layers * b_local * s * cfg.d_model * BF16
           if n_model > 1 else 0.0)
  # FSDP: weight all-gather fwd + bwd, grad reduce-scatter (per-chip egress)
  if getattr(cfg, "fsdp", False) and n_data > 1:
    tp_ar += 3 * (p * BF16) / max(n_model, 1)
  # EP all-to-all (MoE): dispatch+combine, fwd+bwd
  ep = 0.0
  if cfg.n_experts > 0 and cfg.n_experts % n_model == 0:
    a2a_bytes = 1 if getattr(cfg, "moe_a2a_quant", False) else BF16
    ep = 4 * cfg.n_layers * b_local * s * cfg.d_model * a2a_bytes * cfg.top_k
  return grad_ar + tp_ar + ep


def decode_collective_bytes(cfg: ModelConfig, b: int, n_ctx: int,
                            n_data: int, n_model: int,
                            seq_sharded: bool) -> float:
  b_local = max(b / max(n_data, 1), 1) if b > 1 else 1
  tp_ar = (4 * cfg.n_layers * b_local * cfg.d_model * BF16
           if n_model > 1 else 0.0)
  if getattr(cfg, "fsdp", False) and n_data > 1:
    tp_ar += (param_bytes(cfg)) / max(n_model, 1)   # weight all-gather
  seq = 0.0
  if seq_sharded:
    # flash-decoding combine: per layer psum of (g heads x d) partials + stats
    seq = (2 * cfg.n_layers * cfg.n_heads * cfg.head_dim * F32
           * max(n_model * n_data, 1) / max(n_model * n_data, 1))
  return tp_ar + seq


def prefill_collective_bytes(cfg: ModelConfig, b: int, s: int,
                             n_data: int, n_model: int) -> float:
  b_local = b / max(n_data, 1)
  if getattr(cfg, "context_parallel", False):
    # sequence on the model axis, weights replicated: per layer the only
    # cross-chip traffic is the KV all-gather (ring: ~message bytes egress)
    kv_ag = (2 * cfg.n_layers * b_local * s
             * cfg.n_kv_heads * cfg.head_dim * BF16)
    return kv_ag if n_model > 1 else 0.0
  is_ep_moe = cfg.n_experts > 0 and cfg.n_experts % n_model == 0
  ar_per_layer = 2 if (getattr(cfg, "parallel_block", False) or is_ep_moe) \
      else 4
  base = (ar_per_layer * cfg.n_layers * b_local * s * cfg.d_model * BF16
          if n_model > 1 else 0.0)
  if is_ep_moe:
    base += 2 * cfg.n_layers * b_local * s * cfg.d_model * BF16 * cfg.top_k
  return base


# ---------------------------------------------------------------------------
# cell-level summary
# ---------------------------------------------------------------------------

def cell_costs(cfg: ModelConfig, shape: ShapeConfig,
               n_data: int = 16, n_model: int = 16,
               compress_grads: bool = False) -> Dict[str, float]:
  b, s = shape.global_batch, shape.seq_len
  if shape.kind == "train":
    flops = train_step_flops(cfg, b, s)
    hbm = train_step_bytes(cfg, b, s)
    coll = train_collective_bytes(cfg, b, s, n_data, n_model, compress_grads)
  elif shape.kind == "prefill":
    flops = forward_flops(cfg, b, s) + clustering_flops(cfg, b, s)
    hbm = prefill_step_bytes(cfg, b, s)
    coll = prefill_collective_bytes(cfg, b, s, n_data, n_model)
  else:
    flops = decode_step_flops(cfg, b, s)
    hbm = decode_step_bytes(cfg, b, s)
    coll = decode_collective_bytes(cfg, b, s, n_data, n_model,
                                   seq_sharded=(b == 1))
  return {"flops": flops, "hbm_bytes": hbm, "collective_bytes_per_chip": coll}
