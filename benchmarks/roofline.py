"""Roofline analysis (spec: ROOFLINE ANALYSIS) — per (arch x shape x mesh):

  compute term    = FLOPs / (chips * 197e12)
  memory term     = HBM_bytes / (chips * 819e9)
  collective term = collective_bytes_per_chip / 50e9

FLOP/byte volumes come from the validated analytic cost model
(benchmarks/cost_model.py — see its docstring for why compiled cost_analysis
cannot be used directly: XLA counts while-loop bodies once, and reports
per-partition numbers).  The dry-run JSONs contribute the ground truth the
analytic model cannot know: per-device memory_analysis (capacity proof) and
the collective-op schedule (which collectives GSPMD actually emitted).

Headline metric per cell: MFU for compute-bound cells, MBU (memory-bandwidth
utilization of useful bytes) for memory-bound ones — reported as
`roofline_frac` in EXPERIMENTS.md §Roofline / §Perf.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_arch
from repro.configs.base import ALL_SHAPES, ShapeConfig

from benchmarks import cost_model

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def useful_flops(cfg, shape: ShapeConfig) -> float:
  n_active = cfg.active_params()
  if shape.kind == "train":
    return 6.0 * n_active * shape.seq_len * shape.global_batch
  if shape.kind == "prefill":
    return 2.0 * n_active * shape.seq_len * shape.global_batch
  return 2.0 * n_active * shape.global_batch


def useful_bytes(cfg, shape: ShapeConfig) -> float:
  """Minimum HBM traffic a perfect implementation must move per decode step:
  params once + the *compressed* context (PQ when the arch supports it — the
  paper's compressed representation IS the achievable lower bound, so exact-KV
  baselines score < 1 against it)."""
  best = (dataclasses.replace(cfg, pq_enabled=True)
          if cfg.supports_pq else cfg)
  if shape.kind == "decode":
    return (cost_model.param_bytes(best)
            + cost_model.kv_cache_bytes(best, shape.global_batch,
                                        shape.seq_len))
  return cost_model.param_bytes(best)


def analyze_cell(arch: str, shape: ShapeConfig, chips: int = 256,
                 n_data: int = 16, n_model: int = 16,
                 pq: bool = True, dryrun_rec: Optional[dict] = None) -> dict:
  cfg = get_arch(arch)
  if not pq:
    cfg = dataclasses.replace(cfg, pq_enabled=False)
  costs = cost_model.cell_costs(cfg, shape, n_data, n_model)

  t_compute = costs["flops"] / (chips * PEAK_FLOPS)
  t_memory = costs["hbm_bytes"] / (chips * HBM_BW)
  t_collective = costs["collective_bytes_per_chip"] / ICI_BW
  terms = {"compute": t_compute, "memory": t_memory,
           "collective": t_collective}
  dominant = max(terms, key=terms.get)
  t_step = max(terms.values())

  uf = useful_flops(cfg, shape)
  ub = useful_bytes(cfg, shape)
  mfu = uf / (chips * PEAK_FLOPS * t_step) if t_step else 0.0
  mbu = ub / (chips * HBM_BW * t_step) if t_step else 0.0
  headline = mfu if dominant == "compute" else (
      mbu if dominant == "memory" else max(mfu, mbu))

  rec = {
      "arch": arch, "shape": shape.name, "kind": shape.kind,
      "chips": chips, "pq": bool(pq and cfg.supports_pq),
      "t_compute_s": t_compute, "t_memory_s": t_memory,
      "t_collective_s": t_collective, "dominant": dominant,
      "t_step_s": t_step,
      "model_flops": uf, "impl_flops": costs["flops"],
      "useful_flops_ratio": uf / costs["flops"] if costs["flops"] else 0.0,
      "mfu": mfu, "mbu": mbu, "roofline_frac": headline,
      "hbm_bytes": costs["hbm_bytes"],
      "collective_bytes_per_chip": costs["collective_bytes_per_chip"],
  }
  if dryrun_rec is not None:
    rec["mem_analysis"] = dryrun_rec.get("memory", {})
    rec["collective_ops_observed"] = dryrun_rec.get(
        "collectives", {}).get("counts", {})
  return rec


def load_dryrun(results_dir: str = RESULTS_DIR) -> Dict[str, dict]:
  out = {}
  for path in glob.glob(os.path.join(results_dir, "*.json")):
    with open(path) as f:
      rec = json.load(f)
    key = (rec["arch"], rec["shape"], rec["mesh"],
           "pq" if rec.get("pq") else "nopq")
    out[key] = rec
  return out


def full_table(pq: bool = True) -> List[dict]:
  """All 40 (arch x shape) single-pod cells."""
  from repro.configs import ARCHS
  dryrun = load_dryrun()
  rows = []
  for arch in ARCHS:
    for shape in ALL_SHAPES:
      rec = dryrun.get((arch, shape.name, "16x16", "pq" if pq else "nopq"))
      rows.append(analyze_cell(arch, shape, pq=pq, dryrun_rec=rec))
  return rows


def format_table(rows: List[dict]) -> str:
  hdr = (f"{'arch':22s} {'shape':12s} {'pq':3s} "
         f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>9s} "
         f"{'dominant':>10s} {'MFU%':>6s} {'MBU%':>6s} {'roofl%':>7s}")
  lines = [hdr, "-" * len(hdr)]
  for r in rows:
    lines.append(
        f"{r['arch']:22s} {r['shape']:12s} {str(r['pq'])[0]:3s} "
        f"{r['t_compute_s']:10.5f} {r['t_memory_s']:10.5f} "
        f"{r['t_collective_s']:9.5f} {r['dominant']:>10s} "
        f"{100 * r['mfu']:6.1f} {100 * r['mbu']:6.1f} "
        f"{100 * r['roofline_frac']:7.1f}")
  return "\n".join(lines)


def run() -> list:
  from benchmarks import common
  lines = []
  for r in full_table(pq=True):
    lines.append(common.csv_line(
        f"roofline_{r['arch']}_{r['shape']}", 0.0,
        f"dominant={r['dominant']};compute_s={r['t_compute_s']:.5f};"
        f"memory_s={r['t_memory_s']:.5f};coll_s={r['t_collective_s']:.5f};"
        f"roofline_frac={r['roofline_frac']:.3f}"))
  return lines


if __name__ == "__main__":
  print(format_table(full_table(pq=True)))
  print("\n--- baseline (PQ off / exact KV) decode rows ---")
  rows = [r for r in full_table(pq=False) if r["kind"] == "decode"]
  print(format_table(rows))
