"""Dashboard over the BENCH_serve.json serving-perf trajectory.

`benchmarks/run.py --json` appends one timestamped record per run (pq vs
exact tok/s, tiered spill traffic, shared-prefix cache savings).  This
renders that trajectory two ways:

  terminal   a per-run table plus unicode sparklines — the quick "did this
             PR move serve perf" view, zero dependencies;
  PNG        a small matplotlib figure (tok/s trend, pq-vs-exact spill
             ratio, prefix-cache savings) when matplotlib is installed —
             skipped gracefully when it is not (CI installs only jax+numpy).

    python benchmarks/plot_trend.py                 # terminal + PNG
    python benchmarks/plot_trend.py --no-png        # terminal only
    python benchmarks/plot_trend.py --json BENCH_serve.json --png trend.png
"""
from __future__ import annotations

import argparse
import json
import os
import sys

SPARK = "▁▂▃▄▅▆▇█"


def sparkline(values):
  vals = [v for v in values if v is not None]
  if not vals:
    return ""
  lo, hi = min(vals), max(vals)
  span = (hi - lo) or 1.0
  out = []
  for v in values:
    if v is None:
      out.append(" ")
    else:
      out.append(SPARK[min(int((v - lo) / span * (len(SPARK) - 1)),
                           len(SPARK) - 1)])
  return "".join(out)


def load_runs(path: str) -> list:
  with open(path) as f:
    data = json.load(f)
  runs = data.get("runs") if isinstance(data, dict) else None
  if not isinstance(runs, list):
    raise SystemExit(f"{path} is not a {{'runs': [...]}} trajectory")
  return runs


def _policy_toks(run: dict, policy: str):
  return run.get("policies", {}).get(policy, {}).get("tok_per_s")


def _spill_ratio(run: dict):
  return (run.get("tiered") or {}).get("pq_vs_exact_raw_spill")


def _prefix_saved(run: dict, policy: str = "exact"):
  pol = ((run.get("prefix") or {}).get("policies", {})).get(policy, {})
  return pol.get("prefill_tokens_saved_frac")


def _prefix_hit_rate(run: dict, policy: str):
  pol = ((run.get("prefix") or {}).get("policies", {})).get(policy, {})
  return pol.get("prefix_hit_rate")


def _decode_p99(run: dict, policy: str):
  """Per-step decode latency p99 (ms); None on records predating PR 5."""
  return run.get("policies", {}).get(policy, {}).get("decode_step_p99_ms")


def _native_dense_bytes(run: dict):
  """Modeled dense-materialized bytes of the paged pq block-native decode
  (0 = kernels read pool storage in place); None on older records."""
  return (run.get("decode_kernels") or {}).get("pq_block_native_dense_bytes")


def _workload_cell(run: dict, policy: str, arrival: str = "poisson"):
  """One workload-record cell; {} on records predating PR 6."""
  pols = (run.get("workload") or {}).get("policies", {})
  return pols.get(policy, {}).get(arrival, {})


def _goodput(run: dict, policy: str):
  return _workload_cell(run, policy).get("goodput_frac")


def _ttft_p99(run: dict, policy: str):
  return _workload_cell(run, policy).get("ttft_p99_s")


def _stall_ratio(run: dict, policy: str):
  """Overlapped / serialized transfer-stall seconds (< 1 = the async
  spill/fetch stage is winning); None on older records."""
  return _workload_cell(run, policy).get("transfer_stall_ratio")


def _packed_spill(run: dict):
  """q4 spill bytes / int8 spill bytes on the forced-spill trace (< 0.55 =
  the sub-byte layout halves int8); None on records predating PR 8."""
  return (run.get("packed") or {}).get("q4_vs_int8_spill_bytes")


def _packed_resident(run: dict):
  """Resident-q4 exact pool capacity as a fraction of the fp32 pool
  (~0.19 at head_dim 16); None on records predating PR 8."""
  return (run.get("packed") or {}).get("resident_q4_vs_fp32_bytes")


def _shed_goodput_gain(run: dict):
  """SLO-shedding goodput tok/s over the stalling baseline on the overload
  trace (> 1 = shedding doomed work helps the survivors); None on records
  predating PR 9."""
  return (run.get("recovery") or {}).get("shedding", {}).get(
      "shed_vs_stall_goodput")


def _restored_blocks(run: dict):
  """Prefix blocks a restarted engine revived from the snapshot; None on
  records predating PR 9."""
  return (run.get("recovery") or {}).get("restore", {}).get(
      "restored_prefix_blocks")


def _warm_hit_tokens(run: dict):
  return (run.get("recovery") or {}).get("restore", {}).get(
      "warm_hit_tokens")


def _cold_hit_tokens(run: dict):
  return (run.get("recovery") or {}).get("restore", {}).get(
      "cold_hit_tokens")


def _shard_goodput_gain(run: dict):
  """Host-mirror shard recovery goodput over abort-and-recompute on the
  identical seeded shard kill (> 1 = restoring KV beats regenerating it);
  None on records predating PR 10."""
  return (run.get("recovery") or {}).get("shard", {}).get(
      "mirror_vs_recompute_goodput")


def _mesh_cell(run: dict, policy: str, size: int) -> dict:
  """One sharded-serving cell; {} on records predating PR 7."""
  pols = (run.get("mesh") or {}).get("policies", {})
  return pols.get(policy, {}).get("mesh", {}).get(str(size), {})


def _mesh_toks(run: dict, policy: str, size: int):
  return _mesh_cell(run, policy, size).get("tok_per_s")


def _mesh_scale(run: dict, policy: str, size: int):
  """tok/s at mesh=size relative to mesh=1 (host-device CPU meshes measure
  collective overhead, not speedup); None pre-PR7."""
  base = _mesh_toks(run, policy, 1)
  at = _mesh_toks(run, policy, size)
  if not base or at is None:
    return None
  return at / base


def _mesh_bytes_frac(run: dict, policy: str, size: int):
  """Per-shard pool bytes at mesh=size as a fraction of the total pool
  (heads mode: ~1/size, the capacity-wall win); None pre-PR7."""
  cell = _mesh_cell(run, policy, size)
  total = cell.get("total_bytes")
  per = cell.get("bytes_per_shard")
  if not total or per is None:
    return None
  return per / total


def render_terminal(runs: list) -> None:
  def fmt(v, pat="{:8.1f}", blank="       —"):
    return blank if v is None else pat.format(v)

  print(f"{'run':>3} {'sha':>8} {'timestamp':>20} {'pq tok/s':>9} "
        f"{'exact tok/s':>11} {'spill pq/raw':>12} {'prefix saved':>12} "
        f"{'hit(pq)':>8} {'p99(pq) ms':>10} {'goodput(pq)':>11} "
        f"{'ttft p99 s':>10} {'stall o/s':>9} {'mesh x4(pq)':>11} "
        f"{'q4/int8 B':>9}")
  for i, run in enumerate(runs):
    print(f"{i:>3} {run.get('git_sha', '?'):>8} "
          f"{run.get('timestamp', '?'):>20} "
          f"{fmt(_policy_toks(run, 'pq'), '{:9.1f}', '        —')} "
          f"{fmt(_policy_toks(run, 'exact'), '{:11.1f}', '          —')} "
          f"{fmt(_spill_ratio(run), '{:12.3f}', '           —')} "
          f"{fmt(_prefix_saved(run), '{:12.2%}', '           —')} "
          f"{fmt(_prefix_hit_rate(run, 'pq'), '{:8.2f}', '       —')} "
          f"{fmt(_decode_p99(run, 'pq'), '{:10.2f}', '         —')} "
          f"{fmt(_goodput(run, 'pq'), '{:11.2%}', '          —')} "
          f"{fmt(_ttft_p99(run, 'pq'), '{:10.4f}', '         —')} "
          f"{fmt(_stall_ratio(run, 'pq'), '{:9.3f}', '        —')} "
          f"{fmt(_mesh_scale(run, 'pq', 4), '{:11.3f}', '          —')} "
          f"{fmt(_packed_spill(run), '{:9.3f}', '        —')}")
  print()
  for label, series in (
      ("pq tok/s      ", [_policy_toks(r, "pq") for r in runs]),
      ("exact tok/s   ", [_policy_toks(r, "exact") for r in runs]),
      ("spill pq/raw  ", [_spill_ratio(r) for r in runs]),
      ("prefix saved  ", [_prefix_saved(r) for r in runs]),
      ("pq p99 ms     ", [_decode_p99(r, "pq") for r in runs]),
      ("exact p99 ms  ", [_decode_p99(r, "exact") for r in runs]),
      ("goodput pq    ", [_goodput(r, "pq") for r in runs]),
      ("goodput exact ", [_goodput(r, "exact") for r in runs]),
      ("ttft p99 s pq ", [_ttft_p99(r, "pq") for r in runs]),
      ("stall o/s pq  ", [_stall_ratio(r, "pq") for r in runs]),
      ("mesh x2 pq    ", [_mesh_scale(r, "pq", 2) for r in runs]),
      ("mesh x4 pq    ", [_mesh_scale(r, "pq", 4) for r in runs]),
      ("shard B x4 pq ", [_mesh_bytes_frac(r, "pq", 4) for r in runs]),
      ("q4/int8 spill ", [_packed_spill(r) for r in runs]),
      ("q4/fp32 pool  ", [_packed_resident(r) for r in runs]),
      ("shed/stall gp ", [_shed_goodput_gain(r) for r in runs]),
      ("restored blks ", [_restored_blocks(r) for r in runs]),
      ("shard mir gp  ", [_shard_goodput_gain(r) for r in runs]),
  ):
    vals = [v for v in series if v is not None]
    if vals:
      print(f"{label} {sparkline(series)}  (last {vals[-1]:.3g})")
  dense = [_native_dense_bytes(r) for r in runs]
  if any(v is not None for v in dense):
    last = [v for v in dense if v is not None][-1]
    print(f"paged pq block-native dense-materialized bytes/step: {last} "
          f"(0 = kernels read pool storage in place)")


def render_png(runs: list, path: str) -> bool:
  try:
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt
  except ImportError:
    print("matplotlib not installed; skipping PNG (terminal view above is "
          "the dashboard)")
    return False
  xs = list(range(len(runs)))
  fig, axes = plt.subplots(8, 1, figsize=(8, 18), sharex=True)
  axes[0].plot(xs, [_policy_toks(r, "pq") for r in runs], marker="o",
               label="pq")
  axes[0].plot(xs, [_policy_toks(r, "exact") for r in runs], marker="s",
               label="exact")
  axes[0].set_ylabel("serve tok/s")
  axes[0].legend(loc="best")
  axes[0].set_title("BENCH_serve.json trajectory")
  axes[1].plot(xs, [_spill_ratio(r) for r in runs], marker="o", color="tab:red")
  axes[1].axhline(0.25, ls="--", lw=1, color="gray")
  axes[1].set_ylabel("tiered spill\npq / exact raw")
  axes[2].plot(xs, [_prefix_saved(r) for r in runs], marker="o",
               color="tab:green", label="exact prefill saved")
  axes[2].plot(xs, [_prefix_hit_rate(r, "pq") for r in runs], marker="s",
               color="tab:olive", label="pq hit rate")
  axes[2].axhline(0.5, ls="--", lw=1, color="gray")
  axes[2].set_ylabel("prefix cache")
  axes[2].legend(loc="best")
  # per-step decode latency (records before PR 5 plot as gaps)
  axes[3].plot(xs, [_decode_p99(r, "pq") for r in runs], marker="o",
               color="tab:purple", label="pq p99")
  axes[3].plot(xs, [_decode_p99(r, "exact") for r in runs], marker="s",
               color="tab:cyan", label="exact p99")
  axes[3].set_ylabel("decode step\np99 (ms)")
  axes[3].legend(loc="best")
  # workload harness SLO metrics (records before PR 6 plot as gaps)
  axes[4].plot(xs, [_goodput(r, "pq") for r in runs], marker="o",
               color="tab:blue", label="pq goodput")
  axes[4].plot(xs, [_goodput(r, "exact") for r in runs], marker="s",
               color="tab:orange", label="exact goodput")
  axes[4].plot(xs, [_stall_ratio(r, "pq") for r in runs], marker="^",
               color="tab:red", label="pq stall overlap/serial")
  axes[4].axhline(1.0, ls="--", lw=1, color="gray")
  axes[4].set_ylabel("workload SLO")
  axes[4].legend(loc="best")
  # sharded serving: tok/s vs mesh size relative to mesh=1 plus the
  # per-shard pool-byte fraction (records before PR 7 plot as gaps)
  axes[5].plot(xs, [_mesh_scale(r, "pq", 2) for r in runs], marker="o",
               color="tab:blue", label="pq tok/s x2 / x1")
  axes[5].plot(xs, [_mesh_scale(r, "pq", 4) for r in runs], marker="s",
               color="tab:purple", label="pq tok/s x4 / x1")
  axes[5].plot(xs, [_mesh_bytes_frac(r, "pq", 4) for r in runs], marker="^",
               color="tab:green", label="pq pool B/shard x4 (frac)")
  axes[5].axhline(0.25, ls="--", lw=1, color="gray")
  axes[5].set_ylabel("mesh scaling")
  axes[5].legend(loc="best")
  # packed KV codecs (records before PR 8 plot as gaps)
  axes[6].plot(xs, [_packed_spill(r) for r in runs], marker="o",
               color="tab:brown", label="q4/int8 spill bytes")
  axes[6].plot(xs, [_packed_resident(r) for r in runs], marker="s",
               color="tab:pink", label="resident q4/fp32 pool")
  axes[6].axhline(0.55, ls="--", lw=1, color="gray")
  axes[6].axhline(0.30, ls=":", lw=1, color="gray")
  axes[6].set_ylabel("packed bytes\n(frac of baseline)")
  axes[6].legend(loc="best")
  # fault-tolerant serving (records before PR 9 plot as gaps)
  axes[7].plot(xs, [_shed_goodput_gain(r) for r in runs], marker="o",
               color="tab:red", label="shed/stall goodput")
  axes[7].plot(xs, [_restored_blocks(r) for r in runs], marker="s",
               color="tab:green", label="restored prefix blocks")
  # shard-loss recovery (records before PR 10 plot as gaps)
  axes[7].plot(xs, [_shard_goodput_gain(r) for r in runs], marker="^",
               color="tab:blue", label="shard mirror/recompute goodput")
  axes[7].axhline(1.0, ls="--", lw=1, color="gray")
  axes[7].set_ylabel("recovery")
  axes[7].set_xlabel("run")
  axes[7].legend(loc="best")
  fig.tight_layout()
  fig.savefig(path, dpi=120)
  plt.close(fig)
  print(f"wrote {path}")
  return True


def main() -> None:
  ap = argparse.ArgumentParser(description=__doc__)
  ap.add_argument("--json", default="BENCH_serve.json",
                  help="trajectory file written by benchmarks/run.py --json")
  ap.add_argument("--png", default="BENCH_trend.png",
                  help="output figure path")
  ap.add_argument("--no-png", action="store_true",
                  help="terminal dashboard only")
  args = ap.parse_args()
  if not os.path.exists(args.json):
    raise SystemExit(f"{args.json} not found — run "
                     f"`python benchmarks/run.py --json` first")
  runs = load_runs(args.json)
  if not runs:
    raise SystemExit("trajectory is empty")
  render_terminal(runs)
  if not args.no_png:
    render_png(runs, args.png)
  sys.exit(0)


if __name__ == "__main__":
  main()
