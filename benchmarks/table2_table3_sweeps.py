"""Paper Table II (subvector count m) and Table III (centroid count K) sweeps.

The paper sweeps LongBench accuracy; our laptop-scale proxy is attention-output
quality vs the exact attention on clustered synthetic activations (the property
the paper's accuracy rests on).  Expected reproduction:
  - quality improves with m and saturates around m=32 (Table II),
  - quality improves with K and saturates around K=512 (Table III).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import pq, pq_attention as pqa


def _pq_attention_quality(rng, n, d, m, k, g=4, weighted=True):
  keys, vals, w = common.clustered_activations(rng, n, d)
  q = jnp.asarray(rng.normal(size=(g, d)), jnp.float32)
  scale = 1 / np.sqrt(d)
  cfg = pq.PQConfig(m=m, k=k, iters=4)
  wts = w if weighted else jnp.ones_like(w)
  kcb, kidx = pq.build_codebook(keys, wts, cfg)
  vcb, vidx = pq.build_codebook(vals, wts, cfg)
  seg = pqa.PQAttnSegments(
      sink_k=jnp.zeros((0, d)), sink_v=jnp.zeros((0, d)),
      sink_mask=jnp.zeros((0,), bool),
      key_codebook=kcb, value_codebook=vcb,
      key_indices=kidx, value_indices=vidx,
      body_mask=jnp.ones((n,), bool),
      recent_k=jnp.zeros((0, d)), recent_v=jnp.zeros((0, d)),
      recent_mask=jnp.zeros((0,), bool))
  out = pqa.pq_decode_attention(q, seg, scale)
  return common.attention_quality(q, keys, vals, out, scale)


def run(n: int = 2048, d: int = 128) -> list:
  lines = []
  rng = np.random.default_rng(0)

  # Table II: m sweep at K=512 (paper: best balance at m=32)
  for m in (2, 4, 8, 16, 32, 64):
    rng_m = np.random.default_rng(10 + m)
    us = 0.0
    qual = _pq_attention_quality(rng_m, n, d, m=m, k=min(512, n // 4))
    lines.append(common.csv_line(
        f"table2_m{m}", us,
        f"rel_err={qual['rel_err']:.4f};cosine={qual['cosine']:.4f}"))

  # Table III: K sweep at m=32 (paper: saturates at K=512)
  for k in (64, 128, 256, 512):
    rng_k = np.random.default_rng(100 + k)
    qual = _pq_attention_quality(rng_k, n, d, m=32, k=k)
    lines.append(common.csv_line(
        f"table3_k{k}", 0.0,
        f"rel_err={qual['rel_err']:.4f};cosine={qual['cosine']:.4f}"))
  return lines


if __name__ == "__main__":
  for line in run():
    print(line)
