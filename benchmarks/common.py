"""Shared benchmark utilities: synthetic activation generators that mimic the
paper's observation (Fig. 2) that K/V vectors cluster, an attention-quality
metric, and timing helpers (re-exported from repro.common.timing so the serve
driver and the benches share one implementation)."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.common.timing import Stopwatch, time_us  # noqa: F401  (re-export)
from repro.core import pq_attention as pqa


def clustered_activations(rng, n: int, d: int, n_modes: int = 24,
                          noise: float = 0.15, heavy_frac: float = 0.05
                          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
  """KV-like activations: tight clusters (paper Fig. 2) + heavy-hitter tokens.

  Returns (keys, values, attention_weights) where attention_weights mimics the
  Eq. 1 importance distribution (a few tokens soak up most attention mass).
  """
  centers = rng.normal(size=(n_modes, d)) * 2.0
  ids = rng.integers(0, n_modes, n)
  k = centers[ids] + rng.normal(size=(n, d)) * noise
  v = centers[(ids * 7 + 3) % n_modes] + rng.normal(size=(n, d)) * noise
  w = rng.gamma(0.3, 1.0, size=n)
  heavy = rng.choice(n, max(int(n * heavy_frac), 1), replace=False)
  w[heavy] *= 50
  return (jnp.asarray(k, jnp.float32), jnp.asarray(v, jnp.float32),
          jnp.asarray(w / w.sum() * n, jnp.float32))


def attention_quality(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      out_approx: jnp.ndarray, scale: float) -> dict:
  """Quality of an approximate attention output vs the exact one."""
  n = k.shape[0]
  exact = pqa.exact_decode_attention(q, k, v, jnp.ones((n,), bool), scale)
  err = jnp.linalg.norm(out_approx - exact, axis=-1)
  base = jnp.linalg.norm(exact, axis=-1)
  rel = float(jnp.mean(err / jnp.maximum(base, 1e-9)))
  cos = float(jnp.mean(jnp.sum(out_approx * exact, -1)
                       / jnp.maximum(jnp.linalg.norm(out_approx, axis=-1)
                                     * base, 1e-9)))
  return {"rel_err": rel, "cosine": cos,
          "score_proxy": max(0.0, 100.0 * cos)}


def csv_line(name: str, us: float, derived: str) -> str:
  return f"{name},{us:.1f},{derived}"
