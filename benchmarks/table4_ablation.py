"""Paper Table IV ablation: standard PQ / w-o weighting / w-o pre-sort / AQPIM.

Reproduction target (paper, 128 centroids, aggressive compression):
both importance weighting and channel pre-sorting contribute, and full AQPIM
beats standard PQ.  Our metric is *importance-weighted* attention-output error:
heavy-hitter tokens dominate model accuracy (the paper's motivation for Eq. 2),
so the quality score weights each query's error by where its attention mass sits.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import channel_sort, pq, pq_attention as pqa


def _interleaved_channels(rng, n, d):
  """Activations whose correlated channels are interleaved (worst case for
  contiguous splitting — what pre-sorting fixes)."""
  base_d = d // 4
  base = rng.normal(size=(n, base_d))
  chans = []
  for i in range(d):
    src = base[:, i % base_d]
    chans.append(src * (1.0 + 0.05 * (i // base_d))
                 + rng.normal(size=n) * 0.05)
  return np.stack(chans, axis=1)


def _quality(keys, vals, w, q, m, k, weighted, presort, rng):
  n, d = keys.shape
  scale = 1 / np.sqrt(d)
  cfg = pq.PQConfig(m=m, k=k, iters=4)
  if presort:
    perm = channel_sort.greedy_channel_groups(np.asarray(keys), m)
    perm_v = channel_sort.greedy_channel_groups(np.asarray(vals), m)
  else:
    perm = np.arange(d)
    perm_v = np.arange(d)
  keys_s = keys[:, perm]
  vals_s = vals[:, perm_v]
  q_s = q[:, perm]
  wts = w if weighted else jnp.ones_like(w)
  kcb, kidx = pq.build_codebook(keys_s, wts, cfg)
  vcb, vidx = pq.build_codebook(vals_s, wts, cfg)
  seg = pqa.PQAttnSegments(
      sink_k=jnp.zeros((0, d)), sink_v=jnp.zeros((0, d)),
      sink_mask=jnp.zeros((0,), bool),
      key_codebook=kcb, value_codebook=vcb,
      key_indices=kidx, value_indices=vidx,
      body_mask=jnp.ones((n,), bool),
      recent_k=jnp.zeros((0, d)), recent_v=jnp.zeros((0, d)),
      recent_mask=jnp.zeros((0,), bool))
  out = pqa.pq_decode_attention(q_s, seg, scale)
  # un-permute values-channel output for comparison
  inv_v = np.argsort(perm_v)
  out_unperm = out[:, inv_v]
  return common.attention_quality(q, keys, vals, out_unperm, scale)


def run(n: int = 2048, d: int = 128, k: int = 128) -> list:
  """k=128 matches the paper's 'high compression' ablation setting."""
  rng = np.random.default_rng(0)
  keys = jnp.asarray(_interleaved_channels(rng, n, d), jnp.float32)
  vals = jnp.asarray(_interleaved_channels(rng, n, d), jnp.float32)
  _, _, w = common.clustered_activations(rng, n, d)
  # queries aligned with heavy tokens so weighting matters
  heavy = np.argsort(-np.asarray(w))[:8]
  q = keys[heavy[:4]] + jnp.asarray(rng.normal(size=(4, d)) * 0.1, jnp.float32)

  m = 16
  configs = {
      "standard_pq": dict(weighted=False, presort=False),
      "wo_weighting": dict(weighted=False, presort=True),
      "wo_presort": dict(weighted=True, presort=False),
      "aqpim": dict(weighted=True, presort=True),
  }
  lines = []
  results = {}
  for name, cc in configs.items():
    qual = _quality(keys, vals, w, q, m, k, rng=rng, **cc)
    results[name] = qual
    lines.append(common.csv_line(
        f"table4_{name}", 0.0,
        f"rel_err={qual['rel_err']:.4f};cosine={qual['cosine']:.4f}"))
  # headline check mirroring the paper's conclusion
  better = results["aqpim"]["rel_err"] <= results["standard_pq"]["rel_err"]
  lines.append(common.csv_line(
      "table4_aqpim_beats_standard", 0.0, f"holds={better}"))
  return lines


if __name__ == "__main__":
  for line in run():
    print(line)
