"""Paper Fig. 11-13: decode-latency decomposition via a bandwidth model.

The paper's speedups decompose as (Fig. 13):
  gpu+cpu -> gpu-inf : offload elimination  ~ HBM/PCIe bandwidth ratio (11.39x)
  gpu-inf -> gpu+pq  : PQ compression       ~ KV byte-reduction   (5.52x)
  gpu+pq  -> aqpim   : in-memory execution  ~ internal-BW / co-design (3.85x)

We reproduce the same decomposition for the TPU adaptation with measured bytes:
decode-attention bytes from our cache accounting (exact vs PQ), hardware
constants (PCIe 64 GB/s host link, HBM 819 GB/s v5e, GPU HBM 3.35 TB/s for the
paper-faithful row), and report each ratio next to the paper's claim."""
from __future__ import annotations

import numpy as np

from benchmarks import common

# bandwidth constants (bytes/s)
PCIE = 256e9 / 8          # paper: H100 ~26x gap; PCIe gen5 x16 eff. ~32 GB/s/dir
PCIE_PAPER = 256e9        # the paper's aggregate PCIe figure
GPU_HBM = 3.35e12         # H100
TPU_HBM = 819e9           # v5e per chip
TPU_VMEM = 20e12          # ~VMEM bandwidth per core (internal-BW analogue)
PIM_INTERNAL_X = 7.2      # paper: AttAcc! internal bandwidth vs GPU HBM


def decode_attention_bytes(n, d, kv_heads, layers, m=32, idx_bytes=2,
                           k_cent=512, pq=False):
  """Bytes read per decode step for attention (one batch element)."""
  if not pq:
    return layers * kv_heads * n * d * 2 * 2          # exact bf16 K+V
  idx = layers * kv_heads * n * m * idx_bytes * 2     # indices K+V
  cb = layers * kv_heads * 2 * m * k_cent * (d // m) * 2
  return idx + cb


def run(n: int = 32768, d: int = 128, kv_heads: int = 8, layers: int = 32
        ) -> list:
  """Defaults: mistral-7b-like (the paper's model) at 32k context."""
  lines = []
  exact = decode_attention_bytes(n, d, kv_heads, layers, pq=False)
  pq = decode_attention_bytes(n, d, kv_heads, layers, pq=True)
  pq8 = decode_attention_bytes(n, d, kv_heads, layers, pq=True, idx_bytes=1,
                               k_cent=256)

  # Fig. 13 decomposition (paper-faithful constants)
  t_gpu_cpu = exact / PCIE_PAPER          # KV overflows -> streams over PCIe
  t_gpu_inf = exact / GPU_HBM             # imaginary infinite GPU memory
  t_gpu_pq = pq / GPU_HBM                 # PQ on GPU (idealized, as the paper)
  t_aqpim = pq / (GPU_HBM * PIM_INTERNAL_X)

  lines.append(common.csv_line(
      "fig13_offload_elimination", 0.0,
      f"speedup={t_gpu_cpu / t_gpu_inf:.2f}x;paper=11.39x"))
  lines.append(common.csv_line(
      "fig13_pq_compression", 0.0,
      f"speedup={t_gpu_inf / t_gpu_pq:.2f}x;paper=5.52x;"
      f"kv_reduction={exact / pq:.2f}x;paper_kv=6.53x"))
  lines.append(common.csv_line(
      "fig13_pim_internal", 0.0,
      f"speedup={t_gpu_pq / t_aqpim:.2f}x;paper=3.85x"))
  lines.append(common.csv_line(
      "fig13_uint8_indices", 0.0,
      f"kv_reduction={exact / pq8:.2f}x (K=256, uint8 packing)"))

  # TPU adaptation rows: same decomposition on v5e constants
  t_tpu_exact = exact / TPU_HBM
  t_tpu_pq = pq / TPU_HBM
  t_tpu_pq_vmem = pq / TPU_VMEM   # table resident in VMEM (our kernel)
  lines.append(common.csv_line(
      "fig13_tpu_pq_vs_exact", 0.0,
      f"speedup={t_tpu_exact / t_tpu_pq:.2f}x (HBM-bytes ratio on v5e)"))
  lines.append(common.csv_line(
      "fig13_tpu_host_offload_penalty", 0.0,
      f"penalty={ (exact / PCIE) / t_tpu_exact:.1f}x if KV overflowed to host"))

  # Fig. 12: per-step decode scaling with input length
  for nn in (4096, 16384, 65536, 262144, 524288):
    e = decode_attention_bytes(nn, d, kv_heads, layers, pq=False)
    p = decode_attention_bytes(nn, d, kv_heads, layers, pq=True)
    lines.append(common.csv_line(
        f"fig12_n{nn}", 0.0,
        f"exact_ms={e / TPU_HBM * 1e3:.3f};pq_ms={p / TPU_HBM * 1e3:.3f};"
        f"speedup={e / p:.2f}x"))

  # Fig. 11: total time with growing output length (matmul part fixed by PQ)
  for out_len in (512, 2048, 8192):
    # per-step attention bytes grow with n; FFN/proj bytes constant
    ffn_bytes = 12 * 4096 * 14336 / 8 * 2 / 64   # per-chip slice, bf16
    t_exact = sum((decode_attention_bytes(n + i, d, kv_heads, layers)
                   / TPU_HBM) for i in range(0, out_len, max(out_len // 8, 1)))
    t_pq = sum((decode_attention_bytes(n + i, d, kv_heads, layers, pq=True)
                / TPU_HBM) for i in range(0, out_len, max(out_len // 8, 1)))
    lines.append(common.csv_line(
        f"fig11_outlen{out_len}", 0.0,
        f"attn_speedup={t_exact / t_pq:.2f}x;paper_total_up_to=2.33x"))
  return lines


if __name__ == "__main__":
  for line in run():
    print(line)
