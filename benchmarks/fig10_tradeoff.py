"""Paper Fig. 10: memory-reduction ratio vs accuracy, AQPIM vs baselines.

Every method now goes through the unified `CachePolicy` registry
(`repro.core.cache_registry`) on identical inputs: prefill a clustered
synthetic context, run one `append_and_attend` decode step, and compare the
output against the `exact` policy's on the same state.  Memory ratios come
from each policy's own `bytes()` accounting (bf16 exact vs int16/uint8
indices + codebooks / int4-8 scales / kept-token fraction).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro.core import cache_api, cache_registry, kv_cache as kvc, pq


def _policy_points(n: int, d: int):
  """(label, registry key, CacheSpec) per swept Fig. 10 point."""
  cap = n + 8
  base = dict(capacity=cap, head_dim=d, dtype=jnp.float32, sink=8, recent=32)

  def pq_spec(m, k):
    body = n - 8 - 32 + 8
    return cache_api.CacheSpec(
        **base, pq=kvc.PQCacheConfig(sink=8, recent=32, body_capacity=body,
                                     n_windows=1,
                                     pq=pq.PQConfig(m=m, k=k, iters=4)))

  pts = [(f"aqpim_m{m}_k{k}", "pq", pq_spec(m, k))
         for m, k in ((32, 512), (32, 256), (16, 256), (8, 128))]
  pts += [(f"skvq_{bits}bit", "skvq",
           cache_api.CacheSpec(**base, bits=bits, group=32))
          for bits in (8, 4, 2)]
  pts += [(f"snapkv_keep{frac}", "snapkv",
           cache_api.CacheSpec(**base, keep_frac=frac))
          for frac in (0.5, 0.25, 0.125)]
  pts.append(("streamingllm_w512", "streamingllm",
              cache_api.CacheSpec(**base, window=512)))
  pts.append(("pqcache_keep0.125", "pqcache",
              cache_api.CacheSpec(**base, keep_frac=0.125)))
  return pts


def run(n: int = 2048, d: int = 128) -> list:
  rng = np.random.default_rng(0)
  keys, vals, w = common.clustered_activations(rng, n, d)
  k4 = keys[None, None]                       # (1, 1, N, D)
  v4 = vals[None, None]
  w3 = w[None, None]
  q4 = jnp.asarray(rng.normal(size=(1, 4, d)), jnp.float32)
  kn = jnp.asarray(rng.normal(size=(1, 1, d)), jnp.float32)
  vn = jnp.asarray(rng.normal(size=(1, 1, d)), jnp.float32)
  lengths = jnp.asarray([n], jnp.int32)

  def one_step(policy):
    state = policy.prefill(k4, v4, w3 if policy.needs_weights else None)
    out, _ = policy.append_and_attend(state, q4, kn, vn, lengths)
    return np.asarray(out[0], np.float64)     # (g, d)

  exact = one_step(cache_registry.make("exact", cache_api.CacheSpec(
      capacity=n + 8, head_dim=d, dtype=jnp.float32)))

  lines = []
  for label, name, spec in _policy_points(n, d):
    policy = cache_registry.make(name, spec)
    out = one_step(policy)
    cos = float(np.mean(
        np.sum(out * exact, -1)
        / np.maximum(np.linalg.norm(out, axis=-1)
                     * np.linalg.norm(exact, axis=-1), 1e-9)))
    by = policy.bytes(1, 1, d)
    derived = f"mem_reduction={by['reduction_ratio']:.2f}x;cosine={cos:.4f}"
    if "fetched_bytes_per_step" in by:
      derived += f";pcie_bytes_per_step={by['fetched_bytes_per_step']}"
    lines.append(common.csv_line(f"fig10_{label}", 0.0, derived))
  return lines


if __name__ == "__main__":
  for line in run():
    print(line)
