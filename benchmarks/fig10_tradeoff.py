"""Paper Fig. 10: memory-reduction ratio vs accuracy, AQPIM vs baselines.

Methods: AQPIM (PQ, in-PIM), SKVQ-like (uniform quant), SnapKV-like (eviction),
PQCache-like (PQ-select + exact fetch — accuracy ~exact, but pays PCIe traffic,
reported separately).  Memory ratio uses target-hardware byte accounting
(bf16 exact vs int16/uint8 indices + codebooks / int4-8 scales / kept-token
fraction)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import baselines, pq, pq_attention as pqa


def _aqpim_point(rng, keys, vals, w, q, scale, m, k):
  n, d = keys.shape
  cfg = pq.PQConfig(m=m, k=k, iters=4)
  kcb, kidx = pq.build_codebook(keys, w, cfg)
  vcb, vidx = pq.build_codebook(vals, w, cfg)
  seg = pqa.PQAttnSegments(
      sink_k=jnp.zeros((0, d)), sink_v=jnp.zeros((0, d)),
      sink_mask=jnp.zeros((0,), bool),
      key_codebook=kcb, value_codebook=vcb, key_indices=kidx,
      value_indices=vidx, body_mask=jnp.ones((n,), bool),
      recent_k=jnp.zeros((0, d)), recent_v=jnp.zeros((0, d)),
      recent_mask=jnp.zeros((0,), bool))
  out = pqa.pq_decode_attention(q, seg, scale)
  exact_bytes = n * d * 2 * 2
  idx_bytes = n * m * cfg.index_bytes() * 2
  cb_bytes = 2 * m * k * (d // m) * 2
  ratio = exact_bytes / (idx_bytes + cb_bytes)
  return ratio, common.attention_quality(q, keys, vals, out, scale)


def _skvq_point(rng, keys, vals, q, scale, bits):
  n, d = keys.shape
  mask = jnp.ones((n,), bool)
  out = baselines.skvq_decode_attention(q, keys, vals, mask, scale,
                                        bits=bits, group=32)
  # bytes: bits/value + per-group scale+zero (f16) over group=32
  per_tok = d * bits / 8 + (d // 32) * 4
  ratio = (d * 2) / per_tok
  return ratio, common.attention_quality(q, keys, vals, out, scale)


def _snapkv_point(rng, keys, vals, w, q, scale, keep_frac):
  n, d = keys.shape
  keep = max(int(n * keep_frac), 1)
  mask = baselines.snapkv_select(w, keep=keep, sink=4, recent=16, length=n)
  out = pqa.exact_decode_attention(q, keys, vals, mask, scale)
  ratio = n / float(jnp.sum(mask))
  return ratio, common.attention_quality(q, keys, vals, out, scale)


def run(n: int = 2048, d: int = 128) -> list:
  rng = np.random.default_rng(0)
  keys, vals, w = common.clustered_activations(rng, n, d)
  q = jnp.asarray(rng.normal(size=(4, d)), jnp.float32)
  scale = 1 / np.sqrt(d)
  lines = []

  for m, k in ((32, 512), (32, 256), (16, 256), (8, 128)):
    ratio, qual = _aqpim_point(rng, keys, vals, w, q, scale, m, k)
    lines.append(common.csv_line(
        f"fig10_aqpim_m{m}_k{k}", 0.0,
        f"mem_reduction={ratio:.2f}x;cosine={qual['cosine']:.4f}"))

  for bits in (8, 4, 2):
    ratio, qual = _skvq_point(rng, keys, vals, q, scale, bits)
    lines.append(common.csv_line(
        f"fig10_skvq_{bits}bit", 0.0,
        f"mem_reduction={ratio:.2f}x;cosine={qual['cosine']:.4f}"))

  for frac in (0.5, 0.25, 0.125):
    ratio, qual = _snapkv_point(rng, keys, vals, w, q, scale, frac)
    lines.append(common.csv_line(
        f"fig10_snapkv_keep{frac}", 0.0,
        f"mem_reduction={ratio:.2f}x;cosine={qual['cosine']:.4f}"))

  # PQCache-like: accuracy ~exact at keep=12.5% but pays exact-KV fetch traffic
  cfg = pq.PQConfig(m=16, k=128, iters=4)
  out, traffic = baselines.pqcache_decode_attention(
      q, keys, vals, jnp.ones((n,), bool), scale, cfg, keep=n // 8)
  qual = common.attention_quality(q, keys, vals, out, scale)
  lines.append(common.csv_line(
      "fig10_pqcache_keep0.125", 0.0,
      f"cosine={qual['cosine']:.4f};pcie_bytes_per_step={traffic['fetched_bytes']}"))
  return lines


if __name__ == "__main__":
  for line in run():
    print(line)
