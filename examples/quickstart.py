"""Quickstart: AQPIM end to end on one host in ~2 minutes.

  PYTHONPATH=src python examples/quickstart.py

1. builds a reduced tinyllama, trains it briefly on the synthetic pipeline,
2. prefises a prompt — which runs the paper's importance-weighted windowed
   clustering and builds the PQ-compressed KV cache,
3. decodes tokens directly on the compressed cache (lookup+sum attention),
4. compares against the exact-KV path.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import jax, jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.train import TrainRun
from repro.launch.serve import ServeRun


def main():
  print("=== 1. train a reduced tinyllama on the synthetic pipeline ===")
  run = TrainRun(arch="tinyllama-1.1b", reduced=True, steps=40,
                 batch=4, seq=128, lr=1e-3, log_every=10)
  _, losses, _ = run.run()
  print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}\n")

  print("=== 2./3. serve with the PQ-compressed KV cache (AQPIM) ===")
  pq = ServeRun(arch="tinyllama-1.1b", reduced=True, batch=2,
                prompt_len=96, gen=12, pq=True).run()
  print(f"PQ cache: prefill {pq['prefill_s']:.2f}s, "
        f"decode {pq['tok_per_s']:.1f} tok/s")
  print("tokens:", pq["tokens"][0].tolist())

  print("\n=== 4. exact-KV reference path ===")
  ex = ServeRun(arch="tinyllama-1.1b", reduced=True, batch=2,
                prompt_len=96, gen=12, pq=False).run()
  print(f"exact KV: decode {ex['tok_per_s']:.1f} tok/s")
  print("tokens:", ex["tokens"][0].tolist())
  agree = float(np.mean(np.asarray(pq["tokens"]) == np.asarray(ex["tokens"])))
  print(f"\ntoken agreement PQ vs exact (untrained-model proxy): {agree:.2f}")


if __name__ == "__main__":
  main()
