"""AQPIM's capacity-wall scenario at laptop scale: serve a long context whose
exact KV cache would not "fit", using the PQ-compressed cache, and measure the
byte budget + attention fidelity vs the exact path.

  PYTHONPATH=src python examples/longcontext_pq.py [--context 2048]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses
import jax, jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import Model


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--context", type=int, default=2048)
  args = ap.parse_args()
  n = args.context

  cfg = dataclasses.replace(
      get_arch("mistral-7b", reduced=True),    # the paper's model family
      n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
      d_ff=256, pq_m=8, pq_k=128, pq_sink=8, pq_recent=32,
      attn_block=256, dtype_str="float32")
  key = jax.random.PRNGKey(0)
  tokens = jax.random.randint(key, (1, n), 0, cfg.vocab_size)

  results = {}
  for policy in ("pq", "exact"):
    c = dataclasses.replace(cfg, cache_policy=policy)
    model = Model(c, context_len=n + 64)
    params = model.init(key)
    logits, cache = model.prefill(params, tokens)
    lg, _ = model.decode_step(params, tokens[:, -1], cache, jnp.int32(n))
    results[policy] = np.asarray(lg, np.float32)
    # every policy reports its own target-hardware byte budget
    st = model.cache_policy.bytes(1, c.n_kv_heads, c.head_dim)
    print(f"context {n}: {policy} cache {st['total_bytes']/1e6:.2f} MB"
          f"/layer-head-set vs exact {st['equivalent_exact_bytes']/1e6:.2f} MB"
          f" ({st['reduction_ratio']:.1f}x reduction)")

  a, b = results["pq"].ravel(), results["exact"].ravel()
  corr = float(np.corrcoef(a, b)[0, 1])
  print(f"decode-logit correlation PQ vs exact: {corr:.4f}")
  print("top-1 agreement:",
        bool(results["pq"].argmax() == results["exact"].argmax()))


if __name__ == "__main__":
  main()
