"""End-to-end driver: train a ~100M-param llama-family model for a few hundred
steps on the deterministic synthetic pipeline, with async checkpointing and a
simulated preemption mid-run (the job restarts itself and resumes exactly).

  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses
import tempfile

from repro.configs import get_arch
from repro.launch.train import TrainRun
from repro.runtime import fault_tolerance as ft


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--steps", type=int, default=300)
  ap.add_argument("--fail-at", type=int, default=150,
                  help="simulated preemption step (0 = none)")
  args = ap.parse_args()

  # ~100M params: 12L x 768 with a 32k vocab
  base = get_arch("tinyllama-1.1b", reduced=False)
  cfg = dataclasses.replace(
      base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
      d_ff=2048, vocab_size=32000, dtype_str="float32", attn_block=128,
      pq_m=8, pq_k=64)
  print(f"model: {cfg.active_params()/1e6:.0f}M params")

  with tempfile.TemporaryDirectory() as ckpt_dir:
    run = TrainRun(arch="tinyllama-1.1b", steps=args.steps, batch=8, seq=256,
                   lr=6e-4, ckpt_dir=ckpt_dir, ckpt_every=50, log_every=20)
    # swap in the 100M config
    run.build = lambda _b=run.build: _patched_build(run, cfg)
    injector = (ft.FailureInjector(fail_at=(args.fail_at,))
                if args.fail_at else None)
    state, losses, report = run.run(injector=injector)
  print(f"\nfinal loss {losses[-1]:.4f}; "
        f"restarts={report.restarts if report else 0}")


def _patched_build(run, cfg):
  from repro.launch import steps as steps_lib
  from repro.launch.mesh import make_local_mesh
  from repro.configs.base import ShapeConfig
  from repro.data import pipeline as data_lib
  from repro.optim import adamw
  mesh = make_local_mesh()
  shape = ShapeConfig("custom_train", run.seq, run.batch, "train")
  opt_cfg = adamw.OptConfig(lr=run.lr, warmup_steps=run.steps // 20,
                            total_steps=run.steps)
  progs = steps_lib.build_programs(cfg, shape, mesh, opt_cfg=opt_cfg)
  dcfg = data_lib.DataConfig(vocab_size=cfg.vocab_size, seq_len=run.seq,
                             global_batch=run.batch, seed=run.seed)
  return cfg, mesh, progs, opt_cfg, dcfg


if __name__ == "__main__":
  main()
