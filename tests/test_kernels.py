"""Per-kernel allclose vs pure-jnp oracle, sweeping shapes and dtypes
(interpret mode on CPU; same code targets Mosaic on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# kmeans_assign
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,dsub,k", [
    (1, 64, 4, 8), (4, 300, 8, 32), (8, 1024, 16, 64), (2, 100, 2, 512),
])
def test_kmeans_assign_matches_ref(m, n, dsub, k):
  rng = np.random.default_rng(hash((m, n, dsub, k)) % 2**31)
  x = jnp.asarray(rng.normal(size=(m, n, dsub)), jnp.float32)
  c = jnp.asarray(rng.normal(size=(m, k, dsub)), jnp.float32)
  got = ops.kmeans_assign(x, c, blk=128)
  want = ref.kmeans_assign_ref(x, c)
  np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_assign_dtypes(dtype):
  rng = np.random.default_rng(0)
  x = jnp.asarray(rng.normal(size=(2, 256, 8)), dtype)
  c = jnp.asarray(rng.normal(size=(2, 16, 8)), dtype)
  got = ops.kmeans_assign(x, c, blk=128)
  want = ref.kmeans_assign_ref(x, c)
  agree = float(jnp.mean((got == want).astype(jnp.float32)))
  assert agree > 0.99, agree   # bf16 rounding may flip rare argmin ties


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,n,d,blk", [
    (1, 1, 1, 128, 32, 64),
    (2, 4, 2, 256, 64, 64),
    (1, 8, 1, 256, 16, 128),     # MQA
    (2, 6, 6, 192, 32, 64),      # MHA, n not a power of two
])
def test_flash_attention_matches_ref(b, hq, hkv, n, d, blk):
  rng = np.random.default_rng(hash((b, hq, n)) % 2**31)
  q = jnp.asarray(rng.normal(size=(b, hq, n, d)), jnp.float32)
  k = jnp.asarray(rng.normal(size=(b, hkv, n, d)), jnp.float32)
  v = jnp.asarray(rng.normal(size=(b, hkv, n, d)), jnp.float32)
  scale = 1 / np.sqrt(d)
  got = ops.flash_attention(q, k, v, scale, causal=True, blk_q=blk, blk_k=blk)
  want = ref.flash_attention_ref(q, k, v, scale, causal=True)
  np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                             rtol=2e-3, atol=2e-3)


def test_flash_attention_noncausal():
  rng = np.random.default_rng(7)
  q = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
  k = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
  v = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
  got = ops.flash_attention(q, k, v, 0.2, causal=False, blk_q=64, blk_k=64)
  want = ref.flash_attention_ref(q, k, v, 0.2, causal=False)
  np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                             rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3), (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, tol):
  rng = np.random.default_rng(8)
  q = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), dtype)
  k = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), dtype)
  v = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), dtype)
  got = ops.flash_attention(q, k, v, 0.18, blk_q=64, blk_k=64)
  want = ref.flash_attention_ref(q, k, v, 0.18)
  np.testing.assert_allclose(
      np.asarray(got, np.float32), np.asarray(want, np.float32),
      rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# pq decode attention (the flagship kernel)
# ---------------------------------------------------------------------------

def _pq_inputs(rng, b, h, g, d, m, k, n):
  dsub = d // m
  kcb = jnp.asarray(rng.normal(size=(b, h, m, k, dsub)), jnp.float32)
  vcb = jnp.asarray(rng.normal(size=(b, h, m, k, dsub)), jnp.float32)
  kix = jnp.asarray(rng.integers(0, k, size=(b, h, n, m)), jnp.int32)
  vix = jnp.asarray(rng.integers(0, k, size=(b, h, n, m)), jnp.int32)
  q = jnp.asarray(rng.normal(size=(b, h, g, d)), jnp.float32)
  return q, kcb, vcb, kix, vix


@pytest.mark.parametrize("b,h,g,d,m,k,n,blk", [
    (1, 1, 1, 32, 4, 8, 128, 64),
    (2, 2, 4, 64, 8, 32, 256, 64),
    (1, 4, 2, 128, 32, 512, 512, 128),   # paper hyperparameters
    (1, 1, 7, 64, 16, 64, 192, 64),      # odd GQA group (yi-style)
])
def test_pq_decode_matches_ref(b, h, g, d, m, k, n, blk):
  rng = np.random.default_rng(hash((b, h, g, d, m, k, n)) % 2**31)
  q, kcb, vcb, kix, vix = _pq_inputs(rng, b, h, g, d, m, k, n)
  length = jnp.full((b, h), n - 17, jnp.int32)
  scale = 1 / np.sqrt(d)
  out, mx, dn = ops.pq_decode_attention(
      q, kcb, vcb, kix, vix, length, scale, blk=blk)
  bh = b * h
  r_out, r_stats = ref.pq_decode_attention_ref(
      q.reshape(bh, g, d), kcb.reshape(bh, m, k, d // m),
      vcb.reshape(bh, m, k, d // m), kix.reshape(bh, n, m),
      vix.reshape(bh, n, m), length.reshape(-1), scale)
  np.testing.assert_allclose(np.asarray(out).reshape(bh, g, d),
                             np.asarray(r_out), rtol=1e-3, atol=1e-3)
  np.testing.assert_allclose(np.asarray(mx).reshape(bh, g),
                             np.asarray(r_stats[:, 0]), rtol=1e-4, atol=1e-4)
  np.testing.assert_allclose(np.asarray(dn).reshape(bh, g),
                             np.asarray(r_stats[:, 1]), rtol=1e-3, atol=1e-3)


def test_pq_decode_zero_length_body():
  """Empty body (prefill shorter than sink+recent): kernel must not NaN."""
  rng = np.random.default_rng(9)
  q, kcb, vcb, kix, vix = _pq_inputs(rng, 1, 1, 2, 32, 4, 8, 64)
  out, mx, dn = ops.pq_decode_attention(
      q, kcb, vcb, kix, vix, jnp.zeros((1, 1), jnp.int32), 0.2, blk=64)
  assert bool(jnp.all(jnp.isfinite(out)))
  assert float(jnp.max(dn)) == 0.0


def test_combine_segments_exact():
  """Flash-decoding combine over segments == one joint softmax."""
  rng = np.random.default_rng(10)
  g, d, n1, n2 = 2, 16, 40, 24
  q = jnp.asarray(rng.normal(size=(g, d)), jnp.float32)
  k = jnp.asarray(rng.normal(size=(n1 + n2, d)), jnp.float32)
  v = jnp.asarray(rng.normal(size=(n1 + n2, d)), jnp.float32)
  scale = 0.25

  def seg(lo, hi):
    s = (q @ k[lo:hi].T) * scale
    mm = jnp.max(s, -1)
    p = jnp.exp(s - mm[:, None])
    return (p @ v[lo:hi]) / jnp.sum(p, -1)[:, None], mm, jnp.sum(p, -1)

  o1, m1, l1 = seg(0, n1)
  o2, m2, l2 = seg(n1, n1 + n2)
  got = ops.combine_attention_segments([o1, o2], [m1, m2], [l1, l2])
  from repro.core import pq_attention as pqa
  want = pqa.exact_decode_attention(
      q, k, v, jnp.ones((n1 + n2,), bool), scale)
  np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                             rtol=1e-5, atol=1e-5)
