"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the real (1) device;
only launch/dryrun.py pins 512 placeholder devices, in its own process."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks/

import jax
import numpy as np
import pytest


@pytest.fixture
def rng():
  return np.random.default_rng(0)


@pytest.fixture
def key():
  return jax.random.PRNGKey(0)
