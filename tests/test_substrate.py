"""Substrate tests: optimizer, data determinism, checkpoint, fault tolerance,
sharding divisibility, importance weights."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs import ARCHS, get_arch
from repro.core import importance
from repro.data import pipeline as data_lib
from repro.models import Model
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.runtime import fault_tolerance as ft


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
  cfg = adamw.OptConfig(lr=0.1, warmup_steps=5, total_steps=200,
                        weight_decay=0.0, master_f32=False)
  params = {"w": jnp.asarray([5.0, -3.0])}
  state = adamw.init(cfg, params)
  for _ in range(150):
    g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
    params, state, _ = adamw.update(cfg, state, params, g)
  assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_adamw_bf16_params_with_f32_master():
  cfg = adamw.OptConfig(lr=0.05, warmup_steps=1, total_steps=100,
                        weight_decay=0.0, master_f32=True)
  params = {"w": jnp.asarray([1.0, 2.0], jnp.bfloat16)}
  state = adamw.init(cfg, params)
  for _ in range(50):
    g = jax.grad(lambda p: jnp.sum(p["w"].astype(jnp.float32) ** 2))(params)
    params, state, _ = adamw.update(cfg, state, params, g)
  assert params["w"].dtype == jnp.bfloat16
  assert float(jnp.max(jnp.abs(state.master["w"]))) < 0.5


def test_grad_compression_error_feedback_converges():
  """int8-compressed grads with error feedback still minimize the objective."""
  cfg = adamw.OptConfig(lr=0.1, warmup_steps=2, total_steps=300,
                        weight_decay=0.0, master_f32=False,
                        compress_grads=True)
  params = {"w": jnp.linspace(-2, 2, 16)}
  state = adamw.init(cfg, params)
  for _ in range(200):
    g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
    params, state, _ = adamw.update(cfg, state, params, g)
  assert float(jnp.max(jnp.abs(params["w"]))) < 0.15


def test_clip_norm_bounds_update():
  cfg = adamw.OptConfig(lr=1.0, warmup_steps=0, total_steps=10,
                        clip_norm=1e-3, weight_decay=0.0, master_f32=False)
  params = {"w": jnp.zeros((4,))}
  state = adamw.init(cfg, params)
  huge = {"w": jnp.full((4,), 1e6)}
  _, _, m = adamw.update(cfg, state, params, huge)
  assert float(m["grad_norm"]) > 1e5   # raw norm reported pre-clip


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_by_step():
  cfg = data_lib.DataConfig(vocab_size=1000, seq_len=64, global_batch=4)
  a = data_lib.make_batch(cfg, 7)
  b = data_lib.make_batch(cfg, 7)
  np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                np.asarray(b["tokens"]))
  c = data_lib.make_batch(cfg, 8)
  assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_data_shard_slices_consistent():
  """Row r of the global batch is identical however the batch is sliced."""
  cfg = data_lib.DataConfig(vocab_size=500, seq_len=32, global_batch=8)
  full = data_lib._batch_numpy(cfg, 3, 0, 8)
  part = data_lib._batch_numpy(cfg, 3, 5, 8)
  np.testing.assert_array_equal(full[5:], part)


def test_data_has_induction_structure():
  cfg = data_lib.DataConfig(vocab_size=100, seq_len=128, global_batch=1,
                            induction_period=32)
  t = np.asarray(data_lib.make_batch(cfg, 0)["tokens"])[0]
  np.testing.assert_array_equal(t[16:32], t[0:16])


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip():
  tree = {"a": jnp.arange(6).reshape(2, 3),
          "b": {"c": jnp.ones((4,), jnp.bfloat16)},
          "d": jnp.asarray(3, jnp.int32)}
  with tempfile.TemporaryDirectory() as d:
    ckpt_lib.save(d, 42, tree, extra={"next_step": 42})
    assert ckpt_lib.latest_step(d) == 42
    restored, extra = ckpt_lib.restore(d, 42, tree)
    assert extra["next_step"] == 42
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
      np.testing.assert_array_equal(np.asarray(a, np.float32),
                                    np.asarray(b, np.float32))


def test_checkpoint_async_and_latest():
  tree = {"w": jnp.ones((8, 8))}
  with tempfile.TemporaryDirectory() as d:
    cp = ckpt_lib.AsyncCheckpointer()
    cp.save_async(d, 1, tree)
    cp.save_async(d, 2, tree)   # waits for 1 internally
    cp.wait()
    assert ckpt_lib.latest_step(d) == 2


def test_checkpoint_ignores_partial_writes():
  tree = {"w": jnp.ones((2,))}
  with tempfile.TemporaryDirectory() as d:
    ckpt_lib.save(d, 5, tree)
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    assert ckpt_lib.latest_step(d) == 5


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_restart_resumes_and_matches_uninterrupted_run():
  def make_run(fail_at, d):
    inj = ft.FailureInjector(fail_at=fail_at)
    def init_state():
      return {"x": jnp.zeros(()), "hist": jnp.zeros((50,))}
    def step_fn(state, step):
      x = state["x"] + step
      return {"x": x, "hist": state["hist"].at[step].set(x)}
    return ft.run_with_restarts(
        total_steps=30, ckpt_dir=d, ckpt_every=5,
        init_state_fn=init_state, step_fn=step_fn, injector=inj)

  with tempfile.TemporaryDirectory() as d1:
    clean, rep1 = make_run((), d1)
  with tempfile.TemporaryDirectory() as d2:
    failed, rep2 = make_run((7, 18), d2)
  assert rep2.restarts == 2
  assert rep2.resumed_from == [5, 15]
  np.testing.assert_allclose(np.asarray(clean["hist"]),
                             np.asarray(failed["hist"]))


def test_straggler_monitor_flags_slow_steps():
  mon = ft.StragglerMonitor(window=10, timeout_factor=3.0)
  for i in range(10):
    mon.record(i, 0.01)
  assert mon.record(10, 0.2) is True
  assert 10 in mon.flagged


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", list(ARCHS))
def test_param_specs_divisible_on_production_mesh(arch, key):
  """Every sharded dim of every param divides the 16-way model axis."""
  cfg = get_arch(arch)          # FULL config
  model = Model(cfg, context_len=4096)
  abstract = jax.eval_shape(model.init, key)
  specs = shd.param_pspecs(abstract, cfg, 16)

  def check(leaf, spec):
    for dim, ax in zip(leaf.shape[leaf.ndim - len(spec):], spec):
      if ax is not None:
        size = 16 if ax == "model" else 16
        assert dim % size == 0, (leaf.shape, tuple(spec))
  jax.tree_util.tree_map(
      check, abstract, specs,
      is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))


def test_cache_specs_divisible_long500k(key):
  from repro.launch.mesh import make_local_mesh
  cfg = get_arch("llama3-405b", reduced=True)
  model = Model(cfg, context_len=1024)
  cache = jax.eval_shape(lambda: model.init_cache(1))
  mesh = make_local_mesh()
  specs = shd.cache_pspecs(cache, mesh, batch=1, shard_sequence=True)
  assert jax.tree_util.tree_structure(specs) is not None


# ---------------------------------------------------------------------------
# importance weights (Eq. 1)
# ---------------------------------------------------------------------------

def test_importance_matches_dense_colsum(key):
  n, d, t = 64, 16, 8
  q = jax.random.normal(key, (n, d))
  k = jax.random.normal(jax.random.PRNGKey(1), (n, d))
  scale = 1 / np.sqrt(d)
  w = importance.attention_importance_weights(q, k, scale, t=t, chunk=16)
  # dense oracle
  s = (q @ k.T) * scale
  mask = jnp.tril(jnp.ones((n, n), bool))
  s = jnp.where(mask, s, -jnp.inf)
  p = jax.nn.softmax(s, axis=-1)
  want = jnp.sum(p[-t:], axis=0)
  np.testing.assert_allclose(np.asarray(w), np.asarray(want),
                             rtol=1e-4, atol=1e-5)


def test_importance_respects_dynamic_length(key):
  n, d, t, ln = 64, 8, 4, 40
  q = jax.random.normal(key, (n, d))
  k = jax.random.normal(jax.random.PRNGKey(2), (n, d))
  w = importance.attention_importance_weights(
      q, k, 0.3, t=t, chunk=16, length=jnp.int32(ln))
  assert float(jnp.sum(w[ln:])) == 0.0
  np.testing.assert_allclose(float(jnp.sum(w)), t, rtol=1e-4)
