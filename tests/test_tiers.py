"""Tiered KV block pools: refcounted two-tier allocation, residency state
machine, spill codecs, tiered-vs-contiguous token oracles under forced
spill/fetch traffic, and the measured compressed-vs-raw transfer claim."""
import dataclasses

try:
  from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback shim
  from hypothesis_compat import given, settings, strategies as st

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import cache_api, cache_registry, tiers
from repro.core import kv_cache as kvc
from repro.core import pq as pqlib
from repro.launch.engine import ServeEngine


def _cfg(policy="exact", dtype="float32", **kw):
  return dataclasses.replace(get_arch("tinyllama-1.1b", reduced=True),
                             cache_policy=policy, dtype_str=dtype, **kw)


def _pool_drained(layout):
  """Post-drain invariants: every block free on both tiers, no spill residue,
  all refcounts back to zero."""
  layout.manager.check_invariants()
  layout.pool.check()
  assert layout.free_blocks == layout.num_blocks
  assert layout.pool.allocated_count(tiers.DEVICE) == 0
  assert layout.pool.allocated_count(tiers.HOST) == 0
  assert not layout.records


# ---------------------------------------------------------------------------
# TieredBlockPool: refcounts, residency, LRU
# ---------------------------------------------------------------------------

def test_pool_refcounts_and_double_free():
  pool = tiers.TieredBlockPool(4, 2)
  ids = pool.alloc(2, owner="a")
  assert pool.refcount(ids[0]) == 1
  pool.ref(ids)                         # prefix-sharing groundwork
  assert pool.refcount(ids[0]) == 2
  assert pool.unref(ids, owner="a") == []          # refs 2 -> 1: not freed
  assert pool.free_count() == 2
  assert pool.unref(ids, owner="a") == ids         # refs 1 -> 0: freed
  assert pool.free_count() == 4
  with pytest.raises(ValueError):
    pool.unref(ids, owner="a")          # double free
  ids = pool.alloc(1, owner="a")
  with pytest.raises(ValueError):
    pool.unref(ids, owner="b")          # wrong owner
  # host tier is independent accounting
  h = pool.alloc(2, owner=7, tier=tiers.HOST)
  assert pool.free_count(tiers.HOST) == 0
  assert pool.alloc(1, owner=7, tier=tiers.HOST) is None
  pool.unref(h, owner=7, tier=tiers.HOST)
  pool.check()


def test_pool_residency_state_machine():
  pool = tiers.TieredBlockPool(4, 4)
  res = pool.alloc(1, owner=0)
  assert pool.state(res[0]) == tiers.BLOCK_RESIDENT
  inflight = pool.alloc(2, owner=("fetch", 9), state=tiers.BLOCK_IN_FLIGHT)
  with pytest.raises(AssertionError):
    pool.assert_state(inflight, tiers.BLOCK_RESIDENT)   # decode must not touch
  pool.set_state(inflight, tiers.BLOCK_RESIDENT)        # fetch completion
  pool.assert_state(inflight, tiers.BLOCK_RESIDENT)
  with pytest.raises(ValueError):
    pool.set_state(inflight, tiers.BLOCK_IN_FLIGHT)     # no reverse transition
  with pytest.raises(ValueError):
    pool.alloc(1, owner=1, state=tiers.BLOCK_SPILLED)   # illegal on device
  host = pool.alloc(1, owner=1, tier=tiers.HOST)
  assert pool.state(host[0], tiers.HOST) == tiers.BLOCK_SPILLED
  with pytest.raises(ValueError):
    pool.set_state(host, tiers.BLOCK_RESIDENT, tier=tiers.HOST)
  pool.check()


def test_pool_lru_cold_victim_order():
  pool = tiers.TieredBlockPool(6, 0)
  a = pool.alloc(2, owner="a")
  b = pool.alloc(2, owner="b")
  c = pool.alloc(2, owner="c")
  pool.touch(a)
  pool.touch(c)
  pool.touch(b)                          # b is hottest, a coldest of touched
  assert pool.lru_owner(["a", "b", "c"]) == "a"
  pool.touch(a)
  assert pool.lru_owner(["a", "b", "c"]) == "c"
  assert pool.lru_owner([]) is None
  # an owner with no blocks is colder than any touched owner
  assert pool.lru_owner(["b", "ghost"]) == "ghost"


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), dev=st.integers(1, 12),
       host=st.integers(0, 12))
def test_pool_random_traffic_refcount_invariants(seed, dev, host):
  """Random alloc/ref/unref/spill-move traffic across both tiers: the pool
  never double-allocates, never leaks, and a full drain returns every
  refcount to zero."""
  rng = np.random.default_rng(seed)
  pool = tiers.TieredBlockPool(dev, host)
  held = {tiers.DEVICE: {}, tiers.HOST: {}}   # tier -> owner -> [(id, refs)]
  for _ in range(200):
    tier = int(rng.random() < 0.3) if host else tiers.DEVICE
    op = rng.random()
    if op < 0.45:
      owner = int(rng.integers(0, 3))
      n = int(rng.integers(0, pool.num_blocks[tier] + 1))
      ids = pool.alloc(n, owner=owner, tier=tier)
      in_use = sum(len(v) for v in held[tier].values())
      if n > pool.num_blocks[tier] - in_use:
        assert ids is None              # over-ask fails atomically
      else:
        assert ids is not None and len(ids) == n
        flat = [i for v in held[tier].values() for i, _ in v]
        assert not set(ids) & set(flat), "double allocation"
        if ids:
          held[tier].setdefault(owner, []).extend((i, 1) for i in ids)
    elif op < 0.6 and held[tier]:
      owner = list(held[tier])[int(rng.integers(0, len(held[tier])))]
      blocks = held[tier][owner]
      j = int(rng.integers(0, len(blocks)))
      pool.ref([blocks[j][0]], tier=tier)
      blocks[j] = (blocks[j][0], blocks[j][1] + 1)
    elif held[tier]:
      owner = list(held[tier])[int(rng.integers(0, len(held[tier])))]
      blocks = held[tier].pop(owner)
      keep = []
      for i, refs in blocks:
        freed = pool.unref([i], owner=owner, tier=tier)
        if refs > 1:
          assert freed == [], "freed while references remain"
          keep.append((i, refs - 1))
        else:
          assert freed == [i]
      if keep:
        held[tier][owner] = keep
    pool.check()
  for tier in (tiers.DEVICE, tiers.HOST):
    for owner, blocks in list(held[tier].items()):
      for i, refs in blocks:
        for _ in range(refs):
          pool.unref([i], owner=owner, tier=tier)
  pool.check()
  assert pool.allocated_count(tiers.DEVICE) == 0
  assert pool.allocated_count(tiers.HOST) == 0


# ---------------------------------------------------------------------------
# Spill codecs
# ---------------------------------------------------------------------------

def test_raw_codec_roundtrips_bit_exact(rng):
  import jax.numpy as jnp
  x = np.asarray(jnp.asarray(rng.normal(size=(3, 2, 8, 4)), jnp.bfloat16))
  enc, nb = tiers.get_codec("raw").encode(x)
  assert nb == x.nbytes
  out = tiers.get_codec("raw").decode(enc, x.shape, x.dtype)
  np.testing.assert_array_equal(np.asarray(out, np.float32),
                                np.asarray(x, np.float32))


def test_int8_codec_compresses_and_bounds_error(rng):
  x = rng.normal(size=(4, 2, 8, 16)).astype(np.float32)
  codec = tiers.get_codec("int8")
  enc, nb = codec.encode(x)
  assert nb < x.nbytes                  # actually smaller than raw f32
  out = codec.decode(enc, x.shape, np.float32)
  # 8-bit asymmetric quant: error bounded by half a step of the row range
  step = (x.max(-1) - x.min(-1)).max() / 255.0
  assert np.abs(out - x).max() <= step
  with pytest.raises(KeyError):
    tiers.get_codec("zstd")


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), key=st.sampled_from(["q4", "q8"]),
       count=st.sampled_from([1, 5, 31, 32, 33, 64, 321]),
       mag=st.sampled_from([1e-3, 1.0, 1e3]))
def test_packed_spill_codec_roundtrip_odd_tails(seed, key, count, mag):
  """q4/q8 over the flattened stream: byte accounting matches the block
  format exactly, tail groups (count not a multiple of 32) trim back to the
  original element count, and per-group error obeys the half-step bound
  (+ f16 header rounding) at any magnitude, negatives included."""
  rng = np.random.default_rng(seed)
  codec = tiers.get_codec(key)
  x = rng.normal(scale=mag, size=count).astype(np.float32)
  payload, nbytes = codec.encode(x)
  groups = -(-count // 32)
  assert nbytes == groups * (32 * codec.bits // 8 + 4)
  out = codec.decode(payload, (count,), np.float32)
  assert out.shape == (count,)
  pad = np.concatenate([x, np.repeat(x[-1], (-count) % 32)])
  xg = pad.reshape(groups, 32)
  step = (((xg.max(1) - xg.min(1)) / ((1 << codec.bits) - 1))
          .astype(np.float16).astype(np.float32))
  err = np.abs(out - x)
  tol = (0.5 * step + 2 ** -11 * (step * ((1 << codec.bits) - 1)
                                 + np.abs(xg).max(1)) + 1e-12)
  for g in range(groups):
    lo, hi = g * 32, min((g + 1) * 32, count)
    assert err[lo:hi].max() <= tol[g], (key, count, g)


def test_packed_spill_codec_beats_int8_on_real_rows(rng):
  """The PR 8 traffic claim at codec level: q4 moves < 0.55x the bytes int8
  moves on identical KV rows (f16 group headers amortize against int8's
  per-row f32 scale/zero), q8 lands between."""
  x = rng.normal(size=(4, 2, 8, 16)).astype(np.float32)
  size = {k: tiers.get_codec(k).encode(x)[1] for k in ("int8", "q4", "q8")}
  assert size["q4"] / size["int8"] < 0.55
  assert size["q4"] < size["q8"] < size["int8"] < x.nbytes


def test_spec_validates_spill_codec_and_policies_expose_codecs():
  with pytest.raises(ValueError, match="spill_codec"):
    cache_api.CacheSpec(capacity=64, head_dim=16, window=64,
                        spill_codec="gzip")
  spec = cache_api.CacheSpec(capacity=64, head_dim=16, window=32, sink=4,
                             recent=8, spill_codec="int8",
                             pq=kvc.PQCacheConfig(
                                 sink=4, recent=8, body_capacity=64,
                                 pq=pqlib.PQConfig(m=4, k=16)))
  exact = cache_registry.make("exact", spec)
  assert exact.spill_codecs() == kvc.ExactLayerCache(k="int8", v="int8")
  snap = cache_registry.make("snapkv", spec)
  # importance weights always spill raw (quantizing them would perturb
  # eviction choices across a swap)
  assert snap.spill_codecs().w == "raw"
  pq = cache_registry.make("pq", spec)
  # PQ code rows spill verbatim: they ARE the compressed representation
  assert pq.spill_codecs().key_indices == "raw"


# ---------------------------------------------------------------------------
# Tiered engine oracles: token-identical under forced spill/fetch
# ---------------------------------------------------------------------------

def test_tiered_spills_fetches_and_matches_contiguous_oracle():
  """Acceptance: traffic whose KV footprint exceeds the device pool
  completes under tiered+tiered via spill-to-host (KV preserved, zero
  recompute), token-identical to the contiguous run of the same trace."""
  cfg = _cfg()
  oracle = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32)
  tiered = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                       params=oracle.params, cache_layout="tiered",
                       scheduler="tiered", num_blocks=5, host_blocks=16)
  trace = [(list(range(1, 21)), 14), (list(range(3, 25)), 14)]
  want = [oracle.submit(p, max_new_tokens=m) for p, m in trace]
  got = [tiered.submit(p, max_new_tokens=m) for p, m in trace]
  oracle.run_to_completion()
  tiered.run_to_completion()

  assert tiered.stats.spills >= 1           # pool pressure actually hit
  assert tiered.stats.fetches == tiered.stats.spills
  assert tiered.stats.preempts == 0         # swap replaced recompute entirely
  assert sum(r.spill_count for r in got) == tiered.stats.spills
  for w, g in zip(want, got):
    assert g.done and g.tokens == w.tokens, g.rid
  led = tiered.layout.ledger
  assert led.spill_bytes > 0 and led.fetch_bytes == led.spill_bytes
  assert led.spill_blocks == led.fetch_blocks > 0
  assert tiered.stats.spill_bytes == led.spill_bytes
  assert tiered.stats.modeled_pcie_s == led.modeled_pcie_s > 0
  _pool_drained(tiered.layout)


def test_tiered_pq_codes_spill_and_match_oracle():
  """AQPIM pq over the tiered pool: code rows spill verbatim, resident
  rings/codebooks survive the swap bit-exactly, tokens match contiguous."""
  cfg = _cfg("pq", dtype="bfloat16")
  oracle = ServeEngine(cfg, context_len=96, max_batch=2, prompt_capacity=64)
  tiered = ServeEngine(cfg, context_len=96, max_batch=2, prompt_capacity=64,
                       params=oracle.params, cache_layout="tiered",
                       scheduler="tiered", num_blocks=7, host_blocks=32)
  trace = [(list(range(2, 60)), 24), (list(range(4, 49)), 24)]
  want = [oracle.submit(p, max_new_tokens=m) for p, m in trace]
  got = [tiered.submit(p, max_new_tokens=m) for p, m in trace]
  oracle.run_to_completion()
  tiered.run_to_completion()
  assert tiered.stats.spills >= 1
  for w, g in zip(want, got):
    assert g.done and g.tokens == w.tokens, g.rid
  _pool_drained(tiered.layout)


def test_tiered_random_traffic_oracle(rng):
  """Randomized admit/spill/fetch traffic under a tight pool: tokens stay
  identical to contiguous for every request, refcounts/residency clean."""
  cfg = _cfg()
  oracle = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32)
  tiered = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                       params=oracle.params, cache_layout="tiered",
                       scheduler="tiered", num_blocks=5, host_blocks=24)
  pairs = []
  for _ in range(7):
    plen = int(rng.integers(12, 30))
    gen = int(rng.integers(6, min(16, 64 - plen)))
    prompt = rng.integers(1, cfg.vocab_size, size=plen).tolist()
    pairs.append((oracle.submit(prompt, max_new_tokens=gen),
                  tiered.submit(prompt, max_new_tokens=gen)))
  oracle.run_to_completion()
  tiered.run_to_completion()
  for w, g in pairs:
    assert g.tokens == w.tokens, (w.rid, w.tokens, g.tokens)
  assert tiered.stats.spills >= 1, "trace never exercised the spill path"
  _pool_drained(tiered.layout)


def test_fetch_ahead_starts_transfer_before_admit():
  """The one-step fetch-ahead hint: at least one swap-in's transfer starts
  (IN_FLIGHT) on the step before its admit finalizes it."""
  cfg = _cfg()
  eng = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                    cache_layout="tiered", scheduler="tiered",
                    num_blocks=5, host_blocks=16)
  eng.submit(list(range(1, 21)), max_new_tokens=14)
  eng.submit(list(range(3, 25)), max_new_tokens=14)
  eng.run_to_completion()
  assert eng.stats.fetches >= 1
  assert eng.stats.prefetches >= 1
  assert eng.stats.prefetches <= eng.stats.fetches
  _pool_drained(eng.layout)


def test_int8_spill_codec_end_to_end_compresses():
  """Opt-in int8 exact-KV spilling: completes, and the ledger shows the
  boundary traffic genuinely below the raw equivalent."""
  cfg = _cfg(spill_codec="int8")
  eng = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                    cache_layout="tiered", scheduler="tiered",
                    num_blocks=5, host_blocks=16)
  a = eng.submit(list(range(1, 21)), max_new_tokens=14)
  b = eng.submit(list(range(3, 25)), max_new_tokens=14)
  eng.run_to_completion()
  assert a.done and b.done
  led = eng.layout.ledger
  assert eng.stats.spills >= 1
  assert led.compression_ratio < 1.0
  assert led.spill_bytes < led.spill_raw_bytes
  _pool_drained(eng.layout)


@pytest.mark.parametrize("codec,max_ratio", [("q4", 0.20), ("q8", 0.32)])
def test_packed_spill_codec_token_identity_vs_oracle(codec, max_ratio):
  """Sub-byte spill under forced spill/fetch traffic: greedy tokens stay
  identical to the contiguous oracle on this trace (the lossy roundtrip
  only touches spilled-and-fetched blocks, and its half-step perturbation
  does not flip any argmax here), while the ledger shows the boundary
  traffic at the analytic packed fraction of raw f32 (q4 0.15625,
  q8 0.28125 — block leaves divide evenly into 32-groups, no tail)."""
  cfg = _cfg(spill_codec=codec)
  oracle = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32)
  tiered = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                       params=oracle.params, cache_layout="tiered",
                       scheduler="tiered", num_blocks=5, host_blocks=16)
  trace = [(list(range(1, 21)), 14), (list(range(3, 25)), 14)]
  want = [oracle.submit(p, max_new_tokens=m) for p, m in trace]
  got = [tiered.submit(p, max_new_tokens=m) for p, m in trace]
  oracle.run_to_completion()
  tiered.run_to_completion()
  assert tiered.stats.spills >= 1, "trace never exercised the spill path"
  for w, g in zip(want, got):
    assert g.done and g.tokens == w.tokens, g.rid
  led = tiered.layout.ledger
  assert led.spill_bytes < max_ratio * led.spill_raw_bytes
  assert led.fetch_bytes == led.spill_bytes
  _pool_drained(tiered.layout)


def test_tiered_falls_back_to_recompute_when_host_pool_full():
  """Graceful degradation: a host tier too small to hold the victim's KV
  falls back to PR 2 recompute preemption instead of wedging — still
  finishing with correct tokens."""
  cfg = _cfg()
  oracle = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32)
  tiered = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                       params=oracle.params, cache_layout="tiered",
                       scheduler="tiered", num_blocks=5, host_blocks=1)
  trace = [(list(range(1, 21)), 14), (list(range(3, 25)), 14)]
  want = [oracle.submit(p, max_new_tokens=m) for p, m in trace]
  got = [tiered.submit(p, max_new_tokens=m) for p, m in trace]
  oracle.run_to_completion()
  tiered.run_to_completion()
  assert tiered.stats.preempts >= 1     # recompute path taken
  for w, g in zip(want, got):
    assert g.done and g.tokens == w.tokens, g.rid
  _pool_drained(tiered.layout)


def test_tiered_scheduler_requires_tiered_layout():
  with pytest.raises(ValueError, match="tiered"):
    ServeEngine(_cfg(), context_len=64, max_batch=1, prompt_capacity=16,
                cache_layout="paged", scheduler="tiered")


# ---------------------------------------------------------------------------
# The measured communication claim (paper abstract / Fig. 13)
# ---------------------------------------------------------------------------

def test_pq_spill_traffic_under_quarter_of_exact_raw():
  """Acceptance: on an identical forced-spill trace, AQPIM pq moves < 25%
  of the bytes across the tier boundary that raw exact KV moves — the same
  numbers benchmarks/run.py --json records into BENCH_serve.json."""
  from benchmarks.run import run_tiered_transfer
  rec = run_tiered_transfer("tinyllama-1.1b")
  assert rec["policies"]["exact"]["spills"] >= 1
  assert rec["policies"]["pq"]["spills"] >= 1
  assert rec["pq_vs_exact_raw_spill"] is not None
  assert rec["pq_vs_exact_raw_spill"] < 0.25
