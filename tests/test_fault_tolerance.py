"""Fault-tolerant serving (PR 9): the multi-surface FaultPlan (seeded,
order-independent draws), per-fault-class zero-leak + survivor token
identity vs a fault-free oracle, bounded-retry exhaustion semantics,
SLO-driven admission control (shed-beats-stall, tenant priority,
degradation state machine), crash-safe prefix-cache snapshot/restore
through checkpoint/ckpt.py, and the atomic stats-json writer."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.checkpoint import ckpt as ckpt_lib
from repro.core import tiers
from repro.launch import slo as slo_lib
from repro.launch import serve
from repro.launch import workload as wl
from repro.launch.engine import ServeEngine
from repro.runtime import fault_tolerance as ft

from test_tiers import _pool_drained
from test_workload import _SIZING, _cfg, _spec, _tiered


# ---------------------------------------------------------------------------
# FaultPlan: seeded, order-independent, bounded
# ---------------------------------------------------------------------------

def test_fault_plan_draws_deterministic_and_order_independent():
  a = ft.make_fault_plan("fetch", 0.5, seed=11)
  b = ft.make_fault_plan("fetch", 0.5, seed=11)
  pairs = [(r, t) for r in range(6) for t in range(3)]

  def fires(plan, rid, attempt):
    try:
      plan.check_fetch(rid, attempt)
      return False
    except ft.SimulatedFailure:
      return True

  fwd = [fires(a, r, t) for r, t in pairs]
  rev = [fires(b, r, t) for r, t in reversed(pairs)]
  assert fwd == list(reversed(rev))       # same (rid, attempt) -> same draw
  assert a.injected == b.injected == sum(fwd)
  assert a.by_surface["fetch"] == a.injected
  c = ft.make_fault_plan("fetch", 0.5, seed=12)
  assert [fires(c, r, t) for r, t in pairs] != fwd


def test_fault_plan_surfaces_isolated_and_bounded():
  # enabling one surface never perturbs another's stream: the corrupt
  # draws of a corrupt-only plan match those of an all-surfaces plan
  solo = ft.make_fault_plan("corrupt-spill", 0.5, seed=7)
  both = ft.FaultPlan(fetch_rate=0.5, corrupt_rate=0.5, seed=7)
  want = [solo.should_corrupt_spill(r, t)
          for r in range(8) for t in range(2)]
  got = [both.should_corrupt_spill(r, t)
         for r in range(8) for t in range(2)]
  assert want == got
  # max_failures bounds injections across every surface
  capped = ft.FaultPlan(fetch_rate=1.0, decode_rate=1.0, seed=0,
                        max_failures=3)
  fired = 0
  for i in range(10):
    try:
      capped.check_fetch(i)
    except ft.SimulatedFailure:
      fired += 1
    fired += capped.check_decode(i)
  assert fired == capped.injected == 3
  with pytest.raises(KeyError):
    ft.make_fault_plan("cosmic-ray", 0.5)


def test_alloc_spike_blocks_plumbed():
  plan = ft.make_fault_plan("alloc-exhaustion", 1.0, seed=0,
                            alloc_spike_blocks=3)
  assert plan.alloc_spike(step=0) == 3
  assert plan.by_surface["alloc-exhaustion"] == 1


# ---------------------------------------------------------------------------
# fault matrix: zero leaks + survivor token identity, every surface
# ---------------------------------------------------------------------------

def _plan_for(kind, seed=3):
  # corrupt-spill at rate 1.0 would livelock the recompute -> respill ->
  # corrupt cycle, so bound it; likewise the shard surfaces — on this
  # single-device engine every confirmed loss is a whole-pool restart, so
  # an unbounded rate would wipe progress faster than requests can finish
  # (a stall needs confirm_after=2 consecutive draws per death, hence the
  # larger budget).  The other surfaces self-limit via retries.
  if kind == "corrupt-spill":
    return ft.make_fault_plan(kind, 1.0, seed=seed, max_failures=2)
  if kind == "shard-loss":
    return ft.make_fault_plan(kind, 1.0, seed=seed, max_failures=2)
  if kind == "shard-stall":
    return ft.make_fault_plan(kind, 1.0, seed=seed, max_failures=4)
  return ft.make_fault_plan(kind, 0.3, seed=seed)


@pytest.mark.parametrize("kind", sorted(ft.FAULT_KINDS))
def test_fault_matrix_survivors_identical_pools_drained(kind):
  spec = _spec("exact", seed=3)
  oracle = _tiered("exact", clock=wl.VirtualClock())
  r_oracle = wl.WorkloadDriver(oracle, spec).run()
  _pool_drained(oracle.layout)

  plan = _plan_for(kind)
  eng = _tiered("exact", params=oracle.params, clock=wl.VirtualClock(),
                fault_injector=plan)
  r = wl.WorkloadDriver(eng, spec).run()
  _pool_drained(eng.layout)

  assert plan.injected >= 1, (kind, plan.by_surface)
  assert plan.by_surface[kind] == plan.injected
  survivors = [i for i in r.token_streams if i not in r.failed_indices]
  assert survivors
  for i in survivors:
    assert r.token_streams[i] == r_oracle.token_streams[i], (kind, i)
  if kind == "corrupt-spill":
    assert eng.stats.corrupt_pages == plan.injected
  if kind == "alloc-exhaustion":
    assert eng.stats.alloc_spikes == plan.injected
  if kind == "decode-transient":
    assert eng.stats.decode_faults == plan.injected


def test_fetch_retries_exhausted_fail_cleanly():
  """A persistent fetch fault (rate 1.0, unbounded) must drop the spilled
  request with `handle.failed` — not wedge the loop or leak its pages —
  while untouched requests still match the oracle."""
  spec = _spec("exact", seed=3)
  oracle = _tiered("exact", clock=wl.VirtualClock())
  r_oracle = wl.WorkloadDriver(oracle, spec).run()
  plan = ft.make_fault_plan("fetch", 1.0, seed=3)
  eng = _tiered("exact", params=oracle.params, clock=wl.VirtualClock(),
                fault_injector=plan)
  r = wl.WorkloadDriver(eng, spec).run()
  _pool_drained(eng.layout)
  assert r.failed_indices, "rate-1.0 fetch faults never dropped anything"
  assert plan.injected > eng.max_fetch_retries
  for i in r.token_streams:
    if i not in r.failed_indices:
      assert r.token_streams[i] == r_oracle.token_streams[i]


def test_decode_retry_exhaustion_surfaces():
  """Past max_decode_retries consecutive failed attempts the decode fault
  is persistent hardware trouble, not noise: it must surface, not spin."""
  plan = ft.make_fault_plan("decode-transient", 1.0, seed=0)
  eng = _tiered("exact", clock=wl.VirtualClock(), fault_injector=plan,
                max_decode_retries=2)
  eng.submit([5, 6, 7, 8], max_new_tokens=4)
  with pytest.raises(ft.SimulatedFailure):
    eng.run_to_completion()
  assert eng.stats.decode_faults == eng.max_decode_retries + 1


# ---------------------------------------------------------------------------
# SLO admission control: shedding beats stalling
# ---------------------------------------------------------------------------

def _tiered_slo(params=None, **kw):
  """Like test_workload._tiered but on the SLO scheduler with deadline
  enforcement on (that helper hard-codes scheduler='tiered')."""
  sz = _SIZING["exact"]
  eng = ServeEngine(_cfg("exact"), context_len=sz["context_len"],
                    max_batch=2, prompt_capacity=sz["prompt_capacity"],
                    params=params, cache_layout="tiered", scheduler="slo",
                    num_blocks=sz["num_blocks"],
                    host_blocks=sz["host_blocks"],
                    clock=wl.VirtualClock(), slo_enforce=True, **kw)
  eng.layout.ledger.pcie_gbps = 0.002
  return eng


def _overload_spec(n=16, seed=3, **tenant_kw):
  sz = _SIZING["exact"]
  tight = slo_lib.SLOSpec(ttft_s=0.02, tpot_s=0.002)
  tenant_kw.setdefault("slo", tight)
  tenant = wl.TenantSpec(prompt_len=sz["prompt_len"],
                         max_new_tokens=sz["gen"], **tenant_kw)
  return wl.WorkloadSpec(arrival="poisson", rate=400.0, burstiness=6.0,
                         n_requests=n, seed=seed, tenants=(tenant,))


def test_slo_shedding_beats_stalling():
  spec = _overload_spec()
  shed_eng = _tiered_slo()
  r_shed = wl.WorkloadDriver(shed_eng, spec).run()
  _pool_drained(shed_eng.layout)
  stall_eng = _tiered("exact", params=shed_eng.params,
                      clock=wl.VirtualClock())
  r_stall = wl.WorkloadDriver(stall_eng, spec).run()
  _pool_drained(stall_eng.layout)

  assert shed_eng.stats.shed_requests >= 1
  assert r_shed.report["shed"] == shed_eng.stats.shed_requests
  # the headline: cancelling doomed work raises goodput, because the
  # survivors make their deadlines instead of everyone missing together
  assert (r_shed.report["goodput_tok_s"]
          > r_stall.report["goodput_tok_s"]), (r_shed.report,
                                               r_stall.report)
  # the state machine actually moved and recorded its transitions
  trans = shed_eng.stats.degradation_transitions
  assert trans and trans[0]["old"] == "NORMAL"
  assert {t["new"] for t in trans} & {"PRESSURED", "SHEDDING"}
  # shed requests were cancelled cleanly, never marked failed
  assert len(r_shed.shed_indices) == shed_eng.stats.shed_requests
  assert all(not t.failed for t in r_shed.records if t.shed)


def test_slo_priority_tenant_protected():
  """Under overload the higher-priority tenant is shed less and lands more
  good tokens than the bulk tenant — EDF+priority sheds bulk work first.
  (goodput_frac is the wrong yardstick here: a tenant shed to near-zero
  tokens can trivially score 1.0 on the few tokens it kept.)"""
  sz = _SIZING["exact"]
  tight = slo_lib.SLOSpec(ttft_s=0.02, tpot_s=0.002)
  prio = wl.TenantSpec(name="prio", prompt_len=sz["prompt_len"],
                       max_new_tokens=sz["gen"], slo=tight, priority=1)
  bulk = wl.TenantSpec(name="bulk", prompt_len=sz["prompt_len"],
                       max_new_tokens=sz["gen"], slo=tight)
  spec = wl.WorkloadSpec(arrival="poisson", rate=400.0, burstiness=6.0,
                         n_requests=16, seed=3, tenants=(prio, bulk))
  eng = _tiered_slo()
  r = wl.WorkloadDriver(eng, spec).run()
  _pool_drained(eng.layout)
  assert eng.stats.shed_requests >= 1
  stats = {}
  for name in ("prio", "bulk"):
    recs = [t for t in r.records if t.tenant == name]
    stats[name] = (sum(t.shed for t in recs) / len(recs),
                   sum(t.good_tokens for t in recs))
  assert stats["prio"][0] < stats["bulk"][0], stats    # shed fraction
  assert stats["prio"][1] > stats["bulk"][1], stats    # good tokens


# ---------------------------------------------------------------------------
# crash-safe snapshot/restore
# ---------------------------------------------------------------------------

def _paged_prefix(params=None, snapshot_dir=None, num_blocks=10):
  cfg = _cfg("exact", dtype="bfloat16")
  return ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                     cache_layout="paged", scheduler="prefix",
                     num_blocks=num_blocks, prefix_cache=True,
                     params=params, clock=wl.VirtualClock(),
                     snapshot_dir=snapshot_dir)


def _shared_spec(seed=5):
  tenant = wl.TenantSpec(prompt_len=(20, 28), max_new_tokens=(6, 10),
                         shared_prefix_len=16)
  return wl.WorkloadSpec(arrival="poisson", rate=200.0, n_requests=6,
                         seed=seed, tenants=(tenant,))


def test_snapshot_restore_serves_warm_prefix_hits(tmp_path):
  """Acceptance: after a 'restart' (fresh engine, same snapshot_dir) the
  prefix cache is warm — nonzero restored blocks, strictly more hit
  tokens than a cold engine on the identical trace, tokens identical."""
  snap = str(tmp_path / "snap")
  spec = _shared_spec()
  e1 = _paged_prefix(snapshot_dir=snap)
  wl.WorkloadDriver(e1, spec).run()
  path = e1.save_snapshot(step=1)
  assert path and ckpt_lib.latest_step(snap) == 1

  warm = _paged_prefix(params=e1.params, snapshot_dir=snap)
  assert warm.stats.restored_prefix_blocks > 0
  warm.layout.prefix_index.check()
  r_warm = wl.WorkloadDriver(warm, spec).run()

  cold = _paged_prefix(params=e1.params)
  assert cold.stats.restored_prefix_blocks == 0
  r_cold = wl.WorkloadDriver(cold, spec).run()

  assert (warm.layout.prefix_index.hit_tokens
          > cold.layout.prefix_index.hit_tokens)
  assert r_warm.token_streams == r_cold.token_streams
  # dropping the cache drains the paged pool: restore leaked no holds
  warm.layout.prefix_clear()
  assert warm.layout.manager.allocator.free_count == warm.layout.num_blocks


def test_snapshot_restore_rejects_mismatch(tmp_path):
  """A snapshot from a different geometry (or garbage) must be refused,
  leaving the pool untouched, not scattered into the wrong blocks."""
  e1 = _paged_prefix()
  wl.WorkloadDriver(e1, _shared_spec()).run()
  tree, extra = e1.layout.prefix_snapshot()
  assert extra["kind"] == "prefix-cache" and extra["n_blocks"] > 0

  e2 = _paged_prefix(params=e1.params)
  free0 = e2.layout.manager.allocator.free_count
  assert e2.layout.prefix_restore(tree, dict(extra, block=999)) == 0
  assert e2.layout.prefix_restore(tree, dict(extra, kind="junk")) == 0
  assert e2.layout.manager.allocator.free_count == free0
  assert e2.layout.prefix_restore(tree, extra) > 0
  e2.layout.prefix_index.check()


def test_save_snapshot_noop_without_dir():
  eng = _paged_prefix()
  assert eng.save_snapshot() is None


def test_ckpt_load_raw_roundtrip(tmp_path):
  """`load_raw` restores a checkpoint without a template tree — including
  ml_dtypes leaves stored as bit-views — plus the extra metadata."""
  import jax.numpy as jnp
  tree = {"pool_0": np.arange(12, dtype=np.float32).reshape(3, 4),
          "row": np.asarray(jnp.linspace(0, 1, 8, dtype=jnp.bfloat16))}
  extra = {"kind": "prefix-cache", "chains": [[[1, 2], [0]]]}
  ckpt_lib.save(str(tmp_path), 4, tree, extra=extra)
  got, got_extra = ckpt_lib.load_raw(str(tmp_path), 4)
  assert got_extra == extra
  assert set(got) == set(tree)
  for k in tree:
    assert got[k].dtype == tree[k].dtype
    np.testing.assert_array_equal(got[k], tree[k])


# ---------------------------------------------------------------------------
# checksummed spill frames
# ---------------------------------------------------------------------------

def test_payload_checksum_order_invariant_and_sensitive():
  a = {"k": b"\x01\x02", "v": b"\x03\x04"}
  b = {"v": b"\x03\x04", "k": b"\x01\x02"}
  assert tiers.payload_checksum(a) == tiers.payload_checksum(b)
  assert (tiers.payload_checksum({"k": b"\x01\x03", "v": b"\x03\x04"})
          != tiers.payload_checksum(a))


def test_corrupt_spilled_detected_on_fetch():
  """Flipping one byte of a spilled frame must raise SpillPageCorruption
  at decode, never scatter garbage into the device pool."""
  eng = _tiered("exact", clock=wl.VirtualClock())
  spec = _spec("exact", seed=3)
  reqs = wl.generate(spec, vocab_size=eng.cfg.vocab_size,
                     max_prompt_len=eng.prompt_capacity,
                     max_total_len=eng.context_len)
  handles = [eng.submit(list(w.tokens), max_new_tokens=w.max_new_tokens)
             for w in reqs]
  while not any(h.spilled for h in handles):
    assert eng.has_work
    eng.step()
  victim = next(h for h in handles if h.spilled)
  assert eng.layout.corrupt_spilled(victim.rid)
  # the engine's fetch path detects the bad checksum, drops the host copy,
  # and recomputes the prefill — every request still completes cleanly
  eng.run_to_completion()
  assert eng.stats.corrupt_pages >= 1
  assert all(h.done and not h.failed for h in handles)
  _pool_drained(eng.layout)


# ---------------------------------------------------------------------------
# atomic stats-json writes
# ---------------------------------------------------------------------------

def test_write_json_atomic(tmp_path):
  path = str(tmp_path / "stats.json")
  serve.write_json_atomic(path, {"a": 1})
  serve.write_json_atomic(path, {"a": 2, "nested": {"b": [1, 2]}})
  assert json.load(open(path)) == {"a": 2, "nested": {"b": [1, 2]}}
  leftovers = [f for f in os.listdir(tmp_path) if ".tmp." in f]
  assert not leftovers, leftovers


# ---------------------------------------------------------------------------
# serve CLI plumbing for the robustness knobs
# ---------------------------------------------------------------------------

def test_serve_cli_robustness_flags_reach_engine(tmp_path):
  argv = ["--arch", "tinyllama-1.1b", "--reduced", "--engine",
          "--batch", "2", "--prompt-len", "16", "--gen", "8",
          "--cache-policy", "exact", "--cache-layout", "paged",
          "--scheduler", "paged", "--kv-block-size", "8",
          "--num-blocks", "12", "--prefix-cache",
          "--slo-enforce", "--snapshot-dir", str(tmp_path / "snap")]
  args = serve.make_parser().parse_args(argv)
  eng = serve.build_engine(args)
  assert eng.slo_enforce
  assert eng.snapshot_dir == str(tmp_path / "snap")
  assert serve.make_parser().parse_args(
      argv + ["--fault-kind", "corrupt-spill", "--fault-rate", "0.5"]
  ).fault_kind == "corrupt-spill"
  with pytest.raises(SystemExit):
    serve.make_parser().parse_args(argv + ["--fault-kind", "bogus"])


# ---------------------------------------------------------------------------
# multi-surface fault storm soak (PR 10): every surface armed at once
# ---------------------------------------------------------------------------

try:
  from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback shim
  from hypothesis_compat import given, settings, strategies as st


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000),
       policy=st.sampled_from(["exact", "pq"]),
       sched=st.sampled_from(["tiered", "slo"]),
       max_failures=st.integers(2, 6))
def test_multi_surface_fault_storm_soak(seed, policy, sched, max_failures):
  """Randomized soak: all six FaultPlan surfaces armed simultaneously at
  random rates over random policy/scheduler combos.  Whatever the storm
  does, the engine must end clean: zero leaked blocks on both tiers, every
  handle terminal (finished, failed, or shed), and the plan's per-surface
  ledger consistent with its global budget."""
  import random as _random
  rng = _random.Random(seed)
  plan = ft.FaultPlan(seed=seed, max_failures=max_failures,
                      alloc_spike_blocks=rng.randint(1, 3))
  for attr in ft.FAULT_KINDS.values():
    setattr(plan, attr, round(rng.uniform(0.0, 0.6), 3))

  sz = _SIZING[policy]
  spec = _spec(policy, seed=seed % 100)
  eng = ServeEngine(
      _cfg(policy, dtype="bfloat16" if policy == "pq" else "float32"),
      context_len=sz["context_len"], max_batch=2,
      prompt_capacity=sz["prompt_capacity"], cache_layout="tiered",
      scheduler=sched, num_blocks=sz["num_blocks"],
      host_blocks=sz["host_blocks"], clock=wl.VirtualClock(),
      slo_enforce=(sched == "slo"), fault_injector=plan)
  eng.layout.ledger.pcie_gbps = 0.002

  driver = wl.WorkloadDriver(eng, spec)
  result = driver.run()

  _pool_drained(eng.layout)                       # zero leaks, both tiers
  # every submitted request reached a terminal state exactly once
  assert len(result.records) == len(driver.requests)
  for t in result.records:
    assert t.finish_s is not None or t.failed or t.shed
  # the per-surface ledger sums to the global count, inside the budget
  assert sum(plan.by_surface.values()) == plan.injected
  assert plan.injected <= max_failures
  assert set(plan.by_surface) == set(ft.FAULT_KINDS)


# ---------------------------------------------------------------------------
# DegradationController hysteresis
# ---------------------------------------------------------------------------

def _controller():
  from repro.launch.engine import DegradationController
  return DegradationController()


def test_degradation_transition_table():
  """One state at a time, each move gated by SUSTAIN consecutive readings."""
  c = _controller()
  assert c.state == "NORMAL"
  # one pressured reading is not enough (SUSTAIN=2)
  assert c.observe(0.2, 0) is None
  assert c.state == "NORMAL"
  assert c.observe(0.2, 0) == ("NORMAL", "PRESSURED")
  # shed-level pressure with an empty queue only warrants PRESSURED
  assert c.observe(0.05, 0) is None
  assert c.observe(0.05, 0) is None
  assert c.state == "PRESSURED"
  # with queued work it escalates — but still one state per SUSTAIN window
  assert c.observe(0.05, 3) is None
  assert c.observe(0.05, 3) == ("PRESSURED", "SHEDDING")
  assert c.state == "SHEDDING"
  # recovery walks back down one state at a time
  assert c.observe(0.9, 0) is None
  assert c.observe(0.9, 0) == ("SHEDDING", "PRESSURED")
  assert c.observe(0.9, 0) is None
  assert c.observe(0.9, 0) == ("PRESSURED", "NORMAL")


def test_degradation_skips_no_states():
  """NORMAL under sustained shed-level pressure still passes through
  PRESSURED — the ladder has no rung-skipping."""
  c = _controller()
  transitions = [c.observe(0.01, 5) for _ in range(4)]
  assert transitions == [None, ("NORMAL", "PRESSURED"),
                         None, ("PRESSURED", "SHEDDING")]


def test_degradation_sustain_resets_on_relief():
  """A single relieved reading resets the escalation counter: pressure
  must be *consecutive* to move the state."""
  c = _controller()
  assert c.observe(0.2, 0) is None      # up=1
  assert c.observe(0.9, 0) is None      # relief: counters reset
  assert c.observe(0.2, 0) is None      # up=1 again, not 2
  assert c.state == "NORMAL"
  assert c.observe(0.2, 0) == ("NORMAL", "PRESSURED")


def test_degradation_no_flapping_under_oscillation():
  """Free-frac oscillating across the PRESSURE threshold every step never
  moves the state: each direction's counter is cleared by the next reading
  (the hysteresis that keeps one noisy step from toggling shed mode)."""
  c = _controller()
  for _ in range(20):
    assert c.observe(0.2, 2) is None    # wants PRESSURED (up=1, then reset)
    assert c.observe(0.9, 0) is None    # wants NORMAL (counters clear)
  assert c.state == "NORMAL"

  # same oscillation starting from SHEDDING: equally stuck
  c2 = _controller()
  c2.observe(0.2, 1), c2.observe(0.2, 1)
  c2.observe(0.05, 1), c2.observe(0.05, 1)
  assert c2.state == "SHEDDING"
  for _ in range(20):
    assert c2.observe(0.05, 1) is None  # wants to stay
    assert c2.observe(0.9, 0) is None   # wants NORMAL (down=1, then reset)
  assert c2.state == "SHEDDING"
