"""Shard-fault-tolerant mesh serving (PR 10): the health watchdog,
degraded-mesh re-planning, and replicated KV shard recovery.

Two layers, matching test_sharded_serve.py's split:

- In-process tests cover the pure decision logic — `ShardHealth` heartbeat
  semantics (loss confirmation, stall escalation), the seeded shard-fault
  draws on `FaultPlan`, `ShardPlan.replan`'s fallback chain over survivor
  subsets (on device-carrying mesh stand-ins), and `MirrorRecord` checksum
  verification.  None of these touch real devices.
- The acceptance matrix — killing one shard mid-decode on a forced
  8-host-device mesh and requiring the survivors' greedy tokens to stay
  bit-identical to the fault-free single-device oracle across
  {exact, pq} x {heads, seq} x {none, host-mirror}, with zero leaked
  blocks on both tiers — runs as ONE subprocess with
  `XLA_FLAGS=--xla_force_host_platform_device_count=8` (device topology
  freezes at first jax import).
"""
import os
import subprocess
import sys
import types

import numpy as np
import pytest

from repro.core import tiers
from repro.parallel import serve_sharding as ssh
from repro.parallel import sharding as shd
from repro.runtime import fault_tolerance as ft


# ---------------------------------------------------------------------------
# ShardHealth: heartbeat rounds, loss confirmation, stall escalation
# ---------------------------------------------------------------------------

class TestShardHealth:

  def test_healthy_shards_just_beat(self):
    h = ssh.ShardHealth(3)
    assert h.record() == []
    assert h.record() == []
    assert h.beats == [2, 2, 2] and h.missed == [0, 0, 0]
    assert h.alive() == [0, 1, 2]

  def test_loss_confirms_after_consecutive_misses(self):
    h = ssh.ShardHealth(4, confirm_after=2)
    h.mark_lost(2)
    assert h.record() == []          # one miss: suspected, not confirmed
    assert h.missed[2] == 1
    assert h.record() == [2]         # second consecutive miss confirms
    assert h.confirmed == {2}
    assert h.alive() == [0, 1, 3]
    assert h.record() == []          # already confirmed: never re-reported

  def test_single_stall_recovers(self):
    h = ssh.ShardHealth(2, confirm_after=2)
    h.mark_stalled(1)
    assert h.record() == []
    assert h.missed[1] == 1
    assert h.record() == []          # stall cleared: the shard beats again
    assert h.missed[1] == 0 and h.confirmed == set()

  def test_sustained_stall_escalates_to_death(self):
    h = ssh.ShardHealth(2, confirm_after=2)
    h.mark_stalled(0)
    assert h.record() == []
    h.mark_stalled(0)
    assert h.record() == [0]         # straggler held the mesh twice: dead
    assert h.alive() == [1]

  def test_as_dict_shape(self):
    h = ssh.ShardHealth(2, confirm_after=3)
    h.mark_lost(1)
    h.record()
    d = h.as_dict()
    assert d["shards"] == 2 and d["confirm_after"] == 3
    assert d["beats"] == [1, 0] and d["missed"] == [0, 1]
    assert d["lost"] == [1] and d["confirmed"] == []


# ---------------------------------------------------------------------------
# FaultPlan shard surfaces: seeded, order-independent, bounded
# ---------------------------------------------------------------------------

class TestShardFaultDraws:

  def test_draws_deterministic_and_order_independent(self):
    a = ft.make_fault_plan("shard-loss", 0.4, seed=11)
    b = ft.make_fault_plan("shard-loss", 0.4, seed=11)
    steps = list(range(24))
    fwd = [a.shard_loss(s, 4) for s in steps]
    rev = [b.shard_loss(s, 4) for s in reversed(steps)]
    assert fwd == list(reversed(rev))    # same step -> same draw, any order
    assert any(v is not None for v in fwd)
    assert all(v in (None, 0, 1, 2, 3) for v in fwd)
    assert a.injected == sum(v is not None for v in fwd)
    assert a.by_surface["shard-loss"] == a.injected
    c = ft.make_fault_plan("shard-loss", 0.4, seed=12)
    assert [c.shard_loss(s, 4) for s in steps] != fwd

  def test_stall_stream_independent_of_loss(self):
    solo = ft.make_fault_plan("shard-stall", 0.5, seed=7)
    both = ft.FaultPlan(shard_loss_rate=0.5, shard_stall_rate=0.5, seed=7)
    want = [solo.shard_stall(s, 2) for s in range(16)]
    got = [both.shard_stall(s, 2) for s in range(16)]
    assert want == got
    assert both.by_surface["shard-stall"] == sum(v is not None for v in got)

  def test_max_failures_bounds_shard_surfaces(self):
    plan = ft.FaultPlan(shard_loss_rate=1.0, seed=0, max_failures=2)
    hits = [plan.shard_loss(s, 4) for s in range(10)]
    assert sum(v is not None for v in hits) == plan.injected == 2

  def test_single_shard_draw_still_fires(self):
    # an unsharded engine is "shard 0": the draw must fire (whole-pool
    # loss), never index out of range
    plan = ft.make_fault_plan("shard-loss", 1.0, seed=0, max_failures=1)
    assert plan.shard_loss(0, 1) == 0

  def test_shard_kinds_stay_appended(self):
    # _SURFACE_IX is insertion-order derived: reordering FAULT_KINDS would
    # silently reseed every PR 9 surface's draw stream
    assert list(ft.FAULT_KINDS)[:4] == [
        "fetch", "corrupt-spill", "alloc-exhaustion", "decode-transient"]
    assert list(ft.FAULT_KINDS)[4:] == ["shard-loss", "shard-stall"]


# ---------------------------------------------------------------------------
# ShardPlan.replan: the survivor fallback chain
# ---------------------------------------------------------------------------

def _dev_mesh(data, model):
  devs = np.arange(data * model).reshape(data, model)
  return types.SimpleNamespace(devices=devs, axis_names=("data", "model"),
                               shape={"data": data, "model": model})


def _plan(mode, size, kv=4, heads=4, policy="exact", data=1):
  return ssh.ShardPlan(mesh=_dev_mesh(data, size), mode=mode, size=size,
                       n_kv_heads=kv, n_heads=heads, policy=policy)


class TestReplan:

  def test_heads_over_largest_divisor_subset(self):
    # 4-way heads loses shard 1: kv=4 has no divisor 3, so the plan takes
    # heads over the first 2 survivors
    new = _plan("heads", 4).replan([0, 2, 3])
    assert new.mode == "heads" and new.size == 2
    assert new.active and new.bit_identical
    assert list(np.asarray(new.mesh.devices).ravel()) == [0, 2]

  def test_divisible_survivors_keep_heads(self):
    new = _plan("heads", 4).replan([0, 1])
    assert new.mode == "heads" and new.size == 2

  def test_exact_falls_back_to_seq(self):
    # kv=3 over 2 survivors: no divisor >= 2, exact store splits K instead
    new = _plan("heads", 4, kv=3, heads=3).replan([1, 3])
    assert new.mode == "seq" and new.size == 2
    assert not new.bit_identical
    assert list(np.asarray(new.mesh.devices).ravel()) == [1, 3]

  def test_compressed_policy_collapses_to_single_device(self):
    # pq cannot split K (eviction couples to position): last resort is
    # unsharded serving on the first survivor
    new = _plan("heads", 4, kv=3, heads=3, policy="pq").replan([1, 3])
    assert new.mode == "none" and new.size == 1 and not new.active

  def test_sole_survivor_goes_unsharded(self):
    new = _plan("heads", 2).replan([1])
    assert new.mode == "none" and new.size == 1
    assert list(np.asarray(new.mesh.devices).ravel()) == [1]

  def test_survivors_validated(self):
    with pytest.raises(ValueError):
      _plan("heads", 4).replan([])
    with pytest.raises(ValueError):
      _plan("heads", 4).replan([0, 7])

  def test_survivor_submesh_slices_named_axis(self):
    mesh = _dev_mesh(2, 4)
    sub = shd.survivor_submesh(mesh, "model", [0, 2])
    assert np.asarray(sub.devices).shape == (2, 2)
    assert list(np.asarray(sub.devices)[0]) == [0, 2]
    assert dict(sub.shape) == {"data": 2, "model": 2}


# ---------------------------------------------------------------------------
# MirrorRecord: checksum verification
# ---------------------------------------------------------------------------

class TestMirrorRecord:

  def _record(self):
    arr = np.arange(24, dtype=np.float32).reshape(2, 3, 4)
    enc, nb = tiers.get_codec("raw").encode(arr)
    return MirrorFixture(arr, tiers.MirrorRecord(
        slot=0, rid=7, length=5, hwm=5, pairs=[(0, 3), (1, 4)],
        payloads=[("raw", enc, arr.shape, arr.dtype)],
        resident_rows=[None],
        checksums=[tiers.payload_checksum(enc)], nbytes=nb))

  def test_verify_passes_clean(self):
    self._record().rec.verify()

  def test_verify_detects_bit_flip(self):
    fx = self._record()
    fx.rec.payloads[0][1].ravel()[5] += 1.0       # rot one mirror byte
    with pytest.raises(tiers.SpillPageCorruption, match="slot 0"):
      fx.rec.verify()

  def test_device_block_ids(self):
    assert self._record().rec.device_block_ids == [3, 4]

  def test_host_mirror_accounting(self):
    m = tiers.HostMirror()
    rec = self._record().rec
    m.put(rec)
    assert m.writes == 1 and m.write_bytes == rec.nbytes
    assert m.resident_bytes == rec.nbytes
    assert m.get(0) is rec and m.get(1) is None
    d = m.as_dict()
    assert d["slots"] == [0] and d["restores"] == 0
    m.drop(0)
    assert m.resident_bytes == 0


class MirrorFixture:
  def __init__(self, arr, rec):
    self.arr, self.rec = arr, rec


# ---------------------------------------------------------------------------
# the acceptance matrix: one subprocess, 8 forced host devices
# ---------------------------------------------------------------------------

_DRIVER = r'''
import dataclasses
import numpy as np
import jax

from repro.configs import get_arch
from repro.core import tiers
from repro.launch.engine import ServeEngine
from repro.runtime import fault_tolerance as ft

assert len(jax.devices()) == 8, jax.devices()

PARAMS = {}
PROMPTS = [list(range(2, 30)), list(range(5, 29)), list(range(11, 31))]


def build(policy, mesh_model, heads, redundancy="none", plan=None,
          context_len=128, prompt_capacity=None, num_blocks=None,
          host_blocks=None):
  cfg = get_arch("tinyllama-1.1b", reduced=True)
  cfg = dataclasses.replace(cfg, cache_policy=policy, cache_layout="tiered",
                            scheduler="tiered", n_heads=heads[0],
                            n_kv_heads=heads[1])
  key = (policy, heads)
  eng = ServeEngine(cfg, context_len=context_len, max_batch=2,
                    prompt_capacity=prompt_capacity, num_blocks=num_blocks,
                    host_blocks=host_blocks, params=PARAMS.get(key),
                    mesh_model=mesh_model, shard_redundancy=redundancy,
                    fault_injector=plan, shard_confirm_after=2)
  PARAMS[key] = eng.params
  return eng


def drained(layout):
  layout.manager.check_invariants()
  layout.pool.check()
  assert layout.free_blocks == layout.num_blocks
  assert layout.pool.allocated_count(tiers.DEVICE) == 0
  assert layout.pool.allocated_count(tiers.HOST) == 0
  assert not layout.records


def serve(eng, prompts, gen, warm=None, arm=None):
  hs = [eng.submit(p, max_new_tokens=gen) for p in prompts]
  if warm:
    for _ in range(warm):
      eng.step()
    assert eng.active_count > 0, "nothing mid-decode at arming time"
    arm()
  while eng.has_work:
    eng.step()
  assert all(h.done and not h.failed for h in hs), [
      (h.rid, h.failed) for h in hs]
  return [h.tokens for h in hs]


ORACLE = {}


def oracle(policy, heads, gen=8, **kw):
  key = (policy, heads, gen)
  if key not in ORACLE:
    eng = build(policy, 1, heads, **kw)
    ORACLE[key] = serve(eng, PROMPTS, gen)
    drained(eng.layout)
  return ORACLE[key]


# -- matrix: kill one shard mid-decode, survivors must match the oracle -----
LEGS = [  # (policy, mesh_model, heads, expected initial mode)
    ("exact", 4, (4, 4), "heads"),
    ("exact", 4, (4, 2), "seq"),
    ("pq", 4, (4, 4), "heads"),
    ("pq", 2, (4, 4), "heads"),
]
for policy, m, heads, mode in LEGS:
  ref = oracle(policy, heads)
  for redundancy in ("none", "host-mirror"):
    plan = ft.FaultPlan(seed=0)               # armed mid-run
    eng = build(policy, m, heads, redundancy, plan=plan)
    assert eng.shard_plan.mode == mode, (eng.shard_plan, mode)

    def arm():
      plan.shard_loss_rate = 1.0
      plan.max_failures = plan.injected + 1   # exactly one loss fires

    got = serve(eng, PROMPTS, 8, warm=3, arm=arm)
    assert got == ref, (policy, m, heads, redundancy, ref, got)
    drained(eng.layout)
    st = eng.stats
    assert st.shard_losses >= 1 and st.shard_replans >= 1, st
    assert eng.shard_plan.size < m or not eng.shard_plan.active
    lost_data = mode == "heads"               # seq replicates storage
    if lost_data:
      assert st.shard_recovered_requests >= 1, st
      if redundancy == "host-mirror":
        assert st.shard_mirror_restores >= 1, st
      else:
        assert st.shard_mirror_restores == 0 and st.preempts >= 1, st
    info = eng.shard_health_info()
    assert info["redundancy"] == redundancy
    assert info["losses"] == st.shard_losses
    assert info["mesh_shards"] == eng.stats.mesh_shards
    if redundancy == "host-mirror":
      assert info["mirror"]["writes"] > 0
    print(f"loss[{policy}/{mode}x{m}/{redundancy}]: ok "
          f"(replan -> {eng.shard_plan.mode}x{eng.shard_plan.size}, "
          f"{st.shard_mirror_restores} mirror restores, "
          f"{st.preempts} recomputes)")

# -- genuinely seeded loss: the draw (not the test) picks step and victim ---
ref = oracle("exact", (4, 4))
plan = ft.make_fault_plan("shard-loss", 0.2, seed=3, max_failures=1)
eng = build("exact", 4, (4, 4), "host-mirror", plan=plan)
got = serve(eng, PROMPTS, 8)
assert plan.injected == 1 and eng.stats.shard_losses == 1
assert got == ref, (ref, got)
drained(eng.layout)
print(f"seeded loss: ok (victim {eng.stats.dead_shards})")

# -- sustained stall escalates to a confirmed death -------------------------
ref = oracle("exact", (4, 4))
plan = ft.make_fault_plan("shard-stall", 1.0, seed=0, max_failures=4)
eng = build("exact", 4, (4, 4), "host-mirror", plan=plan)
got = serve(eng, PROMPTS, 8)
assert eng.stats.shard_stalls >= 2, eng.stats
assert eng.stats.shard_losses >= 1, "sustained stall never escalated"
assert got == ref, (ref, got)
drained(eng.layout)
print(f"stall escalation: ok ({eng.stats.shard_stalls} stalls -> "
      f"{eng.stats.shard_losses} death)")

# -- spilled requests under pressure: pins damaged -> recompute, not abort --
spill_kw = dict(context_len=64, prompt_capacity=32, num_blocks=5,
                host_blocks=24)
spill_prompts = PROMPTS + [list(range(4, 26))]
ref_eng = build("exact", 1, (4, 4), **spill_kw)
ref = serve(ref_eng, spill_prompts, 10)
assert ref_eng.stats.spills > 0, ref_eng.stats
for redundancy in ("none", "host-mirror"):
  plan = ft.FaultPlan(seed=0)
  eng = build("exact", 4, (4, 4), redundancy, plan=plan, **spill_kw)

  def arm():
    plan.shard_loss_rate = 1.0
    plan.max_failures = plan.injected + 1

  got = serve(eng, spill_prompts, 10, warm=4, arm=arm)
  assert got == ref, (redundancy, ref, got)
  drained(eng.layout)
  assert eng.stats.shard_losses >= 1
  print(f"spill+loss[{redundancy}]: ok ({eng.stats.spills} spills, "
        f"{eng.stats.shard_recovered_requests} recovered)")

print("ALL OK")
'''


def test_shard_fault_matrix_forced_host_devices():
  """The PR 10 acceptance matrix in one subprocess (device count is fixed
  at first jax import, so the in-process suite cannot host it)."""
  root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
  env = dict(os.environ,
             XLA_FLAGS="--xla_force_host_platform_device_count=8",
             JAX_PLATFORMS="cpu")
  env["PYTHONPATH"] = os.pathsep.join(
      [os.path.join(root, "src")]
      + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
  proc = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                        capture_output=True, text=True, timeout=1500)
  assert proc.returncode == 0, (
      f"shard fault driver failed\nstdout:\n{proc.stdout[-4000:]}\n"
      f"stderr:\n{proc.stderr[-4000:]}")
  assert "ALL OK" in proc.stdout
