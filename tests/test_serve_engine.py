"""Continuous-batching ServeEngine: mixed prompt lengths in one batch,
staggered admission/finish, and equivalence with the single-request path."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.engine import ServeEngine


def _cfg(policy="exact", dtype="float32"):
  return dataclasses.replace(get_arch("tinyllama-1.1b", reduced=True),
                             cache_policy=policy, dtype_str=dtype)


def test_engine_mixed_lengths_match_single_request_path():
  """Requests with different prompt lengths share one batch, finish at
  different steps, and produce exactly the tokens of their solo runs."""
  cfg = _cfg("exact")
  eng = ServeEngine(cfg, context_len=96, max_batch=2, prompt_capacity=64)
  r_long = eng.submit(list(range(1, 41)), max_new_tokens=6)   # 40-token prompt
  r_short = eng.submit(list(range(3, 21)), max_new_tokens=3)  # 18-token prompt
  done = eng.run_to_completion()

  assert [r.rid for r in done] == [r_short.rid, r_long.rid]
  assert r_short.finished_step < r_long.finished_step
  assert len(r_long.tokens) == 6 and len(r_short.tokens) == 3

  for req in (r_long, r_short):
    solo = ServeEngine(cfg, context_len=96, max_batch=1, prompt_capacity=64,
                       params=eng.params)
    h = solo.submit(list(req.prompt), max_new_tokens=req.max_new_tokens)
    solo.run_to_completion()
    assert h.tokens == req.tokens, req.rid


def test_engine_admits_from_queue_when_slot_frees():
  """More requests than slots: later requests wait, then reuse freed slots."""
  cfg = _cfg("exact")
  eng = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32)
  reqs = [eng.submit([7 + i] * (10 + 3 * i), max_new_tokens=2)
          for i in range(4)]
  done = eng.run_to_completion()
  assert len(done) == 4 and all(r.done for r in reqs)
  # the overflow requests could only be admitted after the first two finished
  assert min(r.admitted_step for r in reqs[2:]) >= min(
      r.finished_step for r in reqs[:2])
  slots_used = {r.slot for r in reqs}
  assert slots_used <= {0, 1}


def test_engine_runs_with_pq_policy():
  cfg = _cfg("pq", dtype="bfloat16")
  eng = ServeEngine(cfg, context_len=96, max_batch=2, prompt_capacity=64)
  a = eng.submit(list(range(2, 60)), max_new_tokens=4)
  b = eng.submit(list(range(4, 49)), max_new_tokens=4)
  done = eng.run_to_completion()
  assert len(done) == 2
  for r in (a, b):
    assert len(r.tokens) == 4
    assert all(0 <= t < cfg.vocab_size for t in r.tokens)


def test_engine_rejects_recurrent_families_and_bad_prompts():
  with pytest.raises(ValueError):
    ServeEngine(get_arch("rwkv6-3b", reduced=True), context_len=64)
  eng = ServeEngine(_cfg("exact"), context_len=64, max_batch=1,
                    prompt_capacity=16)
  with pytest.raises(ValueError):
    eng.submit(list(range(30)))          # prompt > prompt_capacity
  with pytest.raises(ValueError):
    eng.submit([1, 2, 3], max_new_tokens=200)   # exceeds context
