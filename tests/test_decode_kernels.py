"""Decode-kernel dispatch: oracle parity for the Pallas decode kernels
(interpret mode) and serve-level greedy token identity across dispatches.

Three layers of guarantee, matching the PR 5 numerics contract:

  1. kernel vs pure-JAX oracle — the dense PQ body kernel against
     `pq_decode_attention`'s math, the paged PQ kernel against the dense one
     on a gathered view, and paged flash decode against
     `exact_decode_attention`, across randomized (g, m, K, dsub, block,
     ragged lengths) — fp32-accumulation tolerance;
  2. policy-level — `append_and_attend` under xla vs pallas-interpret
     dispatch agrees on identical state;
  3. serve-level — greedy tokens bit-identical across
     `--decode-kernel {xla, pallas-interpret}` for
     `{paged, tiered} x {exact, pq}` (the acceptance matrix), including a
     forced spill/fetch on the tiered runs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
  from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover — CI image has no hypothesis
  from hypothesis_compat import given, settings, strategies as st

from repro.configs import get_arch
from repro.core import cache_api, cache_registry, decode_dispatch
from repro.core import kv_cache as kvc
from repro.core import pq as pqlib
from repro.core import pq_attention as pqa
from repro.kernels import ops, ref
from repro.launch.engine import ServeEngine


# ---------------------------------------------------------------------------
# dispatch registry
# ---------------------------------------------------------------------------

def test_registry_names_and_resolution():
  assert decode_dispatch.names() == ("auto", "pallas", "pallas-interpret",
                                     "xla")
  assert decode_dispatch.resolve("xla").use_pallas is False
  d = decode_dispatch.resolve("pallas-interpret")
  assert d.use_pallas and d.interpret and d.key == "pallas-interpret"
  with pytest.raises(ValueError):
    decode_dispatch.validate("mosaic")
  auto = decode_dispatch.resolve("auto")
  if jax.default_backend() != "tpu":
    assert auto.use_pallas is False      # auto degrades to xla off-TPU
    with pytest.raises(ValueError):      # compiled Mosaic needs a TPU
      decode_dispatch.resolve("pallas")


def test_cache_spec_validates_decode_kernel():
  with pytest.raises(ValueError):
    cache_api.CacheSpec(capacity=32, head_dim=16, window=16,
                        decode_kernel="nope")


# ---------------------------------------------------------------------------
# kernel vs oracle (hypothesis sweep)
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(
    g=st.integers(1, 4),
    m=st.sampled_from([2, 4, 8]),
    k_cent=st.sampled_from([8, 16, 64]),
    dsub=st.sampled_from([2, 4, 8]),
    blk=st.sampled_from([8, 16, 32]),
    nb=st.integers(2, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_paged_pq_kernel_matches_dense_kernel_and_oracle(
    g, m, k_cent, dsub, blk, nb, seed):
  """Paged PQ kernel == dense PQ kernel == pure-JAX oracle on the gathered
  view, under random block tables, trash entries, and ragged lengths."""
  rng = np.random.default_rng(seed)
  b, h, layers = 2, 2, 2
  d = m * dsub
  n = blk * nb
  pool_blocks = b * nb + 3
  trash = pool_blocks
  layer = int(rng.integers(0, layers))

  q = jnp.asarray(rng.normal(size=(b, h, g, d)), jnp.float32)
  kcb = jnp.asarray(rng.normal(size=(b, h, m, k_cent, dsub)), jnp.float32)
  vcb = jnp.asarray(rng.normal(size=(b, h, m, k_cent, dsub)), jnp.float32)
  idt = np.uint8 if k_cent <= 256 else np.int16
  kip = jnp.asarray(rng.integers(0, k_cent,
                                 size=(pool_blocks + 1, layers, h, blk, m)),
                    idt)
  vip = jnp.asarray(rng.integers(0, k_cent,
                                 size=(pool_blocks + 1, layers, h, blk, m)),
                    idt)
  tables = rng.permutation(pool_blocks)[:b * nb].reshape(b, nb).astype(
      np.int32)
  lengths = rng.integers(0, n + 1, size=(b,)).astype(np.int32)
  for i in range(b):   # entries past the extent point at trash (unallocated)
    for j in range(-(-int(lengths[i]) // blk), nb):
      tables[i, j] = trash
  scale = 1 / np.sqrt(d)

  p_out, p_m, p_l = ops.pq_decode_attention_paged(
      q, kcb, vcb, kip, vip, jnp.asarray(tables), jnp.asarray(layer),
      jnp.asarray(lengths), scale)

  # dense view gathered from the pool (trash rows land past `lengths`)
  kix = np.stack([np.concatenate(
      [np.asarray(kip[tables[i, j], layer], np.int32) for j in range(nb)],
      axis=1) for i in range(b)])                       # (B, H, N, m)
  vix = np.stack([np.concatenate(
      [np.asarray(vip[tables[i, j], layer], np.int32) for j in range(nb)],
      axis=1) for i in range(b)])
  d_out, d_m, d_l = ops.pq_decode_attention(
      q, kcb, vcb, jnp.asarray(kix), jnp.asarray(vix),
      jnp.asarray(np.broadcast_to(lengths[:, None], (b, h)).copy()), scale,
      blk=blk)
  np.testing.assert_allclose(np.asarray(p_out), np.asarray(d_out),
                             rtol=1e-4, atol=1e-4)
  np.testing.assert_allclose(np.asarray(p_l), np.asarray(d_l),
                             rtol=1e-4, atol=1e-4)

  r_out, r_stats = ref.pq_decode_attention_ref(
      np.asarray(q).reshape(b * h, g, d),
      np.asarray(kcb).reshape(b * h, m, k_cent, dsub),
      np.asarray(vcb).reshape(b * h, m, k_cent, dsub),
      jnp.asarray(kix.reshape(b * h, n, m)),
      jnp.asarray(vix.reshape(b * h, n, m)),
      jnp.asarray(np.repeat(lengths, h)), scale)
  np.testing.assert_allclose(np.asarray(p_out).reshape(b * h, g, d),
                             np.asarray(r_out), rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(
    g=st.integers(1, 4),
    d=st.sampled_from([8, 16, 32]),
    blk=st.sampled_from([8, 16]),
    nb=st.integers(2, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_paged_flash_decode_matches_exact_oracle(g, d, blk, nb, seed):
  """Paged flash decode == exact_decode_attention on the gathered view."""
  rng = np.random.default_rng(seed)
  b, h, layers = 2, 2, 2
  n = blk * nb
  pool_blocks = b * nb + 2
  trash = pool_blocks
  layer = int(rng.integers(0, layers))
  q = jnp.asarray(rng.normal(size=(b, h, g, d)), jnp.float32)
  k_pool = jnp.asarray(
      rng.normal(size=(pool_blocks + 1, layers, h, blk, d)), jnp.float32)
  v_pool = jnp.asarray(
      rng.normal(size=(pool_blocks + 1, layers, h, blk, d)), jnp.float32)
  tables = rng.permutation(pool_blocks)[:b * nb].reshape(b, nb).astype(
      np.int32)
  lengths = rng.integers(1, n + 1, size=(b,)).astype(np.int32)
  for i in range(b):
    for j in range(-(-int(lengths[i]) // blk), nb):
      tables[i, j] = trash
  scale = 1 / np.sqrt(d)
  out = ops.paged_flash_decode(
      q, k_pool, v_pool, jnp.asarray(tables), jnp.asarray(layer),
      jnp.asarray(lengths), scale)
  for i in range(b):
    for hh in range(h):
      kd = np.concatenate([np.asarray(k_pool[tables[i, j], layer, hh])
                           for j in range(nb)])
      vd = np.concatenate([np.asarray(v_pool[tables[i, j], layer, hh])
                           for j in range(nb)])
      mask = np.arange(n) < lengths[i]
      want = pqa.exact_decode_attention(
          q[i, hh], jnp.asarray(kd), jnp.asarray(vd), jnp.asarray(mask),
          scale)
      np.testing.assert_allclose(np.asarray(out[i, hh]), np.asarray(want),
                                 rtol=1e-4, atol=1e-4, err_msg=f"bh {i},{hh}")


def test_dense_flash_decode_matches_exact_oracle():
  rng = np.random.default_rng(11)
  b, h, g, n, d = 2, 2, 3, 48, 16
  q = jnp.asarray(rng.normal(size=(b, h, g, d)), jnp.float32)
  k = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  v = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  lengths = jnp.asarray([48, 29], jnp.int32)
  out = ops.flash_decode(q, k, v, lengths, 0.25, blk=16)
  for i in range(b):
    for hh in range(h):
      mask = np.arange(n) < int(lengths[i])
      want = pqa.exact_decode_attention(q[i, hh], k[i, hh], v[i, hh],
                                        jnp.asarray(mask), 0.25)
      np.testing.assert_allclose(np.asarray(out[i, hh]), np.asarray(want),
                                 rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# policy-level parity (dense storage)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ("exact", "pq"))
def test_policy_append_and_attend_kernel_parity(name):
  rng = np.random.default_rng(5)
  b, h, hq, n, cap, d = 2, 2, 4, 24, 48, 16
  k = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  v = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
  kn = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
  vn = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
  w = jnp.ones((b, h, n), jnp.float32)
  pq_geo = kvc.PQCacheConfig(sink=4, recent=8, body_capacity=64, n_windows=1,
                             pq=pqlib.PQConfig(m=4, k=16))
  spec_x = cache_api.CacheSpec(capacity=cap, head_dim=d, sink=4, recent=8,
                               window=16, decode_kernel="xla",
                               pq=pq_geo if name == "pq" else None)
  spec_p = dataclasses.replace(spec_x, decode_kernel="pallas-interpret")
  px = cache_registry.make(name, spec_x)
  pp = cache_registry.make(name, spec_p)
  assert not px.use_kernel and pp.use_kernel
  assert pp.block_native and not px.block_native
  lengths = jnp.asarray([n, n - 5], jnp.int32)
  stt = px.prefill(k, v, w if px.needs_weights else None, lengths)
  ox, sx = px.append_and_attend(stt, q, kn, vn, lengths)
  op, sp = pp.append_and_attend(stt, q, kn, vn, lengths)
  np.testing.assert_allclose(np.asarray(ox), np.asarray(op),
                             rtol=1e-4, atol=1e-4)
  for a, bb in zip(jax.tree_util.tree_leaves(sx),
                   jax.tree_util.tree_leaves(sp)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(bb, np.float32),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# serve-level greedy token identity: {xla, pallas-interpret} x
# {paged, tiered} x {exact, pq}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ("paged", "tiered"))
@pytest.mark.parametrize("policy", ("exact", "pq"))
def test_serve_tokens_identical_across_decode_kernels(layout, policy):
  base = dataclasses.replace(
      get_arch("tinyllama-1.1b", reduced=True), cache_policy=policy,
      cache_layout=layout, scheduler=layout)
  if policy == "exact":
    kwargs = dict(context_len=64, max_batch=2, prompt_capacity=32)
    trace = [(list(range(1, 21)), 14), (list(range(3, 25)), 14),
             ([7] * 9, 6)]
    if layout == "tiered":
      # pool sized below the trace's KV growth (test_tiers recipe): the run
      # must spill and fetch, proving the block-native program coexists with
      # swap preemption
      kwargs.update(num_blocks=5, host_blocks=16)
  else:
    # pq pages only body tokens (length beyond sink+recent): longer prompts
    # so the code rows actually occupy — and overflow — the device pool
    kwargs = dict(context_len=96, max_batch=2, prompt_capacity=64)
    trace = [(list(range(2, 60)), 24), (list(range(4, 49)), 24)]
    if layout == "tiered":
      kwargs.update(num_blocks=7, host_blocks=32)
  outs, params, engines = {}, None, {}
  for kern in ("xla", "pallas-interpret"):
    cfg = dataclasses.replace(base, decode_kernel=kern)
    eng = ServeEngine(cfg, params=params, **kwargs)
    params = eng.params
    handles = [eng.submit(p, max_new_tokens=mx) for p, mx in trace]
    eng.run_to_completion()
    outs[kern] = [h.tokens for h in handles]
    engines[kern] = eng
  assert outs["xla"] == outs["pallas-interpret"], (layout, policy)
  native = engines["pallas-interpret"].layout
  assert native.block_native
  assert native.decode_traffic["dense_materialized_bytes_per_step"] == 0
  assert native.decode_traffic["block_read_bytes_per_step"] > 0
  assert not engines["xla"].layout.block_native
  if layout == "tiered":
    for eng in engines.values():
      assert eng.stats.spills >= 1, "trace never hit pool pressure"

# CLI flag threading (--decode-kernel -> ModelConfig -> layout) is covered
# alongside the other serve flags in tests/test_serve_cli.py.
