"""Serve CLI arg plumbing: every --cache-policy/--cache-layout/--scheduler/
--kv-block-size/--num-blocks/--host-blocks/--spill-codec flag must reach the
constructed engine/ModelConfig (this path had no direct tests and rots
silently), plus the --stats-json machine-readable dump."""
import json

import pytest

from repro.launch import serve


def _engine_for(argv):
  args = serve.make_parser().parse_args(argv)
  return args, serve.build_engine(args)


BASE = ["--arch", "tinyllama-1.1b", "--reduced", "--engine",
        "--batch", "2", "--prompt-len", "16", "--gen", "8"]


@pytest.mark.parametrize("argv,layout,sched,policy", [
    (BASE + ["--cache-policy", "exact"], "contiguous", "fifo", "exact"),
    (BASE + ["--cache-policy", "pq", "--scheduler", "sjf"],
     "contiguous", "sjf", "pq"),
    (BASE + ["--cache-policy", "exact", "--cache-layout", "paged",
             "--scheduler", "paged", "--kv-block-size", "8",
             "--num-blocks", "12"], "paged", "paged", "exact"),
    (BASE + ["--cache-policy", "exact", "--cache-layout", "tiered",
             "--scheduler", "tiered", "--kv-block-size", "8",
             "--num-blocks", "9", "--host-blocks", "20",
             "--spill-codec", "int8"], "tiered", "tiered", "exact"),
])
def test_flags_reach_engine_and_config(argv, layout, sched, policy):
  args, eng = _engine_for(argv)
  assert eng.layout.name == layout
  assert eng.scheduler.name == sched
  assert eng.cfg.cache_policy == policy
  assert eng.cfg.cache_layout == layout
  assert eng.cfg.scheduler == sched
  assert eng.max_batch == args.batch
  assert eng.prompt_capacity == args.prompt_len
  assert eng.context_len == args.prompt_len + args.gen
  if layout in ("paged", "tiered"):
    assert eng.layout.block == args.kv_block_size
    assert eng.cfg.kv_block_size == args.kv_block_size
    assert eng.layout.num_blocks == args.num_blocks
  if layout == "tiered":
    assert eng.layout.host_blocks == args.host_blocks
    assert eng.cfg.host_blocks == args.host_blocks
    assert eng.cfg.spill_codec == args.spill_codec
    # the codec choice must reach the policy's per-buffer spill surface
    codecs = eng.model.cache_policy.spill_codecs()
    assert codecs.k == args.spill_codec


@pytest.mark.parametrize("argv,want_key,native", [
    # default `auto` resolves against the backend: xla on the CPU CI host
    (BASE + ["--cache-policy", "pq"], "xla", False),
    (BASE + ["--cache-policy", "pq", "--decode-kernel", "xla"], "xla",
     False),
    (BASE + ["--cache-policy", "exact", "--cache-layout", "paged",
             "--scheduler", "paged", "--kv-block-size", "8",
             "--decode-kernel", "pallas-interpret"], "pallas-interpret",
     True),
])
def test_decode_kernel_flag_reaches_config_and_layout(argv, want_key, native):
  args, eng = _engine_for(argv)
  assert eng.cfg.decode_kernel == args.decode_kernel
  assert eng.layout.dispatch.key == want_key
  assert getattr(eng.layout, "block_native", False) == native


def test_kv_resident_codec_flag_reaches_policy():
  """--kv-resident-codec q4 must swap the exact policy's resident store to
  the packed variant (same 'exact' registry key, storage-format switch)."""
  from repro.core import cache_api
  args, eng = _engine_for(BASE + ["--cache-policy", "exact",
                                  "--cache-layout", "paged",
                                  "--scheduler", "paged",
                                  "--kv-block-size", "8",
                                  "--kv-resident-codec", "q4"])
  assert eng.cfg.kv_resident_codec == args.kv_resident_codec == "q4"
  policy = eng.model.cache_policy
  assert isinstance(policy, cache_api.PackedExactPolicy)
  assert policy.bits == 4


def test_unknown_codec_flags_fail_at_argparse_with_choices():
  # registry-driven choices: the parser itself rejects unknown keys and its
  # usage error lists the valid set (SystemExit, not a deep ValueError)
  for flag in ("--spill-codec", "--kv-resident-codec"):
    with pytest.raises(SystemExit):
      serve.make_parser().parse_args(BASE + [flag, "zstd"])
  assert set(serve.make_parser().get_default("spill_codec").split()) == {"raw"}


def test_prefix_cache_flags_reach_engine_and_layout():
  args, eng = _engine_for(BASE + ["--cache-policy", "exact",
                                  "--cache-layout", "paged",
                                  "--scheduler", "prefix",
                                  "--kv-block-size", "8",
                                  "--num-blocks", "12",
                                  "--prefix-cache",
                                  "--prefix-cache-blocks", "5"])
  assert eng.prefix_cache and eng.cfg.prefix_cache
  assert eng.cfg.prefix_cache_blocks == 5
  assert eng.layout.prefix_enabled
  assert eng.layout.prefix_index.budget_blocks == 5
  assert eng.scheduler.name == "prefix"


def test_prefix_cache_budget_defaults_to_half_pool():
  _, eng = _engine_for(BASE + ["--cache-policy", "exact",
                               "--cache-layout", "paged",
                               "--scheduler", "prefix",
                               "--kv-block-size", "8",
                               "--num-blocks", "12", "--prefix-cache"])
  assert eng.layout.prefix_index.budget_blocks == 6


def test_prefix_cache_on_contiguous_is_rejected():
  with pytest.raises(ValueError, match="pooled layout"):
    _engine_for(BASE + ["--cache-policy", "exact", "--prefix-cache"])


def test_stats_json_includes_prefix_counters(tmp_path):
  _, eng = _engine_for(BASE + ["--cache-policy", "exact",
                               "--cache-layout", "paged",
                               "--scheduler", "prefix",
                               "--kv-block-size", "8", "--prefix-cache"])
  prompt = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10]
  eng.submit(prompt, max_new_tokens=3)
  eng.submit(prompt, max_new_tokens=3)      # exact repeat -> full hit
  eng.run_to_completion()
  path = tmp_path / "stats.json"
  serve.dump_stats_json(eng, str(path))
  got = json.loads(path.read_text())
  for key in ("prefix_hits", "prefix_full_hits", "prefix_hit_tokens",
              "prefill_tokens", "forked_blocks", "dedup_bytes",
              "prefix_hit_rate"):
    assert key in got, key
  assert got["prefix_hits"] >= 1 and got["prefix_full_hits"] >= 1
  assert got["prefix_cache"]["hits"] == got["prefix_hits"]
  assert got["prefix_cache"]["budget_blocks"] > 0
  # layout.bytes() is self-describing about sharing: blocks counted once
  for key in ("shared_blocks", "dedup_bytes", "prefix_index_blocks",
              "forked_blocks", "peak_mapped_blocks"):
    assert key in got["layout_bytes"], key


def test_tiered_host_pool_defaults_to_4x_device():
  _, eng = _engine_for(BASE + ["--cache-policy", "exact",
                               "--cache-layout", "tiered",
                               "--scheduler", "tiered",
                               "--kv-block-size", "8",
                               "--num-blocks", "6"])
  assert eng.layout.host_blocks == 24


def test_tiered_explicit_zero_host_blocks_is_honored():
  """--host-blocks 0 means *no* host tier (recompute fallback only), not
  'use the default' — 0 must survive the CLI -> engine -> layout plumbing."""
  _, eng = _engine_for(BASE + ["--cache-policy", "exact",
                               "--cache-layout", "tiered",
                               "--scheduler", "tiered",
                               "--kv-block-size", "8",
                               "--num-blocks", "6",
                               "--host-blocks", "0"])
  assert eng.layout.host_blocks == 0


def test_stats_json_dump_is_machine_readable(tmp_path):
  _, eng = _engine_for(BASE + ["--cache-policy", "exact",
                               "--cache-layout", "tiered",
                               "--scheduler", "tiered",
                               "--kv-block-size", "8"])
  eng.submit([1, 2, 3, 4], max_new_tokens=3)
  eng.run_to_completion()
  path = tmp_path / "stats.json"
  serve.dump_stats_json(eng, str(path))
  got = json.loads(path.read_text())
  # the keys CI and benches assert on
  for key in ("occupancy", "admits", "preempts", "finished", "spills",
              "fetches", "spill_bytes", "modeled_pcie_s"):
    assert key in got, key
  assert got["layout"] == "tiered" and got["scheduler"] == "tiered"
  assert got["layout_bytes"]["kind"] == "tiered"
  assert got["transfer"]["total_bytes"] == 0      # nothing spilled here
  assert got["finished"] == 1
