"""Unified CachePolicy API: registry, protocol conformance, equivalence with
the kernel-level free functions, and per-request `lengths` semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache_api, cache_registry, kv_cache as kvc, pq
from repro.core import pq_attention as pqa

ALL_POLICIES = ("exact", "pq", "pqcache", "skvq", "snapkv", "streamingllm")


def _pq_geo(d, sink=4, recent=8, body=32, m=4, k=16):
  return kvc.PQCacheConfig(sink=sink, recent=recent, body_capacity=body,
                           n_windows=1, pq=pq.PQConfig(m=m, k=k))


def _spec(cap, d, **kw):
  kw.setdefault("sink", 4)
  kw.setdefault("recent", 8)
  kw.setdefault("dtype", jnp.float32)
  # the spec-level default window (512) exceeds these smoke capacities, which
  # CacheSpec now rejects at construction
  kw.setdefault("window", cap)
  return cache_api.CacheSpec(capacity=cap, head_dim=d, **kw)


def _inputs(rng, b, h, hq, n, d):
  k = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  v = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
  kn = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
  vn = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
  w = jnp.ones((b, h, n))
  return k, v, q, kn, vn, w


def test_registry_exposes_all_builtin_policies():
  assert cache_registry.names() == tuple(sorted(ALL_POLICIES))
  with pytest.raises(KeyError):
    cache_registry.get("nope")


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_policy_protocol_end_to_end(name):
  """init/prefill/append_and_attend/bytes on every registered policy,
  with scalar and mixed (B,) lengths."""
  rng = np.random.default_rng(0)
  b, h, hq, n, cap, d = 2, 2, 4, 24, 48, 16
  k, v, q, kn, vn, w = _inputs(rng, b, h, hq, n, d)
  spec = _spec(cap, d, window=16, pq=_pq_geo(d))
  policy = cache_registry.make(name, spec)

  st0 = policy.init(b, h, d)
  st = policy.prefill(k, v, w if policy.needs_weights else None)
  # init and prefill states must be structurally interchangeable (the serve
  # engine writes prefilled slots into an init'd batched tree)
  assert (jax.tree_util.tree_structure(st0)
          == jax.tree_util.tree_structure(st))
  assert all(a.shape == b_.shape for a, b_ in
             zip(jax.tree_util.tree_leaves(st0),
                 jax.tree_util.tree_leaves(st)))

  out, st2 = policy.append_and_attend(st, q, kn, vn, jnp.int32(n))
  assert out.shape == (b, hq, d)
  assert np.isfinite(np.asarray(out)).all()

  out_m, _ = policy.append_and_attend(
      st, q, kn, vn, jnp.asarray([n, n - 5], jnp.int32))
  assert np.isfinite(np.asarray(out_m)).all()
  np.testing.assert_allclose(np.asarray(out_m[0]), np.asarray(out[0]),
                             rtol=1e-5, atol=1e-5)

  by = policy.bytes(b, h, d)
  for key in ("per_head_bytes", "total_bytes", "reduction_ratio"):
    assert key in by, (name, by)


def test_exact_policy_matches_free_functions():
  rng = np.random.default_rng(1)
  b, h, hq, n, cap, d = 2, 2, 4, 20, 40, 16
  k, v, q, kn, vn, _ = _inputs(rng, b, h, hq, n, d)
  policy = cache_registry.make("exact", _spec(cap, d))

  st = policy.prefill(k, v)
  ref = kvc.exact_cache_prefill(k, v, cap)
  np.testing.assert_array_equal(np.asarray(st.k), np.asarray(ref.k))

  out, _ = policy.append_and_attend(st, q, kn, vn, jnp.int32(n))
  want, _ = kvc.exact_cache_append_and_attend(
      ref, q, kn, vn, jnp.int32(n), d ** -0.5)
  np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                             rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ("exact", "pq", "streamingllm", "snapkv"))
def test_mixed_lengths_rows_match_single_requests(name):
  """A batch with per-request lengths must reproduce each row's own b=1 run —
  the invariant continuous batching rests on."""
  rng = np.random.default_rng(2)
  b, h, hq, n, cap, d = 3, 1, 2, 20, 40, 16
  k, v, q, kn, vn, w = _inputs(rng, b, h, hq, n, d)
  lengths = jnp.asarray([20, 14, 17], jnp.int32)
  spec = _spec(cap, d, window=12, pq=_pq_geo(d))
  policy = cache_registry.make(name, spec)

  wts = w if policy.needs_weights else None
  st = policy.prefill(k, v, wts, lengths)
  out, _ = policy.append_and_attend(st, q, kn, vn, lengths)

  for i in range(b):
    st1 = policy.prefill(k[i:i + 1], v[i:i + 1],
                         None if wts is None else wts[i:i + 1],
                         lengths[i:i + 1])
    out1, _ = policy.append_and_attend(
        st1, q[i:i + 1], kn[i:i + 1], vn[i:i + 1], lengths[i])
    np.testing.assert_allclose(np.asarray(out[i]), np.asarray(out1[0]),
                               rtol=1e-5, atol=1e-5,
                               err_msg=f"{name} row {i}")


def test_streamingllm_ignores_evicted_tokens():
  """Tokens outside sink+window must not influence the output (eviction)."""
  rng = np.random.default_rng(3)
  b, h, hq, n, cap, d = 1, 1, 2, 24, 32, 16
  k, v, q, kn, vn, _ = _inputs(rng, b, h, hq, n, d)
  policy = cache_registry.make("streamingllm", _spec(cap, d, window=8))

  out_a, _ = policy.append_and_attend(policy.prefill(k, v), q, kn, vn,
                                      jnp.int32(n))
  # poison a mid-context token (outside sink=4, outside last-8 window)
  k_p = k.at[:, :, 10].set(99.0)
  v_p = v.at[:, :, 10].set(-99.0)
  out_b, _ = policy.append_and_attend(policy.prefill(k_p, v_p), q, kn, vn,
                                      jnp.int32(n))
  np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b),
                             rtol=1e-6, atol=1e-6)


def test_model_config_builds_policy():
  import dataclasses
  from repro.configs import get_arch
  cfg = get_arch("tinyllama-1.1b", reduced=True)
  assert cfg.resolved_cache_policy() == "pq"
  assert type(cfg.make_cache_policy(128)).name == "pq"
  legacy = dataclasses.replace(cfg, pq_enabled=False)
  assert legacy.resolved_cache_policy() == "exact"
  swept = dataclasses.replace(cfg, cache_policy="streamingllm")
  assert type(swept.make_cache_policy(128)).name == "streamingllm"
  rwkv = get_arch("rwkv6-3b", reduced=True)
  assert rwkv.make_cache_policy(128) is None


def test_snapkv_keeps_generated_tokens():
  """Appended (generated) tokens get +inf importance so aging out of the
  recent window never evicts them in favor of prompt tokens (real SnapKV
  compresses only the prompt)."""
  rng = np.random.default_rng(4)
  b, h, hq, n, cap, d = 1, 1, 2, 20, 64, 16
  k, v, q, kn, vn, w = _inputs(rng, b, h, hq, n, d)
  policy = cache_registry.make("snapkv", _spec(cap, d))
  st = policy.prefill(k, v, w)
  out, st2 = policy.append_and_attend(st, q, kn, vn, jnp.int32(n))
  assert np.isposinf(np.asarray(st2.w)[0, 0, n])
  # prompt weights untouched, positions beyond the appended token still zero
  np.testing.assert_array_equal(np.asarray(st2.w)[0, 0, :n],
                                np.asarray(st.w)[0, 0, :n])
  assert (np.asarray(st2.w)[0, 0, n + 1:] == 0).all()
