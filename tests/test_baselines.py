"""Baseline KV-compression methods (paper §IV comparison set)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, pq, pq_attention as pqa


def test_uniform_quant_roundtrip_error_drops_with_bits():
  rng = np.random.default_rng(0)
  x = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
  perm = baselines.channel_reorder_by_range(x)
  errs = []
  for bits in (2, 4, 8):
    uq = baselines.uniform_quantize(x, bits, group=8, perm=perm)
    xh = baselines.uniform_dequantize(uq, group=8)
    errs.append(float(jnp.mean((x - xh) ** 2)))
  assert errs[0] > errs[1] > errs[2]
  assert errs[2] < 1e-3


def test_skvq_attention_close_at_8bit():
  rng = np.random.default_rng(1)
  n, d, g = 64, 16, 2
  k = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  q = jnp.asarray(rng.normal(size=(g, d)), jnp.float32)
  mask = jnp.ones((n,), bool)
  exact = pqa.exact_decode_attention(q, k, v, mask, 0.25)
  got = baselines.skvq_decode_attention(q, k, v, mask, 0.25, bits=8, group=8)
  np.testing.assert_allclose(np.asarray(got), np.asarray(exact),
                             rtol=0.05, atol=0.05)


def test_snapkv_always_keeps_sinks_and_recents():
  n, sink, recent, length = 64, 4, 8, 50
  weights = jnp.zeros((n,))
  mask = baselines.snapkv_select(weights, keep=5, sink=sink, recent=recent,
                                 length=length)
  assert bool(jnp.all(mask[:sink]))
  assert bool(jnp.all(mask[length - recent:length]))
  assert not bool(jnp.any(mask[length:]))


def test_streaming_llm_window():
  rng = np.random.default_rng(2)
  n, d = 64, 8
  k = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  q = jnp.asarray(rng.normal(size=(1, d)), jnp.float32)
  out = baselines.streaming_llm_decode_attention(
      q, k, v, length=n, scale=0.3, sink=4, window=16)
  assert bool(jnp.all(jnp.isfinite(out)))


def test_pqcache_recovers_exact_when_keep_is_all():
  rng = np.random.default_rng(3)
  n, d, g = 64, 16, 2
  k = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  q = jnp.asarray(rng.normal(size=(g, d)), jnp.float32)
  mask = jnp.ones((n,), bool)
  cfg = pq.PQConfig(m=4, k=16, iters=4)
  out, traffic = baselines.pqcache_decode_attention(
      q, k, v, mask, 0.25, cfg, keep=n)
  exact = pqa.exact_decode_attention(q, k, v, mask, 0.25)
  np.testing.assert_allclose(np.asarray(out), np.asarray(exact),
                             rtol=1e-4, atol=1e-4)
  assert traffic["fetched_bytes"] == n * d * 2 * 2


def test_pqcache_traffic_grows_with_keep():
  rng = np.random.default_rng(4)
  n, d = 64, 16
  k = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  q = jnp.asarray(rng.normal(size=(1, d)), jnp.float32)
  mask = jnp.ones((n,), bool)
  cfg = pq.PQConfig(m=4, k=16)
  _, t8 = baselines.pqcache_decode_attention(q, k, v, mask, 0.25, cfg, keep=8)
  _, t32 = baselines.pqcache_decode_attention(q, k, v, mask, 0.25, cfg, keep=32)
  assert t32["fetched_bytes"] == 4 * t8["fetched_bytes"]
