"""Deterministic fallback for the `hypothesis` property-testing API.

The CI/container image does not ship `hypothesis`; rather than skip the
property tests, this shim runs each `@given` body against `max_examples`
seeded random draws.  It implements exactly the subset the suite uses:
`given`, `settings(max_examples=, deadline=)`, and the strategies
`integers`, `floats`, `sampled_from`.  When the real package is available
the test modules import it instead (see the try/except at their top).
"""
from __future__ import annotations

import functools
import inspect

import numpy as np

_SEED = 0xA9B1  # fixed: failures must reproduce across runs


class _Strategy:
  def __init__(self, draw):
    self._draw = draw

  def example(self, rng):
    return self._draw(rng)


class strategies:  # noqa: N801 — mirrors `hypothesis.strategies` module name
  @staticmethod
  def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

  @staticmethod
  def floats(min_value, max_value):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

  @staticmethod
  def sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def settings(max_examples: int = 10, deadline=None, **_ignored):
  del deadline

  def deco(fn):
    fn._compat_max_examples = max_examples
    return fn
  return deco


def given(**strats):
  def deco(fn):
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
      n = getattr(wrapper, "_compat_max_examples", 10)
      rng = np.random.default_rng(_SEED)
      for _ in range(n):
        drawn = {name: s.example(rng) for name, s in strats.items()}
        fn(*args, **drawn, **kwargs)

    # pytest reads the signature to decide what is a fixture: expose only the
    # params NOT supplied by strategies (and drop __wrapped__, which pytest
    # would unwrap back to the original full signature).
    sig = inspect.signature(fn)
    remaining = [p for name, p in sig.parameters.items() if name not in strats]
    del wrapper.__wrapped__
    wrapper.__signature__ = sig.replace(parameters=remaining)
    return wrapper
  return deco
