"""MoE sorted-dispatch correctness vs a dense per-token oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers, moe


def _dense_oracle(params, x, top_k, n_experts):
  """Per-token: run its top-k experts directly (no capacity drops)."""
  b, s, d = x.shape
  xf = x.reshape(-1, d)
  logits = xf @ params["router"]
  w, ids = moe.route_topk(logits, top_k)
  out = np.zeros((xf.shape[0], d), np.float32)
  for t in range(xf.shape[0]):
    for j in range(top_k):
      e = int(ids[t, j])
      gate = jax.nn.silu(xf[t] @ params["w_gate"][e])
      up = xf[t] @ params["w_up"][e]
      out[t] += float(w[t, j]) * np.asarray((gate * up) @ params["w_down"][e])
  if "shared" in params:
    sg = jax.nn.sigmoid(xf @ params["shared_gate"])
    shared = layers.mlp(params["shared"], x).reshape(-1, d)
    out = out + np.asarray(sg) * np.asarray(shared, np.float32)
  return out.reshape(b, s, d)


@pytest.mark.parametrize("n_experts,top_k,n_shared", [(4, 2, 0), (8, 2, 1)])
def test_moe_matches_dense_oracle(n_experts, top_k, n_shared, key):
  d, f = 16, 32
  params = moe.moe_init(key, d, n_experts, f, n_shared, top_k, jnp.float32)
  x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
  # capacity_factor large enough that nothing drops
  out, aux = moe.moe_ffn(params, x, top_k, n_experts, capacity_factor=8.0)
  want = _dense_oracle(params, x, top_k, n_experts)
  np.testing.assert_allclose(np.asarray(out), want, rtol=2e-3, atol=2e-3)
  assert np.isfinite(float(aux))


def test_moe_capacity_drops_are_bounded(key):
  """With tiny capacity, output stays finite and within convex-ish range."""
  d, f, e, k = 8, 16, 4, 2
  params = moe.moe_init(key, d, e, f, 0, k, jnp.float32)
  x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, d))
  out, _ = moe.moe_ffn(params, x, k, e, capacity_factor=0.25)
  assert bool(jnp.all(jnp.isfinite(out)))


def test_load_balancing_loss_prefers_uniform():
  t, e, k = 256, 8, 2
  uniform = jnp.zeros((t, e))
  skewed = jnp.zeros((t, e)).at[:, 0].set(10.0)
  ids_u = jnp.stack([jnp.arange(t) % e, (jnp.arange(t) + 1) % e], -1)
  ids_s = jnp.zeros((t, k), jnp.int32)
  l_u = float(moe.load_balancing_loss(uniform, ids_u, e, k))
  l_s = float(moe.load_balancing_loss(skewed, ids_s, e, k))
  assert l_u < l_s


def test_router_weights_normalized(key):
  logits = jax.random.normal(key, (64, 16))
  w, ids = moe.route_topk(logits, 4)
  np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, rtol=1e-5)
  assert int(jnp.max(ids)) < 16


def test_moe_is_differentiable(key):
  d, f, e, k = 8, 16, 4, 2
  params = moe.moe_init(key, d, e, f, 1, k, jnp.float32)
  x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, d))
  def loss(p):
    out, aux = moe.moe_ffn(p, x, k, e)
    return jnp.sum(out ** 2) + 0.01 * aux
  g = jax.grad(loss)(params)
  gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree_util.tree_leaves(g))
  assert np.isfinite(gn) and gn > 0
