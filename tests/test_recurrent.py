"""RWKV6 and SSM: chunked full-sequence forward == step-by-step recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import rwkv6, ssm


def test_rwkv_time_mix_forward_equals_steps(key):
  d, h, b, s = 32, 2, 2, 20
  params = rwkv6.time_mix_init(key, d, h, d // h, jnp.float32)
  x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
  st0 = rwkv6.init_state(b, d, h, jnp.float32)

  full, st_full = rwkv6.time_mix(params, x, st0, h, chunk=8)

  st = st0
  outs = []
  for t in range(s):
    o, st = rwkv6.time_mix_step(params, x[:, t], st, h)
    outs.append(o)
  step_out = jnp.stack(outs, axis=1)
  np.testing.assert_allclose(np.asarray(full), np.asarray(step_out),
                             rtol=2e-3, atol=2e-3)
  np.testing.assert_allclose(np.asarray(st_full.s), np.asarray(st.s),
                             rtol=2e-3, atol=2e-3)


def test_rwkv_chunk_size_invariance(key):
  d, h, b, s = 16, 2, 1, 24
  params = rwkv6.time_mix_init(key, d, h, d // h, jnp.float32)
  x = jax.random.normal(jax.random.PRNGKey(2), (b, s, d)) * 0.5
  st0 = rwkv6.init_state(b, d, h, jnp.float32)
  o1, _ = rwkv6.time_mix(params, x, st0, h, chunk=4)
  o2, _ = rwkv6.time_mix(params, x, st0, h, chunk=24)
  np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                             rtol=2e-3, atol=2e-3)


def test_rwkv_decay_in_unit_interval(key):
  d, h = 16, 2
  params = rwkv6.time_mix_init(key, d, h, d // h, jnp.float32)
  x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, d)) * 2
  x_prev = jnp.concatenate([jnp.zeros((1, 1, d)), x[:, :-1]], 1)
  _, _, _, w, _ = rwkv6._time_mix_inputs(params, x, x_prev, h)
  assert float(jnp.min(w)) > 0.0 and float(jnp.max(w)) < 1.0


def test_ssm_forward_equals_steps(key):
  d, di, n, b, s = 16, 32, 4, 2, 20
  params = ssm.ssm_init(key, d, di, n, jnp.float32)
  x = jax.random.normal(jax.random.PRNGKey(4), (b, s, d)) * 0.5
  st0 = ssm.init_state(b, di, n, jnp.float32)

  full, st_full = ssm.ssm_forward(params, x, st0)

  st = st0
  outs = []
  for t in range(s):
    o, st = ssm.ssm_step(params, x[:, t], st)
    outs.append(o)
  step_out = jnp.stack(outs, axis=1)
  np.testing.assert_allclose(np.asarray(full), np.asarray(step_out),
                             rtol=2e-3, atol=2e-3)
  np.testing.assert_allclose(np.asarray(st_full.h), np.asarray(st.h),
                             rtol=2e-3, atol=2e-3)


def test_ssm_state_is_stable(key):
  """exp(dt*A) < 1: state cannot blow up over long sequences."""
  d, di, n = 8, 16, 4
  params = ssm.ssm_init(key, d, di, n, jnp.float32)
  x = jax.random.normal(jax.random.PRNGKey(5), (1, 256, d))
  st0 = ssm.init_state(1, di, n, jnp.float32)
  out, st = ssm.ssm_forward(params, x, st0)
  assert bool(jnp.all(jnp.isfinite(out)))
  assert float(jnp.max(jnp.abs(st.h))) < 1e4


def test_rwkv_gradients_flow(key):
  d, h = 16, 2
  params = rwkv6.time_mix_init(key, d, h, d // h, jnp.float32)
  x = jax.random.normal(jax.random.PRNGKey(6), (1, 16, d))
  def loss(p):
    st0 = rwkv6.init_state(1, d, h, jnp.float32)
    out, _ = rwkv6.time_mix(p, x, st0, h, chunk=8)
    return jnp.sum(out ** 2)
  g = jax.grad(loss)(params)
  total = sum(float(jnp.sum(jnp.abs(l)))
              for l in jax.tree_util.tree_leaves(g))
  assert np.isfinite(total) and total > 0
