"""Paged KV memory: allocator/table invariants, block gather/scatter
round trips, CacheSpec construction-time validation, and allocated-block
(not capacity) byte reporting under paging."""
import dataclasses

try:
  from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback shim
  from hypothesis_compat import given, settings, strategies as st

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import cache_api, cache_layout, cache_registry
from repro.core import kv_cache as kvc
from repro.core import pq as pqlib


# ---------------------------------------------------------------------------
# BlockAllocator / BlockTableManager invariants
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), num_blocks=st.integers(1, 24))
def test_allocator_random_traffic_never_double_allocates_or_leaks(
    seed, num_blocks):
  rng = np.random.default_rng(seed)
  alloc = cache_layout.BlockAllocator(num_blocks)
  held = {}  # owner -> list of ids
  for _ in range(200):
    if rng.random() < 0.5:
      owner = int(rng.integers(0, 4))
      n = int(rng.integers(0, num_blocks + 2))
      ids = alloc.alloc(n, owner=owner)
      if n > num_blocks - sum(len(v) for v in held.values()):
        assert ids is None  # over-ask must fail atomically
      else:
        assert ids is not None and len(ids) == n
        flat = [i for v in held.values() for i in v]
        assert not set(ids) & set(flat), "double allocation"
        held.setdefault(owner, []).extend(ids)
    elif held:
      owner = list(held)[int(rng.integers(0, len(held)))]
      ids = held.pop(owner)
      k = int(rng.integers(0, len(ids) + 1))
      alloc.free(ids[:k], owner=owner)
      if ids[k:]:
        held[owner] = ids[k:]
    alloc.check()
  assert alloc.free_count + alloc.allocated_count == num_blocks


def test_allocator_rejects_double_free_and_wrong_owner():
  alloc = cache_layout.BlockAllocator(4)
  ids = alloc.alloc(2, owner="a")
  with pytest.raises(ValueError):
    alloc.free(ids, owner="b")        # wrong owner
  alloc.free(ids, owner="a")
  with pytest.raises(ValueError):
    alloc.free(ids, owner="a")        # double free


class _FakeCodec:
  """Minimal codec surface for host-side table tests (no model needed)."""

  def __init__(self, sink=0, window=0, capacity=64):
    self._sink, self._window, self._cap = sink, window, capacity

  def token_extent(self, n):
    return min(n, self._cap)

  def pinned_tokens(self):
    return self._sink

  def dead_below(self, n):
    return max(n - self._window, 0) if self._window else 0


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), num_blocks=st.integers(2, 20),
       window=st.sampled_from([0, 24]))
def test_table_manager_random_admit_grow_reclaim_release(
    seed, num_blocks, window):
  """Random admit/grow/reclaim/preempt traffic: tables never map one physical
  block twice, and a full drain returns every block to the free list."""
  rng = np.random.default_rng(seed)
  block, slots, cap = 8, 3, 64
  mgr = cache_layout.BlockTableManager(
      num_blocks, cap // block, slots, block,
      _FakeCodec(sink=4, window=window, capacity=cap))
  lengths = [0] * slots
  for _ in range(150):
    slot = int(rng.integers(0, slots))
    op = rng.random()
    if lengths[slot] == 0 and op < 0.5:
      want = int(rng.integers(1, cap))
      if mgr.admit(slot, want):
        lengths[slot] = want
    elif lengths[slot] > 0:
      if op < 0.5 and lengths[slot] < cap:
        if mgr.ensure(slot, lengths[slot] + 1):
          lengths[slot] += 1
      elif op < 0.75:
        mgr.reclaim(slot, lengths[slot])
      else:
        mgr.release(slot)          # finish or preempt-and-requeue
        lengths[slot] = 0
    mgr.check_invariants()
  for slot in range(slots):
    mgr.release(slot)
  assert mgr.free_count == num_blocks, "blocks leaked after drain"


# ---------------------------------------------------------------------------
# block gather/scatter numerical core
# ---------------------------------------------------------------------------

def test_blockify_gather_scatter_roundtrip(rng):
  h, n, d, block = 2, 48, 4, 8
  nb = n // block
  dense = jnp.asarray(rng.normal(size=(h, n, d)), jnp.float32)
  blocks = kvc.blockify(dense, 1, block)
  assert blocks.shape == (nb, h, block, d)
  np.testing.assert_array_equal(np.asarray(kvc.unblockify(blocks, 1)),
                                np.asarray(dense))

  # scatter into a shuffled pool, gather back through the same table
  pool = jnp.zeros((nb + 1, h, block, d), jnp.float32)  # +1 trash block
  table = jnp.asarray(rng.permutation(nb), jnp.int32)
  pool = kvc.scatter_blocks(pool, table, dense, 1)
  out = kvc.gather_blocks(pool, table, 1)
  np.testing.assert_array_equal(np.asarray(out), np.asarray(dense))


def test_two_tables_in_one_pool_stay_disjoint(rng):
  """Scattering request B never touches request A's blocks (the 'corrupt
  another request's tokens' invariant, at the primitive level)."""
  h, n, d, block = 1, 32, 4, 8
  nb = n // block
  pool = jnp.zeros((2 * nb + 1, h, block, d), jnp.float32)
  a = jnp.asarray(rng.normal(size=(h, n, d)), jnp.float32)
  b = jnp.asarray(rng.normal(size=(h, n, d)), jnp.float32)
  t_a = jnp.asarray([0, 2, 4, 6], jnp.int32)
  t_b = jnp.asarray([7, 5, 3, 1], jnp.int32)
  pool = kvc.scatter_blocks(pool, t_a, a, 1)
  pool = kvc.scatter_blocks(pool, t_b, b, 1)
  np.testing.assert_array_equal(np.asarray(kvc.gather_blocks(pool, t_a, 1)),
                                np.asarray(a))
  np.testing.assert_array_equal(np.asarray(kvc.gather_blocks(pool, t_b, 1)),
                                np.asarray(b))


# ---------------------------------------------------------------------------
# CacheSpec construction-time validation
# ---------------------------------------------------------------------------

def test_cachespec_rejects_bad_geometry():
  ok = dict(capacity=64, head_dim=16, window=64)
  cache_api.CacheSpec(**ok)  # sanity
  with pytest.raises(ValueError, match="divisible by block"):
    cache_api.CacheSpec(capacity=100, head_dim=16, window=64, block=16)
  with pytest.raises(ValueError, match="keep_frac"):
    cache_api.CacheSpec(capacity=64, head_dim=16, window=64, keep_frac=0.0)
  with pytest.raises(ValueError, match="keep_frac"):
    cache_api.CacheSpec(capacity=64, head_dim=16, window=64, keep_frac=-0.5)
  with pytest.raises(ValueError, match="window"):
    cache_api.CacheSpec(capacity=64, head_dim=16, window=65)
  with pytest.raises(ValueError, match="capacity"):
    cache_api.CacheSpec(capacity=0, head_dim=16, window=1)
  with pytest.raises(ValueError, match="body_capacity"):
    cache_api.CacheSpec(
        capacity=96, head_dim=16, window=96, block=16,
        pq=kvc.PQCacheConfig(sink=8, recent=32, body_capacity=56,
                             pq=pqlib.PQConfig(m=4, k=16)))


def test_policy_codec_surface():
  """token_extent / paged_capacity / paged_axes drive layout geometry."""
  spec = cache_api.CacheSpec(capacity=64, head_dim=16, window=32, sink=4,
                             recent=8,
                             pq=kvc.PQCacheConfig(
                                 sink=4, recent=8, body_capacity=64,
                                 pq=pqlib.PQConfig(m=4, k=16)))
  exact = cache_registry.make("exact", spec)
  assert exact.paged_capacity() == 64
  assert exact.token_extent(10) == 10
  assert exact.dead_below(50) == 0
  assert exact.paged_axes() == kvc.ExactLayerCache(k=2, v=2)

  stream = cache_registry.make("streamingllm", spec)
  assert stream.pinned_tokens() == 4
  assert stream.dead_below(50) == 50 - 32

  pq = cache_registry.make("pq", spec)
  assert pq.paged_capacity() == 64
  assert pq.token_extent(10) == 0          # sink+recent live in the rings
  assert pq.token_extent(20) == 8
  axes = pq.paged_axes()
  assert axes.key_indices == 2 and axes.sink_k == cache_api.RESIDENT


# ---------------------------------------------------------------------------
# allocated-block byte reporting (acceptance: not capacity)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ("exact", "pq"))
def test_paged_bytes_report_allocated_blocks_not_capacity(policy):
  from repro.launch.engine import ServeEngine
  dtype = "float32" if policy == "exact" else "bfloat16"
  cfg = dataclasses.replace(get_arch("tinyllama-1.1b", reduced=True),
                            cache_policy=policy, dtype_str=dtype)
  eng = ServeEngine(cfg, context_len=96, max_batch=2, prompt_capacity=64,
                    cache_layout="paged", scheduler="paged")
  eng.submit(list(range(2, 60)), max_new_tokens=4)
  eng.step()                                   # admit + one decode step
  by = eng.layout.bytes(active_slots=eng.active_count)
  assert by["kind"] == "paged"
  assert by["allocated_blocks"] == eng.layout.manager.allocated_count > 0
  # one short request must cost less than the full pool capacity
  assert by["total_bytes"] < by["capacity_bytes"]
  expected = (by["allocated_blocks"] * by["block_bytes"]
              + eng.active_count * by["resident_bytes_per_slot"])
  assert by["total_bytes"] == expected
  eng.run_to_completion()
  assert eng.layout.bytes()["allocated_blocks"] == 0   # all freed on finish
  eng.layout.manager.check_invariants()
