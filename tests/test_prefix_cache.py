"""Prefix-sharing KV cache: radix-index + copy-on-write invariants, the
token-exactness oracle (--prefix-cache on vs off, bit-identical greedy
output) across {paged, tiered} x {exact, pq} including randomized
spill/fetch traffic over shared blocks, and the measured win (prefill
tokens and mapped KV bytes drop on a shared-prefix trace)."""
import dataclasses

try:
  from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback shim
  from hypothesis_compat import given, settings, strategies as st

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import cache_layout, prefix_index, tiers
from repro.launch.engine import ServeEngine


def _cfg(policy="exact", dtype="float32", **kw):
  return dataclasses.replace(get_arch("tinyllama-1.1b", reduced=True),
                             cache_policy=policy, dtype_str=dtype, **kw)


def _drained(eng):
  """Post-drain invariants: after all requests finish, only the index holds
  blocks; after clearing it, every refcount is back to zero."""
  eng.layout.manager.check_invariants()
  if eng.layout.prefix_index is not None:
    eng.layout.prefix_index.check()
    # every still-allocated block is an index hold, nothing else
    alloc = eng.layout.manager.allocator
    for slot in range(eng.max_batch):
      assert alloc.owned(slot) == [], f"slot {slot} leaked holds"
  eng.clear_prefix_cache()
  assert eng.layout.free_blocks == eng.layout.num_blocks
  pool = getattr(eng.layout, "pool", None)
  if pool is not None:
    pool.check()
    assert pool.allocated_count(tiers.DEVICE) == 0
    assert pool.allocated_count(tiers.HOST) == 0


# ---------------------------------------------------------------------------
# PrefixIndex: structure + LRU budget invariants (host-only, no model)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), budget=st.integers(1, 12))
def test_index_random_traffic_invariants(seed, budget):
  """Random publish/match/full/evict traffic: the hold ledger always equals
  the entries, eviction respects the budget, and clear releases every
  hold exactly once."""
  rng = np.random.default_rng(seed)
  idx = prefix_index.PrefixIndex(block=4, budget_blocks=budget)
  next_block = [0]
  ledger = {}                      # block_id -> holds we expect the pool has

  def take(released):
    for bid in released:
      ledger[bid] -= 1
      assert ledger[bid] >= 0, "index released a hold it never took"

  prompts = [list(rng.integers(0, 5, size=int(rng.integers(1, 15))))
             for _ in range(6)]
  for _ in range(120):
    toks = prompts[int(rng.integers(0, len(prompts)))]
    op = rng.random()
    if op < 0.4:
      ids = idx.match(toks, max_tokens=len(toks) - 1)
      assert len(ids) * idx.block <= max(len(toks) - 1, 0)
    elif op < 0.7:
      n = len(toks) // idx.block
      chain = []
      for _ in range(n):
        chain.append(next_block[0])
        next_block[0] += 1
      take(idx.evict_for(n))
      new = idx.extend(toks, chain)
      for bid in new:
        ledger[bid] = ledger.get(bid, 0) + 1
    else:
      pairs = [(j, next_block[0] + j) for j in range(-(-len(toks) // 4))]
      next_block[0] += len(pairs)
      entry = prefix_index.FullEntry(
          tokens=tuple(int(t) for t in toks), pairs=pairs, hwm=len(pairs),
          resident_rows=[], first_token=1,
          tail_j=(len(pairs) - 1 if len(toks) % 4 else None))
      take(idx.evict_for(len(pairs)))
      for bid in idx.put_full(entry):
        ledger[bid] = ledger.get(bid, 0) + 1
    idx.check()
    assert idx.held_blocks <= budget + 16  # bounded overshoot per insert
  take(idx.clear())
  assert all(v == 0 for v in ledger.values())
  idx.check()
  assert idx.held_blocks == 0


def test_index_eviction_prefers_unreferenced_leaves():
  idx = prefix_index.PrefixIndex(block=2, budget_blocks=2)
  idx.extend([1, 2], [10])         # cold
  idx.extend([3, 4], [11])         # hot (touch below)
  idx.match([3, 4, 5])
  # block 10 is in use by a running request; 11 is not -> 11 evicts first
  released = idx.evict_for(1, in_use=lambda bid: bid == 10)
  assert released == [11]
  # with nothing else evictable, the in-use leaf goes next
  released = idx.evict_for(2, in_use=lambda bid: bid == 10)
  assert released == [10]


def test_chain_match_is_longest_prefix_and_block_aligned():
  idx = prefix_index.PrefixIndex(block=4, budget_blocks=16)
  idx.extend(list(range(12)), [100, 101, 102])
  assert idx.match(list(range(12)) + [99]) == [100, 101, 102]
  assert idx.match(list(range(8)) + [7, 7, 7, 7]) == [100, 101]
  assert idx.match([5, 6, 7]) == []
  # max_tokens caps the match so a suffix token always remains to compute
  assert idx.match(list(range(12)), max_tokens=11) == [100, 101]


# ---------------------------------------------------------------------------
# Copy-on-write block tables
# ---------------------------------------------------------------------------

def test_allocator_multiset_holds_and_cow_sharing():
  alloc = cache_layout.BlockAllocator(4)
  ids = alloc.alloc(2, owner=0)
  alloc.ref(ids, owner=1)                       # slot 1 shares both blocks
  alloc.ref([ids[0]], owner=prefix_index.INDEX_OWNER)
  assert alloc.refcount(ids[0]) == 3
  assert set(alloc.owned(0)) == set(alloc.owned(1)) == set(ids)
  alloc.free(ids, owner=0)                      # slot 0 finishes
  assert alloc.allocated_count == 2             # still held by slot 1 + index
  with pytest.raises(ValueError, match="freed by"):
    alloc.free(ids, owner=0)                    # slot 0 has no hold anymore
  alloc.free(ids, owner=1)
  assert alloc.allocated_count == 1             # ids[0] held by the index
  alloc.free([ids[0]], owner=prefix_index.INDEX_OWNER)
  assert alloc.free_count == 4
  alloc.check()


def test_cow_fork_never_aliases_shared_storage():
  """Acceptance: forking a shared block allocates fresh storage with a
  bit-identical payload and leaves the shared block's bytes untouched."""
  import jax.numpy as jnp
  cfg = _cfg()
  eng = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                    cache_layout="paged", scheduler="prefix", num_blocks=12,
                    prefix_cache=True)
  layout = eng.layout
  prompt = list(range(1, 21))                   # 20 tokens: 1 whole block
  a = eng.submit(prompt, max_new_tokens=2)
  eng.run_to_completion()
  assert a.done
  # resubmit the identical prompt -> full hit -> tail block cow-forked
  # (>2 new tokens so the slot is still live when we inspect it below)
  b = eng.submit(prompt, max_new_tokens=4)
  eng.step()
  assert eng.stats.prefix_full_hits == 1
  assert eng.stats.forked_blocks >= 1
  slot = b.slot
  tail_j = 1                                    # 20 tokens, block 16: tail j=1
  entry = layout.prefix_index.get_full(prompt)
  forked = int(layout.manager.tables[slot, tail_j])
  original = dict(entry.pairs)[tail_j]
  assert forked != original, "fork aliases the shared block"
  # payload bit-identical at fork for the prompt rows it carries
  k_pool = np.asarray(layout.storage.k, np.float32)
  assert np.array_equal(k_pool[forked][:, :4], k_pool[original][:, :4])
  eng.run_to_completion()
  _drained(eng)


def test_contiguous_layout_rejects_prefix_cache():
  with pytest.raises(ValueError, match="pooled layout"):
    ServeEngine(_cfg(), context_len=64, max_batch=1, prompt_capacity=16,
                prefix_cache=True)             # contiguous layout by default


# ---------------------------------------------------------------------------
# Token-exactness oracle: --prefix-cache on vs off, bit-identical greedy
# ---------------------------------------------------------------------------

def _shared_trace(vocab, rng=None, users=4, repeats=2):
  sys_prompt = list(range(1, 18))               # one whole block of 16
  trace = [(sys_prompt + [50 + 3 * u] * 5, 6) for u in range(users)]
  trace += [trace[u % users] for u in range(repeats)]
  return trace


@pytest.mark.parametrize("policy,dtype,ctx,cap,blocks", [
    ("exact", "float32", 64, 32, 16),
    ("pq", "bfloat16", 96, 64, 24),
])
def test_prefix_cache_on_off_oracle_paged(policy, dtype, ctx, cap, blocks):
  """Acceptance: greedy outputs bit-identical with the prefix cache on vs
  off over the paged layout, for exact (chain sharing + suffix-only
  prefill) and pq (full-prompt snapshot hits)."""
  cfg = _cfg(policy, dtype=dtype)
  off = ServeEngine(cfg, context_len=ctx, max_batch=2, prompt_capacity=cap,
                    cache_layout="paged", scheduler="paged",
                    num_blocks=blocks)
  on = ServeEngine(cfg, context_len=ctx, max_batch=2, prompt_capacity=cap,
                   params=off.params, cache_layout="paged",
                   scheduler="prefix", num_blocks=blocks, prefix_cache=True)
  if policy == "pq":
    # pq needs sink+recent tokens before the body; longer shared prompts
    sys_prompt = list(range(2, 50))
    trace = [(sys_prompt + [60 + u] * 8, 8) for u in range(3)]
    trace += [trace[0], trace[1]]
  else:
    trace = _shared_trace(cfg.vocab_size)
  want = [off.submit(p, max_new_tokens=m) for p, m in trace]
  got = [on.submit(p, max_new_tokens=m) for p, m in trace]
  off.run_to_completion()
  on.run_to_completion()
  for w, g in zip(want, got):
    assert g.done and g.tokens == w.tokens, (w.rid, w.tokens, g.tokens)
  assert on.stats.prefix_hits >= 2, "trace never hit the cache"
  if policy == "exact":
    assert on.stats.prefill_tokens < off.stats.prefill_tokens
  assert on.stats.prefix_full_hits >= 1
  _drained(on)


def test_prefix_cache_on_off_oracle_tiered_with_spills():
  """Acceptance: bit-identical under the tiered layout while randomized
  spill/fetch traffic crosses shared blocks — a spilled victim's shared
  prefix blocks stay device-resident (spilled zero times, not once per
  request) and re-adopt on fetch."""
  cfg = _cfg()
  off = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32)
  on = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                   params=off.params, cache_layout="tiered",
                   scheduler="tiered", num_blocks=4, host_blocks=24,
                   prefix_cache=True)
  sys_prompt = list(range(1, 18))
  trace = [(sys_prompt + [40 + 5 * i] * 9, 13) for i in range(3)]
  trace.append((sys_prompt + [40] * 9, 13))     # exact repeat of user 0
  want = [off.submit(p, max_new_tokens=m) for p, m in trace]
  got = [on.submit(p, max_new_tokens=m) for p, m in trace]
  off.run_to_completion()
  on.run_to_completion()
  for w, g in zip(want, got):
    assert g.done and g.tokens == w.tokens, (w.rid, w.tokens, g.tokens)
  assert on.stats.spills >= 1, "pool never pressured a swap-out"
  assert on.stats.prefix_hits >= 2
  # shared prefix blocks never crossed the tier boundary: every spilled
  # record carried its shared pairs as resident pins
  _drained(on)


def test_prefix_cache_tiered_pq_oracle():
  cfg = _cfg("pq", dtype="bfloat16")
  off = ServeEngine(cfg, context_len=96, max_batch=2, prompt_capacity=64)
  on = ServeEngine(cfg, context_len=96, max_batch=2, prompt_capacity=64,
                   params=off.params, cache_layout="tiered",
                   scheduler="tiered", num_blocks=10, host_blocks=32,
                   prefix_cache=True, prefix_cache_blocks=6)
  p1 = list(range(2, 60))
  p2 = list(range(4, 49))
  trace = [(p1, 20), (p2, 20), (p1, 16), (p2, 12)]
  want = [off.submit(p, max_new_tokens=m) for p, m in trace]
  got = [on.submit(p, max_new_tokens=m) for p, m in trace]
  off.run_to_completion()
  on.run_to_completion()
  for w, g in zip(want, got):
    assert g.done and g.tokens == w.tokens, (w.rid, w.tokens, g.tokens)
  assert on.stats.prefix_full_hits >= 1
  _drained(on)


def test_prefix_cache_randomized_on_off_oracle(rng):
  """Randomized mixed traffic (shared prefixes, distinct suffixes, exact
  repeats, varied lengths) under a tight tiered pool: every request's
  tokens stay identical to the cache-off contiguous oracle."""
  cfg = _cfg()
  oracle = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32)
  on = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                   params=oracle.params, cache_layout="tiered",
                   scheduler="tiered", num_blocks=6, host_blocks=24,
                   prefix_cache=True)
  prefixes = [list(range(1, 18)), list(rng.integers(1, 99, size=17))]
  pairs = []
  seen = []
  for _ in range(8):
    r = rng.random()
    if r < 0.25 and seen:
      prompt, gen = seen[int(rng.integers(0, len(seen)))]   # exact repeat
    else:
      pre = prefixes[int(rng.integers(0, len(prefixes)))]
      sfx = rng.integers(1, cfg.vocab_size,
                         size=int(rng.integers(1, 13))).tolist()
      prompt = pre + sfx
      gen = int(rng.integers(2, 14))
      seen.append((prompt, gen))
    pairs.append((oracle.submit(prompt, max_new_tokens=gen),
                  on.submit(prompt, max_new_tokens=gen)))
  oracle.run_to_completion()
  on.run_to_completion()
  for w, g in pairs:
    assert g.tokens == w.tokens, (w.rid, w.tokens, g.tokens)
  assert on.stats.prefix_hits >= 1
  _drained(on)


def test_fifo_starved_by_index_holds_evicts_and_drains():
  """Liveness regression: under fifo (the default scheduler, which picks
  the queue head without gating on admissibility), an idle engine whose
  pool is held mostly by published-but-unused index entries must evict
  them and admit, not livelock."""
  cfg = _cfg()
  eng = ServeEngine(cfg, context_len=64, max_batch=1, prompt_capacity=32,
                    cache_layout="paged", scheduler="fifo", num_blocks=6,
                    prefix_cache=True, prefix_cache_blocks=4)
  # two distinct published prompts pin 4 of the 6 blocks in the index
  a1 = eng.submit(list(range(1, 30)), max_new_tokens=4)
  eng.run_to_completion()
  a2 = eng.submit(list(range(100, 129)), max_new_tokens=4)
  eng.run_to_completion()
  assert a1.done and a2.done
  assert eng.layout.prefix_index.held_blocks >= 4
  # request B shares nothing: needs 3 blocks > 2 free while the index
  # holds the rest — admission must reclaim cached blocks, not livelock
  assert eng.layout.free_blocks < 3
  b = eng.submit(list(range(60, 89)), max_new_tokens=4)
  eng.run_to_completion(max_steps=200)
  assert b.done and len(b.tokens) == 4
  _drained(eng)


# ---------------------------------------------------------------------------
# The measured win (same numbers benchmarks/run.py records)
# ---------------------------------------------------------------------------

def test_shared_prefix_trace_halves_prefill_and_shrinks_kv():
  """Acceptance: on the shared-prefix serving trace, prefill tokens
  computed drop >= 50% (exact, chain sharing) and peak mapped KV bytes
  drop vs the no-cache run; pq hits on repeated prompts with a
  shared-prefix block footprint well under exact's."""
  from benchmarks.run import run_prefix_trace
  rec = run_prefix_trace("tinyllama-1.1b")
  ex = rec["policies"]["exact"]
  pq = rec["policies"]["pq"]
  assert ex["tokens_identical"] and pq["tokens_identical"]
  assert ex["prefill_tokens_saved_frac"] >= 0.5
  assert ex["peak_mapped_bytes"] < ex["peak_mapped_bytes_nocache"]
  assert ex["prefix_hit_rate"] >= 0.5
  assert pq["prefix_hit_rate"] > 0
  assert pq["prefix_full_hits"] >= 1
  assert rec["pq_vs_exact_block_bytes"] < 0.25
