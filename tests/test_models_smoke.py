"""REQUIRED per-arch smoke tests: reduced config of the same family, one
forward/train step on CPU, assert output shapes + no NaNs.  Also checks the
decode path against prefill logits consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import Model

ALL_ARCHS = list(ARCHS) + ["mistral-7b"]


def _modal_for(cfg, key, b, s):
  if cfg.frontend == "audio_frames":
    return jax.random.normal(key, (b, s, cfg.d_model), cfg.dtype)
  if cfg.frontend == "vision_patches":
    return jax.random.normal(key, (b, cfg.n_modal_tokens, cfg.d_model),
                             cfg.dtype)
  return None


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train_step(arch, key):
  cfg = get_arch(arch, reduced=True)
  model = Model(cfg, context_len=128)
  params = model.init(key)
  b, s = 2, 64
  tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
  batch = {"tokens": tokens, "targets": tokens}
  modal = _modal_for(cfg, key, b, s)
  if modal is not None:
    batch["modal"] = modal

  logits, aux = model.forward(params, tokens, modal)
  assert logits.shape == (b, s, cfg.vocab_size)
  assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

  loss, metrics = model.train_loss(params, batch)
  assert np.isfinite(float(loss))

  grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
  gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                       for g in jax.tree_util.tree_leaves(grads)))
  assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_prefill_decode(arch, key):
  cfg = get_arch(arch, reduced=True)
  model = Model(cfg, context_len=128)
  params = model.init(key)
  b, s = 2, 64
  tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
  modal = _modal_for(cfg, key, b, s)

  logits, cache = model.prefill(params, tokens, modal)
  assert logits.shape == (b, cfg.vocab_size)
  assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

  tok = jnp.argmax(logits, -1).astype(jnp.int32)
  step_modal = modal
  if cfg.frontend == "audio_frames":
    step_modal = modal[:, :1]
  lg, cache2 = model.decode_step(params, tok, cache, jnp.int32(s), step_modal)
  assert lg.shape == (b, cfg.vocab_size)
  assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))
  # cache must actually change (token was inserted)
  changed = any(
      not np.array_equal(np.asarray(a), np.asarray(b_))
      for a, b_ in zip(jax.tree_util.tree_leaves(cache),
                       jax.tree_util.tree_leaves(cache2)))
  assert changed


def test_decode_consistency_with_exact_cache(key):
  """With PQ disabled, decode-step logits == full-forward logits."""
  import dataclasses
  cfg = dataclasses.replace(get_arch("tinyllama-1.1b", reduced=True),
                            pq_enabled=False)
  model = Model(cfg, context_len=96)
  params = model.init(key)
  b, s = 2, 33
  tokens = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)

  # path A: prefill s tokens then decode token s
  _, cache = model.prefill(params, tokens[:, :s])
  lg_step, _ = model.decode_step(params, tokens[:, s], cache, jnp.int32(s))
  # path B: full forward over s+1 tokens, last position
  logits_full, _ = model.forward(params, tokens)
  np.testing.assert_allclose(
      np.asarray(lg_step, np.float32),
      np.asarray(logits_full[:, -1], np.float32), rtol=2e-2, atol=2e-2)


def test_pq_decode_tracks_exact_decode(key):
  """PQ cache decode is a close approximation of exact decode (reduced cfg,
  generous K): logits correlation should be high."""
  import dataclasses
  base = get_arch("tinyllama-1.1b", reduced=True)
  s = 64
  tokens = jax.random.randint(key, (2, s), 0, base.vocab_size)
  outs = {}
  for pq_on in (False, True):
    cfg = dataclasses.replace(base, pq_enabled=pq_on, pq_k=64)
    model = Model(cfg, context_len=96)
    params = model.init(key)    # same key -> identical params
    _, cache = model.prefill(params, tokens)
    lg, _ = model.decode_step(params, tokens[:, -1], cache, jnp.int32(s))
    outs[pq_on] = np.asarray(lg, np.float32)
  a, b = outs[False].ravel(), outs[True].ravel()
  corr = np.corrcoef(a, b)[0, 1]
  # random-weight activations are far less clusterable than trained-model KV
  # (paper Fig. 2); 0.95 on an untrained reduced model is a conservative gate
  assert corr > 0.95, corr
