"""PQ/exact KV-cache behaviour: prefill layout, decode append/evict/encode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kv_cache as kvc
from repro.core import pq, pq_attention as pqa


def _cfg(m=4, k=16, sink=4, recent=8, body=64, nw=2):
  return kvc.PQCacheConfig(sink=sink, recent=recent, body_capacity=body,
                           n_windows=nw, pq=pq.PQConfig(m=m, k=k))


def test_exact_cache_decode_matches_dense():
  rng = np.random.default_rng(0)
  b, h, hq, n, d = 2, 2, 4, 32, 16
  k = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  v = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  cache = kvc.exact_cache_prefill(k, v, 64)
  q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
  kn = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
  vn = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
  out, cache2 = kvc.exact_cache_append_and_attend(
      cache, q, kn, vn, jnp.int32(n), 0.25)
  # oracle: attend over the n+1 tokens
  k_all = jnp.concatenate([k, kn[:, :, None]], axis=2)
  v_all = jnp.concatenate([v, vn[:, :, None]], axis=2)
  g = hq // h
  qg = q.reshape(b, h, g, d)
  want = jax.vmap(jax.vmap(lambda qq, kk, vv: pqa.exact_decode_attention(
      qq, kk, vv, jnp.ones((n + 1,), bool), 0.25)))(qg, k_all, v_all)
  np.testing.assert_allclose(np.asarray(out),
                             np.asarray(want.reshape(b, hq, d)),
                             rtol=1e-4, atol=1e-4)


def test_pq_prefill_segments_layout():
  rng = np.random.default_rng(1)
  cfg = _cfg()
  b, h, n, d = 1, 1, 40, 16
  k = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  v = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  w = jnp.ones((b, h, n))
  cache = kvc.pq_cache_prefill(k, v, w, cfg)
  np.testing.assert_allclose(np.asarray(cache.sink_k[0, 0]),
                             np.asarray(k[0, 0, :4]))
  # recent ring holds the last `recent` tokens (at ring positions)
  slots = (np.arange(8) + (40 - 8 - 4)) % 8
  np.testing.assert_allclose(np.asarray(cache.recent_k[0, 0, slots]),
                             np.asarray(k[0, 0, -8:]))
  # K=16 <= 256 -> uint8 target-hardware index width
  assert cache.key_indices.dtype == jnp.uint8


def test_pq_decode_step_against_manual_attention():
  """One decode step == joint softmax over [sink | decoded body | ring | new]."""
  rng = np.random.default_rng(2)
  cfg = _cfg(sink=4, recent=8, body=64, nw=1, m=4, k=32)
  b, h, hq, n, d = 1, 1, 2, 40, 16
  keys = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  vals = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  w = jnp.ones((b, h, n))
  cache = kvc.pq_cache_prefill(keys, vals, w, cfg)
  q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
  kn = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
  vn = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
  scale = 0.25
  out, cache2 = kvc.pq_cache_append_and_attend(
      cache, q, kn, vn, jnp.int32(n), cfg, scale)

  # oracle: token 40 arrives; the ring evicts token (40-12)=28 -> encoded.
  # context = sink(0..3) + body tokens 4..28 (PQ-decoded) + ring 29..39 + new
  body_n = n - cfg.sink - cfg.recent + 1        # includes newly evicted token
  kcb, vcb = cache2.key_codebooks[0, 0, 0], cache2.value_codebooks[0, 0, 0]
  kix = cache2.key_indices[0, 0, :body_n].astype(jnp.int32)
  vix = cache2.value_indices[0, 0, :body_n].astype(jnp.int32)
  body_k = pq.decode(kix, kcb)
  body_v = pq.decode(vix, vcb)
  ring_k = keys[0, 0, cfg.sink + body_n:]
  ring_v = vals[0, 0, cfg.sink + body_n:]
  k_all = jnp.concatenate([keys[0, 0, :cfg.sink], body_k, ring_k, kn[0]])
  v_all = jnp.concatenate([vals[0, 0, :cfg.sink], body_v, ring_v, vn[0]])
  mask = jnp.ones((k_all.shape[0],), bool)
  want = pqa.exact_decode_attention(q[0], k_all, v_all, mask, scale)
  np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want),
                             rtol=2e-2, atol=2e-2)   # bf16 codebook storage


def test_pq_decode_sequence_of_steps_consistent():
  """Run 20 decode steps; lengths/masks stay coherent, outputs finite."""
  rng = np.random.default_rng(3)
  cfg = _cfg(sink=2, recent=4, body=32, nw=1, m=4, k=8)
  b, h, hq, n, d = 2, 2, 4, 10, 8
  keys = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  vals = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  cache = kvc.pq_cache_prefill(keys, vals, jnp.ones((b, h, n)), cfg)
  step = jax.jit(lambda c, q, kk, vv, ln: kvc.pq_cache_append_and_attend(
      c, q, kk, vv, ln, cfg, 0.3))
  for i in range(20):
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    out, cache = step(cache, q, kn, vn, jnp.int32(n + i))
    assert bool(jnp.all(jnp.isfinite(out))), i


def test_cache_byte_accounting():
  cfg = kvc.PQCacheConfig(sink=8, recent=32, body_capacity=32768,
                          n_windows=1, pq=pq.PQConfig(m=32, k=512))
  stats = kvc.pq_cache_bytes(cfg, b=1, h=8, d=128)
  # int16 indices: 64 B/token/side vs 256 B exact -> ~4x at large N
  assert 3.5 < stats["reduction_ratio"] < 4.5, stats


def test_pq_ring_wrap_decode_matches_oracle():
  """Decode far past sink+recent: every step's evict->encode must keep the
  [sink | PQ body | ring] bookkeeping consistent with an exact oracle built
  from the cache's own codebooks (the encode step is treated as ground truth;
  bf16 codebook storage sets the tolerance)."""
  rng = np.random.default_rng(7)
  cfg = _cfg(sink=2, recent=4, body=32, nw=1, m=4, k=16)
  b, h, hq, n, d = 1, 1, 2, 8, 8
  s0, r = cfg.sink, cfg.recent
  keys = [rng.normal(size=(d,)).astype(np.float32) for _ in range(n)]
  vals = [rng.normal(size=(d,)).astype(np.float32) for _ in range(n)]
  k0 = jnp.asarray(np.stack(keys))[None, None]
  v0 = jnp.asarray(np.stack(vals))[None, None]
  cache = kvc.pq_cache_prefill(k0, v0, jnp.ones((b, h, n)), cfg)
  scale = 0.3

  # 3 full ring revolutions past the wrap point
  for pos in range(n, n + 3 * r + 2):
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    kn = rng.normal(size=(d,)).astype(np.float32)
    vn = rng.normal(size=(d,)).astype(np.float32)
    out, cache = kvc.pq_cache_append_and_attend(
        cache, q, jnp.asarray(kn)[None, None], jnp.asarray(vn)[None, None],
        jnp.int32(pos), cfg, scale)
    keys.append(kn)
    vals.append(vn)

    n_tok = pos + 1
    body_n = n_tok - s0 - r
    assert body_n > 0  # evict->encode fired
    kcb = cache.key_codebooks[0, 0, 0]
    vcb = cache.value_codebooks[0, 0, 0]
    body_k = pq.decode(cache.key_indices[0, 0, :body_n].astype(jnp.int32), kcb)
    body_v = pq.decode(cache.value_indices[0, 0, :body_n].astype(jnp.int32),
                       vcb)
    true_k = np.stack(keys)
    true_v = np.stack(vals)
    k_all = jnp.concatenate(
        [jnp.asarray(true_k[:s0]), body_k, jnp.asarray(true_k[s0 + body_n:])])
    v_all = jnp.concatenate(
        [jnp.asarray(true_v[:s0]), body_v, jnp.asarray(true_v[s0 + body_n:])])
    want = pqa.exact_decode_attention(
        q[0], k_all, v_all, jnp.ones((n_tok,), bool), scale)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want),
                               rtol=2e-2, atol=2e-2, err_msg=f"pos={pos}")


def test_pq_append_mixed_lengths_matches_per_row():
  """(B,) lengths vector: each batched row must equal its own b=1 run."""
  rng = np.random.default_rng(8)
  cfg = _cfg(sink=2, recent=4, body=32, nw=1, m=4, k=8)
  b, h, hq, n, d = 3, 2, 4, 16, 8
  keys = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  vals = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  lengths = jnp.asarray([16, 9, 12], jnp.int32)
  cache = kvc.pq_cache_prefill(keys, vals, jnp.ones((b, h, n)), cfg,
                               length=lengths)
  q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
  kn = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
  vn = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
  out, cache2 = kvc.pq_cache_append_and_attend(
      cache, q, kn, vn, lengths, cfg, 0.25)

  for i in range(b):
    c1 = kvc.pq_cache_prefill(keys[i:i + 1], vals[i:i + 1],
                              jnp.ones((1, h, n)), cfg,
                              length=lengths[i:i + 1])
    out1, _ = kvc.pq_cache_append_and_attend(
        c1, q[i:i + 1], kn[i:i + 1], vn[i:i + 1], lengths[i], cfg, 0.25)
    np.testing.assert_allclose(np.asarray(out[i]), np.asarray(out1[0]),
                               rtol=1e-5, atol=1e-5, err_msg=f"row {i}")


def test_pq_prefill_dynamic_length_matches_static_path():
  """length=N through the per-request path must reproduce the static prefill
  in the valid region (independent oracle for the dynamic ring/body math;
  masked padding slots beyond the valid region may differ)."""
  rng = np.random.default_rng(9)
  cfg = _cfg(sink=2, recent=4, body=32, nw=1, m=4, k=8)
  b, h, n, d = 2, 2, 16, 8
  keys = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  vals = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  w = jnp.ones((b, h, n))
  static = kvc.pq_cache_prefill(keys, vals, w, cfg)
  dyn = kvc.pq_cache_prefill(keys, vals, w, cfg,
                             length=jnp.full((b,), n, jnp.int32))
  np.testing.assert_allclose(np.asarray(dyn.sink_k), np.asarray(static.sink_k))
  np.testing.assert_allclose(np.asarray(dyn.recent_k),
                             np.asarray(static.recent_k))
  np.testing.assert_allclose(np.asarray(dyn.recent_v),
                             np.asarray(static.recent_v))
  body_n = n - cfg.sink - cfg.recent
  np.testing.assert_allclose(
      np.asarray(dyn.key_codebooks, np.float32),
      np.asarray(static.key_codebooks, np.float32), rtol=1e-3, atol=1e-3)
  np.testing.assert_array_equal(
      np.asarray(dyn.key_indices[:, :, :body_n]),
      np.asarray(static.key_indices[:, :, :body_n]))
