"""Product Quantization codec + channel sorting tests (paper §III-B/D)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
  from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback shim
  from hypothesis_compat import given, settings, strategies as st

from repro.core import channel_sort, pq


def _clustered(rng, n, d, n_modes=8, noise=0.05):
  centers = rng.normal(size=(n_modes, d)) * 3
  return jnp.asarray(
      centers[rng.integers(0, n_modes, n)] + rng.normal(size=(n, d)) * noise,
      jnp.float32)


def test_roundtrip_shapes():
  rng = np.random.default_rng(0)
  x = _clustered(rng, 256, 64)
  cfg = pq.PQConfig(m=8, k=32)
  cb, idx = pq.build_codebook(x, jnp.ones((256,)), cfg)
  assert cb.shape == (8, 32, 8)
  assert idx.shape == (256, 8)
  rec = pq.decode(idx, cb)
  assert rec.shape == (256, 64)


def test_error_decreases_with_k():
  """Paper Table III: accuracy saturates as K grows."""
  rng = np.random.default_rng(1)
  x = _clustered(rng, 512, 32)
  errs = []
  for k in (4, 16, 64, 256):
    cfg = pq.PQConfig(m=8, k=k, iters=8)
    cb, idx = pq.build_codebook(x, jnp.ones((512,)), cfg)
    errs.append(float(pq.quantization_mse(x, cb, idx)))
  assert errs[0] > errs[-1]
  assert all(a >= b - 1e-5 for a, b in zip(errs, errs[1:])), errs


def test_error_decreases_with_m():
  """Paper Table II: more subvectors -> finer quantization."""
  rng = np.random.default_rng(2)
  x = jnp.asarray(rng.normal(size=(512, 32)), jnp.float32)
  errs = []
  for m in (1, 2, 4, 8, 16):
    cfg = pq.PQConfig(m=m, k=16, iters=8)
    cb, idx = pq.build_codebook(x, jnp.ones((512,)), cfg)
    errs.append(float(pq.quantization_mse(x, cb, idx)))
  assert errs[0] > errs[-1], errs


def test_encode_matches_build_assignment():
  rng = np.random.default_rng(3)
  x = _clustered(rng, 128, 16)
  cfg = pq.PQConfig(m=4, k=8)
  cb, idx = pq.build_codebook(x, jnp.ones((128,)), cfg)
  idx2 = pq.encode(x, cb)
  np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx2))


def test_compression_ratio_accounting():
  cfg = pq.PQConfig(m=32, k=512)
  assert cfg.index_bytes() == 2
  assert cfg.compression_ratio(128) == 128 * 2 / (32 * 2)  # 4x at int16
  cfg8 = pq.PQConfig(m=32, k=256)
  assert cfg8.compression_ratio(128) == 8.0                # 8x at uint8


# ---------------------------------------------------------------------------
# channel sorting (paper §III-D)
# ---------------------------------------------------------------------------

def test_greedy_groups_is_permutation():
  rng = np.random.default_rng(4)
  calib = rng.normal(size=(256, 32))
  perm = channel_sort.greedy_channel_groups(calib, m=8)
  assert sorted(perm.tolist()) == list(range(32))


def test_sorting_groups_correlated_channels():
  """Duplicated channels must land in the same group."""
  rng = np.random.default_rng(5)
  base = rng.normal(size=(512, 4))
  # channels [i, i+4, i+8, i+12] are copies of each other (+ tiny noise)
  calib = np.concatenate([base + rng.normal(size=base.shape) * 1e-3
                          for _ in range(4)], axis=1)
  perm = channel_sort.greedy_channel_groups(calib, m=4)
  groups = perm.reshape(4, 4) % 4
  for g in groups:
    assert len(set(g.tolist())) == 1, groups


def test_presort_reduces_pq_error():
  """Paper Table IV 'w/o pre-sort' ablation, at the codec level."""
  rng = np.random.default_rng(6)
  base = rng.normal(size=(1024, 8))
  # interleaved correlated channels: contiguous split is the worst case
  calib = np.stack(
      [base[:, i % 8] * (1 + 0.01 * i) for i in range(32)], axis=1)
  x = jnp.asarray(calib, jnp.float32)
  cfg = pq.PQConfig(m=8, k=16, iters=8)
  cb0, idx0 = pq.build_codebook(x, jnp.ones((1024,)), cfg)
  e_plain = float(pq.quantization_mse(x, cb0, idx0))
  perm = channel_sort.greedy_channel_groups(calib, m=8)
  xs = x[:, perm]
  cb1, idx1 = pq.build_codebook(xs, jnp.ones((1024,)), cfg)
  e_sorted = float(pq.quantization_mse(xs, cb1, idx1))
  assert e_sorted < e_plain, (e_sorted, e_plain)


def test_absorbed_permutation_preserves_scores():
  """q.k invariant under shared head_dim permutation of W_q, W_k."""
  rng = np.random.default_rng(7)
  d_model, h, hd = 16, 2, 8
  wq = rng.normal(size=(d_model, h, hd)).astype(np.float32)
  wk = rng.normal(size=(d_model, h, hd)).astype(np.float32)
  wv = rng.normal(size=(d_model, h, hd)).astype(np.float32)
  wo = rng.normal(size=(h, hd, d_model)).astype(np.float32)
  perm = np.random.default_rng(8).permutation(hd)
  wq2, wk2, wv2, wo2 = channel_sort.absorb_into_projections(
      wq, wk, wv, wo, perm, perm)
  x = rng.normal(size=(4, d_model)).astype(np.float32)
  q1 = np.einsum("bd,dhk->bhk", x, wq)
  k1 = np.einsum("bd,dhk->bhk", x, wk)
  q2 = np.einsum("bd,dhk->bhk", x, wq2)
  k2 = np.einsum("bd,dhk->bhk", x, wk2)
  np.testing.assert_allclose(
      np.einsum("bhk,chk->bhc", q1, k1),
      np.einsum("bhk,chk->bhc", q2, k2), rtol=1e-5, atol=1e-5)
  # value path: v (x) o composition preserved
  v1 = np.einsum("bd,dhk->bhk", x, wv)
  o1 = np.einsum("bhk,hkd->bd", v1, wo)
  v2 = np.einsum("bd,dhk->bhk", x, wv2)
  o2 = np.einsum("bhk,hkd->bd", v2, wo2)
  np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(m=st.sampled_from([2, 4, 8]), k=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 2**31 - 1))
def test_property_decode_encode_idempotent(m, k, seed):
  """decode(encode(decode(idx))) == decode(idx): codebook points are fixed."""
  rng = np.random.default_rng(seed)
  cb = jnp.asarray(rng.normal(size=(m, k, 4)), jnp.float32)
  idx = jnp.asarray(rng.integers(0, k, size=(32, m)), jnp.int32)
  rec = pq.decode(idx, cb)
  idx2 = pq.encode(rec, cb)
  rec2 = pq.decode(idx2, cb)
  np.testing.assert_allclose(np.asarray(rec), np.asarray(rec2),
                             rtol=1e-5, atol=1e-5)
