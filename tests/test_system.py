"""End-to-end system behaviour: training converges, serving generates,
restart-equivalence under failures, PQ end-to-end on the serve path."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import ServeRun
from repro.launch.train import TrainRun
from repro.runtime import fault_tolerance as ft


def test_training_loss_decreases():
  run = TrainRun(arch="tinyllama-1.1b", reduced=True, steps=25,
                 batch=4, seq=128, lr=1e-3, log_every=100)
  _, losses, _ = run.run()
  first = np.mean(losses[:5])
  last = np.mean(losses[-5:])
  assert last < first * 0.85, (first, last)


def test_training_with_grad_compression_still_learns():
  run = TrainRun(arch="tinyllama-1.1b", reduced=True, steps=20,
                 batch=4, seq=128, lr=1e-3, compress_grads=True,
                 log_every=100)
  _, losses, _ = run.run()
  assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_training_survives_injected_failures():
  """Restarted run reaches the same step count and a sane loss."""
  with tempfile.TemporaryDirectory() as d:
    run = TrainRun(arch="tinyllama-1.1b", reduced=True, steps=20,
                   batch=2, seq=64, lr=1e-3, ckpt_dir=d, ckpt_every=5,
                   log_every=100)
    inj = ft.FailureInjector(fail_at=(7, 13))
    state, losses, report = run.run(injector=inj)
    assert report.restarts == 2
    assert report.resumed_from == [5, 10]
    assert np.isfinite(losses[-1])


def test_serve_generates_with_pq_and_without():
  outs = {}
  for pq_on in (True, False):
    run = ServeRun(arch="tinyllama-1.1b", reduced=True, batch=2,
                   prompt_len=64, gen=8, pq=pq_on, measure_latency=False)
    res = run.run()
    assert res["tokens"].shape == (2, 8)
    outs[pq_on] = np.asarray(res["tokens"])
  # both paths must be valid token ids
  for v in outs.values():
    assert v.min() >= 0


def test_moe_serve_path():
  run = ServeRun(arch="qwen2-moe-a2.7b", reduced=True, batch=2,
                 prompt_len=64, gen=4, pq=True, measure_latency=False)
  res = run.run()
  assert res["tokens"].shape == (2, 4)


def test_rwkv_serve_path():
  """Attention-free arch: serving works with O(1) recurrent state."""
  run = ServeRun(arch="rwkv6-3b", reduced=True, batch=2,
                 prompt_len=64, gen=4, pq=True,   # pq silently inapplicable
                 measure_latency=False)
  res = run.run()
  assert res["pq"] is False
  assert res["tokens"].shape == (2, 4)
