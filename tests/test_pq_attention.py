"""PQ-based attention (paper Fig. 5): exactness and approximation tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
  from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback shim
  from hypothesis_compat import given, settings, strategies as st

from repro.core import pq, pq_attention as pqa, windowed


def _setup(rng, n=128, d=32, m=8, k=16, g=2):
  x_k = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  x_v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  cfg = pq.PQConfig(m=m, k=k, iters=6)
  w = jnp.ones((n,))
  kcb, kidx = pq.build_codebook(x_k, w, cfg)
  vcb, vidx = pq.build_codebook(x_v, w, cfg)
  q = jnp.asarray(rng.normal(size=(g, d)), jnp.float32)
  return x_k, x_v, kcb, kidx, vcb, vidx, q, cfg


def test_lookup_scores_equal_scores_on_reconstruction():
  """Core identity: PQ scores == q . decode(indices) exactly."""
  rng = np.random.default_rng(0)
  x_k, _, kcb, kidx, _, _, q, cfg = _setup(rng)
  table = pqa.inner_product_table(q, kcb)
  s = pqa.lookup_scores(table, kidx)
  rec = pq.decode(kidx, kcb)
  np.testing.assert_allclose(np.asarray(s), np.asarray(q @ rec.T),
                             rtol=1e-4, atol=1e-4)


def test_bucket_output_equals_probs_times_reconstruction():
  """Bucket-sum trick == probs @ decode(indices) exactly (paper steps 6-7)."""
  rng = np.random.default_rng(1)
  _, x_v, _, _, vcb, vidx, q, cfg = _setup(rng)
  probs = jax.nn.softmax(jnp.asarray(rng.normal(size=(2, 128)), jnp.float32))
  buckets = pqa.bucket_accumulate(probs, vidx, cfg.k)
  out = pqa.output_from_buckets(buckets, vcb)
  rec = pq.decode(vidx, vcb)
  np.testing.assert_allclose(np.asarray(out), np.asarray(probs @ rec),
                             rtol=1e-4, atol=1e-4)


def test_pq_attention_equals_exact_on_reconstructed_kv():
  """Full decode attention == exact attention over the reconstructed KV."""
  rng = np.random.default_rng(2)
  x_k, x_v, kcb, kidx, vcb, vidx, q, cfg = _setup(rng)
  n, d = x_k.shape
  seg = pqa.PQAttnSegments(
      sink_k=jnp.zeros((0, d)), sink_v=jnp.zeros((0, d)),
      sink_mask=jnp.zeros((0,), bool),
      key_codebook=kcb, value_codebook=vcb,
      key_indices=kidx, value_indices=vidx,
      body_mask=jnp.ones((n,), bool),
      recent_k=jnp.zeros((0, d)), recent_v=jnp.zeros((0, d)),
      recent_mask=jnp.zeros((0,), bool))
  scale = 1 / np.sqrt(d)
  out = pqa.pq_decode_attention(q, seg, scale)
  rec_k = pq.decode(kidx, kcb)
  rec_v = pq.decode(vidx, vcb)
  want = pqa.exact_decode_attention(q, rec_k, rec_v,
                                    jnp.ones((n,), bool), scale)
  np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                             rtol=1e-4, atol=1e-4)


def test_pq_attention_approaches_exact_as_k_grows():
  """Approximation error vs the TRUE attention shrinks with K (Table III)."""
  rng = np.random.default_rng(3)
  n, d = 128, 32
  x_k = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  x_v = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  q = jnp.asarray(rng.normal(size=(1, d)), jnp.float32)
  scale = 1 / np.sqrt(d)
  exact = pqa.exact_decode_attention(q, x_k, x_v, jnp.ones((n,), bool), scale)
  errs = []
  for k in (2, 8, 32, 128):
    cfg = pq.PQConfig(m=8, k=k, iters=8)
    kcb, kidx = pq.build_codebook(x_k, jnp.ones((n,)), cfg)
    vcb, vidx = pq.build_codebook(x_v, jnp.ones((n,)), cfg)
    seg = pqa.PQAttnSegments(
        sink_k=jnp.zeros((0, d)), sink_v=jnp.zeros((0, d)),
        sink_mask=jnp.zeros((0,), bool),
        key_codebook=kcb, value_codebook=vcb,
        key_indices=kidx, value_indices=vidx,
        body_mask=jnp.ones((n,), bool),
        recent_k=jnp.zeros((0, d)), recent_v=jnp.zeros((0, d)),
        recent_mask=jnp.zeros((0,), bool))
    out = pqa.pq_decode_attention(q, seg, scale)
    errs.append(float(jnp.max(jnp.abs(out - exact))))
  assert errs[0] > errs[-1], errs
  assert errs[-1] < 0.05, errs    # K = N: near-exact


def test_windowed_matches_flat_when_codebooks_tile():
  """nW windows with identical codebooks == flat lookup."""
  rng = np.random.default_rng(4)
  x_k, _, kcb, kidx, _, _, q, cfg = _setup(rng, n=128)
  flat = pqa.lookup_scores(pqa.inner_product_table(q, kcb), kidx)
  cbs = jnp.broadcast_to(kcb[None], (4,) + kcb.shape)
  win = pqa.windowed_lookup_scores(q, cbs, kidx)
  np.testing.assert_allclose(np.asarray(flat), np.asarray(win),
                             rtol=1e-4, atol=1e-4)


def test_windowed_build_warm_start_improves_over_cold_window():
  """Warm-started window codebooks give coherent pages (finite + low error)."""
  rng = np.random.default_rng(5)
  n, d = 256, 16
  x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  cfg = pq.PQConfig(m=4, k=16, iters=4)
  cbs, idx = windowed.windowed_build_codebooks(x, jnp.ones((n,)), cfg, 4)
  rec = windowed.windowed_decode(idx, cbs)
  err = float(jnp.mean((x - rec) ** 2))
  assert np.isfinite(err) and err < float(jnp.var(x)), err


def test_sink_recent_joint_softmax():
  """Mixed segments (sink + body + recent) == one joint softmax."""
  rng = np.random.default_rng(6)
  n, d, s0, r = 64, 16, 4, 8
  keys = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  vals = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  q = jnp.asarray(rng.normal(size=(2, d)), jnp.float32)
  scale = 1 / np.sqrt(d)
  body_k, body_v = keys[s0:n - r], vals[s0:n - r]
  cfg = pq.PQConfig(m=4, k=52, iters=10)  # K ~= body size -> near-lossless
  nb = n - s0 - r
  kcb, kidx = pq.build_codebook(body_k, jnp.ones((nb,)), cfg)
  vcb, vidx = pq.build_codebook(body_v, jnp.ones((nb,)), cfg)
  seg = pqa.PQAttnSegments(
      sink_k=keys[:s0], sink_v=vals[:s0], sink_mask=jnp.ones((s0,), bool),
      key_codebook=kcb, value_codebook=vcb,
      key_indices=kidx, value_indices=vidx,
      body_mask=jnp.ones((nb,), bool),
      recent_k=keys[n - r:], recent_v=vals[n - r:],
      recent_mask=jnp.ones((r,), bool))
  out = pqa.pq_decode_attention(q, seg, scale)
  # oracle: joint softmax over [sink | decode(body) | recent]
  k_all = jnp.concatenate([keys[:s0], pq.decode(kidx, kcb), keys[n - r:]])
  v_all = jnp.concatenate([vals[:s0], pq.decode(vidx, vcb), vals[n - r:]])
  want = pqa.exact_decode_attention(q, k_all, v_all,
                                    jnp.ones((n,), bool), scale)
  np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                             rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), g=st.sampled_from([1, 2, 4]))
def test_property_masked_tokens_never_contribute(seed, g):
  rng = np.random.default_rng(seed)
  n, d = 64, 16
  x_k, x_v, kcb, kidx, vcb, vidx, q, cfg = _setup(rng, n=n, d=d, g=g)
  mask = jnp.arange(n) < 32
  seg = pqa.PQAttnSegments(
      sink_k=jnp.zeros((0, d)), sink_v=jnp.zeros((0, d)),
      sink_mask=jnp.zeros((0,), bool),
      key_codebook=kcb, value_codebook=vcb,
      key_indices=kidx, value_indices=vidx, body_mask=mask,
      recent_k=jnp.zeros((0, d)), recent_v=jnp.zeros((0, d)),
      recent_mask=jnp.zeros((0,), bool))
  out1 = pqa.pq_decode_attention(q, seg, 0.1)
  # poison masked indices: result must not change
  poison = kidx.at[32:].set((kidx[32:] + 7) % cfg.k)
  poison_v = vidx.at[32:].set((vidx[32:] + 3) % cfg.k)
  seg2 = seg._replace(key_indices=poison, value_indices=poison_v)
  out2 = pqa.pq_decode_attention(q, seg2, 0.1)
  np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                             rtol=1e-5, atol=1e-5)
