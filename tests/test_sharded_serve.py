"""Sharded multi-device serving (PR 7): plan resolution, pool placement,
and the cross-shard decode identity bar.

Two layers, because device topology is process-global in jax:

- In-process tests cover the pure decision logic — `make_local_mesh`
  validation, `plan_for`'s fallback chain, the pool-leaf rules in
  `parallel.sharding.cache_pspecs`, `storage_pspec`/`per_shard_bytes`, and
  the mesh-aware dispatch resolution.  None of these touch devices (plan
  and mesh stand-ins carry only `.shape`/`.axis_names`), so they run under
  the normal single-device conftest.
- The acceptance matrix — greedy tokens bit-identical between mesh=1 and
  mesh∈{2,4} across {exact, pq} x {paged, tiered}, plus a forced
  spill/fetch trace and the seq split-K fallback — needs 8 devices, which
  XLA only grants before the first jax import.  It runs as ONE subprocess
  with `XLA_FLAGS=--xla_force_host_platform_device_count=8` in its
  environment (the same mechanism the benchmark's mesh probes and the CI
  mesh-matrix job use).
"""
import dataclasses
import os
import subprocess
import sys
import types

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.decode_dispatch import DecodeDispatch, resolve_for_mesh
from repro.parallel import serve_sharding as ssh
from repro.parallel import sharding as shd


def _mesh_stub(**axes):
  return types.SimpleNamespace(shape=dict(axes),
                               axis_names=tuple(axes))


def _cfg(policy: str, n_heads: int = 4, n_kv_heads: int = 2):
  cfg = get_arch("tinyllama-1.1b", reduced=True)
  return dataclasses.replace(cfg, cache_policy=policy, n_heads=n_heads,
                             n_kv_heads=n_kv_heads)


# ---------------------------------------------------------------------------
# make_local_mesh validation (satellite: the silent device-dropping fix)
# ---------------------------------------------------------------------------

class TestMakeLocalMesh:

  def test_model_axis_must_be_positive(self):
    from repro.launch.mesh import make_local_mesh
    with pytest.raises(ValueError, match=">= 1"):
      make_local_mesh(model=0)

  def test_indivisible_model_axis_raises_with_counts(self):
    # the single-device test process: model=2 cannot tile 1 device; the old
    # code built a (0, 2) mesh that dropped every device
    from repro.launch.mesh import make_local_mesh
    with pytest.raises(ValueError, match=r"model axis size 2.*device count 1"):
      make_local_mesh(model=2)

  def test_explicit_axes_must_tile_exactly(self):
    from repro.launch.mesh import make_local_mesh
    with pytest.raises(ValueError, match="tile the device count"):
      make_local_mesh(model=1, data=3)

  def test_single_device_mesh(self):
    from repro.launch.mesh import make_local_mesh, model_axis_size
    mesh = make_local_mesh(model=1)
    assert dict(mesh.shape) == {"data": 1, "model": 1}
    assert model_axis_size(mesh) == 1


# ---------------------------------------------------------------------------
# plan_for: the partition-mode fallback chain
# ---------------------------------------------------------------------------

class TestPlanFor:

  def test_size_one_is_none_mode(self):
    plan = ssh.plan_for(_cfg("pq"), _mesh_stub(data=1, model=1))
    assert plan.mode == "none" and not plan.active and plan.bit_identical

  def test_divisible_heads_win(self):
    plan = ssh.plan_for(_cfg("pq"), _mesh_stub(data=4, model=2))
    assert plan.mode == "heads" and plan.size == 2
    assert plan.active and plan.bit_identical

  def test_exact_falls_back_to_seq(self):
    # 2 kv heads on a 4-way axis: heads don't divide; exact store splits K
    plan = ssh.plan_for(_cfg("exact"), _mesh_stub(data=2, model=4))
    assert plan.mode == "seq" and plan.size == 4
    assert plan.active and not plan.bit_identical

  def test_compressed_policy_raises_naming_the_chain(self):
    with pytest.raises(ValueError) as e:
      ssh.plan_for(_cfg("pq"), _mesh_stub(data=2, model=4))
    msg = str(e.value)
    assert "pq" in msg and "model=4" in msg and "2" in msg

  def test_describe_round_trips(self):
    mesh = types.SimpleNamespace(shape={"data": 4, "model": 2},
                                 axis_names=("data", "model"),
                                 devices=np.array([[0, 1]] * 4))
    d = ssh.plan_for(_cfg("exact"), mesh).describe()
    assert d["mode"] == "heads" and d["shards"] == 2
    assert d["bit_identical"] is True and len(d["devices"]) == 8


# ---------------------------------------------------------------------------
# pool-leaf fallback chain in parallel.sharding.cache_pspecs (satellite 2)
# ---------------------------------------------------------------------------

class TestPoolLeafPspecs:

  def _specs(self, leaves, hints, model=2):
    mesh = _mesh_stub(data=1, model=model)
    return shd.cache_pspecs(leaves, mesh, batch=2, paged_axes=hints)

  def test_heads_axis_preferred(self):
    # pool leaf (P+1, L, H, block, D): kv heads at axis 2 divide -> model
    pool = np.zeros((9, 2, 4, 16, 8), np.float32)
    (spec,) = self._specs([pool], [2])
    assert spec == shd.P(None, None, "model", None, None)

  def test_split_k_fallback_on_indivisible_heads(self):
    # 3 heads don't divide 2; the leading physical-block axis (8) does
    pool = np.zeros((8, 2, 3, 16, 8), np.float32)
    (spec,) = self._specs([pool], [2])
    assert spec == shd.P("model", None, None, None, None)

  def test_terminal_replicate(self):
    # neither heads nor the block axis divide -> replicate, never crash
    pool = np.zeros((9, 2, 3, 16, 8), np.float32)
    (spec,) = self._specs([pool], [2])
    assert spec == shd.P(None, None, None, None, None)

  def test_resident_hint_uses_dense_rules(self):
    from repro.core.cache_api import RESIDENT
    # (L, B, H, N, D) resident leaf keeps the dense chain: batch over data,
    # heads at axis 2 over model
    dense = np.zeros((2, 2, 4, 32, 8), np.float32)
    (spec,) = self._specs([dense], [RESIDENT])
    assert spec == shd.P(None, ("data",), "model", None, None)

  def test_pq_index_pool_leaf(self):
    # AQPIM PQ code pool (P+1, L, H, block, m) — the PR 5 shape the old
    # dense-only rules misread (axis 1 is layers, not batch)
    pool = np.zeros((9, 2, 4, 16, 2), np.int32)
    (spec,) = self._specs([pool], [2])
    assert spec == shd.P(None, None, "model", None, None)


# ---------------------------------------------------------------------------
# storage placement + per-shard accounting
# ---------------------------------------------------------------------------

class TestStoragePlacement:

  def _plan(self, mode="heads", size=2, kv=4):
    return ssh.ShardPlan(mesh=_mesh_stub(data=1, model=size), mode=mode,
                         size=size, n_kv_heads=kv, n_heads=kv)

  def test_heads_mode_spec(self):
    plan = self._plan()
    pool = np.zeros((9, 2, 4, 16, 8), np.float32)
    assert ssh.storage_pspec(plan, pool) == ssh.P(
        None, None, "model", None, None)
    resident = np.zeros((2, 2, 4, 8), np.float32)
    assert ssh.storage_pspec(plan, resident) == ssh.P(
        None, None, "model", None)

  def test_non_head_leaf_replicates(self):
    plan = self._plan()
    odd = np.zeros((2, 2, 3, 8), np.float32)   # axis 2 != n_kv_heads
    assert ssh.storage_pspec(plan, odd) == ssh.P(None, None, None, None)

  def test_seq_mode_replicates_storage(self):
    plan = self._plan(mode="seq", kv=2)
    pool = np.zeros((9, 2, 2, 16, 8), np.float32)
    assert all(ax is None for ax in ssh.storage_pspec(plan, pool))

  def test_per_shard_bytes_split(self):
    plan = self._plan(size=2, kv=4)
    pool = np.zeros((8, 2, 4, 16, 8), np.float32)    # sharded
    flat = np.zeros((2, 2), np.float32)              # replicated
    acct = ssh.per_shard_bytes(plan, [pool, flat])
    assert acct["sharded_bytes"] == pool.nbytes
    assert acct["replicated_bytes"] == flat.nbytes
    assert acct["bytes_per_shard"] == pool.nbytes // 2 + flat.nbytes
    assert acct["total_bytes"] == pool.nbytes + flat.nbytes

  def test_per_shard_bytes_seq_mode_is_total(self):
    plan = self._plan(mode="seq", size=4, kv=2)
    pool = np.zeros((8, 2, 2, 16, 8), np.float32)
    acct = ssh.per_shard_bytes(plan, [pool])
    assert acct["bytes_per_shard"] == acct["total_bytes"] == pool.nbytes


# ---------------------------------------------------------------------------
# mesh-aware dispatch resolution
# ---------------------------------------------------------------------------

class TestResolveForMesh:

  def test_heads_mode_keeps_kernels(self):
    d = DecodeDispatch(name="pallas-interpret", use_pallas=True,
                       interpret=True)
    assert resolve_for_mesh(d, "heads") is d
    assert resolve_for_mesh(d, "none") is d

  def test_seq_mode_degrades_auto(self):
    d = DecodeDispatch(name="auto", use_pallas=True)
    out = resolve_for_mesh(d, "seq")
    assert not out.use_pallas and out.key == "xla"

  def test_seq_mode_rejects_explicit_kernel(self):
    d = DecodeDispatch(name="pallas-interpret", use_pallas=True,
                       interpret=True)
    with pytest.raises(ValueError, match="split-K"):
      resolve_for_mesh(d, "seq")

  def test_xla_passes_through_everywhere(self):
    d = DecodeDispatch(name="xla", use_pallas=False)
    assert resolve_for_mesh(d, "seq") is d


# ---------------------------------------------------------------------------
# engine-level guards (single device: plan resolution still runs)
# ---------------------------------------------------------------------------

class TestEngineGuards:

  def test_contiguous_layout_rejects_active_plan(self):
    from repro.launch.engine import ServeEngine
    cfg = dataclasses.replace(_cfg("exact"), cache_layout="contiguous")
    plan = ssh.ShardPlan(mesh=_mesh_stub(data=1, model=2), mode="heads",
                         size=2, n_kv_heads=2, n_heads=4)
    with pytest.raises(ValueError, match="paged"):
      ServeEngine(cfg, context_len=64, max_batch=2,
                  mesh=types.SimpleNamespace(shape={"data": 1, "model": 2}))
    del plan

  def test_mesh_model_one_is_unsharded(self):
    from repro.launch.engine import ServeEngine
    cfg = dataclasses.replace(_cfg("exact"), cache_layout="paged",
                              scheduler="paged")
    eng = ServeEngine(cfg, context_len=64, max_batch=2, mesh_model=1)
    assert eng.shard_plan is None
    assert eng.stats.mesh_shards == 1 and eng.stats.mesh_mode == "none"
    info = eng.mesh_info()
    assert info["mode"] == "none" and info["shards"] == 1


# ---------------------------------------------------------------------------
# the acceptance matrix: one subprocess, 8 forced host devices
# ---------------------------------------------------------------------------

_DRIVER = r'''
import dataclasses
import numpy as np
import jax

from repro.configs import get_arch
from repro.launch.engine import ServeEngine

assert len(jax.devices()) == 8, jax.devices()

PARAMS = {}

def run(policy, layout, mesh_model, heads=(4, 4), scheduler=None,
        context_len=128, prompt_capacity=None, num_blocks=None,
        prompts=None, gen=6):
  cfg = get_arch("tinyllama-1.1b", reduced=True)
  cfg = dataclasses.replace(
      cfg, cache_policy=policy, cache_layout=layout,
      scheduler=scheduler or ("tiered" if layout == "tiered" else "paged"),
      n_heads=heads[0], n_kv_heads=heads[1])
  eng = ServeEngine(cfg, context_len=context_len, max_batch=2,
                    prompt_capacity=prompt_capacity, num_blocks=num_blocks,
                    params=PARAMS.get(heads), mesh_model=mesh_model)
  PARAMS[heads] = eng.params
  prompts = prompts or [list(range(1, 20)), list(range(7, 37)),
                        list(range(3, 29))]
  hs = [eng.submit(p, max_new_tokens=gen) for p in prompts]
  while eng.has_work:
    eng.step()
  assert all(h.done and not h.failed for h in hs)
  return [h.tokens for h in hs], eng

# -- bit-identity across {exact, pq} x {paged, tiered} x mesh {1, 2, 4} ----
for policy in ("exact", "pq"):
  for layout in ("paged", "tiered"):
    ref, _ = run(policy, layout, 1)
    for m in (2, 4):
      got, eng = run(policy, layout, m)
      assert eng.shard_plan.mode == "heads", eng.shard_plan
      assert eng.shard_plan.bit_identical
      assert got == ref, (policy, layout, m, ref, got)
      acct = eng.mesh_info()["per_shard"]
      assert acct["bytes_per_shard"] < acct["total_bytes"]
      assert eng.stats.mesh_shards == m
      print(f"identity[{policy}/{layout}/x{m}]: ok "
            f"({acct['bytes_per_shard']}/{acct['total_bytes']} B per shard)")

# -- forced spill/fetch trace on the sharded tiered layout ------------------
# pool sized so two concurrent requests exhaust the device tier: the tiered
# scheduler swaps the LRU victim out (spill), fetches it back later, and the
# resumed tokens must still match the unsharded run bit-for-bit
spill_kw = dict(scheduler="tiered", context_len=64, prompt_capacity=32,
                num_blocks=5,
                prompts=[list(range(2, 30)), list(range(5, 29)),
                         list(range(11, 31)), list(range(4, 26))],
                gen=10)
ref, eng0 = run("exact", "tiered", 1, **spill_kw)
assert eng0.stats.spills > 0 and eng0.stats.fetches > 0, eng0.stats
for m in (2, 4):
  got, eng = run("exact", "tiered", m, **spill_kw)
  assert eng.stats.spills > 0 and eng.stats.fetches > 0, eng.stats
  assert got == ref, (m, ref, got)
  print(f"spill[x{m}]: ok ({eng.stats.spills} spills, "
        f"{eng.stats.fetches} fetches, tokens identical)")

# -- seq split-K fallback: 2 kv heads on a 4-way axis (exact only) ----------
# the combine is exact but reassociates floating point, so the bar is the
# PR 5 empirical one: identical greedy tokens, not bit-identical logits
ref, _ = run("exact", "paged", 1, heads=(4, 2))
got, eng = run("exact", "paged", 4, heads=(4, 2))
assert eng.shard_plan.mode == "seq" and not eng.shard_plan.bit_identical
assert got == ref, (ref, got)
print("seq[x4]: ok (tokens identical under split-K)")

print("ALL OK")
'''


def test_sharded_matrix_forced_host_devices():
  """The PR 7 acceptance matrix in one subprocess (device count is fixed at
  first jax import, so the in-process suite cannot host it)."""
  root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
  env = dict(os.environ,
             XLA_FLAGS="--xla_force_host_platform_device_count=8",
             JAX_PLATFORMS="cpu")
  env["PYTHONPATH"] = os.pathsep.join(
      [os.path.join(root, "src")]
      + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
  proc = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                        capture_output=True, text=True, timeout=1500)
  assert proc.returncode == 0, (
      f"sharded matrix driver failed\nstdout:\n{proc.stdout[-4000:]}\n"
      f"stderr:\n{proc.stderr[-4000:]}")
  assert "ALL OK" in proc.stdout
