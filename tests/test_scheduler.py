"""Pluggable schedulers: admission order, paged admit-on-available-blocks,
preempt-and-requeue under pool exhaustion, and the paged-vs-contiguous
oracle (identical traffic, token-identical output)."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch import scheduler as scheduler_lib
from repro.launch.engine import ServeEngine


def _cfg(policy="exact", dtype="float32", **kw):
  return dataclasses.replace(get_arch("tinyllama-1.1b", reduced=True),
                             cache_policy=policy, dtype_str=dtype, **kw)


def test_registry_and_protocol():
  assert scheduler_lib.names() == ("fifo", "paged", "prefix", "sjf", "slo",
                                   "tiered")
  assert scheduler_lib.make("sjf").name == "sjf"
  with pytest.raises(KeyError):
    scheduler_lib.make("priority")
  assert scheduler_lib.make("paged").preemptive
  assert not scheduler_lib.make("fifo").preemptive
  assert scheduler_lib.make("tiered").preemptive
  assert scheduler_lib.make("tiered").spills
  assert not scheduler_lib.make("paged").spills
  assert scheduler_lib.make("prefix").preemptive
  assert not scheduler_lib.make("prefix").spills
  # slo rides the tiered spill machinery, reordering admission only
  assert scheduler_lib.make("slo").preemptive
  assert scheduler_lib.make("slo").spills


def test_paged_scheduler_requires_paged_layout():
  with pytest.raises(ValueError, match="paged"):
    ServeEngine(_cfg(), context_len=64, max_batch=1, prompt_capacity=16,
                scheduler="paged")          # contiguous layout by default


def test_sjf_admits_shortest_prompt_first():
  cfg = _cfg()
  eng = ServeEngine(cfg, context_len=64, max_batch=1, prompt_capacity=32,
                    scheduler="sjf")
  long_req = eng.submit(list(range(1, 30)), max_new_tokens=2)
  short_req = eng.submit(list(range(1, 6)), max_new_tokens=2)
  done = eng.run_to_completion()
  assert [r.rid for r in done] == [short_req.rid, long_req.rid]
  assert short_req.admitted_step < long_req.admitted_step

  fifo = ServeEngine(cfg, context_len=64, max_batch=1, prompt_capacity=32,
                    params=eng.params)      # default scheduler: fifo
  a = fifo.submit(list(range(1, 30)), max_new_tokens=2)
  b = fifo.submit(list(range(1, 6)), max_new_tokens=2)
  done = fifo.run_to_completion()
  assert [r.rid for r in done] == [a.rid, b.rid]


def test_fifo_on_paged_layout_errors_on_exhaustion():
  """Non-preemptive schedulers surface pool exhaustion instead of wedging."""
  cfg = _cfg()
  eng = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                    cache_layout="paged", num_blocks=5)
  eng.submit(list(range(1, 21)), max_new_tokens=14)
  eng.submit(list(range(3, 25)), max_new_tokens=14)
  with pytest.raises(RuntimeError, match="exhausted"):
    eng.run_to_completion()


def test_submit_rejects_request_larger_than_pool():
  eng = ServeEngine(_cfg(), context_len=64, max_batch=1, prompt_capacity=32,
                    cache_layout="paged", scheduler="paged", num_blocks=2)
  with pytest.raises(ValueError, match="blocks"):
    eng.submit(list(range(1, 30)), max_new_tokens=20)   # needs 4 blocks of 16


def test_paged_preempts_requeues_and_matches_contiguous_oracle():
  """Acceptance: traffic whose combined KV footprint exceeds the block pool
  completes under paged+paged via preempt-and-requeue, token-identical to
  the contiguous run of the same trace."""
  cfg = _cfg()
  oracle = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32)
  paged = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                      params=oracle.params, cache_layout="paged",
                      scheduler="paged", num_blocks=5)
  # each request peaks at 3 blocks (34 tokens); together 6 > pool of 5
  trace = [(list(range(1, 21)), 14), (list(range(3, 25)), 14)]
  want = [oracle.submit(p, max_new_tokens=m) for p, m in trace]
  got = [paged.submit(p, max_new_tokens=m) for p, m in trace]
  oracle.run_to_completion()
  paged.run_to_completion()

  assert paged.stats.preempts >= 1          # pool pressure actually hit
  assert sum(r.preempt_count for r in got) == paged.stats.preempts
  for w, g in zip(want, got):
    assert g.done and g.tokens == w.tokens, g.rid
  paged.layout.manager.check_invariants()
  assert paged.layout.free_blocks == paged.layout.num_blocks


def test_paged_oracle_random_traffic(rng):
  """Randomized admit/preempt traffic: paged engine under a tight pool stays
  token-identical to contiguous for every request, with no block leaks."""
  cfg = _cfg()
  oracle = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32)
  paged = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                      params=oracle.params, cache_layout="paged",
                      scheduler="paged", num_blocks=6)
  pairs = []
  for _ in range(5):
    plen = int(rng.integers(4, 30))
    gen = int(rng.integers(2, min(14, 64 - plen)))
    prompt = rng.integers(1, cfg.vocab_size, size=plen).tolist()
    pairs.append((oracle.submit(prompt, max_new_tokens=gen),
                  paged.submit(prompt, max_new_tokens=gen)))
  oracle.run_to_completion()
  paged.run_to_completion()
  for w, g in zip(*map(list, zip(*pairs))):
    assert g.tokens == w.tokens, (w.rid, w.tokens, g.tokens)
  paged.layout.manager.check_invariants()
  assert paged.layout.free_blocks == paged.layout.num_blocks


def test_engine_stats_track_occupancy_and_waste():
  eng = ServeEngine(_cfg(), context_len=64, max_batch=2, prompt_capacity=32)
  eng.submit(list(range(1, 10)), max_new_tokens=5)   # one request, two lanes
  eng.run_to_completion()
  s = eng.stats
  assert s.admits == 1 and s.finished == 1 and s.preempts == 0
  assert s.decode_steps == 4                          # first token from prefill
  assert s.busy_slot_steps == 4 and s.wasted_slot_steps == 4
  assert s.occupancy == pytest.approx(0.5)
  assert s.as_dict()["occupancy"] == pytest.approx(0.5)
  assert "occupancy" in s.summary()


def test_streaming_ring_reuse_bounds_pool_and_matches_contiguous():
  """StreamingLLM under paging: blocks aging out of the window are reclaimed
  (ring-reuse), bounding resident blocks, with output identical to the
  contiguous run."""
  cfg = _cfg("streamingllm", stream_window=32)
  oracle = ServeEngine(cfg, context_len=128, max_batch=1, prompt_capacity=64)
  # pool of 5 < the 7 blocks a contiguous 109-token slab would need: only
  # ring-reuse makes this request admissible (fits() accounts for reclaim)
  paged = ServeEngine(cfg, context_len=128, max_batch=1, prompt_capacity=64,
                      params=oracle.params, cache_layout="paged",
                      scheduler="paged", num_blocks=5)
  w = oracle.submit(list(range(1, 50)), max_new_tokens=60)
  g = paged.submit(list(range(1, 50)), max_new_tokens=60)
  oracle.run_to_completion()
  paged.run_to_completion()
  assert g.tokens == w.tokens
  assert paged.stats.blocks_reclaimed > 0
  # ring-reuse keeps the peak well under the 108 tokens / 7 blocks a
  # contiguous slab would pin (sink 4 + window 32 + slack -> 4 blocks of 16)
  assert paged.layout.manager.peak_allocated <= 4
