"""Workload harness + async engine loop: seeded arrival processes, tenant
mixes, the virtual-clock driver, SLO report math, overlap-vs-serialized
token identity, IN_FLIGHT-never-decoded, and fault-injected fetch retries."""
import dataclasses
import json

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import tiers
from repro.launch import slo as slo_lib
from repro.launch import workload as wl
from repro.launch.engine import ServeEngine
from repro.runtime.fault_tolerance import FetchFaultInjector

from test_tiers import _pool_drained


def _cfg(policy="exact", dtype="float32", **kw):
  return dataclasses.replace(get_arch("tinyllama-1.1b", reduced=True),
                             cache_policy=policy, dtype_str=dtype, **kw)


# Pressure sizings known to force spills under the tiered pool (mirrors
# benchmarks/run.py::run_workload; pq needs prompt_capacity >= sink+recent
# and longer prompts because its streaming window retires blocks).
_SIZING = {
    "exact": dict(context_len=64, prompt_capacity=32, num_blocks=5,
                  host_blocks=24, prompt_len=(20, 30), gen=(10, 16)),
    "pq": dict(context_len=96, prompt_capacity=64, num_blocks=7,
               host_blocks=32, prompt_len=(42, 58), gen=(12, 24)),
}


def _spec(policy, arrival="poisson", n=8, seed=3, **kw):
  sz = _SIZING[policy]
  tenant = wl.TenantSpec(prompt_len=sz["prompt_len"],
                         max_new_tokens=sz["gen"])
  return wl.WorkloadSpec(arrival=arrival, rate=400.0, burstiness=6.0,
                         n_requests=n, seed=seed, tenants=(tenant,), **kw)


def _tiered(policy, params=None, clock=None, dtype=None, **kw):
  sz = _SIZING[policy]
  cfg = _cfg(policy, dtype=dtype or ("bfloat16" if policy == "pq"
                                     else "float32"))
  eng = ServeEngine(cfg, context_len=sz["context_len"], max_batch=2,
                    prompt_capacity=sz["prompt_capacity"], params=params,
                    cache_layout="tiered", scheduler="tiered",
                    num_blocks=sz["num_blocks"],
                    host_blocks=sz["host_blocks"], clock=clock, **kw)
  # slow the modeled link so transfer time is visible against the decode
  # budget (reduced-config payloads drain in microseconds at 16 GB/s)
  eng.layout.ledger.pcie_gbps = 0.002
  return eng


def _paged(policy, params=None, clock=None):
  sz = _SIZING[policy]
  cfg = _cfg(policy, dtype="bfloat16" if policy == "pq" else "float32")
  return ServeEngine(cfg, context_len=sz["context_len"], max_batch=2,
                     prompt_capacity=sz["prompt_capacity"], params=params,
                     cache_layout="paged", scheduler="paged",
                     num_blocks=2 * (sz["context_len"] // 16), clock=clock)


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

def test_arrival_registry_and_determinism():
  assert set(wl.arrival_names()) >= {"poisson", "bursty", "trace"}
  with pytest.raises(KeyError):
    wl.get_arrival("nope")
  spec = wl.WorkloadSpec(n_requests=512, rate=50.0, seed=7)
  a1 = wl.poisson_arrivals(spec, np.random.default_rng(7))
  a2 = wl.poisson_arrivals(spec, np.random.default_rng(7))
  a3 = wl.poisson_arrivals(spec, np.random.default_rng(8))
  np.testing.assert_array_equal(a1, a2)
  assert not np.array_equal(a1, a3)
  assert np.all(np.diff(a1) >= 0)       # cumulative times are monotone


def test_bursty_same_mean_higher_variance():
  spec = wl.WorkloadSpec(n_requests=4096, rate=50.0, burstiness=6.0, seed=1)
  pois = np.diff(wl.poisson_arrivals(spec, np.random.default_rng(1)),
                 prepend=0.0)
  burst = np.diff(wl.bursty_arrivals(spec, np.random.default_rng(1)),
                  prepend=0.0)
  assert burst.mean() == pytest.approx(1.0 / 50.0, rel=0.1)
  assert pois.mean() == pytest.approx(1.0 / 50.0, rel=0.1)
  # cv^2 = burstiness for Gamma gaps vs 1 for exponential
  cv2 = burst.var() / burst.mean() ** 2
  assert cv2 > 3.0, cv2
  with pytest.raises(ValueError):
    wl.bursty_arrivals(dataclasses.replace(spec, burstiness=0.0),
                       np.random.default_rng(0))


def test_trace_replay_with_overrides(tmp_path):
  trace = [
      {"t": 0.5, "tenant": "b", "prompt_len": 9, "max_new_tokens": 3},
      {"t": 0.0, "prompt": [5, 6, 7], "prompt_len": 3, "max_new_tokens": 2},
      {"t": 1.25},
  ]
  path = tmp_path / "trace.json"
  path.write_text(json.dumps({"events": trace}))
  spec = wl.WorkloadSpec(
      arrival="trace", trace_path=str(path), seed=0,
      tenants=(wl.TenantSpec(name="a", prompt_len=(4, 6)),
               wl.TenantSpec(name="b", prompt_len=(4, 6))))
  reqs = wl.generate(spec, vocab_size=100, max_prompt_len=32,
                     max_total_len=64)
  assert [r.arrival_s for r in reqs] == [0.0, 0.5, 1.25]
  assert reqs[0].tokens == (5, 6, 7) and reqs[0].max_new_tokens == 2
  assert reqs[1].tenant == "b" and reqs[1].prompt_len == 9
  assert reqs[1].max_new_tokens == 3
  assert 4 <= reqs[2].prompt_len <= 6    # unfixed fields stay sampled
  with pytest.raises(ValueError):
    wl.load_trace(None)
  bad = tmp_path / "bad.json"
  bad.write_text(json.dumps([{"t": -1.0}]))
  with pytest.raises(ValueError):
    wl.load_trace(str(bad))


def test_generate_validation_and_clamps():
  with pytest.raises(ValueError):
    wl.generate(wl.WorkloadSpec(n_requests=0), vocab_size=10,
                max_prompt_len=8, max_total_len=16)
  with pytest.raises(ValueError):
    wl.generate(wl.WorkloadSpec(rate=0.0), vocab_size=10,
                max_prompt_len=8, max_total_len=16)
  with pytest.raises(ValueError):
    wl.generate(wl.WorkloadSpec(tenants=()), vocab_size=10,
                max_prompt_len=8, max_total_len=16)
  spec = wl.WorkloadSpec(
      n_requests=32, seed=2,
      tenants=(wl.TenantSpec(prompt_len=(50, 90),
                             max_new_tokens=(30, 60)),))
  reqs = wl.generate(spec, vocab_size=100, max_prompt_len=24,
                     max_total_len=32)
  for r in reqs:
    assert r.prompt_len <= 24
    assert r.prompt_len + r.max_new_tokens < 32   # total fits the context


def test_multitenant_shared_prefix_and_determinism():
  tenants = (wl.TenantSpec(name="shared", weight=2.0, prompt_len=(12, 20),
                           shared_prefix_len=8),
             wl.TenantSpec(name="cold", weight=1.0, prompt_len=(12, 20)))
  spec = wl.WorkloadSpec(n_requests=48, seed=5, tenants=tenants)
  reqs = wl.generate(spec, vocab_size=500, max_prompt_len=32,
                     max_total_len=64)
  again = wl.generate(spec, vocab_size=500, max_prompt_len=32,
                      max_total_len=64)
  assert reqs == again                    # (spec, seed) IS the workload
  shared = [r for r in reqs if r.tenant == "shared"]
  cold = [r for r in reqs if r.tenant == "cold"]
  assert shared and cold                  # both tenants actually sampled
  prefix = shared[0].tokens[:8]
  assert all(r.tokens[:8] == prefix for r in shared)
  assert not all(r.tokens[:8] == prefix for r in cold)
  # weighted mix: the weight-2 tenant dominates
  assert len(shared) > len(cold)


# ---------------------------------------------------------------------------
# virtual clock
# ---------------------------------------------------------------------------

def test_virtual_clock_overlap_vs_serialized_accounting():
  ovl = wl.VirtualClock(overlap=True)
  ready = ovl.start_transfer(0.1)
  assert ready == pytest.approx(0.1)
  assert ovl.now == 0.0                   # overlapped: deadline, no stall
  ovl.advance(0.04)
  ovl.stall_until(ready)                  # data needed now -> partial stall
  assert ovl.now == pytest.approx(0.1)
  assert ovl.transfer_stall_s == pytest.approx(0.06)
  assert ovl.compute_s == pytest.approx(0.04)
  # the link is serial: a second transfer queues behind the first
  assert ovl.start_transfer(0.2) == pytest.approx(0.3)
  assert ovl.link_busy_s == pytest.approx(0.3)

  ser = wl.VirtualClock(overlap=False)
  ser.start_transfer(0.1)
  assert ser.now == pytest.approx(0.1)    # serialized: stalls on the spot
  assert ser.transfer_stall_s == pytest.approx(0.1)
  ser.idle_until(0.5)
  assert ser.idle_s == pytest.approx(0.4)
  with pytest.raises(ValueError):
    ser.advance(-1.0)
  with pytest.raises(ValueError):
    ser.start_transfer(-1.0)
  assert json.dumps(ser.as_dict())        # record-family serializable


# ---------------------------------------------------------------------------
# SLO report math
# ---------------------------------------------------------------------------

def test_slo_report_math():
  slo = slo_lib.SLOSpec(ttft_s=0.5, tpot_s=0.05)
  assert slo.deadline_s(1.0, 10) == pytest.approx(2.0)
  good = slo_lib.RequestTiming(rid=0, tenant="a", arrival_s=0.0,
                               deadline_s=1.0, max_new_tokens=4, n_tokens=5,
                               admit_s=0.1, first_token_s=0.2, finish_s=0.9)
  late = slo_lib.RequestTiming(rid=1, tenant="a", arrival_s=0.0,
                               deadline_s=1.0, max_new_tokens=4, n_tokens=5,
                               admit_s=0.3, first_token_s=0.6, finish_s=1.5)
  dead = slo_lib.RequestTiming(rid=2, tenant="b", arrival_s=0.0,
                               deadline_s=1.0, max_new_tokens=4, n_tokens=2,
                               admit_s=0.1, first_token_s=0.2, finish_s=0.5,
                               failed=True)
  assert good.ttft_s == pytest.approx(0.2)
  assert good.tpot_s == pytest.approx((0.9 - 0.2) / 4)
  assert good.queue_s == pytest.approx(0.1)
  assert good.met_deadline and good.good_tokens == 5
  assert not late.met_deadline and late.good_tokens == 0
  assert not dead.met_deadline            # failed can never meet deadline
  one_tok = slo_lib.RequestTiming(rid=3, tenant="a", arrival_s=0.0,
                                  deadline_s=1.0, max_new_tokens=1,
                                  n_tokens=1, first_token_s=0.2,
                                  finish_s=0.2)
  assert one_tok.tpot_s is None           # undefined for 1-token runs

  rep = slo_lib.build_report([good, late, dead])
  assert rep["requests"] == 3 and rep["failed"] == 1
  assert rep["tokens_total"] == 12 and rep["tokens_within_deadline"] == 5
  assert rep["goodput_frac"] == pytest.approx(5 / 12, abs=1e-4)
  assert rep["deadline_met_frac"] == pytest.approx(1 / 3, abs=1e-4)
  assert rep["ttft"]["n"] == 3 and rep["ttft"]["p50_s"] is not None
  assert set(rep["per_tenant"]) == {"a", "b"}
  assert rep["per_tenant"]["b"]["goodput_frac"] == 0.0
  assert "stall" not in rep               # no clock given

  clock = wl.VirtualClock(now=2.0, compute_s=1.5, transfer_stall_s=0.3,
                          idle_s=0.2)
  rep2 = slo_lib.build_report([good], clock)
  assert rep2["goodput_tok_s"] == pytest.approx(5 / 2.0)
  assert rep2["stall"]["transfer_stall_frac"] == pytest.approx(0.15)
  assert slo_lib.percentiles_s([]) == dict(n=0, p50_s=None, p99_s=None,
                                           mean_s=None)
  assert "goodput" in slo_lib.summary(rep)
  assert json.dumps(rep2)                 # record-family serializable


# ---------------------------------------------------------------------------
# driver end-to-end
# ---------------------------------------------------------------------------

def test_driver_requires_clock():
  eng = ServeEngine(_cfg(), context_len=64, max_batch=1, prompt_capacity=16)
  with pytest.raises(ValueError):
    wl.WorkloadDriver(eng, _spec("exact"))


def test_driver_end_to_end_deterministic():
  spec = _spec("exact", arrival="bursty", n=8)
  base = _tiered("exact", clock=wl.VirtualClock())
  res1 = wl.WorkloadDriver(base, spec).run()
  eng2 = _tiered("exact", params=base.params, clock=wl.VirtualClock())
  res2 = wl.WorkloadDriver(eng2, spec).run()
  assert res1.report["requests"] == 8 and res1.report["failed"] == 0
  assert res1.report["goodput_frac"] > 0
  assert res1.report["ttft"]["p99_s"] is not None
  assert res1.report["tpot"]["p99_s"] is not None
  assert base.stats.spills >= 1           # pressure config actually spilled
  assert res1.report == res2.report       # same seed -> identical report
  assert res1.token_streams == res2.token_streams
  _pool_drained(base.layout)
  _pool_drained(eng2.layout)


# ---------------------------------------------------------------------------
# overlap on/off token identity (the tentpole acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["paged", "tiered"])
@pytest.mark.parametrize("policy", ["exact", "pq"])
def test_overlap_matches_serialized_and_wallclock_oracle(layout, policy):
  """Greedy tokens are bit-identical with the async spill/fetch stage on
  (overlap=True), off (serialized fallback), and absent (wall-clock
  engine fed the same trace) — for both layouts and both cache policies."""
  n = 6
  spec = _spec(policy, arrival="poisson", n=n)
  build = _tiered if layout == "tiered" else _paged
  base = build(policy, clock=wl.VirtualClock(overlap=True))
  res_o = wl.WorkloadDriver(base, spec).run()
  ser = build(policy, params=base.params,
              clock=wl.VirtualClock(overlap=False))
  res_s = wl.WorkloadDriver(ser, spec).run()
  assert res_o.token_streams == res_s.token_streams
  assert len(res_o.token_streams) == n

  # wall-clock oracle: same generated requests, submitted upfront
  oracle = build(policy, params=base.params)
  reqs = wl.generate(spec, vocab_size=base.cfg.vocab_size,
                     max_prompt_len=base.prompt_capacity,
                     max_total_len=base.context_len)
  handles = {w.index: oracle.submit(list(w.tokens),
                                    max_new_tokens=w.max_new_tokens)
             for w in reqs}
  oracle.run_to_completion()
  assert {i: tuple(h.tokens) for i, h in handles.items()} \
      == res_o.token_streams

  if layout == "tiered":
    assert base.stats.spills >= 1, "trace never exercised the spill path"
    # overlap hides transfer time the serialized fallback eats as stall
    assert base.clock.transfer_stall_s <= ser.clock.transfer_stall_s
    _pool_drained(base.layout)
    _pool_drained(ser.layout)


def test_overlap_reduces_transfer_stall():
  """On a spill-heavy bursty trace the double-buffered fetch stage must
  strictly beat the serialized fallback's transfer-stall attribution."""
  spec = _spec("exact", arrival="bursty", n=10)
  base = _tiered("exact", clock=wl.VirtualClock(overlap=True))
  res_o = wl.WorkloadDriver(base, spec).run()
  ser = _tiered("exact", params=base.params,
                clock=wl.VirtualClock(overlap=False))
  res_s = wl.WorkloadDriver(ser, spec).run()
  assert res_o.token_streams == res_s.token_streams
  assert base.stats.spills >= 1 and base.stats.prefetches >= 1
  assert ser.clock.transfer_stall_s > 0
  assert base.clock.transfer_stall_s < ser.clock.transfer_stall_s
  ratio = base.clock.transfer_stall_s / ser.clock.transfer_stall_s
  assert ratio < 1.0, ratio


def test_in_flight_blocks_never_decoded():
  """Step the overlapped engine by hand under randomized spill traffic: a
  rid with an IN_FLIGHT transfer is never in an active slot, and active
  slots' tiered records are never IN_FLIGHT (decode additionally asserts
  BLOCK_RESIDENT on every gathered block inside the layout)."""
  spec = _spec("exact", arrival="bursty", n=10, seed=11)
  eng = _tiered("exact", clock=wl.VirtualClock(overlap=True))
  reqs = wl.generate(spec, vocab_size=eng.cfg.vocab_size,
                     max_prompt_len=eng.prompt_capacity,
                     max_total_len=eng.context_len)
  for w in reqs:
    eng.submit(list(w.tokens), max_new_tokens=w.max_new_tokens)
  saw_in_flight = False
  for _ in range(10_000):
    if not eng.has_work:
      break
    eng.step()
    active = {req.rid for _, req in eng.active_requests}
    in_flight = set(eng.transfers_in_flight)
    saw_in_flight = saw_in_flight or bool(in_flight)
    assert not (active & in_flight), (active, in_flight)
    for rid in active:
      rec = eng.layout.records.get(rid)
      assert rec is None or rec.state != tiers.BLOCK_IN_FLIGHT, rid
  assert not eng.has_work
  assert saw_in_flight, "no transfer was ever in flight — test is vacuous"
  _pool_drained(eng.layout)


# ---------------------------------------------------------------------------
# fault injection: bounded retries, drops, no leaks
# ---------------------------------------------------------------------------

def test_fetch_fault_injector_determinism():
  inj = FetchFaultInjector(fail_rate=0.5, seed=3)
  fates = [True, True]
  for i, _ in enumerate(fates):
    try:
      inj.check_fetch(rid=7, attempt=i)
      fates[i] = False
    except Exception:
      pass
  inj2 = FetchFaultInjector(fail_rate=0.5, seed=3)
  for i, want in enumerate(fates):        # (seed, rid, attempt) keyed draw
    try:
      inj2.check_fetch(rid=7, attempt=i)
      assert not want
    except Exception:
      assert want
  none = FetchFaultInjector(fail_rate=0.0, seed=3)
  none.check_fetch(rid=7, attempt=0)      # never raises at rate 0


def test_fault_injected_retries_keep_tokens_identical():
  """Transient fetch faults requeue the request (bounded retries); every
  surviving request's greedy tokens match the fault-free run."""
  spec = _spec("exact", arrival="bursty", n=10)
  clean = _tiered("exact", clock=wl.VirtualClock())
  res_clean = wl.WorkloadDriver(clean, spec).run()
  faulty = _tiered("exact", params=clean.params, clock=wl.VirtualClock(),
                   fault_injector=FetchFaultInjector(fail_rate=0.3, seed=5))
  res_fault = wl.WorkloadDriver(faulty, spec).run()
  assert faulty.stats.fetch_failures >= 1, "fault injection never fired"
  for idx, toks in res_fault.token_streams.items():
    if idx in res_fault.failed_indices:
      continue
    assert toks == res_clean.token_streams[idx], idx
  assert res_fault.report["failed"] == len(res_fault.failed_indices)
  _pool_drained(faulty.layout)


def test_fetch_retry_exhaustion_drops_request_cleanly():
  """At fail_rate=1.0 every fetch attempt fails: spilled requests exhaust
  max_fetch_retries, are dropped as failed (host blocks reclaimed), and
  the rest of the workload still completes with a clean pool."""
  spec = _spec("exact", arrival="bursty", n=10)
  eng = _tiered("exact", clock=wl.VirtualClock(),
                fault_injector=FetchFaultInjector(fail_rate=1.0, seed=0),
                max_fetch_retries=2)
  res = wl.WorkloadDriver(eng, spec).run()
  assert eng.stats.spills >= 1
  assert res.report["failed"] >= 1
  assert eng.stats.failed_requests == res.report["failed"]
  assert eng.stats.fetch_failures >= 3    # retries actually happened
  assert eng.stats.fetch_aborts == eng.layout.ledger.fetch_aborts
  done = [i for i in res.token_streams if i not in res.failed_indices]
  assert done, "every request failed — workload sizing regressed"
  assert res.report["goodput_frac"] >= 0.0
  _pool_drained(eng.layout)               # dropped requests leak nothing


# ---------------------------------------------------------------------------
# stats snapshot + queue gauges
# ---------------------------------------------------------------------------

def test_stats_as_dict_snapshots_without_mutating():
  spec = _spec("exact", n=6)
  eng = _tiered("exact", clock=wl.VirtualClock())
  wl.WorkloadDriver(eng, spec).run()
  before_depth = list(eng.stats.queue_depth_samples)
  before_wait = list(eng.stats.queue_wait_steps)
  d1 = eng.stats.as_dict()
  d2 = eng.stats.as_dict()
  assert d1 == d2                         # snapshot, not drain
  assert list(eng.stats.queue_depth_samples) == before_depth
  assert list(eng.stats.queue_wait_steps) == before_wait
  assert json.dumps(d1)                   # deques excluded -> serializable
  q = d1["queue"]
  assert q["depth_samples"] == len(before_depth) > 0
  assert q["depth_max"] >= q["depth_mean"] >= 0
  assert q["wait_steps_max"] >= q["wait_steps_mean"] >= 0
  assert d1["virtual_s"] == pytest.approx(eng.clock.now)
  assert d1["compute_s"] == pytest.approx(eng.clock.compute_s)
  gauges = eng.stats.queue_gauges()
  assert gauges["depth_now"] == 0         # drained
