"""Validate the analytic roofline cost model against compiled HLO.

Compiled cost_analysis counts while-loop bodies once, so validation uses
UNROLLED layers and single-block attention on small configs (loop-free HLO),
on a single device (cost_analysis reports per-partition numbers).
Families with sequential-scan recurrences (rwkv/ssm) cannot be made loop-free
and are excluded here; their per-token recurrence flops are hand-derived in
cost_model and covered indirectly by the dense/hybrid linear parts.
"""
import dataclasses

import jax
import pytest

from benchmarks import cost_model
from repro.common import compat
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.launch import steps as steps_lib


def _compiled_flops(cfg, shape):
  mesh = jax.make_mesh((1, 1), ("data", "model"))
  with mesh:
    progs = steps_lib.build_programs(cfg, shape, mesh, donate=False)
    compiled = progs.fn.lower(*progs.abstract_inputs).compile()
    ca = compat.normalize_cost_analysis(compiled.cost_analysis())
    return float(ca.get("flops", 0.0))


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b",
                                  "llama-3.2-vision-11b"])
def test_train_flops_within_band(arch):
  cfg = dataclasses.replace(
      get_arch(arch, reduced=True),
      n_layers=2, unroll_layers=True, remat=False, attn_block=128,
      microbatches=1,   # microbatch scan bodies are cost-counted once
      cross_attn_period=2 if arch == "llama-3.2-vision-11b" else 0)
  if arch == "llama-3.2-vision-11b":
    cfg = dataclasses.replace(cfg, cross_attn_period=2)
  shape = ShapeConfig("t", 128, 8, "train")
  compiled = _compiled_flops(cfg, shape)
  analytic = cost_model.train_step_flops(cfg, 8, 128)
  ratio = analytic / compiled
  assert 0.4 < ratio < 1.5, (arch, compiled, analytic, ratio)


@pytest.mark.parametrize("arch,pq", [("tinyllama-1.1b", True),
                                     ("tinyllama-1.1b", False)])
def test_decode_flops_within_band(arch, pq):
  cfg = dataclasses.replace(
      get_arch(arch, reduced=True),
      n_layers=2, unroll_layers=True, pq_enabled=pq)
  shape = ShapeConfig("d", 256, 4, "decode")
  compiled = _compiled_flops(cfg, shape)
  analytic = cost_model.decode_step_flops(cfg, 4, 256)
  ratio = analytic / compiled
  # tiny reduced dims: fixed overheads dominate -> wide band
  assert 0.3 < ratio < 2.0, (pq, compiled, analytic, ratio)


def test_pq_reduces_decode_memory_term():
  """The paper's headline on our cost model: PQ cuts decode HBM bytes."""
  cfg = get_arch("llama3-405b")
  exact = cost_model.kv_cache_bytes(
      dataclasses.replace(cfg, pq_enabled=False), 128, 32768)
  pq = cost_model.kv_cache_bytes(cfg, 128, 32768)
  assert exact / pq > 3.0, exact / pq
  # uint8 variant (K=256) doubles the reduction
  pq8 = cost_model.kv_cache_bytes(
      dataclasses.replace(cfg, pq_k=256), 128, 32768)
  assert exact / pq8 > 6.0, exact / pq8


def test_int8_weights_halve_param_bytes():
  cfg = get_arch("llama3-405b")
  b_bf16 = cost_model.param_bytes(cfg)
  b_int8 = cost_model.param_bytes(
      dataclasses.replace(cfg, weight_quant="int8"))
  assert 1.8 < b_bf16 / b_int8 < 2.1


def test_parallel_block_halves_tp_collectives():
  # dense arch: pblock halves the TP ARs.  (EP-MoE layers have no MLP-region
  # AR to begin with, so pblock is a no-op there — also asserted.)
  cfg = get_arch("yi-34b")
  base = cost_model.train_collective_bytes(cfg, 256, 4096, 16, 16)
  opt = cost_model.train_collective_bytes(
      dataclasses.replace(cfg, parallel_block=True), 256, 4096, 16, 16)
  assert opt < base
  moe = get_arch("phi3.5-moe-42b-a6.6b")
  m_base = cost_model.train_collective_bytes(moe, 256, 4096, 16, 16)
  m_opt = cost_model.train_collective_bytes(
      dataclasses.replace(moe, parallel_block=True), 256, 4096, 16, 16)
  assert m_opt == m_base


def test_moe_a2a_quant_reduces_collectives():
  cfg = get_arch("phi3.5-moe-42b-a6.6b")
  base = cost_model.train_collective_bytes(cfg, 256, 4096, 16, 16)
  opt = cost_model.train_collective_bytes(
      dataclasses.replace(cfg, moe_a2a_quant=True), 256, 4096, 16, 16)
  assert opt < base


def test_context_parallel_cuts_prefill_collectives():
  cfg = get_arch("tinyllama-1.1b")
  base = cost_model.prefill_collective_bytes(cfg, 32, 32768, 16, 16)
  opt = cost_model.prefill_collective_bytes(
      dataclasses.replace(cfg, context_parallel=True), 32, 32768, 16, 16)
  assert opt < base / 4
