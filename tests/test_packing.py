"""Sub-byte packed KV (kernels/packing.py + the packed exact policy):
bit-unpack roundtrip invariants, quantization error bounds (incl. the
worst-case dynamic-range and constant-group degenerate paths), the Pallas
unpack primitive vs the jnp reference, paged-kernel vs XLA decode parity,
and the resident-q4 footprint/error acceptance numbers."""
import dataclasses

try:
  from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback shim
  from hypothesis_compat import given, settings, strategies as st

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import cache_api, cache_registry, tiers
from repro.core import kv_cache as kvc
from repro.core import pq_attention
from repro.kernels import packing
from repro.launch.engine import ServeEngine

#: f16 relative rounding slack: scale/min are stored f16, so reconstruction
#: error exceeds the ideal half-step by at most ~2^-11 of the group magnitude.
F16_EPS = 2 ** -11


def _spec(**kw):
  kw.setdefault("capacity", 64)
  kw.setdefault("head_dim", 16)
  kw.setdefault("window", 64)
  return cache_api.CacheSpec(**kw)


# ---------------------------------------------------------------------------
# Bit pack/unpack: exact inverses
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), d=st.sampled_from([2, 8, 16, 64, 128]),
       n=st.integers(1, 17))
def test_pack_unpack_u4_roundtrip_exact(seed, d, n):
  rng = np.random.default_rng(seed)
  q = jnp.asarray(rng.integers(0, 16, size=(n, d)), jnp.uint8)
  p = packing.pack_u4(q)
  assert p.shape == (n, d // 2) and p.dtype == jnp.uint8
  back = packing.unpack_u4(p)
  assert back.dtype == jnp.int32
  np.testing.assert_array_equal(np.asarray(back), np.asarray(q, np.int32))


def test_pack_u4_is_split_half_not_interleaved():
  # byte j must carry code j (low nibble) and code j + d/2 (high nibble):
  # the layout that makes unpack a single concat, no gather
  q = jnp.asarray([[1, 2, 3, 4, 5, 6, 7, 8]], jnp.uint8)
  p = np.asarray(packing.pack_u4(q))
  np.testing.assert_array_equal(
      p[0], [1 | (5 << 4), 2 | (6 << 4), 3 | (7 << 4), 4 | (8 << 4)])


def test_unpack_u4_kernel_matches_reference(rng):
  p = jnp.asarray(rng.integers(0, 256, size=(24, 8)), jnp.uint8)
  got = packing.unpack_u4_kernel(p, interpret=True)
  assert got.shape == (24, 16) and got.dtype == jnp.int32
  np.testing.assert_array_equal(np.asarray(got),
                                np.asarray(packing.unpack_u4(p)))


# ---------------------------------------------------------------------------
# Quantize/dequantize: error bounds
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), bits=st.sampled_from([4, 8]),
       d=st.sampled_from([8, 16, 32, 64]),
       mag=st.sampled_from([1e-3, 1.0, 3.0, 1e3]))
def test_quantize_roundtrip_error_half_step(seed, bits, d, mag):
  """|x - dequant(quant(x))| <= scale/2 per group (+ f16 header rounding),
  across magnitudes from sub-f16-step to 1e3 and with negative values."""
  rng = np.random.default_rng(seed)
  group = packing.group_size(d)
  x = jnp.asarray(rng.normal(scale=mag, size=(3, d)), jnp.float32)
  q, scale, mn = packing.quantize_rows(x, bits=bits, group=group)
  assert q.dtype == jnp.uint8 and scale.dtype == jnp.float16
  assert int(q.max()) <= (1 << bits) - 1
  back = packing.dequantize_rows(q, scale, mn, group=group)
  err = np.abs(np.asarray(back) - np.asarray(x)).reshape(3, d // group, group)
  absmax = np.abs(np.asarray(x)).reshape(3, d // group, group).max(-1)
  step = np.asarray(scale, np.float32)
  # half a step, plus the f16 rounding of scale (amplified by up to qmax
  # codes) and of min
  tol = 0.5 * step + F16_EPS * (step * ((1 << bits) - 1) + absmax) + 1e-12
  assert (err.max(-1) <= tol).all(), (err.max(), tol.min())


def test_quantize_constant_group_degrades_to_min(rng):
  # zero range -> f16 scale 0 -> codes 0, dequant returns the f16 minimum
  x = jnp.full((2, 16), 0.7183, jnp.float32)
  q, scale, mn = packing.quantize_rows(x, bits=4, group=16)
  assert int(np.asarray(q).max()) == 0
  assert float(np.abs(np.asarray(scale, np.float32)).max()) == 0.0
  back = np.asarray(packing.dequantize_rows(q, scale, mn, group=16))
  assert np.abs(back - 0.7183).max() <= 0.7183 * F16_EPS


def test_quantize_worst_case_dynamic_range_stays_finite():
  """One huge outlier per group (the case that breaks symmetric quant):
  params stay finite f16, small values collapse toward min but the big one
  survives within half a (now huge) step."""
  x = np.full((1, 32), 1e-4, np.float32)
  x[0, 7] = 6.0e4          # near f16 max; range/15 and min still fit f16
  x[0, 19] = -6.0e4
  q, scale, mn = packing.quantize_rows(jnp.asarray(x), bits=4, group=32)
  assert np.isfinite(np.asarray(scale, np.float32)).all()
  assert np.isfinite(np.asarray(mn, np.float32)).all()
  back = np.asarray(packing.dequantize_rows(q, scale, mn, group=32))
  assert np.isfinite(back).all()
  step = float(np.asarray(scale, np.float32)[0, 0])
  assert np.abs(back - x).max() <= 0.5 * step + F16_EPS * (15 * step + 6e4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), bits=st.sampled_from([4, 8]))
def test_dequant_page_equals_unpack_then_dequant(seed, bits):
  """The one shared formula: dequant_page == dequantize_rows over the
  unpacked codes, bit for bit (this identity is why kernel and XLA paths
  reconstruct identical K/V)."""
  rng = np.random.default_rng(seed)
  x = jnp.asarray(rng.normal(size=(2, 3, 32)), jnp.float32)
  pack, scale, mn = packing.pack_rows(x, bits=bits, group=32)
  assert pack.shape[-1] == packing.packed_width(32, bits)
  via_page = packing.dequant_page(pack, scale, mn, bits=bits, group=32)
  codes = packing.unpack_u4(pack) if bits == 4 else pack
  via_rows = packing.dequantize_rows(codes, scale, mn, group=32)
  np.testing.assert_array_equal(np.asarray(via_page), np.asarray(via_rows))


# ---------------------------------------------------------------------------
# Packed exact cache: paged kernel vs dense XLA parity, error bound
# ---------------------------------------------------------------------------

def test_packed_paged_kernel_matches_dense_xla_attend(rng):
  """Same packed rows through the block-native Pallas(-interpret) kernel and
  the dense masked-XLA attend: outputs agree to float tolerance (tokens are
  therefore identical downstream)."""
  b, h, d, block, bits = 2, 2, 16, 8, 4
  n_blocks, capacity = 3, 24
  lengths = jnp.asarray([13, 7], jnp.int32)
  k = jnp.asarray(rng.normal(size=(b, h, capacity, d)), jnp.float32)
  v = jnp.asarray(rng.normal(size=(b, h, capacity, d)), jnp.float32)
  q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
  k_new = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
  v_new = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
  scale = d ** -0.5

  cache = kvc.packed_exact_cache_prefill(k, v, capacity, bits)
  want, _ = kvc.packed_exact_cache_append_and_attend(
      cache, q, k_new, v_new, lengths, scale, bits, use_kernel=False)

  # scatter the same dense store into pool blocks (pool id 0 = null block)
  tables = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
  pools = []
  for leaf in cache:
    width = leaf.shape[-1]
    pool = jnp.zeros((b * n_blocks + 1, 1, h, block, width), leaf.dtype)
    rows = leaf.reshape(b, h, n_blocks, block, width)
    for i in range(b):
      for j in range(n_blocks):
        pool = pool.at[int(tables[i, j]), 0].set(rows[i, :, j])
    pools.append(pool)
  got, _ = kvc.packed_exact_cache_paged_step(
      pools, jnp.asarray(0, jnp.int32), tables, q, k_new, v_new,
      lengths, scale, bits, interpret=True)
  np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                             atol=1e-5, rtol=1e-5)

  # max-abs-error vs the *unquantized* fp32 oracle on the same paged trace:
  # quantization noise is bounded and shrinks 16x from q4 to q8 (half-step
  # scales with 1/(2^bits - 1)); measured ~0.084 / ~0.005 on this seed
  kf, vf = k, v
  for i in range(b):
    kf = kf.at[i, :, int(lengths[i])].set(k_new[i])
    vf = vf.at[i, :, int(lengths[i])].set(v_new[i])

  def oracle(kk, vv, qq, ln):
    mask = jnp.arange(capacity) < (ln + 1)
    out = jax.vmap(lambda qh, kh, vh: pq_attention.exact_decode_attention(
        qh, kh, vh, mask, scale))(qq.reshape(h, 1, d), kk, vv)
    return out.reshape(h, d)

  fp32 = jax.vmap(oracle)(kf, vf, q, lengths)
  err_q4 = float(jnp.abs(got - fp32).max())
  cache8 = kvc.packed_exact_cache_prefill(k, v, capacity, 8)
  got8, _ = kvc.packed_exact_cache_append_and_attend(
      cache8, q, k_new, v_new, lengths, scale, 8, use_kernel=False)
  err_q8 = float(jnp.abs(got8 - fp32).max())
  assert 0 < err_q4 < 0.25, err_q4
  assert 0 < err_q8 < err_q4 / 4, (err_q8, err_q4)


def test_resident_q4_reconstruction_error_bounded(rng):
  """Prefill->dequant through the packed cache: per-element error obeys the
  per-group half-step bound computed from the *stored* scales — the bound
  the resident-q4 acceptance claim rests on."""
  b, h, n, d, bits = 2, 2, 24, 16, 4
  k = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  v = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
  cache = kvc.packed_exact_cache_prefill(k, v, n, bits)
  k_hat, v_hat = kvc.packed_exact_dequant(cache, bits)
  group = packing.group_size(d)
  for x, x_hat, s in ((k, k_hat, cache.k_scale), (v, v_hat, cache.v_scale)):
    err = np.abs(np.asarray(x_hat) - np.asarray(x))
    err = err.reshape(b, h, n, d // group, group).max(-1)
    step = np.asarray(s, np.float32)
    absmax = np.abs(np.asarray(x)).reshape(
        b, h, n, d // group, group).max(-1)
    tol = 0.5 * step + F16_EPS * (15 * step + absmax) + 1e-12
    assert (err <= tol).all(), float((err - tol).max())
    assert err.max() > 0, "q4 should be lossy on continuous data"


# ---------------------------------------------------------------------------
# Policy dispatch, bytes accounting, engine-level token identity
# ---------------------------------------------------------------------------

def test_resident_codec_dispatches_to_packed_policy():
  packed = cache_registry.make("exact", _spec(kv_resident_codec="q4"))
  assert isinstance(packed, cache_api.PackedExactPolicy)
  assert packed.bits == 4
  assert not packed.prefix_shareable and packed.prefix_cacheable
  # every packed leaf crosses the tier boundary verbatim: codes and f16
  # headers are already the compressed form
  assert set(packed.spill_codecs()._asdict().values()) == {"raw"}
  dense = cache_registry.make("exact", _spec())
  assert type(dense) is cache_api.ExactPolicy


def test_spec_validates_resident_codec_with_valid_keys_listed():
  with pytest.raises(ValueError, match="kv_resident_codec.*q4"):
    _spec(kv_resident_codec="fp4")


def test_packed_bytes_hit_the_capacity_claim():
  """q4 resident store <= 0.30x the fp32 dense leaves at head_dim 16 — the
  ratio BENCH_serve.json records from PagedLayout.capacity_bytes."""
  d = 16
  packed = cache_registry.make("exact", _spec(kv_resident_codec="q4"))
  rep = packed.bytes(2, 2, d)
  assert rep["reduction_ratio"] > 1.0
  # leaf-level truth, independent of the bytes() fp16 baseline: sum actual
  # init nbytes vs the fp32 dense store
  q4_state = packed.init(2, 2, d)
  q4_bytes = sum(np.asarray(leaf).nbytes for leaf in q4_state)
  fp32_bytes = 2 * 2 * packed.spec.capacity * d * 4 * 2
  assert q4_bytes / fp32_bytes <= 0.30
  q8 = cache_registry.make("exact", _spec(kv_resident_codec="q8"))
  q8_bytes = sum(np.asarray(leaf).nbytes for leaf in q8.init(2, 2, d))
  assert q4_bytes < q8_bytes < fp32_bytes


def _cfg(**kw):
  return dataclasses.replace(get_arch("tinyllama-1.1b", reduced=True),
                             cache_policy="exact", dtype_str="float32", **kw)


@pytest.mark.parametrize("layout,sched,extra", [
    ("paged", "paged", dict(num_blocks=12)),
    ("tiered", "tiered", dict(num_blocks=5, host_blocks=16)),
])
def test_resident_q4_tokens_identical_across_dispatches(layout, sched, extra):
  """Greedy tokens from the packed Pallas(-interpret) kernel match the XLA
  reference bit-for-bit on the same params — on both pooled layouts (the
  tiered case also drives packed pages across the spill boundary)."""
  xla = ServeEngine(_cfg(kv_resident_codec="q4", decode_kernel="xla"),
                    context_len=64, max_batch=2, prompt_capacity=32,
                    cache_layout=layout, scheduler=sched, **extra)
  pal = ServeEngine(_cfg(kv_resident_codec="q4",
                         decode_kernel="pallas-interpret"),
                    context_len=64, max_batch=2, prompt_capacity=32,
                    params=xla.params, cache_layout=layout, scheduler=sched,
                    **extra)
  assert pal.layout.block_native
  trace = [(list(range(1, 21)), 14), (list(range(3, 25)), 14)]
  want = [xla.submit(p, max_new_tokens=m) for p, m in trace]
  got = [pal.submit(p, max_new_tokens=m) for p, m in trace]
  xla.run_to_completion()
  pal.run_to_completion()
  if layout == "tiered":
    assert pal.stats.spills >= 1, "trace never exercised the spill path"
  for w, g in zip(want, got):
    assert g.done and g.tokens == w.tokens, g.rid


# ---------------------------------------------------------------------------
# q5: fifth-bit mask plane (PR 9), both registries
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), d=st.sampled_from([8, 16, 64, 128]),
       n=st.integers(1, 9))
def test_pack_unpack_u5_roundtrip_exact(seed, d, n):
  rng = np.random.default_rng(seed)
  q = jnp.asarray(rng.integers(0, 32, size=(n, d)), jnp.uint8)
  p = packing.pack_u5(q)
  # low nibbles split-half (d/2 bytes) + fifth-bit plane (d/8 bytes)
  assert p.shape == (n, d // 2 + d // 8) and p.dtype == jnp.uint8
  np.testing.assert_array_equal(np.asarray(packing.unpack_u5(p)),
                                np.asarray(q, np.int32))


def test_q5_registered_with_intermediate_cost():
  assert packing.RESIDENT_CODECS["q5"] == 5
  assert isinstance(tiers.get_codec("q5"), tiers.Q5SpillCodec)
  # per-value cost sits strictly between q4 and q8 at every group width
  for d in (32, 64, 128):
    w4, w5, w8 = (packing.packed_width(d, b) for b in (4, 5, 8))
    assert w4 < w5 < w8, (d, w4, w5, w8)


def test_q5_spill_codec_between_q4_and_q8(rng):
  """One extra bit per code: q5 spill frames must be larger than q4 and
  smaller than q8, with reconstruction error strictly between them."""
  arr = rng.standard_normal((6, 70)).astype(np.float32)
  out = {}
  for key in ("q4", "q5", "q8"):
    payload, nbytes = tiers.get_codec(key).encode(arr)
    back = tiers.get_codec(key).decode(payload, arr.shape, arr.dtype)
    assert back.shape == arr.shape and back.dtype == arr.dtype
    out[key] = (nbytes, float(np.abs(back - arr).max()))
  assert out["q4"][0] < out["q5"][0] < out["q8"][0], out
  assert out["q4"][1] > out["q5"][1] > out["q8"][1], out
  # q5 halves q4's quantization step: the error bound scales accordingly
  assert out["q5"][1] < 0.6 * out["q4"][1], out


def test_q5_resident_store_between_q4_and_q8():
  sizes = {}
  for key in ("q4", "q5", "q8"):
    pol = cache_registry.make("exact", _spec(kv_resident_codec=key))
    sizes[key] = sum(np.asarray(leaf).nbytes for leaf in pol.init(2, 2, 16))
  assert sizes["q4"] < sizes["q5"] < sizes["q8"], sizes


# ---------------------------------------------------------------------------
# packed exact + prefix cache: the PR 8 interaction, pinned (PR 9)
# ---------------------------------------------------------------------------

def test_packed_exact_prefix_full_hit_oracle():
  """Full-prompt prefix hits over the packed (q4 resident) store must skip
  prefill without perturbing greedy tokens: the repeated prompt's stream is
  bit-identical to a cache-off oracle's."""
  cfg = _cfg(kv_resident_codec="q4", decode_kernel="pallas-interpret")
  off = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                    cache_layout="paged", scheduler="paged", num_blocks=12)
  on = ServeEngine(cfg, context_len=64, max_batch=2, prompt_capacity=32,
                   params=off.params, cache_layout="paged",
                   scheduler="prefix", num_blocks=12, prefix_cache=True)
  assert on.layout.block_native
  trace = [(list(range(1, 21)), 10), (list(range(1, 21)), 10)]
  want = [off.submit(p, max_new_tokens=m) for p, m in trace]
  got = [on.submit(p, max_new_tokens=m) for p, m in trace]
  off.run_to_completion()
  on.run_to_completion()
  assert on.stats.prefix_full_hits >= 1, on.stats
  assert on.stats.prefill_tokens < off.stats.prefill_tokens
  for w, g in zip(want, got):
    assert g.done and g.tokens == w.tokens, g.rid
