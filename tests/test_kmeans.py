"""Weighted k-means (paper Eq. 2): unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
  from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded fallback shim
  from hypothesis_compat import given, settings, strategies as st

from repro.core import kmeans


def test_assignment_is_nearest():
  rng = np.random.default_rng(0)
  x = jnp.asarray(rng.normal(size=(100, 8)), jnp.float32)
  c = jnp.asarray(rng.normal(size=(7, 8)), jnp.float32)
  a = kmeans.assign_clusters(x, c)
  d = np.linalg.norm(np.asarray(x)[:, None] - np.asarray(c)[None], axis=-1)
  np.testing.assert_array_equal(np.asarray(a), d.argmin(-1))


def test_objective_decreases_over_iterations():
  rng = np.random.default_rng(1)
  x = jnp.asarray(rng.normal(size=(512, 16)), jnp.float32)
  w = jnp.ones((512,))
  errs = []
  for iters in (0, 1, 2, 4, 8):
    c, a = kmeans.weighted_kmeans(x, w, k=32, iters=iters)
    errs.append(float(kmeans.weighted_quantization_error(x, w, c, a)))
  assert all(e1 >= e2 - 1e-3 for e1, e2 in zip(errs, errs[1:])), errs


def test_four_iterations_near_converged():
  """Paper §III-B: 4 iterations reach a stable state."""
  rng = np.random.default_rng(2)
  centers = rng.normal(size=(16, 8)) * 5
  x = jnp.asarray(
      centers[rng.integers(0, 16, 2048)] + rng.normal(size=(2048, 8)) * 0.1,
      jnp.float32)
  w = jnp.ones((2048,))
  c4, a4 = kmeans.weighted_kmeans(x, w, k=16, iters=4)
  c20, a20 = kmeans.weighted_kmeans(x, w, k=16, iters=20)
  e4 = float(kmeans.weighted_quantization_error(x, w, c4, a4))
  e20 = float(kmeans.weighted_quantization_error(x, w, c20, a20))
  assert e4 <= e20 * 1.10 + 1e-6, (e4, e20)


def test_weighting_prioritizes_heavy_tokens():
  """Heavily weighted tokens get lower quantization error than unweighted."""
  rng = np.random.default_rng(3)
  x = jnp.asarray(rng.normal(size=(512, 8)), jnp.float32)
  w = jnp.ones((512,)).at[:32].set(100.0)      # 32 heavy hitters
  cw, aw = kmeans.weighted_kmeans(x, w, k=16, iters=8)
  cu, au = kmeans.weighted_kmeans(x, jnp.ones((512,)), k=16, iters=8)
  def heavy_err(c, a):
    recon = c[a[:32]]
    return float(jnp.sum((x[:32] - recon) ** 2))
  assert heavy_err(cw, aw) < heavy_err(cu, au)


def test_mask_excludes_padding():
  rng = np.random.default_rng(4)
  x = np.asarray(rng.normal(size=(128, 4)), np.float32)
  x[100:] = 1e3                                  # poisoned padding
  mask = jnp.arange(128) < 100
  c, a = kmeans.weighted_kmeans(
      jnp.asarray(x), jnp.ones((128,)), k=8, iters=4, mask=mask)
  assert float(jnp.max(jnp.abs(c))) < 100.0      # centroids ignore padding


def test_empty_cluster_frozen():
  x = jnp.asarray(np.zeros((16, 4), np.float32))
  c, a = kmeans.weighted_kmeans(x, jnp.ones((16,)), k=8, iters=2)
  assert bool(jnp.all(jnp.isfinite(c)))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(16, 128), d=st.integers(2, 16),
       k=st.integers(2, 16), seed=st.integers(0, 2**31 - 1))
def test_property_update_reduces_weighted_objective(n, d, k, seed):
  """One Lloyd update never increases the weighted objective."""
  rng = np.random.default_rng(seed)
  x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
  w = jnp.asarray(rng.uniform(0.1, 2.0, size=(n,)), jnp.float32)
  c0 = kmeans.init_centroids(x, k)
  a0 = kmeans.assign_clusters(x, c0)
  e0 = float(kmeans.weighted_quantization_error(x, w, c0, a0))
  c1 = kmeans._weighted_update(x, w, a0, c0)
  a1 = kmeans.assign_clusters(x, c1)
  e1 = float(kmeans.weighted_quantization_error(x, w, c1, a1))
  assert e1 <= e0 + 1e-3 * max(abs(e0), 1.0)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_singleton_clusters_exact(seed):
  """K = N: every point its own centroid -> zero error."""
  rng = np.random.default_rng(seed)
  x = jnp.asarray(rng.normal(size=(16, 4)) * 10, jnp.float32)
  c, a = kmeans.weighted_kmeans(x, jnp.ones((16,)), k=16, iters=6)
  err = float(kmeans.weighted_quantization_error(x, jnp.ones((16,)), c, a))
  assert err < 1e-2, err
