"""repro: AQPIM (PIM-aware KV-cache Product Quantization) on TPU, in JAX."""
__version__ = "1.0.0"
