"""Unified `CachePolicy` API: one swappable surface for every KV-cache method.

AQPIM's core claim is that PQ-compressed KV attention is a *drop-in
replacement* for exact decode attention (paper Fig. 3a/5), evaluated by
sweeping it against SKVQ/SnapKV/StreamingLLM/PQCache-style baselines on
identical inputs (§IV-A/B, Fig. 10).  This module makes "which KV policy"
a first-class choice.  Every policy implements:

    init(b, h, d)                                   -> state
    prefill(k, v, weights, lengths)                 -> state
    append_and_attend(state, q, k_new, v_new, lengths) -> (out, state)
    bytes(b, h, d)                                  -> dict

Shapes: k/v (B, H, N, D); q (B, Hq, D) with GQA groups folded into Hq;
`lengths` is a per-request (B,) int32 vector (a scalar broadcasts), so one
batch may mix prompt lengths — the substrate for continuous batching in
`repro.launch.engine`.  `weights` are the Eq. 1 importance weights
(B, H, N); only policies with `needs_weights=True` receive them.

Policies are selected by string key via `repro.core.cache_registry`:
`exact`, `pq` (AQPIM), `skvq`, `snapkv`, `streamingllm`, `pqcache`.

Migration from the old free functions:

    exact_cache_init/prefill/append_and_attend  -> ExactPolicy methods
    pq_cache_init/prefill/append_and_attend     -> PQPolicy methods
    baselines.{skvq,snapkv,streaming_llm,pqcache}_decode_attention
        -> the corresponding policy's append_and_attend

The kernel-level free functions in `kv_cache.py`/`baselines.py` remain the
numerical core; policies bind geometry (a `CacheSpec`) and add the batched
per-request-length semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common import Array
from repro.core import baselines, cache_registry, decode_dispatch
from repro.core import kv_cache as kvc
from repro.core import pq as pqlib
from repro.core import pq_attention
from repro.core import tiers
from repro.kernels import packing


def _fit_m(m: int, d: int) -> int:
  while m > 1 and d % m != 0:
    m //= 2
  return max(m, 1)


@dataclasses.dataclass(frozen=True)
class CacheSpec:
  """Static geometry + hyperparameters shared by all policies.

  `capacity` is the maximum context (prompt + generated) per request;
  policy-specific fields are ignored by policies that don't use them.
  """
  capacity: int
  head_dim: int
  dtype: Any = jnp.bfloat16
  sink: int = 8              # exact sink tokens (paper §IV-A)
  recent: int = 32           # exact recent window (= t of Eq. 1)
  window: int = 512          # streamingllm sliding window
  bits: int = 4              # skvq uniform-quant bits
  group: int = 32            # skvq channel-group size
  keep_frac: float = 0.25    # snapkv / pqcache kept-token fraction
  block: int = 0             # paged-layout token-block size (0 = contiguous)
  spill_codec: str = "raw"   # tiered-layout float-KV spill codec: any key in
                             # core.tiers.SPILL_CODECS (raw | int8 | q4 | q8;
                             # non-raw are lossy — PQ code rows always spill
                             # verbatim)
  kv_resident_codec: str = "none"  # exact-policy *resident* store format:
                             # none keeps dense floats; q4/q8 store packed
                             # codes + f16 headers (kernels/packing.py) and
                             # decode in-kernel.  Other policies ignore it.
  decode_kernel: str = "auto"  # decode attention implementation: registry key
                               # in core.decode_dispatch (xla | pallas |
                               # pallas-interpret | auto); resolved once at
                               # policy construction
  pq: Optional[kvc.PQCacheConfig] = None   # aqpim geometry (policy "pq")
  pq_select: Optional[pqlib.PQConfig] = None  # pqcache ANN-index codec
  scale: Optional[float] = None            # softmax scale; None -> d^-0.5

  def __post_init__(self):
    # geometry errors surface here, with names, instead of deep inside a
    # vmapped kernel as a shape/assert failure several layers down
    if self.capacity <= 0:
      raise ValueError(f"capacity must be positive, got {self.capacity}")
    if self.sink < 0 or self.recent < 0:
      raise ValueError(
          f"sink/recent must be >= 0, got ({self.sink}, {self.recent})")
    if not 0.0 < self.keep_frac <= 1.0:
      raise ValueError(f"keep_frac must be in (0, 1], got {self.keep_frac}")
    if not 0 < self.window <= self.capacity:
      raise ValueError(
          f"window must be in (0, capacity={self.capacity}], got "
          f"{self.window}")
    if self.block < 0:
      raise ValueError(f"block must be >= 0, got {self.block}")
    if self.spill_codec not in tiers.SPILL_CODECS:
      raise ValueError(
          f"spill_codec must be one of {tuple(sorted(tiers.SPILL_CODECS))}, "
          f"got {self.spill_codec!r}")
    if self.kv_resident_codec not in packing.RESIDENT_CODECS:
      raise ValueError(
          f"kv_resident_codec must be one of "
          f"{tuple(packing.RESIDENT_CODECS)}, got "
          f"{self.kv_resident_codec!r}")
    decode_dispatch.validate(self.decode_kernel)
    if self.block and self.capacity % self.block:
      raise ValueError(
          f"capacity {self.capacity} not divisible by block size "
          f"{self.block} (paged layouts need whole token blocks)")
    if (self.block and self.pq is not None
        and self.pq.body_capacity % self.block):
      raise ValueError(
          f"pq body_capacity {self.pq.body_capacity} not divisible by "
          f"block size {self.block}")

  @property
  def keep(self) -> int:
    return max(int(self.capacity * self.keep_frac), 1)

  def sm_scale(self, d: int) -> float:
    return self.scale if self.scale is not None else float(d) ** -0.5


class WeightedLayerCache(NamedTuple):
  """Exact KV plus per-token importance (snapkv's observation window)."""
  k: Array               # (B, H, N, D)
  v: Array
  w: Array               # (B, H, N) f32


# Sentinel for `CachePolicy.paged_axes`: the leaf has no token axis and stays
# resident per slot (never paged).  An int sentinel — not None — because None
# leaves collapse out of a pytree and the axes tree must keep the state's
# exact structure.
RESIDENT = -1


class CachePolicy:
  """Base class; subclasses register themselves under a string key.

  Beyond the four storage methods, a policy is a *codec over a layout*: it
  describes which leaves of its state carry a token axis (`paged_axes`) and
  how many paged tokens a given cached length occupies (`token_extent`), so
  `core.cache_layout.PagedLayout` can page any policy's state — AQPIM PQ
  codes page exactly the way exact KV does — without knowing its internals.
  """
  name: str = "base"
  needs_weights: bool = False
  #: True if this policy's prefilled per-position state is *causal* — a paged
  #: token's stored bytes depend only on prompt tokens at or before it — so
  #: whole prefix blocks may be shared copy-on-write across requests with
  #: different suffixes (core.prefix_index).  Weighted/clustered states
  #: (snapkv importance, AQPIM codebooks) couple positions and must be False.
  prefix_shareable: bool = False
  #: True if a *full-prompt* snapshot (blocks + resident leaves + first
  #: greedy token) is a bit-exact resume for an identical prompt.  Holds for
  #: every deterministic policy; full entries are how non-shareable policies
  #: (pq, snapkv) still hit on repeated prompts.
  prefix_cacheable: bool = True

  #: True when this policy has a Pallas decode-kernel implementation (dense
  #: storage).  Policies without one silently stay on the XLA path whatever
  #: the dispatch says — there is nothing else to run.
  kernel_decode: bool = False

  def __init__(self, spec: CacheSpec):
    self.spec = spec
    # resolved once; the serve engine compiles one decode program per run
    self.dispatch = decode_dispatch.resolve(spec.decode_kernel)

  @property
  def use_kernel(self) -> bool:
    """Does this policy's dense decode path run the Pallas kernel?"""
    return self.dispatch.use_pallas and self.kernel_decode

  @property
  def effective_decode_kernel(self) -> str:
    """What actually runs this policy's decode attention — 'xla' whenever
    the policy has no kernel implementation (or its geometry gates it off),
    whatever the requested dispatch was.  Stats and bench records must
    label runs with this, not the request."""
    return self.dispatch.key if self.use_kernel else "xla"

  @property
  def block_native(self) -> bool:
    """Can the paged decode step read pool storage in place (no dense
    gather)?  Policies with a paged kernel variant override; pooled layouts
    consult this to pick between the dense gather->decode->scatter program
    and the block-table-native one."""
    return False

  # -- protocol -------------------------------------------------------------
  def init(self, b: int, h: int, d: int) -> Any:
    raise NotImplementedError

  def prefill(self, k: Array, v: Array, weights: Optional[Array] = None,
              lengths: Optional[Array] = None) -> Any:
    raise NotImplementedError

  def append_and_attend(self, state: Any, q: Array, k_new: Array,
                        v_new: Array, lengths: Array) -> Tuple[Array, Any]:
    raise NotImplementedError

  def bytes(self, b: int, h: int, d: int) -> dict:
    raise NotImplementedError

  # -- paged-layout codec surface -------------------------------------------
  def paged_axes(self):
    """Pytree matching one *batched* state (leading dim B): per leaf, the
    token-axis index, or RESIDENT for fixed-size leaves (codebooks, rings)."""
    raise NotImplementedError(
        f"{type(self).__name__} does not describe a paged layout")

  def paged_capacity(self) -> int:
    """Size of the paged token axis (the dense buffer the codec attends on)."""
    return self.spec.capacity

  def token_extent(self, length: int) -> int:
    """Paged tokens that must be resident when `length` tokens are cached."""
    return min(length, self.paged_capacity())

  def pinned_tokens(self) -> int:
    """Leading paged tokens that may never be reclaimed (attention sinks)."""
    return 0

  def dead_below(self, length: int) -> int:
    """Paged-token positions < this are evicted by the policy's own masking
    and may be reclaimed (ring-reuse); 0 means nothing is reclaimable."""
    del length
    return 0

  def spill_codecs(self):
    """Pytree of spill-codec keys, same structure as `paged_axes()`: how each
    *paged* buffer crosses the device->host tier boundary (`core.tiers`).
    RESIDENT leaves (rings, codebooks) always spill raw — they must survive a
    swap-out bit-exactly.  Default: everything spills verbatim, which for
    AQPIM's PQ code rows *is* the compressed representation — the point of
    the paper's communication claim."""
    return jax.tree_util.tree_map(lambda ax: "raw", self.paged_axes())

  def append_and_attend_paged(self, resident_leaves, pool_leaves, layer,
                              tables, q: Array, k_new: Array, v_new: Array,
                              lengths: Array):
    """Block-table-native decode step over pooled storage.

    `resident_leaves` / `pool_leaves` are the flattened state (paged_axes
    leaf order) with the *other* kind's entries None: resident leaves carry
    this layer's per-slot state (B, ...), pool leaves the physical pools
    (P+1, L, ..., block, ...) shared across layers; `layer` is the scan's
    layer counter, `tables` the (B, nb) block tables.  Returns
    (out (B, Hq, D), resident_leaves, pool_leaves) with the same None
    pattern.  Only policies with `block_native=True` implement this.
    """
    raise NotImplementedError(
        f"{type(self).__name__} has no block-native decode step")

  def __repr__(self) -> str:
    return f"{type(self).__name__}(capacity={self.spec.capacity})"


# ---------------------------------------------------------------------------
# Exact-family policies: full-precision store, per-policy attend transform
# ---------------------------------------------------------------------------

class _ExactStorePolicy(CachePolicy):
  """Shared store/append machinery for policies that keep exact KV.

  Subclasses override `_attend(q, k, v, w, length)` operating per
  (batch, kv-head): q (g, d), k/v (N, d), w (N,) f32 or None, `length` the
  count of cached tokens *including* the token just inserted minus one
  (i.e. valid positions are < length + 1).
  """
  tracks_weights = False
  # plain exact stores are causal per position -> prefix blocks shareable;
  # weight-tracking (snapkv) and ring-reusing (streamingllm) subclasses
  # override back to False
  prefix_shareable = True

  def init(self, b: int, h: int, d: int) -> Any:
    base = kvc.exact_cache_init(b, h, self.spec.capacity, d, self.spec.dtype)
    if not self.tracks_weights:
      return base
    return WeightedLayerCache(
        k=base.k, v=base.v, w=jnp.zeros((b, h, self.spec.capacity),
                                        jnp.float32))

  def prefill(self, k: Array, v: Array, weights: Optional[Array] = None,
              lengths: Optional[Array] = None) -> Any:
    del lengths  # padding rows are masked at attend time by `lengths`
    base = kvc.exact_cache_prefill(k, v, self.spec.capacity)
    if not self.tracks_weights:
      return base
    b, h, n, _ = k.shape
    w = weights if weights is not None else jnp.zeros((b, h, n))
    w = jnp.pad(w.astype(jnp.float32),
                ((0, 0), (0, 0), (0, self.spec.capacity - n)))
    return WeightedLayerCache(k=base.k, v=base.v, w=w)

  def append_and_attend(self, state: Any, q: Array, k_new: Array,
                        v_new: Array, lengths: Array) -> Tuple[Array, Any]:
    b = q.shape[0]
    d = q.shape[-1]
    lens = kvc.as_lengths(lengths, b)
    scale = self.spec.sm_scale(d)
    tracks = self.tracks_weights

    def one(k_c, v_c, w_c, qq, kn, vn, ln):
      # k_c/v_c (H, N, D), w_c (H, N) or None, qq (Hq, D), ln scalar
      h = k_c.shape[0]
      hq = qq.shape[0]
      g = hq // h
      k_c, v_c = kvc.exact_insert_one(k_c, v_c, kn, vn, ln)
      qg = qq.reshape(h, g, d)
      if w_c is None:
        out = jax.vmap(lambda qh, kh, vh: self._attend(qh, kh, vh, None, ln)
                       )(qg, k_c, v_c)
        return out.reshape(hq, d), k_c, v_c, None
      # generated tokens get +inf importance: real SnapKV compresses only the
      # prompt, so post-prefill tokens must outrank every observed prompt
      # weight in the top-keep selection once they age out of `recent`
      w_c = jax.lax.dynamic_update_slice(
          w_c, jnp.full((w_c.shape[0], 1), jnp.inf, w_c.dtype), (0, ln))
      out = jax.vmap(lambda qh, kh, vh, wh: self._attend(qh, kh, vh, wh, ln)
                     )(qg, k_c, v_c, w_c)
      return out.reshape(hq, d), k_c, v_c, w_c

    if tracks:
      out, k_c, v_c, w_c = jax.vmap(one)(
          state.k, state.v, state.w, q, k_new, v_new, lens)
      return out, WeightedLayerCache(k=k_c, v=v_c, w=w_c)
    out, k_c, v_c, _ = jax.vmap(
        lambda k_c, v_c, qq, kn, vn, ln: one(k_c, v_c, None, qq, kn, vn, ln)
    )(state.k, state.v, q, k_new, v_new, lens)
    return out, kvc.ExactLayerCache(k=k_c, v=v_c)

  # scale is bound per call because d is only known there
  def _attend(self, q: Array, k: Array, v: Array, w: Optional[Array],
              length: Array) -> Array:
    raise NotImplementedError

  def _valid_mask(self, n: int, length: Array) -> Array:
    return jnp.arange(n) < (length + 1)

  def paged_axes(self):
    # k/v (B, H, N, D) and w (B, H, N): token axis 2 on every leaf
    if self.tracks_weights:
      return WeightedLayerCache(k=2, v=2, w=2)
    return kvc.ExactLayerCache(k=2, v=2)

  def spill_codecs(self):
    # exact KV may spill raw or int8 (CacheSpec.spill_codec); importance
    # weights drive top-k selection and always spill raw — quantizing them
    # would perturb snapkv's eviction choices across a swap
    c = self.spec.spill_codec
    if self.tracks_weights:
      return WeightedLayerCache(k=c, v=c, w="raw")
    return kvc.ExactLayerCache(k=c, v=c)


@cache_registry.register("exact")
class ExactPolicy(_ExactStorePolicy):
  """Full-precision KV, dense decode attention (the paper's upper bound).

  Kernel dispatch: with a pallas dispatch the dense step runs the
  flash-decode kernel (`kernels/paged_flash_decode.flash_decode_kernel`) and
  the paged step is block-table-native (`paged_flash_decode_kernel` reads the
  K/V pool in place — no dense gather, one inserted row written).

  With `CacheSpec.kv_resident_codec` set to q4/q8, construction transparently
  yields a `PackedExactPolicy` — same registry key, packed resident store.
  """
  kernel_decode = True

  def __new__(cls, spec: CacheSpec):
    # the resident codec is a storage-format switch, not a different
    # algorithm: "exact" stays the one registry key and the spec picks the
    # store, so every construction path (registry, config, tests) agrees
    if cls is ExactPolicy and spec.kv_resident_codec != "none":
      return super().__new__(PackedExactPolicy)
    return super().__new__(cls)

  @property
  def block_native(self) -> bool:
    return self.dispatch.use_pallas

  def append_and_attend(self, state, q, k_new, v_new, lengths):
    if self.use_kernel:
      return kvc.exact_cache_append_and_attend_kernel(
          state, q, k_new, v_new, lengths, self.spec.sm_scale(q.shape[-1]),
          interpret=self.dispatch.interpret)
    # identical semantics to the generic path; delegate so the plain-exact
    # row step has exactly one implementation (kv_cache.py)
    return kvc.exact_cache_append_and_attend(
        state, q, k_new, v_new, lengths, self.spec.sm_scale(q.shape[-1]))

  def append_and_attend_paged(self, resident_leaves, pool_leaves, layer,
                              tables, q, k_new, v_new, lengths):
    k_pool, v_pool = pool_leaves
    out, k_pool, v_pool = kvc.exact_cache_paged_step(
        k_pool, v_pool, layer, tables, q, k_new, v_new, lengths,
        self.spec.sm_scale(q.shape[-1]), interpret=self.dispatch.interpret)
    return out, list(resident_leaves), [k_pool, v_pool]

  def bytes(self, b: int, h: int, d: int) -> dict:
    fp = 2
    per_head = self.spec.capacity * d * fp * 2
    return dict(per_head_bytes=per_head, total_bytes=per_head * b * h,
                equivalent_exact_bytes=per_head * b * h, reduction_ratio=1.0)


class PackedExactPolicy(ExactPolicy):
  """Exact attention over a sub-byte packed resident store (q4/q8).

  State is `kv_cache.PackedExactLayerCache`: split-half nibble codes plus
  per-group f16 scale/min pages (kernels/packing.py block format) — ~0.19x
  the fp32 store at q4 — making the exact policy capacity-competitive with
  pq while keeping its attend semantics.  With a pallas dispatch the paged
  step is block-native through `packed_paged_flash_decode_kernel` (codes
  unpacked in VMEM); the XLA path dequantizes the dense store with the same
  formula, so greedy decode agrees bit-for-bit across dispatches.

  Constructed via `ExactPolicy.__new__` when `spec.kv_resident_codec` is
  q4/q8 — never registered under its own key.
  """
  # packed rows are causal per position, but the chunked suffix-prefill path
  # (_attn_chunk) inserts into dense k/v leaves only — so prefix blocks are
  # not shareable; full-prompt entries (prefix_cacheable) still hit
  prefix_shareable = False

  def __init__(self, spec: CacheSpec):
    super().__init__(spec)
    self.bits = packing.RESIDENT_CODECS[spec.kv_resident_codec]

  def init(self, b: int, h: int, d: int):
    return kvc.packed_exact_cache_init(b, h, self.spec.capacity, d,
                                       self.bits)

  def prefill(self, k, v, weights=None, lengths=None):
    del weights, lengths  # padding rows are masked at attend time
    return kvc.packed_exact_cache_prefill(k, v, self.spec.capacity,
                                          self.bits)

  def append_and_attend(self, state, q, k_new, v_new, lengths):
    return kvc.packed_exact_cache_append_and_attend(
        state, q, k_new, v_new, lengths, self.spec.sm_scale(q.shape[-1]),
        bits=self.bits, use_kernel=self.use_kernel,
        interpret=self.dispatch.interpret)

  def append_and_attend_paged(self, resident_leaves, pool_leaves, layer,
                              tables, q, k_new, v_new, lengths):
    out, pools = kvc.packed_exact_cache_paged_step(
        pool_leaves, layer, tables, q, k_new, v_new, lengths,
        self.spec.sm_scale(q.shape[-1]), bits=self.bits,
        interpret=self.dispatch.interpret)
    return out, list(resident_leaves), pools

  def paged_axes(self):
    return kvc.PackedExactLayerCache(k_pack=2, k_scale=2, k_min=2,
                                     v_pack=2, v_scale=2, v_min=2)

  def spill_codecs(self):
    # already sub-byte: packed pages must cross the tier boundary verbatim
    # (re-quantizing codes would corrupt them; they *are* the compression)
    return kvc.PackedExactLayerCache(k_pack="raw", k_scale="raw",
                                     k_min="raw", v_pack="raw",
                                     v_scale="raw", v_min="raw")

  def bytes(self, b: int, h: int, d: int) -> dict:
    group = packing.group_size(d)
    # codes + f16 scale/min headers, k and v
    per_tok = packing.packed_width(d, self.bits) + (d // group) * 4
    per_head = self.spec.capacity * per_tok * 2
    exact = self.spec.capacity * d * 2 * 2
    return dict(per_head_bytes=per_head, total_bytes=per_head * b * h,
                equivalent_exact_bytes=exact * b * h,
                reduction_ratio=exact / per_head)


@cache_registry.register("streamingllm")
class StreamingLLMPolicy(_ExactStorePolicy):
  """Static sink + sliding window; everything else evicted (masked)."""
  # ring-reuse retires prefix blocks mid-decode; sharing them would pin what
  # the window machinery exists to recycle
  prefix_shareable = False

  def _attend(self, q, k, v, w, length):
    return baselines.streaming_llm_decode_attention(
        q, k, v, length + 1, self.spec.sm_scale(q.shape[-1]),
        sink=self.spec.sink, window=self.spec.window)

  def pinned_tokens(self) -> int:
    return self.spec.sink

  def dead_below(self, length: int) -> int:
    # tokens below length-window are masked out forever -> their blocks can
    # be recycled (the paged layout's ring-reuse for the streaming window)
    return max(length - self.spec.window, 0)

  def bytes(self, b: int, h: int, d: int) -> dict:
    fp = 2
    kept = min(self.spec.sink + self.spec.window, self.spec.capacity)
    per_head = kept * d * fp * 2
    exact = self.spec.capacity * d * fp * 2
    return dict(per_head_bytes=per_head, total_bytes=per_head * b * h,
                equivalent_exact_bytes=exact * b * h,
                reduction_ratio=exact / per_head)


@cache_registry.register("skvq")
class SKVQPolicy(_ExactStorePolicy):
  """Sliding-window uniform quantization with channel reordering.

  Storage is modeled (bytes()); compute follows §IV-E: GPUs must upcast, so
  the attend path quantize-dequantizes the full valid context each step.
  """

  def _attend(self, q, k, v, w, length):
    mask = self._valid_mask(k.shape[0], length)
    # zero masked rows so garbage never skews the channel-range reorder
    k_m = jnp.where(mask[:, None], k, 0)
    v_m = jnp.where(mask[:, None], v, 0)
    return baselines.skvq_decode_attention(
        q, k_m, v_m, mask, self.spec.sm_scale(q.shape[-1]),
        bits=self.spec.bits, group=min(self.spec.group, k.shape[-1]))

  def bytes(self, b: int, h: int, d: int) -> dict:
    g = min(self.spec.group, d)
    per_tok = d * self.spec.bits / 8 + (d // g) * 4   # int storage + scale/zero
    per_head = int(self.spec.capacity * per_tok) * 2
    exact = self.spec.capacity * d * 2 * 2
    return dict(per_head_bytes=per_head, total_bytes=per_head * b * h,
                equivalent_exact_bytes=exact * b * h,
                reduction_ratio=exact / per_head)


@cache_registry.register("snapkv")
class SnapKVPolicy(_ExactStorePolicy):
  """Importance top-k eviction: sinks + recents + top-`keep` body tokens.

  Matches real SnapKV's asymmetry: the *prompt* body competes for the keep
  budget by observed importance, while generated tokens (weighted +inf at
  append) are never evicted in favor of prompt tokens."""
  needs_weights = True
  tracks_weights = True
  # Eq. 1 importance at a prefix position is observed by *later* queries —
  # suffix-dependent, so prefix blocks are not shareable (full entries only)
  prefix_shareable = False

  def _attend(self, q, k, v, w, length):
    mask = baselines.snapkv_select(
        w, keep=self.spec.keep, sink=self.spec.sink,
        recent=self.spec.recent, length=length + 1)
    return pq_attention.exact_decode_attention(
        q, k, v, mask, self.spec.sm_scale(q.shape[-1]))

  def bytes(self, b: int, h: int, d: int) -> dict:
    kept = min(self.spec.sink + self.spec.recent + self.spec.keep,
               self.spec.capacity)
    per_head = kept * d * 2 * 2
    exact = self.spec.capacity * d * 2 * 2
    return dict(per_head_bytes=per_head, total_bytes=per_head * b * h,
                equivalent_exact_bytes=exact * b * h,
                reduction_ratio=exact / per_head)


@cache_registry.register("pqcache")
class PQCachePolicy(_ExactStorePolicy):
  """PQ as ANN index to select top-k, exact KV fetched for selected tokens.

  Accuracy ~exact; the cost AQPIM eliminates is the per-step exact-KV fetch
  over PCIe, accounted in bytes()['fetched_bytes_per_step'].

  NOTE: this models *selection quality and traffic*, not wall-clock: the PQ
  index is rebuilt from scratch each step (the real PQCache builds it once
  at prefill and appends incrementally), so tok/s measured with this policy
  overstates the baseline's compute cost.  bytes() reflects the persistent
  index the real system stores.
  """

  def _select_cfg(self, d: int) -> pqlib.PQConfig:
    if self.spec.pq_select is not None:
      return self.spec.pq_select
    # matches the historical Fig. 10 operating point — a *strong* baseline
    # (weakening it would flatter AQPIM's relative accuracy)
    return pqlib.PQConfig(m=_fit_m(16, d), k=128, iters=4)

  def _attend(self, q, k, v, w, length):
    mask = self._valid_mask(k.shape[0], length)
    out, _ = baselines.pqcache_decode_attention(
        q, k, v, mask, self.spec.sm_scale(q.shape[-1]),
        self._select_cfg(k.shape[-1]), keep=self.spec.keep)
    return out

  def bytes(self, b: int, h: int, d: int) -> dict:
    cfg = self._select_cfg(d)
    idx = self.spec.capacity * cfg.m * cfg.index_bytes() * 2
    per_head = idx                        # on-accelerator footprint: the index
    exact = self.spec.capacity * d * 2 * 2
    return dict(per_head_bytes=per_head, total_bytes=per_head * b * h,
                equivalent_exact_bytes=exact * b * h,
                reduction_ratio=exact / per_head,
                fetched_bytes_per_step=self.spec.keep * d * 2 * 2 * b * h)


# ---------------------------------------------------------------------------
# AQPIM PQ policy
# ---------------------------------------------------------------------------

@cache_registry.register("pq")
class PQPolicy(CachePolicy):
  """AQPIM: sink/recent exact, PQ-compressed body, attention on compressed
  data (paper Fig. 3a/5).  Wraps the kv_cache.py kernel-level core.

  Kernel dispatch: with a pallas dispatch the body segment runs the fused
  Pallas kernel (`kernels/pq_decode.py` — VMEM-pinned inner-product table,
  flash-decoding stats) and the exact sink/recent segments combine with it
  exactly; the paged step is block-table-native (index pages read from the
  pool in place, one encoded row written per step).  Single-window codebooks
  only (the kernel pins one table page); multi-window configs stay on the
  XLA path.  The XLA body uses the kernel's reconstruct-values formulation
  (`pq_attention.reconstruct_values`) — identical math to the bucket-sum
  reference, reassociated, and the cheaper XLA lowering when m*K >> d.
  """
  needs_weights = True
  kernel_decode = True
  # codebooks cluster over the whole prompt body: a prefix's code rows are
  # suffix-dependent, so sharing is full-prompt entries only — which is
  # where the PQ footprint advantage compounds (one cached prompt's code
  # rows are 5-8x smaller than the exact KV it replaces)
  prefix_shareable = False

  def __init__(self, spec: CacheSpec):
    super().__init__(spec)
    if spec.pq is None:
      raise ValueError("PQPolicy requires CacheSpec.pq geometry")
    if (spec.pq.sink, spec.pq.recent) != (spec.sink, spec.recent):
      # _attn_prefill reads the Eq. 1 window t from spec.recent while the
      # cache rings use spec.pq — drift would silently skew weight quality
      raise ValueError(
          f"CacheSpec sink/recent ({spec.sink},{spec.recent}) must match "
          f"PQCacheConfig ({spec.pq.sink},{spec.pq.recent})")
    self.pq_cfg = spec.pq

  def init(self, b: int, h: int, d: int):
    return kvc.pq_cache_init(b, h, d, self.pq_cfg, self.spec.dtype)

  def prefill(self, k, v, weights=None, lengths=None):
    if weights is None:
      weights = jnp.ones(k.shape[:3], jnp.float32)
    return kvc.pq_cache_prefill(k, v, weights, self.pq_cfg, length=lengths)

  @property
  def use_kernel(self) -> bool:
    return (self.dispatch.use_pallas and self.pq_cfg.n_windows == 1)

  @property
  def block_native(self) -> bool:
    return self.use_kernel

  def append_and_attend(self, state, q, k_new, v_new, lengths):
    if self.use_kernel:
      return kvc.pq_cache_append_and_attend_kernel(
          state, q, k_new, v_new, lengths, self.pq_cfg,
          self.spec.sm_scale(q.shape[-1]),
          interpret=self.dispatch.interpret)
    return kvc.pq_cache_append_and_attend(
        state, q, k_new, v_new, lengths, self.pq_cfg,
        self.spec.sm_scale(q.shape[-1]), value_mode=self._xla_value_mode())

  def _xla_value_mode(self) -> str:
    """Size-aware XLA value path: both formulations are the same sum
    reassociated, but bucket's one-hot matmul costs O(N*m*K) against
    reconstruction's O(N*d) — reconstruct wins once the codebook axis
    dwarfs the head dim (the paper operating point m=32, K=512, d=128),
    while tiny sweep configs keep the BLAS-friendly bucket form."""
    if self.pq_cfg.n_windows != 1:
      return "bucket"       # windowed output path has no reconstruct form
    pq = self.pq_cfg.pq
    return "reconstruct" if pq.m * pq.k >= 16 * self.spec.head_dim else \
        "bucket"

  def append_and_attend_paged(self, resident_leaves, pool_leaves, layer,
                              tables, q, k_new, v_new, lengths):
    (sink_k, sink_v, recent_k, recent_v, kcb, vcb, _, _) = resident_leaves
    (_, _, _, _, _, _, kip, vip) = pool_leaves
    (out, sink_k, sink_v, recent_k, recent_v, kip, vip) = \
        kvc.pq_cache_paged_step(
            sink_k, sink_v, recent_k, recent_v, kcb, vcb, kip, vip, layer,
            tables, q, k_new, v_new, lengths, self.pq_cfg,
            self.spec.sm_scale(q.shape[-1]),
            interpret=self.dispatch.interpret)
    return (out,
            [sink_k, sink_v, recent_k, recent_v, kcb, vcb, None, None],
            [None, None, None, None, None, None, kip, vip])

  def bytes(self, b: int, h: int, d: int) -> dict:
    return kvc.pq_cache_bytes(self.pq_cfg, b, h, d)

  def paged_axes(self):
    # only the per-token PQ codes page; sink/recent rings and the codebooks
    # are fixed-size per request and stay resident
    return kvc.PQLayerCache(
        sink_k=RESIDENT, sink_v=RESIDENT,
        recent_k=RESIDENT, recent_v=RESIDENT,
        key_codebooks=RESIDENT, value_codebooks=RESIDENT,
        key_indices=2, value_indices=2)

  def paged_capacity(self) -> int:
    return self.pq_cfg.body_capacity

  def token_extent(self, length: int) -> int:
    # body offsets are positions [sink, length - recent): the sink/recent
    # tokens live in the resident rings, not in paged storage
    used = length - self.pq_cfg.sink - self.pq_cfg.recent
    return min(max(used, 0), self.pq_cfg.body_capacity)
