"""KV-cache structures: exact and PQ-compressed (AQPIM §III-A/H layout).

PQ cache layout per layer (paper §IV-A hyperparameters):

  [ sink (8 tokens, exact) | PQ body (windowed codebooks + indices) | recent (32, exact) ]

- the first `sink` tokens are kept full precision (attention sinks),
- the most recent `recent` tokens are kept full precision in a ring buffer (also
  the importance window t of Eq. 1),
- everything in between lives as per-(head, window) codebooks plus per-token
  m-subvector indices.

During decode (paper Fig. 3a): the new token's k/v enter the recent ring; the token
evicted from the ring is *encoded* (index append — paper step 3) against its
window's codebook page.  Codebooks themselves stay fixed after prefill (the paper
evaluated OnlinePQ and dropped it).  All shapes are static: every op here is
jit/pjit-safe and lowers into the multi-pod serve_step.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common import Array
from repro.core import pq, pq_attention, windowed
from repro.kernels import ops as kops
from repro.kernels import packing


def as_lengths(length, b: int) -> Array:
  """Normalize a scalar length or per-request (B,) lengths to (B,) int32."""
  ln = jnp.asarray(length, jnp.int32)
  if ln.ndim == 0:
    return jnp.broadcast_to(ln, (b,))
  return ln.reshape(b)


# ---------------------------------------------------------------------------
# Block-indexed storage primitives (paged KV memory)
#
# A *paged* cache stores a token-axis leaf as fixed-size blocks in a shared
# physical pool instead of one contiguous per-request slab; a per-request
# block table maps logical token-block j -> physical pool block.  These four
# primitives are the numerical core the `core.cache_layout.PagedLayout`
# builds on; they are shape-static and vmap/jit-safe, so the gather -> decode
# -> scatter round trip lowers into one compiled step.
# ---------------------------------------------------------------------------

def blockify(x: Array, axis: int, block: int) -> Array:
  """Split token axis `axis` of a dense leaf into leading blocks.

  (..., N, ...) with N = nb*block  ->  (nb, ..., block, ...)
  """
  n = x.shape[axis]
  assert n % block == 0, f"token axis {n} not divisible by block {block}"
  x = x.reshape(x.shape[:axis] + (n // block, block) + x.shape[axis + 1:])
  return jnp.moveaxis(x, axis, 0)


def unblockify(blocks: Array, axis: int) -> Array:
  """Inverse of `blockify`: (nb, ..., block, ...) -> dense (..., N, ...)."""
  x = jnp.moveaxis(blocks, 0, axis)
  return x.reshape(x.shape[:axis] + (x.shape[axis] * x.shape[axis + 1],)
                   + x.shape[axis + 2:])


def gather_blocks(pool: Array, table: Array, axis: int) -> Array:
  """Materialize one request's dense leaf view from the physical pool.

  pool (P, ...block leaf...) indexed by table (nb,) int32 -> dense leaf whose
  token axis sits at `axis`.  Unallocated logical blocks point at the pool's
  trash block; their garbage rows land at positions >= the request's length
  and are masked inside every policy's attend path.
  """
  return unblockify(pool[table], axis)


def scatter_blocks(pool: Array, table: Array, dense: Array, axis: int) -> Array:
  """Write a request's dense leaf back into its pool blocks (inverse gather)."""
  block = pool.shape[axis + 1]
  return pool.at[table].set(blockify(dense, axis, block).astype(pool.dtype))


class PQCacheConfig(NamedTuple):
  """Static geometry of a PQ cache."""
  sink: int = 8            # exact sink tokens (paper §IV-A)
  recent: int = 32         # exact sliding-window tokens (= t of Eq. 1)
  body_capacity: int = 0   # max PQ-compressed tokens (multiple of n_windows)
  n_windows: int = 1       # codebook pages (paper: 1 suffices for long context)
  pq: pq.PQConfig = pq.PQConfig()

  @property
  def window_len(self) -> int:
    return self.body_capacity // self.n_windows

  def capacity(self) -> int:
    return self.sink + self.recent + self.body_capacity


class PQLayerCache(NamedTuple):
  """One layer's compressed KV state.  Leading dims (B, H_kv)."""
  sink_k: Array          # (B, H, S0, D)
  sink_v: Array
  recent_k: Array        # (B, H, R, D) ring buffer
  recent_v: Array
  key_codebooks: Array   # (B, H, nW, m, K, dsub) f32
  value_codebooks: Array
  key_indices: Array     # (B, H, Nb, m) int32
  value_indices: Array


class ExactLayerCache(NamedTuple):
  k: Array               # (B, H, N_max, D)
  v: Array


# ---------------------------------------------------------------------------
# Exact cache
# ---------------------------------------------------------------------------

def exact_cache_init(b: int, h: int, n_max: int, d: int, dtype) -> ExactLayerCache:
  z = jnp.zeros((b, h, n_max, d), dtype)
  return ExactLayerCache(k=z, v=z)


def exact_cache_prefill(k: Array, v: Array, n_max: int) -> ExactLayerCache:
  """k/v (B, H, N, D) -> cache padded to n_max."""
  b, h, n, d = k.shape
  pad = ((0, 0), (0, 0), (0, n_max - n), (0, 0))
  return ExactLayerCache(k=jnp.pad(k, pad), v=jnp.pad(v, pad))


def exact_insert_one(
    k_c: Array,          # (H, N, D)
    v_c: Array,
    k_new: Array,        # (H, D)
    v_new: Array,
    length: Array,       # scalar int32: tokens already cached in this row
) -> Tuple[Array, Array]:
  """Insert one token at position `length` of a single request's exact store.

  Shared by the free-function path below and the exact-family policies in
  `core.cache_api` so the insertion layout has exactly one implementation.
  """
  k_c = jax.lax.dynamic_update_slice(
      k_c, k_new[:, None, :].astype(k_c.dtype), (0, length, 0))
  v_c = jax.lax.dynamic_update_slice(
      v_c, v_new[:, None, :].astype(v_c.dtype), (0, length, 0))
  return k_c, v_c


def _exact_append_attend_one(
    k_c: Array,          # (H, N, D)
    v_c: Array,
    q: Array,            # (Hq, D)
    k_new: Array,        # (H, D)
    v_new: Array,
    length: Array,       # scalar int32: tokens already cached in this row
    scale: float,
) -> Tuple[Array, Array, Array]:
  """One request's decode step; batching is a vmap over this (per-row length)."""
  h, n_max, d = k_c.shape
  hq = q.shape[0]
  g = hq // h
  k_c, v_c = exact_insert_one(k_c, v_c, k_new, v_new, length)
  mask = jnp.arange(n_max) < (length + 1)

  qg = q.reshape(h, g, d)
  out = jax.vmap(
      lambda qq, kk, vv: pq_attention.exact_decode_attention(qq, kk, vv, mask, scale)
  )(qg, k_c, v_c)                                     # (H, g, D)
  return out.reshape(hq, d), k_c, v_c


def exact_cache_append_and_attend(
    cache: ExactLayerCache,
    q: Array,            # (B, Hq, D)
    k_new: Array,        # (B, H, D)
    v_new: Array,
    length: Array,       # scalar int32 OR (B,) per-request lengths
    scale: float,
) -> Tuple[Array, ExactLayerCache]:
  b = q.shape[0]
  lengths = as_lengths(length, b)
  out, k_c, v_c = jax.vmap(
      functools.partial(_exact_append_attend_one, scale=scale)
  )(cache.k, cache.v, q, k_new, v_new, lengths)
  return out, ExactLayerCache(k=k_c, v=v_c)


# ---------------------------------------------------------------------------
# Packed exact cache: sub-byte resident KV (kernels/packing.py block format)
# ---------------------------------------------------------------------------

class PackedExactLayerCache(NamedTuple):
  """Exact KV stored as q4/q8 block-quantized pages (kernels/packing.py).

  Token axis is 2 on every leaf, mirroring ExactLayerCache, so the paged/
  tiered layouts page this state exactly like the dense one — the pool
  blocks simply hold codes + f16 headers instead of floats.
  """
  k_pack: Array          # (B, H, N, d*bits/8) uint8 — split-half nibbles
  k_scale: Array         # (B, H, N, G) f16 — per-group scale, G = d/group
  k_min: Array           # (B, H, N, G) f16 — per-group minimum
  v_pack: Array
  v_scale: Array
  v_min: Array


def packed_exact_cache_init(b: int, h: int, n_max: int, d: int,
                            bits: int) -> PackedExactLayerCache:
  group = packing.group_size(d)
  zp = jnp.zeros((b, h, n_max, packing.packed_width(d, bits)), jnp.uint8)
  zs = jnp.zeros((b, h, n_max, d // group), jnp.float16)
  return PackedExactLayerCache(k_pack=zp, k_scale=zs, k_min=zs,
                               v_pack=zp, v_scale=zs, v_min=zs)


def packed_exact_cache_prefill(k: Array, v: Array, n_max: int,
                               bits: int) -> PackedExactLayerCache:
  """k/v (B, H, N, D) -> quantized cache padded to n_max."""
  b, h, n, d = k.shape
  group = packing.group_size(d)
  kp, ks, km = packing.pack_rows(k, bits=bits, group=group)
  vp, vs, vm = packing.pack_rows(v, bits=bits, group=group)
  pad = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, n_max - n), (0, 0)))
  return PackedExactLayerCache(k_pack=pad(kp), k_scale=pad(ks),
                               k_min=pad(km), v_pack=pad(vp),
                               v_scale=pad(vs), v_min=pad(vm))


def packed_exact_dequant(cache: PackedExactLayerCache,
                         bits: int) -> Tuple[Array, Array]:
  """Whole-store dequant -> (k, v) f32 (..., N, D); the XLA reference path
  (same formula the kernel applies per mapped block)."""
  d = cache.k_pack.shape[-1] * 8 // bits
  group = packing.group_size(d)
  k = packing.dequant_page(cache.k_pack, cache.k_scale, cache.k_min,
                           bits=bits, group=group)
  v = packing.dequant_page(cache.v_pack, cache.v_scale, cache.v_min,
                           bits=bits, group=group)
  return k, v


def _packed_insert_one(kp, ks, km, vp, vs, vm, k_new, v_new, length, *,
                       bits: int):
  """Quantize one token row and insert it at `length` (leaves are (H, N, x),
  k_new/v_new (H, D)) — the packed analogue of `exact_insert_one`."""
  d = k_new.shape[-1]
  group = packing.group_size(d)
  knp, kns, knm = packing.pack_rows(k_new, bits=bits, group=group)
  vnp, vns, vnm = packing.pack_rows(v_new, bits=bits, group=group)

  def ins(buf, row):
    return jax.lax.dynamic_update_slice(
        buf, row[:, None, :].astype(buf.dtype), (0, length, 0))

  return (ins(kp, knp), ins(ks, kns), ins(km, knm),
          ins(vp, vnp), ins(vs, vns), ins(vm, vnm))


def packed_exact_cache_append_and_attend(
    cache: PackedExactLayerCache,
    q: Array,            # (B, Hq, D)
    k_new: Array,        # (B, H, D)
    v_new: Array,
    length: Array,       # scalar int32 OR (B,) per-request lengths
    scale: float,
    bits: int,
    use_kernel: bool = False,
    interpret: bool = True,
) -> Tuple[Array, PackedExactLayerCache]:
  """Dense-storage packed decode step: quantize-insert the new row, then
  attend over the dequantized store (flash-decode kernel or masked XLA)."""
  b, hq, d = q.shape
  h = cache.k_pack.shape[1]
  g = hq // h
  lengths = as_lengths(length, b)
  leaves = jax.vmap(functools.partial(_packed_insert_one, bits=bits))(
      *cache, k_new, v_new, lengths)
  cache = PackedExactLayerCache(*leaves)
  k_c, v_c = packed_exact_dequant(cache, bits)      # (B, H, N, D) f32
  if use_kernel:
    out = kops.flash_decode(q.reshape(b, h, g, d), k_c, v_c, lengths + 1,
                            scale, interpret=interpret)
    return out.reshape(b, hq, d), cache
  n_max = k_c.shape[2]

  def one(kk, vv, qq, ln):
    mask = jnp.arange(n_max) < (ln + 1)
    qg = qq.reshape(h, g, d)
    out = jax.vmap(
        lambda qh, kh, vh: pq_attention.exact_decode_attention(
            qh, kh, vh, mask, scale))(qg, kk, vv)
    return out.reshape(hq, d)

  out = jax.vmap(one)(k_c, v_c, q, lengths)
  return out, cache


def packed_exact_cache_paged_step(
    pool_leaves,         # 6 pools, PackedExactLayerCache leaf order:
                         # (P+1, L, H, block, x) with x = dp | G | G
    layer: Array,        # scalar int32
    tables: Array,       # (B, nb) int32
    q: Array,            # (B, Hq, D)
    k_new: Array,        # (B, H, D)
    v_new: Array,
    length: Array,
    scale: float,
    bits: int,
    interpret: bool = True,
):
  """Block-table-native packed decode step: quantize the new row, write its
  codes + headers into the mapped pool block, attend in place through the
  packed kernel (codes are unpacked in VMEM — never densified in HBM)."""
  kp, ks, km, vp, vs, vm = pool_leaves
  b, hq, d = q.shape
  h = kp.shape[2]
  g = hq // h
  block = kp.shape[3]
  group = packing.group_size(d)
  lengths = as_lengths(length, b)
  pids = tables[jnp.arange(b), lengths // block]
  rows = lengths % block
  knp, kns, knm = packing.pack_rows(k_new, bits=bits, group=group)
  vnp, vns, vnm = packing.pack_rows(v_new, bits=bits, group=group)
  kp = kp.at[pids, layer, :, rows].set(knp.astype(kp.dtype))
  ks = ks.at[pids, layer, :, rows].set(kns.astype(ks.dtype))
  km = km.at[pids, layer, :, rows].set(knm.astype(km.dtype))
  vp = vp.at[pids, layer, :, rows].set(vnp.astype(vp.dtype))
  vs = vs.at[pids, layer, :, rows].set(vns.astype(vs.dtype))
  vm = vm.at[pids, layer, :, rows].set(vnm.astype(vm.dtype))
  out = kops.packed_paged_flash_decode(
      q.reshape(b, h, g, d), kp, ks, km, vp, vs, vm, tables, layer,
      lengths + 1, scale, bits=bits, interpret=interpret)
  return out.reshape(b, hq, d), [kp, ks, km, vp, vs, vm]


# ---------------------------------------------------------------------------
# PQ cache
# ---------------------------------------------------------------------------

def index_storage_dtype(cfg: PQCacheConfig):
  """Target-hardware index width (beyond-paper: uint8 at K<=256 halves the
  dominant decode-memory term vs int16 — EXPERIMENTS.md §Perf)."""
  return jnp.uint8 if cfg.pq.k <= 256 else jnp.int16


def pq_cache_init(
    b: int, h: int, d: int, cfg: PQCacheConfig, dtype=jnp.bfloat16
) -> PQLayerCache:
  m, k = cfg.pq.m, cfg.pq.k
  dsub = d // m
  idt = index_storage_dtype(cfg)
  return PQLayerCache(
      sink_k=jnp.zeros((b, h, cfg.sink, d), dtype),
      sink_v=jnp.zeros((b, h, cfg.sink, d), dtype),
      recent_k=jnp.zeros((b, h, cfg.recent, d), dtype),
      recent_v=jnp.zeros((b, h, cfg.recent, d), dtype),
      # bf16 codebook storage (paper: fp16 row buffers); f32 at compute sites
      key_codebooks=jnp.zeros((b, h, cfg.n_windows, m, k, dsub), jnp.bfloat16),
      value_codebooks=jnp.zeros((b, h, cfg.n_windows, m, k, dsub), jnp.bfloat16),
      # target-hardware index width: uint8 when K<=256 else int16; cast to
      # int32 only at gather sites.
      key_indices=jnp.zeros((b, h, cfg.body_capacity, m), idt),
      value_indices=jnp.zeros((b, h, cfg.body_capacity, m), idt),
  )


def _pq_prefill_one(
    k: Array,            # (H, N, D)
    v: Array,
    weights: Array,      # (H, N)
    length: Array,       # scalar int32: true prompt length (<= N)
    cfg: PQCacheConfig,
) -> PQLayerCache:
  """Per-request PQ prefill with a dynamic valid length (right-padded inputs).

  The layout invariant is the same as the static path: token p >= sink lives at
  ring slot (p - sink) % recent; body offsets are positions [sink, length-recent).
  Tokens beyond `length` (padding) are excluded from clustering via the body
  mask and never become visible: the decode-side masks derive from `length`.
  """
  h, n, d = k.shape
  s0, r, nb = cfg.sink, cfg.recent, cfg.body_capacity
  assert n >= s0 + r, f"prefill capacity {n} < sink+recent {s0 + r}"
  # static worst case (length == n): the mirror of the batched path's
  # `body exceeds capacity` assert — without it, overflow tokens would be
  # silently masked out of the body instead of raising
  assert n - s0 - r <= nb, (
      f"prefill capacity {n} can overflow body capacity {nb} (sink={s0}, "
      f"recent={r})")

  sink_k, sink_v = k[:, :s0], v[:, :s0]
  # last `recent` valid tokens -> ring slots keyed by absolute position
  start = jnp.maximum(length - r, 0)
  rec_tok_k = jax.lax.dynamic_slice(k, (0, start, 0), (h, r, d))
  rec_tok_v = jax.lax.dynamic_slice(v, (0, start, 0), (h, r, d))
  slots = (jnp.arange(r) + start - s0) % r
  recent_k = jnp.zeros((h, r, d), k.dtype).at[:, slots].set(rec_tok_k)
  recent_v = jnp.zeros((h, r, d), v.dtype).at[:, slots].set(rec_tok_v)

  # body candidates occupy positions [s0, s0+nb); clustering masked to the
  # true body [s0, length - r)
  pad = max(s0 + nb - n, 0)
  kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))[:, s0:s0 + nb]
  vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))[:, s0:s0 + nb]
  wp = jnp.pad(weights, ((0, 0), (0, pad)))[:, s0:s0 + nb]
  body_n = jnp.clip(length - s0 - r, 0, nb)
  mask = jnp.arange(nb) < body_n

  def per_head(kk, vv, ww):
    k_cb, k_idx = windowed.windowed_build_codebooks(
        kk, ww, cfg.pq, cfg.n_windows, mask=mask)
    v_cb, v_idx = windowed.windowed_build_codebooks(
        vv, ww, cfg.pq, cfg.n_windows, mask=mask)
    return k_cb, k_idx, v_cb, v_idx

  k_cb, k_idx, v_cb, v_idx = jax.vmap(per_head)(kp, vp, wp)
  idt = index_storage_dtype(cfg)
  return PQLayerCache(
      sink_k=sink_k, sink_v=sink_v,
      recent_k=recent_k, recent_v=recent_v,
      key_codebooks=k_cb.astype(jnp.bfloat16),
      value_codebooks=v_cb.astype(jnp.bfloat16),
      key_indices=k_idx.astype(idt),
      value_indices=v_idx.astype(idt),
  )


def pq_cache_prefill(
    k: Array,            # (B, H, N, D)
    v: Array,
    weights: Array,      # (B, H, N) importance weights (Eq. 1)
    cfg: PQCacheConfig,
    length: Optional[Array] = None,   # (B,) per-request lengths (None -> N)
) -> PQLayerCache:
  """Compress a prefilled KV into the PQ cache (paper Fig. 3a prefill step 3).

  Body tokens are positions [sink, N - recent); they are placed at body offsets
  [0, N - sink - recent).  The windowed clustering runs per (batch, head) — this is
  the computation the paper hides behind GPU prefill on the PIM side, and that we
  fuse into the prefill step.
  """
  b, h, n, d = k.shape
  if length is not None:
    return jax.vmap(functools.partial(_pq_prefill_one, cfg=cfg))(
        k, v, weights, as_lengths(length, b))
  s0, r, nb = cfg.sink, cfg.recent, cfg.body_capacity
  assert n >= s0 + r, f"prefill length {n} < sink+recent {s0 + r}"
  body_n = n - s0 - r
  assert body_n <= nb, f"body {body_n} exceeds capacity {nb}"

  sink_k, sink_v = k[:, :, :s0], v[:, :, :s0]
  # ring layout: token (s0 + i) lives at slot i % r; after prefill the last r
  # tokens occupy slots ((n - r - s0) + j) % r for j in [0, r)
  rec_tok_k, rec_tok_v = k[:, :, n - r:], v[:, :, n - r:]
  slots = (jnp.arange(r) + (n - r - s0)) % r
  recent_k = jnp.zeros((b, h, r, d), k.dtype).at[:, :, slots].set(rec_tok_k)
  recent_v = jnp.zeros((b, h, r, d), v.dtype).at[:, :, slots].set(rec_tok_v)

  body_k = k[:, :, s0:n - r]
  body_v = v[:, :, s0:n - r]
  body_w = weights[:, :, s0:n - r]

  # pad body to full capacity so window boundaries are static
  pad = nb - body_n
  body_k = jnp.pad(body_k, ((0, 0), (0, 0), (0, pad), (0, 0)))
  body_v = jnp.pad(body_v, ((0, 0), (0, 0), (0, pad), (0, 0)))
  body_w = jnp.pad(body_w, ((0, 0), (0, 0), (0, pad)))
  mask = jnp.arange(nb) < body_n

  def per_head(kk, vv, ww):
    k_cb, k_idx = windowed.windowed_build_codebooks(
        kk, ww, cfg.pq, cfg.n_windows, mask=mask)
    v_cb, v_idx = windowed.windowed_build_codebooks(
        vv, ww, cfg.pq, cfg.n_windows, mask=mask)
    return k_cb, k_idx, v_cb, v_idx

  k_cb, k_idx, v_cb, v_idx = jax.vmap(jax.vmap(per_head))(
      body_k, body_v, body_w)

  idt = index_storage_dtype(cfg)
  return PQLayerCache(
      sink_k=sink_k, sink_v=sink_v,
      recent_k=recent_k, recent_v=recent_v,
      key_codebooks=k_cb.astype(jnp.bfloat16),
      value_codebooks=v_cb.astype(jnp.bfloat16),
      key_indices=k_idx.astype(idt),
      value_indices=v_idx.astype(idt),
  )


class PQRingStep(NamedTuple):
  """Everything one PQ decode step changes *except* where the encoded indices
  land — shared by the dense path (scatter into the per-slot index buffer)
  and the block-native path (scatter one row into the physical pool)."""
  sink_k: Array          # (H, S0, D) updated
  sink_v: Array
  recent_k: Array        # (H, R, D) updated
  recent_v: Array
  k_idx_new: Array       # (H, m) encoded eviction (garbage when !do_evict)
  v_idx_new: Array
  ev: Array              # scalar int32 body offset being filled (clipped)
  do_evict: Array        # scalar bool
  sink_mask: Array       # (S0,)
  rec_mask: Array        # (R,)
  body_len: Array        # scalar int32 valid body tokens after this step


def _pq_ring_step_one(
    sink_k: Array,        # (H, S0, D)
    sink_v: Array,
    recent_k: Array,      # (H, R, D)
    recent_v: Array,
    key_codebooks: Array,    # (H, nW, m, K, dsub)
    value_codebooks: Array,
    k_new: Array,         # (H, D)
    v_new: Array,
    length: Array,        # scalar int32 tokens already cached (incl. prefill)
    cfg: PQCacheConfig,
) -> PQRingStep:
  """Steps 1-3 of one request's PQ decode: evict->encode, insert, masks.

  Reads and writes of the single affected ring slot use one-hot masks
  instead of dynamic slice/update: bit-identical results (selecting one row
  is exact; untouched rows pass through `where` verbatim) but elementwise
  ops where XLA-CPU would otherwise emit per-row scatter/gather kernels —
  measurably cheaper on the vmapped serve hot path.
  """
  s0, r, nb = cfg.sink, cfg.recent, cfg.body_capacity
  pos = length                                     # position of the new token

  in_sink = pos < s0
  slot = jnp.clip((pos - s0) % r, 0, r - 1)
  evict_pos = pos - s0 - r                          # body offset being filled

  # --- 1. encode the evicted ring entry into the PQ body -------------------
  do_evict = evict_pos >= 0
  ev = jnp.clip(evict_pos, 0, nb - 1)
  win_id = jnp.clip(ev // max(cfg.window_len, 1), 0, cfg.n_windows - 1)

  rsel = (jnp.arange(r) == slot)[None, :, None]               # (1, R, 1)
  old_k = jnp.sum(jnp.where(rsel, recent_k.astype(jnp.float32), 0.0),
                  axis=1)                                     # (H, D)
  old_v = jnp.sum(jnp.where(rsel, recent_v.astype(jnp.float32), 0.0),
                  axis=1)

  if cfg.n_windows == 1:
    # single codebook page (the paper's long-context setting): the page is
    # statically known, so skip windowed_encode's per-token page gather
    def encode_one(x, cbs):
      # x (D,), cbs (1, m, K, dsub)
      xs = x.reshape(cbs.shape[1], 1, cbs.shape[3])           # (m, 1, dsub)
      d2 = jnp.sum((cbs[0].astype(jnp.float32) - xs) ** 2, axis=-1)
      return jnp.argmin(d2, axis=-1).astype(jnp.int32)        # (m,)
  else:
    def encode_one(x, cbs):
      # x (D,), cbs (nW, m, K, dsub)
      return windowed.windowed_encode(x[None], cbs, win_id[None])[0]  # (m,)
  k_idx_new = jax.vmap(encode_one)(old_k, key_codebooks)      # (H, m)
  v_idx_new = jax.vmap(encode_one)(old_v, value_codebooks)

  # --- 2. insert the new token (sink while warming up, else ring) ----------
  sink_sel = ((jnp.arange(s0) == jnp.clip(pos, 0, s0 - 1))
              & in_sink)[None, :, None]                       # (1, S0, 1)
  ring_sel = ((jnp.arange(r) == slot) & ~in_sink)[None, :, None]

  def insert(buf, sel, val):
    return jnp.where(sel, val[:, None, :].astype(buf.dtype), buf)
  sink_k = insert(sink_k, sink_sel, k_new)
  sink_v = insert(sink_v, sink_sel, v_new)
  recent_k = insert(recent_k, ring_sel, k_new)
  recent_v = insert(recent_v, ring_sel, v_new)

  # --- 3. masks after insertion --------------------------------------------
  n_tok = pos + 1
  sink_mask = jnp.arange(s0) < jnp.minimum(n_tok, s0)
  rec_count = jnp.clip(n_tok - s0, 0, r)
  rec_mask = jnp.arange(r) < rec_count          # ring fills sequentially pre-wrap
  body_len = jnp.clip(n_tok - s0 - r, 0, nb)
  return PQRingStep(
      sink_k=sink_k, sink_v=sink_v, recent_k=recent_k, recent_v=recent_v,
      k_idx_new=k_idx_new, v_idx_new=v_idx_new, ev=ev, do_evict=do_evict,
      sink_mask=sink_mask, rec_mask=rec_mask, body_len=body_len)


def _pq_append_attend_one(
    cache: PQLayerCache,  # leaves without the batch dim: (H, ...)
    q: Array,             # (Hq, D)
    k_new: Array,         # (H, D)
    v_new: Array,
    length: Array,        # scalar int32 tokens already cached (incl. prefill)
    cfg: PQCacheConfig,
    scale: float,
    value_mode: str = "bucket",
) -> Tuple[Array, PQLayerCache]:
  hq, d = q.shape
  h = cache.recent_k.shape[0]
  g = hq // h
  nb = cfg.body_capacity

  step = _pq_ring_step_one(
      cache.sink_k, cache.sink_v, cache.recent_k, cache.recent_v,
      cache.key_codebooks, cache.value_codebooks, k_new, v_new, length, cfg)

  # one-hot masked row write (no scatter kernel; bit-identical)
  ev_sel = ((jnp.arange(nb) == step.ev) & step.do_evict)[None, :, None]

  def maybe_scatter(idx_store, idx_new):
    return jnp.where(ev_sel, idx_new[:, None, :].astype(idx_store.dtype),
                     idx_store)
  key_indices = maybe_scatter(cache.key_indices, step.k_idx_new)
  value_indices = maybe_scatter(cache.value_indices, step.v_idx_new)

  sink_k, sink_v = step.sink_k, step.sink_v
  recent_k, recent_v = step.recent_k, step.recent_v
  sink_mask, rec_mask = step.sink_mask, step.rec_mask
  body_mask = jnp.arange(nb) < step.body_len

  # --- 4. PQ attention on compressed context -------------------------------
  qg = q.reshape(h, g, d)

  def attend(qq, sk, sv, rk, rv, kcb, vcb, kix, vix):
    seg = pq_attention.PQAttnSegments(
        sink_k=sk, sink_v=sv, sink_mask=sink_mask,
        key_codebook=kcb if cfg.n_windows > 1 else kcb[0],
        value_codebook=vcb if cfg.n_windows > 1 else vcb[0],
        key_indices=kix, value_indices=vix, body_mask=body_mask,
        recent_k=rk, recent_v=rv, recent_mask=rec_mask)
    return pq_attention.pq_decode_attention(qq, seg, scale,
                                            value_mode=value_mode)

  out = jax.vmap(attend)(
      qg, sink_k, sink_v, recent_k, recent_v,
      cache.key_codebooks, cache.value_codebooks,
      key_indices, value_indices)                  # (H, g, D)

  new_cache = PQLayerCache(
      sink_k=sink_k, sink_v=sink_v, recent_k=recent_k, recent_v=recent_v,
      key_codebooks=cache.key_codebooks, value_codebooks=cache.value_codebooks,
      key_indices=key_indices, value_indices=value_indices)
  return out.reshape(hq, d), new_cache


def pq_cache_append_and_attend(
    cache: PQLayerCache,
    q: Array,            # (B, Hq, D)
    k_new: Array,        # (B, H, D)
    v_new: Array,
    length: Array,       # scalar int32 OR (B,) per-request lengths
    cfg: PQCacheConfig,
    scale: float,
    value_mode: str = "bucket",
) -> Tuple[Array, PQLayerCache]:
  """One decode step: insert token, evict->encode, attend on compressed context.

  Mirrors paper Fig. 3a decode: (3) append indices, (4) PQ attention.  Batched
  as a vmap over the per-request core so each row may sit at a different
  position in its ring/body (continuous batching).
  """
  b = q.shape[0]
  lengths = as_lengths(length, b)
  return jax.vmap(
      functools.partial(_pq_append_attend_one, cfg=cfg, scale=scale,
                        value_mode=value_mode)
  )(cache, q, k_new, v_new, lengths)


# ---------------------------------------------------------------------------
# Kernel-dispatch decode paths (core.decode_dispatch)
#
# The functions below are the Pallas-backed implementations the policies
# select when the resolved dispatch says `use_pallas`.  They compute the PQ
# body (and for exact, the whole context) through the fused kernels and the
# small exact segments (sink/recent) in pure JAX, combined exactly via
# flash-decoding (max, denom) stats — numerically, a reassociated version of
# the oracle's joint softmax (fp32 throughout).
#
# The *_paged_step variants are block-table-native: cached token state lives
# in the paged layout's physical pool (leading pool axis, then layer) and is
# read in place by the kernels through scalar-prefetched block tables; the
# only writes are the single rows this step produced.  No dense per-request
# view ever materializes in HBM — the round trip the dense gather->decode->
# scatter program pays twice per step.
# ---------------------------------------------------------------------------


def _pq_segments_combine(q, step_masks, sink_k, sink_v, recent_k, recent_v,
                         body, scale):
  """Combine kernel body stats with pure-JAX sink/recent segment stats.

  q (B, H, g, D); sink/recent (B, H, S, D); body = (out, max, denom) from the
  kernel; step_masks = (sink_mask (B, S0), rec_mask (B, R)).
  """
  sink_mask, rec_mask = step_masks

  def seg(qq, k, v, mask):
    return pq_attention.segment_attention_stats(qq, k, v, mask, scale)

  def per_req(qq, sk, sv, rk, rv, sm, rm):
    # vmap over kv heads; masks are per-request (shared across heads)
    s_out, s_m, s_l = jax.vmap(lambda a, b, c: seg(a, b, c, sm))(qq, sk, sv)
    r_out, r_m, r_l = jax.vmap(lambda a, b, c: seg(a, b, c, rm))(qq, rk, rv)
    return s_out, s_m, s_l, r_out, r_m, r_l

  s_out, s_m, s_l, r_out, r_m, r_l = jax.vmap(per_req)(
      q, sink_k, sink_v, recent_k, recent_v, sink_mask, rec_mask)
  b_out, b_m, b_l = body
  return kops.combine_attention_segments(
      [b_out, s_out, r_out], [b_m, s_m, r_m], [b_l, s_l, r_l])


def pq_cache_append_and_attend_kernel(
    cache: PQLayerCache,
    q: Array,            # (B, Hq, D)
    k_new: Array,        # (B, H, D)
    v_new: Array,
    length: Array,
    cfg: PQCacheConfig,
    scale: float,
    interpret: bool = True,
) -> Tuple[Array, PQLayerCache]:
  """Dense-storage PQ decode step through the Pallas body kernel.

  Same storage contract as `pq_cache_append_and_attend`; single-window
  codebooks only (the kernel pins one table page in VMEM).
  """
  assert cfg.n_windows == 1, "kernel path requires a single codebook window"
  b, hq, d = q.shape
  h = cache.recent_k.shape[1]
  g = hq // h
  lengths = as_lengths(length, b)

  step = jax.vmap(functools.partial(_pq_ring_step_one, cfg=cfg))(
      cache.sink_k, cache.sink_v, cache.recent_k, cache.recent_v,
      cache.key_codebooks, cache.value_codebooks, k_new, v_new, lengths)

  nb = cfg.body_capacity
  ev_sel = ((jnp.arange(nb)[None] == step.ev[:, None])
            & step.do_evict[:, None])[:, None, :, None]      # (B, 1, nb, 1)

  def maybe_scatter(idx_store, idx_new):
    return jnp.where(ev_sel, idx_new[:, :, None, :].astype(idx_store.dtype),
                     idx_store)
  key_indices = maybe_scatter(cache.key_indices, step.k_idx_new)
  value_indices = maybe_scatter(cache.value_indices, step.v_idx_new)

  qg = q.reshape(b, h, g, d)
  body = kops.pq_decode_attention(
      qg, cache.key_codebooks[:, :, 0], cache.value_codebooks[:, :, 0],
      key_indices, value_indices,
      jnp.broadcast_to(step.body_len[:, None], (b, h)), scale,
      blk=kops.decode_block(cfg.body_capacity), interpret=interpret)
  out = _pq_segments_combine(
      qg, (step.sink_mask, step.rec_mask), step.sink_k, step.sink_v,
      step.recent_k, step.recent_v, body, scale)

  new_cache = PQLayerCache(
      sink_k=step.sink_k, sink_v=step.sink_v,
      recent_k=step.recent_k, recent_v=step.recent_v,
      key_codebooks=cache.key_codebooks,
      value_codebooks=cache.value_codebooks,
      key_indices=key_indices, value_indices=value_indices)
  return out.reshape(b, hq, d), new_cache


def pq_cache_paged_step(
    sink_k: Array,        # (B, H, S0, D)
    sink_v: Array,
    recent_k: Array,      # (B, H, R, D)
    recent_v: Array,
    key_codebooks: Array,    # (B, H, nW, m, K, dsub)
    value_codebooks: Array,
    key_index_pool: Array,   # (P+1, L, H, block, m) narrow int
    value_index_pool: Array,
    layer: Array,         # scalar int32
    tables: Array,        # (B, nb) int32 block tables (trash = P)
    q: Array,             # (B, Hq, D)
    k_new: Array,         # (B, H, D)
    v_new: Array,
    length: Array,
    cfg: PQCacheConfig,
    scale: float,
    interpret: bool = True,
):
  """Block-table-native PQ decode step: pool read in place, one row written.

  Returns (out (B, Hq, D), updated rings..., updated pools...).  The evicted
  ring entry's encoded indices land directly in pool block
  ``tables[b, ev // block]`` (or the trash block when nothing evicts); the
  body kernel then streams exactly the table-mapped blocks.
  """
  assert cfg.n_windows == 1, "kernel path requires a single codebook window"
  b, hq, d = q.shape
  h = recent_k.shape[1]
  g = hq // h
  block = key_index_pool.shape[3]
  trash = key_index_pool.shape[0] - 1
  lengths = as_lengths(length, b)

  step = jax.vmap(functools.partial(_pq_ring_step_one, cfg=cfg))(
      sink_k, sink_v, recent_k, recent_v, key_codebooks, value_codebooks,
      k_new, v_new, lengths)

  # single-row pool writes: the only body-state HBM traffic this step makes.
  # Non-evicting rows aim at the trash block, whose content is never read.
  pids = jnp.where(step.do_evict,
                   tables[jnp.arange(b), step.ev // block], trash)
  rows = step.ev % block
  key_index_pool = key_index_pool.at[pids, layer, :, rows].set(
      step.k_idx_new.astype(key_index_pool.dtype))
  value_index_pool = value_index_pool.at[pids, layer, :, rows].set(
      step.v_idx_new.astype(value_index_pool.dtype))

  qg = q.reshape(b, h, g, d)
  body = kops.pq_decode_attention_paged(
      qg, key_codebooks[:, :, 0], value_codebooks[:, :, 0],
      key_index_pool, value_index_pool, tables, layer, step.body_len,
      scale, interpret=interpret)
  out = _pq_segments_combine(
      qg, (step.sink_mask, step.rec_mask), step.sink_k, step.sink_v,
      step.recent_k, step.recent_v, body, scale)
  return (out.reshape(b, hq, d), step.sink_k, step.sink_v, step.recent_k,
          step.recent_v, key_index_pool, value_index_pool)


def exact_cache_append_and_attend_kernel(
    cache: ExactLayerCache,
    q: Array,            # (B, Hq, D)
    k_new: Array,        # (B, H, D)
    v_new: Array,
    length: Array,
    scale: float,
    interpret: bool = True,
) -> Tuple[Array, ExactLayerCache]:
  """Dense-storage exact decode step through the flash-decode kernel."""
  b, hq, d = q.shape
  h = cache.k.shape[1]
  g = hq // h
  lengths = as_lengths(length, b)
  k_c, v_c = jax.vmap(exact_insert_one)(cache.k, cache.v, k_new, v_new,
                                        lengths)
  out = kops.flash_decode(q.reshape(b, h, g, d), k_c, v_c, lengths + 1,
                          scale, interpret=interpret)
  return out.reshape(b, hq, d), ExactLayerCache(k=k_c, v=v_c)


def exact_cache_paged_step(
    k_pool: Array,       # (P+1, L, H, block, D)
    v_pool: Array,
    layer: Array,        # scalar int32
    tables: Array,       # (B, nb) int32
    q: Array,            # (B, Hq, D)
    k_new: Array,        # (B, H, D)
    v_new: Array,
    length: Array,
    scale: float,
    interpret: bool = True,
):
  """Block-table-native exact decode step: insert one row, attend in place."""
  b, hq, d = q.shape
  h = k_pool.shape[2]
  g = hq // h
  block = k_pool.shape[3]
  lengths = as_lengths(length, b)
  pids = tables[jnp.arange(b), lengths // block]
  rows = lengths % block
  k_pool = k_pool.at[pids, layer, :, rows].set(k_new.astype(k_pool.dtype))
  v_pool = v_pool.at[pids, layer, :, rows].set(v_new.astype(v_pool.dtype))
  out = kops.paged_flash_decode(
      q.reshape(b, h, g, d), k_pool, v_pool, tables, layer, lengths + 1,
      scale, interpret=interpret)
  return out.reshape(b, hq, d), k_pool, v_pool


def pq_cache_bytes(cfg: PQCacheConfig, b: int, h: int, d: int) -> dict:
  """Target-hardware byte accounting (bf16 exact, fp16 codebooks, packed indices)."""
  fp = 2
  exact = (cfg.sink + cfg.recent) * d * fp * 2
  cb = cfg.n_windows * cfg.pq.m * cfg.pq.k * (d // cfg.pq.m) * fp * 2
  idx = cfg.body_capacity * cfg.pq.m * cfg.pq.index_bytes() * 2
  per_head = exact + cb + idx
  equivalent_exact = cfg.capacity() * d * fp * 2
  return dict(
      per_head_bytes=per_head,
      total_bytes=per_head * b * h,
      equivalent_exact_bytes=equivalent_exact * b * h,
      reduction_ratio=equivalent_exact / per_head,
  )
