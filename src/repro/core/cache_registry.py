"""String-keyed registries of KV-cache policies and cache layouts.

Every method the paper sweeps (AQPIM PQ, exact, SKVQ/SnapKV/StreamingLLM/
PQCache baselines — §IV-A/B, Fig. 10) registers itself here under a short
key; models, the serve engine, and the benchmark harness all select the
policy by name:

    from repro.core import cache_registry
    policy = cache_registry.make("pq", spec)

A second namespace holds *cache layouts* (`core.cache_layout`): how policy
state is physically stored — `contiguous` per-slot slabs or `paged`
fixed-size token blocks:

    layout = cache_registry.make_layout("paged", model, max_batch)

Kept import-light (stdlib only) so it can sit below both `core.cache_api`
and `configs.base` without cycles.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

_REGISTRY: Dict[str, type] = {}
_LAYOUTS: Dict[str, type] = {}


def register(name: str) -> Callable[[type], type]:
  """Class decorator: `@register("pq") class PQPolicy(CachePolicy)`."""
  def deco(cls: type) -> type:
    if name in _REGISTRY and _REGISTRY[name] is not cls:
      raise ValueError(f"cache policy {name!r} already registered")
    _REGISTRY[name] = cls
    cls.name = name
    return cls
  return deco


def get(name: str) -> type:
  _ensure_builtin()
  try:
    return _REGISTRY[name]
  except KeyError:
    raise KeyError(
        f"unknown cache policy {name!r}; available: {names()}") from None


def make(name: str, spec):
  """Instantiate the policy registered under `name` with a CacheSpec."""
  return get(name)(spec)


def names() -> Tuple[str, ...]:
  _ensure_builtin()
  return tuple(sorted(_REGISTRY))


def _ensure_builtin() -> None:
  # registration happens at class definition; importing cache_api is enough
  from repro.core import cache_api  # noqa: F401  (cycle-safe: lazy)


# ---------------------------------------------------------------------------
# cache layouts
# ---------------------------------------------------------------------------

def register_layout(name: str) -> Callable[[type], type]:
  """Class decorator: `@register_layout("paged") class PagedLayout(...)`."""
  def deco(cls: type) -> type:
    if name in _LAYOUTS and _LAYOUTS[name] is not cls:
      raise ValueError(f"cache layout {name!r} already registered")
    _LAYOUTS[name] = cls
    cls.name = name
    return cls
  return deco


def get_layout(name: str) -> type:
  _ensure_builtin_layouts()
  try:
    return _LAYOUTS[name]
  except KeyError:
    raise KeyError(
        f"unknown cache layout {name!r}; available: {layout_names()}"
    ) from None


def make_layout(name: str, model, max_batch: int, **kwargs):
  """Instantiate the layout registered under `name` for a built Model."""
  return get_layout(name)(model, max_batch, **kwargs)


def layout_names() -> Tuple[str, ...]:
  _ensure_builtin_layouts()
  return tuple(sorted(_LAYOUTS))


def _ensure_builtin_layouts() -> None:
  from repro.core import cache_layout  # noqa: F401  (cycle-safe: lazy)
