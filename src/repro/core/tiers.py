"""Tiered KV block pools: device tier + host tier with compressed spill/fetch.

The paper's headline systems claim is that moving *raw* activations between
GPU and CPU accounts for 90-98.5% of decoding latency, and that moving
*compressed* KV instead is what breaks the capacity wall (abstract, Fig. 13
`gpu+cpu`).  PR 2's `PagedLayout` still assumes one flat device-resident
pool, so the only answer to exhaustion is preempt-and-recompute.  This
module adds the missing memory tier:

  ``TieredBlockPool``   generalizes `cache_layout.BlockAllocator` into a
                        *refcounted* allocator over two tiers — tier 0 is
                        the device/PIM pool, tier 1 a large host pool —
                        with a per-block residency state machine
                        (BLOCK_RESIDENT / BLOCK_SPILLED / BLOCK_IN_FLIGHT)
                        and LRU cold-victim selection.  Refcounts are the
                        groundwork for prefix sharing (copy-on-write block
                        tables, the next ROADMAP rung): today the engine
                        holds exactly one reference per block, and the
                        invariant suite checks counts return to zero.
  ``SpillCodec``        per-buffer encode/decode applied when a block
                        crosses the tier boundary: ``raw`` copies verbatim
                        (AQPIM PQ code rows are already ~int8 codes —
                        spilling them raw *is* the compressed traffic);
                        ``int8`` per-block asymmetric uniform quantization
                        reusing the SKVQ machinery in `core.baselines`.
  ``TransferLedger``    counts bytes crossing the tier boundary in each
                        direction (plus the raw-equivalent bytes), making
                        the paper's compressed-vs-raw traffic ratio a
                        directly measured quantity, and models the PCIe
                        time those transfers would cost.

`core.cache_layout.TieredLayout` composes these under the `CacheLayout`
protocol; `launch.scheduler.TieredScheduler` drives spill-instead-of-
recompute preemption on top.
"""
from __future__ import annotations

import collections
import dataclasses
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

# Residency states of a physical block's payload.  Device blocks are
# BLOCK_RESIDENT (decodable) or BLOCK_IN_FLIGHT (a fetch is materializing
# them; decoding would read garbage).  Host blocks are always BLOCK_SPILLED.
# Legal transitions: RESIDENT -spill-> SPILLED -prefetch-> IN_FLIGHT
# -fetch-complete-> RESIDENT.
BLOCK_RESIDENT = "RESIDENT"
BLOCK_SPILLED = "SPILLED"
BLOCK_IN_FLIGHT = "IN_FLIGHT"

DEVICE = 0   # tier 0: device/PIM block pool (decodable storage)
HOST = 1     # tier 1: large host pool (spill target, never decoded from)

_TIER_STATES = {DEVICE: (BLOCK_RESIDENT, BLOCK_IN_FLIGHT),
                HOST: (BLOCK_SPILLED,)}


#: Holder key for legacy owner-less `ref()` calls on a multi-holder block.
ANON = "<anon>"


@dataclasses.dataclass
class _BlockMeta:
  holders: collections.Counter          # owner -> hold count (multiset)
  state: str
  last_touch: int

  @property
  def refs(self) -> int:
    return sum(self.holders.values())


class TieredBlockPool:
  """Refcounted free-list allocator over two block tiers.

  Owners are opaque tags (the engine uses slot indices on tier 0 and request
  ids on tier 1; the prefix index a sentinel).  Since PR 4 a block's
  ownership is a *multiset of holders* — copy-on-write prefix sharing holds
  one published block from the index and from every request whose table maps
  it.  `alloc` hands out blocks with one hold; `ref`/`unref` adjust holds
  and a block returns to the free list only when the last holder lets go.
  Every transition is checked: double alloc, unref of a free block or of a
  hold the owner does not have, or an illegal residency transition raises —
  the invariants the hypothesis suite drives.
  """

  def __init__(self, device_blocks: int, host_blocks: int):
    if device_blocks <= 0:
      raise ValueError(f"device_blocks must be positive, got {device_blocks}")
    if host_blocks < 0:
      raise ValueError(f"host_blocks must be >= 0, got {host_blocks}")
    self.num_blocks = {DEVICE: device_blocks, HOST: host_blocks}
    self._free: Dict[int, collections.deque] = {
        DEVICE: collections.deque(range(device_blocks)),
        HOST: collections.deque(range(host_blocks))}
    self._meta: Dict[int, Dict[int, _BlockMeta]] = {DEVICE: {}, HOST: {}}
    self._clock = 0

  # -- accounting ------------------------------------------------------------
  def free_count(self, tier: int = DEVICE) -> int:
    return len(self._free[tier])

  def allocated_count(self, tier: int = DEVICE) -> int:
    return len(self._meta[tier])

  def refcount(self, i: int, tier: int = DEVICE) -> int:
    meta = self._meta[tier].get(i)
    return 0 if meta is None else meta.refs

  def state(self, i: int, tier: int = DEVICE) -> Optional[str]:
    meta = self._meta[tier].get(i)
    return None if meta is None else meta.state

  def holder_count(self, i: int, owner: Any, tier: int = DEVICE) -> int:
    meta = self._meta[tier].get(i)
    return 0 if meta is None else meta.holders.get(owner, 0)

  def owned(self, owner: Any, tier: int = DEVICE) -> List[int]:
    return [i for i, m in self._meta[tier].items()
            if m.holders.get(owner, 0) > 0]

  # -- allocation ------------------------------------------------------------
  def alloc(self, n: int, owner: Any = None, tier: int = DEVICE,
            state: Optional[str] = None) -> Optional[List[int]]:
    """Allocate `n` blocks (refcount 1); None (and no change) if unavailable."""
    if n < 0:
      raise ValueError(f"cannot allocate {n} blocks")
    state = state or (BLOCK_RESIDENT if tier == DEVICE else BLOCK_SPILLED)
    if state not in _TIER_STATES[tier]:
      raise ValueError(f"state {state} illegal on tier {tier}")
    if n > len(self._free[tier]):
      return None
    ids = [self._free[tier].popleft() for _ in range(n)]
    for i in ids:
      if i in self._meta[tier]:
        raise AssertionError(f"free list returned owned block {i}")
      self._meta[tier][i] = _BlockMeta(
          holders=collections.Counter({owner: 1}), state=state,
          last_touch=self._tick())
    return ids

  def ref(self, ids: Sequence[int], tier: int = DEVICE, owner: Any = None
          ) -> None:
    """Take an additional hold (prefix sharing / spill pinning).  `owner=None`
    (legacy) attributes the hold to the sole existing holder when there is
    exactly one, else to the anonymous holder."""
    for i in ids:
      meta = self._require(i, tier)
      key = owner
      if key is None:
        key = (next(iter(meta.holders)) if len(meta.holders) == 1 else ANON)
      meta.holders[key] += 1

  def unref(self, ids: Sequence[int], owner: Any = None, tier: int = DEVICE
            ) -> List[int]:
    """Drop one hold per id; blocks whose last hold is dropped return to the
    free list.  Returns the ids actually freed.  `owner=None` (legacy) drops
    the sole holder's hold (anonymous holds first) and refuses on a
    multi-owner block (ambiguous)."""
    freed = []
    for i in ids:
      meta = self._meta[tier].get(i)
      if meta is None:
        raise ValueError(f"unref of free tier-{tier} block {i} (double free)")
      key = owner
      if key is None:
        if meta.holders.get(ANON, 0) > 0:
          key = ANON
        elif len(meta.holders) == 1:
          key = next(iter(meta.holders))
        else:
          raise ValueError(
              f"tier-{tier} block {i} held by "
              f"{sorted(map(repr, meta.holders))}; anonymous unref is "
              f"ambiguous")
      if meta.holders.get(key, 0) <= 0:
        raise ValueError(
            f"tier-{tier} block {i} owned by "
            f"{sorted(map(repr, meta.holders))}, unreffed by {owner!r}")
      meta.holders[key] -= 1
      if meta.holders[key] == 0:
        del meta.holders[key]
      if not meta.holders:
        del self._meta[tier][i]
        self._free[tier].append(i)
        freed.append(i)
    return freed

  # BlockAllocator-compatible alias (TierView delegates here)
  def free(self, ids: Sequence[int], owner: Any = None, tier: int = DEVICE
           ) -> None:
    self.unref(ids, owner=owner, tier=tier)

  def reassign(self, ids: Sequence[int], old_owner: Any, new_owner: Any,
               tier: int = DEVICE) -> None:
    """Move one hold per block from `old_owner` to `new_owner` (fetch
    completion adopts prefetched/shared blocks into the destination slot's
    table).  Other holders (the prefix index, other slots) are untouched."""
    for i in ids:
      meta = self._require(i, tier)
      if meta.holders.get(old_owner, 0) <= 0:
        raise ValueError(
            f"tier-{tier} block {i} owned by "
            f"{sorted(map(repr, meta.holders))}, reassigned from "
            f"{old_owner!r}")
      meta.holders[old_owner] -= 1
      if meta.holders[old_owner] == 0:
        del meta.holders[old_owner]
      meta.holders[new_owner] += 1

  # -- residency state machine ----------------------------------------------
  def set_state(self, ids: Sequence[int], state: str, tier: int = DEVICE
                ) -> None:
    if state not in _TIER_STATES[tier]:
      raise ValueError(f"state {state} illegal on tier {tier}")
    for i in ids:
      meta = self._require(i, tier)
      if meta.state == state:
        continue
      legal = (meta.state, state) in ((BLOCK_IN_FLIGHT, BLOCK_RESIDENT),)
      if not legal:
        raise ValueError(
            f"illegal residency transition {meta.state} -> {state} on "
            f"tier-{tier} block {i}")
      meta.state = state

  def assert_state(self, ids: Sequence[int], state: str, tier: int = DEVICE
                   ) -> None:
    for i in ids:
      got = self._require(i, tier).state
      if got != state:
        raise AssertionError(
            f"tier-{tier} block {i} is {got}, expected {state}")

  # -- LRU -------------------------------------------------------------------
  def touch(self, ids: Sequence[int], tier: int = DEVICE) -> None:
    t = self._tick()
    for i in ids:
      self._require(i, tier).last_touch = t

  def owner_last_touch(self, owner: Any, tier: int = DEVICE) -> int:
    """Most recent touch over the owner's blocks (-1 if it owns none)."""
    touches = [m.last_touch for m in self._meta[tier].values()
               if m.holders.get(owner, 0) > 0]
    return max(touches) if touches else -1

  def lru_owner(self, owners: Sequence[Any], tier: int = DEVICE
                ) -> Optional[Any]:
    """Coldest owner: the one whose newest block touch is oldest."""
    if not owners:
      return None
    return min(owners, key=lambda o: self.owner_last_touch(o, tier))

  # -- invariants ------------------------------------------------------------
  def check(self) -> None:
    """Per tier: free list and meta map partition [0, num_blocks) exactly,
    refcounts are positive, and every state is legal for its tier."""
    for tier in (DEVICE, HOST):
      free = set(self._free[tier])
      owned = set(self._meta[tier])
      if len(free) != len(self._free[tier]):
        raise AssertionError(f"duplicate ids in tier-{tier} free list")
      if free & owned:
        raise AssertionError(
            f"tier-{tier} blocks both free and owned: {free & owned}")
      if free | owned != set(range(self.num_blocks[tier])):
        raise AssertionError(f"tier-{tier} allocator leaked/invented blocks")
      for i, meta in self._meta[tier].items():
        if meta.refs <= 0 or any(c <= 0 for c in meta.holders.values()):
          raise AssertionError(f"tier-{tier} block {i} held with refs<=0")
        if meta.state not in _TIER_STATES[tier]:
          raise AssertionError(
              f"tier-{tier} block {i} in illegal state {meta.state}")

  def _require(self, i: int, tier: int) -> _BlockMeta:
    meta = self._meta[tier].get(i)
    if meta is None:
      raise ValueError(f"tier-{tier} block {i} is not allocated")
    return meta

  def _tick(self) -> int:
    self._clock += 1
    return self._clock

  def __repr__(self) -> str:
    return (f"TieredBlockPool(device={self.allocated_count(DEVICE)}/"
            f"{self.num_blocks[DEVICE]}, host={self.allocated_count(HOST)}/"
            f"{self.num_blocks[HOST]})")


class TierView:
  """`BlockAllocator`-shaped view of one tier of a `TieredBlockPool`, so
  `cache_layout.BlockTableManager` runs unchanged over the device tier."""

  def __init__(self, pool: TieredBlockPool, tier: int = DEVICE):
    self.pool = pool
    self.tier = tier

  @property
  def num_blocks(self) -> int:
    return self.pool.num_blocks[self.tier]

  @property
  def free_count(self) -> int:
    return self.pool.free_count(self.tier)

  @property
  def allocated_count(self) -> int:
    return self.pool.allocated_count(self.tier)

  def alloc(self, n: int, owner: Any = None) -> Optional[List[int]]:
    return self.pool.alloc(n, owner=owner, tier=self.tier)

  def free(self, ids: Sequence[int], owner: Any = None) -> None:
    self.pool.unref(ids, owner=owner, tier=self.tier)

  def ref(self, ids: Sequence[int], owner: Any = None) -> None:
    self.pool.ref(ids, tier=self.tier, owner=owner)

  def refcount(self, i: int) -> int:
    return self.pool.refcount(i, tier=self.tier)

  def holder_count(self, i: int, owner: Any) -> int:
    return self.pool.holder_count(i, owner, tier=self.tier)

  def owned(self, owner: Any) -> List[int]:
    return self.pool.owned(owner, tier=self.tier)

  def check(self) -> None:
    self.pool.check()


# ---------------------------------------------------------------------------
# Spill codecs: what a buffer looks like while it lives on the host tier
# ---------------------------------------------------------------------------

class SpillCodec:
  """Encode/decode one buffer's blocks across the tier boundary.

  `encode` receives a stacked numpy array of blocks (n, ...) and returns an
  opaque payload plus the byte count that actually crosses the boundary;
  `decode` reconstructs the block stack.  Codecs are chosen *per buffer* by
  `CachePolicy.spill_codecs()` — PQ code rows spill verbatim (they are the
  compressed representation), exact KV spills raw or via int8.
  """
  key: str = "base"

  def encode(self, arr: np.ndarray) -> Tuple[Any, int]:
    raise NotImplementedError

  def decode(self, payload: Any, shape: Tuple[int, ...], dtype) -> np.ndarray:
    raise NotImplementedError


class RawSpillCodec(SpillCodec):
  """Verbatim copy: spilled bytes == resident bytes (lossless)."""
  key = "raw"

  def encode(self, arr: np.ndarray) -> Tuple[Any, int]:
    payload = np.array(arr, copy=True)
    return payload, payload.nbytes

  def decode(self, payload: Any, shape, dtype) -> np.ndarray:
    return np.asarray(payload, dtype=dtype).reshape(shape)


class Int8SpillCodec(SpillCodec):
  """Asymmetric int8 uniform quantization via the SKVQ machinery
  (`baselines.uniform_quantize` at bits=8, identity channel permutation,
  one quant group per trailing-axis row).  Lossy for float KV — opt-in via
  `CacheSpec.spill_codec='int8'`; integer buffers should spill raw instead.
  """
  key = "int8"

  def encode(self, arr: np.ndarray) -> Tuple[Any, int]:
    from repro.core import baselines        # jax-importing; keep lazy so the
    import jax.numpy as jnp                 # pool stays importable host-side
    x = np.asarray(arr, np.float32)         # bf16 (ml_dtypes) upcasts cleanly
    d = x.shape[-1]
    uq = baselines.uniform_quantize(
        jnp.asarray(x.reshape(-1, d)), bits=8, group=d, perm=jnp.arange(d))
    payload = dict(q=np.asarray(uq.q), scale=np.asarray(uq.scale),
                   zero=np.asarray(uq.zero))
    nbytes = sum(v.nbytes for v in payload.values())
    return payload, nbytes

  def decode(self, payload: Any, shape, dtype) -> np.ndarray:
    from repro.core import baselines
    import jax.numpy as jnp
    d = shape[-1]
    uq = baselines.UniformQuantized(
        q=jnp.asarray(payload["q"]), scale=jnp.asarray(payload["scale"]),
        zero=jnp.asarray(payload["zero"]), perm=jnp.arange(d), bits=8)
    rows = np.asarray(baselines.uniform_dequantize(uq, group=d))
    return rows.reshape(shape).astype(dtype)


class PackedSpillCodec(SpillCodec):
  """GGUF-style sub-byte block quantization over the flattened value stream.

  Layout per group of 32 consecutive values: f16 scale + f16 min (4 B
  header) followed by the bit-packed codes — q4 split-half packs a group
  into 16 B (0.625 B/value), q5 adds a fifth-bit mask plane (4 B/group,
  0.75 B/value), q8 stores one byte per code (1.125 B/value).  Against
  Int8SpillCodec's per-row f32 scale/zero (1 B/value + 8 B/row) this
  roughly halves the boundary traffic again.

  Tail groups are padded by replicating the final value — padding with
  zeros would widen the last group's dynamic range and degrade every real
  value in it — and the pad is trimmed on decode via the stored count.

  Scale/min are rounded through f16 *before* the codes are computed (the
  same discipline as kernels/packing.py), so decode reproduces exactly the
  values the encoder targeted.  numpy-pure: spill/fetch run host-side.
  """
  key = "packed"
  bits = 4
  GROUP = 32

  def encode(self, arr: np.ndarray) -> Tuple[Any, int]:
    x = np.asarray(arr, np.float32).reshape(-1)  # bf16 upcasts via ml_dtypes
    count = x.size
    pad = (-count) % self.GROUP
    if pad:
      x = np.concatenate([x, np.full((pad,), x[-1] if count else 0.0,
                                     np.float32)])
    xg = x.reshape(-1, self.GROUP)
    qmax = (1 << self.bits) - 1
    scale = ((xg.max(axis=1) - xg.min(axis=1)) / qmax).astype(np.float16)
    mn = xg.min(axis=1).astype(np.float16)
    s32 = scale.astype(np.float32)
    safe = np.where(s32 > 0, s32, 1.0)
    q = np.clip(np.rint((xg - mn.astype(np.float32)[:, None])
                        / safe[:, None]), 0, qmax).astype(np.uint8)
    payload = dict(scale=scale, mn=mn, count=count)
    half = self.GROUP // 2
    if self.bits == 4:
      payload["q"] = (q[:, :half] | (q[:, half:] << 4)).astype(np.uint8)
    elif self.bits == 5:
      # low nibbles in the q4 split-half layout + fifth-bit mask plane
      # (LSB-first within each byte, matching kernels/packing.pack_u5)
      lo = q & 0xF
      payload["q"] = (lo[:, :half] | (lo[:, half:] << 4)).astype(np.uint8)
      payload["hi"] = np.packbits(((q >> 4) & 1).astype(np.uint8), axis=1,
                                  bitorder="little")
    else:
      payload["q"] = q
    nbytes = sum(v.nbytes for k, v in payload.items() if k != "count")
    return payload, nbytes

  def decode(self, payload: Any, shape, dtype) -> np.ndarray:
    q = payload["q"]
    if self.bits in (4, 5):
      q = np.concatenate([q & 0xF, (q >> 4) & 0xF], axis=1)
    if self.bits == 5:
      bit = np.unpackbits(payload["hi"], axis=1,
                          bitorder="little")[:, :self.GROUP]
      q = q | (bit << 4)
    xg = (q.astype(np.float32) * payload["scale"].astype(np.float32)[:, None]
          + payload["mn"].astype(np.float32)[:, None])
    return xg.reshape(-1)[:payload["count"]].reshape(shape).astype(dtype)


class Q4SpillCodec(PackedSpillCodec):
  key = "q4"
  bits = 4


class Q5SpillCodec(PackedSpillCodec):
  key = "q5"
  bits = 5


class Q8SpillCodec(PackedSpillCodec):
  key = "q8"
  bits = 8


SPILL_CODECS: Dict[str, SpillCodec] = {
    c.key: c() for c in (RawSpillCodec, Int8SpillCodec,
                         Q4SpillCodec, Q5SpillCodec, Q8SpillCodec)}


def payload_checksum(payload: Any) -> int:
  """CRC32 over a spill payload's bytes (dict payloads folded key-sorted).

  The frame checksum for corruption detection on fetch: cheap, order
  deterministic, and codec-agnostic — raw arrays and dict payloads (packed
  q/scale/mn planes, int8 q/scale/zero) hash the same way.
  """
  crc = 0
  if isinstance(payload, dict):
    for k in sorted(payload):
      v = payload[k]
      if isinstance(v, np.ndarray):
        crc = zlib.crc32(np.ascontiguousarray(v).view(np.uint8).reshape(-1),
                         crc)
      else:
        crc = zlib.crc32(repr(v).encode(), crc)
  elif isinstance(payload, np.ndarray):
    crc = zlib.crc32(np.ascontiguousarray(payload).view(np.uint8).reshape(-1),
                     crc)
  else:
    crc = zlib.crc32(repr(payload).encode(), crc)
  return crc


class SpillPageCorruption(RuntimeError):
  """A spilled page's stored checksum no longer matches its payload bytes."""


def get_codec(key: str) -> SpillCodec:
  try:
    return SPILL_CODECS[key]
  except KeyError:
    raise KeyError(f"unknown spill codec {key!r}; available: "
                   f"{tuple(sorted(SPILL_CODECS))}") from None


# ---------------------------------------------------------------------------
# Transfer ledger: the measured communication claim
# ---------------------------------------------------------------------------

#: Modeled host link bandwidth (PCIe 4.0 x16 effective ~16 GB/s), the link
#: the paper's Fig. 11/13 latency model charges raw-activation movement to.
PCIE_GBPS = 16.0


@dataclasses.dataclass
class TransferLedger:
  """Bytes crossing the tier boundary, each direction, plus raw equivalents.

  `*_bytes` is what actually crosses (post-codec); `*_raw_bytes` is what the
  same traffic would cost uncompressed — their ratio is the paper's
  compressed-vs-raw communication claim, measured instead of modeled.
  """
  spill_bytes: int = 0        # device -> host, post-codec
  spill_raw_bytes: int = 0    # device -> host, uncompressed equivalent
  fetch_bytes: int = 0        # host -> device, post-codec
  fetch_raw_bytes: int = 0
  spill_blocks: int = 0
  fetch_blocks: int = 0
  spill_events: int = 0       # swap-out operations (whole-request granularity)
  fetch_events: int = 0
  fetch_aborts: int = 0       # IN_FLIGHT fetches rolled back (fault/cancel)
  pcie_gbps: float = PCIE_GBPS

  def record_spill(self, nbytes: int, raw_bytes: int, blocks: int) -> None:
    self.spill_bytes += nbytes
    self.spill_raw_bytes += raw_bytes
    self.spill_blocks += blocks
    self.spill_events += 1

  def record_fetch(self, nbytes: int, raw_bytes: int, blocks: int) -> None:
    self.fetch_bytes += nbytes
    self.fetch_raw_bytes += raw_bytes
    self.fetch_blocks += blocks
    self.fetch_events += 1

  @property
  def total_bytes(self) -> int:
    return self.spill_bytes + self.fetch_bytes

  @property
  def compression_ratio(self) -> float:
    """Post-codec / raw bytes over all boundary traffic (1.0 = no savings)."""
    raw = self.spill_raw_bytes + self.fetch_raw_bytes
    return self.total_bytes / raw if raw else 1.0

  @property
  def modeled_pcie_s(self) -> float:
    """Time the measured boundary traffic would occupy the host link."""
    return self.total_bytes / (self.pcie_gbps * 1e9)

  def transfer_s(self, nbytes: int) -> float:
    """Link time one transfer of `nbytes` occupies under the PCIe model —
    the per-event duration the virtual-clock engine draws its transfer
    completion times from (modeled_pcie_s is this summed over the run)."""
    return nbytes / (self.pcie_gbps * 1e9)

  def as_dict(self) -> dict:
    d = dataclasses.asdict(self)
    d["total_bytes"] = self.total_bytes
    d["compression_ratio"] = round(self.compression_ratio, 4)
    d["modeled_pcie_s"] = self.modeled_pcie_s
    return d

  def summary(self) -> str:
    return (f"spilled {self.spill_bytes} B ({self.spill_blocks} blocks, "
            f"{self.spill_events} events), fetched {self.fetch_bytes} B "
            f"({self.fetch_blocks} blocks, {self.fetch_events} events), "
            f"{self.compression_ratio:.2f}x of raw, "
            f"~{self.modeled_pcie_s * 1e3:.2f} ms PCIe")


@dataclasses.dataclass
class SpillRecord:
  """Host-tier residue of one swapped-out request.

  `pairs` preserves each spilled block's *logical* table index (ring-reuse
  leaves trash holes mid-row); `payloads` holds one codec payload per paged
  leaf; `resident_rows` the per-slot resident leaves (rings, codebooks) that
  would otherwise be overwritten by the slot's next tenant.  While a
  fetch-ahead is materializing the request, `device_ids`/`staged` hold the
  IN_FLIGHT destination blocks and decoded arrays.

  `shared_pairs` are prefix-shared blocks (held by the prefix index or
  other requests): they never cross the tier boundary — a pin hold keeps
  them device-resident while the request is swapped out, so a shared
  prefix costs the PCIe link nothing however many requests swap over it.
  """
  rid: int
  length: int
  hwm: int
  pairs: List[Tuple[int, int]]          # (logical_j, host_block_id)
  payloads: List[Optional[Tuple[str, Any, Tuple[int, ...], Any]]]
  resident_rows: List[Optional[np.ndarray]]
  state: str = BLOCK_SPILLED
  nbytes: int = 0                       # post-codec bytes on the host tier
  raw_bytes: int = 0                    # uncompressed-equivalent bytes
  device_ids: Optional[List[int]] = None
  staged: Optional[List[Optional[np.ndarray]]] = None
  shared_pairs: List[Tuple[int, int]] = dataclasses.field(
      default_factory=list)             # (logical_j, device_block_id)
  checksums: List[Optional[int]] = dataclasses.field(
      default_factory=list)             # per-payload CRC32 frame checksums

  @property
  def spill_owner(self) -> Tuple[str, int]:
    """Holder tag pinning `shared_pairs` on the device while swapped out."""
    return ("spillshare", self.rid)

  @property
  def host_ids(self) -> List[int]:
    return [hid for _, hid in self.pairs]

  @property
  def n_blocks(self) -> int:
    return len(self.pairs)


# ---------------------------------------------------------------------------
# Host-tier shard mirror (shard redundancy)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MirrorRecord:
  """Host-tier write-through copy of one active slot's pool pages.

  Shaped like a `SpillRecord` minus the host-block bookkeeping: the mirror
  is redundancy, not residency — it never occupies `TieredBlockPool` host
  blocks, so mirroring a shard cannot contend with the spill path for
  capacity.  `pairs` map each live logical table index to its *device*
  block id (the blocks a restore re-scatters into), `payloads` hold one
  spill-codec payload per paged leaf, `resident_rows` the per-slot resident
  leaves, and `checksums` the same CRC32 frame checksums spill frames carry
  — a bit-flipped mirror page is detected before any byte re-enters the
  device pool.
  """
  slot: int
  rid: int
  length: int
  hwm: int
  pairs: List[Tuple[int, int]]          # (logical_j, device_block_id)
  payloads: List[Optional[Tuple[str, Any, Tuple[int, ...], Any]]]
  resident_rows: List[Optional[np.ndarray]]
  checksums: List[Optional[int]] = dataclasses.field(default_factory=list)
  nbytes: int = 0                       # post-codec bytes held on the host
  raw_bytes: int = 0

  @property
  def device_block_ids(self) -> List[int]:
    return [bid for _, bid in self.pairs]

  def verify(self) -> None:
    """Raise `SpillPageCorruption` when any payload fails its checksum."""
    for payload, want in zip(self.payloads, self.checksums):
      if payload is None or want is None:
        continue
      got = payload_checksum(payload[1])
      if got != want:
        raise SpillPageCorruption(
            f"mirror page for request {self.rid} (slot {self.slot}) failed "
            f"its checksum: stored {want:#010x}, computed {got:#010x}")


class HostMirror:
  """Write-through host mirror of active slots' device pool pages.

  `--shard-redundancy host-mirror`: after every decode step the layout
  refreshes one `MirrorRecord` per active slot (encoded through the same
  spill codecs the tier boundary uses, CRC32-checksummed per frame).  When
  the watchdog confirms a shard death in heads mode — where every resident
  block loses a kv-head slice — a lost slot restores by decode + re-scatter
  under the replanned mesh instead of abort-and-recompute.  Counters feed
  the stats-json `shard_health` section and the `recovery.shard` bench.
  """

  def __init__(self) -> None:
    self.records: Dict[int, MirrorRecord] = {}
    self.writes = 0
    self.write_bytes = 0
    self.restores = 0
    self.restore_bytes = 0

  def put(self, rec: MirrorRecord) -> None:
    self.records[rec.slot] = rec
    self.writes += 1
    self.write_bytes += rec.nbytes

  def get(self, slot: int) -> Optional[MirrorRecord]:
    return self.records.get(slot)

  def drop(self, slot: int) -> None:
    self.records.pop(slot, None)

  def clear(self) -> None:
    self.records.clear()

  @property
  def resident_bytes(self) -> int:
    """Host bytes the live mirror currently holds (not cumulative)."""
    return sum(r.nbytes for r in self.records.values())

  def as_dict(self) -> dict:
    return dict(slots=sorted(self.records), writes=self.writes,
                write_bytes=self.write_bytes, restores=self.restores,
                restore_bytes=self.restore_bytes,
                resident_bytes=self.resident_bytes)
