"""Importance weights from attention scores (AQPIM §III-C, Eq. 1).

    w = sum( S[-t:, :], axis=0 )

where S is the (softmaxed, causal) attention-score matrix of the prefill and t is a
small window (paper: t = 32, shared with the sliding-window size).  Tokens that the
most recent queries attend to strongly get larger weights and therefore smaller
quantization error in the weighted k-means.

The paper computes w on the GPU during prefill "aligned with FlashAttention": only
the last t query rows are needed, so the cost is O(t*N*d) — negligible next to the
O(N^2*d) prefill.  We implement exactly that: a standalone chunked pass over keys
for the t most recent queries (numerically stable two-pass softmax).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common import Array


@functools.partial(jax.jit, static_argnames=("t", "chunk"))
def attention_importance_weights(
    q: Array,
    k: Array,
    scale: float,
    t: int = 32,
    chunk: int = 2048,
    length: Array | None = None,
) -> Array:
  """Per-token importance weights for one (batch, head).

  Args:
    q: (N, d) queries of the prefill (post-RoPE).
    k: (N, d) keys.
    scale: softmax scale (1/sqrt(d)).
    t: number of trailing queries to aggregate (Eq. 1 window).
    chunk: key-chunk size for the streaming pass.
    length: optional dynamic valid length (<= N); defaults to N.

  Returns:
    w: (N,) f32 weights; positions >= length get weight 0.
  """
  n, d = q.shape
  if length is None:
    length = jnp.asarray(n, jnp.int32)
  # the last t valid queries: positions length-t .. length-1
  q_start = jnp.maximum(length - t, 0)
  q_idx = q_start + jnp.arange(t)                      # (t,) may exceed; masked below
  q_valid = q_idx < length
  q_t = jnp.take(q, jnp.clip(q_idx, 0, n - 1), axis=0).astype(jnp.float32)

  n_chunks = (n + chunk - 1) // chunk
  n_pad = n_chunks * chunk

  def scores_for_chunk(c):
    k_start = c * chunk
    k_blk = jax.lax.dynamic_slice_in_dim(
        jnp.pad(k, ((0, n_pad - n), (0, 0))), k_start, chunk, axis=0
    ).astype(jnp.float32)
    s = (q_t @ k_blk.T) * scale                        # (t, chunk)
    kpos = k_start + jnp.arange(chunk)
    causal = kpos[None, :] <= q_idx[:, None]
    valid = (kpos[None, :] < length) & causal & q_valid[:, None]
    return jnp.where(valid, s, -jnp.inf)

  # pass 1: row max & denom
  def pass1(c, carry):
    row_max, denom = carry
    s = scores_for_chunk(c)
    new_max = jnp.maximum(row_max, jnp.max(s, axis=-1))
    denom = denom * jnp.exp(row_max - new_max) + jnp.sum(
        jnp.exp(s - new_max[:, None]), axis=-1)
    return new_max, denom

  row_max0 = jnp.full((t,), -jnp.inf, jnp.float32)
  denom0 = jnp.zeros((t,), jnp.float32)
  row_max, denom = jax.lax.fori_loop(0, n_chunks, pass1, (row_max0, denom0))
  denom = jnp.maximum(denom, 1e-30)

  # pass 2: accumulate column sums of softmax probabilities
  def pass2(c, w_acc):
    s = scores_for_chunk(c)
    p = jnp.exp(s - row_max[:, None]) / denom[:, None]   # (t, chunk)
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    col = jnp.sum(p, axis=0)                              # (chunk,)
    return jax.lax.dynamic_update_slice_in_dim(
        w_acc, jax.lax.dynamic_slice_in_dim(w_acc, c * chunk, chunk) + col,
        c * chunk, axis=0)

  w = jax.lax.fori_loop(0, n_chunks, pass2, jnp.zeros((n_pad,), jnp.float32))
  w = w[:n]
  pos = jnp.arange(n)
  return jnp.where(pos < length, w, 0.0)


def uniform_weights(n: int) -> Array:
  """Unweighted PQ baseline (ablation 'w/o weighting')."""
  return jnp.ones((n,), jnp.float32)
