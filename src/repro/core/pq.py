"""Product Quantization codec for activation (KV) compression (AQPIM §III-B).

A head-dim vector x in R^d is split into m subvectors of size dsub = d/m.  Each
subvector space has its own codebook of K centroids learned by (importance-weighted)
k-means.  A token is stored as m small integers (its per-subvector centroid ids),
giving a compression ratio of

    d * bytes(fp16) / (m * bytes(index))         e.g. 128*2 / (32*2)  = 4x (int16)
                                                  or  128*2 / (32*1)  = 8x (uint8, K<=256)

plus the (amortized, tiny) codebook itself.  The paper's defaults are m=32, K=512.

Codebooks here are *per attention head* (paper §III-G maps each head to its own HBM
stack); batching over heads/batch is done with vmap at the call sites.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common import Array
from repro.core import kmeans


@dataclasses.dataclass(frozen=True)
class PQConfig:
  """Static PQ hyperparameters (paper Table II/III defaults)."""
  m: int = 32                 # number of subvectors
  k: int = 512                # centroids per subvector codebook
  iters: int = 4              # k-means iterations (fixed; paper §III-B)
  index_dtype: jnp.dtype = jnp.int32  # storage dtype for indices (int32 in JAX;
                              # int16/uint8 on real HW — bytes accounted in benches)

  def dsub(self, head_dim: int) -> int:
    assert head_dim % self.m == 0, f"head_dim={head_dim} % m={self.m} != 0"
    return head_dim // self.m

  def index_bytes(self) -> int:
    """Bytes/index on target hardware (uint8 if K<=256 else int16)."""
    return 1 if self.k <= 256 else 2

  def compressed_token_bytes(self, head_dim: int, fp_bytes: int = 2) -> int:
    del head_dim, fp_bytes
    return self.m * self.index_bytes()

  def exact_token_bytes(self, head_dim: int, fp_bytes: int = 2) -> int:
    return head_dim * fp_bytes

  def compression_ratio(self, head_dim: int) -> float:
    return self.exact_token_bytes(head_dim) / self.compressed_token_bytes(head_dim)


def split(x: Array, m: int) -> Array:
  """(..., N, d) -> (..., N, m, dsub)."""
  *lead, n, d = x.shape
  return x.reshape(*lead, n, m, d // m)


def merge(x: Array) -> Array:
  """(..., N, m, dsub) -> (..., N, d)."""
  *lead, n, m, dsub = x.shape
  return x.reshape(*lead, n, m * dsub)


def encode(x: Array, codebook: Array) -> Array:
  """Assign each subvector to its nearest centroid.

  x: (N, d); codebook: (m, K, dsub) -> indices (N, m) int32.
  """
  m = codebook.shape[0]
  xs = split(x, m)                                    # (N, m, dsub)
  xs = jnp.swapaxes(xs, 0, 1)                         # (m, N, dsub)
  idx = jax.vmap(kmeans.assign_clusters)(xs, codebook)  # (m, N)
  return jnp.swapaxes(idx, 0, 1).astype(jnp.int32)    # (N, m)


def decode(indices: Array, codebook: Array) -> Array:
  """Reconstruct vectors from indices.  indices (N, m), codebook (m,K,dsub) -> (N,d)."""
  n, m = indices.shape
  gathered = jax.vmap(lambda cb, ix: cb[ix], in_axes=(0, 1), out_axes=1)(
      codebook, indices
  )                                                   # (N, m, dsub)
  return merge(gathered)


def build_codebook(
    x: Array,
    weights: Array,
    cfg: PQConfig,
    key: Optional[Array] = None,
    mask: Optional[Array] = None,
    init_codebook: Optional[Array] = None,
) -> Tuple[Array, Array]:
  """Learn a per-subvector weighted-kmeans codebook and encode x.

  Args:
    x: (N, d) tokens for one head.
    weights: (N,) importance weights (Eq. 1); pass ones for unweighted PQ.
    cfg: PQConfig.
    key: optional PRNG key (None -> deterministic strided init).
    mask: optional (N,) validity mask.
    init_codebook: optional (m, K, dsub) warm start (page-aware windowed
      clustering copies the previous window's centroids — paper Fig. 6 step 1).

  Returns:
    codebook (m, K, dsub) f32, indices (N, m) int32.
  """
  m = cfg.m
  xs = jnp.swapaxes(split(x, m), 0, 1)                # (m, N, dsub)

  if init_codebook is None:
    def fit(sub):
      return kmeans.weighted_kmeans(
          sub, weights, k=cfg.k, iters=cfg.iters, key=key, mask=mask
      )
    codebook, idx = jax.vmap(fit)(xs)
  else:
    def refine(sub, cb0):
      def body(_, cb):
        a = kmeans.assign_clusters(sub, cb)
        return kmeans._weighted_update(
            sub,
            jnp.where(mask, weights, 0.0) if mask is not None else weights,
            a,
            cb,
        )
      cb = jax.lax.fori_loop(0, cfg.iters, body, cb0.astype(jnp.float32))
      return cb, kmeans.assign_clusters(sub, cb)
    codebook, idx = jax.vmap(refine)(xs, init_codebook)

  return codebook, jnp.swapaxes(idx, 0, 1).astype(jnp.int32)


def quantization_mse(x: Array, codebook: Array, indices: Array) -> Array:
  """Mean squared reconstruction error (accuracy proxy for Tables II/III)."""
  recon = decode(indices, codebook)
  return jnp.mean((x.astype(jnp.float32) - recon) ** 2)
