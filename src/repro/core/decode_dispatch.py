"""Decode-kernel dispatch registry: which implementation runs the decode
attention hot path.

AQPIM's headline claim is that attention *directly on compressed codes* makes
decode faster, not slower — but that only holds if the serve path actually
runs the fused kernel instead of the pure-JAX oracle.  This module makes the
choice a first-class, string-keyed axis (mirroring `cache_registry`):

  ``xla``              the pure-JAX reference path (`core.pq_attention`,
                       `core.kv_cache`) — XLA fuses the gathers; bit-exact
                       oracle semantics; the only option for policies without
                       a kernel implementation (skvq, snapkv, ...).
  ``pallas``           compiled Mosaic kernels (`kernels/pq_decode.py`,
                       `kernels/paged_flash_decode.py`).  TPU only — on CPU
                       there is nothing to compile them to, so resolution
                       fails loudly instead of silently interpreting at 100x
                       slowdown.
  ``pallas-interpret`` the same kernels through the Pallas interpreter: runs
                       anywhere (CPU CI included), numerically identical
                       kernel semantics, debugging/parity-testing speed.
  ``auto``             pallas on TPU, xla elsewhere — the default; a fresh
                       checkout behaves exactly like the pre-dispatch code on
                       CPU and picks up the kernels on real hardware.

Resolution happens once, at policy/layout construction (`resolve(name)`), so
the serve engine compiles exactly one decode program per run; there is no
per-step branching.  Policies consult the resolved `DecodeDispatch` inside
`append_and_attend` (dense storage) and layouts use it to choose between the
dense gather->decode->scatter program and the block-table-native program
(`core.cache_layout.PagedLayout`).

Kept import-light (no repro.core imports) so it sits below `cache_api` and
`configs.base` without cycles.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class DecodeDispatch:
  """Resolved decode-kernel choice.

  `use_pallas` selects the kernel implementations; `interpret` is the Pallas
  interpret flag those kernels receive (always explicit after resolution —
  never backend-guessed per call, so a serve run cannot mix modes).
  """
  name: str
  use_pallas: bool
  interpret: bool = False

  @property
  def key(self) -> str:
    """Stable identifier for stats/bench records."""
    if not self.use_pallas:
      return "xla"
    return "pallas-interpret" if self.interpret else "pallas"


_RESOLVERS: Dict[str, Callable[[], DecodeDispatch]] = {}


def register(name: str) -> Callable[[Callable[[], DecodeDispatch]],
                                    Callable[[], DecodeDispatch]]:
  def deco(fn: Callable[[], DecodeDispatch]) -> Callable[[], DecodeDispatch]:
    if name in _RESOLVERS and _RESOLVERS[name] is not fn:
      raise ValueError(f"decode kernel {name!r} already registered")
    _RESOLVERS[name] = fn
    return fn
  return deco


def names() -> Tuple[str, ...]:
  return tuple(sorted(_RESOLVERS))


def validate(name: str) -> None:
  """Cheap config-time check (no backend query): is the key known?"""
  if name not in _RESOLVERS:
    raise ValueError(
        f"unknown decode kernel {name!r}; available: {names()}")


def resolve(name: str) -> DecodeDispatch:
  """Resolve a registry key against the current backend."""
  validate(name)
  return _RESOLVERS[name]()


def resolve_for_mesh(dispatch: DecodeDispatch, shard_mode: str
                     ) -> DecodeDispatch:
  """Second, mesh-aware resolution stage for the sharded serve path.

  `shard_mode` is the resolved `parallel.serve_sharding.ShardPlan.mode`
  (kept a plain string so this module stays import-light).  Heads-mode
  sharding keeps whatever the backend stage picked — the paged kernels are
  head-shape-generic and each shard simply streams its own head slice of
  the pool.  The seq split-K fallback lives only in the dense xla program,
  so an explicitly requested kernel dispatch fails loudly there while
  `auto`'s backend pick quietly degrades to xla (the same doctrine as
  `auto` on CPU).
  """
  if shard_mode in ("none", "heads") or not dispatch.use_pallas:
    return dispatch
  if dispatch.name != "auto":
    raise ValueError(
        f"--decode-kernel {dispatch.name} cannot run under sequence "
        f"split-K sharding (kv heads not divisible by the mesh model "
        f"axis): the split lives in the dense xla program; use 'auto' or "
        f"'xla', or pick a mesh size dividing the kv heads")
  return DecodeDispatch(name=dispatch.name, use_pallas=False)


@register("xla")
def _xla() -> DecodeDispatch:
  return DecodeDispatch(name="xla", use_pallas=False)


@register("pallas")
def _pallas() -> DecodeDispatch:
  if jax.default_backend() != "tpu":
    raise ValueError(
        "--decode-kernel pallas compiles Mosaic kernels and needs a TPU "
        "backend; use 'pallas-interpret' (runs anywhere, slowly) or 'auto' "
        f"(xla on {jax.default_backend()!r})")
  return DecodeDispatch(name="pallas", use_pallas=True, interpret=False)


@register("pallas-interpret")
def _pallas_interpret() -> DecodeDispatch:
  return DecodeDispatch(name="pallas-interpret", use_pallas=True,
                        interpret=True)


@register("auto")
def _auto() -> DecodeDispatch:
  if jax.default_backend() == "tpu":
    return DecodeDispatch(name="auto", use_pallas=True, interpret=False)
  return DecodeDispatch(name="auto", use_pallas=False)
