"""Token-aligned radix index over published KV block chains (prefix cache).

Serving traffic is dominated by requests that share long system/context
prompts (LoL-PIM/PIMphony frame exactly this long-context pressure as the
PIM serving bottleneck).  This module is the lookup structure behind
copy-on-write prefix sharing: it maps *prompt token prefixes*, at KV-block
granularity, to live physical block ids in the paged pool, so admission can
`ref()` every matched block into a new request's table instead of
re-prefilling and re-allocating it.

Two kinds of entries, published at admission time (right after a request's
prefill, when the blocks still hold exactly the prefill-time state):

  chain nodes   a radix trie keyed by whole token blocks.  A node at depth
                j holds the physical block storing paged tokens
                [j*block, (j+1)*block) of every prompt that shares this
                token prefix.  Sound only for policies whose prefilled
                per-position state is *causal* (`CachePolicy.
                prefix_shareable`: exact-store codecs) — a position's KV
                must not depend on later prompt tokens.
  full entries  keyed by the entire prompt.  These capture everything a
                bit-exact resume needs — the whole block chain, the
                per-slot resident leaves (AQPIM's rings and codebooks),
                and the first greedy token — so policies whose prefill
                couples positions (PQ clustering, SnapKV importance) still
                hit when the *whole* prompt repeats, which real traffic
                does constantly (retries, regenerate, multi-turn replays).

The index takes one pool hold per block per entry (owner
``INDEX_OWNER``); pool-side `ref`/`unref` are performed by the owning
layout, which calls `evict_for`/`clear` and releases whatever holds this
structure hands back.  Eviction is LRU and prefers *unreferenced leaves*:
a block no running request maps is reclaimed before one that is hot in
some slot's table.

Pure host-side Python/NumPy — no jax imports — so the trie invariants can
be property-tested without building a model.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: Pool-hold owner tag for every block the index keeps alive.
INDEX_OWNER = "<prefix-index>"


class _Node:
  """One published token block: trie edge label = its token tuple."""
  __slots__ = ("tokens", "parent", "children", "block_id", "last_hit")

  def __init__(self, tokens: Tuple[int, ...], parent: Optional["_Node"],
               block_id: int, last_hit: int):
    self.tokens = tokens
    self.parent = parent
    self.children: Dict[Tuple[int, ...], "_Node"] = {}
    self.block_id = block_id
    self.last_hit = last_hit


@dataclasses.dataclass
class FullEntry:
  """Bit-exact resume state for one exact prompt (published post-prefill).

  `pairs` mirrors the slot's live table (logical_j, block_id); the block at
  `tail_j` (the partial last block, if any) is the one the donor keeps
  writing during decode — a hit must `cow_fork` it, never map it shared.
  `resident_rows` are host copies of the per-slot RESIDENT leaves (PQ
  rings/codebooks; empty-None list for all-paged policies).  `first_token`
  is the greedy argmax of the prefill logits, so a full hit skips prefill
  entirely.
  """
  tokens: Tuple[int, ...]
  pairs: List[Tuple[int, int]]
  hwm: int
  resident_rows: List[Optional[np.ndarray]]
  first_token: int
  tail_j: Optional[int]
  last_hit: int = 0

  @property
  def block_ids(self) -> List[int]:
    return [bid for _, bid in self.pairs]


class PrefixIndex:
  """Radix trie + full-prompt map over published block chains."""

  def __init__(self, block: int, budget_blocks: int):
    if block <= 0:
      raise ValueError(f"block must be positive, got {block}")
    if budget_blocks < 0:
      raise ValueError(f"budget_blocks must be >= 0, got {budget_blocks}")
    self.block = block
    self.budget_blocks = budget_blocks
    self._root = _Node((), None, -1, 0)
    self._full: Dict[Tuple[int, ...], FullEntry] = {}
    self._holds: collections.Counter = collections.Counter()  # bid -> holds
    self._clock = 0
    # observability (engine stats / bench pull these)
    self.hits = 0
    self.full_hits = 0
    self.hit_tokens = 0
    self.evicted_blocks = 0

  # -- introspection ---------------------------------------------------------
  @property
  def held_blocks(self) -> int:
    """Distinct physical blocks this index keeps alive (the budget unit)."""
    return len(self._holds)

  def holds(self, block_id: int) -> int:
    return self._holds.get(block_id, 0)

  @property
  def chain_nodes(self) -> int:
    n = 0
    stack = [self._root]
    while stack:
      node = stack.pop()
      n += len(node.children)
      stack.extend(node.children.values())
    return n

  @property
  def full_entries(self) -> int:
    return len(self._full)

  # -- lookup ----------------------------------------------------------------
  def _blocks_of(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
    """Whole token blocks of a prompt (the trie's edge labels)."""
    toks = tuple(int(t) for t in tokens)
    return [toks[j:j + self.block]
            for j in range(0, len(toks) - len(toks) % self.block, self.block)]

  def match(self, tokens: Sequence[int], max_tokens: Optional[int] = None,
            touch: bool = True) -> List[int]:
    """Physical block ids of the longest published chain prefixing `tokens`.

    `max_tokens` caps the match (the engine passes len(tokens)-1 so at
    least one suffix token is always recomputed for its logits).  Matched
    nodes are LRU-touched unless `touch=False` — read-only admissibility
    probes (schedulers walk the whole queue every step) must not refresh
    recency, or a never-admitted queued prompt would pin its chain against
    eviction forever.
    """
    limit = len(tokens) if max_tokens is None else min(max_tokens,
                                                       len(tokens))
    ids: List[int] = []
    node = self._root
    for blk in self._blocks_of(tokens[:limit]):
      child = node.children.get(blk)
      if child is None:
        break
      node = child
      ids.append(node.block_id)
    if ids and touch:
      self._touch_chain(node)
    return ids

  def get_full(self, tokens: Sequence[int], touch: bool = True
               ) -> Optional[FullEntry]:
    entry = self._full.get(tuple(int(t) for t in tokens))
    if entry is not None and touch:
      entry.last_hit = self._tick()
    return entry

  def record_hit(self, n_tokens: int, full: bool = False) -> None:
    self.hits += 1
    self.hit_tokens += n_tokens
    if full:
      self.full_hits += 1

  # -- publish ---------------------------------------------------------------
  def extend(self, tokens: Sequence[int], block_ids: Sequence[int]
             ) -> List[int]:
    """Publish a prompt's whole-block chain.  `block_ids[j]` is the physical
    block holding token block j.  Existing nodes win (their block already
    serves other requests); only *newly inserted* nodes take an index hold —
    the returned ids are exactly the holds the caller must `ref` in the
    pool (under INDEX_OWNER).
    """
    blks = self._blocks_of(tokens)
    if len(block_ids) > len(blks):
      raise ValueError(
          f"{len(block_ids)} block ids for {len(blks)} whole token blocks")
    new_holds: List[int] = []
    node = self._root
    t = self._tick()
    for blk, bid in zip(blks, block_ids):
      child = node.children.get(blk)
      if child is None:
        child = _Node(blk, node, int(bid), t)
        node.children[blk] = child
        self._holds[int(bid)] += 1
        new_holds.append(int(bid))
      else:
        child.last_hit = t
      node = child
    return new_holds

  def put_full(self, entry: FullEntry) -> List[int]:
    """Publish a full-prompt entry; returns the pool holds taken (one per
    block — every block of the entry, tail included).  An existing entry
    for the same prompt wins (first publisher's state is already live)."""
    key = entry.tokens
    if key in self._full:
      self._full[key].last_hit = self._tick()
      return []
    entry.last_hit = self._tick()
    self._full[key] = entry
    holds: List[int] = []
    for bid in entry.block_ids:
      self._holds[bid] += 1
      holds.append(bid)
    return holds

  # -- snapshot (crash-safe restart) -----------------------------------------
  def chain_paths(self) -> List[Tuple[Tuple[int, ...], List[int]]]:
    """Root-to-leaf (tokens, block_ids) paths.  The trie is fully
    determined by its leaf paths, so re-`extend`ing each one (with block
    ids remapped to the restored pool's allocation) rebuilds an identical
    structure — interior nodes dedup on the shared prefixes."""
    out: List[Tuple[Tuple[int, ...], List[int]]] = []
    stack: List[Tuple[_Node, List[int], List[int]]] = [(self._root, [], [])]
    while stack:
      node, toks, ids = stack.pop()
      if not node.children:
        if node is not self._root:
          out.append((tuple(toks), ids))
        continue
      for blk, child in sorted(node.children.items()):
        stack.append((child, toks + list(blk), ids + [child.block_id]))
    return out

  def full_values(self) -> List[FullEntry]:
    """Published full-prompt entries, insertion-ordered (snapshot view)."""
    return list(self._full.values())

  # -- eviction --------------------------------------------------------------
  def evict_for(self, incoming_blocks: int, in_use=None) -> List[int]:
    """Make room for `incoming_blocks` new holds under the budget; returns
    the pool holds released (caller unrefs them, owner=INDEX_OWNER)."""
    if self.budget_blocks <= 0:
      return []
    return self.shrink_to(max(self.budget_blocks - incoming_blocks, 0),
                          in_use)

  def shrink_to(self, target_blocks: int, in_use=None) -> List[int]:
    """Evict until at most `target_blocks` distinct blocks are held;
    returns the pool holds released (caller unrefs, owner=INDEX_OWNER).

    Victims are LRU over evictable units — trie *leaves* (an interior node
    is pinned by its descendants) and full entries — preferring units whose
    blocks no request currently maps (`in_use(block_id) -> bool`).  May
    stop early only when nothing evictable remains.
    """
    released: List[int] = []
    guard = 0
    while self.held_blocks > target_blocks:
      guard += 1
      if guard > 100_000:
        raise AssertionError("prefix-index eviction failed to converge")
      victim = self._coldest_unit(in_use)
      if victim is None:
        break
      released.extend(self._drop_unit(victim))
    return released

  def clear(self) -> List[int]:
    """Drop every entry; returns all pool holds to release (one id per
    hold, duplicates included)."""
    released: List[int] = []
    for bid, n in self._holds.items():
      released.extend([bid] * n)
    self._holds.clear()
    self._root = _Node((), None, -1, 0)
    self._full.clear()
    return released

  def _leaves(self) -> List[_Node]:
    out = []
    stack = list(self._root.children.values())
    while stack:
      node = stack.pop()
      if node.children:
        stack.extend(node.children.values())
      else:
        out.append(node)
    return out

  def _coldest_unit(self, in_use):
    """(kind, unit) with the best eviction score, or None when empty."""
    used = in_use if in_use is not None else (lambda bid: False)
    best = None
    best_key = None
    for node in self._leaves():
      key = (bool(used(node.block_id)), node.last_hit)
      if best_key is None or key < best_key:
        best, best_key = ("node", node), key
    for entry in self._full.values():
      key = (any(used(b) for b in entry.block_ids), entry.last_hit)
      if best_key is None or key < best_key:
        best, best_key = ("full", entry), key
    return best

  def _drop_unit(self, unit) -> List[int]:
    kind, obj = unit
    released: List[int] = []
    if kind == "node":
      parent = obj.parent
      del parent.children[obj.tokens]
      released.append(self._drop_hold(obj.block_id))
    else:
      del self._full[obj.tokens]
      for bid in obj.block_ids:
        released.append(self._drop_hold(bid))
    return released

  def _drop_hold(self, bid: int) -> int:
    if self._holds.get(bid, 0) <= 0:
      raise AssertionError(f"index released a hold it never took on {bid}")
    self._holds[bid] -= 1
    if self._holds[bid] == 0:
      del self._holds[bid]
    self.evicted_blocks += 1
    return bid

  # -- internals -------------------------------------------------------------
  def _touch_chain(self, node: _Node) -> None:
    t = self._tick()
    while node is not None and node.parent is not None:
      node.last_hit = t
      node = node.parent

  def _tick(self) -> int:
    self._clock += 1
    return self._clock

  def check(self) -> None:
    """Structural invariants: holds match entries exactly, parents link."""
    holds = collections.Counter()
    stack = [self._root]
    while stack:
      node = stack.pop()
      for blk, child in node.children.items():
        if child.tokens != blk or child.parent is not node:
          raise AssertionError("trie edge/parent linkage broken")
        if len(blk) != self.block:
          raise AssertionError(f"edge label of {len(blk)} tokens "
                               f"(block={self.block})")
        holds[child.block_id] += 1
        stack.append(child)
    for entry in self._full.values():
      for bid in entry.block_ids:
        holds[bid] += 1
    if holds != self._holds:
      raise AssertionError(
          f"index hold ledger drifted: {dict(self._holds)} vs entries "
          f"{dict(holds)}")

  def __repr__(self) -> str:
    return (f"PrefixIndex(block={self.block}, nodes={self.chain_nodes}, "
            f"full={self.full_entries}, held={self.held_blocks}/"
            f"{self.budget_blocks})")
