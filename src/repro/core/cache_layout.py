"""`CacheLayout` API: physical KV storage as fixed-size token blocks.

AQPIM's point is breaking the PIM *capacity wall*: the KV cache has to fit
and move inside banked memory, which means block-granular placement, not one
monolithic `(B, H, N, D)` slab per request (paper §II/§IV; LoL-PIM/PIMphony
make the same bank-partitioned, capacity-managed layout central to
long-context PIM serving).  This module splits *what* is cached (a
`CachePolicy` codec — exact, AQPIM pq, skvq, ...) from *where* it lives:

  ``ContiguousLayout``  one capacity-sized slab per engine slot (PR 1
                        behavior, the default);
  ``PagedLayout``       a shared pool of fixed-size token blocks with a
                        `BlockAllocator` and per-request block tables —
                        alloc/free/gather/scatter, ring-reuse for the
                        streaming window.

A layout pages *any* policy's state through the codec surface on
`CachePolicy` (`paged_axes` / `token_extent` / `paged_capacity`): AQPIM's
PQ codes page exactly the way exact KV does, while its codebooks and
sink/recent rings stay resident.  ``bytes()`` on a layout reports the *true
allocated-block footprint*, not capacity.

Layouts are selected by string key via `repro.core.cache_registry`
(`make_layout("paged", model, max_batch)`); the serve engine exposes them as
`--cache-layout` and drives admission through `repro.launch.scheduler`.

The numerical core (blockify/unblockify/gather_blocks/scatter_blocks) lives
in `core.kv_cache`; everything here composes those into three jitted
programs (admit-scatter, gather->decode->scatter, plus the contiguous
slot-insert) so paging adds no per-step recompilation.
"""
from __future__ import annotations

import collections
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache_registry
from repro.core import kv_cache as kvc
from repro.core.cache_api import RESIDENT


class BlockAllocator:
  """Free-list allocator over `num_blocks` physical token blocks.

  Owners are opaque tags (the engine uses slot indices).  Every transition is
  checked: allocating an owned block, freeing a free block, or freeing with
  the wrong owner raises — the invariants the hypothesis suite drives.
  """

  def __init__(self, num_blocks: int):
    if num_blocks <= 0:
      raise ValueError(f"num_blocks must be positive, got {num_blocks}")
    self.num_blocks = num_blocks
    self._free: collections.deque = collections.deque(range(num_blocks))
    self._owner: Dict[int, Any] = {}

  @property
  def free_count(self) -> int:
    return len(self._free)

  @property
  def allocated_count(self) -> int:
    return len(self._owner)

  def alloc(self, n: int, owner: Any = None) -> Optional[List[int]]:
    """Allocate `n` blocks for `owner`; None (and no change) if unavailable."""
    if n < 0:
      raise ValueError(f"cannot allocate {n} blocks")
    if n > len(self._free):
      return None
    ids = [self._free.popleft() for _ in range(n)]
    for i in ids:
      if i in self._owner:
        raise AssertionError(f"free list returned owned block {i}")
      self._owner[i] = owner
    return ids

  def free(self, ids: Sequence[int], owner: Any = None) -> None:
    for i in ids:
      if i not in self._owner:
        raise ValueError(f"double free of block {i}")
      if owner is not None and self._owner[i] != owner:
        raise ValueError(
            f"block {i} owned by {self._owner[i]!r}, freed by {owner!r}")
      del self._owner[i]
      self._free.append(i)

  def owned(self, owner: Any) -> List[int]:
    return [i for i, o in self._owner.items() if o == owner]

  def check(self) -> None:
    """Free list and owner map must partition [0, num_blocks) exactly."""
    free = set(self._free)
    owned = set(self._owner)
    if len(free) != len(self._free):
      raise AssertionError("duplicate ids in free list")
    if free & owned:
      raise AssertionError(f"blocks both free and owned: {free & owned}")
    if free | owned != set(range(self.num_blocks)):
      raise AssertionError("allocator leaked or invented blocks")


class BlockTableManager:
  """Host-side paged bookkeeping: per-slot block tables over an allocator.

  Pure NumPy/Python — no device storage — so allocator/table invariants can
  be property-tested against random admit/grow/reclaim/release traffic
  without building a model.  Logical block j of a slot covers paged tokens
  [j*block, (j+1)*block); unallocated entries hold the trash sentinel
  (`num_blocks`), which physically exists in the pool so gathers/scatters of
  not-yet-filled blocks stay in bounds and never touch another request.
  """

  def __init__(self, num_blocks: int, blocks_per_req: int, max_slots: int,
               block: int, policy):
    self.allocator = BlockAllocator(num_blocks)
    self.block = block
    self.blocks_per_req = blocks_per_req
    self.trash = num_blocks
    self.tables = np.full((max_slots, blocks_per_req), self.trash, np.int32)
    self._hwm = np.zeros(max_slots, np.int64)   # logical blocks ever grown to
    self.policy = policy
    self.peak_allocated = 0

  @property
  def free_count(self) -> int:
    return self.allocator.free_count

  @property
  def allocated_count(self) -> int:
    return self.allocator.allocated_count

  def blocks_for(self, length: int) -> int:
    """Blocks needed to hold `length` cached tokens under this codec."""
    return -(-self.policy.token_extent(int(length)) // self.block)

  def need_blocks(self, slot: int, length: int) -> int:
    return max(self.blocks_for(length) - int(self._hwm[slot]), 0)

  def admit(self, slot: int, length: int) -> bool:
    if self._hwm[slot] != 0 or (self.tables[slot] != self.trash).any():
      raise AssertionError(f"slot {slot} admitted while occupied")
    return self.ensure(slot, length)

  def ensure(self, slot: int, length: int) -> bool:
    """Grow slot to cover `length` tokens; False (no change) on exhaustion."""
    need = self.need_blocks(slot, length)
    if need == 0:
      return True
    ids = self.allocator.alloc(need, owner=slot)
    if ids is None:
      return False
    hwm = int(self._hwm[slot])
    self.tables[slot, hwm:hwm + need] = ids
    self._hwm[slot] = hwm + need
    self.peak_allocated = max(self.peak_allocated, self.allocated_count)
    return True

  def reclaim(self, slot: int, length: int) -> int:
    """Ring-reuse: free blocks the codec has masked out forever (e.g. the
    streaming window's aged-out tokens).  Returns blocks freed."""
    dead = self.policy.dead_below(int(length))
    if dead <= 0:
      return 0
    first = -(-self.policy.pinned_tokens() // self.block)
    last = min(dead // self.block, int(self._hwm[slot]))
    freed = 0
    for j in range(first, last):
      pid = int(self.tables[slot, j])
      if pid != self.trash:
        self.allocator.free([pid], owner=slot)
        self.tables[slot, j] = self.trash
        freed += 1
    return freed

  def release(self, slot: int) -> None:
    ids = [int(x) for x in self.tables[slot] if x != self.trash]
    if ids:
      self.allocator.free(ids, owner=slot)
    self.tables[slot, :] = self.trash
    self._hwm[slot] = 0

  def check_invariants(self) -> None:
    self.allocator.check()
    live = self.tables[self.tables != self.trash]
    if len(set(live.tolist())) != live.size:
      raise AssertionError("physical block mapped by two table entries")
    for slot in range(self.tables.shape[0]):
      row = set(self.tables[slot][self.tables[slot] != self.trash].tolist())
      if row != set(self.allocator.owned(slot)):
        raise AssertionError(
            f"slot {slot} table/owner mismatch: {row} vs "
            f"{set(self.allocator.owned(slot))}")


class CacheLayout:
  """Physical-storage protocol between a built `Model` and the serve engine.

  The engine never touches cache trees directly anymore; it asks the layout
  to `admit` a prefilled request into a slot, `ensure` growth room before a
  decode step, `decode` one batched step over the layout's own storage, and
  `release` on finish.  Block-pool methods are no-ops for layouts without a
  pool, so schedulers can query them uniformly.
  """
  name: str = "base"

  # -- admission / lifetime --------------------------------------------------
  def fits(self, total_len: int, prompt_len: int = 0) -> bool:
    """Can a request of `total_len` cached tokens ever be served alone?"""
    return True

  def can_admit(self, prompt_len: int, total_len: Optional[int] = None
                ) -> bool:
    """Is there storage to admit a prompt of this length right now?
    `total_len` (prompt + max new tokens) lets pooled layouts keep one
    block of growth headroom and avoid admit->preempt thrash."""
    return True

  def admit(self, slot: int, slot_cache: Any, prompt_len: int) -> None:
    raise NotImplementedError

  def release(self, slot: int) -> None:
    raise NotImplementedError

  # -- per-step growth -------------------------------------------------------
  def need_blocks(self, slot: int, target_len: int) -> int:
    return 0

  def ensure(self, slot: int, target_len: int) -> bool:
    return True

  def reclaim(self, slot: int, length: int) -> int:
    return 0

  @property
  def free_blocks(self) -> int:
    return 0

  # -- compute ---------------------------------------------------------------
  def decode(self, params: Any, cur: np.ndarray, lengths: np.ndarray):
    """Run one batched decode step over this layout's storage; returns logits."""
    raise NotImplementedError

  def bytes(self, active_slots: int = 0) -> dict:
    raise NotImplementedError

  def __repr__(self) -> str:
    return f"{type(self).__name__}()"


@cache_registry.register_layout("contiguous")
class ContiguousLayout(CacheLayout):
  """PR 1 storage: one capacity-sized slab per slot, batched tree (L, B, ...).

  Admission writes a prefilled slot cache into batch row `slot` via a donated
  dynamic-update; decode donates the whole tree.  `bytes()` is honest about
  what this layout costs: every slot pays full capacity whether or not a
  short request sits in it — the number paging exists to shrink.
  """

  def __init__(self, model, max_batch: int, *, block_size: Optional[int] = None,
               num_blocks: Optional[int] = None):
    del block_size, num_blocks   # no block pool
    self.model = model
    self.max_batch = max_batch
    self.storage = model.init_cache(max_batch)
    self._decode_fused = jax.jit(model.decode_step, donate_argnums=(2,))
    self._insert = jax.jit(
        lambda cache, c1, slot: jax.tree_util.tree_map(
            lambda c, x: jax.lax.dynamic_update_slice_in_dim(
                c, x.astype(c.dtype), slot, axis=1), cache, c1),
        donate_argnums=(0,))

  def admit(self, slot: int, slot_cache: Any, prompt_len: int) -> None:
    del prompt_len  # slabs are capacity-sized regardless
    self.storage = self._insert(self.storage, slot_cache,
                                jnp.asarray(slot, jnp.int32))

  def release(self, slot: int) -> None:
    pass  # the slab is overwritten by the next admit

  def decode(self, params, cur, lengths):
    logits, self.storage = self._decode_fused(
        params, jnp.asarray(cur), self.storage, jnp.asarray(lengths))
    return logits

  def bytes(self, active_slots: int = 0) -> dict:
    total = sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(self.storage))
    per_slot = total // max(self.max_batch, 1)
    return dict(kind="contiguous", total_bytes=total,
                per_slot_bytes=per_slot, capacity_bytes=total,
                active_bytes=active_slots * per_slot)


@cache_registry.register_layout("paged")
class PagedLayout(CacheLayout):
  """Block-pooled storage: per-request block tables over a shared pool.

  Every token-axis leaf of the policy state (exact K/V slabs, snapkv weights,
  AQPIM PQ code rows) is stored as `(P+1, ..., block, ...)` physical blocks —
  index P is the trash block backing unallocated table entries — while
  resident leaves (codebooks, sink/recent rings) stay per-slot.  One jitted
  program fuses block-gather -> decode_step -> block-scatter, so the dense
  per-request view the vmapped cores consume never materializes outside the
  compiled step.
  """

  def __init__(self, model, max_batch: int, *, block_size: Optional[int] = None,
               num_blocks: Optional[int] = None):
    policy = model.cache_policy
    if policy is None:
      raise ValueError("paged layout needs a KV cache policy "
                       "(attn-free families have no KV cache)")
    self.model = model
    self.max_batch = max_batch
    self.block = int(block_size or policy.spec.block or 16)
    cap = policy.paged_capacity()
    if self.block <= 0 or cap % self.block:
      raise ValueError(
          f"paged token capacity {cap} not divisible by block size "
          f"{self.block} ({type(policy).__name__})")
    self.blocks_per_req = cap // self.block
    self.num_blocks = int(num_blocks or max_batch * self.blocks_per_req)
    self.manager = BlockTableManager(
        self.num_blocks, self.blocks_per_req, max_batch, self.block, policy)
    self._axes = policy.paged_axes()

    template = model.init_cache(max_batch)

    def storage_leaf(ax, leaf):
      if ax == RESIDENT:
        return jnp.array(leaf)       # (L, B, ...) per-slot resident
      # (L, B, ..., N at ax, ...) -> pool (P+1, L, ..., block, ...)
      slot_shape = leaf.shape[:1] + leaf.shape[2:]
      pool_shape = ((self.num_blocks + 1,) + slot_shape[:ax] + (self.block,)
                    + slot_shape[ax + 1:])
      return jnp.zeros(pool_shape, leaf.dtype)

    self.storage = jax.tree_util.tree_map(storage_leaf, self._axes, template)

    def gather(storage, tables):
      def one(ax, st):
        if ax == RESIDENT:
          return st
        dense = jax.vmap(lambda t: kvc.gather_blocks(st, t, ax))(tables)
        return jnp.moveaxis(dense, 0, 1)          # (B, L, ...) -> (L, B, ...)
      return jax.tree_util.tree_map(one, self._axes, storage)

    def scatter(storage, tables, new_caches):
      flat = tables.reshape(-1)
      def one(ax, st, dense):
        if ax == RESIDENT:
          return dense.astype(st.dtype)
        per_slot = jnp.moveaxis(dense, 1, 0)      # (B, L, ...)
        blocks = jax.vmap(lambda x: kvc.blockify(x, ax, self.block))(per_slot)
        blocks = blocks.reshape((-1,) + blocks.shape[2:])   # (B*nb, ...)
        # duplicate indices only ever collide on the trash block, whose
        # content is never read
        return st.at[flat].set(blocks.astype(st.dtype))
      return jax.tree_util.tree_map(one, self._axes, storage, new_caches)

    def decode_fused(params, cur, storage, tables, lengths):
      caches = gather(storage, tables)
      logits, new_caches = model.decode_step(params, cur, caches, lengths)
      return logits, scatter(storage, tables, new_caches)

    def admit_fused(storage, slot_cache, table, slot):
      def one(ax, st, sc):
        if ax == RESIDENT:
          return jax.lax.dynamic_update_slice_in_dim(
              st, sc.astype(st.dtype), slot, axis=1)
        blocks = kvc.blockify(sc[:, 0], ax, self.block)
        return st.at[table].set(blocks.astype(st.dtype))
      return jax.tree_util.tree_map(one, self._axes, storage, slot_cache)

    self._decode_fused = jax.jit(decode_fused, donate_argnums=(2,))
    self._admit_fused = jax.jit(admit_fused, donate_argnums=(0,))

  # -- admission / lifetime --------------------------------------------------
  def fits(self, total_len: int, prompt_len: int = 0) -> bool:
    return self._peak_blocks(total_len, prompt_len) <= self.num_blocks

  def _peak_blocks(self, total_len: int, prompt_len: int = 0) -> int:
    """Worst-case simultaneously-held blocks over a solo request's life.

    Accounts for ring-reuse: a streaming-window codec reclaims aged-out
    blocks every step, so its working set is ~window-sized even when
    `blocks_for(total_len)` exceeds the pool.  Admission itself transiently
    holds the full prompt extent (reclaim only runs after the first step),
    hence the `prompt_len` floor.
    """
    mgr = self.manager
    pol = mgr.policy
    pinned = -(-pol.pinned_tokens() // self.block)
    start = max(prompt_len, 1)
    peak = mgr.blocks_for(start)
    for n in range(start + 1, total_len + 1):
      freed = max(pol.dead_below(n - 1) // self.block - pinned, 0)
      peak = max(peak, mgr.blocks_for(n) - freed)
    return peak

  def can_admit(self, prompt_len: int, total_len: Optional[int] = None
                ) -> bool:
    need = self.manager.blocks_for(prompt_len)
    if total_len is not None:
      # one block of growth headroom (vLLM-style watermark), capped at the
      # request's true worst case so admission can never become impossible
      need = min(need + 1, self.manager.blocks_for(total_len))
    return need <= self.manager.free_count

  def admit(self, slot: int, slot_cache: Any, prompt_len: int) -> None:
    if not self.manager.admit(slot, prompt_len):
      raise RuntimeError(
          f"block pool exhausted admitting {prompt_len}-token prompt "
          f"(free={self.manager.free_count})")
    self.storage = self._admit_fused(
        self.storage, slot_cache, jnp.asarray(self.manager.tables[slot]),
        jnp.asarray(slot, jnp.int32))

  def release(self, slot: int) -> None:
    self.manager.release(slot)

  # -- per-step growth -------------------------------------------------------
  def need_blocks(self, slot: int, target_len: int) -> int:
    return self.manager.need_blocks(slot, target_len)

  def ensure(self, slot: int, target_len: int) -> bool:
    return self.manager.ensure(slot, target_len)

  def reclaim(self, slot: int, length: int) -> int:
    return self.manager.reclaim(slot, length)

  @property
  def free_blocks(self) -> int:
    return self.manager.free_count

  # -- compute ---------------------------------------------------------------
  def decode(self, params, cur, lengths):
    logits, self.storage = self._decode_fused(
        params, jnp.asarray(cur), self.storage,
        jnp.asarray(self.manager.tables), jnp.asarray(lengths))
    return logits

  def bytes(self, active_slots: int = 0) -> dict:
    """True allocated-block footprint (what paging buys), not capacity."""
    block_bytes = 0
    resident_total = 0
    for ax, leaf in zip(jax.tree_util.tree_leaves(self._axes),
                        jax.tree_util.tree_leaves(self.storage)):
      if ax == RESIDENT:
        resident_total += leaf.nbytes
      else:
        block_bytes += leaf.nbytes // (self.num_blocks + 1)
    per_slot_resident = resident_total // max(self.max_batch, 1)
    allocated = self.manager.allocated_count
    return dict(
        kind="paged", block=self.block, num_blocks=self.num_blocks,
        allocated_blocks=allocated, peak_blocks=self.manager.peak_allocated,
        block_bytes=block_bytes,
        resident_bytes_per_slot=per_slot_resident,
        total_bytes=(allocated * block_bytes
                     + active_slots * per_slot_resident),
        capacity_bytes=(self.num_blocks * block_bytes
                        + self.max_batch * per_slot_resident))

  def __repr__(self) -> str:
    return (f"PagedLayout(block={self.block}, num_blocks={self.num_blocks}, "
            f"free={self.free_blocks})")
