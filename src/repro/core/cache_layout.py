"""`CacheLayout` API: physical KV storage as fixed-size token blocks.

AQPIM's point is breaking the PIM *capacity wall*: the KV cache has to fit
and move inside banked memory, which means block-granular placement, not one
monolithic `(B, H, N, D)` slab per request (paper §II/§IV; LoL-PIM/PIMphony
make the same bank-partitioned, capacity-managed layout central to
long-context PIM serving).  This module splits *what* is cached (a
`CachePolicy` codec — exact, AQPIM pq, skvq, ...) from *where* it lives:

  ``ContiguousLayout``  one capacity-sized slab per engine slot (PR 1
                        behavior, the default);
  ``PagedLayout``       a shared pool of fixed-size token blocks with a
                        `BlockAllocator` and per-request block tables —
                        alloc/free/gather/scatter, ring-reuse for the
                        streaming window;
  ``TieredLayout``      paged storage over a *two-tier* refcounted pool
                        (`core.tiers`): device tier 0 + large host tier 1,
                        compressed spill/fetch through each policy's
                        per-buffer spill codecs, residency state machine,
                        and a `TransferLedger` measuring tier-boundary
                        bytes (the paper's compressed-vs-raw traffic
                        claim, measured).

A layout pages *any* policy's state through the codec surface on
`CachePolicy` (`paged_axes` / `token_extent` / `paged_capacity`): AQPIM's
PQ codes page exactly the way exact KV does, while its codebooks and
sink/recent rings stay resident.  ``bytes()`` on a layout reports the *true
allocated-block footprint*, not capacity — counting a prefix-shared block
once, plus what sharing deduplicated.

Since PR 4 the pooled layouts are also **prefix-sharing**: with
``prefix_cache=True`` block tables are copy-on-write over a
`core.prefix_index.PrefixIndex` — admission `ref()`s every block of the
longest published prompt prefix into the new request's table, `cow_fork`
gives a request a private copy of any block it could write (the partial
tail block), and `prefill_chunk` runs the suffix-only prefill the engine
drives (fixed chunk shapes, one compile).  `TieredLayout` keeps shared
blocks device-resident across swap-outs: a shared prefix spills zero
times, not once per request.

Layouts are selected by string key via `repro.core.cache_registry`
(`make_layout("paged", model, max_batch)`); the serve engine exposes them as
`--cache-layout` and drives admission through `repro.launch.scheduler`.

The numerical core (blockify/unblockify/gather_blocks/scatter_blocks) lives
in `core.kv_cache`; everything here composes those into three jitted
programs (admit-scatter, gather->decode->scatter, plus the contiguous
slot-insert) so paging adds no per-step recompilation.
"""
from __future__ import annotations

import collections
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache_registry
from repro.core import decode_dispatch
from repro.core import kv_cache as kvc
from repro.core import prefix_index as pfx
from repro.core import tiers as tiersmod
from repro.core.cache_api import RESIDENT
from repro.parallel import serve_sharding as ssh


class BlockAllocator:
  """Free-list allocator over `num_blocks` physical token blocks.

  Owners are opaque tags (the engine uses slot indices; the prefix index a
  sentinel).  Since PR 4 a block may be held by *several* owners at once —
  copy-on-write prefix sharing `ref()`s a published block into every request
  that matches it — so ownership is a multiset of holders and a block only
  returns to the free list when the last holder lets go.  Every transition
  is checked: allocating a held block, freeing a free block, or freeing a
  hold the owner does not have raises — the invariants the hypothesis suite
  drives.
  """

  def __init__(self, num_blocks: int):
    if num_blocks <= 0:
      raise ValueError(f"num_blocks must be positive, got {num_blocks}")
    self.num_blocks = num_blocks
    self._free: collections.deque = collections.deque(range(num_blocks))
    self._holders: Dict[int, collections.Counter] = {}

  @property
  def free_count(self) -> int:
    return len(self._free)

  @property
  def allocated_count(self) -> int:
    return len(self._holders)

  def alloc(self, n: int, owner: Any = None) -> Optional[List[int]]:
    """Allocate `n` blocks for `owner`; None (and no change) if unavailable."""
    if n < 0:
      raise ValueError(f"cannot allocate {n} blocks")
    if n > len(self._free):
      return None
    ids = [self._free.popleft() for _ in range(n)]
    for i in ids:
      if i in self._holders:
        raise AssertionError(f"free list returned owned block {i}")
      self._holders[i] = collections.Counter({owner: 1})
    return ids

  def ref(self, ids: Sequence[int], owner: Any = None) -> None:
    """Take an additional hold on allocated blocks (prefix sharing)."""
    for i in ids:
      if i not in self._holders:
        raise ValueError(f"ref of free block {i}")
      self._holders[i][owner] += 1

  def refcount(self, i: int) -> int:
    h = self._holders.get(i)
    return 0 if h is None else sum(h.values())

  def holder_count(self, i: int, owner: Any) -> int:
    h = self._holders.get(i)
    return 0 if h is None else h.get(owner, 0)

  def free(self, ids: Sequence[int], owner: Any = None) -> None:
    """Drop one hold per id; blocks with no holds left return to the free
    list.  `owner=None` (legacy single-holder callers) drops the sole
    holder's hold and refuses on a shared block (ambiguous)."""
    for i in ids:
      holders = self._holders.get(i)
      if holders is None:
        raise ValueError(f"double free of block {i}")
      key = owner
      if key is None and None not in holders:
        if len(holders) != 1:
          raise ValueError(
              f"block {i} held by {sorted(map(repr, holders))}; "
              f"anonymous free is ambiguous")
        key = next(iter(holders))
      if holders.get(key, 0) <= 0:
        raise ValueError(
            f"block {i} owned by {sorted(map(repr, holders))}, "
            f"freed by {owner!r}")
      holders[key] -= 1
      if holders[key] == 0:
        del holders[key]
      if not holders:
        del self._holders[i]
        self._free.append(i)

  def owned(self, owner: Any) -> List[int]:
    return [i for i, h in self._holders.items() if h.get(owner, 0) > 0]

  def check(self) -> None:
    """Free list and holder map must partition [0, num_blocks) exactly."""
    free = set(self._free)
    owned = set(self._holders)
    if len(free) != len(self._free):
      raise AssertionError("duplicate ids in free list")
    if free & owned:
      raise AssertionError(f"blocks both free and owned: {free & owned}")
    if free | owned != set(range(self.num_blocks)):
      raise AssertionError("allocator leaked or invented blocks")
    for i, holders in self._holders.items():
      if any(c <= 0 for c in holders.values()) or not holders:
        raise AssertionError(f"block {i} held with non-positive hold count")


class BlockTableManager:
  """Host-side paged bookkeeping: per-slot block tables over an allocator.

  Pure NumPy/Python — no device storage — so allocator/table invariants can
  be property-tested against random admit/grow/reclaim/release traffic
  without building a model.  Logical block j of a slot covers paged tokens
  [j*block, (j+1)*block); unallocated entries hold the trash sentinel
  (`num_blocks`), which physically exists in the pool so gathers/scatters of
  not-yet-filled blocks stay in bounds and never touch another request.
  """

  def __init__(self, num_blocks: int, blocks_per_req: int, max_slots: int,
               block: int, policy, allocator=None):
    # any BlockAllocator-shaped pool works; TieredLayout passes a device-tier
    # view of a refcounted `tiers.TieredBlockPool`
    self.allocator = allocator if allocator is not None else BlockAllocator(
        num_blocks)
    self.block = block
    self.blocks_per_req = blocks_per_req
    self.trash = num_blocks
    self.tables = np.full((max_slots, blocks_per_req), self.trash, np.int32)
    self._hwm = np.zeros(max_slots, np.int64)   # logical blocks ever grown to
    self.policy = policy
    self.peak_allocated = 0
    # peak *distinct table-mapped* blocks: the concurrent working set, which
    # counts a prefix-shared block once and excludes index-pinned blocks no
    # request currently maps — the honest "KV bytes needed to serve" number
    self.peak_mapped = 0

  @property
  def free_count(self) -> int:
    return self.allocator.free_count

  @property
  def allocated_count(self) -> int:
    return self.allocator.allocated_count

  def blocks_for(self, length: int) -> int:
    """Blocks needed to hold `length` cached tokens under this codec."""
    return -(-self.policy.token_extent(int(length)) // self.block)

  def high_water(self, slot: int) -> int:
    """Logical blocks this slot has ever grown to (restored on swap-in)."""
    return int(self._hwm[slot])

  def adopt(self, slot: int, pairs, hwm: int) -> None:
    """Install already-allocated blocks into an empty slot's table (fetch
    completion): `pairs` are (logical_j, physical_id) with ring-reuse holes
    preserved.  The blocks must already be owned by `slot`."""
    if self._hwm[slot] != 0 or (self.tables[slot] != self.trash).any():
      raise AssertionError(f"slot {slot} adopted into while occupied")
    owned = set(self.allocator.owned(slot))
    for j, pid in pairs:
      if pid not in owned:
        raise AssertionError(f"adopting block {pid} not owned by slot {slot}")
      self.tables[slot, j] = pid
    self._hwm[slot] = hwm
    self._note_peaks()

  def share(self, slot: int, ids: Sequence[int]) -> None:
    """Copy-on-write admission: install someone else's live blocks as this
    empty slot's leading table entries, taking one hold per block.  The
    slot may then `ensure` exclusive growth blocks behind them; it must
    never write content into positions the shared blocks cover (the engine
    guarantees writes start at the first unshared token)."""
    if self._hwm[slot] != 0 or (self.tables[slot] != self.trash).any():
      raise AssertionError(f"slot {slot} shared into while occupied")
    self.allocator.ref(ids, owner=slot)
    for j, pid in enumerate(ids):
      self.tables[slot, j] = pid
    self._hwm[slot] = len(ids)
    self._note_peaks()

  def need_blocks(self, slot: int, length: int) -> int:
    return max(self.blocks_for(length) - int(self._hwm[slot]), 0)

  def admit(self, slot: int, length: int) -> bool:
    if self._hwm[slot] != 0 or (self.tables[slot] != self.trash).any():
      raise AssertionError(f"slot {slot} admitted while occupied")
    return self.ensure(slot, length)

  def ensure(self, slot: int, length: int) -> bool:
    """Grow slot to cover `length` tokens; False (no change) on exhaustion."""
    need = self.need_blocks(slot, length)
    if need == 0:
      return True
    ids = self.allocator.alloc(need, owner=slot)
    if ids is None:
      return False
    hwm = int(self._hwm[slot])
    self.tables[slot, hwm:hwm + need] = ids
    self._hwm[slot] = hwm + need
    self._note_peaks()
    return True

  def reclaim(self, slot: int, length: int) -> int:
    """Ring-reuse: free blocks the codec has masked out forever (e.g. the
    streaming window's aged-out tokens).  Returns blocks freed."""
    dead = self.policy.dead_below(int(length))
    if dead <= 0:
      return 0
    first = -(-self.policy.pinned_tokens() // self.block)
    last = min(dead // self.block, int(self._hwm[slot]))
    freed = 0
    for j in range(first, last):
      pid = int(self.tables[slot, j])
      if pid != self.trash:
        self.allocator.free([pid], owner=slot)
        self.tables[slot, j] = self.trash
        freed += 1
    return freed

  def release(self, slot: int) -> None:
    ids = [int(x) for x in self.tables[slot] if x != self.trash]
    if ids:
      self.allocator.free(ids, owner=slot)
    self.tables[slot, :] = self.trash
    self._hwm[slot] = 0

  def _note_peaks(self) -> None:
    self.peak_allocated = max(self.peak_allocated, self.allocated_count)
    live = self.tables[self.tables != self.trash]
    self.peak_mapped = max(self.peak_mapped, len(set(live.tolist())))

  def check_invariants(self) -> None:
    self.allocator.check()
    # a physical block may be mapped by several *slots* (prefix sharing),
    # but never twice within one slot's table, and every mapping must be
    # backed by a hold that slot actually has
    for slot in range(self.tables.shape[0]):
      row_list = self.tables[slot][self.tables[slot] != self.trash].tolist()
      row = set(row_list)
      if len(row) != len(row_list):
        raise AssertionError(
            f"slot {slot} maps a physical block twice: {sorted(row_list)}")
      if row != set(self.allocator.owned(slot)):
        raise AssertionError(
            f"slot {slot} table/owner mismatch: {row} vs "
            f"{set(self.allocator.owned(slot))}")


class CacheLayout:
  """Physical-storage protocol between a built `Model` and the serve engine.

  The engine never touches cache trees directly anymore; it asks the layout
  to `admit` a prefilled request into a slot, `ensure` growth room before a
  decode step, `decode` one batched step over the layout's own storage, and
  `release` on finish.  Block-pool methods are no-ops for layouts without a
  pool, so schedulers can query them uniformly.
  """
  name: str = "base"
  #: True if this layout manages a shared block pool (pool-gating schedulers
  #: require one); `spills` additionally marks a host spill tier.
  pooled: bool = False
  spills: bool = False

  # -- admission / lifetime --------------------------------------------------
  def fits(self, total_len: int, prompt_len: int = 0) -> bool:
    """Can a request of `total_len` cached tokens ever be served alone?"""
    return True

  def can_admit(self, prompt_len: int, total_len: Optional[int] = None
                ) -> bool:
    """Is there storage to admit a prompt of this length right now?
    `total_len` (prompt + max new tokens) lets pooled layouts keep one
    block of growth headroom and avoid admit->preempt thrash."""
    return True

  def admit(self, slot: int, slot_cache: Any, prompt_len: int) -> None:
    raise NotImplementedError

  def release(self, slot: int) -> None:
    raise NotImplementedError

  # -- per-step growth -------------------------------------------------------
  def need_blocks(self, slot: int, target_len: int) -> int:
    return 0

  def ensure(self, slot: int, target_len: int) -> bool:
    return True

  def reclaim(self, slot: int, length: int) -> int:
    return 0

  @property
  def free_blocks(self) -> int:
    return 0

  # -- compute ---------------------------------------------------------------
  def decode(self, params: Any, cur: np.ndarray, lengths: np.ndarray):
    """Run one batched decode step over this layout's storage; returns logits."""
    raise NotImplementedError

  def bytes(self, active_slots: int = 0) -> dict:
    raise NotImplementedError

  def __repr__(self) -> str:
    return f"{type(self).__name__}()"


@cache_registry.register_layout("contiguous")
class ContiguousLayout(CacheLayout):
  """PR 1 storage: one capacity-sized slab per slot, batched tree (L, B, ...).

  Admission writes a prefilled slot cache into batch row `slot` via a donated
  dynamic-update; decode donates the whole tree.  `bytes()` is honest about
  what this layout costs: every slot pays full capacity whether or not a
  short request sits in it — the number paging exists to shrink.
  """

  def __init__(self, model, max_batch: int, *, block_size: Optional[int] = None,
               num_blocks: Optional[int] = None,
               host_blocks: Optional[int] = None,
               prefix_cache: bool = False,
               prefix_cache_blocks: Optional[int] = None,
               shard_plan: Optional[ssh.ShardPlan] = None,
               shard_redundancy: str = "none"):
    del block_size, num_blocks, host_blocks   # no block pool, no host tier
    del prefix_cache_blocks
    if prefix_cache:
      raise ValueError(
          "prefix cache requires a pooled layout: contiguous slabs have no "
          "shareable blocks — use --cache-layout paged or tiered")
    if shard_plan is not None and shard_plan.active:
      raise ValueError(
          "sharded serving partitions a block pool; contiguous slabs have "
          "none — use --cache-layout paged or tiered with --mesh-model > 1")
    if shard_redundancy not in (None, "none"):
      raise ValueError(
          f"--shard-redundancy {shard_redundancy!r} mirrors pool pages; "
          "contiguous slabs have no block pool — use --cache-layout paged "
          "or tiered, or drop to --shard-redundancy none")
    self.mirror = None
    self.model = model
    self.max_batch = max_batch
    self.storage = model.init_cache(max_batch)
    # the policy resolved its decode dispatch at construction; slab decode
    # consults it *inside* append_and_attend (dense kernel vs pure JAX), so
    # this fused program is already kernel-dispatched — exposed here for
    # stats/bench records
    self.dispatch = (model.cache_policy.dispatch
                     if model.cache_policy is not None
                     else decode_dispatch.resolve("xla"))
    self._decode_fused = jax.jit(model.decode_step, donate_argnums=(2,))
    self._insert = jax.jit(
        lambda cache, c1, slot: jax.tree_util.tree_map(
            lambda c, x: jax.lax.dynamic_update_slice_in_dim(
                c, x.astype(c.dtype), slot, axis=1), cache, c1),
        donate_argnums=(0,))

  def admit(self, slot: int, slot_cache: Any, prompt_len: int) -> None:
    del prompt_len  # slabs are capacity-sized regardless
    self.storage = self._insert(self.storage, slot_cache,
                                jnp.asarray(slot, jnp.int32))

  def release(self, slot: int) -> None:
    pass  # the slab is overwritten by the next admit

  def decode(self, params, cur, lengths):
    logits, self.storage = self._decode_fused(
        params, jnp.asarray(cur), self.storage, jnp.asarray(lengths))
    return logits

  def bytes(self, active_slots: int = 0) -> dict:
    total = sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(self.storage))
    per_slot = total // max(self.max_batch, 1)
    return dict(kind="contiguous", total_bytes=total,
                per_slot_bytes=per_slot, capacity_bytes=total,
                active_bytes=active_slots * per_slot)


@cache_registry.register_layout("paged")
class PagedLayout(CacheLayout):
  """Block-pooled storage: per-request block tables over a shared pool.

  Every token-axis leaf of the policy state (exact K/V slabs, snapkv weights,
  AQPIM PQ code rows) is stored as `(P+1, ..., block, ...)` physical blocks —
  index P is the trash block backing unallocated table entries — while
  resident leaves (codebooks, sink/recent rings) stay per-slot.  One jitted
  program fuses block-gather -> decode_step -> block-scatter, so the dense
  per-request view the vmapped cores consume never materializes outside the
  compiled step.
  """

  pooled = True

  def __init__(self, model, max_batch: int, *, block_size: Optional[int] = None,
               num_blocks: Optional[int] = None,
               host_blocks: Optional[int] = None,
               prefix_cache: bool = False,
               prefix_cache_blocks: Optional[int] = None,
               shard_plan: Optional[ssh.ShardPlan] = None,
               shard_redundancy: str = "none"):
    del host_blocks   # single-tier pool; TieredLayout consumes it
    policy = model.cache_policy
    if policy is None:
      raise ValueError("paged layout needs a KV cache policy "
                       "(attn-free families have no KV cache)")
    self.model = model
    self.max_batch = max_batch
    self.shard_plan = shard_plan
    plan_active = shard_plan is not None and shard_plan.active
    self.block = int(block_size or policy.spec.block or 16)
    cap = policy.paged_capacity()
    if self.block <= 0 or cap % self.block:
      raise ValueError(
          f"paged token capacity {cap} not divisible by block size "
          f"{self.block} ({type(policy).__name__})")
    self.blocks_per_req = cap // self.block
    self.num_blocks = int(num_blocks or max_batch * self.blocks_per_req)
    self.manager = BlockTableManager(
        self.num_blocks, self.blocks_per_req, max_batch, self.block, policy,
        allocator=self._make_allocator(self.num_blocks))
    self._axes = policy.paged_axes()

    template = model.init_cache(max_batch)

    def storage_leaf(ax, leaf):
      if ax == RESIDENT:
        return jnp.array(leaf)       # (L, B, ...) per-slot resident
      # (L, B, ..., N at ax, ...) -> pool (P+1, L, ..., block, ...)
      slot_shape = leaf.shape[:1] + leaf.shape[2:]
      pool_shape = ((self.num_blocks + 1,) + slot_shape[:ax] + (self.block,)
                    + slot_shape[ax + 1:])
      return jnp.zeros(pool_shape, leaf.dtype)

    self.storage = jax.tree_util.tree_map(storage_leaf, self._axes, template)
    if plan_active:
      # commit pool + resident leaves to their mesh placement up front so
      # the admission/fork/chunk programs (plain jits under GSPMD) keep the
      # layout instead of re-deciding it per program
      self.storage = ssh.place_storage(self.storage, shard_plan)

    def gather(storage, tables):
      def one(ax, st):
        if ax == RESIDENT:
          return st
        dense = jax.vmap(lambda t: kvc.gather_blocks(st, t, ax))(tables)
        return jnp.moveaxis(dense, 0, 1)          # (B, L, ...) -> (L, B, ...)
      return jax.tree_util.tree_map(one, self._axes, storage)

    def scatter(storage, tables, new_caches):
      flat = tables.reshape(-1)
      def one(ax, st, dense):
        if ax == RESIDENT:
          return dense.astype(st.dtype)
        per_slot = jnp.moveaxis(dense, 1, 0)      # (B, L, ...)
        blocks = jax.vmap(lambda x: kvc.blockify(x, ax, self.block))(per_slot)
        blocks = blocks.reshape((-1,) + blocks.shape[2:])   # (B*nb, ...)
        # duplicate indices only ever collide on the trash block, whose
        # content is never read
        return st.at[flat].set(blocks.astype(st.dtype))
      return jax.tree_util.tree_map(one, self._axes, storage, new_caches)

    def decode_fused(params, cur, storage, tables, lengths):
      caches = gather(storage, tables)
      logits, new_caches = model.decode_step(params, cur, caches, lengths)
      return logits, scatter(storage, tables, new_caches)

    def admit_fused(storage, slot_cache, table, slot):
      def one(ax, st, sc):
        if ax == RESIDENT:
          return jax.lax.dynamic_update_slice_in_dim(
              st, sc.astype(st.dtype), slot, axis=1)
        blocks = kvc.blockify(sc[:, 0], ax, self.block)
        return st.at[table].set(blocks.astype(st.dtype))
      return jax.tree_util.tree_map(one, self._axes, storage, slot_cache)

    self._gather = gather
    self._scatter = scatter
    self._admit_fused = jax.jit(admit_fused, donate_argnums=(0,))

    # -- block-table-native decode (kernel dispatch) -------------------------
    # With a pallas dispatch and a policy that has a paged kernel variant,
    # decode skips the dense round trip entirely: the kernels stream the
    # table-mapped pool blocks in place (scalar-prefetched block tables) and
    # the only writes are this step's rows.  The dense gather/scatter
    # programs above remain — admission, COW forks, and the chunked suffix
    # prefill still use them — but the per-step decode traffic they cost
    # drops to zero.
    axes_leaves = jax.tree_util.tree_leaves(self._axes)

    def decode_native(params, cur, storage, tables, lengths):
      leaves, treedef = jax.tree_util.tree_flatten(storage)
      res = [st if ax == RESIDENT else None
             for ax, st in zip(axes_leaves, leaves)]
      pools = [None if ax == RESIDENT else st
               for ax, st in zip(axes_leaves, leaves)]
      logits, res, pools = model.decode_step_paged(
          params, cur, res, pools, tables, lengths)
      merged = [r if ax == RESIDENT else p
                for ax, r, p in zip(axes_leaves, res, pools)]
      return logits, jax.tree_util.tree_unflatten(treedef, merged)

    # the raw (unsharded) program bodies are kept so `replan` can re-bind
    # them to a degraded mesh after a confirmed shard loss
    self._decode_fused_body = decode_fused
    self._decode_native_body = decode_native
    self._bind_plan(shard_plan)
    self._init_mirror(shard_redundancy)
    # layout-constant byte terms of the traffic model (storage shapes are
    # fixed): one pool block / one token row across all layers and heads,
    # summed over paged leaves — hoisted so the per-step snapshot only
    # scans the (B, nb) table
    self._traffic_per_block = 0
    self._traffic_per_row = 0
    for ax, st in zip(jax.tree_util.tree_leaves(self._axes),
                      jax.tree_util.tree_leaves(self.storage)):
      if ax == RESIDENT:
        continue
      pb = st.nbytes // st.shape[0]
      self._traffic_per_block += pb
      self._traffic_per_row += pb // self.block
    # peak per-step traffic snapshot, refreshed while decoding (live tables)
    self.decode_traffic = self.decode_traffic_model()
    self._init_prefix_cache(prefix_cache, prefix_cache_blocks)

  # -- shard plan binding / degraded-mesh replan -----------------------------
  def _bind_plan(self, plan: Optional[ssh.ShardPlan]) -> None:
    """(Re)compile the decode programs against a shard plan.

    Called once at construction and again by `replan` after a confirmed
    shard loss: dispatch re-resolves for the new mode (seq split-K lives
    only in the dense xla program, so an auto-picked pallas dispatch
    degrades — and an explicit one raises — before anything compiles), and
    the fused/native bodies re-wrap + re-jit under the new mesh.
    """
    self.shard_plan = plan
    plan_active = plan is not None and plan.active
    policy = self.manager.policy
    self.dispatch = policy.dispatch
    if plan is not None:
      # mesh-aware second resolution (see resolve_for_mesh)
      self.dispatch = decode_dispatch.resolve_for_mesh(
          self.dispatch, plan.mode)
    self.block_native = bool(
        policy.block_native and self.dispatch.use_pallas
        and self.model.cfg.family in ("dense", "moe")
        and not self.model.cfg.hybrid)
    fused = self._decode_fused_body
    if plan_active:
      fused = ssh.wrap_decode(fused, plan, self.storage)
    self._decode_fused = jax.jit(fused, donate_argnums=(2,))
    if self.block_native:
      native = self._decode_native_body
      if plan_active:
        native = ssh.wrap_decode(native, plan, self.storage)
      self._decode_native = jax.jit(native, donate_argnums=(2,))

  def replan(self, new_plan: ssh.ShardPlan) -> None:
    """Adopt a degraded-mesh plan after a confirmed shard loss.

    Host-side state (tables, allocator, prefix index, spill records) is
    device-agnostic and survives untouched; only where the pool bytes live
    and which decode program runs change.  Storage is re-placed on the
    survivor submesh and the decode programs re-bind — recovering the
    *content* of blocks the dead shard held is the engine's job
    (`mirror_restore` or recompute-prefill), not this method's.
    """
    self.storage = ssh.place_storage(self.storage, new_plan)
    self._bind_plan(new_plan)

  def damage_storage(self) -> int:
    """Zero every storage leaf (simulated shard-loss data damage).

    In heads mode a dead shard held one kv-head slice of *every* pool
    block, so no resident block survives intact; zeroing the whole tree is
    the honest superset, and makes recovery falsifiable — a slot the
    engine fails to restore decodes from zeros and diverges from the
    oracle instead of silently passing.  Returns bytes scrubbed.
    """
    scrubbed = sum(lf.nbytes
                   for lf in jax.tree_util.tree_leaves(self.storage))
    self.storage = jax.tree_util.tree_map(
        lambda lf: jnp.zeros_like(lf), self.storage)
    return scrubbed

  # -- host-tier shard mirror (--shard-redundancy host-mirror) ---------------
  def _init_mirror(self, shard_redundancy: str) -> None:
    self.shard_redundancy = str(shard_redundancy or "none")
    if self.shard_redundancy not in ("none", "host-mirror"):
      raise ValueError(
          f"unknown --shard-redundancy {self.shard_redundancy!r}; "
          "expected one of ('none', 'host-mirror')")
    self.mirror: Optional[tiersmod.HostMirror] = None
    self._mirror_codec_leaves: Optional[list] = None
    if self.shard_redundancy != "host-mirror":
      return
    policy = self.manager.policy
    codec_tree = policy.spill_codecs()
    if (jax.tree_util.tree_structure(codec_tree)
        != jax.tree_util.tree_structure(self._axes)):
      raise ValueError(
          f"{type(policy).__name__}.spill_codecs() structure does not match "
          f"paged_axes()")
    self._mirror_codec_leaves = jax.tree_util.tree_leaves(codec_tree)
    for ck in self._mirror_codec_leaves:
      tiersmod.get_codec(ck)                  # fail fast on unknown keys
    self.mirror = tiersmod.HostMirror()

  def mirror_sync(self, slot: int, rid: int, length: int) -> int:
    """Refresh the host mirror of one active slot (write-through).

    Encodes the slot's live pool blocks through the policy's spill codecs
    and saves its resident rows bit-exactly, CRC32-stamping each frame —
    the same wire format `TieredLayout.spill` writes, minus the host-block
    bookkeeping (the mirror never occupies pool capacity).  Returns the
    post-codec bytes written; 0 when mirroring is off.
    """
    if self.mirror is None:
      return 0
    mgr = self.manager
    row = mgr.tables[slot]
    pairs = [(j, int(row[j])) for j in range(self.blocks_per_req)
             if row[j] != mgr.trash]
    n = len(pairs)
    padded = np.full((self.blocks_per_req,), mgr.trash, np.int32)
    padded[:n] = [pid for _, pid in pairs]
    padded_j = jnp.asarray(padded)
    payloads: list = []
    resident_rows: list = []
    nbytes = raw = 0
    for ax, ck, st in zip(jax.tree_util.tree_leaves(self._axes),
                          self._mirror_codec_leaves,
                          jax.tree_util.tree_leaves(self.storage)):
      if ax == RESIDENT:
        rowv = np.asarray(st[:, slot])
        payloads.append(None)
        resident_rows.append(rowv)
        nbytes += rowv.nbytes
        raw += rowv.nbytes
      else:
        arr = np.asarray(st[padded_j])[:n]
        enc, nb = tiersmod.get_codec(ck).encode(arr)
        payloads.append((ck, enc, arr.shape, arr.dtype))
        resident_rows.append(None)
        nbytes += nb
        raw += arr.nbytes
    rec = tiersmod.MirrorRecord(
        slot=slot, rid=rid, length=length, hwm=mgr.high_water(slot),
        pairs=pairs, payloads=payloads, resident_rows=resident_rows,
        checksums=[None if p is None else tiersmod.payload_checksum(p[1])
                   for p in payloads],
        nbytes=nbytes, raw_bytes=raw)
    self.mirror.put(rec)
    return nbytes

  def mirror_restore(self, slot: int) -> Optional[tiersmod.MirrorRecord]:
    """Rebuild a slot's pool pages from its host mirror after shard loss.

    Verifies every frame checksum first (`SpillPageCorruption` on
    mismatch, storage untouched — the engine falls back to recompute),
    then decodes and re-scatters the payloads into the *same* device block
    ids under the current (replanned) placement, and restores the slot's
    resident rows.  Returns the record restored, or None when the mirror
    holds nothing for this slot.
    """
    if self.mirror is None:
      return None
    rec = self.mirror.get(slot)
    if rec is None:
      return None
    rec.verify()
    dev_ids = rec.device_block_ids
    padded = np.full((self.blocks_per_req,), self.manager.trash, np.int32)
    padded[:len(dev_ids)] = dev_ids
    padded_j = jnp.asarray(padded)
    leaves, treedef = jax.tree_util.tree_flatten(self.storage)
    out = []
    for ax, st, payload, rowv in zip(jax.tree_util.tree_leaves(self._axes),
                                     leaves, rec.payloads,
                                     rec.resident_rows):
      if ax == RESIDENT:
        st = st.at[:, slot].set(jnp.asarray(rowv).astype(st.dtype))
      else:
        ck, enc, shape, dtype = payload
        staged = tiersmod.get_codec(ck).decode(enc, shape, dtype)
        # pad with zero blocks aimed at the trash block: fixed shapes keep
        # the dispatch cache warm, and trash content is never read
        pad_shape = (self.blocks_per_req,) + tuple(st.shape[1:])
        vals = np.zeros(pad_shape, staged.dtype)
        vals[:len(dev_ids)] = staged
        st = st.at[padded_j].set(jnp.asarray(vals).astype(st.dtype))
      out.append(st)
    self.storage = jax.tree_util.tree_unflatten(treedef, out)
    self.mirror.restores += 1
    self.mirror.restore_bytes += rec.nbytes
    return rec

  # -- prefix sharing (copy-on-write block tables) ---------------------------
  def _init_prefix_cache(self, enabled: bool,
                         budget_blocks: Optional[int]) -> None:
    self.prefix_enabled = bool(enabled)
    self.prefix_index: Optional[pfx.PrefixIndex] = None
    self.forked_blocks = 0          # cow_fork count (EngineStats mirrors it)
    # padded prefill extent the chunk path must attend over; the engine
    # sets it (set_prompt_capacity) before the first prefill_chunk
    self._kv_extent = self.manager.policy.paged_capacity()
    policy = self.manager.policy
    # chain (partial-prefix) sharing additionally needs causal per-position
    # prefill numerics: exact-store policies over the dense family (MoE
    # capacity routing couples positions across the sequence)
    self.prefix_shareable = bool(
        self.prefix_enabled and policy.prefix_shareable
        and self.model.cfg.family == "dense")
    if not self.prefix_enabled:
      return
    budget = (int(budget_blocks) if budget_blocks is not None
              else max(self.num_blocks // 2, 1))
    self.prefix_index = pfx.PrefixIndex(self.block, budget)

    def fork_fused(storage, src, dst):
      def one(ax, st):
        if ax == RESIDENT:
          return st
        return st.at[dst].set(st[src])
      return jax.tree_util.tree_map(one, self._axes, storage)

    def chunk_fused(params, storage, table, tokens, start, kv_extent):
      caches = self._gather(storage, table[None])
      logits, new_caches = self.model.prefill_chunk(
          params, tokens, caches, start, kv_extent)
      return logits, self._scatter(storage, table[None], new_caches)

    self._fork_fused = jax.jit(fork_fused, donate_argnums=(0,))
    self._chunk_fused = jax.jit(chunk_fused, donate_argnums=(1,),
                                static_argnums=(5,))

  def _require_prefix(self) -> pfx.PrefixIndex:
    if self.prefix_index is None:
      raise RuntimeError("prefix cache is disabled on this layout")
    return self.prefix_index

  def _block_in_tables(self, bid: int) -> bool:
    """Is this physical block mapped by any slot's table right now?"""
    return bool((self.tables_view() == bid).any())

  def tables_view(self) -> np.ndarray:
    return self.manager.tables

  def prefix_plan(self, tokens: Sequence[int], total_len: int,
                  touch: bool = False) -> dict:
    """Admission plan for a prompt under the prefix cache.

    kind 'full'  — an identical prompt's snapshot is live: zero prefill,
                   `need` covers only the COW tail fork + growth headroom;
    kind 'chain' — `match` leading blocks are shared; prefill only the
                   suffix (need = remaining blocks + headroom);
    kind 'none'  — no published prefix (or sharing gated off): full
                   prefill, same need as `can_admit`.

    `touch=True` (the engine's actual admission) refreshes the matched
    entries' LRU recency; scheduler probes stay read-only.
    """
    mgr = self.manager
    prompt_len = len(tokens)

    def headroom(need: int, shared: int) -> int:
      # one growth-headroom block (mirrors can_admit), capped at the true
      # worst case so admission can never become impossible
      cap = max(mgr.blocks_for(total_len) - shared, need)
      return min(need + 1, cap)

    if self.prefix_enabled:
      idx = self._require_prefix()
      entry = idx.get_full(tokens, touch=touch)
      if entry is not None:
        fork = 0 if entry.tail_j is None else 1
        return dict(kind="full", entry=entry, match=[],
                    matched_tokens=prompt_len,
                    need=headroom(fork, len(entry.pairs) - fork))
      if self.prefix_shareable:
        match = idx.match(tokens, max_tokens=prompt_len - 1, touch=touch)
        if match:
          need = mgr.blocks_for(prompt_len) - len(match)
          return dict(kind="chain", entry=None, match=match,
                      matched_tokens=len(match) * self.block,
                      need=headroom(need, len(match)))
    return dict(kind="none", entry=None, match=[], matched_tokens=0,
                need=headroom(mgr.blocks_for(prompt_len), 0))

  def admit_shared(self, slot: int, match: Sequence[int], prompt_len: int
                   ) -> None:
    """COW admission: ref the matched chain blocks into this slot's table,
    then allocate exclusive blocks for the remainder of the prompt."""
    mgr = self.manager
    mgr.share(slot, list(match))
    if not mgr.ensure(slot, prompt_len):
      mgr.release(slot)               # drop the shared holds we just took
      raise RuntimeError(
          f"block pool exhausted admitting shared-prefix prompt "
          f"({prompt_len} tokens, {len(match)} shared blocks, "
          f"free={mgr.free_count})")

  def admit_from_full(self, slot: int, entry: pfx.FullEntry) -> None:
    """Full-prompt hit: map the snapshot's blocks shared, fork the partial
    tail block (the donor keeps writing it), restore resident leaves."""
    mgr = self.manager
    ids = [bid for _, bid in sorted(entry.pairs)]
    mgr.share(slot, ids)
    if entry.tail_j is not None:
      self.cow_fork(slot, entry.tail_j)
    if mgr.high_water(slot) != entry.hwm:
      raise AssertionError(
          f"full-entry hwm drifted: {mgr.high_water(slot)} vs {entry.hwm}")
    if any(row is not None for row in entry.resident_rows):
      leaves, treedef = jax.tree_util.tree_flatten(self.storage)
      out = []
      for ax, st, row in zip(jax.tree_util.tree_leaves(self._axes), leaves,
                             entry.resident_rows):
        if ax == RESIDENT:
          st = st.at[:, slot].set(jnp.asarray(row).astype(st.dtype))
        out.append(st)
      self.storage = jax.tree_util.tree_unflatten(treedef, out)

  def cow_fork(self, slot: int, j: int) -> int:
    """Copy-on-write fork: give `slot` a private copy of logical block `j`
    (alloc + device copy + unref the shared original).  The freed hold never
    aliases: the new block is exclusively owned and the shared block's
    payload is untouched."""
    mgr = self.manager
    old = int(mgr.tables[slot, j])
    if old == mgr.trash:
      raise ValueError(f"cow_fork of unallocated logical block {j}")
    new = mgr.allocator.alloc(1, owner=slot)
    if new is None:
      mgr.release(slot)
      raise RuntimeError(f"block pool exhausted forking block {old}")
    self.storage = self._fork_fused(
        self.storage, jnp.asarray(old, jnp.int32),
        jnp.asarray(new[0], jnp.int32))
    mgr.tables[slot, j] = new[0]
    mgr.allocator.free([old], owner=slot)
    mgr._note_peaks()
    self.forked_blocks += 1
    return new[0]

  def prefill_chunk(self, params, slot: int, tokens: np.ndarray, start: int):
    """Run one fixed-shape suffix-prefill chunk over this slot's storage
    (gather -> Model.prefill_chunk -> scatter, one compile per chunk shape).
    Returns per-row logits; the engine picks the true last token's row."""
    logits, self.storage = self._chunk_fused(
        params, self.storage, jnp.asarray(self.manager.tables[slot]),
        jnp.asarray(tokens), jnp.asarray(start, jnp.int32),
        int(self._kv_extent))
    return logits

  def set_prompt_capacity(self, prompt_capacity: int) -> None:
    """The engine's padded prefill extent — the chunk path must attend over
    exactly this many key positions to stay bit-identical with it."""
    self._kv_extent = int(prompt_capacity)

  def prefix_publish(self, slot: int, tokens: Sequence[int],
                     first_token: int) -> None:
    """Publish this freshly-prefilled slot into the index: whole prompt
    blocks as a shareable chain (causal policies), plus a full-prompt entry
    (any deterministic policy) under the refcount+LRU block budget."""
    if not self.prefix_enabled:
      return
    idx = self._require_prefix()
    mgr = self.manager
    policy = mgr.policy
    tokens = tuple(int(t) for t in tokens)
    prompt_len = len(tokens)
    live = [(j, int(mgr.tables[slot, j])) for j in range(self.blocks_per_req)
            if mgr.tables[slot, j] != mgr.trash]

    chain_ids: List[int] = []
    if self.prefix_shareable:
      # exact-store codecs: paged token j*block..(j+1)*block-1 are prompt
      # positions verbatim (token_extent is the identity)
      n_whole = prompt_len // self.block
      by_j = dict(live)
      chain_ids = [by_j[j] for j in range(n_whole) if j in by_j]
      if len(chain_ids) != n_whole:
        chain_ids = []                # ring holes (shouldn't happen pre-decode)

    extent = policy.token_extent(prompt_len)
    tail_j = (extent // self.block) if extent % self.block else None
    if tail_j is not None and tail_j not in dict(live):
      tail_j = None
    entry = None
    if policy.prefix_cacheable:
      resident_rows = []
      for ax, st in zip(jax.tree_util.tree_leaves(self._axes),
                        jax.tree_util.tree_leaves(self.storage)):
        resident_rows.append(np.asarray(st[:, slot]) if ax == RESIDENT
                             else None)
      entry = pfx.FullEntry(tokens=tokens, pairs=list(live),
                            hwm=mgr.high_water(slot),
                            resident_rows=resident_rows,
                            first_token=int(first_token), tail_j=tail_j)

    # budget pressure is measured in *new distinct holds* only: most of a
    # shared prompt's blocks are usually index-held already (chain nodes
    # keep existing holds), and counting them would over-evict hot entries
    # or refuse to publish prompts whose prefix is entirely cached
    candidate = set(chain_ids) | {b for _, b in (entry.pairs if entry
                                                 else [])}
    incoming = sum(1 for b in candidate if idx.holds(b) == 0)
    if incoming > idx.budget_blocks:
      return                          # prompt alone overflows the budget
    released = idx.evict_for(incoming, in_use=self._block_in_tables)
    if released:
      mgr.allocator.free(released, owner=pfx.INDEX_OWNER)
    if chain_ids:
      new_holds = idx.extend(tokens, chain_ids)
      if new_holds:
        mgr.allocator.ref(new_holds, owner=pfx.INDEX_OWNER)
    if entry is not None:
      holds = idx.put_full(entry)
      if holds:
        mgr.allocator.ref(holds, owner=pfx.INDEX_OWNER)

  def prefix_evict_one(self) -> bool:
    """Starvation valve: evict the coldest index unit so its blocks can
    serve admission.  The engine calls this when the pool is idle (no
    active requests) yet nothing in the queue is admissible — the only
    thing holding blocks then is the cache itself."""
    if self.prefix_index is None or self.prefix_index.held_blocks == 0:
      return False
    released = self.prefix_index.shrink_to(
        self.prefix_index.held_blocks - 1, in_use=self._block_in_tables)
    if not released:
      return False
    self.manager.allocator.free(released, owner=pfx.INDEX_OWNER)
    return True

  def prefix_clear(self) -> int:
    """Drop every cached prefix (all index holds back to the pool).
    Returns the number of holds released — after all requests finish, this
    is what takes every refcount back to zero."""
    if self.prefix_index is None:
      return 0
    released = self.prefix_index.clear()
    if released:
      self.manager.allocator.free(released, owner=pfx.INDEX_OWNER)
    return len(released)

  # -- crash-safe snapshot/restore -------------------------------------------
  def prefix_snapshot(self) -> Tuple[Dict[str, np.ndarray], dict]:
    """Host snapshot of the prefix cache: every index-held pool block's
    contents plus the trie/full-entry structure, as a ckpt-able
    ``{name: array}`` tree + JSON-able metadata.

    Block ids are positional: the tree stores the held blocks' rows in
    sorted-id order and the metadata references blocks by *position in
    that order* — restore allocates fresh physical ids and remaps, so a
    snapshot restores into any pool with room for it (the ids the saving
    pool happened to use mean nothing to the restoring one).
    """
    idx = self._require_prefix()
    paths = idx.chain_paths()
    fulls = idx.full_values()
    held = {bid for _, bids in paths for bid in bids}
    held.update(bid for e in fulls for bid in e.block_ids)
    ids = sorted(held)
    pos = {bid: p for p, bid in enumerate(ids)}
    tree: Dict[str, np.ndarray] = {}
    axes_leaves = jax.tree_util.tree_leaves(self._axes)
    if ids:
      sel = jnp.asarray(ids, jnp.int32)
      for k, (ax, st) in enumerate(
          zip(axes_leaves, jax.tree_util.tree_leaves(self.storage))):
        if ax == RESIDENT:
          continue
        tree[f"pool_{k}"] = np.asarray(st[sel])
    full_meta = []
    for i, e in enumerate(fulls):
      resident = []
      for k, row in enumerate(e.resident_rows):
        resident.append(row is not None)
        if row is not None:
          tree[f"full_{i}_r{k}"] = np.asarray(row)
      full_meta.append(dict(
          tokens=[int(t) for t in e.tokens],
          pairs=[[int(j), pos[bid]] for j, bid in e.pairs],
          hwm=int(e.hwm), first_token=int(e.first_token),
          tail_j=None if e.tail_j is None else int(e.tail_j),
          resident=resident))
    extra = dict(
        kind="prefix-cache", block=self.block, n_blocks=len(ids),
        chains=[[list(toks), [pos[b] for b in bids]]
                for toks, bids in paths],
        fulls=full_meta)
    return tree, extra

  def prefix_restore(self, tree: Dict[str, np.ndarray], extra: dict) -> int:
    """Rebuild the prefix cache from a `prefix_snapshot` tree.

    Meant for engine construction (empty tables, empty index): allocates
    fresh physical blocks under the index owner tag, scatters the saved
    contents, and re-publishes chains + full entries with block ids
    remapped to the new allocation.  Conservatively returns 0 — restoring
    nothing, which is always safe — when the snapshot is empty, was taken
    under a different block size, exceeds this layout's index budget, or
    the pool cannot hold it.  Returns the number of restored blocks.
    """
    if not self.prefix_enabled:
      return 0
    idx = self._require_prefix()
    if (extra.get("kind") != "prefix-cache"
        or int(extra.get("block", -1)) != self.block):
      return 0
    n = int(extra.get("n_blocks", 0))
    if n == 0 or n > idx.budget_blocks:
      return 0
    mgr = self.manager
    new_ids = mgr.allocator.alloc(n, owner=pfx.INDEX_OWNER)
    if new_ids is None:
      return 0
    sel = jnp.asarray(new_ids, jnp.int32)
    leaves, treedef = jax.tree_util.tree_flatten(self.storage)
    out = []
    for k, (ax, st) in enumerate(
        zip(jax.tree_util.tree_leaves(self._axes), leaves)):
      if ax != RESIDENT:
        st = st.at[sel].set(jnp.asarray(tree[f"pool_{k}"]).astype(st.dtype))
      out.append(st)
    self.storage = jax.tree_util.tree_unflatten(treedef, out)
    if self.prefix_shareable:
      for toks, poss in extra.get("chains", []):
        idx.extend(toks, [new_ids[p] for p in poss])
    for i, meta in enumerate(extra.get("fulls", [])):
      rows = [np.asarray(tree[f"full_{i}_r{k}"]) if flag else None
              for k, flag in enumerate(meta["resident"])]
      idx.put_full(pfx.FullEntry(
          tokens=tuple(int(t) for t in meta["tokens"]),
          pairs=[(int(j), new_ids[p]) for j, p in meta["pairs"]],
          hwm=int(meta["hwm"]), resident_rows=rows,
          first_token=int(meta["first_token"]), tail_j=meta["tail_j"]))
    # reconcile pool holds with the rebuilt ledger: alloc() took one hold
    # per block, but the index may hold a block several times (chain node
    # + full entries) or — when a unit was gated off, e.g. chains on a
    # non-shareable policy — not at all
    restored = 0
    for bid in new_ids:
      holds = idx.holds(bid)
      if holds > 1:
        mgr.allocator.ref([bid] * (holds - 1), owner=pfx.INDEX_OWNER)
      elif holds == 0:
        mgr.allocator.free([bid], owner=pfx.INDEX_OWNER)
      if holds:
        restored += 1
    return restored

  def _make_allocator(self, num_blocks: int):
    """Pool-construction hook: TieredLayout substitutes a device-tier view
    of a refcounted two-tier pool."""
    return BlockAllocator(num_blocks)

  # -- admission / lifetime --------------------------------------------------
  def fits(self, total_len: int, prompt_len: int = 0) -> bool:
    return self._peak_blocks(total_len, prompt_len) <= self.num_blocks

  def _peak_blocks(self, total_len: int, prompt_len: int = 0) -> int:
    """Worst-case simultaneously-held blocks over a solo request's life.

    Accounts for ring-reuse: a streaming-window codec reclaims aged-out
    blocks every step, so its working set is ~window-sized even when
    `blocks_for(total_len)` exceeds the pool.  Admission itself transiently
    holds the full prompt extent (reclaim only runs after the first step),
    hence the `prompt_len` floor.
    """
    mgr = self.manager
    pol = mgr.policy
    pinned = -(-pol.pinned_tokens() // self.block)
    start = max(prompt_len, 1)
    peak = mgr.blocks_for(start)
    for n in range(start + 1, total_len + 1):
      freed = max(pol.dead_below(n - 1) // self.block - pinned, 0)
      peak = max(peak, mgr.blocks_for(n) - freed)
    return peak

  def can_admit(self, prompt_len: int, total_len: Optional[int] = None
                ) -> bool:
    need = self.manager.blocks_for(prompt_len)
    if total_len is not None:
      # one block of growth headroom (vLLM-style watermark), capped at the
      # request's true worst case so admission can never become impossible
      need = min(need + 1, self.manager.blocks_for(total_len))
    return need <= self.manager.free_count

  def admit(self, slot: int, slot_cache: Any, prompt_len: int) -> None:
    if not self.manager.admit(slot, prompt_len):
      raise RuntimeError(
          f"block pool exhausted admitting {prompt_len}-token prompt "
          f"(free={self.manager.free_count})")
    self.storage = self._admit_fused(
        self.storage, slot_cache, jnp.asarray(self.manager.tables[slot]),
        jnp.asarray(slot, jnp.int32))

  def release(self, slot: int) -> None:
    if self.mirror is not None:
      self.mirror.drop(slot)
    self.manager.release(slot)

  # -- per-step growth -------------------------------------------------------
  def need_blocks(self, slot: int, target_len: int) -> int:
    return self.manager.need_blocks(slot, target_len)

  def ensure(self, slot: int, target_len: int) -> bool:
    return self.manager.ensure(slot, target_len)

  def reclaim(self, slot: int, length: int) -> int:
    return self.manager.reclaim(slot, length)

  @property
  def free_blocks(self) -> int:
    return self.manager.free_count

  # -- compute ---------------------------------------------------------------
  def decode(self, params, cur, lengths):
    # peak-traffic snapshot while tables are live (the model is meaningless
    # after requests drain).  Only the block-native path varies per step
    # (mapped blocks/rows); the dense program's figure is a layout constant
    # already captured at init, so the hot loop skips the table scan there.
    if self.block_native:
      snap = self.decode_traffic_model()
      if snap["bytes_per_step"] >= self.decode_traffic["bytes_per_step"]:
        self.decode_traffic = snap
    decode = self._decode_native if self.block_native else self._decode_fused
    logits, self.storage = decode(
        params, jnp.asarray(cur), self.storage,
        jnp.asarray(self.manager.tables), jnp.asarray(lengths))
    return logits

  def decode_traffic_model(self) -> dict:
    """Modeled per-step decode HBM traffic for the paged token state.

    `dense` is what the gather->decode->scatter program moves: every slot's
    full table extent materialized as a dense per-request view and written
    back (2x).  `block-native` reads only the table-mapped pool blocks in
    place and writes one token row per active slot.  The figure the
    tentpole's acceptance tracks is `dense_materialized_bytes_per_step`:
    zero exactly when the block-native program is the one decode() runs.
    """
    mgr = self.manager
    tables = mgr.tables
    live = tables != mgr.trash
    mapped_entries = int(live.sum())
    active = int(live.any(axis=1).sum())
    per_block = self._traffic_per_block
    per_row = self._traffic_per_row
    dense = 2 * per_block * self.blocks_per_req * self.max_batch
    reads = per_block * mapped_entries
    writes = per_row * active
    return dict(
        decode_path="block-native" if self.block_native else "dense-gather",
        decode_kernel=mgr.policy.effective_decode_kernel,
        dense_materialized_bytes_per_step=0 if self.block_native else dense,
        dense_gather_scatter_bytes_per_step=dense,
        block_read_bytes_per_step=reads,
        row_write_bytes_per_step=writes,
        bytes_per_step=(reads + writes) if self.block_native else dense)

  def bytes(self, active_slots: int = 0) -> dict:
    """True allocated-block footprint (what paging buys), not capacity."""
    block_bytes = 0
    resident_total = 0
    for ax, leaf in zip(jax.tree_util.tree_leaves(self._axes),
                        jax.tree_util.tree_leaves(self.storage)):
      if ax == RESIDENT:
        resident_total += leaf.nbytes
      else:
        block_bytes += leaf.nbytes // (self.num_blocks + 1)
    per_slot_resident = resident_total // max(self.max_batch, 1)
    allocated = self.manager.allocated_count
    # prefix sharing: `allocated_blocks * block_bytes` counts each physical
    # block ONCE however many tables map it; `dedup_bytes` is what per-
    # request copies of the multiply-mapped blocks would have cost on top
    tables = self.manager.tables
    live = tables[tables != self.manager.trash].tolist()
    refs = collections.Counter(live)
    shared_blocks = sum(1 for c in refs.values() if c > 1)
    dedup_bytes = sum(c - 1 for c in refs.values() if c > 1) * block_bytes
    out = dict(
        kind="paged", block=self.block, num_blocks=self.num_blocks,
        allocated_blocks=allocated, peak_blocks=self.manager.peak_allocated,
        peak_mapped_blocks=self.manager.peak_mapped,
        peak_mapped_bytes=self.manager.peak_mapped * block_bytes,
        block_bytes=block_bytes,
        resident_bytes_per_slot=per_slot_resident,
        shared_blocks=shared_blocks, dedup_bytes=dedup_bytes,
        prefix_index_blocks=(self.prefix_index.held_blocks
                             if self.prefix_index is not None else 0),
        forked_blocks=self.forked_blocks,
        total_bytes=(allocated * block_bytes
                     + active_slots * per_slot_resident),
        capacity_bytes=(self.num_blocks * block_bytes
                        + self.max_batch * per_slot_resident))
    if self.shard_plan is not None:
      out["sharding"] = ssh.per_shard_bytes(self.shard_plan, self.storage)
    return out

  def __repr__(self) -> str:
    return (f"PagedLayout(block={self.block}, num_blocks={self.num_blocks}, "
            f"free={self.free_blocks})")


@cache_registry.register_layout("tiered")
class TieredLayout(PagedLayout):
  """Two-tier block storage: device pool (tier 0) + large host pool (tier 1).

  Same decodable storage as `PagedLayout`, but pool exhaustion no longer
  forces preempt-and-recompute: a victim request's blocks *spill* to the
  host tier through its policy's per-buffer `spill_codecs()` (PQ code rows
  verbatim, exact KV raw or int8 via the SKVQ machinery), its per-slot
  resident leaves (rings, codebooks) are saved bit-exactly, and a later
  `fetch` restores everything and resumes decoding where it left off — with
  the `TransferLedger` counting the bytes that crossed in each direction.

  Residency state machine per spilled request's blocks:
  RESIDENT -spill-> SPILLED -prefetch-> IN_FLIGHT -fetch-> RESIDENT.
  `decode` asserts every table-mapped block is RESIDENT — touching a
  SPILLED or IN_FLIGHT block is the corruption this machinery must never
  allow, and the invariant the test suite drives.
  """
  spills = True

  def __init__(self, model, max_batch: int, *, block_size: Optional[int] = None,
               num_blocks: Optional[int] = None,
               host_blocks: Optional[int] = None,
               prefix_cache: bool = False,
               prefix_cache_blocks: Optional[int] = None,
               shard_plan: Optional[ssh.ShardPlan] = None,
               shard_redundancy: str = "none"):
    self._host_blocks_arg = host_blocks       # consumed by _make_allocator
    super().__init__(model, max_batch, block_size=block_size,
                     num_blocks=num_blocks, prefix_cache=prefix_cache,
                     prefix_cache_blocks=prefix_cache_blocks,
                     shard_plan=shard_plan,
                     shard_redundancy=shard_redundancy)
    policy = model.cache_policy
    codec_tree = policy.spill_codecs()
    if (jax.tree_util.tree_structure(codec_tree)
        != jax.tree_util.tree_structure(self._axes)):
      raise ValueError(
          f"{type(policy).__name__}.spill_codecs() structure does not match "
          f"paged_axes()")
    self._axes_leaves = jax.tree_util.tree_leaves(self._axes)
    self._codec_leaves = jax.tree_util.tree_leaves(codec_tree)
    for ck in self._codec_leaves:
      tiersmod.get_codec(ck)                  # fail fast on unknown keys
    self.ledger = tiersmod.TransferLedger()
    self.records: Dict[int, tiersmod.SpillRecord] = {}

  def _make_allocator(self, num_blocks: int):
    host = self._host_blocks_arg
    # "large host pool": default 4x the device pool, the capacity-wall gap
    # the host tier exists to absorb.  An explicit 0 is honored (no host
    # tier: exhaustion falls back to recompute preemption).
    self.host_blocks = 4 * num_blocks if host is None else int(host)
    self.pool = tiersmod.TieredBlockPool(num_blocks, self.host_blocks)
    return tiersmod.TierView(self.pool, tiersmod.DEVICE)

  # -- spill / fetch ---------------------------------------------------------
  def _live_row(self, slot: int):
    """(logical_j, device_id) pairs of a slot's table, trash holes skipped."""
    row = self.manager.tables[slot]
    return [(j, int(row[j])) for j in range(self.blocks_per_req)
            if row[j] != self.manager.trash]

  def _split_shared(self, slot: int):
    """Partition a slot's live blocks into (shared, exclusive) pairs.

    A block with any hold beyond this slot's own (the prefix index, another
    request's table, another spill record's pin) is *shared*: it must stay
    device-resident across this slot's swap-out — a shared prefix block
    spills zero times, not once per request."""
    shared, excl = [], []
    for j, pid in self._live_row(slot):
      (shared if self.pool.refcount(pid) > 1 else excl).append((j, pid))
    return shared, excl

  def can_spill(self, slot: int) -> bool:
    _, excl = self._split_shared(slot)
    return len(excl) <= self.pool.free_count(tiersmod.HOST)

  def spill(self, slot: int, rid: int, length: int) -> int:
    """Swap a slot out: encode its exclusive blocks to the host tier, pin
    its shared (prefix) blocks device-resident, save its resident leaves,
    release its table.  Returns device blocks actually freed."""
    if rid in self.records:
      raise ValueError(f"request {rid} already spilled")
    mgr = self.manager
    shared, live = self._split_shared(slot)
    dev_ids = [pid for _, pid in live]
    n = len(dev_ids)
    host_ids = self.pool.alloc(n, owner=rid, tier=tiersmod.HOST)
    if host_ids is None:
      raise RuntimeError(
          f"host pool exhausted spilling slot {slot} "
          f"(need {n}, free {self.pool.free_count(tiersmod.HOST)})")
    hwm = mgr.high_water(slot)
    padded = np.full((self.blocks_per_req,), mgr.trash, np.int32)
    padded[:n] = dev_ids
    padded_j = jnp.asarray(padded)
    payloads: list = []
    resident_rows: list = []
    nbytes = raw = 0
    for ax, ck, st in zip(self._axes_leaves, self._codec_leaves,
                          jax.tree_util.tree_leaves(self.storage)):
      if ax == RESIDENT:
        # per-slot leaves (rings, codebooks) would be overwritten by the
        # slot's next tenant; they cross the boundary raw (bit-exact)
        rowv = np.asarray(st[:, slot])
        payloads.append(None)
        resident_rows.append(rowv)
        nbytes += rowv.nbytes
        raw += rowv.nbytes
      else:
        arr = np.asarray(st[padded_j])[:n]
        enc, nb = tiersmod.get_codec(ck).encode(arr)
        payloads.append((ck, enc, arr.shape, arr.dtype))
        resident_rows.append(None)
        nbytes += nb
        raw += arr.nbytes
    rec = tiersmod.SpillRecord(
        rid=rid, length=length, hwm=hwm,
        pairs=[(j, hid) for (j, _), hid in zip(live, host_ids)],
        payloads=payloads, resident_rows=resident_rows,
        shared_pairs=list(shared),
        checksums=[None if p is None else tiersmod.payload_checksum(p[1])
                   for p in payloads])
    if shared:
      # pin shared blocks device-resident across the swap-out: the slot's
      # hold is about to be released and the index may evict at any time
      self.pool.ref([pid for _, pid in shared], owner=rec.spill_owner)
    if self.mirror is not None:
      # the spill record is now the authoritative host copy; the mirror
      # entry would go stale the moment the slot is re-tenanted
      self.mirror.drop(slot)
    mgr.release(slot)                   # slot's holds dropped, excl freed
    rec.nbytes, rec.raw_bytes = nbytes, raw
    self.records[rid] = rec
    self.ledger.record_spill(nbytes, raw, n)
    return n

  def can_fetch(self, rid: int, total_len: Optional[int] = None) -> bool:
    rec = self.records[rid]
    if rec.state == tiersmod.BLOCK_IN_FLIGHT:
      return True                       # destination blocks already held
    need = rec.n_blocks
    if total_len is not None:
      # one growth-headroom block (mirrors can_admit), capped at the true
      # worst case so re-admission can never become impossible
      need = max(min(need + 1, self.manager.blocks_for(total_len)),
                 rec.n_blocks)
    return need <= self.manager.free_count

  def prefetch(self, rid: int) -> bool:
    """Fetch-ahead hint: allocate IN_FLIGHT destination blocks and stage the
    decoded payloads now, so the admit on the *next* step only finalizes.
    Returns False (no change) when the request is not spilled or the device
    pool cannot hold it yet — it is a hint, never an obligation."""
    rec = self.records.get(rid)
    if rec is None or rec.state != tiersmod.BLOCK_SPILLED:
      return False
    # same growth-headroom watermark can_fetch applies to the SPILLED path:
    # starting a transfer into a pool with zero slack would admit a request
    # whose first growth immediately spills someone else (an avoidable
    # device<->host round trip)
    if min(rec.n_blocks + 1, self.num_blocks) > self.manager.free_count:
      return False
    ids = self.pool.alloc(rec.n_blocks, owner=("fetch", rid),
                          state=tiersmod.BLOCK_IN_FLIGHT)
    if ids is None:
      return False
    rec.device_ids = ids
    try:
      rec.staged = self._decode_payloads(rec)
    except tiersmod.SpillPageCorruption:
      # roll the allocation back before surfacing: the record stays SPILLED
      # and the destination blocks return to the free pool (no leak)
      self.pool.unref(ids, owner=("fetch", rid))
      rec.device_ids = None
      raise
    rec.state = tiersmod.BLOCK_IN_FLIGHT
    self.ledger.record_fetch(rec.nbytes, rec.raw_bytes, rec.n_blocks)
    return True

  def fetch(self, rid: int, slot: int) -> None:
    """Swap a request back in: blocks RESIDENT, table adopted into `slot`,
    storage leaves restored, host blocks freed."""
    rec = self.records.pop(rid)
    mgr = self.manager
    if rec.state == tiersmod.BLOCK_SPILLED:   # no fetch-ahead happened
      ids = self.pool.alloc(rec.n_blocks, owner=("fetch", rid),
                            state=tiersmod.BLOCK_IN_FLIGHT)
      if ids is None:
        self.records[rid] = rec               # restore; caller gated wrongly
        raise RuntimeError(
            f"device pool exhausted fetching request {rid} "
            f"(need {rec.n_blocks}, free {mgr.free_count})")
      rec.device_ids = ids
      try:
        rec.staged = self._decode_payloads(rec)
      except tiersmod.SpillPageCorruption:
        self.pool.unref(ids, owner=("fetch", rid))
        rec.device_ids = None
        self.records[rid] = rec           # restore: still SPILLED, no leak
        raise
      self.ledger.record_fetch(rec.nbytes, rec.raw_bytes, rec.n_blocks)
    dev_ids = list(rec.device_ids or [])
    self.pool.set_state(dev_ids, tiersmod.BLOCK_RESIDENT)
    self.pool.reassign(dev_ids, ("fetch", rid), slot)
    if rec.shared_pairs:
      # shared prefix blocks never left the device: hand their pin holds to
      # the destination slot (they are RESIDENT throughout — other requests
      # may have decoded from them the whole time)
      self.pool.reassign([pid for _, pid in rec.shared_pairs],
                         rec.spill_owner, slot)
    mgr.adopt(slot,
              rec.shared_pairs + [(j, did)
                                  for (j, _), did in zip(rec.pairs, dev_ids)],
              rec.hwm)
    padded = np.full((self.blocks_per_req,), mgr.trash, np.int32)
    padded[:len(dev_ids)] = dev_ids
    padded_j = jnp.asarray(padded)
    leaves, treedef = jax.tree_util.tree_flatten(self.storage)
    out = []
    for ax, st, staged, rowv in zip(self._axes_leaves, leaves, rec.staged,
                                    rec.resident_rows):
      if ax == RESIDENT:
        st = st.at[:, slot].set(jnp.asarray(rowv).astype(st.dtype))
      else:
        # pad with zero blocks aimed at the trash block: fixed shapes keep
        # the dispatch cache warm, and trash content is never read
        pad_shape = (self.blocks_per_req,) + tuple(st.shape[1:])
        vals = np.zeros(pad_shape, staged.dtype)
        vals[:len(dev_ids)] = staged
        st = st.at[padded_j].set(jnp.asarray(vals).astype(st.dtype))
      out.append(st)
    self.storage = jax.tree_util.tree_unflatten(treedef, out)
    self.pool.unref(rec.host_ids, owner=rid, tier=tiersmod.HOST)

  def abort_prefetch(self, rid: int) -> bool:
    """Roll an IN_FLIGHT fetch back to SPILLED (transfer failed or was
    cancelled): free the destination device blocks, drop the staged decoded
    arrays.  The host-tier payload is untouched, so a retry simply starts
    the transfer over.  Returns False (no change) when the request has no
    fetch in flight."""
    rec = self.records.get(rid)
    if rec is None or rec.state != tiersmod.BLOCK_IN_FLIGHT:
      return False
    self.pool.unref(rec.device_ids or [], owner=("fetch", rid))
    rec.device_ids = None
    rec.staged = None
    rec.state = tiersmod.BLOCK_SPILLED
    self.ledger.fetch_aborts += 1
    return True

  def drop_spilled(self, rid: int) -> int:
    """Permanently discard a spilled request's state (bounded fetch retries
    exhausted: the request is failed, not resumed).  Releases everything
    the record holds — in-flight destination blocks, shared-prefix pins,
    host-tier blocks — so a dropped request leaks nothing from either pool.
    Returns the host blocks freed."""
    rec = self.records.pop(rid)
    if rec.state == tiersmod.BLOCK_IN_FLIGHT and rec.device_ids:
      self.pool.unref(rec.device_ids, owner=("fetch", rid))
    if rec.shared_pairs:
      self.pool.unref([pid for _, pid in rec.shared_pairs],
                      owner=rec.spill_owner)
    self.pool.unref(rec.host_ids, owner=rid, tier=tiersmod.HOST)
    return rec.n_blocks

  def spill_pins(self, rid: int) -> List[int]:
    """Device block ids a spilled request pins (its shared prefix blocks).

    The shard-loss recovery path uses this to decide whether a spilled
    request can simply resume: if any pinned block was damaged by the dead
    shard, its cached prefix is gone and the request must recompute."""
    rec = self.records.get(rid)
    if rec is None:
      return []
    return [pid for _, pid in rec.shared_pairs]

  def _decode_payloads(self, rec):
    # verify the frame checksums stamped at spill time before decoding:
    # a corrupted host page must never be scattered into decodable storage
    sums = rec.checksums or [None] * len(rec.payloads)
    for p, want in zip(rec.payloads, sums):
      if p is None or want is None:
        continue
      if tiersmod.payload_checksum(p[1]) != want:
        raise tiersmod.SpillPageCorruption(
            f"request {rec.rid}: spilled page checksum mismatch "
            f"(codec {p[0]!r})")
    return [None if p is None else
            tiersmod.get_codec(p[0]).decode(p[1], p[2], p[3])
            for p in rec.payloads]

  def corrupt_spilled(self, rid: int) -> bool:
    """Flip one byte in a spilled request's first encoded page (fault
    injection): the stored checksum goes stale, so the next fetch attempt
    raises `SpillPageCorruption` instead of decoding garbage.  Returns
    False when the request has no encoded host-tier payload to corrupt."""
    rec = self.records.get(rid)
    if rec is None:
      return False
    for p in rec.payloads:
      if p is None:
        continue
      enc = p[1]
      arrs = ([v for v in (enc[k] for k in sorted(enc))
               if isinstance(v, np.ndarray)]
              if isinstance(enc, dict) else
              [enc] if isinstance(enc, np.ndarray) else [])
      for a in arrs:
        if a.nbytes:
          a.view(np.uint8).reshape(-1)[0] ^= 0xFF
          return True
    return False

  # -- compute ---------------------------------------------------------------
  def decode(self, params, cur, lengths):
    # the invariant this tier system must never break: a decode step only
    # touches RESIDENT device blocks (SPILLED/IN_FLIGHT payloads are not in
    # decodable storage)
    tables = self.manager.tables
    live = [int(x) for x in tables[tables != self.manager.trash]]
    self.pool.assert_state(live, tiersmod.BLOCK_RESIDENT)
    self.pool.touch(live)               # LRU clock for cold-victim selection
    return super().decode(params, cur, lengths)

  def lru_victim(self, active, tiebreak=None) -> Optional[int]:
    """Coldest active slot by last block touch (LRU cold-victim selection).

    `active` is (slot, request) pairs; `tiebreak(request)` orders equally-
    cold slots (every decoding slot is touched each step, so ties are the
    common case).  The pool stays a layout-private detail — schedulers call
    this instead of reaching into it.
    """
    active = list(active)
    if not active:
      return None
    if tiebreak is None:
      tiebreak = lambda req: 0                    # noqa: E731
    return min(active, key=lambda sr: (self.pool.owner_last_touch(sr[0]),
                                       tiebreak(sr[1])))[0]

  def bytes(self, active_slots: int = 0) -> dict:
    d = super().bytes(active_slots)
    # NOTE: the transfer ledger is deliberately not embedded here — callers
    # that want it read `layout.ledger.as_dict()` (one source of truth)
    d.update(
        kind="tiered", host_blocks=self.host_blocks,
        host_allocated_blocks=self.pool.allocated_count(tiersmod.HOST),
        spilled_requests=len(self.records),
        spilled_payload_bytes=sum(r.nbytes for r in self.records.values()))
    return d

  def __repr__(self) -> str:
    return (f"TieredLayout(block={self.block}, num_blocks={self.num_blocks}, "
            f"host_blocks={self.host_blocks}, free={self.free_blocks}, "
            f"spilled={len(self.records)})")
