"""Page-aware windowed clustering (AQPIM §III-B Fig. 6 + §III-F co-design).

The sequence is divided into context windows; each window gets its own codebook
"page" sized so all K centroids' inner products fit one DRAM row (PIM) / one VMEM
tile (TPU).  When a window advances, the previous window's centroids are *copied to
the new page and refined* on the new window's tokens (warm start) — Fig. 6 step (1).

A single window over the whole sequence (the paper's default: 512 centroids for the
entire context) is the degenerate case n_windows=1.

Implemented as a `lax.scan` over windows, carrying the centroid state: this makes
the whole compression step one fixed-shape jitted program that pjit can shard
(windows are sequential by construction — the warm-start chain — but everything
inside a window is data-parallel over subvectors/heads).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common import Array
from repro.core import kmeans, pq


def windowed_build_codebooks(
    x: Array,
    weights: Array,
    cfg: pq.PQConfig,
    n_windows: int,
    mask: Optional[Array] = None,
) -> Tuple[Array, Array]:
  """Cluster a (N, d) token stream into n_windows warm-started codebook pages.

  Returns:
    codebooks: (n_windows, m, K, dsub) f32
    indices:   (N, m) int32
  """
  n, d = x.shape
  assert n % n_windows == 0, f"N={n} must divide into n_windows={n_windows}"
  w_len = n // n_windows
  m = cfg.m
  xs = x.reshape(n_windows, w_len, d)
  ws = weights.reshape(n_windows, w_len)
  if mask is None:
    mask = jnp.ones((n,), bool)
  ms = mask.reshape(n_windows, w_len)

  # subvector view per window: (nW, m, W, dsub)
  xs_sub = jnp.swapaxes(pq.split(xs, m), 1, 2)

  def first_window():
    cb, idx = pq.build_codebook(xs[0], ws[0], cfg, mask=ms[0])
    return cb, idx

  cb0, idx0 = first_window()

  def step(carry, inp):
    prev_cb = carry                                   # (m, K, dsub)
    x_w, w_w, m_w = inp                               # (W, d), (W,), (W,)
    cb, idx = pq.build_codebook(
        x_w, w_w, cfg, mask=m_w, init_codebook=prev_cb)
    return cb, (cb, idx)

  if n_windows == 1:
    codebooks = cb0[None]
    indices = idx0
  else:
    _, (cbs, idxs) = jax.lax.scan(
        step, cb0, (xs[1:], ws[1:], ms[1:]))
    codebooks = jnp.concatenate([cb0[None], cbs], axis=0)
    indices = jnp.concatenate([idx0[None], idxs], axis=0).reshape(n, m)
  return codebooks, indices


def windowed_encode(
    x: Array, codebooks: Array, window_ids: Array
) -> Array:
  """Encode tokens against their window's codebook page.

  x: (N, d); codebooks: (nW, m, K, dsub); window_ids: (N,) int32 -> (N, m).
  Used during decode to append a new token's indices (paper Fig. 3a decode step 3).
  """
  cb_tok = codebooks[window_ids]                      # (N, m, K, dsub)
  m = codebooks.shape[1]
  xs = pq.split(x, m)                                 # (N, m, dsub)

  def assign_token(sub_tok, cb):
    # sub_tok (m, dsub), cb (m, K, dsub)
    d2 = jnp.sum((cb - sub_tok[:, None, :]) ** 2, axis=-1)  # (m, K)
    return jnp.argmin(d2, axis=-1).astype(jnp.int32)
  return jax.vmap(assign_token)(xs.astype(jnp.float32), cb_tok.astype(jnp.float32))


def windowed_decode(
    indices: Array, codebooks: Array
) -> Array:
  """Reconstruct (N, d) from windowed pages (testing/debug only — the attention
  path never reconstructs; that is the point of the paper)."""
  n_w, m, k, dsub = codebooks.shape
  n = indices.shape[0]
  w_len = n // n_w
  idx_w = indices.reshape(n_w, w_len, m)
  out = jax.vmap(pq.decode)(idx_w, codebooks)         # (nW, W, d)
  return out.reshape(n, m * dsub)
