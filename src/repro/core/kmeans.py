"""Importance-weighted k-means clustering (AQPIM §III-C, Eq. (2)).

The paper's key algorithmic component: k-means over activation (sub)vectors where
each token carries an importance weight derived from attention scores.  Centroids
are updated as weighted averages (Eq. 2):

    mu_k = sum_{n in C_k} w_n x_n / sum_{n in C_k} w_n

Per AQPIM §III-B, a *fixed* number of iterations (4) converges to a stable state,
which lets the PIM hide clustering behind prefill.  We keep the iteration count a
static Python int so the loop unrolls/scans into a fixed-depth HLO — essential for
`jax.jit`/`pjit` and for the dry-run cost model.

All accumulation is f32 regardless of input dtype (bf16-safe).  Empty clusters keep
their previous centroid (mirrors standard k-means practice; the paper's PIM dataflow
computes numerator on BankPE, 1/denominator on BufferPE — a zero denominator never
reaches the divider because assignment retains at least the seeding token unless a
centroid loses all members, in which case we freeze it).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common import Array

DEFAULT_ITERS = 4  # paper §III-B: "just four iterations converge to a stable state"


def pairwise_sq_dists(x: Array, centroids: Array) -> Array:
  """Squared Euclidean distances, matmul-dominant form (MXU friendly).

  ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2.  Shapes: x (N, d), centroids (K, d)
  -> (N, K).  f32 accumulation.
  """
  x = x.astype(jnp.float32)
  centroids = centroids.astype(jnp.float32)
  x_sq = jnp.sum(x * x, axis=-1, keepdims=True)            # (N, 1)
  c_sq = jnp.sum(centroids * centroids, axis=-1)           # (K,)
  cross = x @ centroids.T                                  # (N, K)  MXU
  return x_sq - 2.0 * cross + c_sq[None, :]


def assign_clusters(x: Array, centroids: Array) -> Array:
  """Nearest-centroid assignment (paper: Distance Calculation + Cluster Assignment)."""
  return jnp.argmin(pairwise_sq_dists(x, centroids), axis=-1).astype(jnp.int32)


def _weighted_update(
    x: Array, w: Array, assign: Array, centroids: Array
) -> Array:
  """One weighted centroid update (Eq. 2), one-hot-matmul (scatter-free) form.

  The one-hot matmul is the TPU-native analogue of the paper's BankPE
  scatter-accumulate: it is a dense (K, N) @ (N, d) matmul that maps onto the MXU.
  """
  n, d = x.shape
  k = centroids.shape[0]
  x32 = x.astype(jnp.float32)
  w32 = w.astype(jnp.float32)
  onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)     # (N, K)
  wo = onehot * w32[:, None]                                # (N, K)
  num = wo.T @ x32                                          # (K, d) weighted sums
  den = jnp.sum(wo, axis=0)                                 # (K,)  weight mass
  safe_den = jnp.maximum(den, 1e-12)
  new_centroids = num / safe_den[:, None]
  # freeze empty clusters
  empty = (den <= 1e-12)[:, None]
  return jnp.where(empty, centroids.astype(jnp.float32), new_centroids)


def init_centroids(x: Array, k: int, key: Array | None = None) -> Array:
  """Deterministic strided init (default) or random-choice init.

  Strided init picks every (N//K)-th token: cheap, deterministic across hosts
  (important for SPMD — every data shard must agree on the centroid seed when the
  sequence axis is sharded), and empirically as good as random init at 4 iterations.
  """
  n = x.shape[0]
  if key is None:
    stride = max(n // k, 1)
    idx = (jnp.arange(k) * stride) % n
  else:
    idx = jax.random.choice(key, n, shape=(k,), replace=n < k)
  return x[idx].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def weighted_kmeans(
    x: Array,
    w: Array,
    k: int,
    iters: int = DEFAULT_ITERS,
    key: Array | None = None,
    mask: Array | None = None,
) -> Tuple[Array, Array]:
  """Importance-weighted k-means.

  Args:
    x: (N, d) points (tokens' subvectors).
    w: (N,) non-negative importance weights (Eq. 1).
    k: number of centroids (paper default 512).
    iters: fixed iteration count (paper default 4).
    key: optional PRNG key for random init; None -> deterministic strided init.
    mask: optional (N,) bool; False entries are padding and are excluded by
      zeroing their weight AND pushing their distance to +inf-equivalent so they
      never seed/claim a centroid by assignment weight.

  Returns:
    (centroids (k, d) f32, assignments (N,) int32)
  """
  x_init = x
  if mask is not None:
    w = jnp.where(mask, w, 0.0)
    # padding must never seed a centroid: collapse masked rows onto row 0
    # (duplicate seeds become empty clusters and freeze near real data)
    x_init = jnp.where(mask[:, None], x, x[0])
  # guard: if all weights vanish (e.g. fully-padded window) fall back to uniform.
  total = jnp.sum(w.astype(jnp.float32))
  w = jnp.where(total > 0, w, jnp.ones_like(w))

  centroids0 = init_centroids(x_init, k, key)

  def body(_, carry):
    centroids = carry
    assign = assign_clusters(x, centroids)
    return _weighted_update(x, w, assign, centroids)

  centroids = jax.lax.fori_loop(0, iters, body, centroids0)
  assign = assign_clusters(x, centroids)
  return centroids, assign


def weighted_quantization_error(
    x: Array, w: Array, centroids: Array, assign: Array
) -> Array:
  """Weighted objective the paper minimizes: sum_n w_n ||x_n - mu_{a_n}||^2."""
  recon = centroids[assign]
  err = jnp.sum((x.astype(jnp.float32) - recon) ** 2, axis=-1)
  return jnp.sum(w.astype(jnp.float32) * err)
