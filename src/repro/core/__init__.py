"""AQPIM core: the paper's contribution as composable JAX modules.

- kmeans         importance-weighted k-means (Eq. 2), fixed-iteration
- channel_sort   cosine-similarity channel grouping absorbed into projections
- pq             Product Quantization codec (split/encode/decode/build)
- windowed       page-aware windowed clustering (warm-started codebook pages)
- pq_attention   attention directly on compressed data (Fig. 5 flow)
- importance     attention-score importance weights (Eq. 1)
- kv_cache       exact + PQ-compressed KV caches (sink | body | recent)
- baselines      SKVQ/SnapKV/StreamingLLM/PQCache-like comparison methods
"""
from repro.core import (
    baselines,
    channel_sort,
    importance,
    kmeans,
    kv_cache,
    pq,
    pq_attention,
    windowed,
)

__all__ = [
    "baselines",
    "channel_sort",
    "importance",
    "kmeans",
    "kv_cache",
    "pq",
    "pq_attention",
    "windowed",
]
