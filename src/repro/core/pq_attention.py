"""PQ-based attention computed directly on compressed KV (AQPIM Fig. 5).

Decode attention for one new query against a PQ-compressed context:

  1. split q into m subvectors                                  (paper step 1)
  2. inner-product table  T[j,k] = <q_j, C_key[j,k]>            (paper step 2)
  3. score lookup         s_n = sum_j T[j, key_idx[n,j]]        (paper step 3-4)
  4. softmax over (sink | PQ body | recent window)              (paper step 5)
  5. value bucket-sum     B[j,k] = sum_{n: val_idx[n,j]=k} p_n  (paper step 6, no
     reconstruction: out_j = sum_k B[j,k] * C_val[j,k])         (paper step 7)

Step 3's "intra-row indirection" (random lookups guaranteed to hit one DRAM row)
maps to: T lives in VMEM inside the Pallas kernel (kernels/pq_decode.py); this module
is the mathematically identical pure-JAX implementation used for (a) the oracle,
(b) CPU-hosted paths, (c) the lowered multi-pod graphs (XLA fuses the gathers).

Step 5's bucket accumulation replaces the O(N*d) score@V GEMV with an O(N*m)
scatter + O(m*K*dsub) = O(K*d) matmul — the FLOP and byte savings that the paper's
Fig. 12/13 measure.

Everything here is per-(batch, kv-head); call sites vmap.  GQA queries arrive as a
group (g, d) sharing one compressed KV head.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common import Array
from repro.core import pq

NEG_INF = -1e30


def inner_product_table(q: Array, codebook: Array) -> Array:
  """q (..., d), codebook (m, K, dsub) -> T (..., m, K).  f32."""
  m, _, dsub = codebook.shape
  qs = q.reshape(*q.shape[:-1], m, dsub).astype(jnp.float32)
  return jnp.einsum("...md,mkd->...mk", qs, codebook.astype(jnp.float32))


def lookup_scores(table: Array, key_indices: Array) -> Array:
  """T (..., m, K), key_indices (N, m) -> scores (..., N).

  sum over subvectors of table values selected by each token's centroid ids.
  Implemented as ONE gather over the flattened (m*K) table axis (indices
  offset by their subvector's page) instead of m vmapped gathers — the same
  values, one kernel; ~2.5x faster at the paper operating point on XLA.
  """
  n, m = key_indices.shape
  k = table.shape[-1]
  flat_idx = (key_indices + jnp.arange(m)[None, :] * k).reshape(-1)  # (N*m,)
  flat_t = table.reshape(*table.shape[:-2], m * k)
  gathered = jnp.take(flat_t, flat_idx, axis=-1)      # (..., N*m)
  return jnp.sum(gathered.reshape(*table.shape[:-2], n, m), axis=-1)


def bucket_accumulate(probs: Array, value_indices: Array, k: int) -> Array:
  """probs (..., N), value_indices (N, m) -> buckets (..., m, K).

  Scatter-add of attention probabilities into per-(subvector, centroid) buckets.
  The MXU-friendly formulation (one-hot matmul) is used by the perf path; this
  scatter form is the reference semantics (identical result).
  """
  def one_sub(idx_j: Array) -> Array:
    onehot = jax.nn.one_hot(idx_j, k, dtype=probs.dtype)   # (N, K)
    return probs @ onehot                                  # (..., K)
  buckets = jax.vmap(one_sub, in_axes=1, out_axes=-2)(value_indices)
  return buckets                                           # (..., m, K)


def output_from_buckets(buckets: Array, value_codebook: Array) -> Array:
  """buckets (..., m, K), codebook (m, K, dsub) -> out (..., d)."""
  out_sub = jnp.einsum(
      "...mk,mkd->...md", buckets.astype(jnp.float32),
      value_codebook.astype(jnp.float32))
  return out_sub.reshape(*out_sub.shape[:-2], -1)


def reconstruct_values(value_indices: Array, value_codebook: Array) -> Array:
  """value_indices (N, m), codebook (m, K, dsub) -> decoded values (N, d).

  The mathematically identical dual of the bucket-sum: out = p @ V_rec equals
  output_from_buckets(bucket_accumulate(p, idx, K), C) exactly (same terms,
  reassociated).  This is the formulation the Pallas kernel uses in VMEM and
  the cheaper one for XLA hosts whenever m*K >> d — the bucket path's one-hot
  matmul costs O(N*m*K) flops against O(N*d) here.
  """
  def one_sub(cb_j: Array, idx_j: Array) -> Array:
    return jnp.take(cb_j.astype(jnp.float32), idx_j, axis=0)   # (N, dsub)
  sub = jax.vmap(one_sub, in_axes=(0, 1), out_axes=1)(
      value_codebook, value_indices)                           # (N, m, dsub)
  return sub.reshape(sub.shape[0], -1)


def segment_attention_stats(
    q: Array, k: Array, v: Array, mask: Array, scale: float
) -> tuple:
  """One exact segment's flash-decoding partial: q (g, d), k/v (S, d).

  Returns (normalized out (g, d), running max (g,), denom (g,)) — the combine
  contract shared with the Pallas kernels (`ops.combine_attention_segments`).
  An all-masked segment yields (0, NEG_INF, 0) and combines to nothing.
  """
  q32 = q.astype(jnp.float32)
  s = (q32 @ k.astype(jnp.float32).T) * scale
  s = jnp.where(mask[None, :], s, NEG_INF)
  mm = jnp.max(s, axis=-1, initial=NEG_INF)
  p = jnp.exp(s - mm[:, None])
  p = jnp.where(mask[None, :], p, 0.0)
  denom = jnp.sum(p, axis=-1)
  out = (p @ v.astype(jnp.float32)) / jnp.maximum(denom, 1e-30)[:, None]
  return out, mm, denom


class PQAttnSegments(NamedTuple):
  """One kv-head's compressed context (paper §IV-A layout).

  sink: first tokens kept exact (8 by default); recent: sliding window kept exact
  (32 by default, also the importance window t); body: PQ-compressed middle.
  """
  sink_k: Array          # (S0, d)
  sink_v: Array          # (S0, d)
  sink_mask: Array       # (S0,) bool
  key_codebook: Array    # (m, K, dsub)  (or (nW, m, K, dsub) windowed)
  value_codebook: Array  # (m, K, dsub)
  key_indices: Array     # (N, m) int32
  value_indices: Array   # (N, m) int32
  body_mask: Array       # (N,) bool
  recent_k: Array        # (R, d)
  recent_v: Array        # (R, d)
  recent_mask: Array     # (R,) bool


def pq_decode_attention(
    q: Array,
    seg: PQAttnSegments,
    scale: float,
    value_mode: str = "bucket",
) -> Array:
  """Single-step decode attention over compressed context, jointly softmaxed.

  q: (g, d) — GQA query group sharing this kv head (g=1 for MHA).
  Returns (g, d) attention outputs, f32.

  `value_mode` selects the body value path: "bucket" is the paper's bucket-sum
  reference semantics; "reconstruct" computes the identical sum through
  decoded value rows (`reconstruct_values`) — the kernel's VMEM formulation
  and the faster XLA lowering when m*K >> d (serve hot path).
  """
  q32 = q.astype(jnp.float32)

  windowed = seg.key_codebook.ndim == 4
  if windowed:
    s_body = windowed_lookup_scores(
        q32, seg.key_codebook, seg.key_indices) * scale
  else:
    table_k = inner_product_table(q32, seg.key_codebook)      # (g, m, K)
    s_body = lookup_scores(table_k, seg.key_indices) * scale  # (g, N)
  s_body = jnp.where(seg.body_mask[None, :], s_body, NEG_INF)

  # sink and recent are both small exact segments: one concatenated score
  # matmul instead of two (fewer kernels on the serve hot path; identical
  # joint softmax)
  k_ex = jnp.concatenate([seg.sink_k, seg.recent_k], axis=0)
  v_ex = jnp.concatenate([seg.sink_v, seg.recent_v], axis=0)
  mask_ex = jnp.concatenate([seg.sink_mask, seg.recent_mask], axis=0)
  s_ex = (q32 @ k_ex.astype(jnp.float32).T) * scale            # (g, S0+R)
  s_ex = jnp.where(mask_ex[None, :], s_ex, NEG_INF)

  # `initial` handles zero-size segments (e.g. sink-less configs)
  m_all = jnp.maximum(
      jnp.max(s_body, axis=-1, initial=NEG_INF),
      jnp.max(s_ex, axis=-1, initial=NEG_INF),
  )                                                            # (g,)
  # masked scores sit at NEG_INF, so their exp underflows to exactly 0
  e_body = jnp.exp(s_body - m_all[:, None])
  e_ex = jnp.exp(s_ex - m_all[:, None])
  denom = jnp.sum(e_body, -1) + jnp.sum(e_ex, -1)

  if windowed:
    out_body = windowed_output(e_body, seg.value_indices, seg.value_codebook)
  elif value_mode == "reconstruct":
    vrec = reconstruct_values(seg.value_indices, seg.value_codebook)  # (N, d)
    out_body = e_body @ vrec                                          # (g, d)
  else:
    k_cent = seg.value_codebook.shape[1]
    buckets = bucket_accumulate(e_body, seg.value_indices, k_cent)  # (g, m, K)
    out_body = output_from_buckets(buckets, seg.value_codebook)     # (g, d)
  out_ex = e_ex @ v_ex.astype(jnp.float32)
  return (out_body + out_ex) / denom[:, None]


# ---------------------------------------------------------------------------
# Page-aware windowed variant (paper §III-B Fig. 6, §III-F)
# ---------------------------------------------------------------------------

def windowed_lookup_scores(
    q: Array, codebooks: Array, key_indices: Array
) -> Array:
  """q (g, d), codebooks (nW, m, K, dsub), key_indices (N, m), N = nW*W.

  Each window has its own codebook page (one DRAM row on PIM; one VMEM tile on
  TPU).  Tables are computed per window, lookups never cross a window boundary —
  the TPU analogue of "indirection only happens within a page".
  """
  n_w = codebooks.shape[0]
  n, m = key_indices.shape
  w = n // n_w
  idx_w = key_indices.reshape(n_w, w, m)

  def per_window(cb, idx):
    table = inner_product_table(q, cb)          # (g, m, K)
    return lookup_scores(table, idx)            # (g, W)
  scores = jax.vmap(per_window)(codebooks, idx_w)   # (nW, g, W)
  return jnp.moveaxis(scores, 0, 1).reshape(q.shape[0], n)


def windowed_output(
    probs: Array, value_indices: Array, codebooks: Array
) -> Array:
  """probs (g, N), value_indices (N, m), codebooks (nW, m, K, dsub) -> (g, d)."""
  n_w, m, k, dsub = codebooks.shape
  g, n = probs.shape
  w = n // n_w
  p_w = probs.reshape(g, n_w, w)
  idx_w = value_indices.reshape(n_w, w, m)

  def per_window(p, cb, idx):
    buckets = bucket_accumulate(p, idx, k)       # (g, m, K)
    return output_from_buckets(buckets, cb)      # (g, d)
  outs = jax.vmap(per_window, in_axes=(1, 0, 0))(p_w, codebooks, idx_w)
  return jnp.sum(outs, axis=0)


# ---------------------------------------------------------------------------
# Reference exact attention for error measurement
# ---------------------------------------------------------------------------

def exact_decode_attention(
    q: Array, k: Array, v: Array, mask: Array, scale: float
) -> Array:
  """q (g, d), k/v (N, d), mask (N,) -> (g, d).  f32 oracle."""
  s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
  s = jnp.where(mask[None, :], s, NEG_INF)
  p = jax.nn.softmax(s, axis=-1)
  return p @ v.astype(jnp.float32)
