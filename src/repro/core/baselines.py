"""Baseline KV-cache mitigation methods the paper compares against (§IV-A/B).

- SKVQ-like   : sliding-window uniform quantization with channel reordering
                (asymmetric per-group int4/int8; sink + recent kept exact).
- SnapKV-like : eviction — keep top-k tokens by attention importance observed from
                a recent query window.
- StreamingLLM: static sink + sliding window (eviction of everything else).
- PQCache-like: PQ used only to *identify* top-k tokens (approx. inner-product
                search); exact KV for selected tokens is "fetched from CPU" — we
                model the fetch bytes for the Fig. 11/13 bandwidth analysis.

All are implemented as drop-in decode-attention transforms so the benchmark harness
can sweep method x compression-ratio on identical inputs (Fig. 10 analogue).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common import Array
from repro.core import pq, pq_attention

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# SKVQ-like: group-wise uniform quantization with channel reorder
# ---------------------------------------------------------------------------

class UniformQuantized(NamedTuple):
  q: Array        # (N, d) int8 storage
  scale: Array    # (N, groups) f32
  zero: Array     # (N, groups) f32
  perm: Array     # (d,) channel reorder
  bits: int


def channel_reorder_by_range(x: Array) -> Array:
  """SKVQ reorders channels so similar dynamic ranges share a quant group."""
  rng = jnp.max(x, axis=0) - jnp.min(x, axis=0)
  return jnp.argsort(rng)


def uniform_quantize(x: Array, bits: int, group: int, perm: Array) -> UniformQuantized:
  """Asymmetric per-(token, channel-group) uniform quantization."""
  n, d = x.shape
  xp = x[:, perm].astype(jnp.float32)
  g = d // group
  xg = xp.reshape(n, g, group)
  lo = jnp.min(xg, axis=-1)
  hi = jnp.max(xg, axis=-1)
  qmax = float(2 ** bits - 1)
  scale = jnp.maximum(hi - lo, 1e-8) / qmax
  q = jnp.clip(jnp.round((xg - lo[..., None]) / scale[..., None]), 0, qmax)
  return UniformQuantized(
      q=q.reshape(n, d).astype(jnp.uint8 if bits <= 8 else jnp.int32),
      scale=scale, zero=lo, perm=perm, bits=bits)


def uniform_dequantize(uq: UniformQuantized, group: int) -> Array:
  n, d = uq.q.shape
  g = d // group
  xg = uq.q.astype(jnp.float32).reshape(n, g, group)
  xp = xg * uq.scale[..., None] + uq.zero[..., None]
  inv = jnp.argsort(uq.perm)
  return xp.reshape(n, d)[:, inv]


def skvq_decode_attention(
    q: Array, k: Array, v: Array, mask: Array, scale: float,
    bits: int = 4, group: int = 32,
) -> Array:
  """Quantize-dequantize KV then exact attention (GPUs must upcast — §IV-E)."""
  perm_k = channel_reorder_by_range(k)
  perm_v = channel_reorder_by_range(v)
  k_hat = uniform_dequantize(uniform_quantize(k, bits, group, perm_k), group)
  v_hat = uniform_dequantize(uniform_quantize(v, bits, group, perm_v), group)
  return pq_attention.exact_decode_attention(q, k_hat, v_hat, mask, scale)


# ---------------------------------------------------------------------------
# SnapKV-like: importance top-k eviction
# ---------------------------------------------------------------------------

def snapkv_select(weights: Array, keep: int, sink: int, recent: int,
                  length: int) -> Array:
  """Token keep-mask: sinks + recents always kept; top-(keep) body by weight."""
  n = weights.shape[0]
  pos = jnp.arange(n)
  always = (pos < sink) | ((pos >= length - recent) & (pos < length))
  body_w = jnp.where(always | (pos >= length), -jnp.inf, weights)
  thresh_idx = jnp.argsort(-body_w)[:keep]
  kept = jnp.zeros((n,), bool).at[thresh_idx].set(True)
  return (kept & (pos < length)) | (always & (pos < length))


def snapkv_decode_attention(
    q: Array, k: Array, v: Array, weights: Array, length: int, scale: float,
    keep: int, sink: int = 8, recent: int = 32,
) -> Array:
  mask = snapkv_select(weights, keep, sink, recent, length)
  return pq_attention.exact_decode_attention(q, k, v, mask, scale)


def streaming_llm_decode_attention(
    q: Array, k: Array, v: Array, length: int, scale: float,
    sink: int = 8, window: int = 512,
) -> Array:
  n = k.shape[0]
  pos = jnp.arange(n)
  mask = ((pos < sink) | (pos >= length - window)) & (pos < length)
  return pq_attention.exact_decode_attention(q, k, v, mask, scale)


# ---------------------------------------------------------------------------
# PQCache-like: PQ as ANN index, exact KV fetched for selected tokens
# ---------------------------------------------------------------------------

def pqcache_decode_attention(
    q: Array, k: Array, v: Array, mask: Array, scale: float,
    cfg: pq.PQConfig, keep: int,
) -> Tuple[Array, dict]:
  """Approximate MIPS via PQ scores -> exact attention over top-k fetched KV.

  Returns (out, traffic) where traffic counts the exact-KV bytes that would cross
  PCIe in the real system (the cost AQPIM eliminates — Fig. 13 `gpu+cpu`).
  """
  g, d = q.shape
  n = k.shape[0]
  w = jnp.ones((n,), jnp.float32)
  codebook, idx = pq.build_codebook(k, w, cfg, mask=mask)
  table = pq_attention.inner_product_table(q, codebook)
  approx = pq_attention.lookup_scores(table, idx)             # (g, N)
  approx = jnp.where(mask[None], approx, NEG_INF)
  score = jnp.max(approx, axis=0)                             # group max (GQA union)
  top = jnp.argsort(-score)[:keep]
  sel = jnp.zeros((n,), bool).at[top].set(True) & mask
  out = pq_attention.exact_decode_attention(q, k, v, sel, scale)
  traffic = dict(
      fetched_bytes=int(keep) * d * 2 * 2,    # k+v bf16 over PCIe per step
      index_bytes=n * cfg.m * cfg.index_bytes(),
  )
  return out, traffic
