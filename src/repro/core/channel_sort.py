"""Channel pre-sorting for PQ vector splitting (AQPIM §III-D).

Standard PQ splits the head dimension into contiguous subvectors, ignoring
inter-channel correlation.  The paper groups channels by cosine similarity so each
subvector is internally coherent, lowering quantization error at the same codebook
size.  The resulting permutation is a *static* orthonormal matrix absorbed offline
into the projection weights:

    W_q' = W_q P_k,  W_k' = W_k P_k,  W_v' = W_v P_v,  W_o' = W_o P_v^T

(absorbing P_k into both q and k preserves q.k exactly; absorbing P_v / P_v^T into
v and o preserves the attention output exactly).  Calibration data (e.g. a Wikitext
slice — here a synthetic calibration batch) determines the grouping offline, so
inference carries zero runtime overhead.
"""
from __future__ import annotations

import numpy as np

from repro.common import Array


def cosine_similarity_matrix(calib: np.ndarray) -> np.ndarray:
  """(N, d) calibration activations -> (d, d) channel cosine similarity."""
  x = np.asarray(calib, dtype=np.float64)
  cols = x / (np.linalg.norm(x, axis=0, keepdims=True) + 1e-12)  # normalize channels
  return cols.T @ cols


def greedy_channel_groups(calib: np.ndarray, m: int) -> np.ndarray:
  """Greedy cosine-similarity grouping (paper §III-D).

  Repeat m times: pick the first unassigned channel as reference, greedily take the
  top-(dsub-1) most similar unassigned channels to form a group.

  Returns a permutation `perm` of length d such that channels
  perm[g*dsub:(g+1)*dsub] form group g.
  """
  d = calib.shape[-1]
  assert d % m == 0, f"d={d} must be divisible by m={m}"
  dsub = d // m
  sim = cosine_similarity_matrix(calib)
  unassigned = np.ones(d, dtype=bool)
  perm = []
  for _ in range(m):
    ref = int(np.argmax(unassigned))            # first unassigned channel
    unassigned[ref] = False
    group = [ref]
    if dsub > 1:
      s = sim[ref].copy()
      s[~unassigned] = -np.inf
      top = np.argsort(-s)[: dsub - 1]
      for t in top:
        unassigned[int(t)] = False
      group.extend(int(t) for t in top)
    perm.extend(group)
  perm = np.asarray(perm, dtype=np.int64)
  assert len(np.unique(perm)) == d
  return perm


def permutation_matrix(perm: np.ndarray) -> np.ndarray:
  """P with columns reordered so that (x @ P)[j] = x[perm[j]]."""
  d = perm.shape[0]
  p = np.zeros((d, d), dtype=np.float32)
  p[perm, np.arange(d)] = 1.0
  return p


def absorb_into_projections(
    w_q: np.ndarray,
    w_k: np.ndarray,
    w_v: np.ndarray,
    w_o: np.ndarray,
    perm_k: np.ndarray,
    perm_v: np.ndarray,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
  """Fold sorting matrices into projections; per-head last-dim layout assumed.

  w_q/w_k/w_v: (d_model, n_heads, head_dim); w_o: (n_heads, head_dim, d_model).
  perm_* are head_dim-permutations shared across heads (PQ codebooks are per head,
  but the channel grouping operates within head_dim).
  """
  wq = w_q[..., perm_k]
  wk = w_k[..., perm_k]
  wv = w_v[..., perm_v]
  inv_v = np.argsort(perm_v)
  wo = w_o[:, perm_v, :] if w_o.ndim == 3 else w_o
  del inv_v
  return wq, wk, wv, wo


def identity_perm(d: int) -> np.ndarray:
  return np.arange(d, dtype=np.int64)
