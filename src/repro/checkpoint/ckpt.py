"""Sharded, async, elastic checkpointing (no orbax in this environment).

Format: one directory per step containing
  manifest.json   — tree structure, shapes, dtypes, save metadata
  <leaf-id>.npy   — one array per pytree leaf

Properties:
  - *async*: `save_async` snapshots device arrays to host then writes on a
    background thread; training continues immediately.
  - *elastic*: restore() device_puts every leaf with the *target* sharding —
    resuming on a different mesh (more/fewer data shards) needs no conversion.
  - *atomic*: writes go to `<dir>.tmp`, renamed on completion; partially written
    checkpoints are never visible to `latest_step`.
  - *crash-safe*: every leaf carries a CRC32 in the manifest and the manifest
    is fsynced before the rename publishes it; a bit-rotted or truncated leaf
    fails restore with `CheckpointCorruption` instead of loading silently.
    Manifests written before checksums existed load unverified.
  - *self-describing*: restore can rebuild the tree without a target template
    (tested), though passing one enables dtype/shape validation.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional, Tuple

import jax
import ml_dtypes  # registers bfloat16 etc. with numpy
import numpy as np

from repro.common import PyTree


class CheckpointCorruption(RuntimeError):
  """A checkpoint leaf failed its manifest CRC32 — refuse to load it."""


def _leaf_crc(arr: np.ndarray) -> int:
  """CRC32 of a leaf's on-disk byte image (the bit-viewed array)."""
  return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _verify_leaf(arr: np.ndarray, meta: dict, where: str) -> None:
  """Check a loaded leaf against its manifest CRC32, if one was recorded.
  Called on the *stored* representation (before any dtype re-view), so the
  checksum covers exactly the bytes that sat on disk."""
  want = meta.get("crc32")
  if want is None:                     # pre-checksum manifest: load unverified
    return
  got = _leaf_crc(arr)
  if got != want:
    raise CheckpointCorruption(
        f"checkpoint leaf {meta['name']!r} in {where} failed its checksum: "
        f"stored {want:#010x}, computed {got:#010x} — the snapshot is "
        "corrupt or truncated; refusing to load it")


def _leaf_paths(tree: PyTree):
  flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
  names = []
  for path, _ in flat:
    name = "_".join(
        str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
    names.append(name or "leaf")
  # disambiguate duplicates
  seen = {}
  uniq = []
  for n in names:
    c = seen.get(n, 0)
    seen[n] = c + 1
    uniq.append(f"{n}__{c}" if c else n)
  return flat, treedef, uniq


def save(path: str, step: int, tree: PyTree, extra: Optional[dict] = None
         ) -> str:
  """Synchronous checkpoint write.  Returns the final directory."""
  final = os.path.join(path, f"step_{step:08d}")
  tmp = final + ".tmp"
  if os.path.exists(tmp):
    shutil.rmtree(tmp)
  os.makedirs(tmp, exist_ok=True)

  flat, treedef, names = _leaf_paths(tree)
  manifest = {"step": step, "leaves": [], "extra": extra or {}}
  for (path_k, leaf), name in zip(flat, names):
    arr = np.asarray(jax.device_get(leaf))
    dtype_str = str(arr.dtype)
    if arr.dtype.kind not in "biufc":    # ml_dtypes (bf16): store as raw bits
      arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
    np.save(os.path.join(tmp, name + ".npy"), arr)
    manifest["leaves"].append(
        {"name": name, "shape": list(arr.shape), "dtype": dtype_str,
         "crc32": _leaf_crc(arr)})
  try:   # informational only; user-defined nodes (NamedTuples) not proto-able
    manifest["treedef"] = jax.tree_util.tree_structure(
        tree).serialize_using_proto().hex()
  except Exception:  # noqa: BLE001
    manifest["treedef"] = ""
  with open(os.path.join(tmp, "manifest.json"), "w") as f:
    json.dump(manifest, f)
    f.flush()
    os.fsync(f.fileno())               # manifest durable before the rename
  if os.path.exists(final):
    shutil.rmtree(final)
  os.rename(tmp, final)
  return final


class AsyncCheckpointer:
  """Snapshot-then-write-in-background checkpointing."""

  def __init__(self):
    self._thread: Optional[threading.Thread] = None
    self.last_path: Optional[str] = None

  def save_async(self, path: str, step: int, tree: PyTree,
                 extra: Optional[dict] = None) -> None:
    self.wait()
    # snapshot to host memory synchronously (cheap vs. disk IO)
    host_tree = jax.tree_util.tree_map(
        lambda x: np.asarray(jax.device_get(x)), tree)

    def _write():
      self.last_path = save(path, step, host_tree, extra)

    self._thread = threading.Thread(target=_write, daemon=True)
    self._thread.start()

  def wait(self) -> None:
    if self._thread is not None:
      self._thread.join()
      self._thread = None


def latest_step(path: str) -> Optional[int]:
  if not os.path.isdir(path):
    return None
  steps = []
  for d in os.listdir(path):
    if d.startswith("step_") and not d.endswith(".tmp"):
      try:
        steps.append(int(d.split("_")[1]))
      except ValueError:
        pass
  return max(steps) if steps else None


def load_raw(path: str, step: int) -> Tuple[dict, dict]:
  """Restore a checkpoint without a target template: ``({name: array},
  extra)``, arrays staying host-side numpy.

  For consumers that own their tree layout and rebuild from leaf names
  (the serve engine's prefix-cache snapshot).  Dtypes round-trip via the
  manifest — bit-stored ml_dtypes leaves (bf16) are re-viewed."""
  d = os.path.join(path, f"step_{step:08d}")
  with open(os.path.join(d, "manifest.json")) as f:
    manifest = json.load(f)
  out = {}
  for meta in manifest["leaves"]:
    arr = np.load(os.path.join(d, meta["name"] + ".npy"))
    _verify_leaf(arr, meta, d)
    saved_dtype = np.dtype(meta["dtype"])
    if arr.dtype != saved_dtype:         # bit-stored ml_dtypes leaf
      arr = arr.view(saved_dtype)
    out[meta["name"]] = arr
  return out, manifest.get("extra", {})


def restore(path: str, step: int, target: PyTree,
            shardings: Optional[PyTree] = None) -> Tuple[PyTree, dict]:
  """Restore into the target tree structure, resharding to `shardings`.

  Elastic restart: shardings may correspond to a *different* mesh than the one
  that saved — device_put redistributes transparently.
  """
  d = os.path.join(path, f"step_{step:08d}")
  with open(os.path.join(d, "manifest.json")) as f:
    manifest = json.load(f)

  flat, treedef, names = _leaf_paths(target)
  by_name = {m["name"]: m for m in manifest["leaves"]}
  leaves = []
  shard_flat = (jax.tree_util.tree_leaves(
      shardings, is_leaf=lambda x: hasattr(x, "spec"))
      if shardings is not None else [None] * len(flat))
  for ((_, tgt), name, shd) in zip(flat, names, shard_flat):
    meta = by_name[name]
    arr = np.load(os.path.join(d, name + ".npy"))
    _verify_leaf(arr, meta, d)
    saved_dtype = np.dtype(meta["dtype"])
    if arr.dtype != saved_dtype:         # bit-stored ml_dtypes leaf
      arr = arr.view(saved_dtype)
    assert list(arr.shape) == list(tgt.shape), (
        f"{name}: ckpt shape {arr.shape} != target {tgt.shape}")
    if hasattr(tgt, "dtype") and arr.dtype != np.dtype(tgt.dtype):
      # ml_dtypes (bf16) casts are not always registered numpy-side; go via jax
      import jax.numpy as _jnp
      arr = np.asarray(_jnp.asarray(arr).astype(tgt.dtype))
    if shd is not None:
      leaves.append(jax.device_put(arr, shd))
    else:
      leaves.append(jax.device_put(arr))
  tree = jax.tree_util.tree_unflatten(treedef, leaves)
  return tree, manifest.get("extra", {})
