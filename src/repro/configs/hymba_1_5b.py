"""hymba-1.5b [hybrid] — parallel attention + mamba heads, ssm_state=16.
PQ applies to the attention heads' KV; SSM heads carry recurrent state.
[arXiv:2411.13676; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    hybrid=True, ssm_state=16, ssm_d_inner=1600,
    microbatches=4,
    source="arXiv:2411.13676", verified="hf",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, ssm_d_inner=64, pq_m=4, pq_k=16,
    pq_sink=4, pq_recent=8, attn_block=64, dtype_str="float32")
