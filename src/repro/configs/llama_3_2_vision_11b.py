"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5th layer.
Vision tower is a STUB: input_specs provides precomputed patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    cross_attn_period=5, n_modal_tokens=1600, frontend="vision_patches",
    microbatches=4,
    source="hf:meta-llama/Llama-3.2-11B-Vision", verified="unverified",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, cross_attn_period=2, n_modal_tokens=16,
    pq_m=4, pq_k=16, pq_sink=4, pq_recent=8, attn_block=64,
    dtype_str="float32")
