"""llama3-405b [dense] — GQA, 128k vocab; the capacity-wall flagship.
[arXiv:2407.21783; unverified]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab_size=128256,
    fsdp=True,   # 810 GB bf16 params: must shard over BOTH mesh axes
    microbatches=8,  # bound live activations: 1M-token global batch in chunks
    source="arXiv:2407.21783", verified="unverified",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, pq_m=4, pq_k=16, pq_sink=4, pq_recent=8,
    attn_block=64, dtype_str="float32")
