"""musicgen-medium [audio] — decoder-only over EnCodec tokens; MHA kv=24.
Frontend (EnCodec) is a STUB: input_specs provides precomputed frame embeddings.
[arXiv:2306.05284; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048,
    frontend="audio_frames",
    microbatches=4,
    source="arXiv:2306.05284", verified="hf",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=64, pq_m=8, pq_k=16, pq_sink=4, pq_recent=8,
    attn_block=64, dtype_str="float32")
