"""Architecture configs (one module per assigned arch) + registry."""
from repro.configs.registry import ARCHS, get_arch

__all__ = ["ARCHS", "get_arch"]
