"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2, GQA kv=8.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab_size=32064,
    n_experts=16, top_k=2, moe_d_ff=6400, n_shared_experts=0,
    microbatches=4, fsdp=True,
    source="hf:microsoft/Phi-3.5-MoE-instruct", verified="hf",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, moe_d_ff=96, n_experts=4, top_k=2,
    vocab_size=256, pq_m=4, pq_k=16, pq_sink=4, pq_recent=8,
    attn_block=64, dtype_str="float32")
