"""mistral-7b [dense] — the paper's own evaluation model (§IV-A).
[arXiv:2310.06825; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=32000, rope_theta=1000000.0,
    source="arXiv:2310.06825", verified="hf",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, pq_m=4, pq_k=16, pq_sink=4, pq_recent=8,
    attn_block=64, dtype_str="float32")
