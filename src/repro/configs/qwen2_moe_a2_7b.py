"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4 experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    n_experts=60, top_k=4, moe_d_ff=1408, n_shared_experts=4,
    microbatches=4,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B", verified="hf",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    d_ff=96, moe_d_ff=96, n_experts=8, top_k=2, n_shared_experts=1,
    vocab_size=256, pq_m=8, pq_k=16, pq_sink=4, pq_recent=8,
    attn_block=64, dtype_str="float32")
