"""granite-3-8b [dense] — GQA. [hf:ibm-granite/granite-3.0-2b-base; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12800, vocab_size=49155,
    microbatches=4,
    source="hf:ibm-granite/granite-3.0-2b-base", verified="hf",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, pq_m=4, pq_k=16, pq_sink=4, pq_recent=8,
    attn_block=64, dtype_str="float32")
