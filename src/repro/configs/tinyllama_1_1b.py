"""tinyllama-1.1b [dense] — llama2-arch small. [arXiv:2401.02385; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=64,
    d_ff=5632, vocab_size=32000, rope_theta=10000.0,
    microbatches=4,
    source="arXiv:2401.02385", verified="hf",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, pq_m=4, pq_k=16, pq_sink=4, pq_recent=8,
    attn_block=64, dtype_str="float32")
