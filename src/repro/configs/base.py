"""Config schema: architecture, shapes, PQ/runtime settings.

Every assigned architecture is a ModelConfig instance in its own module
(src/repro/configs/<id>.py) with the exact published hyperparameters, plus a
`reduced()` smoke-scale variant of the same family for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core import kv_cache as kvc
from repro.core import pq as pqlib


@dataclasses.dataclass(frozen=True)
class ModelConfig:
  name: str
  family: str                  # dense | moe | ssm | hybrid | audio | vlm
  n_layers: int
  d_model: int
  n_heads: int
  n_kv_heads: int
  d_ff: int
  vocab_size: int
  head_dim: int = 0            # 0 -> d_model // n_heads

  # MoE
  n_experts: int = 0
  top_k: int = 0
  moe_d_ff: int = 0
  n_shared_experts: int = 0
  capacity_factor: float = 1.25

  # SSM / hybrid
  attn_free: bool = False      # rwkv6: no attention, no KV cache
  hybrid: bool = False         # hymba: parallel attn + SSM heads
  ssm_state: int = 0
  ssm_d_inner: int = 0

  # multimodal
  cross_attn_period: int = 0   # every k-th layer is cross-attn (vlm)
  n_modal_tokens: int = 0      # precomputed patch/frame embeddings (stub frontend)
  frontend: str = "none"       # none | audio_frames | vision_patches

  rope_theta: float = 500000.0
  norm_eps: float = 1e-5
  dtype_str: str = "bfloat16"

  # runtime knobs (overridden per run via dataclasses.replace)
  attn_block: int = 512
  decode_cache_len: int = 4096     # exact-cache capacity for decode
  cache_policy: str = "pq"         # registry key: exact | pq | skvq | snapkv |
                                   # streamingllm | pqcache (core/cache_registry)
  cache_layout: str = "contiguous"  # physical KV storage: contiguous | paged
                                    # | tiered (core/cache_layout)
  scheduler: str = "fifo"          # serve-engine admission: fifo | sjf | paged
                                   # | tiered (launch/scheduler)
  kv_block_size: int = 16          # paged-layout token-block granularity
  decode_kernel: str = "auto"      # decode attention implementation: xla
                                   # (pure-JAX reference) | pallas (Mosaic,
                                   # TPU only) | pallas-interpret (kernels
                                   # through the interpreter, runs anywhere)
                                   # | auto (pallas on TPU, xla elsewhere);
                                   # core/decode_dispatch registry
  host_blocks: Optional[int] = None  # tiered-layout host (tier 1) pool size
                                     # in blocks; None -> layout default (4x
                                     # device), 0 -> no host tier (exhaustion
                                     # falls back to recompute preemption)
  spill_codec: str = "raw"         # tiered-layout exact-KV spill codec: any
                                   # core.tiers.SPILL_CODECS key (raw | int8
                                   # | q4 | q8; PQ codes always spill
                                   # verbatim — they ARE the compressed form)
  kv_resident_codec: str = "none"  # exact-policy resident KV store: none
                                   # (dense floats) | q4 | q8 (sub-byte
                                   # packed pages decoded in-kernel —
                                   # kernels/packing.py block format)
  prefix_cache: bool = False       # share prompt-prefix KV blocks across
                                   # requests (copy-on-write tables +
                                   # suffix-only prefill; paged/tiered
                                   # layouts only, token-exact under greedy)
  prefix_cache_blocks: Optional[int] = None  # device blocks the prefix index
                                             # may pin (refcount+LRU budget);
                                             # None -> half the device pool
  stream_window: int = 512         # streamingllm sliding window (clamped to
                                   # context; paged layout ring-reuses blocks
                                   # that age out of it)
  pq_enabled: bool = True          # legacy toggle: False downgrades "pq"->"exact"
  pq_m: int = 32                   # paper Table II optimum
  pq_k: int = 512                  # paper Table III optimum
  pq_sink: int = 8                 # paper §IV-A
  pq_recent: int = 32              # paper §IV-A (= t of Eq. 1)
  pq_windows: int = 1              # paper §III-B: one page suffices
  remat: bool = True
  unroll_layers: bool = False      # python-loop layers (cost-model validation:
                                   # XLA cost_analysis counts while bodies once)
  # beyond-paper performance features (§Perf hillclimbs)
  weight_quant: str = "none"       # "int8": serve weights stored int8+scale
  parallel_block: bool = False     # PaLM-style fused attn+FFN residual: halves
                                   # the TP all-reduce count per layer
  context_parallel: bool = False   # prefill: sequence on the model axis,
                                   # weights replicated, per-layer KV all-gather
                                   # (small-model prefill collective fix)
  moe_a2a_quant: bool = False      # int8 rows across the EP all-to-alls
  microbatches: int = 1            # gradient-accumulation chunks per step
  fsdp: bool = False               # 2D weight sharding (model x data): params/
                                   # optimizer fully sharded, weight all-gather
                                   # on use (required: 405B does not fit 16 GB
                                   # HBM with TP-only sharding)

  # provenance
  source: str = ""
  verified: str = ""

  def __post_init__(self):
    if self.head_dim == 0:
      object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

  @property
  def dtype(self):
    return jnp.dtype(self.dtype_str)

  @property
  def supports_pq(self) -> bool:
    return not self.attn_free

  def resolved_cache_policy(self) -> str:
    """Effective registry key: legacy `pq_enabled=False` means exact; families
    without attention never build a KV policy at all."""
    if not self.supports_pq:
      return "exact"
    if self.cache_policy == "pq" and not self.pq_enabled:
      return "exact"
    return self.cache_policy

  def make_cache_policy(self, context_len: int):
    """Build the configured CachePolicy for a given max context (None when the
    family has no attention KV cache, e.g. rwkv6)."""
    from repro.core import cache_api, cache_registry
    if self.attn_free:
      return None
    name = self.resolved_cache_policy()
    spec = cache_api.CacheSpec(
        capacity=context_len, head_dim=self.head_dim, dtype=self.dtype,
        sink=self.pq_sink, recent=self.pq_recent,
        # the streaming window is clamped to small contexts (window ==
        # capacity keeps everything, same effective behavior)
        window=min(self.stream_window, context_len),
        block=(self.kv_block_size
               if self.cache_layout in ("paged", "tiered") else 0),
        spill_codec=self.spill_codec,
        kv_resident_codec=self.kv_resident_codec,
        decode_kernel=self.decode_kernel,
        pq=self.pq_cache_config(context_len) if name == "pq" else None)
    return cache_registry.make(name, spec)

  def pq_cache_config(self, context_len: int) -> Optional[kvc.PQCacheConfig]:
    """PQ cache geometry for a given max context.

    None whenever the *effective* cache policy is not "pq" — so the cost
    model, roofline, and dry-run byte accounting stay in lockstep with the
    policy the model actually runs (not just the legacy pq_enabled flag).
    """
    if self.resolved_cache_policy() != "pq":
      return None
    body = max(context_len - self.pq_sink - self.pq_recent, self.pq_windows)
    # round body capacity to a multiple of windows AND the kernel block (512)
    blk = 512 if context_len >= 4096 else 64
    mult = self.pq_windows * blk
    body = -(-body // mult) * mult
    m = self.pq_m
    while self.head_dim % m != 0:
      m //= 2
    return kvc.PQCacheConfig(
        sink=self.pq_sink, recent=self.pq_recent, body_capacity=body,
        n_windows=self.pq_windows,
        pq=pqlib.PQConfig(m=m, k=self.pq_k))

  def active_params(self) -> int:
    """Approx active parameter count (MoE counts top_k + shared experts)."""
    d, v, l = self.d_model, self.vocab_size, self.n_layers
    attn = d * self.head_dim * (self.n_heads * 2 + self.n_kv_heads * 2)
    if self.n_experts > 0:
      ffn = 3 * d * self.moe_d_ff * (self.top_k + self.n_shared_experts)
      ffn += d * self.n_experts  # router
    elif self.attn_free:
      attn = 5 * d * d + d * d   # r/k/v/g/o + loras approx
      ffn = 2 * d * self.d_ff + d * d
    else:
      ffn = 3 * d * self.d_ff
    if self.hybrid:
      attn += 2 * d * self.ssm_d_inner + self.ssm_d_inner * d
    core = l * (attn + ffn)
    if self.cross_attn_period:
      n_cross = l // self.cross_attn_period
      core += n_cross * (attn + 3 * d * self.d_ff)
    return core + 2 * v * d

  def total_params(self) -> int:
    if self.n_experts > 0:
      d, l = self.d_model, self.n_layers
      attn = d * self.head_dim * (self.n_heads * 2 + self.n_kv_heads * 2)
      ffn = 3 * d * self.moe_d_ff * (self.n_experts + self.n_shared_experts)
      return l * (attn + ffn + d * self.n_experts) + 2 * self.vocab_size * d
    return self.active_params()


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
  """One assigned input-shape cell."""
  name: str
  seq_len: int
  global_batch: int
  kind: str        # train | prefill | decode

  @property
  def is_decode(self) -> bool:
    return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def smoke_shape(kind: str = "train") -> ShapeConfig:
  if kind == "train":
    return ShapeConfig("smoke_train", 128, 2, "train")
  if kind == "prefill":
    return ShapeConfig("smoke_prefill", 128, 2, "prefill")
  return ShapeConfig("smoke_decode", 128, 2, "decode")
