"""rwkv6-3b [ssm] — Finch, data-dependent decay; attention-free.
[arXiv:2404.05892; hf]  AQPIM inapplicable (no KV cache) — DESIGN.md §5."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=8960, vocab_size=65536,
    attn_free=True, pq_enabled=False,
    microbatches=4,
    source="arXiv:2404.05892", verified="hf",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    d_ff=128, vocab_size=256, attn_block=64, dtype_str="float32")
