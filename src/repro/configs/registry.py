"""Registry: --arch <id> -> ModelConfig (full + reduced smoke variant)."""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import ModelConfig

_MODULES: Dict[str, str] = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "rwkv6-3b": "rwkv6_3b",
    "yi-34b": "yi_34b",
    "llama3-405b": "llama3_405b",
    "granite-3-8b": "granite_3_8b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "musicgen-medium": "musicgen_medium",
    "hymba-1.5b": "hymba_1_5b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "mistral-7b": "mistral_7b",
}

ARCHS = tuple(k for k in _MODULES if k != "mistral-7b")


def get_arch(name: str, reduced: bool = False) -> ModelConfig:
  if name not in _MODULES:
    raise KeyError(f"unknown arch {name!r}; choose from {sorted(_MODULES)}")
  mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
  return mod.REDUCED if reduced else mod.CONFIG
