"""yi-34b [dense] — llama-arch GQA. [arXiv:2403.04652; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    microbatches=4, fsdp=True,
    source="arXiv:2403.04652", verified="hf",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, pq_m=4, pq_k=16, pq_sink=4, pq_recent=8,
    attn_block=64, dtype_str="float32")
