"""Distribution: sharding rules, pipeline parallelism, collectives."""
from repro.parallel import sharding

__all__ = ["sharding"]
