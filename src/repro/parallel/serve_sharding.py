"""Sharded multi-device serving: mesh-partitioned block pools + cross-shard decode.

AQPIM's serve path (PR 1-6) is single-device; the PIM systems it competes
with (PIMphony / LoL-PIM) get their headline numbers by spreading attention
across channels/ranks.  The software analogue here partitions the paged KV
block pool over a named JAX mesh and runs the decode step under `shard_map`,
with host-side orchestration (block tables, admission, spill/fetch, the
prefix index) untouched — one global `BlockTableManager` keeps issuing the
same tables; only where the pool *bytes* live and who computes which heads
changes.

Two partition modes, picked by the same fallback-chain doctrine as
`parallel.sharding._choose` (first candidate whose dims divide wins):

``heads``   kv heads over the `model` axis when `n_kv_heads % size == 0`.
            Every pool leaf `(P+1, L, H, block, ...)` and resident leaf
            `(L, B, H, ...)` carries kv heads at axis 2, so one rule shards
            the whole policy-state tree.  Inside the decode step each shard
            computes q/k/v from replicated activations, slices its own
            kv-head range (GQA query groups follow their kv head), runs the
            policy's unmodified attend on its local heads, and an ordered
            `all_gather(..., tiled=True)` reassembles the per-head attention
            context before the replicated `wo` projection.  Per-kv-head
            attention is fully independent in every policy, concatenation is
            exact, and the post-attention network is replicated — greedy
            tokens are **bit-identical** to single-device, for every cache
            policy.

``seq``     flash-decoding split-K over the sequence axis when heads don't
            divide.  Each shard owns a contiguous chunk of token positions,
            computes partial-softmax `(out, max, denom)` stats over (owned
            positions) ∩ (valid positions), and the stats are all-gathered
            and merged in fixed shard order through the same exact
            `kernels.ops.combine_attention_segments` PR 5 uses for the PQ
            sink/recent segments.  Storage stays replicated (the terminal
            fallback of the `_choose` chain); the combine is mathematically
            exact but reassociates floating point, so this mode carries the
            same empirical token-identity bar PR 5 applied across kernels
            rather than a bit-identity guarantee.  Exact policy only —
            compressed policies couple eviction to position and need the
            heads mode (plan_for raises with the fallback chain named).

Mode ``none`` (mesh model axis of 1) is the plain unsharded path: no
shard_map, no collectives, byte-for-byte the PR 6 programs.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import MODEL_AXIS


@dataclasses.dataclass(frozen=True)
class ShardPlan:
  """Resolved decode-sharding decision for one serve run.

  Frozen at engine/layout construction (like `DecodeDispatch`): the serve
  loop compiles exactly one decode program per run, with no per-step
  branching on mesh state.
  """
  mesh: Mesh
  axis: str = MODEL_AXIS
  mode: str = "none"            # "none" | "heads" | "seq"
  size: int = 1                 # shards along `axis`
  n_kv_heads: int = 0
  n_heads: int = 0
  policy: str = "exact"         # resolved cache policy; gates the seq link

  @property
  def active(self) -> bool:
    return self.mode != "none" and self.size > 1

  @property
  def bit_identical(self) -> bool:
    """Does this plan guarantee bit-identical greedy tokens vs mesh=1?"""
    return self.mode in ("none", "heads")

  def describe(self) -> dict:
    return dict(axis=self.axis, mode=self.mode, shards=self.size,
                devices=[str(d) for d in self.mesh.devices.reshape(-1)],
                bit_identical=self.bit_identical)

  def replan(self, survivors) -> "ShardPlan":
    """Degraded-mesh plan over the surviving shards after confirmed deaths.

    Same fallback-chain doctrine as `plan_for`, re-run against what is
    left: **heads** over the largest divisor-compatible survivor subset
    (kv heads re-partition; extra survivors idle rather than breaking
    divisibility), else **seq** split-K over every survivor when the
    policy supports it, else **single-device** on the first survivor.
    Survivor indices are shard positions along `self.axis` in the current
    plan; the returned plan's mesh is a submesh of the current one
    (`parallel.sharding.survivor_submesh`), so re-placing storage with
    `place_storage` moves the pool onto the survivors.
    """
    from repro.parallel.sharding import survivor_submesh
    surv = sorted(set(int(s) for s in survivors))
    if not surv:
      raise ValueError("cannot replan with no surviving shards")
    if any(s < 0 or s >= max(self.size, 1) for s in surv):
      raise ValueError(f"survivors {surv} out of range for a "
                       f"{self.size}-shard plan")
    n = len(surv)
    k = max((d for d in range(2, n + 1)
             if self.n_kv_heads > 0 and self.n_kv_heads % d == 0),
            default=1)
    if k > 1:
      mesh = survivor_submesh(self.mesh, self.axis, surv[:k])
      return dataclasses.replace(self, mesh=mesh, mode="heads", size=k)
    if n > 1 and self.policy in _SEQ_CAPABLE_POLICIES:
      mesh = survivor_submesh(self.mesh, self.axis, surv)
      return dataclasses.replace(self, mesh=mesh, mode="seq", size=n)
    mesh = survivor_submesh(self.mesh, self.axis, surv[:1])
    return dataclasses.replace(self, mesh=mesh, mode="none", size=1)


# Policies whose decode attend the seq split-K path can drive: the split
# masks positions inside a plain exact-store softmax.  Compressed/windowed
# policies couple eviction and encoding to absolute position and are heads-
# mode only.
_SEQ_CAPABLE_POLICIES = ("exact",)


def plan_for(cfg, mesh: Mesh, *, axis: str = MODEL_AXIS) -> ShardPlan:
  """Pick the partition mode for this (config, mesh) — fallback-chain style.

  Mirrors `parallel.sharding._choose`: candidates in preference order, first
  one whose divisibility holds wins; an impossible chain raises with every
  link named instead of silently replicating a pool the caller asked to
  shard.
  """
  size = int(dict(mesh.shape).get(axis, 1))
  policy = cfg.resolved_cache_policy()
  if size <= 1:
    return ShardPlan(mesh=mesh, axis=axis, mode="none", size=1,
                     n_kv_heads=cfg.n_kv_heads, n_heads=cfg.n_heads,
                     policy=policy)
  if cfg.n_kv_heads % size == 0:
    mode = "heads"
  elif policy in _SEQ_CAPABLE_POLICIES:
    mode = "seq"
  else:
    raise ValueError(
        f"cannot shard decode for policy {policy!r} over {axis}={size}: "
        f"kv heads ({cfg.n_kv_heads}) are not divisible by the axis, and "
        f"the sequence split-K fallback supports only policies "
        f"{_SEQ_CAPABLE_POLICIES} (compressed policies couple eviction to "
        f"position); pick a mesh model axis dividing {cfg.n_kv_heads}")
  return ShardPlan(mesh=mesh, axis=axis, mode=mode, size=size,
                   n_kv_heads=cfg.n_kv_heads, n_heads=cfg.n_heads,
                   policy=policy)


# ---------------------------------------------------------------------------
# Trace-time shard context
#
# The decode programs live behind `Model.decode_step` / `decode_step_paged`
# and a `jax.lax.scan` over layers; threading a plan argument through every
# signature would churn the whole model API for a serve-only concern.
# Instead the layout activates the plan around *tracing* its shard_map body,
# and the attention seam (`models.transformer._attn_step*`) consults it.
# Purely trace-time state: the compiled program bakes the decision in.
# ---------------------------------------------------------------------------

_ACTIVE: Optional[ShardPlan] = None


@contextlib.contextmanager
def activate(plan: Optional[ShardPlan]):
  global _ACTIVE
  prev = _ACTIVE
  _ACTIVE = plan if (plan is not None and plan.active) else None
  try:
    yield
  finally:
    _ACTIVE = prev


def active_plan() -> Optional[ShardPlan]:
  return _ACTIVE


# ---------------------------------------------------------------------------
# Storage placement
# ---------------------------------------------------------------------------

def storage_pspec(plan: ShardPlan, leaf) -> P:
  """Partition rule for one decode-storage leaf.

  Both storage families carry kv heads at axis 2 — pool leaves
  `(P+1, L, H, block, ...)` and resident leaves `(L, B, H, ...)` — so heads
  mode is one spec; seq mode replicates storage (the split is over compute).
  """
  nd = leaf.ndim
  if (not plan.active or plan.mode != "heads" or nd < 3
      or leaf.shape[2] != plan.n_kv_heads):
    return P(*([None] * nd))
  return P(None, None, plan.axis, *([None] * (nd - 3)))


def storage_pspecs(plan: ShardPlan, storage: Any) -> Any:
  return jax.tree_util.tree_map(lambda lf: storage_pspec(plan, lf), storage)


def place_storage(storage: Any, plan: ShardPlan) -> Any:
  """Commit a freshly built storage tree to its mesh placement."""
  return jax.tree_util.tree_map(
      lambda lf: jax.device_put(
          lf, NamedSharding(plan.mesh, storage_pspec(plan, lf))), storage)


def replicate(tree: Any, plan: ShardPlan) -> Any:
  """Commit a tree (params) replicated over every mesh device."""
  return jax.tree_util.tree_map(
      lambda lf: jax.device_put(
          lf, NamedSharding(plan.mesh, P(*([None] * jnp.ndim(lf))))), tree)


def wrap_decode(decode_fn, plan: ShardPlan, storage_example: Any):
  """shard_map a `(params, cur, storage, tables, lengths) -> (logits,
  storage)` decode program under the plan.

  Everything except storage is replicated in and out; storage follows
  `storage_pspec` (head-partitioned pools in heads mode, replicated in seq
  mode).  The body runs the *unmodified* program — the attention seam reads
  the activated plan and does the per-shard slice / ordered all_gather (or
  split-K stats merge), so logits leave the body replicated.  check_rep is
  off: the replication of post-all_gather values is by construction, not
  provable by the rep checker.
  """
  st_specs = storage_pspecs(plan, storage_example)

  def body(params, cur, storage, tables, lengths):
    with activate(plan):
      return decode_fn(params, cur, storage, tables, lengths)

  return shard_map(
      body, plan.mesh,
      in_specs=(P(), P(), st_specs, P(), P()),
      out_specs=(P(), st_specs),
      check_rep=False)


# ---------------------------------------------------------------------------
# Heads mode: per-shard head slice + ordered context gather
# ---------------------------------------------------------------------------

def shard_attn_inputs(q, k, v, plan: ShardPlan):
  """Slice replicated q/k/v `(B, H*, d)` to this shard's kv-head range.

  GQA query heads are laid out kv-head-major (`q.reshape(h, g, d)` in every
  policy), so the query slice for kv heads [i*h_loc, (i+1)*h_loc) is the
  contiguous [i*h_loc*g, (i+1)*h_loc*g).
  """
  idx = jax.lax.axis_index(plan.axis)
  h_loc = plan.n_kv_heads // plan.size
  g = plan.n_heads // plan.n_kv_heads
  q = jax.lax.dynamic_slice_in_dim(q, idx * h_loc * g, h_loc * g, axis=1)
  k = jax.lax.dynamic_slice_in_dim(k, idx * h_loc, h_loc, axis=1)
  v = jax.lax.dynamic_slice_in_dim(v, idx * h_loc, h_loc, axis=1)
  return q, k, v


def gather_attn_outputs(attn, plan: ShardPlan):
  """Reassemble the full per-head attention context in shard order.

  tiled=True concatenates along the head axis; shard i contributed heads
  [i*h_loc*g, (i+1)*h_loc*g), so the result is exactly the unsharded
  `(B, Hq, d)` context — bitwise, since each head's values were computed by
  exactly one shard with single-device math.
  """
  return jax.lax.all_gather(attn, plan.axis, axis=1, tiled=True)


# ---------------------------------------------------------------------------
# Seq mode: flash-decoding split-K over token positions
# ---------------------------------------------------------------------------

def seq_append_and_attend(cache, q, k_new, v_new, lengths, scale,
                          plan: ShardPlan):
  """Exact-policy decode step, split-K over the sequence across shards.

  Cache leaves arrive replicated `(B, H, N, D)`; every shard performs the
  identical token insert (so storage stays replicated), then computes
  partial-softmax stats over (its contiguous position chunk) ∩ (pos <
  length+1).  Ownership chunks tile [0, N), so the union covers each valid
  position exactly once; an all-masked shard contributes the neutral
  (0, NEG_INF, 0) stats `segment_attention_stats` defines.  Stats are
  all-gathered and merged in fixed shard order via the exact PR 5 combine.
  """
  from repro.core import kv_cache as kvc
  from repro.core import pq_attention
  from repro.kernels import ops as kops

  b, hq, d = q.shape
  h = cache.k.shape[1]
  g = hq // h
  lengths = kvc.as_lengths(lengths, b)
  k_c, v_c = jax.vmap(kvc.exact_insert_one)(cache.k, cache.v, k_new, v_new,
                                            lengths)
  n_max = k_c.shape[2]
  idx = jax.lax.axis_index(plan.axis)
  chunk = -(-n_max // plan.size)
  pos = jnp.arange(n_max)
  owned = (pos >= idx * chunk) & (pos < (idx + 1) * chunk)

  qg = q.reshape(b, h, g, d)

  def per_req(qq, kk, vv, ln):
    mask = owned & (pos < ln + 1)
    return jax.vmap(
        lambda qh, kh, vh: pq_attention.segment_attention_stats(
            qh, kh, vh, mask, scale))(qq, kk, vv)

  out, mx, dn = jax.vmap(per_req)(qg, k_c, v_c, lengths)
  outs = jax.lax.all_gather(out, plan.axis)       # (S, B, H, g, D)
  mxs = jax.lax.all_gather(mx, plan.axis)
  dns = jax.lax.all_gather(dn, plan.axis)
  combined = kops.combine_attention_segments(
      [outs[i] for i in range(plan.size)],
      [mxs[i] for i in range(plan.size)],
      [dns[i] for i in range(plan.size)])
  return combined.reshape(b, hq, d), cache._replace(k=k_c, v=v_c)


# ---------------------------------------------------------------------------
# Shard health watchdog
# ---------------------------------------------------------------------------


class ShardHealth:
  """Per-shard decode heartbeat watchdog.

  The engine records one heartbeat round per serve step: every shard beats
  unless the fault injector marked it lost (it stops beating permanently)
  or stalled (it skips this round).  A shard that misses `confirm_after`
  consecutive rounds is confirmed dead exactly once — `record()` returns
  the newly confirmed ids and the engine drains in-flight transfers,
  replans over the survivors, and recovers affected requests.  A sustained
  stall therefore escalates to a loss, the standard watchdog semantics; a
  transient straggle just costs the mesh one step of virtual time.
  """

  def __init__(self, shards: int = 1, confirm_after: int = 2):
    self.shards = max(int(shards), 1)
    self.confirm_after = max(int(confirm_after), 1)
    self.beats = [0] * self.shards
    self.missed = [0] * self.shards
    self.lost: set = set()
    self.confirmed: set = set()
    self._stalled: set = set()

  def mark_lost(self, shard: int) -> None:
    """Shard stops heartbeating permanently (shard-loss injection)."""
    self.lost.add(int(shard))

  def mark_stalled(self, shard: int) -> None:
    """Shard misses the next heartbeat round only (shard-stall)."""
    self._stalled.add(int(shard))

  def record(self) -> list:
    """One heartbeat round; returns shard ids newly confirmed dead."""
    fresh = []
    for s in range(self.shards):
      if s in self.lost or s in self._stalled:
        self.missed[s] += 1
        if self.missed[s] >= self.confirm_after and s not in self.confirmed:
          self.confirmed.add(s)
          fresh.append(s)
      else:
        self.beats[s] += 1
        self.missed[s] = 0
    self._stalled.clear()
    return fresh

  def alive(self) -> list:
    return [s for s in range(self.shards) if s not in self.confirmed]

  def as_dict(self) -> dict:
    return dict(shards=self.shards, confirm_after=self.confirm_after,
                beats=list(self.beats), missed=list(self.missed),
                lost=sorted(self.lost), confirmed=sorted(self.confirmed))


# ---------------------------------------------------------------------------
# Accounting
# ---------------------------------------------------------------------------

def per_shard_bytes(plan: ShardPlan, storage: Any) -> dict:
  """Pool/resident bytes each shard actually holds.

  Heads mode divides every H-sharded leaf by the shard count; seq (and
  none) replicate storage, so per-shard equals total.  Derived from the
  same leaves `PagedLayout.bytes()` walks, so the two sections agree.
  """
  sharded = 0
  replicated = 0
  for lf in jax.tree_util.tree_leaves(storage):
    spec = storage_pspec(plan, lf)
    if any(ax is not None for ax in spec):
      sharded += lf.nbytes
    else:
      replicated += lf.nbytes
  size = plan.size if plan.active else 1
  return dict(
      mode=plan.mode, shards=size,
      total_bytes=sharded + replicated,
      sharded_bytes=sharded, replicated_bytes=replicated,
      bytes_per_shard=sharded // size + replicated)
