"""Sharding rules: param/optimizer/cache PartitionSpecs for the production mesh.

Megatron-style tensor parallelism over the `model` axis, data parallelism over
(`pod`, `data`).  pjit requires *argument* dims to divide evenly by their mesh
axes, so every rule is a FALLBACK CHAIN: the preferred axis placement is used when
divisible, otherwise the next candidate (e.g. GQA kv-projections with 8 kv-heads on
a 16-way model axis shard head_dim instead; granite's 49155 vocab shards d_model;
qwen2's 60 experts shard the expert FFN dim instead of the expert axis).

Decode caches get their own chains:
  - kv-heads over `model` when divisible, else the *sequence* axis over `model`
    (flash-decoding split-K: partial softmax stats are psum-combined by GSPMD);
  - `long_500k` (batch=1) shards the PQ body sequence over BOTH (data, model) —
    full sequence parallelism, the only parallelism available at batch 1.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import PyTree
from repro.configs.base import ModelConfig

MODEL_AXIS = "model"
DATA_AXES_SINGLE = ("data",)
DATA_AXES_MULTI = ("pod", "data")


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
  return DATA_AXES_MULTI if "pod" in mesh.axis_names else DATA_AXES_SINGLE


def survivor_submesh(mesh: Mesh, axis: str, survivors: Sequence[int]):
  """Mesh over the surviving shard columns of `axis` (degraded-mesh replan).

  A dead shard cannot be excised from a `jax.sharding.Mesh` in place; the
  serve-path watchdog (`parallel.serve_sharding.ShardHealth`) instead
  rebuilds a smaller mesh from the survivors' device columns — every other
  axis keeps its full extent.  Also accepts the duck-typed mesh stand-ins
  the in-process tests use (anything with `.devices` + `.axis_names`), for
  which it returns a stand-in of the same shape.
  """
  import numpy as np
  names = tuple(mesh.axis_names)
  if axis not in names:
    raise ValueError(f"mesh has no axis {axis!r}; axes: {names}")
  ax = names.index(axis)
  devs = np.asarray(mesh.devices)
  size = devs.shape[ax]
  surv = sorted(set(int(s) for s in survivors))
  if not surv or any(s < 0 or s >= size for s in surv):
    raise ValueError(f"survivors {sorted(set(survivors))} must be a "
                     f"non-empty subset of range({size}) along {axis!r}")
  sub = np.take(devs, surv, axis=ax)
  try:
    return Mesh(sub, names)
  except (TypeError, ValueError, KeyError):
    # mesh stand-ins carry plain ints for devices; mirror their shape
    import types
    return types.SimpleNamespace(devices=sub, axis_names=names,
                                 shape=dict(zip(names, sub.shape)))


def _axis_size(mesh_axes: dict, axis) -> int:
  if axis is None:
    return 1
  if isinstance(axis, (tuple, list)):
    n = 1
    for a in axis:
      n *= mesh_axes[a]
    return n
  return mesh_axes[axis]


def _fits(shape: Sequence[int], spec: Tuple, mesh_axes: dict) -> bool:
  for dim, axis in zip(shape[len(shape) - len(spec):], spec):
    if axis is not None and dim % _axis_size(mesh_axes, axis) != 0:
      return False
  return True


def _choose(shape: Sequence[int], candidates: Sequence[Tuple],
            mesh_axes: dict) -> P:
  """First candidate whose sharded trailing dims divide; else replicate.
  Candidates are trailing-dim specs, left-padded with None."""
  nd = len(shape)
  for cand in candidates:
    if len(cand) <= nd and _fits(shape, cand, mesh_axes):
      return P(*([None] * (nd - len(cand)) + list(cand)))
  return P(*([None] * nd))


def _path_str(path) -> str:
  return "/".join(
      str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def param_pspecs(params: PyTree, cfg: ModelConfig,
                 model_axis_size: int,
                 mesh_axes: Optional[dict] = None) -> PyTree:
  """PartitionSpec tree matching the (stacked-layer) parameter tree.

  cfg.fsdp: additionally shard the non-TP matmul dim over `data` (ZeRO-3/FSDP:
  GSPMD all-gathers weights at use, reduce-scatters grads).  Never the leading
  stacked-layer dim — scan slices must stay device-local.
  """
  axes = dict(mesh_axes or {MODEL_AXIS: model_axis_size})
  axes.setdefault("data", 16)
  M = MODEL_AXIS
  # FSDP shards over every data-parallel axis (pod included on multi-pod)
  D = None
  if cfg.fsdp:
    D = ("pod", "data") if "pod" in axes else "data"

  def rule(path, leaf) -> P:
    s = _path_str(path)
    # int8-stored weights: {"q": ..., "scale": ...} leaves share the parent rule
    if s.endswith("/q") or s.endswith("/scale"):
      s = s.rsplit("/", 1)[0]
    sh = leaf.shape

    if s == "embed":                       # (V, D)
      return _choose(sh, [(M, D), (M, None), (None, M)], axes)
    if s == "lm_head":                     # (D, V)
      return _choose(sh, [(D, M), (None, M), (M, None)], axes)

    # MoE experts (.., E, D, F) / (.., E, F, D): EP when E divides, else TP on F
    if re.search(r"moe/w_(gate|up)$", s):
      return _choose(sh, [(M, D, None), (M, None, None), (None, D, M),
                          (None, None, M), (None, M, None)], axes)
    if re.search(r"moe/w_down$", s):
      return _choose(sh, [(M, None, D), (M, None, None), (None, M, D),
                          (None, M, None), (None, None, M)], axes)
    if s.endswith("moe/router"):
      return P(*([None] * leaf.ndim))

    # dense / shared-expert MLP
    if re.search(r"(mlp|shared)/w_(gate|up)$", s):
      return _choose(sh, [(D, M), (None, M), (M, None)], axes)
    if re.search(r"(mlp|shared)/w_down$", s):
      return _choose(sh, [(M, D), (M, None), (None, M)], axes)

    # attention (.., D, H, hd) / (.., H, hd, D)
    if re.search(r"(attn|cross)/w[qkv]$", s):
      return _choose(sh, [(D, M, None), (None, M, None), (None, None, M),
                          (M, None, None)], axes)
    if re.search(r"(attn|cross)/wo$", s):
      return _choose(sh, [(M, None, D), (M, None, None), (None, M, None),
                          (None, None, M)], axes)

    # RWKV time-mix / channel-mix (.., D, D) and (.., H, hd)
    if re.search(r"tm/w[rkvg]$", s) or s.endswith("cm/wk") or s.endswith("cm/wr"):
      return _choose(sh, [(None, M), (M, None)], axes)
    if s.endswith("tm/wo") or s.endswith("cm/wv"):
      return _choose(sh, [(M, None), (None, M)], axes)
    if s.endswith("tm/u"):
      return _choose(sh, [(M, None)], axes)

    # SSM: d_inner-sharded
    if s.endswith("ssm/w_in") or s.endswith("ssm/w_dt2"):
      return _choose(sh, [(None, M)], axes)
    if s.endswith("ssm/conv_w"):
      return _choose(sh, [(None, M)], axes)
    if re.search(r"ssm/(w_bc|w_dt|a_log|w_out)$", s):
      return _choose(sh, [(M, None)], axes)
    if re.search(r"ssm/(dt_bias|d_skip)$", s):
      return _choose(sh, [(M,)], axes)

    # norms, gates, loras, mus: replicated
    return P(*([None] * leaf.ndim))

  return jax.tree_util.tree_map_with_path(rule, params)


def batch_pspecs(mesh: Mesh, with_modal: bool = False) -> dict:
  da = data_axes(mesh)
  specs = {"tokens": P(da, None), "targets": P(da, None)}
  if with_modal:
    specs["modal"] = P(da, None, None)
  return specs


def cache_pspecs(cache: PyTree, mesh: Mesh, batch: int,
                 shard_sequence: bool = False,
                 paged_axes: Optional[PyTree] = None) -> PyTree:
  """PartitionSpecs for a decode-cache tree (see module docstring).

  `paged_axes` (a tree matching `cache`, the policy's `paged_axes()`)
  marks physical *pool* leaves: an entry >= 0 says this leaf is block-pooled
  storage `(P+1, L, H, block, ...)` — heads at axis 2, the paged token axis
  blocked behind a leading physical-block axis — rather than a dense
  per-request `(L, B, H, N, ...)` cache.  Pool leaves predate none of the
  dense chains' assumptions (their axis 1 is *layers*, not batch), so they
  get their own fallback chain: kv heads (axis 2) over `model` when
  divisible, else flash-decoding split-K over the sequence via the leading
  block axis, else replicate.  Entries < 0 (RESIDENT) and
  `paged_axes=None` fall through to the dense rules unchanged.
  """
  axes = dict(mesh.shape)
  da = data_axes(mesh)
  n_data = _axis_size(axes, da)
  batch_ax = da if (batch > 1 and batch % n_data == 0) else None
  M = MODEL_AXIS
  seq_both = ("data", M) if "pod" not in mesh.axis_names else \
      (("pod", "data", M))

  def pool_rule(leaf) -> P:
    sh, nd = leaf.shape, leaf.ndim
    if nd < 4:
      return P(*([None] * nd))
    return _choose(sh, [
        # kv heads (axis 2 of (P+1, L, H, block, ...)) over model
        (M,) + (None,) * (nd - 3),
        # split-K fallback: partition the physical-block (sequence) axis
        (M,) + (None,) * (nd - 1),
    ], axes)

  def rule(path, leaf, ax_hint=None) -> P:
    if ax_hint is not None and ax_hint >= 0:
      return pool_rule(leaf)
    s = _path_str(path)
    sh = leaf.shape
    nd = leaf.ndim
    # PQ index stores: (L, B, H, Nb, m)
    if "indices" in s and nd >= 5:
      if shard_sequence and batch == 1:
        return _choose(sh, [(None, None, seq_both, None),
                            (None, None, (M,), None)], axes)
      if shard_sequence:
        return _choose(sh, [(None, batch_ax, None, M, None),
                            (None, batch_ax, M, None, None)], axes)
      return _choose(sh, [(None, batch_ax, M, None, None),
                          (None, batch_ax, None, M, None)], axes)
    # codebooks (L, B, H, nW, m, K, dsub): heads on model when divisible,
    # else centroid axis K on model; batch always on data — NEVER fully
    # replicated (at B=128 the per-sequence codebooks are cache-scale data)
    if "codebooks" in s:
      return _choose(sh, [
          (None, batch_ax, M) + (None,) * (nd - 3),
          (None, batch_ax, None, None, None, M, None),
          (None, batch_ax) + (None,) * (nd - 2),
      ], axes)
    # exact kv / sink / recent: (L, B, H, N, D)
    if nd >= 5:
      if shard_sequence and batch == 1:
        return _choose(sh, [(None, None, None, seq_both, None),
                            (None, None, M, None, None),
                            (None, None, None, None, M)], axes)
      if shard_sequence:
        return _choose(sh, [(None, batch_ax, None, M, None),
                            (None, batch_ax, M, None, None),
                            (None, batch_ax, None, None, None)], axes)
      return _choose(sh, [(None, batch_ax, M, None, None),
                          (None, batch_ax, None, M, None),
                          (None, batch_ax, None, None, M)], axes)
    if nd == 4:   # ssm h (L,B,d_inner,n) / rwkv s handled above by ndim>=5
      return _choose(sh, [(None, batch_ax, M, None),
                          (None, batch_ax, None, M),
                          (None, batch_ax, None, None)], axes)
    if nd == 3:   # (L, B, D)-ish recurrent leaves
      return _choose(sh, [(None, batch_ax, M),
                          (None, batch_ax, None)], axes)
    return P(*([None] * nd))

  if paged_axes is not None:
    return jax.tree_util.tree_map_with_path(rule, cache, paged_axes)
  return jax.tree_util.tree_map_with_path(rule, cache)


def opt_pspecs(param_specs: PyTree, zero1: bool = False) -> PyTree:
  """Optimizer-moment specs mirror params (ZeRO-1 handled at build site)."""
  return param_specs


def make_shardings(pspecs: PyTree, mesh: Mesh) -> PyTree:
  return jax.tree_util.tree_map(
      lambda s: NamedSharding(mesh, s), pspecs,
      is_leaf=lambda x: isinstance(x, P))
