"""Deterministic sharded synthetic LM data pipeline.

Properties a real cluster needs and that the fault-tolerance layer relies on:

  - *Deterministic by (step, position)*: batch contents are a pure function of the
    global step, so a restarted job regenerates exactly the skipped batches —
    no data-loader state in checkpoints.
  - *Host-sharded*: each host materializes only its addressable shard
    (jax.make_array_from_callback), so the pipeline scales to multi-pod meshes.
  - *Structured tokens*: a mixture of copy/induction patterns and Zipfian noise so
    small models show a real learning signal in the end-to-end example.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
  vocab_size: int
  seq_len: int
  global_batch: int
  seed: int = 0
  induction_period: int = 64     # repeated-segment period (learnable structure)


def _batch_numpy(cfg: DataConfig, step: int, lo: int, hi: int) -> np.ndarray:
  """Rows [lo, hi) of the global batch for `step` — pure function of indices."""
  rows = []
  for r in range(lo, hi):
    rng = np.random.default_rng(
        np.uint64(cfg.seed * 1_000_003 + step * 65_537 + r))
    zipf = rng.zipf(1.3, size=cfg.seq_len).astype(np.int64)
    base = np.minimum(zipf, cfg.vocab_size - 1)
    # induction structure: second half of each period repeats the first half
    p = cfg.induction_period
    seq = base.copy()
    for start in range(0, cfg.seq_len - p, p):
      half = p // 2
      seq[start + half:start + p] = seq[start:start + half]
    rows.append(seq)
  return np.stack(rows).astype(np.int32)


def make_batch(cfg: DataConfig, step: int, mesh: Optional[Mesh] = None,
               batch_spec: Optional[P] = None) -> Dict[str, jax.Array]:
  """Build the global batch for `step`, sharded over the mesh if given."""
  shape = (cfg.global_batch, cfg.seq_len)
  if mesh is None:
    tokens = jnp.asarray(_batch_numpy(cfg, step, 0, cfg.global_batch))
  else:
    sharding = NamedSharding(mesh, batch_spec or P())
    def cb(index):
      rows = index[0]
      lo = rows.start or 0
      hi = rows.stop if rows.stop is not None else cfg.global_batch
      return _batch_numpy(cfg, step, lo, hi)
    tokens = jax.make_array_from_callback(shape, sharding, cb)
  targets = jnp.concatenate(
      [tokens[:, 1:], jnp.full((cfg.global_batch, 1), -1, jnp.int32)], axis=1)
  return {"tokens": tokens, "targets": targets}


def iterator(cfg: DataConfig, start_step: int = 0,
             mesh: Optional[Mesh] = None,
             batch_spec: Optional[P] = None) -> Iterator[Dict[str, jax.Array]]:
  """Infinite deterministic stream; restart-safe via start_step skip-ahead."""
  step = start_step
  while True:
    yield make_batch(cfg, step, mesh, batch_spec)
    step += 1


def from_shape(shape: ShapeConfig, vocab_size: int, seed: int = 0
               ) -> DataConfig:
  return DataConfig(vocab_size=vocab_size, seq_len=shape.seq_len,
                    global_batch=shape.global_batch, seed=seed)
