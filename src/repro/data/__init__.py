"""Deterministic sharded synthetic data pipeline."""
from repro.data import pipeline

__all__ = ["pipeline"]
