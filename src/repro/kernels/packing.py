"""Sub-byte KV packing: GGUF-style block quantization + Pallas bit-unpack.

Block format (the GGUF ``Q4_1`` layout adapted to KV rows): values are
grouped along the channel axis into groups of ``group = gcd(d, 32)``; each
group stores

    scale   f16    (max - min) / (2^bits - 1)
    min     f16    group minimum
    codes   `bits`-wide unsigned codes; at 4 bits, *split-half* packed —
            byte ``j`` of a row carries code ``j`` in its low nibble and
            code ``j + d/2`` in its high nibble, so unpacking is one
            concat of (p & 0xF, p >> 4) and channel order is preserved
            without any interleave shuffle (TPU-friendly: no gathers).

q8 is the same layout with one byte per code.  q5 stores the low nibble in
the q4 split-half layout and appends a fifth-bit *mask plane* — one byte per
8 channels, LSB-first — so unpacking is the q4 unpack plus one masked-or.
Per-value cost: q4 = 0.625 B (group 32), q5 = 0.75 B, q8 = 1.125 B, vs
2 B bf16 / 4 B f32.

The quantization parameters are rounded through f16 *before* the codes are
computed, so dequantizing with the stored f16 scale/min reproduces exactly
the values the encoder targeted — the Pallas kernel body and the XLA
reference path share one dequant formula (``codes * scale + min`` in f32)
and therefore agree bit-for-bit on the reconstructed K/V.

`dequant_page` is jnp-only and shape-polymorphic: the same function widens
a uint8 nibble page inside a Pallas kernel (VMEM-resident, no HBM round
trip) and dequantizes the whole dense store on the XLA path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Resident-KV codec registry: CacheSpec.kv_resident_codec key -> code width.
#: "none" keeps the dense float store (the pre-PR8 exact policy).
RESIDENT_CODECS = {"none": 0, "q4": 4, "q5": 5, "q8": 8}


def group_size(d: int) -> int:
  """Quant-group length along the channel axis: 32, shrunk to divide d."""
  return math.gcd(d, 32)


def packed_width(d: int, bits: int) -> int:
  """Bytes one packed row of `d` values occupies (codes only)."""
  return d * bits // 8


def quantize_rows(x: jax.Array, *, bits: int, group: int):
  """x (..., d) float -> (codes uint8 (..., d), scale f16 (..., G), min f16).

  Asymmetric per-group uniform quantization.  scale/min are rounded through
  f16 first and the codes are computed against the *rounded* params, so the
  stored f16 header dequantizes the codes exactly as the encoder intended.
  A zero f16 scale (constant or sub-f16-range group) degrades to codes=0,
  dequantizing to the group minimum.
  """
  qmax = (1 << bits) - 1
  d = x.shape[-1]
  lead = x.shape[:-1]
  xg = x.astype(jnp.float32).reshape(lead + (d // group, group))
  lo = jnp.min(xg, axis=-1)
  hi = jnp.max(xg, axis=-1)
  scale = ((hi - lo) / qmax).astype(jnp.float16)
  mn = lo.astype(jnp.float16)
  s32 = scale.astype(jnp.float32)
  safe = jnp.where(s32 > 0, s32, 1.0)
  q = jnp.clip(jnp.round((xg - mn.astype(jnp.float32)[..., None])
                         / safe[..., None]), 0, qmax)
  return q.astype(jnp.uint8).reshape(lead + (d,)), scale, mn


def dequantize_rows(q: jax.Array, scale: jax.Array, mn: jax.Array,
                    *, group: int) -> jax.Array:
  """codes (..., d) int + per-group f16 params -> f32 (..., d).

  One formula for every consumer: f32(codes) * f32(scale) + f32(min).
  """
  d = q.shape[-1]
  lead = q.shape[:-1]
  qg = q.astype(jnp.float32).reshape(lead + (d // group, group))
  x = (qg * scale.astype(jnp.float32)[..., None]
       + mn.astype(jnp.float32)[..., None])
  return x.reshape(lead + (d,))


def pack_u4(q: jax.Array) -> jax.Array:
  """(..., d) uint8 nibble codes -> (..., d//2) uint8, split-half layout."""
  dp = q.shape[-1] // 2
  return (q[..., :dp] | (q[..., dp:] << 4)).astype(jnp.uint8)


def unpack_u4(p: jax.Array) -> jax.Array:
  """(..., dp) uint8 -> (..., 2*dp) int32 nibble codes.

  Widened to int32 *before* the shift: sub-word vector shifts are the op
  TPUs lack — int32 is the lane-native width the VPU operates on.
  """
  pi = p.astype(jnp.int32)
  return jnp.concatenate([pi & 0xF, (pi >> 4) & 0xF], axis=-1)


def pack_u5(q: jax.Array) -> jax.Array:
  """(..., d) uint8 5-bit codes -> (..., 5*d//8) uint8.

  Low nibbles in the q4 split-half layout (d/2 bytes) followed by the
  fifth-bit mask plane: channel j's high bit lands in byte j // 8, bit
  position j % 8 (LSB-first) — d/8 bytes.  Requires d % 8 == 0.
  """
  d = q.shape[-1]
  lo = pack_u4(q & 0xF)
  hb = ((q >> 4) & 1).astype(jnp.int32).reshape(q.shape[:-1] + (d // 8, 8))
  weights = (jnp.int32(1) << jnp.arange(8, dtype=jnp.int32))
  hi = jnp.sum(hb * weights, axis=-1).astype(jnp.uint8)
  return jnp.concatenate([lo, hi], axis=-1)


def unpack_u5(p: jax.Array) -> jax.Array:
  """(..., 5*d//8) uint8 -> (..., d) int32 codes: q4 unpack + one masked-or."""
  d = p.shape[-1] * 8 // 5
  lo = unpack_u4(p[..., :d // 2])
  hi = p[..., d // 2:].astype(jnp.int32)
  shifts = jnp.arange(8, dtype=jnp.int32)
  bit = ((hi[..., :, None] >> shifts) & 1).reshape(p.shape[:-1] + (d,))
  return lo | (bit << 4)


def pack_rows(x: jax.Array, *, bits: int, group: int):
  """x (..., d) float -> (packed uint8 (..., d*bits/8), scale f16, min f16)."""
  q, scale, mn = quantize_rows(x, bits=bits, group=group)
  if bits == 4:
    return pack_u4(q), scale, mn
  if bits == 5:
    return pack_u5(q), scale, mn
  return q, scale, mn


def dequant_page(pack: jax.Array, scale: jax.Array, mn: jax.Array,
                 *, bits: int, group: int) -> jax.Array:
  """Packed page (..., d*bits/8) uint8 + f16 headers -> f32 values (..., d).

  jnp-only: runs identically inside a Pallas kernel body (the in-VMEM
  widen) and on the XLA reference path, which is what makes the two decode
  programs produce bit-identical attention inputs.
  """
  if bits == 4:
    q = unpack_u4(pack)
  elif bits == 5:
    q = unpack_u5(pack)
  else:
    q = pack.astype(jnp.int32)
  return dequantize_rows(q, scale, mn, group=group)


# ---------------------------------------------------------------------------
# Standalone Pallas bit-unpack primitive
# ---------------------------------------------------------------------------

def _unpack_u4_kernel(p_ref, out_ref):
  out_ref[...] = unpack_u4(p_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def unpack_u4_kernel(p: jax.Array, interpret: bool = True) -> jax.Array:
  """Widen a (n, dp) uint8 nibble page to (n, 2*dp) int32 codes in VMEM.

  The unit-testable core of the packed decode kernels: everything they add
  on top (dequant + flash accumulate) is ordinary f32 math.
  """
  n, dp = p.shape
  return pl.pallas_call(
      _unpack_u4_kernel,
      out_shape=jax.ShapeDtypeStruct((n, 2 * dp), jnp.int32),
      interpret=interpret,
      name="unpack_u4",
  )(p)
