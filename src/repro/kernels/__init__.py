"""Pallas TPU kernels for AQPIM's compute hot-spots.

- pq_decode       PQ decode attention on compressed KV (VMEM table = the paper's
                  intra-row indirection analogue); dense + block-table-native
                  (paged pool) variants
- paged_flash_decode  exact-policy flash decode, dense + block-table-native
- kmeans_assign   distance-calculation + cluster-assignment step of online k-means
- flash_attention exact blockwise attention (prefill / baseline)

Each kernel has a pure-jnp oracle in ref.py; ops.py holds the jit'd wrappers.
Kernels are validated with interpret=True on CPU and target Mosaic on TPU.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
