"""Pallas TPU kernel: flash-decode attention for the exact policy, block-table
native.

One decode step of exact attention — a (g, d) GQA query group against that kv
head's cached K/V — as a flash-decoding scan over token blocks with running
(max, denom) in VMEM scratch.  Two entry points share one block body:

  ``flash_decode_kernel``        dense per-request K/V (BH, N, d) — the
                                 contiguous-layout serve path;
  ``paged_flash_decode_kernel``  *block-table-native*: K/V live in the paged
                                 layout's physical pool (P+1, L, H, block, d)
                                 and the sequence-block grid axis streams pool
                                 block ``table[bh, j]`` of layer ``layer[0]``
                                 via scalar-prefetched index maps.  The pool
                                 is an ordinary pallas_call input — never
                                 sliced, gathered, or densified in HBM; the
                                 only HBM reads are the mapped blocks.

This is the storage/compute cooperation LoL-PIM-style systems identify as the
long-context decode bottleneck: the dense gather->decode->scatter round trip
(2x the active KV through HBM per step) collapses to block reads plus the one
inserted token row.

Unallocated table entries point at the pool's trash block; their rows sit at
positions >= the request's length and are masked like any ragged tail.

Grid: (batch*kv_heads, token_blocks), both sequential ("arbitrary") so the
(max, denom, acc) scratch carries across the token axis and is re-inited per
bh row at @pl.when(j == 0).

VMEM budget per grid cell (g<=16, d=128, blk<=512, f32):
  k/v blocks 2*(blk, d)  <= 0.5 MiB
  acc (g, d) + s (g, blk) + m/l (g, 1)  << 0.1 MiB
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat
from repro.kernels import packing

NEG_INF = -1e30


def _init_scratch(g, d, acc_ref, m_ref, l_ref):
  acc_ref[...] = jnp.zeros((g, d), jnp.float32)
  m_ref[...] = jnp.full((g, 1), NEG_INF, jnp.float32)
  l_ref[...] = jnp.zeros((g, 1), jnp.float32)


def _accumulate_block(q, k, v, valid, scale, acc_ref, m_ref, l_ref):
  """One token block of flash decoding.  q (g, d); k/v (blk, d); valid (blk,)."""
  s = jax.lax.dot_general(
      q, k, dimension_numbers=(((1,), (1,)), ((), ())),
      preferred_element_type=jnp.float32) * scale     # (g, blk)
  s = jnp.where(valid[None, :], s, NEG_INF)
  m_prev = m_ref[...]
  mu = jnp.max(s, axis=-1, keepdims=True)
  m_new = jnp.maximum(m_prev, mu)
  alpha = jnp.exp(m_prev - m_new)
  p = jnp.exp(s - m_new)
  p = jnp.where(valid[None, :], p, 0.0)
  l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
  m_ref[...] = m_new
  acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
      p, v, dimension_numbers=(((1,), (0,)), ((), ())),
      preferred_element_type=jnp.float32)             # (g, d)


def _finalize(out_ref, acc_ref, l_ref):
  out_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
      out_ref.dtype)


def _flash_decode_kernel(
    length_ref,            # (BH,) int32 — valid tokens (incl. inserted one)
    q_ref,                 # (1, g, d)
    k_ref,                 # (1, blk, d)
    v_ref,                 # (1, blk, d)
    out_ref,               # (1, g, d) f32
    acc_ref, m_ref, l_ref,
    *, scale: float, blk: int, n_blocks: int,
):
  bh = pl.program_id(0)
  j = pl.program_id(1)
  g, d = q_ref.shape[1], q_ref.shape[2]

  @pl.when(j == 0)
  def _init():
    _init_scratch(g, d, acc_ref, m_ref, l_ref)

  length = length_ref[bh]
  pos = j * blk + jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1)[0]

  @pl.when(j * blk < length)
  def _block():
    _accumulate_block(q_ref[0].astype(jnp.float32),
                      k_ref[0].astype(jnp.float32),
                      v_ref[0].astype(jnp.float32),
                      pos < length, scale, acc_ref, m_ref, l_ref)

  @pl.when(j == n_blocks - 1)
  def _done():
    _finalize(out_ref, acc_ref, l_ref)


@functools.partial(
    jax.jit, static_argnames=("scale", "blk", "interpret"))
def flash_decode_kernel(
    q: jax.Array,        # (BH, g, d)
    k: jax.Array,        # (BH, N, d)
    v: jax.Array,        # (BH, N, d)
    length: jax.Array,   # (BH,) int32 — valid tokens per row
    scale: float,
    blk: int = 512,
    interpret: bool = True,
) -> jax.Array:
  """Dense-storage flash decode: (BH, g, d) f32 attention outputs."""
  bhn, g, d = q.shape
  n = k.shape[1]
  assert n % blk == 0, f"capacity {n} must be a multiple of blk={blk}"
  n_blocks = n // blk
  kernel = functools.partial(
      _flash_decode_kernel, scale=scale, blk=blk, n_blocks=n_blocks)
  return pl.pallas_call(
      kernel,
      grid_spec=_compat.scalar_grid_spec(
          num_scalar_prefetch=1,
          grid=(bhn, n_blocks),
          in_specs=[
              pl.BlockSpec((1, g, d), lambda bh, j, L: (bh, 0, 0)),
              pl.BlockSpec((1, blk, d), lambda bh, j, L: (bh, j, 0)),
              pl.BlockSpec((1, blk, d), lambda bh, j, L: (bh, j, 0)),
          ],
          out_specs=pl.BlockSpec((1, g, d), lambda bh, j, L: (bh, 0, 0)),
          scratch_shapes=[
              pltpu.VMEM((g, d), jnp.float32),
              pltpu.VMEM((g, 1), jnp.float32),
              pltpu.VMEM((g, 1), jnp.float32),
          ],
      ),
      out_shape=jax.ShapeDtypeStruct((bhn, g, d), jnp.float32),
      compiler_params=_compat.compiler_params(
          dimension_semantics=("arbitrary", "arbitrary")),
      interpret=interpret,
      name="flash_decode",
  )(length, q, k, v)


def _paged_flash_decode_kernel(
    tables_ref,            # (BH, nb) int32 — per-slot block tables
    layer_ref,             # (1,) int32
    length_ref,            # (BH,) int32
    q_ref,                 # (1, g, d)
    k_ref,                 # (1, 1, 1, blk, d) — pool block tables[bh, j]
    v_ref,                 # (1, 1, 1, blk, d)
    out_ref,               # (1, g, d) f32
    acc_ref, m_ref, l_ref,
    *, scale: float, blk: int, n_blocks: int,
):
  bh = pl.program_id(0)
  j = pl.program_id(1)
  g, d = q_ref.shape[1], q_ref.shape[2]

  @pl.when(j == 0)
  def _init():
    _init_scratch(g, d, acc_ref, m_ref, l_ref)

  length = length_ref[bh]
  pos = j * blk + jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1)[0]

  @pl.when(j * blk < length)
  def _block():
    _accumulate_block(q_ref[0].astype(jnp.float32),
                      k_ref[0, 0, 0].astype(jnp.float32),
                      v_ref[0, 0, 0].astype(jnp.float32),
                      pos < length, scale, acc_ref, m_ref, l_ref)

  @pl.when(j == n_blocks - 1)
  def _done():
    _finalize(out_ref, acc_ref, l_ref)


@functools.partial(
    jax.jit, static_argnames=("scale", "interpret"))
def paged_flash_decode_kernel(
    q: jax.Array,          # (BH, g, d)
    k_pool: jax.Array,     # (P+1, L, H, blk, d)
    v_pool: jax.Array,     # (P+1, L, H, blk, d)
    tables: jax.Array,     # (BH, nb) int32 — logical block j -> pool block
    layer: jax.Array,      # (1,) int32
    length: jax.Array,     # (BH,) int32 — valid tokens per row
    scale: float,
    interpret: bool = True,
) -> jax.Array:
  """Block-table-native flash decode over pooled K/V: (BH, g, d) f32."""
  bhn, g, d = q.shape
  n_heads = k_pool.shape[2]
  blk = k_pool.shape[3]
  n_blocks = tables.shape[1]
  kernel = functools.partial(
      _paged_flash_decode_kernel, scale=scale, blk=blk, n_blocks=n_blocks)

  def pool_spec():
    return pl.BlockSpec(
        (1, 1, 1, blk, d),
        lambda bh, j, tbl, lyr, L: (tbl[bh, j], lyr[0], bh % n_heads, 0, 0))

  return pl.pallas_call(
      kernel,
      grid_spec=_compat.scalar_grid_spec(
          num_scalar_prefetch=3,
          grid=(bhn, n_blocks),
          in_specs=[
              pl.BlockSpec((1, g, d), lambda bh, j, tbl, lyr, L: (bh, 0, 0)),
              pool_spec(),
              pool_spec(),
          ],
          out_specs=pl.BlockSpec((1, g, d),
                                 lambda bh, j, tbl, lyr, L: (bh, 0, 0)),
          scratch_shapes=[
              pltpu.VMEM((g, d), jnp.float32),
              pltpu.VMEM((g, 1), jnp.float32),
              pltpu.VMEM((g, 1), jnp.float32),
          ],
      ),
      out_shape=jax.ShapeDtypeStruct((bhn, g, d), jnp.float32),
      compiler_params=_compat.compiler_params(
          dimension_semantics=("arbitrary", "arbitrary")),
      interpret=interpret,
      name="paged_flash_decode",
  )(tables, layer, length, q, k_pool, v_pool)


def _packed_paged_flash_decode_kernel(
    tables_ref,            # (BH, nb) int32 — per-slot block tables
    layer_ref,             # (1,) int32
    length_ref,            # (BH,) int32
    q_ref,                 # (1, g, d)
    kp_ref,                # (1, 1, 1, blk, dp) uint8 — packed K codes
    ks_ref,                # (1, 1, 1, blk, G) f16 — K group scales
    km_ref,                # (1, 1, 1, blk, G) f16 — K group minima
    vp_ref, vs_ref, vm_ref,
    out_ref,               # (1, g, d) f32
    acc_ref, m_ref, l_ref,
    *, scale: float, blk: int, n_blocks: int, bits: int, group: int,
):
  bh = pl.program_id(0)
  j = pl.program_id(1)
  g, d = q_ref.shape[1], q_ref.shape[2]

  @pl.when(j == 0)
  def _init():
    _init_scratch(g, d, acc_ref, m_ref, l_ref)

  length = length_ref[bh]
  pos = j * blk + jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1)[0]

  @pl.when(j * blk < length)
  def _block():
    # widen the nibble pages in VMEM: the only HBM reads are the packed
    # codes + f16 headers — ~0.35x the bytes of the float block
    k = packing.dequant_page(kp_ref[0, 0, 0], ks_ref[0, 0, 0],
                             km_ref[0, 0, 0], bits=bits, group=group)
    v = packing.dequant_page(vp_ref[0, 0, 0], vs_ref[0, 0, 0],
                             vm_ref[0, 0, 0], bits=bits, group=group)
    _accumulate_block(q_ref[0].astype(jnp.float32), k, v,
                      pos < length, scale, acc_ref, m_ref, l_ref)

  @pl.when(j == n_blocks - 1)
  def _done():
    _finalize(out_ref, acc_ref, l_ref)


@functools.partial(
    jax.jit, static_argnames=("scale", "bits", "interpret"))
def packed_paged_flash_decode_kernel(
    q: jax.Array,          # (BH, g, d)
    k_pack: jax.Array,     # (P+1, L, H, blk, d*bits/8) uint8
    k_scale: jax.Array,    # (P+1, L, H, blk, G) f16
    k_min: jax.Array,      # (P+1, L, H, blk, G) f16
    v_pack: jax.Array,
    v_scale: jax.Array,
    v_min: jax.Array,
    tables: jax.Array,     # (BH, nb) int32 — logical block j -> pool block
    layer: jax.Array,      # (1,) int32
    length: jax.Array,     # (BH,) int32 — valid tokens per row
    scale: float,
    bits: int,
    interpret: bool = True,
) -> jax.Array:
  """Block-table-native flash decode over *packed* pooled K/V.

  Same grid/scratch structure as `paged_flash_decode_kernel`; the two float
  pool inputs become six (codes + f16 scale/min per tensor) and each mapped
  block is bit-unpacked and dequantized in VMEM before the flash accumulate.
  """
  bhn, g, d = q.shape
  n_heads = k_pack.shape[2]
  blk = k_pack.shape[3]
  dp = k_pack.shape[4]
  n_groups = k_scale.shape[4]
  group = d // n_groups
  n_blocks = tables.shape[1]
  kernel = functools.partial(
      _packed_paged_flash_decode_kernel, scale=scale, blk=blk,
      n_blocks=n_blocks, bits=bits, group=group)

  def pool_spec(width):
    return pl.BlockSpec(
        (1, 1, 1, blk, width),
        lambda bh, j, tbl, lyr, L: (tbl[bh, j], lyr[0], bh % n_heads, 0, 0))

  return pl.pallas_call(
      kernel,
      grid_spec=_compat.scalar_grid_spec(
          num_scalar_prefetch=3,
          grid=(bhn, n_blocks),
          in_specs=[
              pl.BlockSpec((1, g, d), lambda bh, j, tbl, lyr, L: (bh, 0, 0)),
              pool_spec(dp), pool_spec(n_groups), pool_spec(n_groups),
              pool_spec(dp), pool_spec(n_groups), pool_spec(n_groups),
          ],
          out_specs=pl.BlockSpec((1, g, d),
                                 lambda bh, j, tbl, lyr, L: (bh, 0, 0)),
          scratch_shapes=[
              pltpu.VMEM((g, d), jnp.float32),
              pltpu.VMEM((g, 1), jnp.float32),
              pltpu.VMEM((g, 1), jnp.float32),
          ],
      ),
      out_shape=jax.ShapeDtypeStruct((bhn, g, d), jnp.float32),
      compiler_params=_compat.compiler_params(
          dimension_semantics=("arbitrary", "arbitrary")),
      interpret=interpret,
      name="packed_paged_flash_decode",
  )(tables, layer, length, q, k_pack, k_scale, k_min,
    v_pack, v_scale, v_min)
