"""Version-compat shims for the Pallas TPU API surface.

One place to touch when the jax floor moves.  Three things drift across
releases and must not break CPU CI, where every kernel runs under
``interpret=True`` (the CI machine has no TPU, so any compat failure turns
the kernels into untested dead code):

  * jax<0.5 names the TPU compiler-params class ``TPUCompilerParams``;
    newer releases call it ``CompilerParams``.
  * some releases reject keywords the other accepts (``dimension_semantics``
    moved around) — ``compiler_params()`` constructs whichever works and
    returns None when neither does.  Interpret mode ignores compiler params
    entirely, so None keeps CPU CI green while TPU builds still get the
    dimension semantics they need.
  * ``PrefetchScalarGridSpec`` (scalar-prefetched block tables — the
    block-table-native decode kernels depend on it) is TPU-namespace in the
    supported range; ``scalar_grid_spec()`` is the single lookup point.
"""
from jax.experimental import pallas as pl  # noqa: F401  (re-export surface)
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def compiler_params(dimension_semantics=None):
  """Best-effort compiler params: right class, tolerated kwargs, else None.

  Returning None is always safe for interpret mode (params are ignored);
  on TPU it merely drops the parallelism hint rather than crashing.
  """
  kwargs = {}
  if dimension_semantics is not None:
    kwargs["dimension_semantics"] = tuple(dimension_semantics)
  try:
    return CompilerParams(**kwargs)
  except TypeError:
    try:
      return CompilerParams()
    except TypeError:
      return None


def scalar_grid_spec(*, num_scalar_prefetch, grid, in_specs, out_specs,
                     scratch_shapes):
  """Grid spec with scalar prefetch (index maps may read prefetched refs)."""
  spec_cls = getattr(pltpu, "PrefetchScalarGridSpec", None)
  if spec_cls is None:  # pragma: no cover — future jax: moved into pl.GridSpec
    return pl.GridSpec(
        num_scalar_prefetch=num_scalar_prefetch, grid=grid,
        in_specs=in_specs, out_specs=out_specs,
        scratch_shapes=scratch_shapes)
  return spec_cls(
      num_scalar_prefetch=num_scalar_prefetch, grid=grid,
      in_specs=in_specs, out_specs=out_specs, scratch_shapes=scratch_shapes)
