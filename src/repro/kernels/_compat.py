"""Version-compat shims for the Pallas TPU API surface.

One place to touch when the jax floor moves: jax<0.5 names the TPU
compiler-params class `TPUCompilerParams`; newer releases call it
`CompilerParams`.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
