"""Pallas TPU kernel: blockwise causal flash attention (exact baseline).

The exact-attention hot path for prefill/training — the computation AQPIM's PQ
attention replaces during decode, and the baseline every paper figure compares
against.  Standard flash-attention-2 style forward: online softmax with running
(max, denom) in VMEM scratch, KV blocks streamed innermost, GQA handled by mapping
the query head to its KV head in the BlockSpec index_map (no KV replication in HBM).

Grid: (batch, q_heads, q_blocks, kv_blocks) — kv axis sequential (accumulators),
the rest parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, out_ref,
    acc_ref, m_ref, l_ref,
    *, scale: float, blk_q: int, blk_k: int, n_kv_blocks: int, causal: bool,
):
  i = pl.program_id(2)
  j = pl.program_id(3)

  @pl.when(j == 0)
  def _init():
    acc_ref[...] = jnp.zeros_like(acc_ref)
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)

  # skip blocks strictly above the causal diagonal
  run = (not causal) or (j * blk_k <= i * blk_q + blk_q - 1)

  @pl.when(run)
  def _block():
    q = q_ref[0, 0].astype(jnp.float32)               # (blk_q, d)
    k = k_ref[0, 0].astype(jnp.float32)               # (blk_k, d)
    v = v_ref[0, 0].astype(jnp.float32)               # (blk_k, d)
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (blk_q, blk_k)
    if causal:
      q_pos = i * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
      k_pos = j * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
      s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]
    mu = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, mu)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

  @pl.when(j == n_kv_blocks - 1)
  def _finalize():
    out_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
        out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "causal", "blk_q", "blk_k", "interpret"))
def flash_attention_kernel(
    q: jax.Array,   # (B, Hq, N, d)
    k: jax.Array,   # (B, Hkv, N, d)
    v: jax.Array,   # (B, Hkv, N, d)
    scale: float,
    causal: bool = True,
    blk_q: int = 512,
    blk_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
  b, hq, n, d = q.shape
  hkv = k.shape[1]
  g = hq // hkv
  assert n % blk_q == 0 and n % blk_k == 0
  n_kv_blocks = n // blk_k
  grid = (b, hq, n // blk_q, n_kv_blocks)

  return pl.pallas_call(
      functools.partial(
          _flash_kernel, scale=scale, blk_q=blk_q, blk_k=blk_k,
          n_kv_blocks=n_kv_blocks, causal=causal),
      grid=grid,
      in_specs=[
          pl.BlockSpec((1, 1, blk_q, d), lambda b_, h, i, j: (b_, h, i, 0)),
          pl.BlockSpec((1, 1, blk_k, d), lambda b_, h, i, j: (b_, h // g, j, 0)),
          pl.BlockSpec((1, 1, blk_k, d), lambda b_, h, i, j: (b_, h // g, j, 0)),
      ],
      out_specs=pl.BlockSpec((1, 1, blk_q, d), lambda b_, h, i, j: (b_, h, i, 0)),
      out_shape=jax.ShapeDtypeStruct((b, hq, n, d), q.dtype),
      scratch_shapes=[
          pltpu.VMEM((blk_q, d), jnp.float32),
          pltpu.VMEM((blk_q, 1), jnp.float32),
          pltpu.VMEM((blk_q, 1), jnp.float32),
      ],
      compiler_params=_CompilerParams(
          dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
      ),
      interpret=interpret,
      name="flash_attention_fwd",
  )(q, k, v)
