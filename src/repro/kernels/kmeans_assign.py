"""Pallas TPU kernel: k-means assignment step (AQPIM Table I: DC + CA).

The paper's Distance Calculation runs on BankPEs (matmul-shaped, near-bank) and
Cluster Assignment (argmin reduction) on the BufferPE.  On TPU both fuse into one
kernel: the ||x||^2 - 2 x.C^T + ||C||^2 expansion is a (blk, dsub) @ (dsub, K)
MXU matmul plus rank-1 corrections; the argmin over the K lane axis is a VPU
reduction.  Centroids for all m subvector spaces stay VMEM-resident across the
sequence sweep (they are the "codebook page").

Grid: (m, sequence_blocks); centroid block is revisited per subvector (constant
along the sequence axis), token blocks stream through.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _assign_kernel(x_ref, c_ref, out_ref, *, blk: int):
  """x_ref (1, blk, dsub); c_ref (1, K, dsub); out_ref (1, blk) int32."""
  x = x_ref[0].astype(jnp.float32)                     # (blk, dsub)
  c = c_ref[0].astype(jnp.float32)                     # (K, dsub)
  cross = jax.lax.dot_general(
      x, c, dimension_numbers=(((1,), (1,)), ((), ())),
      preferred_element_type=jnp.float32)              # (blk, K) MXU
  c_sq = jnp.sum(c * c, axis=-1)                       # (K,)
  # ||x||^2 is constant per row — irrelevant for the argmin; skip it (saves VPU work)
  dist = c_sq[None, :] - 2.0 * cross                   # (blk, K)
  out_ref[0] = jnp.argmin(dist, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def kmeans_assign_kernel(
    x: jax.Array,          # (m, N, dsub)
    centroids: jax.Array,  # (m, K, dsub)
    blk: int = 1024,
    interpret: bool = True,
) -> jax.Array:
  """Nearest-centroid ids (m, N) int32."""
  m, n, dsub = x.shape
  _, k_cent, _ = centroids.shape
  assert n % blk == 0, f"N={n} must be a multiple of blk={blk}"
  grid = (m, n // blk)
  return pl.pallas_call(
      functools.partial(_assign_kernel, blk=blk),
      grid=grid,
      in_specs=[
          pl.BlockSpec((1, blk, dsub), lambda mi, j: (mi, j, 0)),
          pl.BlockSpec((1, k_cent, dsub), lambda mi, j: (mi, 0, 0)),
      ],
      out_specs=pl.BlockSpec((1, blk), lambda mi, j: (mi, j)),
      out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
      compiler_params=_CompilerParams(
          dimension_semantics=("parallel", "arbitrary"),
      ),
      interpret=interpret,
      name="kmeans_assign",
  )(x, centroids)
