"""Public jit'd wrappers around the Pallas kernels.

`interpret=None` auto-selects: Pallas interpret mode on CPU (this container),
compiled Mosaic on real TPU.  The model code can also bypass kernels entirely
(pure-JAX path) — see models/model.py `use_pallas` — which is what the multi-pod
dry-run lowers (XLA-fused HLO is what cost_analysis reads).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _flash
from repro.kernels import kmeans_assign as _assign
from repro.kernels import paged_flash_decode as _pfd
from repro.kernels import pq_decode as _pqd


def _auto_interpret(interpret: Optional[bool]) -> bool:
  if interpret is None:
    return jax.default_backend() != "tpu"
  return interpret


def decode_block(n: int, preferred: int = 512) -> int:
  """Largest power-of-two sequence block <= `preferred` dividing `n`.

  The decode kernels require the token capacity to split into whole blocks;
  serve-path capacities are engine-chosen (body capacity, context length), so
  the call sites pick the block instead of asserting.
  """
  blk = preferred
  while blk > 1 and n % blk:
    blk //= 2
  return max(blk, 1)


def pq_decode_attention(
    q: jax.Array,               # (B, H_kv, g, d)
    key_codebook: jax.Array,    # (B, H_kv, m, K, dsub)
    value_codebook: jax.Array,  # (B, H_kv, m, K, dsub)
    key_indices: jax.Array,     # (B, H_kv, N, m)
    value_indices: jax.Array,   # (B, H_kv, N, m)
    length: jax.Array,          # scalar or (B, H_kv)
    scale: float,
    blk: int = 512,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
  """PQ body attention. Returns (out (B,H,g,d) f32, max (B,H,g), denom (B,H,g))."""
  b, h, g, d = q.shape
  bh = b * h
  m, k_cent, dsub = key_codebook.shape[2:]
  n = key_indices.shape[2]
  if jnp.ndim(length) == 0:
    length = jnp.full((bh,), length, jnp.int32)
  else:
    length = length.reshape(bh).astype(jnp.int32)
  vcbt = jnp.swapaxes(value_codebook, -1, -2)          # (B,H,m,dsub,K)
  out, stats = _pqd.pq_decode_attention_kernel(
      q.reshape(bh, g, d),
      key_codebook.reshape(bh, m, k_cent, dsub).astype(jnp.float32),
      vcbt.reshape(bh, m, dsub, k_cent).astype(jnp.float32),
      key_indices.reshape(bh, n, m).astype(jnp.int32),
      value_indices.reshape(bh, n, m).astype(jnp.int32),
      length,
      scale=scale, blk=blk, interpret=_auto_interpret(interpret))
  out = out.reshape(b, h, g, d)
  stats = stats.reshape(b, h, 2, g)
  return out, stats[:, :, 0], stats[:, :, 1]


def pq_decode_attention_paged(
    q: jax.Array,               # (B, H_kv, g, d)
    key_codebook: jax.Array,    # (B, H_kv, m, K, dsub)
    value_codebook: jax.Array,  # (B, H_kv, m, K, dsub)
    key_index_pool: jax.Array,  # (P+1, L, H_kv, blk, m) narrow int
    value_index_pool: jax.Array,
    tables: jax.Array,          # (B, nb) int32 per-slot block tables
    layer: jax.Array,           # scalar int32
    length: jax.Array,          # (B,) valid body tokens
    scale: float,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
  """Block-table-native PQ body attention (zero dense materialization).

  Same return contract as `pq_decode_attention`: (out, max, denom) per
  (B, H, g) for the exact sink/recent segment combine.
  """
  b, h, g, d = q.shape
  bh = b * h
  m, k_cent, dsub = key_codebook.shape[2:]
  vcbt = jnp.swapaxes(value_codebook, -1, -2)          # (B,H,m,dsub,K)
  tables_bh = jnp.repeat(tables.astype(jnp.int32), h, axis=0)   # (BH, nb)
  length_bh = jnp.repeat(length.astype(jnp.int32), h, axis=0)
  out, stats = _pqd.pq_decode_attention_paged_kernel(
      q.reshape(bh, g, d),
      key_codebook.reshape(bh, m, k_cent, dsub).astype(jnp.float32),
      vcbt.reshape(bh, m, dsub, k_cent).astype(jnp.float32),
      key_index_pool, value_index_pool,
      tables_bh, jnp.reshape(layer, (1,)).astype(jnp.int32), length_bh,
      scale=scale, interpret=_auto_interpret(interpret))
  out = out.reshape(b, h, g, d)
  stats = stats.reshape(b, h, 2, g)
  return out, stats[:, :, 0], stats[:, :, 1]


def flash_decode(
    q: jax.Array,        # (B, H_kv, g, d)
    k: jax.Array,        # (B, H_kv, N, d)
    v: jax.Array,        # (B, H_kv, N, d)
    length: jax.Array,   # (B,) or (B, H_kv) valid tokens per row
    scale: float,
    blk: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
  """Dense-storage flash decode (exact policy, contiguous layout)."""
  b, h, g, d = q.shape
  bh = b * h
  n = k.shape[2]
  if jnp.ndim(length) == 1:
    length = jnp.repeat(length.astype(jnp.int32), h, axis=0)
  else:
    length = length.reshape(bh).astype(jnp.int32)
  out = _pfd.flash_decode_kernel(
      q.reshape(bh, g, d), k.reshape(bh, n, d), v.reshape(bh, n, d),
      length, scale=scale, blk=decode_block(n, min(blk, n)),
      interpret=_auto_interpret(interpret))
  return out.reshape(b, h, g, d)


def paged_flash_decode(
    q: jax.Array,        # (B, H_kv, g, d)
    k_pool: jax.Array,   # (P+1, L, H_kv, blk, d)
    v_pool: jax.Array,
    tables: jax.Array,   # (B, nb) int32
    layer: jax.Array,    # scalar int32
    length: jax.Array,   # (B,) valid tokens per row
    scale: float,
    interpret: Optional[bool] = None,
) -> jax.Array:
  """Block-table-native flash decode over pooled K/V (exact policy)."""
  b, h, g, d = q.shape
  bh = b * h
  tables_bh = jnp.repeat(tables.astype(jnp.int32), h, axis=0)
  length_bh = jnp.repeat(length.astype(jnp.int32), h, axis=0)
  out = _pfd.paged_flash_decode_kernel(
      q.reshape(bh, g, d), k_pool, v_pool, tables_bh,
      jnp.reshape(layer, (1,)).astype(jnp.int32), length_bh,
      scale=scale, interpret=_auto_interpret(interpret))
  return out.reshape(b, h, g, d)


def packed_paged_flash_decode(
    q: jax.Array,        # (B, H_kv, g, d)
    k_pack: jax.Array,   # (P+1, L, H_kv, blk, d*bits/8) uint8
    k_scale: jax.Array,  # (P+1, L, H_kv, blk, G) f16
    k_min: jax.Array,
    v_pack: jax.Array,
    v_scale: jax.Array,
    v_min: jax.Array,
    tables: jax.Array,   # (B, nb) int32
    layer: jax.Array,    # scalar int32
    length: jax.Array,   # (B,) valid tokens per row
    scale: float,
    bits: int,
    interpret: Optional[bool] = None,
) -> jax.Array:
  """Block-table-native flash decode over sub-byte packed pooled K/V
  (exact policy with `kv_resident_codec` q4/q8): mapped code pages are
  bit-unpacked and dequantized in VMEM, never densified in HBM."""
  b, h, g, d = q.shape
  bh = b * h
  tables_bh = jnp.repeat(tables.astype(jnp.int32), h, axis=0)
  length_bh = jnp.repeat(length.astype(jnp.int32), h, axis=0)
  out = _pfd.packed_paged_flash_decode_kernel(
      q.reshape(bh, g, d), k_pack, k_scale, k_min, v_pack, v_scale, v_min,
      tables_bh, jnp.reshape(layer, (1,)).astype(jnp.int32), length_bh,
      scale=scale, bits=bits, interpret=_auto_interpret(interpret))
  return out.reshape(b, h, g, d)


def kmeans_assign(
    x: jax.Array,          # (m, N, dsub)
    centroids: jax.Array,  # (m, K, dsub)
    blk: int = 1024,
    interpret: Optional[bool] = None,
) -> jax.Array:
  m, n, dsub = x.shape
  pad = (-n) % blk
  if pad:
    x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
  out = _assign.kmeans_assign_kernel(
      x, centroids, blk=blk, interpret=_auto_interpret(interpret))
  return out[:, :n]


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    scale: float, causal: bool = True,
    blk_q: int = 512, blk_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
  n = q.shape[2]
  blk_q = min(blk_q, n)
  blk_k = min(blk_k, n)
  return _flash.flash_attention_kernel(
      q, k, v, scale=scale, causal=causal, blk_q=blk_q, blk_k=blk_k,
      interpret=_auto_interpret(interpret))


def combine_attention_segments(outs, maxes, denoms) -> jax.Array:
  """Exact flash-decoding combine of per-segment partial attentions.

  Each segment supplies a *normalized* output plus its (running max, denom);
  combining is numerically exact: softmax over the union of segments.
  Shapes: out (..., g, d); max/denom (..., g).
  """
  m_all = functools.reduce(jnp.maximum, maxes)
  num = None
  den = None
  for o, mm, l in zip(outs, maxes, denoms):
    w = l * jnp.exp(mm - m_all)
    term = o * w[..., None]
    num = term if num is None else num + term
    den = w if den is None else den + w
  return num / jnp.maximum(den, 1e-30)[..., None]
