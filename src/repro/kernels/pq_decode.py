"""Pallas TPU kernel: PQ decode attention on compressed KV (AQPIM Fig. 5 + §III-F).

TPU adaptation of the paper's intra-row indirection: the inner-product table
T[g, j, k] = <q_subvec, C_key[j, k]> is computed once per decode step and *pinned in
VMEM scratch* — the analogue of the paper's "lookup table resides within a single
DRAM row".  Per-token centroid-id lookups are then VMEM-local lane gathers
(jnp.take_along_axis over the K lane axis), never re-touching HBM: every index block
is streamed HBM->VMEM exactly once, like the paper's "one row activation per window".

The value path adapts the paper's bucket-sum: instead of a scatter (TPU-hostile),
each sequence block's value subvectors are gathered *block-locally in VMEM* from the
value codebook (stored (m, dsub, K), gathers along lanes) and contracted against the
attention probabilities on the MXU.  HBM traffic is identical to the paper's scheme
(indices + codebook once); the reconstruction exists only inside VMEM — the paper
avoids it because BankPEs cannot afford the buffer, which VMEM provides for free.

Softmax is fused flash-decoding style: running (max, denom) carried across sequence
blocks in VMEM scratch; the kernel emits the *body segment's* normalized output plus
(max, denom) so the wrapper can exactly combine it with the full-precision sink and
recent segments (paper §IV-A layout).

Grid: (batch*kv_heads, sequence_blocks) — both sequential ("arbitrary") so scratch
accumulators carry across the sequence axis; the batch*head axis revisits scratch
from a clean @pl.when(j == 0) init.

VMEM budget per grid cell (defaults g<=16, m=32, K=512, d=128, blk=512):
  T (g, m, K) f32          <= 1.0 MiB
  codebooks 2 * m*K*dsub   =  0.5 MiB (f32, in + transposed value layout)
  index blocks 2*(blk, m)  =  0.128 MiB int32
  acc/vrec/p blocks        <= 0.6 MiB
  total                    ~  2.3 MiB  << VMEM
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _pq_decode_kernel(
    # scalar prefetch
    length_ref,            # (BH,) int32 in SMEM — valid body tokens per row
    # inputs
    q_ref,                 # (1, g, d)
    kcb_ref,               # (1, m, K, dsub)
    vcbt_ref,              # (1, m, dsub, K)   value codebook, lane-gather layout
    kidx_ref,              # (1, blk, m) int32
    vidx_ref,              # (1, blk, m) int32
    # outputs
    out_ref,               # (1, g, d) f32
    stats_ref,             # (1, 2, g) f32  [0]=running max, [1]=denom
    # scratch
    t_ref,                 # VMEM (g, m, K) f32
    acc_ref,               # VMEM (g, d) f32
    m_ref,                 # VMEM (g, 1) f32
    l_ref,                 # VMEM (g, 1) f32
    *,
    scale: float,
    blk: int,
    n_blocks: int,
):
  bh = pl.program_id(0)
  j = pl.program_id(1)
  g, d = q_ref.shape[1], q_ref.shape[2]
  m, k_cent, dsub = kcb_ref.shape[1], kcb_ref.shape[2], kcb_ref.shape[3]

  @pl.when(j == 0)
  def _init():
    # Step 1-2 (paper): subvector split + inner-product table, once per step.
    q = q_ref[0].astype(jnp.float32)                    # (g, d)
    qs = q.reshape(g, m, dsub)
    cb = kcb_ref[0].astype(jnp.float32)                 # (m, K, dsub)
    # (g, m, K) = sum_dsub qs[g,m,:] * cb[m,K,:] — MXU contraction per subvector
    t_ref[...] = jax.lax.dot_general(
        qs.transpose(1, 0, 2), cb.transpose(0, 2, 1),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).transpose(1, 0, 2) * scale                        # (m,g,K)->(g,m,K)
    acc_ref[...] = jnp.zeros((g, d), jnp.float32)
    m_ref[...] = jnp.full((g, 1), NEG_INF, jnp.float32)
    l_ref[...] = jnp.zeros((g, 1), jnp.float32)

  length = length_ref[bh]
  pos = j * blk + jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1)[0]
  valid = pos < length

  @pl.when(j * blk < length)
  def _block():
    # Step 3-4 (paper): score lookup from the VMEM-resident table.
    kidx = kidx_ref[0]                                  # (blk, m)
    kidx_t = kidx.T                                     # (m, blk) lane-dim gather
    def score_one(gi):
      gath = jnp.take_along_axis(t_ref[gi], kidx_t, axis=1)   # (m, blk)
      return jnp.sum(gath, axis=0)                            # (blk,)
    s = jnp.stack([score_one(gi) for gi in range(g)])         # (g, blk)
    s = jnp.where(valid[None, :], s, NEG_INF)

    # Step 5 (paper): fused online softmax.
    m_prev = m_ref[...]                                 # (g, 1)
    mu = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, mu)
    alpha = jnp.exp(m_prev - m_new)                     # (g, 1)
    p = jnp.exp(s - m_new)                              # (g, blk)
    p = jnp.where(valid[None, :], p, 0.0)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new

    # Step 6-7 (paper): block-local VMEM gather of value subvectors + MXU contract.
    vidx_t = vidx_ref[0].T                              # (m, blk)
    def gather_v(mi):
      idx = jnp.broadcast_to(vidx_t[mi][None, :], (dsub, blk))
      return jnp.take_along_axis(vcbt_ref[0, mi], idx, axis=1)  # (dsub, blk)
    vrec = jnp.concatenate([gather_v(mi) for mi in range(m)], axis=0)  # (d, blk)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, vrec, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # (g, d)

  @pl.when(j == n_blocks - 1)
  def _finalize():
    l = l_ref[...]
    safe = jnp.maximum(l, 1e-30)
    out_ref[0] = (acc_ref[...] / safe).astype(out_ref.dtype)
    stats_ref[0, 0, :] = m_ref[...][:, 0]
    stats_ref[0, 1, :] = l[:, 0]


@functools.partial(
    jax.jit,
    static_argnames=("scale", "blk", "interpret"),
)
def pq_decode_attention_kernel(
    q: jax.Array,        # (BH, g, d)
    key_codebook: jax.Array,    # (BH, m, K, dsub) f32
    value_codebook_t: jax.Array,  # (BH, m, dsub, K) f32
    key_indices: jax.Array,     # (BH, N, m) int32
    value_indices: jax.Array,   # (BH, N, m) int32
    length: jax.Array,          # (BH,) int32
    scale: float,
    blk: int = 512,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
  """Returns (body_out (BH, g, d) f32, stats (BH, 2, g) f32 = [max, denom])."""
  bhn, g, d = q.shape
  _, m, k_cent, dsub = key_codebook.shape
  n = key_indices.shape[1]
  assert n % blk == 0, f"body capacity {n} must be a multiple of blk={blk}"
  n_blocks = n // blk

  grid = (bhn, n_blocks)
  kernel = functools.partial(
      _pq_decode_kernel, scale=scale, blk=blk, n_blocks=n_blocks)

  out, stats = pl.pallas_call(
      kernel,
      grid_spec=pltpu.PrefetchScalarGridSpec(
          num_scalar_prefetch=1,
          grid=grid,
          in_specs=[
              pl.BlockSpec((1, g, d), lambda bh, j, L: (bh, 0, 0)),
              pl.BlockSpec((1, m, k_cent, dsub), lambda bh, j, L: (bh, 0, 0, 0)),
              pl.BlockSpec((1, m, dsub, k_cent), lambda bh, j, L: (bh, 0, 0, 0)),
              pl.BlockSpec((1, blk, m), lambda bh, j, L: (bh, j, 0)),
              pl.BlockSpec((1, blk, m), lambda bh, j, L: (bh, j, 0)),
          ],
          out_specs=[
              pl.BlockSpec((1, g, d), lambda bh, j, L: (bh, 0, 0)),
              pl.BlockSpec((1, 2, g), lambda bh, j, L: (bh, 0, 0)),
          ],
          scratch_shapes=[
              pltpu.VMEM((g, m, k_cent), jnp.float32),
              pltpu.VMEM((g, d), jnp.float32),
              pltpu.VMEM((g, 1), jnp.float32),
              pltpu.VMEM((g, 1), jnp.float32),
          ],
      ),
      out_shape=[
          jax.ShapeDtypeStruct((bhn, g, d), jnp.float32),
          jax.ShapeDtypeStruct((bhn, 2, g), jnp.float32),
      ],
      compiler_params=_CompilerParams(
          dimension_semantics=("arbitrary", "arbitrary"),
      ),
      interpret=interpret,
      name="pq_decode_attention",
  )(length, q, key_codebook, value_codebook_t, key_indices, value_indices)
  return out, stats
