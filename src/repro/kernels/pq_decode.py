"""Pallas TPU kernel: PQ decode attention on compressed KV (AQPIM Fig. 5 + §III-F).

TPU adaptation of the paper's intra-row indirection: the inner-product table
T[g, j, k] = <q_subvec, C_key[j, k]> is computed once per decode step and *pinned in
VMEM scratch* — the analogue of the paper's "lookup table resides within a single
DRAM row".  Per-token centroid-id lookups are then VMEM-local lane gathers
(jnp.take_along_axis over the K lane axis), never re-touching HBM: every index block
is streamed HBM->VMEM exactly once, like the paper's "one row activation per window".

The value path adapts the paper's bucket-sum: instead of a scatter (TPU-hostile),
each sequence block's value subvectors are gathered *block-locally in VMEM* from the
value codebook (stored (m, dsub, K), gathers along lanes) and contracted against the
attention probabilities on the MXU.  HBM traffic is identical to the paper's scheme
(indices + codebook once); the reconstruction exists only inside VMEM — the paper
avoids it because BankPEs cannot afford the buffer, which VMEM provides for free.

Softmax is fused flash-decoding style: running (max, denom) carried across sequence
blocks in VMEM scratch; the kernel emits the *body segment's* normalized output plus
(max, denom) so the wrapper can exactly combine it with the full-precision sink and
recent segments (paper §IV-A layout).

Two entry points share one block body:

  ``pq_decode_attention_kernel``        dense index buffers (BH, N, m) — the
                                        contiguous-layout serve path and the
                                        kernel-parity oracle target;
  ``pq_decode_attention_paged_kernel``  *block-table-native*: index pages live
                                        in the paged layout's physical pool
                                        (P+1, L, H, block, m) and the sequence
                                        -block grid axis streams block j of
                                        request bh straight from pool block
                                        ``table[bh, j]`` via a scalar-prefetched
                                        per-slot block table (+ a prefetched
                                        layer index, so the pool never gets
                                        sliced or gathered in HBM).  Zero dense
                                        materialization: the only HBM reads are
                                        the mapped blocks themselves.

Grid: (batch*kv_heads, sequence_blocks) — both sequential ("arbitrary") so scratch
accumulators carry across the sequence axis; the batch*head axis revisits scratch
from a clean @pl.when(j == 0) init.

VMEM budget per grid cell (defaults g<=16, m=32, K=512, d=128, blk=512):
  T (g, m, K) f32          <= 1.0 MiB
  codebooks 2 * m*K*dsub   =  0.5 MiB (f32, in + transposed value layout)
  index blocks 2*(blk, m)  =  0.128 MiB int32
  acc/vrec/p blocks        <= 0.6 MiB
  total                    ~  2.3 MiB  << VMEM
(The paged variant streams layout-sized blocks — typically 16 tokens — so its
index-block term is smaller still; everything else is identical.)
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

NEG_INF = -1e30


def _init_scratch(q_ref, kcb_ref, t_ref, acc_ref, m_ref, l_ref, scale):
  """Steps 1-2 (paper): subvector split + inner-product table, once per step."""
  g, d = q_ref.shape[1], q_ref.shape[2]
  m, _, dsub = kcb_ref.shape[1], kcb_ref.shape[2], kcb_ref.shape[3]
  q = q_ref[0].astype(jnp.float32)                    # (g, d)
  qs = q.reshape(g, m, dsub)
  cb = kcb_ref[0].astype(jnp.float32)                 # (m, K, dsub)
  # (g, m, K) = sum_dsub qs[g,m,:] * cb[m,K,:] — MXU contraction per subvector
  t_ref[...] = jax.lax.dot_general(
      qs.transpose(1, 0, 2), cb.transpose(0, 2, 1),
      dimension_numbers=(((2,), (1,)), ((0,), (0,))),
      preferred_element_type=jnp.float32,
  ).transpose(1, 0, 2) * scale                        # (m,g,K)->(g,m,K)
  acc_ref[...] = jnp.zeros((g, d), jnp.float32)
  m_ref[...] = jnp.full((g, 1), NEG_INF, jnp.float32)
  l_ref[...] = jnp.zeros((g, 1), jnp.float32)


def _accumulate_block(kidx, vidx, vcbt_ref, valid,
                      t_ref, acc_ref, m_ref, l_ref):
  """Steps 3-7 (paper) for one sequence block.

  kidx/vidx (blk, m) int32; vcbt_ref (1, m, dsub, K); valid (blk,) bool.
  """
  g = t_ref.shape[0]
  m, dsub, _ = vcbt_ref.shape[1], vcbt_ref.shape[2], vcbt_ref.shape[3]
  blk = kidx.shape[0]

  # Step 3-4 (paper): score lookup from the VMEM-resident table.
  kidx_t = kidx.T                                     # (m, blk) lane-dim gather
  def score_one(gi):
    gath = jnp.take_along_axis(t_ref[gi], kidx_t, axis=1)   # (m, blk)
    return jnp.sum(gath, axis=0)                            # (blk,)
  s = jnp.stack([score_one(gi) for gi in range(g)])         # (g, blk)
  s = jnp.where(valid[None, :], s, NEG_INF)

  # Step 5 (paper): fused online softmax.
  m_prev = m_ref[...]                                 # (g, 1)
  mu = jnp.max(s, axis=-1, keepdims=True)
  m_new = jnp.maximum(m_prev, mu)
  alpha = jnp.exp(m_prev - m_new)                     # (g, 1)
  p = jnp.exp(s - m_new)                              # (g, blk)
  p = jnp.where(valid[None, :], p, 0.0)
  l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
  m_ref[...] = m_new

  # Step 6-7 (paper): block-local VMEM gather of value subvectors + MXU contract.
  vidx_t = vidx.T                                     # (m, blk)
  def gather_v(mi):
    idx = jnp.broadcast_to(vidx_t[mi][None, :], (dsub, blk))
    return jnp.take_along_axis(vcbt_ref[0, mi], idx, axis=1)  # (dsub, blk)
  vrec = jnp.concatenate([gather_v(mi) for mi in range(m)], axis=0)  # (d, blk)
  acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
      p, vrec, dimension_numbers=(((1,), (1,)), ((), ())),
      preferred_element_type=jnp.float32)             # (g, d)


def _finalize(out_ref, stats_ref, acc_ref, m_ref, l_ref):
  l = l_ref[...]
  safe = jnp.maximum(l, 1e-30)
  out_ref[0] = (acc_ref[...] / safe).astype(out_ref.dtype)
  stats_ref[0, 0, :] = m_ref[...][:, 0]
  stats_ref[0, 1, :] = l[:, 0]


def _pq_decode_kernel(
    # scalar prefetch
    length_ref,            # (BH,) int32 in SMEM — valid body tokens per row
    # inputs
    q_ref,                 # (1, g, d)
    kcb_ref,               # (1, m, K, dsub)
    vcbt_ref,              # (1, m, dsub, K)   value codebook, lane-gather layout
    kidx_ref,              # (1, blk, m) int32
    vidx_ref,              # (1, blk, m) int32
    # outputs
    out_ref,               # (1, g, d) f32
    stats_ref,             # (1, 2, g) f32  [0]=running max, [1]=denom
    # scratch
    t_ref,                 # VMEM (g, m, K) f32
    acc_ref,               # VMEM (g, d) f32
    m_ref,                 # VMEM (g, 1) f32
    l_ref,                 # VMEM (g, 1) f32
    *,
    scale: float,
    blk: int,
    n_blocks: int,
):
  bh = pl.program_id(0)
  j = pl.program_id(1)

  @pl.when(j == 0)
  def _init():
    _init_scratch(q_ref, kcb_ref, t_ref, acc_ref, m_ref, l_ref, scale)

  length = length_ref[bh]
  pos = j * blk + jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1)[0]
  valid = pos < length

  @pl.when(j * blk < length)
  def _block():
    _accumulate_block(kidx_ref[0], vidx_ref[0], vcbt_ref, valid,
                      t_ref, acc_ref, m_ref, l_ref)

  @pl.when(j == n_blocks - 1)
  def _done():
    _finalize(out_ref, stats_ref, acc_ref, m_ref, l_ref)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "blk", "interpret"),
)
def pq_decode_attention_kernel(
    q: jax.Array,        # (BH, g, d)
    key_codebook: jax.Array,    # (BH, m, K, dsub) f32
    value_codebook_t: jax.Array,  # (BH, m, dsub, K) f32
    key_indices: jax.Array,     # (BH, N, m) int32
    value_indices: jax.Array,   # (BH, N, m) int32
    length: jax.Array,          # (BH,) int32
    scale: float,
    blk: int = 512,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
  """Returns (body_out (BH, g, d) f32, stats (BH, 2, g) f32 = [max, denom])."""
  bhn, g, d = q.shape
  _, m, k_cent, dsub = key_codebook.shape
  n = key_indices.shape[1]
  assert n % blk == 0, f"body capacity {n} must be a multiple of blk={blk}"
  n_blocks = n // blk

  grid = (bhn, n_blocks)
  kernel = functools.partial(
      _pq_decode_kernel, scale=scale, blk=blk, n_blocks=n_blocks)

  out, stats = pl.pallas_call(
      kernel,
      grid_spec=_compat.scalar_grid_spec(
          num_scalar_prefetch=1,
          grid=grid,
          in_specs=[
              pl.BlockSpec((1, g, d), lambda bh, j, L: (bh, 0, 0)),
              pl.BlockSpec((1, m, k_cent, dsub), lambda bh, j, L: (bh, 0, 0, 0)),
              pl.BlockSpec((1, m, dsub, k_cent), lambda bh, j, L: (bh, 0, 0, 0)),
              pl.BlockSpec((1, blk, m), lambda bh, j, L: (bh, j, 0)),
              pl.BlockSpec((1, blk, m), lambda bh, j, L: (bh, j, 0)),
          ],
          out_specs=[
              pl.BlockSpec((1, g, d), lambda bh, j, L: (bh, 0, 0)),
              pl.BlockSpec((1, 2, g), lambda bh, j, L: (bh, 0, 0)),
          ],
          scratch_shapes=[
              pltpu.VMEM((g, m, k_cent), jnp.float32),
              pltpu.VMEM((g, d), jnp.float32),
              pltpu.VMEM((g, 1), jnp.float32),
              pltpu.VMEM((g, 1), jnp.float32),
          ],
      ),
      out_shape=[
          jax.ShapeDtypeStruct((bhn, g, d), jnp.float32),
          jax.ShapeDtypeStruct((bhn, 2, g), jnp.float32),
      ],
      compiler_params=_compat.compiler_params(
          dimension_semantics=("arbitrary", "arbitrary")),
      interpret=interpret,
      name="pq_decode_attention",
  )(length, q, key_codebook, value_codebook_t, key_indices, value_indices)
  return out, stats


# ---------------------------------------------------------------------------
# Block-table-native variant (paged layout)
# ---------------------------------------------------------------------------

def _pq_decode_paged_kernel(
    # scalar prefetch
    tables_ref,            # (BH, nb) int32 — per-slot block tables
    layer_ref,             # (1,) int32 — which layer's pool plane to read
    length_ref,            # (BH,) int32 — valid body tokens per row
    # inputs
    q_ref,                 # (1, g, d)
    kcb_ref,               # (1, m, K, dsub)
    vcbt_ref,              # (1, m, dsub, K)
    kidx_ref,              # (1, 1, 1, blk, m) — pool block table[bh, j]
    vidx_ref,              # (1, 1, 1, blk, m)
    # outputs
    out_ref,               # (1, g, d) f32
    stats_ref,             # (1, 2, g) f32
    # scratch
    t_ref, acc_ref, m_ref, l_ref,
    *,
    scale: float,
    blk: int,
    n_blocks: int,
):
  bh = pl.program_id(0)
  j = pl.program_id(1)

  @pl.when(j == 0)
  def _init():
    _init_scratch(q_ref, kcb_ref, t_ref, acc_ref, m_ref, l_ref, scale)

  length = length_ref[bh]
  pos = j * blk + jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1)[0]
  valid = pos < length

  @pl.when(j * blk < length)
  def _block():
    # pool index pages store the target-hardware narrow dtype (uint8/int16);
    # widen for the lane gathers only here, inside VMEM
    _accumulate_block(kidx_ref[0, 0, 0].astype(jnp.int32),
                      vidx_ref[0, 0, 0].astype(jnp.int32),
                      vcbt_ref, valid, t_ref, acc_ref, m_ref, l_ref)

  @pl.when(j == n_blocks - 1)
  def _done():
    _finalize(out_ref, stats_ref, acc_ref, m_ref, l_ref)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "interpret"),
)
def pq_decode_attention_paged_kernel(
    q: jax.Array,          # (BH, g, d)
    key_codebook: jax.Array,      # (BH, m, K, dsub) f32
    value_codebook_t: jax.Array,  # (BH, m, dsub, K) f32
    key_index_pool: jax.Array,    # (P+1, L, H, blk, m) narrow int
    value_index_pool: jax.Array,  # (P+1, L, H, blk, m)
    tables: jax.Array,            # (BH, nb) int32 — logical j -> pool block
    layer: jax.Array,             # (1,) int32
    length: jax.Array,            # (BH,) int32 — valid body tokens
    scale: float,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
  """Block-table-native PQ body attention over pooled index pages.

  The sequence-block grid axis reads pool block ``tables[bh, j]`` of layer
  ``layer[0]`` directly via the scalar-prefetched index maps — the physical
  pool is an ordinary pallas_call input, never sliced, gathered, or
  densified in HBM.  Unallocated table entries point at the pool's trash
  block; their rows sit at positions >= ``length`` and are masked like any
  ragged tail.  Returns the same (normalized body out, [max, denom]) contract
  as the dense kernel, for the exact sink/recent segment combine.
  """
  bhn, g, d = q.shape
  _, m, k_cent, dsub = key_codebook.shape
  n_heads = key_index_pool.shape[2]
  blk = key_index_pool.shape[3]
  n_blocks = tables.shape[1]

  grid = (bhn, n_blocks)
  kernel = functools.partial(
      _pq_decode_paged_kernel, scale=scale, blk=blk, n_blocks=n_blocks)

  def pool_spec():
    return pl.BlockSpec(
        (1, 1, 1, blk, m),
        lambda bh, j, tbl, lyr, L: (tbl[bh, j], lyr[0], bh % n_heads, 0, 0))

  out, stats = pl.pallas_call(
      kernel,
      grid_spec=_compat.scalar_grid_spec(
          num_scalar_prefetch=3,
          grid=grid,
          in_specs=[
              pl.BlockSpec((1, g, d), lambda bh, j, tbl, lyr, L: (bh, 0, 0)),
              pl.BlockSpec((1, m, k_cent, dsub),
                           lambda bh, j, tbl, lyr, L: (bh, 0, 0, 0)),
              pl.BlockSpec((1, m, dsub, k_cent),
                           lambda bh, j, tbl, lyr, L: (bh, 0, 0, 0)),
              pool_spec(),
              pool_spec(),
          ],
          out_specs=[
              pl.BlockSpec((1, g, d), lambda bh, j, tbl, lyr, L: (bh, 0, 0)),
              pl.BlockSpec((1, 2, g), lambda bh, j, tbl, lyr, L: (bh, 0, 0)),
          ],
          scratch_shapes=[
              pltpu.VMEM((g, m, k_cent), jnp.float32),
              pltpu.VMEM((g, d), jnp.float32),
              pltpu.VMEM((g, 1), jnp.float32),
              pltpu.VMEM((g, 1), jnp.float32),
          ],
      ),
      out_shape=[
          jax.ShapeDtypeStruct((bhn, g, d), jnp.float32),
          jax.ShapeDtypeStruct((bhn, 2, g), jnp.float32),
      ],
      compiler_params=_compat.compiler_params(
          dimension_semantics=("arbitrary", "arbitrary")),
      interpret=interpret,
      name="pq_decode_attention_paged",
  )(tables, layer, length, q, key_codebook, value_codebook_t,
    key_index_pool, value_index_pool)
  return out, stats
