"""Pure-jnp oracles for every Pallas kernel (allclose targets for tests)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import kmeans as _kmeans
from repro.core import pq_attention as _pqa

NEG_INF = -1e30


def pq_decode_attention_ref(
    q: jax.Array,               # (BH, g, d)
    key_codebook: jax.Array,    # (BH, m, K, dsub)
    value_codebook: jax.Array,  # (BH, m, K, dsub)  (natural layout)
    key_indices: jax.Array,     # (BH, N, m)
    value_indices: jax.Array,   # (BH, N, m)
    length: jax.Array,          # (BH,)
    scale: float,
) -> Tuple[jax.Array, jax.Array]:
  """Oracle for kernels/pq_decode.py: (out (BH,g,d), stats (BH,2,g))."""
  n = key_indices.shape[1]

  def one(qh, kcb, vcb, kix, vix, ln):
    mask = jnp.arange(n) < ln
    table = _pqa.inner_product_table(qh.astype(jnp.float32), kcb)
    s = _pqa.lookup_scores(table, kix) * scale            # (g, N)
    s = jnp.where(mask[None, :], s, NEG_INF)
    mrow = jnp.max(s, axis=-1)                            # (g,)
    p = jnp.exp(s - mrow[:, None])
    p = jnp.where(mask[None, :], p, 0.0)
    denom = jnp.sum(p, axis=-1)
    buckets = _pqa.bucket_accumulate(p, vix, vcb.shape[1])
    out = _pqa.output_from_buckets(buckets, vcb) / jnp.maximum(
        denom, 1e-30)[:, None]
    stats = jnp.stack([mrow, denom])
    return out, stats

  return jax.vmap(one)(q, key_codebook, value_codebook,
                       key_indices, value_indices, length)


def kmeans_assign_ref(x: jax.Array, centroids: jax.Array) -> jax.Array:
  """Oracle for kernels/kmeans_assign.py: (m, N) int32."""
  return jax.vmap(_kmeans.assign_clusters)(x, centroids)


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, scale: float, causal: bool = True,
) -> jax.Array:
  """Oracle for kernels/flash_attention.py: dense causal softmax attention."""
  b, hq, n, d = q.shape
  hkv = k.shape[1]
  g = hq // hkv
  k = jnp.repeat(k, g, axis=1)
  v = jnp.repeat(v, g, axis=1)
  s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                 k.astype(jnp.float32)) * scale
  if causal:
    mask = jnp.tril(jnp.ones((n, n), bool))
    s = jnp.where(mask[None, None], s, NEG_INF)
  p = jax.nn.softmax(s, axis=-1)
  return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def combine_segments_ref(
    outs: list, maxes: list, denoms: list
) -> jax.Array:
  """Flash-decoding combine of per-segment (normalized out, max, denom)."""
  m_all = jnp.max(jnp.stack(maxes), axis=0)
  num = 0.0
  den = 0.0
  for o, mm, l in zip(outs, maxes, denoms):
    w = l * jnp.exp(mm - m_all)
    num = num + o * w[..., None]
    den = den + w
  return num / jnp.maximum(den, 1e-30)[..., None]
