"""Cluster runtime: fault tolerance, straggler mitigation."""
from repro.runtime import fault_tolerance

__all__ = ["fault_tolerance"]
