"""Fault tolerance: checkpoint/restart driver, failure injection, straggler
mitigation policy.

At 1000+ node scale the failure model is: (a) hard node loss -> job restart from
the latest checkpoint on a possibly different device count (elastic); (b) stragglers
-> per-step wall-clock monitoring with a backup-step policy.  Deterministic data
(data/pipeline.py is a pure function of step) + async checkpoints (checkpoint/ckpt)
make restarts exact: no data is replayed or skipped.

`run_with_restarts` is the supervisor loop used by tests and examples: it runs a
step function, injects simulated failures, and proves the restart path end to end
on this single-process container.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.checkpoint import ckpt as ckpt_lib


class SimulatedFailure(RuntimeError):
  """Stands in for a node loss / preemption in tests."""


@dataclasses.dataclass
class FailureInjector:
  """Raise SimulatedFailure at the given steps (once each)."""
  fail_at: Tuple[int, ...] = ()
  _fired: set = dataclasses.field(default_factory=set)

  def check(self, step: int) -> None:
    if step in self.fail_at and step not in self._fired:
      self._fired.add(step)
      raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class FetchFaultInjector:
  """Seeded host-tier fetch faults for the serve engine's retry path.

  The workload harness injects these to prove the engine survives a failed
  spill/fetch transfer: `check_fetch` raises `SimulatedFailure` with
  probability `fail_rate` per attempt, from a private seeded stream —
  deterministic across runs, independent of traffic order (each (rid,
  attempt) pair draws from a stream derived from the base seed, so two runs
  that fetch in different orders still fault the same attempts).  An
  optional `max_failures` bounds total injections so a high rate cannot
  starve a small workload forever.
  """
  fail_rate: float = 0.0
  seed: int = 0
  max_failures: Optional[int] = None
  injected: int = 0

  def check_fetch(self, rid: int, attempt: int = 0) -> None:
    if self.fail_rate <= 0.0:
      return
    if self.max_failures is not None and self.injected >= self.max_failures:
      return
    # integer seed mix (tuple seeding is hash-based and deprecated); the
    # multipliers are primes large enough that (seed, rid, attempt) triples
    # from any realistic run never collide
    key = (self.seed * 1_000_003 + rid) * 1_000_003 + attempt
    draw = random.Random(key).random()
    if draw < self.fail_rate:
      self.injected += 1
      raise SimulatedFailure(
          f"injected fetch fault for request {rid} (attempt {attempt})")


#: Fault-surface registry: --fault-kind CLI key -> FaultPlan rate field.
#: Each surface draws from its own seeded stream (surface index mixed into
#: the key), so enabling one surface never perturbs another's draws.
FAULT_KINDS = {
    "fetch": "fetch_rate",                  # transient spill-fetch failures
    "corrupt-spill": "corrupt_rate",        # host-tier page corruption
    "alloc-exhaustion": "alloc_rate",       # transient device-pool squeeze
    "decode-transient": "decode_rate",      # decode-step soft errors
    # shard surfaces MUST stay appended after the PR 9 four: _SURFACE_IX is
    # insertion-order derived and existing seeded draws may not move
    "shard-loss": "shard_loss_rate",        # mesh shard dies (stops beating)
    "shard-stall": "shard_stall_rate",      # mesh shard straggles one step
}

_SURFACE_IX = {name: i + 1 for i, name in enumerate(FAULT_KINDS)}


@dataclasses.dataclass
class FaultPlan:
  """Seeded multi-surface fault schedule for the serve engine.

  Generalizes `FetchFaultInjector` to six surfaces — spill-fetch
  transfers, host-page corruption, allocator exhaustion spikes, transient
  decode-step failures, and (PR 10) shard loss/stall on the serve mesh —
  each drawing from its own private stream keyed on (seed, surface, a, b).  Draws are *order-independent*:
  two runs that hit the surfaces in different orders fault the same
  (request, attempt) / (step, attempt) pairs, which is what makes the
  fault-matrix token-identity property testable at all.  `max_failures`
  bounds total injections across all surfaces so a high rate cannot wedge
  a small workload forever.
  """
  fetch_rate: float = 0.0
  corrupt_rate: float = 0.0
  alloc_rate: float = 0.0
  decode_rate: float = 0.0
  shard_loss_rate: float = 0.0
  shard_stall_rate: float = 0.0
  alloc_spike_blocks: int = 2
  seed: int = 0
  max_failures: Optional[int] = None
  injected: int = 0
  by_surface: Dict[str, int] = dataclasses.field(
      default_factory=lambda: {k: 0 for k in FAULT_KINDS})

  def _draw(self, surface: str, a: int, b: int) -> float:
    key = ((self.seed * 1_000_003 + _SURFACE_IX[surface]) * 1_000_003
           + a) * 1_000_003 + b
    return random.Random(key).random()

  def _fires(self, surface: str, rate: float, a: int, b: int) -> bool:
    if rate <= 0.0:
      return False
    if self.max_failures is not None and self.injected >= self.max_failures:
      return False
    if self._draw(surface, a, b) < rate:
      self.injected += 1
      self.by_surface[surface] += 1
      return True
    return False

  def check_fetch(self, rid: int, attempt: int = 0) -> None:
    """Engine-compatible with `FetchFaultInjector.check_fetch`."""
    if self._fires("fetch", self.fetch_rate, rid, attempt):
      raise SimulatedFailure(
          f"injected fetch fault for request {rid} (attempt {attempt})")

  def should_corrupt_spill(self, rid: int, attempt: int = 0) -> bool:
    """True when the page just spilled for `rid` should be corrupted."""
    return self._fires("corrupt-spill", self.corrupt_rate, rid, attempt)

  def alloc_spike(self, step: int) -> int:
    """Device blocks transiently unavailable at this step (0 = no spike)."""
    if self._fires("alloc-exhaustion", self.alloc_rate, step, 0):
      return self.alloc_spike_blocks
    return 0

  def check_decode(self, step: int, attempt: int = 0) -> bool:
    """True when this decode attempt should fail (engine retries with
    backoff; attempts index the retry stream so a retry re-draws)."""
    return self._fires("decode-transient", self.decode_rate, step, attempt)

  def shard_loss(self, step: int, n_shards: int = 1) -> Optional[int]:
    """Shard index to mark dead at this step, or None.

    Keyed on the step (b=0 selects the fire draw, b=1 the victim draw) so
    the same mesh steps lose the same shard regardless of traffic order.
    On a 1-shard/unsharded engine the draw still fires — the engine treats
    it as a whole-pool loss and recovers every resident request.
    """
    if not self._fires("shard-loss", self.shard_loss_rate, step, 0):
      return None
    n = max(int(n_shards), 1)
    return min(int(self._draw("shard-loss", step, 1) * n), n - 1)

  def shard_stall(self, step: int, n_shards: int = 1) -> Optional[int]:
    """Shard index that straggles (misses one heartbeat) at this step."""
    if not self._fires("shard-stall", self.shard_stall_rate, step, 0):
      return None
    n = max(int(n_shards), 1)
    return min(int(self._draw("shard-stall", step, 1) * n), n - 1)


def make_fault_plan(kind: str, rate: float, seed: int = 0,
                    max_failures: Optional[int] = None,
                    alloc_spike_blocks: int = 2) -> FaultPlan:
  """Build a single-surface `FaultPlan` from a `--fault-kind` CLI key."""
  if kind not in FAULT_KINDS:
    raise KeyError(f"unknown fault kind {kind!r}; available: "
                   f"{tuple(FAULT_KINDS)}")
  plan = FaultPlan(seed=seed, max_failures=max_failures,
                   alloc_spike_blocks=alloc_spike_blocks)
  setattr(plan, FAULT_KINDS[kind], rate)
  return plan


@dataclasses.dataclass
class StragglerMonitor:
  """Detects slow steps against a rolling median.

  On a synchronous SPMD mesh a straggler stalls everyone; the mitigation at
  cluster scale is (1) flag the slow host for the scheduler, (2) if the stall
  exceeds `timeout_factor` x median, abort the step and restart from the last
  checkpoint without it (elastic down-scale).  Here we implement the detection
  and the decision; the abort path reuses the restart machinery.
  """
  window: int = 20
  timeout_factor: float = 5.0
  history: List[float] = dataclasses.field(default_factory=list)
  flagged: List[int] = dataclasses.field(default_factory=list)

  def record(self, step: int, seconds: float) -> bool:
    """Returns True if this step is a straggler."""
    self.history.append(seconds)
    if len(self.history) > self.window:
      self.history.pop(0)
    med = sorted(self.history)[len(self.history) // 2]
    slow = len(self.history) >= 5 and seconds > self.timeout_factor * med
    if slow:
      self.flagged.append(step)
    return slow


@dataclasses.dataclass
class RestartReport:
  restarts: int
  steps_run: int
  resumed_from: List[int]
  straggler_steps: List[int]


def run_with_restarts(
    total_steps: int,
    ckpt_dir: str,
    ckpt_every: int,
    init_state_fn: Callable[[], Any],
    step_fn: Callable[[Any, int], Any],
    injector: Optional[FailureInjector] = None,
    max_restarts: int = 10,
    state_shardings: Optional[Any] = None,
) -> Tuple[Any, RestartReport]:
  """Supervisor: run `step_fn` to total_steps, surviving injected failures.

  State is an arbitrary pytree; checkpoints every `ckpt_every` steps (async) and
  restores the latest on restart.  Proves: (1) restart resumes the exact step,
  (2) deterministic data makes the trajectory independent of failures.
  """
  checkpointer = ckpt_lib.AsyncCheckpointer()
  monitor = StragglerMonitor()
  restarts = 0
  resumed_from: List[int] = []
  steps_run = 0

  while True:
    # --- (re)initialize ---
    state = init_state_fn()
    start = 0
    latest = ckpt_lib.latest_step(ckpt_dir)
    if latest is not None:
      state, extra = ckpt_lib.restore(ckpt_dir, latest, state,
                                      state_shardings)
      start = int(extra.get("next_step", latest))
      resumed_from.append(start)

    try:
      for step in range(start, total_steps):
        if injector is not None:
          injector.check(step)
        t0 = time.monotonic()
        state = step_fn(state, step)
        monitor.record(step, time.monotonic() - t0)
        steps_run += 1
        if (step + 1) % ckpt_every == 0:
          checkpointer.save_async(ckpt_dir, step + 1, state,
                                  extra={"next_step": step + 1})
      checkpointer.wait()
      ckpt_lib.save(ckpt_dir, total_steps, state,
                    extra={"next_step": total_steps})
      return state, RestartReport(
          restarts=restarts, steps_run=steps_run,
          resumed_from=resumed_from, straggler_steps=monitor.flagged)
    except SimulatedFailure:
      checkpointer.wait()
      restarts += 1
      if restarts > max_restarts:
        raise
