"""Mamba-style selective SSM head (for Hymba's parallel attn+SSM blocks,
arXiv:2411.13676).  State size per channel is `ssm_state` (16 for hymba-1.5b).

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * x_t (x) B_t
    y_t = h_t . C_t + D_skip * x_t

Depthwise causal conv (kernel 4) precedes the scan, as in Mamba.  Train/prefill is
scan-over-chunks with rematerialized inner scans; decode carries (h, conv tail).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common import Array
from repro.models import layers

CONV_K = 4
CHUNK = 64


class SSMState(NamedTuple):
  h: Array         # (B, d_inner, n) f32
  conv: Array      # (B, CONV_K - 1, d_inner) trailing inputs


def ssm_init(key, d_model: int, d_inner: int, n_state: int, dtype) -> dict:
  ks = jax.random.split(key, 7)
  dt_rank = max(d_model // 16, 1)
  return {
      "w_in": layers.dense_init(ks[0], d_model, (2 * d_inner,), dtype),
      "conv_w": (jax.random.normal(ks[1], (CONV_K, d_inner), jnp.float32)
                 * 0.1).astype(dtype),
      "w_bc": layers.dense_init(ks[2], d_inner, (2 * n_state,), dtype),
      "w_dt": layers.dense_init(ks[3], d_inner, (dt_rank,), dtype),
      "w_dt2": layers.dense_init(ks[4], dt_rank, (d_inner,), dtype),
      "dt_bias": jnp.zeros((d_inner,), jnp.float32),
      "a_log": jnp.log(jnp.tile(
          jnp.arange(1, n_state + 1, dtype=jnp.float32)[None, :],
          (d_inner, 1))),
      "d_skip": jnp.ones((d_inner,), jnp.float32),
      "w_out": layers.dense_init(ks[5], d_inner, (d_model,), dtype),
  }


def _causal_conv(x: Array, w: Array, tail: Array) -> Tuple[Array, Array]:
  """Depthwise causal conv, kernel CONV_K.  x (B, S, C), tail (B, K-1, C)."""
  xx = jnp.concatenate([tail.astype(x.dtype), x], axis=1)     # (B, S+K-1, C)
  out = sum(
      xx[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(CONV_K))
  new_tail = xx[:, -(CONV_K - 1):]
  return out, new_tail


def _ssm_inputs(params: dict, x: Array, conv_tail: Array):
  """x (B, S, D) -> gates and scan inputs."""
  xz = x @ params["w_in"]
  x_p, z = jnp.split(xz, 2, axis=-1)                   # (B, S, d_inner)
  x_c, new_tail = _causal_conv(x_p, params["conv_w"], conv_tail)
  x_c = jax.nn.silu(x_c)
  bc = x_c @ params["w_bc"]
  b_ssm, c_ssm = jnp.split(bc, 2, axis=-1)             # (B, S, n)
  dt = jax.nn.softplus(
      (x_c @ params["w_dt"]) @ params["w_dt2"]
      + params["dt_bias"].astype(x.dtype))             # (B, S, d_inner)
  return x_c, z, b_ssm, c_ssm, dt, new_tail


def _scan_chunked(params, x_c, b_ssm, c_ssm, dt, h0, chunk=CHUNK):
  b, s, d_inner = x_c.shape
  n = b_ssm.shape[-1]
  a = -jnp.exp(params["a_log"])                         # (d_inner, n)

  pad = (-s) % chunk
  n_chunks = (s + pad) // chunk
  def to_chunks(t):
    t = jnp.pad(t.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    return jnp.moveaxis(t.reshape(b, n_chunks, chunk, t.shape[-1]), 0, 2)
  xc, bs, cs, dts = (to_chunks(t) for t in (x_c, b_ssm, c_ssm, dt))
  if pad:
    valid = (jnp.arange(n_chunks * chunk) < s).reshape(n_chunks, chunk)
    dts = jnp.where(valid[:, :, None, None], dts, 0.0)  # dt=0: h unchanged

  @jax.checkpoint
  def chunk_body(h, inp):
    xx, bb, cc, dd = inp
    def step(h_c, inp_s):
      x_t, b_t, c_t, dt_t = inp_s
      da = jnp.exp(dt_t[..., None] * a[None])           # (B, d_inner, n)
      h_new = da * h_c + (dt_t * x_t)[..., None] * b_t[:, None, :]
      y = jnp.einsum("bdn,bn->bd", h_new, c_t)
      return h_new, y
    h_out, ys = jax.lax.scan(step, h, (xx, bb, cc, dd))
    return h_out, ys

  h_final, ys = jax.lax.scan(chunk_body, h0.astype(jnp.float32),
                             (xc, bs, cs, dts))
  y = jnp.moveaxis(ys, 2, 0).reshape(b, n_chunks * chunk, d_inner)[:, :s]
  return y, h_final


def ssm_forward(params: dict, x: Array, state: SSMState
                ) -> Tuple[Array, SSMState]:
  """Full-sequence selective SSM: (B, S, D) -> (B, S, D)."""
  x_c, z, b_ssm, c_ssm, dt, new_tail = _ssm_inputs(params, x, state.conv)
  y, h_final = _scan_chunked(params, x_c, b_ssm, c_ssm, dt, state.h)
  y = y + params["d_skip"].astype(jnp.float32) * x_c.astype(jnp.float32)
  y = (y.astype(x.dtype) * jax.nn.silu(z))
  out = y @ params["w_out"]
  return out, SSMState(h=h_final, conv=new_tail)


def ssm_step(params: dict, x: Array, state: SSMState) -> Tuple[Array, SSMState]:
  """Single-token decode: x (B, D)."""
  x_c, z, b_ssm, c_ssm, dt, new_tail = _ssm_inputs(
      params, x[:, None, :], state.conv)
  a = -jnp.exp(params["a_log"])
  x32 = x_c[:, 0].astype(jnp.float32)
  dt32 = dt[:, 0].astype(jnp.float32)
  b32 = b_ssm[:, 0].astype(jnp.float32)
  c32 = c_ssm[:, 0].astype(jnp.float32)
  da = jnp.exp(dt32[..., None] * a[None])
  h_new = da * state.h + (dt32 * x32)[..., None] * b32[:, None, :]
  y = jnp.einsum("bdn,bn->bd", h_new, c32)
  y = y + params["d_skip"] * x32
  y = y.astype(x.dtype) * jax.nn.silu(z[:, 0])
  out = y @ params["w_out"]
  return out, SSMState(h=h_new, conv=new_tail)


def init_state(b: int, d_inner: int, n_state: int, dtype=jnp.bfloat16
               ) -> SSMState:
  return SSMState(
      h=jnp.zeros((b, d_inner, n_state), jnp.float32),
      conv=jnp.zeros((b, CONV_K - 1, d_inner), dtype),
  )
