"""Composable transformer layers: norms, RoPE, chunked (flash-style) attention,
SwiGLU MLP.  Everything is functional: `init_*` builds param dicts, `apply`-style
functions consume them.  Compute dtype is the config dtype (bf16 by default) with
f32 for softmax/norm statistics.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common import Array

NEG_INF = -1e30


def activation_constraint(x: Array) -> Array:
  """Best-effort sequence-over-model sharding of the (B, S, D) residual stream
  (Megatron-SP style): bounds remat-saved activation memory at 405B scale.
  No-op outside a mesh context (eager tests) or on unsuitable shapes."""
  try:
    from jax.sharding import PartitionSpec as _P
    if x.ndim == 3 and x.shape[1] % 16 == 0:
      return jax.lax.with_sharding_constraint(
          x, _P(None, "model", None))
    return x
  except Exception:   # noqa: BLE001 — no mesh / axis absent: leave unsharded
    return x


# ---------------------------------------------------------------------------
# int8 weight storage (beyond-paper serving optimization, §Perf cell C)
#
# Weights live in HBM as int8 + per-output-channel f32 scale; dequantization
# happens in-registers at use (XLA fuses `q.astype(bf16) * scale` into the
# consuming dot).  Halves the parameter term of the decode memory roofline.
# ---------------------------------------------------------------------------

def quantize_weight(w: Array, contract_axes) -> dict:
  """Symmetric per-output-channel int8 quantization."""
  w32 = w.astype(jnp.float32)
  amax = jnp.max(jnp.abs(w32), axis=contract_axes, keepdims=True)
  scale = jnp.maximum(amax, 1e-12) / 127.0
  q = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
  return {"q": q, "scale": scale.astype(jnp.float32)}


def wv(w, dtype=jnp.bfloat16) -> Array:
  """Weight view: dequantize int8-stored weights, pass plain arrays through."""
  if isinstance(w, dict) and "q" in w:
    return (w["q"].astype(jnp.float32) * w["scale"]).astype(dtype)
  return w


def embed_lookup(embed, tokens: Array) -> Array:
  """Embedding gather that dequantizes only the gathered rows."""
  if isinstance(embed, dict) and "q" in embed:
    rows = embed["q"][tokens].astype(jnp.float32)
    return (rows * embed["scale"][tokens]).astype(jnp.bfloat16)
  return embed[tokens]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_shape, dtype) -> Array:
  """Truncated-normal fan-in init."""
  shape = (in_dim,) + tuple(out_shape)
  scale = 1.0 / jnp.sqrt(in_dim)
  return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
          * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> Array:
  return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype) -> dict:
  return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: dict, x: Array, eps: float = 1e-5) -> Array:
  x32 = x.astype(jnp.float32)
  var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
  out = x32 * jax.lax.rsqrt(var + eps)
  return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
  half = head_dim // 2
  return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
  """x (..., S, H, hd), positions (..., S) or (S,)."""
  hd = x.shape[-1]
  freqs = rope_freqs(hd, theta)                        # (hd/2,)
  angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
  cos = jnp.cos(angles)[..., None, :]                  # (..., S, 1, hd/2)
  sin = jnp.sin(angles)[..., None, :]
  x32 = x.astype(jnp.float32)
  x1, x2 = jnp.split(x32, 2, axis=-1)
  out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
  return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked causal attention (pure-JAX flash) — differentiable, O(blk^2) memory
# ---------------------------------------------------------------------------

def chunked_attention(
    q: Array,            # (B, Hq, S, d)
    k: Array,            # (B, Hkv, S, d)
    v: Array,            # (B, Hkv, S, d)
    scale: float,
    causal: bool = True,
    blk_q: int = 512,
    blk_k: int = 512,
    q_offset: Any = 0,
) -> Array:
  """Blockwise online-softmax attention; the lowered-HLO twin of the Pallas kernel.

  Structured as scan(q blocks) x scan(kv blocks) so XLA never materializes the
  (S, S) score matrix — essential for the 32k prefill and 4k x 256 train shapes.
  GQA via reshaping q to (B, Hkv, g, S, d).

  `q_offset` (int or traced scalar) is the absolute position of q row 0 when
  the query rows are a *suffix chunk* of a longer cached context (prefix-
  sharing suffix-only prefill): the causal mask compares key positions
  against `q_offset + row`.  Per-row numerics are invariant to the q extent
  and blocking, so a chunk's rows match a full-sequence call bit for bit.
  """
  b, hq, sq, d = q.shape
  hkv, sk = k.shape[1], k.shape[2]
  g = hq // hkv
  blk_q = min(blk_q, sq)
  blk_k = min(blk_k, sk)
  sq_real, sk_real = sq, sk
  pad_q = (-sq) % blk_q
  pad_k = (-sk) % blk_k
  if pad_q:
    q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    sq += pad_q
  if pad_k:
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sk += pad_k
  nq, nk = sq // blk_q, sk // blk_k

  qg = q.reshape(b, hkv, g, sq, d)
  q_blocks = qg.reshape(b, hkv, g, nq, blk_q, d)
  k_blocks = k.reshape(b, hkv, nk, blk_k, d)
  v_blocks = v.reshape(b, hkv, nk, blk_k, d)

  def q_block_body(qi, q_blk):
    # q_blk (b, hkv, g, blk_q, d)
    def kv_body(carry, inputs):
      acc, m_i, l_i = carry
      kj, k_blk, v_blk = inputs
      s_blk = jnp.einsum(
          "bhgqd,bhkd->bhgqk", q_blk.astype(jnp.float32),
          k_blk.astype(jnp.float32)) * scale
      kpos = kj * blk_k + jnp.arange(blk_k)
      if causal:
        qpos = q_offset + qi * blk_q + jnp.arange(blk_q)
        mask = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] < sk_real)
        s_blk = jnp.where(mask[None, None, None], s_blk, NEG_INF)
      elif pad_k:
        s_blk = jnp.where((kpos < sk_real)[None, None, None, None],
                          s_blk, NEG_INF)
      mu = jnp.max(s_blk, axis=-1)
      m_new = jnp.maximum(m_i, mu)
      alpha = jnp.exp(m_i - m_new)
      p = jnp.exp(s_blk - m_new[..., None])
      l_new = alpha * l_i + jnp.sum(p, axis=-1)
      acc = alpha[..., None] * acc + jnp.einsum(
          "bhgqk,bhkd->bhgqd", p, v_blk.astype(jnp.float32))
      return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, hkv, g, blk_q, d), jnp.float32)
    m0 = jnp.full((b, hkv, g, blk_q), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, blk_q), jnp.float32)
    kjs = jnp.arange(nk)
    (acc, m_i, l_i), _ = jax.lax.scan(
        kv_body, (acc0, m0, l0),
        (kjs, jnp.moveaxis(k_blocks, 2, 0), jnp.moveaxis(v_blocks, 2, 0)))
    return acc / jnp.maximum(l_i, 1e-30)[..., None]

  outs = jax.lax.map(
      lambda args: q_block_body(*args),
      (jnp.arange(nq), jnp.moveaxis(q_blocks, 3, 0)))
  # outs (nq, b, hkv, g, blk_q, d) -> (b, hq, sq_real, d)
  out = jnp.moveaxis(outs, 0, 3).reshape(b, hkv, g, sq, d)
  return out.reshape(b, hq, sq, d)[:, :, :sq_real].astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def attention_init(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype) -> dict:
  ks = jax.random.split(key, 4)
  return {
      "wq": dense_init(ks[0], d_model, (n_heads, head_dim), dtype),
      "wk": dense_init(ks[1], d_model, (n_kv_heads, head_dim), dtype),
      "wv": dense_init(ks[2], d_model, (n_kv_heads, head_dim), dtype),
      "wo": dense_init(ks[3], n_heads * head_dim, (d_model,), dtype).reshape(
          n_heads, head_dim, d_model),
  }


def attention_qkv(params: dict, x: Array, positions: Array,
                  rope_theta: float) -> Tuple[Array, Array, Array]:
  """x (B, S, D) -> q (B, H, S, hd), k/v (B, Hkv, S, hd), RoPE applied."""
  q = jnp.einsum("bsd,dhk->bshk", x, wv(params["wq"], x.dtype))
  k = jnp.einsum("bsd,dhk->bshk", x, wv(params["wk"], x.dtype))
  v = jnp.einsum("bsd,dhk->bshk", x, wv(params["wv"], x.dtype))
  q = apply_rope(q, positions, rope_theta)
  k = apply_rope(k, positions, rope_theta)
  return (jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2))


def attention_out(params: dict, attn: Array) -> Array:
  """attn (B, H, S, hd) -> (B, S, D)."""
  return jnp.einsum("bhsk,hkd->bsd", attn, wv(params["wo"], attn.dtype))


def self_attention(params: dict, x: Array, positions: Array, scale: float,
                   rope_theta: float, blk: int = 512) -> Array:
  from repro.models import flash
  q, k, v = attention_qkv(params, x, positions, rope_theta)
  s = q.shape[2]
  if s % min(blk, s) == 0:
    # flash path with the memory-correct custom VJP (O(S) residuals)
    attn = flash.flash_attention(q, k, v, scale, True, min(blk, s))
  else:
    attn = chunked_attention(q, k, v, scale, causal=True, blk_q=blk, blk_k=blk)
  return attention_out(params, attn)


def cross_attention_init(key, d_model: int, n_heads: int, n_kv_heads: int,
                         head_dim: int, dtype) -> dict:
  p = attention_init(key, d_model, n_heads, n_kv_heads, head_dim, dtype)
  p["q_norm"] = rmsnorm_init(head_dim, dtype)
  p["k_norm"] = rmsnorm_init(head_dim, dtype)
  return p


def cross_attention(params: dict, x: Array, kv_src: Array, scale: float,
                    blk: int = 512) -> Array:
  """x (B, S, D) attends to kv_src (B, T, D) (no causality, no RoPE —
  llama-3.2-vision style with q/k norms)."""
  q = jnp.einsum("bsd,dhk->bshk", x, wv(params["wq"], x.dtype))
  k = jnp.einsum("btd,dhk->bthk", kv_src, wv(params["wk"], x.dtype))
  v = jnp.einsum("btd,dhk->bthk", kv_src, wv(params["wv"], x.dtype))
  q = rmsnorm(params["q_norm"], q)
  k = rmsnorm(params["k_norm"], k)
  q, k, v = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
  attn = chunked_attention(q, k, v, scale, causal=False,
                           blk_q=min(blk, q.shape[2]), blk_k=min(blk, k.shape[2]))
  return attention_out(params, attn)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype) -> dict:
  ks = jax.random.split(key, 3)
  return {
      "w_gate": dense_init(ks[0], d_model, (d_ff,), dtype),
      "w_up": dense_init(ks[1], d_model, (d_ff,), dtype),
      "w_down": dense_init(ks[2], d_ff, (d_model,), dtype),
  }


def mlp(params: dict, x: Array) -> Array:
  gate = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, wv(params["w_gate"], x.dtype)))
  up = jnp.einsum("bsd,df->bsf", x, wv(params["w_up"], x.dtype))
  return jnp.einsum("bsf,fd->bsd", gate * up, wv(params["w_down"], x.dtype))
