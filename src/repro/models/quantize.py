"""int8 weight-storage conversion (beyond-paper serving optimization).

Walks a parameter tree and replaces every matmul weight with
{"q": int8, "scale": f32 per-output-channel}.  The decode memory roofline is
parameter-read dominated at small batch; int8 storage halves that term vs bf16
(EXPERIMENTS.md §Perf cell C).  Consumers dequantize through layers.wv /
layers.embed_lookup — XLA fuses the dequant into the dot.

Path -> contract-axes rules (negative axes: leaves carry stacked layer dims):
  attn|cross / wq|wk|wv : (.., D, H, hd)  contract -3
  attn|cross / wo       : (.., H, hd, D)  contract (-3, -2)
  mlp|shared / w_gate|w_up : (.., D, F)   contract -2
  mlp|shared / w_down      : (.., F, D)   contract -2
  moe / w_*             : (.., E, D, F) / (.., E, F, D)  contract -2
  lm_head               : (D, V)          contract -2 (=0)
  embed                 : (V, D)          contract -1 (per-row)
RWKV/SSM weights are left in bf16 (recurrent numerics are more sensitive; the
families are small — documented in DESIGN.md).
"""
from __future__ import annotations

import re
from typing import Any

import jax

from repro.models import layers

_RULES = (
    (re.compile(r"(attn|cross)/w[qkv]$"), (-3,)),
    (re.compile(r"(attn|cross)/wo$"), (-3, -2)),
    (re.compile(r"(mlp|shared|cross_mlp)/w_(gate|up|down)$"), (-2,)),
    (re.compile(r"moe/w_(gate|up|down)$"), (-2,)),
    (re.compile(r"^lm_head$"), (-2,)),
    (re.compile(r"^embed$"), (-1,)),
)


def _path_str(path) -> str:
  return "/".join(
      str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def quantize_params(params: Any) -> Any:
  """Return a new tree with int8-stored matmul weights."""
  def rule(path, leaf):
    s = _path_str(path)
    for pat, axes in _RULES:
      if pat.search(s):
        return layers.quantize_weight(leaf, axes)
    return leaf
  return jax.tree_util.tree_map_with_path(rule, params)
