"""Flash attention with a memory-correct custom VJP (pure JAX).

Differentiating a scan-based online-softmax forward makes JAX save the (blk_q,
blk_k) probability tiles of EVERY block pair — O(S^2) residual memory, the exact
thing flash attention exists to avoid (observed: ~400 GiB/device temp at 405B
train_4k).  This module implements the FlashAttention-2 backward: residuals are
only (q, k, v, out, lse) — O(S) — and the probability tiles are *recomputed*
blockwise in the backward pass.

  D_i  = rowsum(dout * out)
  p    = exp(q k^T * scale - lse)
  dv  += p^T dout
  dp   = dout v^T
  ds   = p * (dp - D_i) * scale
  dq  += ds k ;  dk += ds^T q

Shapes: q (B, Hq, S, d); k/v (B, Hkv, S, d) with GQA group g = Hq/Hkv.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common import Array

NEG_INF = -1e30


def _fwd_impl(q, k, v, scale, causal, blk):
  """Blockwise forward returning (out, lse)."""
  b, hq, s, d = q.shape
  hkv = k.shape[1]
  g = hq // hkv
  blk = min(blk, s)
  assert s % blk == 0
  n = s // blk
  qg = q.reshape(b, hkv, g, n, blk, d)
  kb = jnp.moveaxis(k.reshape(b, hkv, n, blk, d), 2, 0)
  vb = jnp.moveaxis(v.reshape(b, hkv, n, blk, d), 2, 0)

  def q_block(qi, q_blk):
    def kv_body(carry, inp):
      acc, m_i, l_i = carry
      kj, k_blk, v_blk = inp
      s_blk = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk.astype(jnp.float32),
                         k_blk.astype(jnp.float32)) * scale
      if causal:
        qpos = qi * blk + jnp.arange(blk)
        kpos = kj * blk + jnp.arange(blk)
        s_blk = jnp.where((kpos[None] <= qpos[:, None])[None, None, None],
                          s_blk, NEG_INF)
      mu = jnp.max(s_blk, -1)
      m_new = jnp.maximum(m_i, mu)
      alpha = jnp.exp(m_i - m_new)
      p = jnp.exp(s_blk - m_new[..., None])
      l_new = alpha * l_i + jnp.sum(p, -1)
      acc = alpha[..., None] * acc + jnp.einsum(
          "bhgqk,bhkd->bhgqd", p, v_blk.astype(jnp.float32))
      return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, hkv, g, blk, d), jnp.float32)
    m0 = jnp.full((b, hkv, g, blk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, blk), jnp.float32)
    (acc, m_i, l_i), _ = jax.lax.scan(
        kv_body, (acc0, m0, l0), (jnp.arange(n), kb, vb))
    out = acc / jnp.maximum(l_i, 1e-30)[..., None]
    lse = m_i + jnp.log(jnp.maximum(l_i, 1e-30))
    return out, lse

  outs, lses = jax.lax.map(
      lambda a: q_block(*a), (jnp.arange(n), jnp.moveaxis(qg, 3, 0)))
  out = jnp.moveaxis(outs, 0, 3).reshape(b, hq, s, d)
  lse = jnp.moveaxis(lses, 0, 3).reshape(b, hq, s)
  return out.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q: Array, k: Array, v: Array, scale: float,
                    causal: bool = True, blk: int = 512) -> Array:
  out, _ = _fwd_impl(q, k, v, scale, causal, blk)
  return out


def _fwd(q, k, v, scale, causal, blk):
  out, lse = _fwd_impl(q, k, v, scale, causal, blk)
  return out, (q, k, v, out, lse)


def _bwd(scale, causal, blk, res, dout):
  q, k, v, out, lse = res
  b, hq, s, d = q.shape
  hkv = k.shape[1]
  g = hq // hkv
  blk = min(blk, s)
  n = s // blk

  q32 = q.reshape(b, hkv, g, n, blk, d).astype(jnp.float32)
  do32 = dout.reshape(b, hkv, g, n, blk, d).astype(jnp.float32)
  o32 = out.reshape(b, hkv, g, n, blk, d).astype(jnp.float32)
  lse_b = lse.reshape(b, hkv, g, n, blk)
  kb = k.reshape(b, hkv, n, blk, d).astype(jnp.float32)
  vb = v.reshape(b, hkv, n, blk, d).astype(jnp.float32)
  delta = jnp.sum(do32 * o32, -1)                       # (b,hkv,g,n,blk)

  def kv_body(dq_acc, inp):
    kj, k_blk, v_blk = inp

    def q_body(carry, inp_q):
      dk_j, dv_j = carry
      qi, q_blk, do_blk, lse_blk, delta_blk = inp_q
      s_blk = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk, k_blk) * scale
      if causal:
        qpos = qi * blk + jnp.arange(blk)
        kpos = kj * blk + jnp.arange(blk)
        mask = (kpos[None] <= qpos[:, None])[None, None, None]
        s_blk = jnp.where(mask, s_blk, NEG_INF)
      p = jnp.exp(s_blk - lse_blk[..., None])           # recomputed tile
      dv_j = dv_j + jnp.einsum("bhgqk,bhgqd->bhkd", p, do_blk)
      dp = jnp.einsum("bhgqd,bhkd->bhgqk", do_blk, v_blk)
      ds = p * (dp - delta_blk[..., None]) * scale
      dq_i = jnp.einsum("bhgqk,bhkd->bhgqd", ds, k_blk)
      dk_j = dk_j + jnp.einsum("bhgqk,bhgqd->bhkd", ds, q_blk)
      return (dk_j, dv_j), dq_i

    zeros_kv = jnp.zeros((b, hkv, blk, d), jnp.float32)
    (dk_j, dv_j), dq_blocks = jax.lax.scan(
        q_body, (zeros_kv, zeros_kv),
        (jnp.arange(n), jnp.moveaxis(q32, 3, 0), jnp.moveaxis(do32, 3, 0),
         jnp.moveaxis(lse_b, 3, 0), jnp.moveaxis(delta, 3, 0)))
    dq_acc = dq_acc + jnp.moveaxis(dq_blocks, 0, 3)     # (b,hkv,g,n,blk,d)
    return dq_acc, (dk_j, dv_j)

  dq0 = jnp.zeros((b, hkv, g, n, blk, d), jnp.float32)
  dq, (dks, dvs) = jax.lax.scan(
      kv_body, dq0, (jnp.arange(n), jnp.moveaxis(kb, 2, 0),
                     jnp.moveaxis(vb, 2, 0)))
  dk = jnp.moveaxis(dks, 0, 2).reshape(b, hkv, s, d)
  dv = jnp.moveaxis(dvs, 0, 2).reshape(b, hkv, s, d)
  return (dq.reshape(b, hq, s, d).astype(q.dtype),
          dk.astype(k.dtype), dv.astype(v.dtype))


flash_attention.defvjp(_fwd, _bwd)
