"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time-mix with
data-dependent decay, plus channel-mix FFN.

Time-mix recurrence per head (state S in R^{hd x hd}):

    y_t = r_t . (S_{t-1} + (u * k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

with per-channel decay w_t = exp(-exp(w0 + lora_w(xx_t)))  (data-dependent — the
"Finch" feature) and token-shift ddlerp mixing for r/k/v/w/g.

Training/prefill uses scan-over-chunks with inner rematerialized scans (bounded
backward memory: chunk-boundary states only).  Decode carries (S, x_prev) in the
cache — O(1) per token, which is why this arch runs the `long_500k` shape natively.

AQPIM applicability: there is no KV cache to compress (DESIGN.md §5) — the paper's
technique is inapplicable and this arch runs without it.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common import Array
from repro.models import layers

LORA_RANK = 32
CHUNK = 64


class RWKVState(NamedTuple):
  s: Array           # (B, H, hd, hd) wkv state
  x_prev_att: Array  # (B, D) last input to time-mix
  x_prev_ffn: Array  # (B, D) last input to channel-mix


def time_mix_init(key, d_model: int, n_heads: int, head_dim: int, dtype) -> dict:
  ks = jax.random.split(key, 14)
  d = d_model
  def lora(k_, r=LORA_RANK):
    k1, k2 = jax.random.split(k_)
    return {"a": layers.dense_init(k1, d, (r,), dtype),
            "b": layers.dense_init(k2, r, (d,), dtype)}
  return {
      "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(dtype),
      "lora_r": lora(ks[1]), "lora_k": lora(ks[2]), "lora_v": lora(ks[3]),
      "lora_w": lora(ks[4], 64), "lora_g": lora(ks[5]),
      "w0": (jax.random.normal(ks[6], (d,), jnp.float32) * 0.1 - 0.6).astype(
          jnp.float32),
      "u": (jax.random.normal(ks[7], (n_heads, head_dim), jnp.float32) * 0.1
            ).astype(jnp.float32),
      "wr": layers.dense_init(ks[8], d, (d,), dtype),
      "wk": layers.dense_init(ks[9], d, (d,), dtype),
      "wv": layers.dense_init(ks[10], d, (d,), dtype),
      "wg": layers.dense_init(ks[11], d, (d,), dtype),
      "wo": layers.dense_init(ks[12], d, (d,), dtype),
      "ln_x": {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
  }


def channel_mix_init(key, d_model: int, d_ff: int, dtype) -> dict:
  ks = jax.random.split(key, 3)
  return {
      "mu": jax.random.uniform(ks[0], (2, d_model), jnp.float32).astype(dtype),
      "wk": layers.dense_init(ks[1], d_model, (d_ff,), dtype),
      "wv": layers.dense_init(ks[2], d_ff, (d_model,), dtype),
      "wr": layers.dense_init(jax.random.fold_in(ks[0], 7), d_model,
                              (d_model,), dtype),
  }


def _ddlerp(x: Array, x_prev: Array, mu: Array, lora: dict) -> Array:
  """Data-dependent lerp: x + (x_prev - x) * (mu + tanh(xx A) B)."""
  xx = x + (x_prev - x) * mu.astype(x.dtype)
  dd = jnp.tanh(xx @ lora["a"]) @ lora["b"]
  return x + (x_prev - x) * (mu.astype(x.dtype) + dd)


def _group_norm(p: dict, x: Array, n_heads: int) -> Array:
  """Per-head group norm on (B, S, D)."""
  b, s, d = x.shape
  xh = x.reshape(b, s, n_heads, d // n_heads).astype(jnp.float32)
  mean = jnp.mean(xh, axis=-1, keepdims=True)
  var = jnp.var(xh, axis=-1, keepdims=True)
  xh = (xh - mean) * jax.lax.rsqrt(var + 64e-5)
  xf = xh.reshape(b, s, d)
  return (xf * p["scale"].astype(jnp.float32)
          + p["bias"].astype(jnp.float32)).astype(x.dtype)


def _time_mix_inputs(params: dict, x: Array, x_prev: Array, n_heads: int):
  """Project r/k/v/w/g for a (B, S, D) block given the shifted inputs."""
  b, s, d = x.shape
  hd = d // n_heads
  mu = params["mu"]
  xr = _ddlerp(x, x_prev, mu[0], params["lora_r"])
  xk = _ddlerp(x, x_prev, mu[1], params["lora_k"])
  xv = _ddlerp(x, x_prev, mu[2], params["lora_v"])
  xw = _ddlerp(x, x_prev, mu[3], params["lora_w"])
  xg = _ddlerp(x, x_prev, mu[4], params["lora_g"])
  r = (xr @ params["wr"]).reshape(b, s, n_heads, hd)
  k = (xk @ params["wk"]).reshape(b, s, n_heads, hd)
  v = (xv @ params["wv"]).reshape(b, s, n_heads, hd)
  g = jax.nn.silu(xg @ params["wg"])
  logw = -jnp.exp(jnp.clip(
      params["w0"].astype(jnp.float32)
      + (jnp.tanh(xw @ params["lora_w"]["a"]) @ params["lora_w"]["b"]
         ).astype(jnp.float32), -8.0, 4.0))
  w = jnp.exp(logw).reshape(b, s, n_heads, hd)          # decay in (0, 1)
  return r, k, v, w, g


def _wkv_scan(r, k, v, w, u, s0):
  """Sequential wkv recurrence over a chunk.

  r/k/v/w: (C, B, H, hd) f32; u: (H, hd); s0: (B, H, hd, hd).
  Returns (y (C, B, H, hd), s_final).
  """
  def step(s, inp):
    r_t, k_t, v_t, w_t = inp
    kv = jnp.einsum("bhi,bhj->bhij", k_t, v_t)
    y = jnp.einsum("bhi,bhij->bhj", r_t, s + u[None, :, :, None] * kv)
    s_new = w_t[..., None] * s + kv
    return s_new, y
  return jax.lax.scan(step, s0, (r, k, v, w), unroll=1)


def time_mix(params: dict, x: Array, state: RWKVState, n_heads: int,
             chunk: int = CHUNK) -> Tuple[Array, RWKVState]:
  """Full-sequence time-mix: (B, S, D) -> (B, S, D), new state."""
  b, s, d = x.shape
  hd = d // n_heads
  x_shift = jnp.concatenate([state.x_prev_att[:, None, :], x[:, :-1]], axis=1)
  r, k, v, w, g = _time_mix_inputs(params, x, x_shift, n_heads)
  r32, k32, v32, w32 = (t.astype(jnp.float32) for t in (r, k, v, w))
  u = params["u"].astype(jnp.float32)

  pad = (-s) % chunk
  def pad_t(t):
    return jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
  n_chunks = (s + pad) // chunk
  # (nC, C, B, H, hd)
  def to_chunks(t):
    return jnp.moveaxis(
        pad_t(t).reshape(b, n_chunks, chunk, n_heads, hd), 0, 2)
  rc, kc, vc, wc = (to_chunks(t) for t in (r32, k32, v32, w32))
  # padding must not alter the state: decay 1, k 0
  if pad:
    valid = (jnp.arange(n_chunks * chunk) < s).reshape(n_chunks, chunk)
    wc = jnp.where(valid[:, :, None, None, None], wc, 1.0)
    kc = jnp.where(valid[:, :, None, None, None], kc, 0.0)

  @jax.checkpoint
  def chunk_body(s_carry, inp):
    rr, kk, vv, ww = inp
    s_new, y = _wkv_scan(rr, kk, vv, ww, u, s_carry)
    return s_new, y

  s_final, ys = jax.lax.scan(chunk_body, state.s.astype(jnp.float32),
                             (rc, kc, vc, wc))
  y = jnp.moveaxis(ys, 2, 0).reshape(b, n_chunks * chunk, d)[:, :s]
  y = _group_norm(params["ln_x"], y.astype(x.dtype), n_heads)
  out = (y * g) @ params["wo"]
  new_state = RWKVState(
      s=s_final, x_prev_att=x[:, -1], x_prev_ffn=state.x_prev_ffn)
  return out, new_state


def time_mix_step(params: dict, x: Array, state: RWKVState, n_heads: int
                  ) -> Tuple[Array, RWKVState]:
  """Single-token decode: x (B, D) -> (B, D).  O(1) state update."""
  b, d = x.shape
  hd = d // n_heads
  x_in = x[:, None, :]
  x_prev = state.x_prev_att[:, None, :]
  r, k, v, w, g = _time_mix_inputs(params, x_in, x_prev, n_heads)
  r32, k32, v32, w32 = (t[:, 0].astype(jnp.float32) for t in (r, k, v, w))
  u = params["u"].astype(jnp.float32)
  s = state.s.astype(jnp.float32)
  kv = jnp.einsum("bhi,bhj->bhij", k32, v32)
  y = jnp.einsum("bhi,bhij->bhj", r32, s + u[None, :, :, None] * kv)
  s_new = w32[..., None] * s + kv
  y = _group_norm(params["ln_x"], y.reshape(b, 1, d).astype(x.dtype), n_heads)
  out = (y[:, 0] * g[:, 0]) @ params["wo"]
  return out, RWKVState(s=s_new, x_prev_att=x, x_prev_ffn=state.x_prev_ffn)


def channel_mix(params: dict, x: Array, x_prev_last: Array
                ) -> Tuple[Array, Array]:
  """(B, S, D) -> (B, S, D); returns new x_prev for the state."""
  x_shift = jnp.concatenate([x_prev_last[:, None, :], x[:, :-1]], axis=1)
  mu = params["mu"]
  xk = x + (x_shift - x) * mu[0].astype(x.dtype)
  xr = x + (x_shift - x) * mu[1].astype(x.dtype)
  k = jnp.square(jax.nn.relu(xk @ params["wk"]))
  kv = k @ params["wv"]
  return jax.nn.sigmoid(xr @ params["wr"]) * kv, x[:, -1]


def init_state(b: int, d_model: int, n_heads: int, dtype=jnp.float32
               ) -> RWKVState:
  hd = d_model // n_heads
  return RWKVState(
      s=jnp.zeros((b, n_heads, hd, hd), jnp.float32),
      x_prev_att=jnp.zeros((b, d_model), dtype),
      x_prev_ffn=jnp.zeros((b, d_model), dtype),
  )
