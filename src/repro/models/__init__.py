"""Model zoo: composable decoder blocks for all assigned architecture families."""
from repro.models.model import Model

__all__ = ["Model"]
