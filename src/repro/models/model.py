"""Unified Model API over all architecture families.

  model = Model(cfg)
  params = model.init(key)
  loss, metrics = model.train_loss(params, batch)            # full-seq training
  logits, cache = model.prefill(params, tokens[, modal])     # builds (PQ) cache
  logits, cache = model.decode_step(params, tok, cache, length[, modal])

Layer parameters are stacked and scanned; caches are pytrees whose leaves carry a
leading layer axis, so decode scans (params_layer, cache_layer) together.  The PQ
cache path implements AQPIM end to end: importance weights + windowed weighted
k-means at prefill, encode-append + compressed-attention at decode.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common import Array
from repro.configs.base import ModelConfig
from repro.core import kv_cache as kvc
from repro.models import layers, rwkv6, ssm, transformer as tfm


class Model:
  def __init__(self, cfg: ModelConfig, context_len: Optional[int] = None):
    self.cfg = cfg
    self.context_len = context_len or cfg.decode_cache_len
    # the unified KV-cache policy (core.cache_api); None for attn-free families
    self.cache_policy = cfg.make_cache_policy(self.context_len)

  # -------------------------------------------------------------------------
  # init
  # -------------------------------------------------------------------------
  def init(self, key: Array) -> Dict[str, Any]:
    cfg = self.cfg
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": layers.embed_init(k_embed, cfg.vocab_size, cfg.d_model,
                                   cfg.dtype),
        "final_norm": layers.rmsnorm_init(cfg.d_model, cfg.dtype),
        "lm_head": layers.dense_init(k_head, cfg.d_model, (cfg.vocab_size,),
                                     cfg.dtype),
    }
    if cfg.family == "ssm":
      block_init = functools.partial(tfm.rwkv_block_init, cfg=cfg)
      n_stack = cfg.n_layers
    elif cfg.family == "vlm":
      block_init = functools.partial(tfm.vlm_group_init, cfg=cfg)
      assert cfg.n_layers % cfg.cross_attn_period == 0
      n_stack = cfg.n_layers // cfg.cross_attn_period
    else:
      block_init = functools.partial(tfm.dense_block_init, cfg=cfg)
      n_stack = cfg.n_layers
    keys = jax.random.split(k_layers, n_stack)
    params["layers"] = jax.vmap(lambda k_: block_init(k_))(keys)
    if cfg.weight_quant == "int8":
      from repro.models import quantize
      params = quantize.quantize_params(params)
    return params

  # -------------------------------------------------------------------------
  # embedding / frontend stubs
  # -------------------------------------------------------------------------
  def _embed(self, params, tokens: Array, modal: Optional[Array]) -> Array:
    x = layers.embed_lookup(params["embed"], tokens)
    if self.cfg.frontend == "audio_frames" and modal is not None:
      # EnCodec frame-embedding stub: precomputed (B, S, D) added to tokens
      x = x + modal.astype(x.dtype)
    return x

  def _logits(self, params, x: Array) -> Array:
    x = layers.rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
    return jnp.einsum("...d,dv->...v", x,
                      layers.wv(params["lm_head"], x.dtype))

  # -------------------------------------------------------------------------
  # training forward
  # -------------------------------------------------------------------------
  @staticmethod
  def _scan_layers(body, init, stacked, unroll: bool):
    """lax.scan over stacked layer params, or a python loop when unrolled
    (roofline validation: while-loop bodies are cost-counted once by XLA)."""
    if not unroll:
      return jax.lax.scan(body, init, stacked)
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
      lp = jax.tree_util.tree_map(lambda x: x[i], stacked)
      carry, y = body(carry, lp)
      ys.append(y)
    if ys and ys[0] is not None:
      ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
      ys = None
    return carry, ys

  def forward(self, params, tokens: Array, modal: Optional[Array] = None
              ) -> Tuple[Array, Array]:
    """(B, S) tokens -> (logits (B, S, V), moe aux loss)."""
    cfg = self.cfg
    x = self._embed(params, tokens, modal)
    positions = jnp.arange(tokens.shape[1])[None, :]

    if cfg.family == "ssm":
      def body(carry, lp):
        y = carry
        state = rwkv6.init_state(y.shape[0], cfg.d_model, cfg.n_heads, y.dtype)
        fn = functools.partial(tfm.rwkv_block_forward, cfg=cfg)
        if cfg.remat:
          fn = jax.checkpoint(fn)
        y, _ = fn(lp, y, state)
        return y, None
      x, _ = self._scan_layers(body, x, params["layers"],
                               cfg.unroll_layers)
      aux = jnp.asarray(0.0, jnp.float32)
    elif cfg.family == "vlm":
      def body(carry, lp):
        y, aux = carry
        fn = functools.partial(tfm.vlm_group_forward, cfg=cfg)
        if cfg.remat:
          fn = jax.checkpoint(fn)
        y, aux_i = fn(lp, y, modal.astype(y.dtype), positions)
        return (y, aux + aux_i), None
      (x, aux), _ = self._scan_layers(
          body, (x, jnp.asarray(0.0, jnp.float32)), params["layers"],
          cfg.unroll_layers)
    else:
      def body(carry, lp):
        y, aux = carry
        if cfg.fsdp:
          y = layers.activation_constraint(y)
        fn = functools.partial(tfm.dense_block_forward, cfg=cfg)
        if cfg.remat:
          fn = jax.checkpoint(fn)
        y, aux_i = fn(lp, y, positions)
        return (y, aux + aux_i), None
      (x, aux), _ = self._scan_layers(
          body, (x, jnp.asarray(0.0, jnp.float32)), params["layers"],
          cfg.unroll_layers)

    return self._logits(params, x), aux

  def train_loss(self, params, batch: Dict[str, Array]
                 ) -> Tuple[Array, Dict[str, Array]]:
    """Causal LM loss with z-loss and MoE load-balance aux."""
    logits, aux = self.forward(params, batch["tokens"], batch.get("modal"))
    targets = batch["targets"]
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.clip(targets, 0)[..., None], axis=-1)[..., 0]
    mask = (targets >= 0).astype(jnp.float32)
    n = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum((lse - gold) * mask) / n
    z_loss = 1e-4 * jnp.sum(jnp.square(lse) * mask) / n
    aux_loss = 0.01 * aux / max(self.cfg.n_layers, 1)
    loss = ce + z_loss + aux_loss
    return loss, {"ce": ce, "z_loss": z_loss, "aux": aux_loss,
                  "tokens": n}

  # -------------------------------------------------------------------------
  # prefill
  # -------------------------------------------------------------------------
  def prefill(self, params, tokens: Array, modal: Optional[Array] = None,
              lengths: Optional[Array] = None) -> Tuple[Array, Any]:
    """Full-context forward that also builds every layer's cache.

    PQ codebook generation happens layer by layer inside the scan — the paper's
    "layer-wise codebook generation minimizes peak memory" (§III-B).

    `lengths` (B,) marks each request's true prompt length when `tokens` is a
    right-padded mixed batch; logits are then taken at each row's last valid
    token.  None (default) means every row spans the full sequence.
    """
    cfg = self.cfg
    if lengths is not None and (cfg.family == "ssm" or cfg.hybrid):
      raise ValueError(
          "lengths-aware prefill is unsupported for recurrent state "
          "(ssm/hybrid families): the carried state would absorb the "
          "right-padding tokens")
    x = self._embed(params, tokens, modal)
    positions = jnp.arange(tokens.shape[1])[None, :]

    if cfg.family == "ssm":
      def body(y, lp):
        state = rwkv6.init_state(y.shape[0], cfg.d_model, cfg.n_heads, y.dtype)
        y, st = tfm.rwkv_block_forward(lp, y, state, cfg)
        return y, st
      x, caches = jax.lax.scan(body, x, params["layers"])
    elif cfg.family == "vlm":
      def body(y, lp):
        y, c = tfm.vlm_group_prefill(lp, y, modal.astype(y.dtype), positions,
                                     cfg, self.cache_policy, lengths)
        return y, c
      x, caches = jax.lax.scan(body, x, params["layers"])
    else:
      def body(y, lp):
        y, c = tfm.dense_block_prefill(lp, y, positions, cfg,
                                       self.cache_policy, lengths)
        return y, c
      x, caches = jax.lax.scan(body, x, params["layers"])

    if lengths is None:
      x_last = x[:, -1:]
    else:
      idx = jnp.clip(lengths.astype(jnp.int32) - 1, 0, x.shape[1] - 1)
      x_last = x[jnp.arange(x.shape[0]), idx][:, None]
    logits = self._logits(params, x_last)
    return logits[:, 0], caches

  def prefill_chunk(self, params, tokens: Array, caches, start: Array,
                    kv_extent: int) -> Tuple[Array, Any]:
    """Suffix-only prefill over a fixed-size chunk of prompt rows.

    `tokens` (B, C) are prompt positions [start, start+C); `caches` already
    hold the K/V of positions [0, start) (a shared prefix ref'd from the
    prefix index).  Inserts the chunk's K/V and returns logits for every
    chunk row — the caller picks the row of the prompt's true last token.
    `kv_extent` must equal the padded extent the full prefill attends over
    (prompt capacity): that is what makes chunked and full prefill
    bit-identical per row.  Dense family only — MoE capacity routing and
    recurrent state couple positions across the sequence.
    """
    cfg = self.cfg
    if cfg.family != "dense":
      raise ValueError(
          f"prefill_chunk supports the dense family only, got {cfg.family!r}")
    x = self._embed(params, tokens, None)
    positions = start + jnp.arange(tokens.shape[1])[None, :]

    def body(y, inp):
      lp, c = inp
      y, c = tfm.dense_block_chunk(lp, y, c, positions, cfg, kv_extent)
      return y, c
    x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    return self._logits(params, x), new_caches

  # -------------------------------------------------------------------------
  # decode
  # -------------------------------------------------------------------------
  def decode_step(self, params, token: Array, caches, lengths: Array,
                  modal: Optional[Array] = None) -> Tuple[Array, Any]:
    """token (B,) int32; caches leading dim = layer stack; lengths (B,) int32
    per-request cached-token counts (a scalar broadcasts)."""
    cfg = self.cfg
    lengths = kvc.as_lengths(lengths, token.shape[0])
    x = self._embed(params, token[:, None], modal if cfg.frontend == "none"
                    else None)
    if cfg.frontend == "audio_frames" and modal is not None:
      x = x + modal[:, :1].astype(x.dtype)

    if cfg.family == "ssm":
      def body(y, inp):
        lp, st = inp
        y, st = tfm.rwkv_block_step(lp, y, st, cfg)
        return y, st
      x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    elif cfg.family == "vlm":
      def body(y, inp):
        lp, c = inp
        y, c = tfm.vlm_group_step(lp, y, modal.astype(y.dtype), c, lengths,
                                  cfg, self.cache_policy)
        return y, c
      x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    else:
      def body(y, inp):
        lp, c = inp
        y, c = tfm.dense_block_step(lp, y, c, lengths, cfg, self.cache_policy)
        return y, c
      x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))

    logits = self._logits(params, x[:, 0])
    return logits, new_caches

  def decode_step_paged(self, params, token: Array, resident_leaves,
                        pool_leaves, tables: Array, lengths: Array
                        ) -> Tuple[Array, Any, Any]:
    """Block-table-native decode step: attention reads pooled KV in place.

    `resident_leaves` is the flattened per-layer policy state (paged leaves
    None) with leading layer axis; `pool_leaves` the physical pools
    (P+1, L, ..., block, ...) shared across the layer scan (carried, updated
    functionally with single-row writes); `tables` the (B, nb) per-slot block
    tables.  The layer counter rides the carry so each layer's kernel call
    addresses its own pool plane through the scalar-prefetched index maps —
    the pool is never sliced, gathered, or densified.  Dense/MoE attention
    families only (the ones the serve engine admits).
    """
    cfg = self.cfg
    if cfg.family not in ("dense", "moe") or cfg.hybrid:
      raise ValueError(
          f"decode_step_paged supports dense/moe attention, got "
          f"{cfg.family!r} (hybrid={cfg.hybrid})")
    lengths = kvc.as_lengths(lengths, token.shape[0])
    x = self._embed(params, token[:, None], None)

    def body(carry, inp):
      y, layer, pools = carry
      lp, res = inp
      y, new_res, pools = tfm.dense_block_step_paged(
          lp, y, res, pools, layer, tables, lengths, cfg, self.cache_policy)
      return (y, layer + 1, pools), new_res

    (x, _, pool_leaves), new_resident = jax.lax.scan(
        body, (x, jnp.asarray(0, jnp.int32), pool_leaves),
        (params["layers"], resident_leaves))
    logits = self._logits(params, x[:, 0])
    return logits, new_resident, pool_leaves

  # -------------------------------------------------------------------------
  # cache constructors (dry-run input specs / serving init)
  # -------------------------------------------------------------------------
  def init_cache(self, batch: int) -> Any:
    """Zero cache at full context capacity (decode-shape dry-runs)."""
    cfg = self.cfg
    n_stack = (cfg.n_layers if cfg.family != "vlm"
               else cfg.n_layers // cfg.cross_attn_period)

    def one_layer_kv():
      return self.cache_policy.init(batch, cfg.n_kv_heads, cfg.head_dim)

    def stack(tree, n):
      return jax.tree_util.tree_map(
          lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree)

    if cfg.family == "ssm":
      st = rwkv6.init_state(batch, cfg.d_model, cfg.n_heads, cfg.dtype)
      return stack(st, n_stack)
    if cfg.family == "vlm":
      inner = stack(one_layer_kv(), cfg.cross_attn_period - 1)
      return stack(inner, n_stack)
    if cfg.hybrid:
      pair = (one_layer_kv(),
              ssm.init_state(batch, cfg.ssm_d_inner, cfg.ssm_state, cfg.dtype))
      return stack(pair, n_stack)
    return stack(one_layer_kv(), n_stack)
