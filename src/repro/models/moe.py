"""Mixture-of-Experts FFN with sort-based token dispatch (qwen2-moe / phi3.5-moe).

Dispatch is the MaxText/Mixtral-JAX style sorted-scatter: flatten (token, slot)
pairs, sort by expert id, place into a fixed-capacity per-expert buffer, run all
experts as one batched einsum (the EP-shardable tensor), gather back and combine
with router weights.  Static shapes throughout (capacity-factor drop policy), so it
lowers cleanly under pjit; with experts sharded over the `model` axis GSPMD turns
the scatter/gather into all-to-alls — the EP pattern.

qwen2-moe extras: 4 shared experts (a dense SwiGLU of 4x moe_d_ff) with a sigmoid
shared-gate, plus 60 routed top-4 with normalized top-k probs.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common import Array
from repro.models import layers


def moe_init(key, d_model: int, n_experts: int, moe_d_ff: int,
             n_shared: int, top_k: int, dtype) -> dict:
  ks = jax.random.split(key, 5)
  p = {
      "router": layers.dense_init(ks[0], d_model, (n_experts,), jnp.float32),
      "w_gate": jax.vmap(
          lambda k_: layers.dense_init(k_, d_model, (moe_d_ff,), dtype))(
              jax.random.split(ks[1], n_experts)),
      "w_up": jax.vmap(
          lambda k_: layers.dense_init(k_, d_model, (moe_d_ff,), dtype))(
              jax.random.split(ks[2], n_experts)),
      "w_down": jax.vmap(
          lambda k_: layers.dense_init(k_, moe_d_ff, (d_model,), dtype))(
              jax.random.split(ks[3], n_experts)),
  }
  if n_shared > 0:
    kss = jax.random.split(ks[4], 2)
    p["shared"] = layers.mlp_init(kss[0], d_model, n_shared * moe_d_ff, dtype)
    p["shared_gate"] = layers.dense_init(kss[1], d_model, (1,), jnp.float32)
  return p


@functools.partial(jax.jit, static_argnames=("top_k",))
def route_topk(router_logits: Array, top_k: int) -> Tuple[Array, Array]:
  """(T, E) logits -> (weights (T, k) f32 normalized, expert ids (T, k) int32)."""
  probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
  w, ids = jax.lax.top_k(probs, top_k)
  w = w / jnp.sum(w, axis=-1, keepdims=True)           # norm_topk_prob
  return w, ids.astype(jnp.int32)


def load_balancing_loss(router_logits: Array, ids: Array, n_experts: int,
                        top_k: int) -> Array:
  """Switch-style aux loss: E * sum_e f_e * P_e."""
  probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
  p_e = jnp.mean(probs, axis=0)                         # (E,)
  onehot = jax.nn.one_hot(ids, n_experts, dtype=jnp.float32)  # (T, k, E)
  f_e = jnp.mean(jnp.sum(onehot, axis=1), axis=0)       # (E,)
  return n_experts * jnp.sum(f_e * p_e)


def _quant_rows(x: Array) -> Tuple[Array, Array]:
  """Per-row symmetric int8 (the quantized-a2a wire format)."""
  scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), -1,
                              keepdims=True), 1e-12) / 127.0
  q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
               ).astype(jnp.int8)
  return q, scale


def moe_ffn(
    params: dict,
    x: Array,                 # (B, S, D)
    top_k: int,
    n_experts: int,
    capacity_factor: float = 1.25,
    a2a_quant: bool = False,
) -> Tuple[Array, Array]:
  """Returns (out (B, S, D), aux_loss scalar).

  a2a_quant: int8-quantize the token rows crossing the EP dispatch/combine
  all-to-alls (halves the dominant MoE-training collective bytes; §Perf B).
  """
  b, s, d = x.shape
  t = b * s
  xf = x.reshape(t, d)
  logits = xf.astype(jnp.float32) @ params["router"]    # (T, E)
  w, ids = route_topk(logits, top_k)                    # (T, k)
  aux = load_balancing_loss(logits, ids, n_experts, top_k)

  capacity = int(max(1, round(t * top_k / n_experts * capacity_factor)))
  # --- sorted dispatch ---
  flat_ids = ids.reshape(-1)                            # (T*k,)
  order = jnp.argsort(flat_ids)                         # stable
  sorted_ids = flat_ids[order]
  tok_of = order // top_k                               # source token per slot
  # position within each expert's contiguous segment
  first_occurrence = jnp.searchsorted(sorted_ids, sorted_ids, side="left")
  seg_pos = jnp.arange(t * top_k) - first_occurrence
  keep = seg_pos < capacity                             # drop overflow
  slot = sorted_ids * capacity + jnp.clip(seg_pos, 0, capacity - 1)

  safe_slot = jnp.where(keep, slot, n_experts * capacity - 1)
  if a2a_quant:
    # dispatch int8 rows + scales; dequantize expert-side (post all-to-all)
    xq, xscale = _quant_rows(xf)
    bufq = jnp.zeros((n_experts * capacity, d), jnp.int8).at[safe_slot].set(
        jnp.where(keep[:, None], xq[tok_of], 0), mode="drop")
    bufs = jnp.zeros((n_experts * capacity, 1), jnp.float32).at[safe_slot].set(
        jnp.where(keep[:, None], xscale[tok_of], 0), mode="drop")
    buf = (bufq.astype(jnp.float32) * bufs).astype(x.dtype)
  else:
    buf = jnp.zeros((n_experts * capacity, d), x.dtype)
    buf = buf.at[safe_slot].set(
        jnp.where(keep[:, None], xf[tok_of], 0), mode="drop")
  buf = buf.reshape(n_experts, capacity, d)

  # --- batched experts (the EP-shardable einsum) ---
  gate = jax.nn.silu(jnp.einsum(
      "ecd,edf->ecf", buf, layers.wv(params["w_gate"], buf.dtype)))
  up = jnp.einsum("ecd,edf->ecf", buf, layers.wv(params["w_up"], buf.dtype))
  expert_out = jnp.einsum(
      "ecf,efd->ecd", gate * up, layers.wv(params["w_down"], buf.dtype))
  expert_out = expert_out.reshape(n_experts * capacity, d)

  # --- combine ---
  if a2a_quant:
    eq, es = _quant_rows(expert_out)                    # int8 return a2a
    gathered = (eq[slot].astype(jnp.float32) * es[slot]) * keep[:, None]
    gathered = gathered.astype(expert_out.dtype)
  else:
    gathered = expert_out[slot] * keep[:, None]         # (T*k, D)
  w_sorted = w.reshape(-1)[order]
  contrib = gathered.astype(jnp.float32) * w_sorted[:, None]
  out = jnp.zeros((t, d), jnp.float32).at[tok_of].add(contrib)

  if "shared" in params:
    sg = jax.nn.sigmoid(xf.astype(jnp.float32) @ params["shared_gate"])
    shared = layers.mlp(params["shared"], x).reshape(t, d)
    out = out + sg * shared.astype(jnp.float32)

  return out.reshape(b, s, d).astype(x.dtype), aux
