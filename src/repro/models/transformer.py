"""Block assembly for all assigned architecture families.

A "block" is one decoder layer (or one pattern group for the VLM, which
interleaves cross-attention layers).  Blocks come in three call modes:

  - forward : full-sequence (training — no cache)
  - prefill : full-sequence, returns the layer's cache contribution
              (KV -> exact or PQ-compressed per config; recurrent state for SSM)
  - step    : single-token decode against the layer cache

Layer parameters are stacked (leading dim = n_layers or n_groups) and the model
scans over them — essential for compile time at 126 layers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common import Array
from repro.core import importance as imp
from repro.core import kv_cache as kvc
from repro.core import pq as pqlib
from repro.models import layers, moe as moe_mod, rwkv6, ssm
from repro.parallel import serve_sharding as ssh


# ---------------------------------------------------------------------------
# Attention sub-layer with cache modes
# ---------------------------------------------------------------------------

def _attn_prefill(
    p: dict, x: Array, positions: Array, cfg, policy, lengths=None
) -> Tuple[Array, Any]:
  """Run attention over the full sequence AND build this layer's KV cache.

  `policy` is a `repro.core.cache_api.CachePolicy`; for the PQ policy this is
  where the paper's in-memory clustering runs: the importance weights (Eq. 1)
  come from the same q/k, and the windowed weighted k-means compresses the
  body — layer by layer, exactly the paper's "layer-wise codebook generation"
  that bounds peak memory.  `lengths` (B,) marks true prompt lengths for
  right-padded mixed batches (None -> full sequence).
  """
  scale = cfg.head_dim ** -0.5
  q, k, v = layers.attention_qkv(p, x, positions, cfg.rope_theta)
  attn = layers.chunked_attention(q, k, v, scale, causal=True,
                                  blk_q=cfg.attn_block, blk_k=cfg.attn_block)
  out = layers.attention_out(p, attn)

  w = None
  if policy.needs_weights:
    # Eq. 1 weights per (batch, kv head): queries of the kv-group, averaged.
    b, hq, s, hd = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, s, hd)[:, :, 0]           # lead query head / group
    t = policy.spec.recent
    chunk = min(cfg.attn_block, s)
    if lengths is None:
      w = jax.vmap(jax.vmap(
          lambda qq, kk: imp.attention_importance_weights(
              qq, kk, scale, t=t, chunk=chunk)))(qg, k)  # (B, Hkv, S)
    else:
      w = jax.vmap(lambda qb, kb, ln: jax.vmap(
          lambda qq, kk: imp.attention_importance_weights(
              qq, kk, scale, t=t, chunk=chunk, length=ln))(qb, kb)
      )(qg, k, lengths)
  cache = policy.prefill(k, v, w, lengths)
  return out, cache


def _attn_chunk(
    p: dict, x: Array, cache, positions: Array, cfg, kv_extent: int
) -> Tuple[Array, Any]:
  """Suffix-chunk attention: insert this chunk's K/V into an existing
  exact-store cache and attend causally at absolute positions.

  The bit-exactness contract with `_attn_prefill` (the prefix-cache on/off
  oracle): the chunk attends over the same `kv_extent` key extent the full
  prefill used (prompt capacity), with the same `blk_k` blocking, and every
  op is per-row — so row p's output here equals row p of a full prefill
  whose earlier rows produced exactly the cached prefix K/V.  Masked
  positions hold stale block payloads instead of padding activations, but
  contribute exact zeros either way.  Exact-store caches only (`policy.
  prefix_shareable`); weighted/clustered states couple positions and take
  the full-entry path instead.
  """
  scale = cfg.head_dim ** -0.5
  q, k, v = layers.attention_qkv(p, x, positions, cfg.rope_theta)
  start = positions[0, 0]
  chunk = x.shape[1]

  def insert(buf, new):
    # pad-insert-crop keeps shapes static while a dynamic start never
    # clamp-shifts: start + chunk always fits the padded extent
    pad = jnp.pad(new.astype(buf.dtype),
                  ((0, 0), (0, 0), (0, buf.shape[2] - chunk), (0, 0)))
    rolled = jnp.roll(pad, start, axis=2)
    written = jnp.arange(buf.shape[2])
    mask = ((written >= start) & (written < start + chunk))[None, None, :,
                                                            None]
    return jnp.where(mask, rolled, buf)

  k_c = insert(cache.k, k)
  v_c = insert(cache.v, v)
  attn = layers.chunked_attention(
      q, k_c[:, :, :kv_extent], v_c[:, :, :kv_extent], scale, causal=True,
      blk_q=cfg.attn_block, blk_k=cfg.attn_block, q_offset=start)
  out = layers.attention_out(p, attn)
  return out, cache._replace(k=k_c, v=v_c)


def dense_block_chunk(p: dict, x: Array, cache, positions: Array, cfg,
                      kv_extent: int) -> Tuple[Array, Any]:
  """Suffix-only prefill: one layer over a chunk of prompt rows, consuming
  the already-cached prefix as attention context (prefix sharing)."""
  h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
  attn, cache = _attn_chunk(p["attn"], h, cache, positions, cfg, kv_extent)
  if cfg.parallel_block:
    ffn, _ = _ffn_apply(p, h, cfg)
    return x + attn + ffn, cache
  x = x + attn
  h = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
  ffn, _ = _ffn_apply(p, h, cfg)
  return x + ffn, cache


def _attn_qkv_step(p: dict, x: Array, lengths: Array, cfg):
  """Single-token q/k/v projection + RoPE at each row's position."""
  pos = lengths[:, None]                                 # (B, 1) RoPE positions
  q = jnp.einsum("bsd,dhk->bshk", x, layers.wv(p["wq"], x.dtype))
  k = jnp.einsum("bsd,dhk->bshk", x, layers.wv(p["wk"], x.dtype))
  v = jnp.einsum("bsd,dhk->bshk", x, layers.wv(p["wv"], x.dtype))
  q = layers.apply_rope(q, pos, cfg.rope_theta)[:, 0]    # (B, H, hd)
  k = layers.apply_rope(k, pos, cfg.rope_theta)[:, 0]
  return q, k, v[:, 0]


def _attn_step(
    p: dict, x: Array, cache, lengths: Array, cfg, policy
) -> Tuple[Array, Any]:
  """Single-token attention against the cache.  x (B, 1, D), lengths (B,).

  Under an active shard plan (traced inside the sharded serve path's
  shard_map) the per-kv-head independence of every policy is the partition
  seam: q/k/v come out of the replicated projections full-width, each shard
  attends only its kv-head slice against its local cache shard, and an
  ordered all_gather reassembles the exact per-head context before the
  replicated `wo` projection — bit-identical to the unsharded step.  The
  seq fallback instead split-Ks the exact-store softmax across shards.
  """
  lengths = kvc.as_lengths(lengths, x.shape[0])
  q, k, v = _attn_qkv_step(p, x, lengths, cfg)
  plan = ssh.active_plan()
  if plan is None:
    attn, new_cache = policy.append_and_attend(cache, q, k, v, lengths)
  elif plan.mode == "heads":
    q_l, k_l, v_l = ssh.shard_attn_inputs(q, k, v, plan)
    attn, new_cache = policy.append_and_attend(cache, q_l, k_l, v_l, lengths)
    attn = ssh.gather_attn_outputs(attn, plan)
  else:                                   # seq split-K (exact store only)
    attn, new_cache = ssh.seq_append_and_attend(
        cache, q, k, v, lengths, cfg.head_dim ** -0.5, plan)
  out = jnp.einsum("bhk,hkd->bd", attn.astype(x.dtype),
                   layers.wv(p["wo"], x.dtype))
  return out[:, None, :], new_cache


def _attn_step_paged(
    p: dict, x: Array, resident, pools, layer, tables, lengths: Array,
    cfg, policy
) -> Tuple[Array, Any, Any]:
  """Single-token attention reading pooled block storage in place.

  `resident`/`pools` are this layer's flattened policy-state leaves (the
  other kind None); the policy's block-native step streams pool blocks via
  the per-slot `tables` and writes only the rows this token produced — the
  dense gather->decode->scatter round trip never happens.
  """
  lengths = kvc.as_lengths(lengths, x.shape[0])
  q, k, v = _attn_qkv_step(p, x, lengths, cfg)
  plan = ssh.active_plan()
  if plan is None:
    attn, resident, pools = policy.append_and_attend_paged(
        resident, pools, layer, tables, q, k, v, lengths)
  else:
    # heads mode only: the block-native kernels are H-shape-generic, so the
    # same slice/attend/gather seam as `_attn_step` applies — each shard's
    # kernel streams its own head-slice of the pool through the shared
    # scalar-prefetched tables.  (Seq mode forces the dense program at
    # dispatch resolution; see core.decode_dispatch.resolve_for_plan.)
    q_l, k_l, v_l = ssh.shard_attn_inputs(q, k, v, plan)
    attn, resident, pools = policy.append_and_attend_paged(
        resident, pools, layer, tables, q_l, k_l, v_l, lengths)
    attn = ssh.gather_attn_outputs(attn, plan)
  out = jnp.einsum("bhk,hkd->bd", attn.astype(x.dtype),
                   layers.wv(p["wo"], x.dtype))
  return out[:, None, :], resident, pools


# ---------------------------------------------------------------------------
# Dense / MoE blocks
# ---------------------------------------------------------------------------

def dense_block_init(key, cfg) -> dict:
  ks = jax.random.split(key, 4)
  p = {
      "ln1": layers.rmsnorm_init(cfg.d_model, cfg.dtype),
      "attn": layers.attention_init(ks[0], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim, cfg.dtype),
      "ln2": layers.rmsnorm_init(cfg.d_model, cfg.dtype),
  }
  if cfg.n_experts > 0:
    p["moe"] = moe_mod.moe_init(ks[1], cfg.d_model, cfg.n_experts,
                                cfg.moe_d_ff, cfg.n_shared_experts,
                                cfg.top_k, cfg.dtype)
  else:
    p["mlp"] = layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.dtype)
  if cfg.hybrid:
    p["ssm"] = ssm.ssm_init(ks[2], cfg.d_model, cfg.ssm_d_inner,
                            cfg.ssm_state, cfg.dtype)
    p["ln_attn_out"] = layers.rmsnorm_init(cfg.d_model, cfg.dtype)
    p["ln_ssm_out"] = layers.rmsnorm_init(cfg.d_model, cfg.dtype)
  return p


def _ffn_apply(p: dict, x: Array, cfg) -> Tuple[Array, Array]:
  if cfg.n_experts > 0:
    out, aux = moe_mod.moe_ffn(p["moe"], x, cfg.top_k, cfg.n_experts,
                               cfg.capacity_factor,
                               a2a_quant=getattr(cfg, "moe_a2a_quant", False))
    return out, aux
  return layers.mlp(p["mlp"], x), jnp.asarray(0.0, jnp.float32)


def dense_block_forward(p: dict, x: Array, positions: Array, cfg
                        ) -> Tuple[Array, Array]:
  """Training forward (hybrid runs SSM branch in parallel with attention)."""
  h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
  scale = cfg.head_dim ** -0.5
  attn = layers.self_attention(p["attn"], h, positions, scale,
                               cfg.rope_theta, blk=cfg.attn_block)
  if cfg.hybrid:
    s0 = ssm.init_state(x.shape[0], cfg.ssm_d_inner, cfg.ssm_state, x.dtype)
    ssm_out, _ = ssm.ssm_forward(p["ssm"], h, s0)
    attn = 0.5 * (layers.rmsnorm(p["ln_attn_out"], attn, cfg.norm_eps)
                  + layers.rmsnorm(p["ln_ssm_out"], ssm_out, cfg.norm_eps))
  if cfg.parallel_block:
    # PaLM-style fused residual: one TP all-reduce per layer instead of two
    ffn, aux = _ffn_apply(p, h, cfg)
    return x + attn + ffn, aux
  x = x + attn
  h = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
  ffn, aux = _ffn_apply(p, h, cfg)
  return x + ffn, aux


def dense_block_prefill(p: dict, x: Array, positions: Array, cfg,
                        policy, lengths=None) -> Tuple[Array, Any]:
  h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
  attn, kv_cache = _attn_prefill(p["attn"], h, positions, cfg, policy, lengths)
  if cfg.hybrid:
    s0 = ssm.init_state(x.shape[0], cfg.ssm_d_inner, cfg.ssm_state, x.dtype)
    ssm_out, ssm_state = ssm.ssm_forward(p["ssm"], h, s0)
    attn = 0.5 * (layers.rmsnorm(p["ln_attn_out"], attn, cfg.norm_eps)
                  + layers.rmsnorm(p["ln_ssm_out"], ssm_out, cfg.norm_eps))
    cache = (kv_cache, ssm_state)
  else:
    cache = kv_cache
  if cfg.parallel_block:
    ffn, _ = _ffn_apply(p, h, cfg)
    return x + attn + ffn, cache
  x = x + attn
  h = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
  ffn, _ = _ffn_apply(p, h, cfg)
  return x + ffn, cache


def dense_block_step_paged(p: dict, x: Array, resident, pools, layer,
                           tables, lengths: Array, cfg, policy
                           ) -> Tuple[Array, Any, Any]:
  """One decoder layer's decode step over block-pooled KV storage.

  Mirrors `dense_block_step` exactly, with the attention sub-layer reading
  the physical block pool in place (`_attn_step_paged`).  Dense/MoE only —
  the hybrid SSM branch carries extra recurrent state and stays on the
  dense-cache path.
  """
  h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
  attn, resident, pools = _attn_step_paged(
      p["attn"], h, resident, pools, layer, tables, lengths, cfg, policy)
  if cfg.parallel_block:
    ffn, _ = _ffn_apply(p, h, cfg)
    return x + attn + ffn, resident, pools
  x = x + attn
  h = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
  ffn, _ = _ffn_apply(p, h, cfg)
  return x + ffn, resident, pools


def dense_block_step(p: dict, x: Array, cache, lengths: Array, cfg,
                     policy) -> Tuple[Array, Any]:
  h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
  if cfg.hybrid:
    kv_cache, ssm_state = cache
    attn, new_kv = _attn_step(p["attn"], h, kv_cache, lengths, cfg, policy)
    ssm_out, new_ssm = ssm.ssm_step(p["ssm"], h[:, 0], ssm_state)
    attn = 0.5 * (layers.rmsnorm(p["ln_attn_out"], attn, cfg.norm_eps)
                  + layers.rmsnorm(p["ln_ssm_out"], ssm_out[:, None],
                                   cfg.norm_eps))
    new_cache = (new_kv, new_ssm)
  else:
    attn, new_cache = _attn_step(p["attn"], h, cache, lengths, cfg, policy)
  if cfg.parallel_block:
    ffn, _ = _ffn_apply(p, h, cfg)
    return x + attn + ffn, new_cache
  x = x + attn
  h = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
  ffn, _ = _ffn_apply(p, h, cfg)
  return x + ffn, new_cache


# ---------------------------------------------------------------------------
# RWKV-6 block
# ---------------------------------------------------------------------------

def rwkv_block_init(key, cfg) -> dict:
  ks = jax.random.split(key, 2)
  return {
      "ln1": layers.rmsnorm_init(cfg.d_model, cfg.dtype),
      "tm": rwkv6.time_mix_init(ks[0], cfg.d_model, cfg.n_heads,
                                cfg.head_dim, cfg.dtype),
      "ln2": layers.rmsnorm_init(cfg.d_model, cfg.dtype),
      "cm": rwkv6.channel_mix_init(ks[1], cfg.d_model, cfg.d_ff, cfg.dtype),
  }


def rwkv_block_forward(p: dict, x: Array, state: rwkv6.RWKVState, cfg
                       ) -> Tuple[Array, rwkv6.RWKVState]:
  h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
  tm_out, state = rwkv6.time_mix(p["tm"], h, state, cfg.n_heads)
  x = x + tm_out
  h = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
  cm_out, x_prev_ffn = rwkv6.channel_mix(p["cm"], h, state.x_prev_ffn)
  state = state._replace(x_prev_ffn=x_prev_ffn)
  return x + cm_out, state


def rwkv_block_step(p: dict, x: Array, state: rwkv6.RWKVState, cfg
                    ) -> Tuple[Array, rwkv6.RWKVState]:
  h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)           # (B, 1, D)
  tm_out, state = rwkv6.time_mix_step(p["tm"], h[:, 0], state, cfg.n_heads)
  x = x + tm_out[:, None]
  h = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
  cm_out, x_prev_ffn = rwkv6.channel_mix(p["cm"], h, state.x_prev_ffn)
  state = state._replace(x_prev_ffn=x_prev_ffn)
  return x + cm_out, state


# ---------------------------------------------------------------------------
# VLM pattern group: [cross-attn layer, (period-1) self layers]
# ---------------------------------------------------------------------------

def vlm_group_init(key, cfg) -> dict:
  ks = jax.random.split(key, cfg.cross_attn_period + 1)
  self_layers = jax.vmap(lambda k_: dense_block_init(k_, cfg))(
      jnp.stack(ks[1:cfg.cross_attn_period]))
  return {
      "cross_ln": layers.rmsnorm_init(cfg.d_model, cfg.dtype),
      "cross": layers.cross_attention_init(
          ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
          cfg.dtype),
      "cross_gate": jnp.zeros((1,), jnp.float32),
      "cross_mlp_ln": layers.rmsnorm_init(cfg.d_model, cfg.dtype),
      "cross_mlp": layers.mlp_init(
          jax.random.fold_in(ks[0], 3), cfg.d_model, cfg.d_ff, cfg.dtype),
      "cross_mlp_gate": jnp.zeros((1,), jnp.float32),
      "selfs": self_layers,
  }


def _cross_layer(p: dict, x: Array, vision: Array, cfg) -> Array:
  scale = cfg.head_dim ** -0.5
  h = layers.rmsnorm(p["cross_ln"], x, cfg.norm_eps)
  attn = layers.cross_attention(p["cross"], h, vision, scale,
                                blk=cfg.attn_block)
  x = x + jnp.tanh(p["cross_gate"]).astype(x.dtype) * attn
  h = layers.rmsnorm(p["cross_mlp_ln"], x, cfg.norm_eps)
  return x + jnp.tanh(p["cross_mlp_gate"]).astype(x.dtype) * layers.mlp(
      p["cross_mlp"], h)


def _scan_selfs(p_selfs, x, fn):
  def body(carry, lp):
    y, aux = carry
    y, aux_i = fn(lp, y)
    return (y, aux + aux_i), None
  (x, aux), _ = jax.lax.scan(body, (x, jnp.asarray(0.0, jnp.float32)), p_selfs)
  return x, aux


def vlm_group_forward(p: dict, x: Array, vision: Array, positions: Array,
                      cfg) -> Tuple[Array, Array]:
  x = _cross_layer(p, x, vision, cfg)
  return _scan_selfs(
      p["selfs"], x, lambda lp, y: dense_block_forward(lp, y, positions, cfg))


def vlm_group_prefill(p: dict, x: Array, vision: Array, positions: Array,
                      cfg, policy, lengths=None) -> Tuple[Array, Any]:
  x = _cross_layer(p, x, vision, cfg)
  def body(y, lp):
    y, cache = dense_block_prefill(lp, y, positions, cfg, policy, lengths)
    return y, cache
  def scan_body(carry, lp):
    y = carry
    y, cache = body(y, lp)
    return y, cache
  x, caches = jax.lax.scan(scan_body, x, p["selfs"])
  return x, caches


def vlm_group_step(p: dict, x: Array, vision: Array, caches, lengths: Array,
                   cfg, policy) -> Tuple[Array, Any]:
  x = _cross_layer(p, x, vision, cfg)
  def scan_body(carry, inp):
    y = carry
    lp, cache = inp
    y, new_cache = dense_block_step(lp, y, cache, lengths, cfg, policy)
    return y, new_cache
  x, new_caches = jax.lax.scan(scan_body, x, (p["selfs"], caches))
  return x, new_caches
