"""Step builders: jitted/shardable train_step, prefill_step, serve_step per
(architecture x shape x mesh), plus ShapeDtypeStruct input specs for the dry-run.

These are the programs the multi-pod dry-run lowers and the launchers execute.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import Model
from repro.optim import adamw
from repro.parallel import sharding as shd


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                model: Optional[Model] = None) -> Dict[str, Any]:
  """Every model input for the given shape, as ShapeDtypeStructs.

  train:   {tokens (B,S) i32, targets (B,S) i32[, modal]}
  prefill: {tokens (B,S) i32[, modal]}
  decode:  {token (B,) i32, cache <tree>, length (B,) i32[, modal]}
           (length is per-request so one decode batch can mix positions —
            the continuous-batching substrate)
  """
  b, s = shape.global_batch, shape.seq_len
  i32 = jnp.int32
  sds = jax.ShapeDtypeStruct

  def modal_spec(seq: int):
    if cfg.frontend == "audio_frames":
      return sds((b, seq, cfg.d_model), cfg.dtype)
    if cfg.frontend == "vision_patches":
      return sds((b, cfg.n_modal_tokens, cfg.d_model), cfg.dtype)
    return None

  if shape.kind == "train":
    specs = {"tokens": sds((b, s), i32), "targets": sds((b, s), i32)}
    m = modal_spec(s)
    if m is not None:
      specs["modal"] = m
    return specs

  if shape.kind == "prefill":
    specs = {"tokens": sds((b, s), i32)}
    m = modal_spec(s)
    if m is not None:
      specs["modal"] = m
    return specs

  # decode: one new token against a cache of seq_len
  model = model or Model(cfg, context_len=s)
  cache = jax.eval_shape(lambda: model.init_cache(b))
  specs = {"token": sds((b,), i32), "cache": cache,
           "length": sds((b,), i32)}
  m = modal_spec(1)
  if m is not None:
    specs["modal"] = m
  return specs


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(model: Model, opt_cfg: adamw.OptConfig):
  """(params, opt_state, batch) -> (params, opt_state, metrics).

  cfg.microbatches > 1: gradient accumulation — the global batch is processed
  in chunks under lax.scan, bounding live activation memory (how a 1M-token
  llama-405b batch fits 16 GB/chip); grads are averaged before the update.
  """
  n_micro_cfg = max(model.cfg.microbatches, 1)

  def grad_of(params, batch):
    return jax.value_and_grad(model.train_loss, has_aux=True)(params, batch)

  def train_step(params, opt_state, batch):
    b = batch["tokens"].shape[0]
    n_micro = n_micro_cfg if (b >= n_micro_cfg and b % n_micro_cfg == 0) else 1
    if n_micro == 1:
      (loss, metrics), grads = grad_of(params, batch)
    else:
      def split(x):
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
      micro = jax.tree_util.tree_map(split, batch)

      def body(acc, mb):
        (l, m), g = grad_of(params, mb)
        acc_g, acc_l = acc
        acc_g = jax.tree_util.tree_map(
            lambda a, b_: a + b_.astype(jnp.float32) / n_micro, acc_g, g)
        return (acc_g, acc_l + l / n_micro), None

      zero = jax.tree_util.tree_map(
          lambda p_: jnp.zeros(p_.shape, jnp.float32), params)
      (grads, loss), _ = jax.lax.scan(body, (zero, jnp.float32(0)), micro)
      metrics = {"tokens": jnp.float32(
          batch["tokens"].shape[0] * batch["tokens"].shape[1])}
    new_params, new_opt, opt_metrics = adamw.update(
        opt_cfg, opt_state, params, grads)
    metrics = dict(metrics, loss=loss, **opt_metrics)
    return new_params, new_opt, metrics
  return train_step


def make_prefill_step(model: Model):
  def prefill_step(params, batch):
    return model.prefill(params, batch["tokens"], batch.get("modal"))
  return prefill_step


def make_serve_step(model: Model):
  def serve_step(params, batch):
    return model.decode_step(params, batch["token"], batch["cache"],
                             batch["length"], batch.get("modal"))
  return serve_step


# ---------------------------------------------------------------------------
# sharded (pjit) builders
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedPrograms:
  """Everything needed to lower/execute one (arch, shape, mesh) cell."""
  model: Model
  mesh: Mesh
  param_specs: Any
  fn: Any                 # the jitted function
  in_specs: Any           # pspecs matching fn's args
  out_specs: Any
  abstract_inputs: Tuple  # SDS tree matching fn's args


def _batch_specs_tree(cfg: ModelConfig, mesh: Mesh, specs: Dict[str, Any],
                      seq_shard: bool, model_obj: Model) -> Dict[str, Any]:
  da = shd.data_axes(mesh)
  n_data = 1
  for a in da:
    n_data *= mesh.shape[a]

  def batch_ax(b: int):
    return da if b % n_data == 0 and b >= n_data else None

  out = {}
  for k, v in specs.items():
    if k in ("tokens", "targets"):
      out[k] = P(batch_ax(v.shape[0]), None)
    elif k == "modal":
      out[k] = P(batch_ax(v.shape[0]), None, None)
    elif k == "token":
      out[k] = P(batch_ax(v.shape[0]))
    elif k == "length":
      out[k] = P(batch_ax(v.shape[0]))
    elif k == "cache":
      batch = jax.tree_util.tree_leaves(v)[0].shape[1]
      out[k] = shd.cache_pspecs(v, mesh, batch, shard_sequence=seq_shard)
    else:
      out[k] = P()
  return out


def build_programs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                   opt_cfg: Optional[adamw.OptConfig] = None,
                   donate: bool = True) -> ShardedPrograms:
  """Construct the jitted program + shardings for one cell."""
  model = Model(cfg, context_len=shape.seq_len)
  model_axis = mesh.shape["model"]

  abstract_params = jax.eval_shape(
      functools.partial(model.init), jax.random.PRNGKey(0))
  context_par = cfg.context_parallel and shape.kind == "prefill"
  if context_par:
    # context parallelism: weights replicated, sequence over the model axis
    pspecs = jax.tree_util.tree_map(
        lambda leaf: P(*([None] * leaf.ndim)), abstract_params)
  else:
    pspecs = shd.param_pspecs(abstract_params, cfg, model_axis,
                              mesh_axes=dict(mesh.shape))
  specs = input_specs(cfg, shape, model)
  # long-context batch=1 decode: sequence-parallel PQ body
  seq_shard = (shape.is_decode and shape.global_batch == 1) or context_par
  bspecs = _batch_specs_tree(cfg, mesh, specs, seq_shard, model)
  if context_par:
    bspecs["tokens"] = P(shd.data_axes(mesh), "model")

  if shape.kind == "train":
    opt_cfg = opt_cfg or adamw.OptConfig()
    abstract_opt = jax.eval_shape(
        functools.partial(adamw.init, opt_cfg), abstract_params)
    # ZeRO-1: master/moments are FSDP-sharded over the data axes even when the
    # weights themselves are TP-only (f32 optimizer state is 6x the bf16
    # weights — it must never be data-replicated at scale)
    zero1 = shd.param_pspecs(
        abstract_params, dataclasses.replace(cfg, fsdp=True), model_axis,
        mesh_axes=dict(mesh.shape))
    opt_specs = adamw.OptState(
        step=P(),
        mu=zero1, nu=jax.tree_util.tree_map(lambda s: s, zero1),
        master=zero1 if abstract_opt.master is not None else None,
        error=zero1 if abstract_opt.error is not None else None)
    fn = jax.jit(
        make_train_step(model, opt_cfg),
        in_shardings=(shd.make_shardings(pspecs, mesh),
                      shd.make_shardings(opt_specs, mesh),
                      shd.make_shardings(bspecs, mesh)),
        out_shardings=(shd.make_shardings(pspecs, mesh),
                       shd.make_shardings(opt_specs, mesh),
                       None),
        donate_argnums=(0, 1) if donate else ())
    return ShardedPrograms(
        model=model, mesh=mesh, param_specs=pspecs, fn=fn,
        in_specs=(pspecs, opt_specs, bspecs),
        out_specs=(pspecs, opt_specs, None),
        abstract_inputs=(abstract_params, abstract_opt, specs))

  if shape.kind == "prefill":
    cache_shape = jax.eval_shape(
        lambda p, b: model.prefill(p, b["tokens"], b.get("modal")),
        abstract_params, specs)[1]
    cache_specs = shd.cache_pspecs(
        cache_shape, mesh, shape.global_batch, shard_sequence=context_par)
    fn = jax.jit(
        make_prefill_step(model),
        in_shardings=(shd.make_shardings(pspecs, mesh),
                      shd.make_shardings(bspecs, mesh)),
        out_shardings=(None, shd.make_shardings(cache_specs, mesh)))
    return ShardedPrograms(
        model=model, mesh=mesh, param_specs=pspecs, fn=fn,
        in_specs=(pspecs, bspecs), out_specs=(None, cache_specs),
        abstract_inputs=(abstract_params, specs))

  # decode
  cache_specs = bspecs["cache"]
  fn = jax.jit(
      make_serve_step(model),
      in_shardings=(shd.make_shardings(pspecs, mesh),
                    shd.make_shardings(bspecs, mesh)),
      out_shardings=(None, shd.make_shardings(cache_specs, mesh)),
      donate_argnums=(1,) if donate else ())
  return ShardedPrograms(
      model=model, mesh=mesh, param_specs=pspecs, fn=fn,
      in_specs=(pspecs, bspecs), out_specs=(None, cache_specs),
      abstract_inputs=(abstract_params, specs))
