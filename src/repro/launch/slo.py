"""SLO metrics layer for the workload harness: per-request latency targets
and the report the serving benchmarks assert on.

The PIM serving papers this repo tracks (LoL-PIM, PIM-AI — PAPERS.md)
evaluate long-context serving against *latency SLOs*, not raw throughput:
a request is only useful if its first token lands within a TTFT budget and
subsequent tokens keep up with a per-token (TPOT) budget.  This module owns
those definitions so the workload driver, the serve CLI, the benchmark
records, and CI all measure the same thing:

  ``SLOSpec``        the per-request targets (TTFT + TPOT seconds) and the
                     deadline they induce;
  ``RequestTiming``  one served request's virtual-time trajectory
                     (arrival -> admit -> first token -> finish) with the
                     derived TTFT / TPOT / queueing-delay metrics;
  ``build_report``   aggregates timings + the engine's virtual clock into
                     the ``workload`` record family: TTFT/TPOT/queue
                     percentiles, goodput (tokens served within deadline),
                     and stall-time attribution (compute vs transfer vs
                     idle) — the paper's 90-98.5% communication-share claim
                     as a per-run measured split.

Everything here is pure host-side arithmetic over virtual timestamps; no
wall clock, no RNG — two runs of the same seeded workload produce the
identical report.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class SLOSpec:
  """Per-request latency targets, LoL-PIM style.

  `ttft_s` bounds arrival -> first token (queueing + prefill); `tpot_s`
  bounds the steady-state per-token cadence.  Together they induce one
  deadline for the whole generation: a request that finishes past it
  produced no *good* tokens, however many it produced.
  """
  ttft_s: float = 0.5
  tpot_s: float = 0.05

  def deadline_s(self, arrival_s: float, max_new_tokens: int) -> float:
    return arrival_s + self.ttft_s + self.tpot_s * max(max_new_tokens, 1)


@dataclasses.dataclass
class RequestTiming:
  """One request's virtual-time trajectory and its derived SLO metrics.

  All timestamps are virtual-clock seconds.  `first_token_s` is when the
  prefill emitted token 0 (TTFT ends there); `finish_s` when the last token
  landed.  A `failed` request (dropped after bounded fetch retries) counts
  against goodput but keeps whatever timings it accumulated; a `shed` one
  (cancelled by SLO admission control) likewise — shedding trades those
  requests' zero-anyway goodput for the survivors' deadlines.
  """
  rid: int
  tenant: str
  arrival_s: float
  deadline_s: float
  max_new_tokens: int
  n_tokens: int = 0
  admit_s: Optional[float] = None
  first_token_s: Optional[float] = None
  finish_s: Optional[float] = None
  failed: bool = False
  shed: bool = False

  @property
  def ttft_s(self) -> Optional[float]:
    if self.first_token_s is None:
      return None
    return self.first_token_s - self.arrival_s

  @property
  def tpot_s(self) -> Optional[float]:
    """Mean per-token time after the first token (None for 1-token runs)."""
    if self.first_token_s is None or self.finish_s is None:
      return None
    if self.n_tokens <= 1:
      return None
    return (self.finish_s - self.first_token_s) / (self.n_tokens - 1)

  @property
  def queue_s(self) -> Optional[float]:
    if self.admit_s is None:
      return None
    return self.admit_s - self.arrival_s

  @property
  def met_deadline(self) -> bool:
    return (not self.failed and not self.shed and self.finish_s is not None
            and self.finish_s <= self.deadline_s + 1e-12)

  @property
  def good_tokens(self) -> int:
    """Tokens that count toward goodput: all of them iff the deadline held."""
    return self.n_tokens if self.met_deadline else 0


def percentiles_s(values: Sequence[Optional[float]]) -> dict:
  """p50/p99/mean over virtual seconds — the one percentile definition the
  workload record family uses (mirrors `timing.latency_percentiles_ms`)."""
  vals = [v for v in values if v is not None]
  if not vals:
    return dict(n=0, p50_s=None, p99_s=None, mean_s=None)
  a = np.asarray(vals, np.float64)
  return dict(n=int(a.size),
              p50_s=round(float(np.percentile(a, 50)), 6),
              p99_s=round(float(np.percentile(a, 99)), 6),
              mean_s=round(float(a.mean()), 6))


def _stall_attribution(clock) -> dict:
  """Where the run's virtual time went: decode/prefill compute, transfer
  stall (blocked on the modeled PCIe link), or idle (no work due)."""
  total = max(clock.now, 1e-12)
  return dict(
      virtual_s=round(clock.now, 6),
      compute_s=round(clock.compute_s, 6),
      transfer_stall_s=round(clock.transfer_stall_s, 6),
      idle_s=round(clock.idle_s, 6),
      link_busy_s=round(clock.link_busy_s, 6),
      compute_frac=round(clock.compute_s / total, 4),
      transfer_stall_frac=round(clock.transfer_stall_s / total, 4),
      idle_frac=round(clock.idle_s / total, 4))


def build_report(records: Sequence[RequestTiming], clock=None) -> dict:
  """The ``workload`` record: SLO percentiles + goodput + stall attribution.

  `clock` is the run's `workload.VirtualClock` (None for wall-clock-free
  callers; the stall section is then omitted).  Goodput is measured two
  ways: the fraction of served tokens that were *good* (whole-request
  deadline held) and those good tokens over the virtual makespan (tok/s).
  """
  records = list(records)
  total_tokens = sum(r.n_tokens for r in records)
  good_tokens = sum(r.good_tokens for r in records)
  met = sum(1 for r in records if r.met_deadline)
  out = dict(
      requests=len(records),
      failed=sum(1 for r in records if r.failed),
      shed=sum(1 for r in records if r.shed),
      tokens_total=total_tokens,
      tokens_within_deadline=good_tokens,
      goodput_frac=round(good_tokens / total_tokens, 4) if total_tokens
      else 0.0,
      deadline_met_frac=round(met / len(records), 4) if records else 0.0,
      ttft=percentiles_s([r.ttft_s for r in records]),
      tpot=percentiles_s([r.tpot_s for r in records]),
      queue=percentiles_s([r.queue_s for r in records]))
  if clock is not None:
    makespan = max(clock.now, 1e-12)
    out["goodput_tok_s"] = round(good_tokens / makespan, 2)
    out["served_tok_s"] = round(total_tokens / makespan, 2)
    out["stall"] = _stall_attribution(clock)
  per_tenant: Dict[str, List[RequestTiming]] = {}
  for r in records:
    per_tenant.setdefault(r.tenant, []).append(r)
  out["per_tenant"] = {
      name: dict(
          requests=len(rs),
          tokens=sum(r.n_tokens for r in rs),
          goodput_frac=round(sum(r.good_tokens for r in rs)
                             / max(sum(r.n_tokens for r in rs), 1), 4),
          ttft_p99_s=percentiles_s([r.ttft_s for r in rs])["p99_s"],
          queue_p99_s=percentiles_s([r.queue_s for r in rs])["p99_s"])
      for name, rs in sorted(per_tenant.items())}
  return out


def summary(report: dict) -> str:
  """One-line human rendering of a build_report() dict."""
  s = (f"{report['requests']} requests ({report['failed']} failed, "
       f"{report.get('shed', 0)} shed), "
       f"goodput {100 * report['goodput_frac']:.1f}% of "
       f"{report['tokens_total']} tokens "
       f"({100 * report['deadline_met_frac']:.1f}% of deadlines met)")
  if report["ttft"]["n"]:
    s += (f" | TTFT p50 {report['ttft']['p50_s'] * 1e3:.1f} / p99 "
          f"{report['ttft']['p99_s'] * 1e3:.1f} ms")
  if report["tpot"]["n"]:
    s += (f" | TPOT p50 {report['tpot']['p50_s'] * 1e3:.2f} / p99 "
          f"{report['tpot']['p99_s'] * 1e3:.2f} ms")
  stall = report.get("stall")
  if stall:
    s += (f" | time: {100 * stall['compute_frac']:.0f}% compute, "
          f"{100 * stall['transfer_stall_frac']:.0f}% transfer stall, "
          f"{100 * stall['idle_frac']:.0f}% idle")
  return s
