"""Production mesh construction (spec: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant — importing this module never touches jax
device state.  Callers (dryrun.py) are responsible for setting
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
  shape = (2, 16, 16) if multi_pod else (16, 16)
  axes = ("pod", "data", "model") if multi_pod else ("data", "model")
  return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: int | None = None):
  """Mesh over whatever devices exist (tests / CPU smoke / --mesh-model N).

  Axis sizes must tile the device count exactly: the old `data = n // model`
  silently built an (n//model, model) mesh that *dropped* devices whenever
  `model` did not divide n (or, with an explicit `data`, let `make_mesh`
  fail deep inside jax with an opaque reshape error).  Both are now loud,
  named errors at the call site.
  """
  n = len(jax.devices())
  if model < 1:
    raise ValueError(
        f"mesh model axis must be >= 1, got {model}; pass --mesh-model N "
        f"with N >= 1 (N=1 serves unsharded)")
  if n % model != 0:
    raise ValueError(
        f"model axis size {model} does not divide the device count {n}; "
        f"pass --mesh-model with a divisor of {n}, or force more host "
        f"devices via XLA_FLAGS=--xla_force_host_platform_device_count=N "
        f"(shard redundancy does not relax this: --shard-redundancy "
        f"host-mirror protects KV pages, it cannot invent devices)")
  if data is None:
    data = n // model
  if data * model != n:
    raise ValueError(
        f"mesh axes (data={data}, model={model}) cover {data * model} "
        f"devices but {n} exist; axis sizes must tile the device count "
        f"exactly — adjust --mesh-model (and the data axis) so "
        f"data * model == {n}")
  return jax.make_mesh((data, model), ("data", "model"))


def model_axis_size(mesh) -> int:
  """Size of the mesh's `model` axis (1 when the axis is absent)."""
  return int(dict(mesh.shape).get("model", 1))
