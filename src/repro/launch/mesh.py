"""Production mesh construction (spec: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant — importing this module never touches jax
device state.  Callers (dryrun.py) are responsible for setting
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
  shape = (2, 16, 16) if multi_pod else (16, 16)
  axes = ("pod", "data", "model") if multi_pod else ("data", "model")
  return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: int | None = None):
  """Mesh over whatever devices exist (tests / CPU smoke)."""
  n = len(jax.devices())
  if data is None:
    data = n // model
  return jax.make_mesh((data, model), ("data", "model"))
