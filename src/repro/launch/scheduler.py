"""Pluggable request schedulers for the continuous-batching serve engine.

PR 1's `ServeEngine` hard-coded FIFO admission; the ROADMAP names "a
scheduler smarter than FIFO" as an open scale item.  This module makes the
admit/preempt decision a string-keyed protocol, mirroring how KV methods
are `CachePolicy` keys and storage is a `CacheLayout` key:

    from repro.launch import scheduler
    sched = scheduler.make("paged")

| key      | admit order                  | on block exhaustion            |
|----------|------------------------------|--------------------------------|
| `fifo`   | submission order             | error (cannot preempt)         |
| `sjf`    | shortest prompt first        | error (cannot preempt)         |
| `paged`  | first request whose prompt   | preempt-and-requeue the        |
|          | fits the free block pool     | youngest running request       |
| `tiered` | first admissible request     | *spill* the LRU-coldest        |
|          | (fetch spilled, prefill new) | running request to the host    |
|          |                              | tier (recompute only if the    |
|          |                              | host pool is full)             |
| `prefix` | admissible request with the  | preempt-and-requeue the        |
|          | longest cached prefix first  | youngest running request       |
|          | (cache-hot admits first)     |                                |
| `slo`    | highest priority, earliest   | shed the lowest-priority       |
|          | deadline first (EDF within   | expired running request, else  |
|          | priority tiers)              | the tiered LRU spill choice    |

Schedulers see the engine read-only: the queue of `RequestHandle`s, the
active slots, and the layout's block pool.  The engine performs the actual
prefill/admit/preempt/spill/fetch; a scheduler only answers "which request
next?", "who yields when the pool runs dry?", and (tiered) "whose spilled
state should start fetching ahead of its admit?".
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence, Tuple

_SCHEDULERS: Dict[str, type] = {}


def register(name: str) -> Callable[[type], type]:
  def deco(cls: type) -> type:
    if name in _SCHEDULERS and _SCHEDULERS[name] is not cls:
      raise ValueError(f"scheduler {name!r} already registered")
    _SCHEDULERS[name] = cls
    cls.name = name
    return cls
  return deco


def get(name: str) -> type:
  try:
    return _SCHEDULERS[name]
  except KeyError:
    raise KeyError(
        f"unknown scheduler {name!r}; available: {names()}") from None


def make(name: str):
  return get(name)()


def names() -> Tuple[str, ...]:
  return tuple(sorted(_SCHEDULERS))


class Scheduler:
  """Admission-order + preemption protocol driving `ServeEngine.step`."""
  name: str = "base"
  #: True if this scheduler gates admission on the layout's block pool and
  #: resolves exhaustion by preempting (requires a pooled layout to matter).
  preemptive: bool = False
  #: True if exhaustion victims should *spill* to the host tier (swap
  #: preemption, KV preserved) instead of recompute-preempting (requires a
  #: tiered layout).
  spills: bool = False

  def pick(self, queue: Sequence, engine) -> Optional[int]:
    """Index into `queue` of the next request to admit, or None to wait."""
    raise NotImplementedError

  def on_exhausted(self, engine) -> Optional[int]:
    """Block pool ran dry mid-decode: slot to preempt-and-requeue, or None
    if this scheduler cannot preempt (the engine then raises)."""
    del engine
    return None

  def fetch_ahead(self, engine) -> Optional[int]:
    """Rid of a spilled queued request whose transfer should start now (one
    step before its admit), or None.  A hint: the engine may ignore it."""
    del engine
    return None

  def fetch_ahead_many(self, engine, depth: int) -> Sequence[int]:
    """Rids (up to `depth`) whose host->device transfers should be in
    flight now — the async double-buffered generalization of fetch_ahead
    used by the virtual-clock engine.  The engine skips rids that already
    have a transfer draining; still only a hint."""
    del depth
    rid = self.fetch_ahead(engine)
    return [] if rid is None else [rid]

  def shard_recovery_requeue(self, engine, reqs: Sequence) -> Sequence:
    """Order in which requests recovered from a shard loss re-enter the
    queue head (first element re-admits first).  Default: submission order
    — the fairness FIFO recovery owes requests that lost progress through
    no fault of their own."""
    del engine
    return sorted(reqs, key=lambda r: r.rid)

  def __repr__(self) -> str:
    return f"{type(self).__name__}()"


@register("fifo")
class FIFOScheduler(Scheduler):
  """Strict submission order (PR 1 behavior)."""

  def pick(self, queue, engine):
    del engine
    return 0 if queue else None


@register("sjf")
class SJFScheduler(Scheduler):
  """Shortest-prompt-first: minimizes mean wait under mixed prompt lengths
  (classic shortest-job-first, with prompt length as the job-size proxy)."""

  def pick(self, queue, engine):
    del engine
    if not queue:
      return None
    return min(range(len(queue)), key=lambda i: (queue[i].prompt_len,
                                                 queue[i].rid))


@register("paged")
class PagedScheduler(Scheduler):
  """Admit-on-available-blocks with preempt-and-requeue on exhaustion.

  Admission walks the queue in submission order and admits the first request
  whose prompt fits the free block pool (short requests may overtake one
  stuck long prompt, but nothing starves: blocks free monotonically as
  running requests finish).  When a decode step cannot grow every running
  request by a block, the *youngest* running request yields — it has the
  least work to redo under recompute-preemption — and is requeued at the
  queue head.  Never preempts the last running request: a request that fits
  the pool alone (checked at submit) can always finish solo.
  """
  preemptive = True

  def pick(self, queue, engine):
    for i, req in enumerate(queue):
      if engine.admissible(req):
        return i
    return None

  def on_exhausted(self, engine):
    active = [(req.admitted_step, req.rid, slot)
              for slot, req in engine.active_requests]
    if len(active) <= 1:
      return None
    return max(active)[2]


@register("prefix")
class PrefixScheduler(PagedScheduler):
  """Cache-affinity admission over the prefix index.

  Queued requests are scored by how many prompt tokens the prefix cache
  already holds for them (whole-prompt snapshot = the full prompt; chain
  match = matched blocks x block size); the admissible request with the
  longest cached prefix admits first, FIFO on ties — cache-hot requests
  reuse published blocks while they are still resident instead of queueing
  behind cold ones that will re-allocate them.  Admissibility accounts for
  sharing: a hit needs only its unshared suffix blocks.  Exhaustion falls
  back to the paged scheduler's youngest-yields recompute preemption
  (cached-block eviction itself lives in the index and prefers
  unreferenced leaves).  Works with the prefix cache off too (degrades to
  plain admit-on-available-blocks).
  """

  def pick(self, queue, engine):
    layout = engine.layout
    best, best_key = None, None
    for i, req in enumerate(queue):
      if req.spilled:
        if not layout.can_fetch(req.rid,
                                req.prompt_len + req.max_new_tokens):
          continue
        matched = req.prompt_len          # its KV is already materialized
      elif getattr(layout, "prefix_enabled", False):
        # one read-only plan per request: both the admissibility gate and
        # the cache-affinity score (no LRU touch from queue probes)
        plan = layout.prefix_plan(req.prompt,
                                  req.prompt_len + req.max_new_tokens)
        if plan["need"] > layout.free_blocks:
          continue
        matched = plan["matched_tokens"]
      else:
        if not engine.admissible(req):
          continue
        matched = 0
      key = (-matched, req.rid)           # longest cached prefix, FIFO ties
      if best_key is None or key < best_key:
        best, best_key = i, key
    return best


@register("tiered")
class TieredScheduler(Scheduler):
  """Spill-don't-recompute admission over a two-tier block pool.

  Admission walks the queue in submission order and admits the first
  request that is servable *right now*: a spilled request whose blocks fit
  back into the free device pool (fetch), or a fresh request whose prompt
  fits (prefill).  On exhaustion the LRU-coldest running request yields —
  its KV moves to the host tier through the spill codecs instead of being
  thrown away, so resuming costs one fetch, not a re-prefill (recompute
  preemption remains the engine's fallback when the host pool is full).
  Never victimizes the last running request.  `fetch_ahead` points the
  engine at the next spilled request one step before a slot frees for it,
  so the (modeled) PCIe transfer overlaps the step boundary.
  """
  preemptive = True
  spills = True

  def pick(self, queue, engine):
    for i, req in enumerate(queue):
      if engine.admissible(req):
        return i
    return None

  def on_exhausted(self, engine):
    active = engine.active_requests
    if len(active) <= 1:
      return None
    # LRU cold-victim via the layout's selection hook; ties (every active
    # slot is touched each decode step) fall back to youngest-admitted,
    # matching the paged scheduler's least-work-lost choice
    return engine.layout.lru_victim(
        active, tiebreak=lambda req: (-(req.admitted_step or 0), -req.rid))

  def fetch_ahead(self, engine):
    if engine.active_count >= engine.max_batch:
      return None                      # no slot will be free at next admit
    for req in engine.queue_view:
      if req.spilled:
        return req.rid                 # layout.prefetch no-ops if unready
    return None

  def fetch_ahead_many(self, engine, depth):
    """The next `depth` spilled queued requests, in queue order.  Unlike
    the one-step hint this does *not* gate on a free slot: under the
    overlapping virtual clock a transfer drains while every slot decodes,
    precisely so the data is resident the moment a slot frees (the engine's
    fetch_depth already bounds how many drain at once, and layout.prefetch
    refuses when the device pool lacks headroom)."""
    out = []
    for req in engine.queue_view:
      if req.spilled:
        out.append(req.rid)
        if len(out) >= depth:
          break
    return out


@register("slo")
class SLOScheduler(TieredScheduler):
  """Priority-then-deadline admission (EDF within each priority tier).

  Admissible queued requests order by (higher priority first, earliest
  deadline first, FIFO ties): under overload the engine's SLO shedding
  removes doomed work from the queue, and this ordering spends the slots
  that remain on the requests most likely to still meet their deadline —
  per-tenant fairness falls out of tenants carrying their own priorities
  and deadlines rather than a separate quota mechanism.  Requests without
  a deadline sort last within their priority tier.  Exhaustion prefers the
  lowest-priority *expired* active request as the victim (its tokens are
  already worthless; the engine sheds it outright under slo_enforce),
  falling back to the tiered LRU spill choice.
  """

  def pick(self, queue, engine):
    best, best_key = None, None
    for i, req in enumerate(queue):
      if not engine.admissible(req):
        continue
      dl = req.deadline_s if req.deadline_s is not None else float("inf")
      key = (-req.priority, dl, req.rid)
      if best_key is None or key < best_key:
        best, best_key = i, key
    return best

  def on_exhausted(self, engine):
    active = engine.active_requests
    if len(active) <= 1:
      return None
    clock = getattr(engine, "clock", None)
    if clock is not None:
      expired = [(req.priority, -(req.admitted_step or 0), slot)
                 for slot, req in active
                 if req.deadline_s is not None
                 and clock.now >= req.deadline_s]
      if expired:
        return min(expired)[2]
    return super().on_exhausted(engine)

  def shard_recovery_requeue(self, engine, reqs):
    """Recovered requests re-admit highest priority / tightest deadline
    first — the ones most likely to still make their SLO get the slots."""
    del engine
    return sorted(reqs, key=lambda r: (
        -r.priority,
        r.deadline_s if r.deadline_s is not None else float("inf"),
        r.rid))
