"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract memory / cost / collective statistics for the roofline analysis.

MUST be the process entry point (python -m repro.launch.dryrun ...): the first
two lines below pin 512 placeholder devices BEFORE any jax import, because jax
locks the device count on first init.  Nothing else in the repo sets XLA_FLAGS.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402  (imports must follow the env pin)
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax

from repro.common import compat
from repro.configs import ARCHS, get_arch
from repro.configs.base import ALL_SHAPES, ShapeConfig, smoke_shape
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
  n = 1
  for d in dims.split(","):
    if d:
      n *= int(d)
  return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
  """Sum output-shape bytes of every collective op in optimized HLO.

  `-start` ops are counted, `-done` skipped (async pairs).  Tuple outputs
  contribute each element.
  """
  totals = {op: 0 for op in COLLECTIVE_OPS}
  counts = {op: 0 for op in COLLECTIVE_OPS}
  for line in hlo_text.splitlines():
    stripped = line.strip()
    m = re.match(r"^(%?[\w.\-]+)\s*=\s*(.*)$", stripped)
    if not m:
      continue
    rhs = m.group(2)
    for op in COLLECTIVE_OPS:
      # match "<shape(s)> <op>(" or "<shape(s)> <op>-start("
      opm = re.search(r"^\(?([^)]*?)\)?\s+" + re.escape(op)
                      + r"(-start)?\(", rhs)
      if opm and f" {op}-done(" not in rhs:
        shapes = _SHAPE_RE.findall(opm.group(1))
        b = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        totals[op] += b
        counts[op] += 1
        break
  totals_all = sum(totals.values())
  return {"by_op": totals, "counts": counts, "total_bytes": totals_all}


def run_cell(arch: str, shape: ShapeConfig, multi_pod: bool,
             pq: bool = True, reduced: bool = False,
             print_analysis: bool = True,
             overrides: dict | None = None) -> dict:
  """Lower + compile one cell; return the roofline record."""
  cfg = get_arch(arch, reduced=reduced)
  if not pq:
    cfg = dataclasses.replace(cfg, pq_enabled=False)
  if overrides:
    cfg = dataclasses.replace(cfg, **overrides)
  mesh = make_production_mesh(multi_pod=multi_pod)

  rec = {
      "arch": arch, "shape": shape.name, "kind": shape.kind,
      "overrides": dict(overrides or {}),
      "mesh": "2x16x16" if multi_pod else "16x16",
      "chips": int(mesh.size), "pq": pq and cfg.supports_pq,
      "seq_len": shape.seq_len, "global_batch": shape.global_batch,
  }
  t0 = time.monotonic()
  with mesh:
    progs = steps_lib.build_programs(cfg, shape, mesh, donate=False)
    lowered = progs.fn.lower(*progs.abstract_inputs)
    rec["lower_s"] = round(time.monotonic() - t0, 2)
    t1 = time.monotonic()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.monotonic() - t1, 2)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        k: int(getattr(mem, k))
        for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "generated_code_size_in_bytes")
        if hasattr(mem, k)}
    cost = compat.normalize_cost_analysis(compiled.cost_analysis())
    rec["cost"] = {k: float(v) for k, v in cost.items()
                   if isinstance(v, (int, float))}
    hlo = compiled.as_text()
    rec["collectives"] = collective_bytes(hlo)
    rec["hlo_lines"] = hlo.count("\n")

    if print_analysis:
      print(f"--- {arch} x {shape.name} x {rec['mesh']} "
            f"(pq={rec['pq']}) ---")
      print("memory_analysis:", rec["memory"])
      print("cost_analysis flops=%.3e bytes=%.3e" % (
          rec["cost"].get("flops", 0.0),
          rec["cost"].get("bytes accessed", 0.0)))
      print("collectives:", rec["collectives"]["by_op"],
            "total=%.3e" % rec["collectives"]["total_bytes"])
  return rec


def shape_by_name(name: str) -> ShapeConfig:
  for s in ALL_SHAPES:
    if s.name == name:
      return s
  if name.startswith("smoke"):
    return smoke_shape(name.split("_")[1] if "_" in name else "train")
  raise KeyError(name)


def main():
  ap = argparse.ArgumentParser(description=__doc__)
  ap.add_argument("--arch", default="all",
                  help="arch id or 'all'")
  ap.add_argument("--shape", default="all",
                  help="shape name or 'all'")
  ap.add_argument("--mesh", default="single",
                  choices=["single", "multi", "both"])
  ap.add_argument("--no-pq", action="store_true",
                  help="baseline: exact (uncompressed) KV cache")
  ap.add_argument("--reduced", action="store_true",
                  help="smoke-scale configs (plumbing check)")
  ap.add_argument("--out", default="benchmarks/results/dryrun",
                  help="directory for per-cell JSON records")
  ap.add_argument("--set", action="append", default=[],
                  help="config override key=value (e.g. weight_quant=int8, "
                       "pq_k=256, parallel_block=true) — for Perf variants")
  ap.add_argument("--tag", default="", help="suffix for output JSON names")
  args = ap.parse_args()

  overrides = {}
  for kv in args.set:
    k, v = kv.split("=", 1)
    if v.lower() in ("true", "false"):
      overrides[k] = v.lower() == "true"
    else:
      try:
        overrides[k] = int(v)
      except ValueError:
        overrides[k] = v

  archs = list(ARCHS) if args.arch == "all" else [args.arch]
  shapes = (list(ALL_SHAPES) if args.shape == "all"
            else [shape_by_name(args.shape)])
  meshes = {"single": [False], "multi": [True],
            "both": [False, True]}[args.mesh]

  os.makedirs(args.out, exist_ok=True)
  failures = []
  for arch in archs:
    for shape in shapes:
      for multi in meshes:
        tag = f"{arch}__{shape.name}__{'multi' if multi else 'single'}" \
              + ("__nopq" if args.no_pq else "") \
              + (f"__{args.tag}" if args.tag else "")
        out_path = os.path.join(args.out, tag + ".json")
        try:
          rec = run_cell(arch, shape, multi, pq=not args.no_pq,
                         reduced=args.reduced, overrides=overrides)
          with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
          print(f"[ok] {tag}  lower={rec['lower_s']}s "
                f"compile={rec['compile_s']}s")
        except Exception as e:  # noqa: BLE001 — record and continue the sweep
          traceback.print_exc()
          failures.append((tag, repr(e)))
          print(f"[FAIL] {tag}: {e}")
  if failures:
    print(f"\n{len(failures)} FAILURES:")
    for tag, err in failures:
      print(" ", tag, err[:200])
    raise SystemExit(1)
  print("\nall cells passed")


if __name__ == "__main__":
  main()
