"""Continuous-batching serve engine on top of the unified CachePolicy API.

The fixed-batch demo loop in `launch.serve` decodes B requests in lockstep:
all prompts share one length and all finish together.  Real serving (the
ROADMAP north star; LoL-PIM/PIMphony-style long-context PIM serving) needs
*continuous batching*: a request queue, slot-based admit/finish between
jitted decode steps, and per-slot length tracking.  That is what this
module provides:

    engine = ServeEngine(cfg, context_len=256, max_batch=4)
    h1 = engine.submit([12, 7, 99, ...], max_new_tokens=16)
    h2 = engine.submit(prompt2, max_new_tokens=4)       # any prompt length
    while engine.has_work:
      for done in engine.step():
        print(done.rid, done.tokens)

Mechanics
---------
- One jitted batch=1 prefill (prompts right-padded to `prompt_capacity`),
  one jitted batch=`max_batch` decode step, and one jitted donated
  slot-insert — three compiles total, regardless of how many requests
  stream through.
- The decode cache is a single batched tree (leaves (L, B, ...)); admitting
  a request writes its prefilled slot-cache into batch row `slot`, so
  requests at different positions coexist in one `decode_step` thanks to the
  per-request `lengths` vector threaded through the CachePolicy API.
- Greedy sampling; inactive slots decode garbage that is simply discarded
  (their rows are overwritten at the next admit).

Families with sequence-recurrent prefill state (ssm/hybrid) or extra modal
streams (vlm/audio) are not admitted — right-padded prefill would corrupt
their recurrent state.  Dense and MoE architectures are supported.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import Model


@dataclasses.dataclass
class RequestHandle:
  """One submitted generation request; `tokens` fills in as it decodes."""
  rid: int
  prompt: np.ndarray                 # (prompt_len,) int32
  max_new_tokens: int
  tokens: List[int] = dataclasses.field(default_factory=list)
  done: bool = False
  slot: Optional[int] = None
  admitted_step: Optional[int] = None
  finished_step: Optional[int] = None

  @property
  def prompt_len(self) -> int:
    return int(self.prompt.shape[0])


class ServeEngine:
  """Slot-based continuous batching over `Model.prefill` / `Model.decode_step`."""

  def __init__(self, cfg: ModelConfig, *, context_len: int = 256,
               max_batch: int = 4, prompt_capacity: Optional[int] = None,
               params: Any = None, seed: int = 0):
    if cfg.family not in ("dense", "moe"):
      raise ValueError(
          f"ServeEngine supports dense/moe attention families, got "
          f"{cfg.family!r} (recurrent prefill state cannot be right-padded)")
    if cfg.frontend != "none":
      raise ValueError("ServeEngine does not manage modal input streams")
    self.cfg = cfg
    self.context_len = context_len
    self.max_batch = max_batch
    self.prompt_capacity = prompt_capacity or max(context_len // 2,
                                                  cfg.pq_sink + cfg.pq_recent)
    if not self.prompt_capacity < context_len:
      raise ValueError(
          f"prompt_capacity {self.prompt_capacity} must be < context_len "
          f"{context_len}")
    if (cfg.resolved_cache_policy() == "pq"
        and self.prompt_capacity < cfg.pq_sink + cfg.pq_recent):
      raise ValueError(
          f"pq policy needs prompt_capacity >= sink+recent "
          f"({cfg.pq_sink}+{cfg.pq_recent}), got {self.prompt_capacity}")
    self.model = Model(cfg, context_len=context_len)

    if params is None:
      params = jax.jit(self.model.init)(jax.random.PRNGKey(seed))
    self.params = params
    self._prefill = jax.jit(
        lambda p, t, ln: self.model.prefill(p, t, None, lengths=ln))
    # caches are donated on both hot paths: decode updates in place instead
    # of reallocating the full (L, B, context) KV tree every token
    self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))
    # slot is a traced operand (one compile covers every slot) and the batched
    # cache is donated, so admission updates buffers in place instead of
    # copying the whole tree per admit
    self._insert = jax.jit(
        lambda cache, c1, slot: jax.tree_util.tree_map(
            lambda c, x: jax.lax.dynamic_update_slice_in_dim(
                c, x.astype(c.dtype), slot, axis=1), cache, c1),
        donate_argnums=(0,))

    self.cache = self.model.init_cache(max_batch)
    self._lengths = np.zeros((max_batch,), np.int32)
    self._cur = np.zeros((max_batch,), np.int32)
    self._slots: List[Optional[RequestHandle]] = [None] * max_batch
    self._queue: collections.deque = collections.deque()
    self._next_rid = 0
    self._step_no = 0

  # -------------------------------------------------------------------------
  # public API
  # -------------------------------------------------------------------------

  def submit(self, prompt: Sequence[int], max_new_tokens: int = 16
             ) -> RequestHandle:
    prompt = np.asarray(prompt, np.int32).reshape(-1)
    if not 0 < prompt.shape[0] <= self.prompt_capacity:
      raise ValueError(
          f"prompt length {prompt.shape[0]} not in (0, {self.prompt_capacity}]")
    if max_new_tokens < 1:
      raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
    if prompt.shape[0] + max_new_tokens > self.context_len:
      raise ValueError("prompt + max_new_tokens exceeds context capacity")
    req = RequestHandle(rid=self._next_rid, prompt=prompt,
                        max_new_tokens=max_new_tokens)
    self._next_rid += 1
    self._queue.append(req)
    return req

  @property
  def has_work(self) -> bool:
    return bool(self._queue) or any(r is not None for r in self._slots)

  @property
  def active_count(self) -> int:
    return sum(r is not None for r in self._slots)

  def step(self) -> List[RequestHandle]:
    """Admit queued requests into free slots, run one batched decode step,
    and return the requests that finished this step."""
    finished = self._admit()
    if self.active_count == 0:
      self._step_no += 1
      return finished

    logits, self.cache = self._decode(
        self.params, jnp.asarray(self._cur), self.cache,
        jnp.asarray(self._lengths))
    next_tok = np.asarray(jnp.argmax(logits, axis=-1), np.int32)

    for slot, req in enumerate(self._slots):
      if req is None:
        continue
      # the token we just fed (cur) is now cached at position lengths[slot]
      self._lengths[slot] += 1
      tok = int(next_tok[slot])
      req.tokens.append(tok)
      self._cur[slot] = tok
      if (len(req.tokens) >= req.max_new_tokens
          or int(self._lengths[slot]) + 1 >= self.context_len):
        finished.append(self._finish(slot, req))
    self._step_no += 1
    return finished

  def run_to_completion(self, max_steps: int = 10_000) -> List[RequestHandle]:
    """Drive `step()` until queue and slots drain; returns finish order."""
    done: List[RequestHandle] = []
    steps = 0
    while self.has_work:
      done.extend(self.step())
      steps += 1
      if steps > max_steps:
        raise RuntimeError(f"engine did not drain within {max_steps} steps")
    return done

  # -------------------------------------------------------------------------
  # internals
  # -------------------------------------------------------------------------

  def _admit(self) -> List[RequestHandle]:
    """Prefill queued requests into free slots (one compile: fixed pad)."""
    finished = []
    for slot in range(self.max_batch):
      if self._slots[slot] is not None or not self._queue:
        continue
      req = self._queue.popleft()
      padded = np.zeros((1, self.prompt_capacity), np.int32)
      padded[0, :req.prompt_len] = req.prompt
      logits, slot_cache = self._prefill(
          self.params, jnp.asarray(padded),
          jnp.asarray([req.prompt_len], jnp.int32))
      self.cache = self._insert(self.cache, slot_cache,
                                jnp.asarray(slot, jnp.int32))
      first = int(np.asarray(jnp.argmax(logits[0], axis=-1)))
      req.slot = slot
      req.admitted_step = self._step_no
      req.tokens.append(first)
      self._slots[slot] = req
      self._lengths[slot] = req.prompt_len
      self._cur[slot] = first
      if len(req.tokens) >= req.max_new_tokens:
        finished.append(self._finish(slot, req))
    return finished

  def _finish(self, slot: int, req: RequestHandle) -> RequestHandle:
    req.done = True
    req.finished_step = self._step_no
    self._slots[slot] = None
    self._lengths[slot] = 0
    self._cur[slot] = 0
    return req
