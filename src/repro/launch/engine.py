"""Continuous-batching serve engine over CacheLayout storage + a Scheduler.

The fixed-batch demo loop in `launch.serve` decodes B requests in lockstep:
all prompts share one length and all finish together.  Real serving (the
ROADMAP north star; LoL-PIM/PIMphony-style long-context PIM serving) needs
*continuous batching*: a request queue, slot-based admit/finish between
jitted decode steps, and per-slot length tracking.  That is what this
module provides:

    engine = ServeEngine(cfg, context_len=256, max_batch=4)
    h1 = engine.submit([12, 7, 99, ...], max_new_tokens=16)
    h2 = engine.submit(prompt2, max_new_tokens=4)       # any prompt length
    while engine.has_work:
      for done in engine.step():
        print(done.rid, done.tokens)
    print(engine.stats.summary())

Storage and policy are split along the PR 2 API boundary:

- *What* is cached is the `CachePolicy` codec (`cfg.cache_policy`: exact,
  AQPIM pq, skvq, ...).
- *Where* it lives is the `CacheLayout` (`cfg.cache_layout` /
  `cache_layout=` kwarg): `contiguous` capacity-sized slabs per slot,
  `paged` fixed-size token blocks from a shared `BlockAllocator` pool, or
  `tiered` — paged storage over a two-tier refcounted pool (device + host)
  with compressed spill/fetch through the policy's spill codecs.
- *Who runs next* is the `Scheduler` (`cfg.scheduler` / `scheduler=`):
  `fifo`, `sjf`, `paged` (admit-on-available-blocks, preempt-and-requeue
  on pool exhaustion — recompute preemption: a preempted request is re-
  prefilled from its prompt and, under greedy decoding, regenerates the
  identical tokens), `tiered` (swap preemption: the LRU-coldest victim's
  KV spills to the host tier and a later fetch resumes it mid-decode — no
  recompute; `engine.stats` counts spills/fetches, the bytes that crossed,
  and the PCIe time they model), or `prefix` (longest-cached-prefix-first
  cache-affinity admission).
- *What is already known* is the prefix cache (`cfg.prefix_cache` /
  `prefix_cache=`; pooled layouts only): admission looks the prompt up in
  the layout's `PrefixIndex` and either restores a whole-prompt snapshot
  (zero prefill — the first greedy token was published with it), shares
  the matched block chain copy-on-write and prefills **only the uncached
  suffix** through a fixed-shape chunked-prefill jit, or falls back to the
  ordinary full prefill.  All three paths produce bit-identical greedy
  tokens; `engine.stats` counts hits, hit tokens, cow-forks, and deduped
  bytes.

Mechanics
---------
- One jitted batch=1 prefill (prompts right-padded to `prompt_capacity`)
  plus the layout's own compiled programs (slot-insert and decode for
  contiguous; admit-scatter and gather->decode->scatter for paged) — a
  fixed number of compiles regardless of how many requests stream through.
- Per-request `lengths` thread through the CachePolicy API so requests at
  different positions coexist in one decode step.
- Greedy sampling.  Inactive slots still burn a decode lane; `engine.stats`
  now counts that waste (occupancy, wasted slot-steps, admits/preempts)
  instead of letting it pass silently.

Families with sequence-recurrent prefill state (ssm/hybrid) or extra modal
streams (vlm/audio) are not admitted — right-padded prefill would corrupt
their recurrent state.  Dense and MoE architectures are supported.
"""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.common import timing
from repro.configs.base import ModelConfig
from repro.core import cache_registry
from repro.core import tiers as tiersmod
from repro.launch import mesh as mesh_lib
from repro.launch import scheduler as scheduler_lib
from repro.models import Model
from repro.parallel import serve_sharding as ssh
from repro.runtime import fault_tolerance


@dataclasses.dataclass
class RequestHandle:
  """One submitted generation request; `tokens` fills in as it decodes."""
  rid: int
  prompt: np.ndarray                 # (prompt_len,) int32
  max_new_tokens: int
  tokens: List[int] = dataclasses.field(default_factory=list)
  done: bool = False
  slot: Optional[int] = None
  admitted_step: Optional[int] = None
  finished_step: Optional[int] = None
  preempt_count: int = 0             # recompute preemptions (KV discarded)
  spilled: bool = False              # KV currently on the host tier
  spill_count: int = 0               # swap-outs (KV preserved across them)
  resume_len: int = 0                # cached length at swap-out
  resume_cur: int = 0                # pending token at swap-out
  # fault-tolerance (host-tier fetch faults, workload-harness injectable)
  fetch_failures: int = 0            # transient fetch faults survived so far
  failed: bool = False               # dropped after bounded fetch retries
  # SLO admission control (PR 9): deadline from SLOSpec, tenant priority,
  # and whether the engine shed this request instead of finishing it
  deadline_s: Optional[float] = None
  tenant: str = "default"
  priority: int = 0                  # higher sheds later under pressure
  shed: bool = False                 # cancelled by SLO/pressure shedding
  # virtual-clock timestamps (None on wall-clock engines); the workload
  # harness folds these into per-request TTFT/TPOT/queueing SLO metrics
  submitted_step: Optional[int] = None
  submit_s: Optional[float] = None   # arrival (stamped by the driver)
  admit_s: Optional[float] = None    # first admission (queueing delay ends)
  first_token_s: Optional[float] = None
  finish_s: Optional[float] = None

  @property
  def prompt_len(self) -> int:
    return int(self.prompt.shape[0])


@dataclasses.dataclass
class EngineStats:
  """Per-run engine counters (the wasted-compute blind spot, quantified)."""
  max_batch: int
  # mesh-sharded serving (PR 7): shard count and partition mode of the run's
  # ShardPlan ("none" | "heads" | "seq"); 1/"none" on single-device engines
  mesh_shards: int = 1
  mesh_mode: str = "none"
  steps: int = 0                 # step() calls, including idle ones
  decode_steps: int = 0          # batched decode launches
  busy_slot_steps: int = 0       # slot-steps that advanced a live request
  wasted_slot_steps: int = 0     # slot-steps that decoded garbage (idle lane)
  admits: int = 0
  preempts: int = 0              # recompute preemptions (tokens regenerated)
  finished: int = 0
  blocks_reclaimed: int = 0      # ring-reuse frees (paged streaming window)
  # tiered-layout spill/fetch accounting (zero on single-tier layouts)
  spills: int = 0                # swap-outs to the host tier (KV preserved)
  fetches: int = 0               # swap-ins from the host tier
  prefetches: int = 0            # fetch-ahead transfers started early
  spill_bytes: int = 0           # device -> host, post-spill-codec
  fetch_bytes: int = 0           # host -> device, post-spill-codec
  modeled_pcie_s: float = 0.0    # time that traffic would occupy the link
  fetch_failures: int = 0        # injected/transient fetch faults (requeued)
  fetch_aborts: int = 0          # IN_FLIGHT transfers rolled back to SPILLED
  failed_requests: int = 0       # dropped after exhausting bounded retries
  # multi-surface fault injection + SLO shedding (PR 9)
  shed_requests: int = 0         # cancelled by deadline/pressure shedding
  pressure_sheds: int = 0        # sheds triggered by pool exhaustion
  alloc_spikes: int = 0          # transient allocator-exhaustion injections
  decode_faults: int = 0         # transient decode-step faults retried
  corrupt_pages: int = 0         # corrupted spill pages detected + recovered
  restored_prefix_blocks: int = 0  # prefix blocks revived from a snapshot
  # shard fault tolerance (PR 10): watchdog + degraded-mesh replan counters
  shard_losses: int = 0          # shards confirmed dead by the watchdog
  shard_stalls: int = 0          # one-round shard straggles injected
  shard_replans: int = 0         # degraded-mesh re-plans adopted
  shard_mirror_restores: int = 0   # slots rebuilt from the host mirror
  shard_recovered_requests: int = 0  # requests recovered (mirror or recompute)
  dead_shards: List[int] = dataclasses.field(default_factory=list)
  shard_heartbeats: List[int] = dataclasses.field(default_factory=list)
  # graceful-degradation state machine: current state plus the transition
  # log (bounded; each entry records step/virtual time/old/new)
  degradation_state: str = "NORMAL"
  degradation_transitions: List[dict] = dataclasses.field(
      default_factory=list)
  # virtual-clock accounting (zero on wall-clock engines): where the run's
  # simulated makespan went — the stall-attribution split the SLO report
  # and the workload benchmark records break out
  virtual_s: float = 0.0         # simulated makespan so far
  compute_s: float = 0.0         # decode + prefill virtual time
  transfer_stall_s: float = 0.0  # blocked on the modeled PCIe link
  idle_s: float = 0.0            # no work due (waiting on arrivals)
  link_busy_s: float = 0.0       # link occupancy (overlapped or stalled)
  # prefix-cache accounting (zero when --prefix-cache is off)
  prefix_hits: int = 0           # admissions that matched the prefix index
  prefix_full_hits: int = 0      # whole-prompt hits (prefill skipped)
  prefix_hit_tokens: int = 0     # prompt tokens served from cached blocks
  prefill_tokens: int = 0        # prompt tokens actually prefilled (computed)
  forked_blocks: int = 0         # copy-on-write forks of shared blocks
  dedup_bytes: int = 0           # peak bytes saved by multi-mapped blocks
  # wall-clock per batched decode step (launch -> next-token sync), the
  # distribution CI's p99 regression guard watches.  Bounded: a long-lived
  # engine keeps the most recent window of samples, not its whole history
  decode_step_s: collections.deque = dataclasses.field(
      default_factory=lambda: collections.deque(maxlen=4096), repr=False)
  # queue gauges the workload harness reads: depth sampled once per step(),
  # and per-request waiting time (submit -> first admit) in engine steps.
  # Same bounded-window policy as decode_step_s
  queue_depth_samples: collections.deque = dataclasses.field(
      default_factory=lambda: collections.deque(maxlen=4096), repr=False)
  queue_wait_steps: collections.deque = dataclasses.field(
      default_factory=lambda: collections.deque(maxlen=4096), repr=False)

  @property
  def occupancy(self) -> float:
    """Fraction of decode lanes that did useful work."""
    lanes = self.decode_steps * self.max_batch
    return self.busy_slot_steps / lanes if lanes else 0.0

  @property
  def prefix_hit_rate(self) -> float:
    """Fraction of submitted prompt tokens served from the prefix cache."""
    total = self.prefix_hit_tokens + self.prefill_tokens
    return self.prefix_hit_tokens / total if total else 0.0

  def decode_latency(self) -> dict:
    """Per-step decode latency percentiles (ms) over this run.

    Samples are raw wall clock: a cold step that traced+compiled is counted
    as-is.  Callers that want steady-state numbers drain a warmup request
    first and then reset the stats (`engine.stats = EngineStats(...)`) —
    the serve CLI demo and the benchmark harness both do."""
    return timing.latency_percentiles_ms(self.decode_step_s)

  def queue_gauges(self) -> dict:
    """Queue-pressure snapshot over the sample windows: current/mean/max
    depth and mean/max per-request waiting time (in engine steps)."""
    depth = list(self.queue_depth_samples)
    wait = list(self.queue_wait_steps)
    return dict(
        depth_now=int(depth[-1]) if depth else 0,
        depth_mean=round(float(np.mean(depth)), 3) if depth else 0.0,
        depth_max=int(max(depth)) if depth else 0,
        wait_steps_mean=round(float(np.mean(wait)), 3) if wait else 0.0,
        wait_steps_max=int(max(wait)) if wait else 0,
        depth_samples=len(depth), wait_samples=len(wait))

  def as_dict(self) -> dict:
    """Read-only snapshot: a fresh dict every call, counters untouched.
    Deque-valued fields (raw sample windows) are excluded *by type*, not by
    name — new gauges stay in-process automatically instead of leaking
    unserializable deques into stats-json (the old name-based filter only
    knew about decode_step_s)."""
    d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)
         if not isinstance(getattr(self, f.name), collections.deque)}
    d["occupancy"] = round(self.occupancy, 4)
    d["prefix_hit_rate"] = round(self.prefix_hit_rate, 4)
    d["decode_latency"] = self.decode_latency()
    d["queue"] = self.queue_gauges()
    return d

  def summary(self) -> str:
    s = (f"occupancy {100 * self.occupancy:.1f}% "
         f"({self.busy_slot_steps}/{self.decode_steps * self.max_batch} "
         f"slot-steps, {self.wasted_slot_steps} wasted) | "
         f"admits {self.admits}, preempts {self.preempts}, "
         f"finished {self.finished}, reclaimed {self.blocks_reclaimed} "
         f"blocks")
    lat = self.decode_latency()
    if lat["steps"]:
      s += (f" | decode step p50 {lat['p50_ms']:.2f} ms / "
            f"p99 {lat['p99_ms']:.2f} ms")
    if self.spills or self.fetches:
      s += (f" | spills {self.spills} ({self.spill_bytes} B), fetches "
            f"{self.fetches} ({self.fetch_bytes} B, {self.prefetches} "
            f"ahead), ~{self.modeled_pcie_s * 1e3:.2f} ms PCIe")
    if self.prefix_hits:
      s += (f" | prefix hits {self.prefix_hits} ({self.prefix_full_hits} "
            f"full), {100 * self.prefix_hit_rate:.1f}% of prompt tokens "
            f"cached, {self.forked_blocks} cow-forks, {self.dedup_bytes} B "
            f"deduped")
    if self.fetch_failures or self.failed_requests:
      s += (f" | fetch faults {self.fetch_failures} "
            f"({self.fetch_aborts} aborts, {self.failed_requests} requests "
            f"dropped)")
    if self.shed_requests or self.degradation_transitions:
      s += (f" | shed {self.shed_requests} ({self.pressure_sheds} under "
            f"pressure), degradation {self.degradation_state} "
            f"({len(self.degradation_transitions)} transitions)")
    if self.decode_faults or self.corrupt_pages or self.alloc_spikes:
      s += (f" | faults: {self.decode_faults} decode retried, "
            f"{self.corrupt_pages} corrupt pages recovered, "
            f"{self.alloc_spikes} alloc spikes")
    if self.restored_prefix_blocks:
      s += f" | restored {self.restored_prefix_blocks} prefix blocks"
    if self.shard_losses or self.shard_stalls:
      s += (f" | shard faults: {self.shard_losses} lost "
            f"(dead {self.dead_shards}), {self.shard_stalls} stalled, "
            f"{self.shard_replans} replans, "
            f"{self.shard_mirror_restores} mirror restores, "
            f"{self.shard_recovered_requests} requests recovered")
    if self.virtual_s:
      s += (f" | virtual {self.virtual_s:.3f} s "
            f"({1e3 * self.compute_s:.1f} ms compute, "
            f"{1e3 * self.transfer_stall_s:.1f} ms transfer stall, "
            f"{1e3 * self.idle_s:.1f} ms idle)")
    if self.mesh_shards > 1:
      s += f" | mesh {self.mesh_shards}-way ({self.mesh_mode})"
    return s


#: Graceful-degradation states, escalation order.
DEGRADATION_STATES = ("NORMAL", "PRESSURED", "SHEDDING")


class DegradationController:
  """NORMAL -> PRESSURED -> SHEDDING state machine over pool pressure.

  Observes free-block fraction and queue depth once per engine step and
  moves one state at a time, each direction gated by a sustain count — a
  single tight step cannot flip the engine into shedding, and one lucky
  step cannot flip it back (hysteresis).  What each state *does* lives in
  the engine: PRESSURED progressively evicts prefix-cache entries and
  stops admitting already-expired work; SHEDDING additionally cancels
  queued requests that provably cannot meet their deadline.
  """
  PRESSURE_FREE_FRAC = 0.25    # escalate NORMAL -> PRESSURED below this
  SHED_FREE_FRAC = 0.10        # escalate PRESSURED -> SHEDDING below this
  SUSTAIN = 2                  # consecutive observations to move one state

  def __init__(self):
    self.state = "NORMAL"
    self._up = 0
    self._down = 0

  def observe(self, free_frac: float,
              queue_depth: int) -> Optional[Tuple[str, str]]:
    """Feed one step's pressure reading; returns (old, new) on transition."""
    if free_frac <= self.SHED_FREE_FRAC and queue_depth > 0:
      want = 2
    elif free_frac <= self.PRESSURE_FREE_FRAC:
      want = 1
    else:
      want = 0
    cur = DEGRADATION_STATES.index(self.state)
    if want > cur:
      self._up, self._down = self._up + 1, 0
      if self._up >= self.SUSTAIN:
        old, self.state = self.state, DEGRADATION_STATES[cur + 1]
        self._up = 0
        return (old, self.state)
    elif want < cur:
      self._down, self._up = self._down + 1, 0
      if self._down >= self.SUSTAIN:
        old, self.state = self.state, DEGRADATION_STATES[cur - 1]
        self._down = 0
        return (old, self.state)
    else:
      self._up = self._down = 0
    return None


class ServeEngine:
  """Slot-based continuous batching over `Model.prefill` / `Model.decode_step`."""

  def __init__(self, cfg: ModelConfig, *, context_len: int = 256,
               max_batch: int = 4, prompt_capacity: Optional[int] = None,
               params: Any = None, seed: int = 0,
               cache_layout: Optional[str] = None,
               scheduler: Optional[str] = None,
               block_size: Optional[int] = None,
               num_blocks: Optional[int] = None,
               host_blocks: Optional[int] = None,
               prefix_cache: Optional[bool] = None,
               prefix_cache_blocks: Optional[int] = None,
               clock: Any = None,
               fault_injector: Any = None,
               max_fetch_retries: int = 3,
               max_decode_retries: int = 3,
               slo_enforce: bool = False,
               snapshot_dir: Optional[str] = None,
               mesh: Any = None,
               mesh_model: Optional[int] = None,
               shard_redundancy: str = "none",
               shard_confirm_after: int = 2):
    if cfg.family not in ("dense", "moe"):
      raise ValueError(
          f"ServeEngine supports dense/moe attention families, got "
          f"{cfg.family!r} (recurrent prefill state cannot be right-padded)")
    if cfg.frontend != "none":
      raise ValueError("ServeEngine does not manage modal input streams")
    self.cfg = cfg
    self.context_len = context_len
    self.max_batch = max_batch
    self.prompt_capacity = prompt_capacity or max(context_len // 2,
                                                  cfg.pq_sink + cfg.pq_recent)
    if not self.prompt_capacity < context_len:
      raise ValueError(
          f"prompt_capacity {self.prompt_capacity} must be < context_len "
          f"{context_len}")
    if (cfg.resolved_cache_policy() == "pq"
        and self.prompt_capacity < cfg.pq_sink + cfg.pq_recent):
      raise ValueError(
          f"pq policy needs prompt_capacity >= sink+recent "
          f"({cfg.pq_sink}+{cfg.pq_recent}), got {self.prompt_capacity}")

    layout_name = cache_layout or cfg.cache_layout
    sched_name = scheduler or cfg.scheduler
    self.scheduler = scheduler_lib.make(sched_name)
    layout_cls = cache_registry.get_layout(layout_name)
    if self.scheduler.preemptive and not layout_cls.pooled:
      raise ValueError(
          f"scheduler {sched_name!r} gates admission on the block pool; "
          f"it requires cache_layout='paged' or 'tiered', got "
          f"{layout_name!r}")
    if self.scheduler.spills and not layout_cls.spills:
      raise ValueError(
          f"scheduler {sched_name!r} spills victims to the host tier; "
          f"it requires cache_layout='tiered', got {layout_name!r}")

    # mesh-sharded serving (PR 7): resolve the partition plan before any
    # storage is built so placement, dispatch resolution, and the decode
    # shard_map all see the same frozen decision
    if mesh is None and mesh_model is not None and mesh_model > 1:
      mesh = mesh_lib.make_local_mesh(model=mesh_model)
    self.shard_plan = None if mesh is None else ssh.plan_for(cfg, mesh)
    plan_active = self.shard_plan is not None and self.shard_plan.active
    if plan_active and not layout_cls.pooled:
      raise ValueError(
          f"sharded serving (mesh model axis "
          f"{self.shard_plan.size}) partitions the block pool; it requires "
          f"cache_layout='paged' or 'tiered', got {layout_name!r} — pass "
          f"--cache-layout paged/tiered, or drop --mesh-model to 1 (and "
          f"--shard-redundancy to none) to serve unsharded")

    self.model = Model(cfg, context_len=context_len)
    if params is None:
      params = jax.jit(self.model.init)(jax.random.PRNGKey(seed))
    if plan_active:
      # the network outside attention is replicated — commit params to every
      # mesh device once instead of letting GSPMD re-broadcast per program
      params = ssh.replicate(params, self.shard_plan)
    self.params = params
    self._prefill = jax.jit(
        lambda p, t, ln: self.model.prefill(p, t, None, lengths=ln))
    # physical cache storage + its compiled admit/decode programs
    self.prefix_cache = (cfg.prefix_cache if prefix_cache is None
                         else bool(prefix_cache))
    self.layout = cache_registry.make_layout(
        layout_name, self.model, max_batch,
        block_size=block_size, num_blocks=num_blocks,
        host_blocks=host_blocks if host_blocks is not None
        else cfg.host_blocks,
        prefix_cache=self.prefix_cache,
        prefix_cache_blocks=prefix_cache_blocks
        if prefix_cache_blocks is not None else cfg.prefix_cache_blocks,
        shard_plan=self.shard_plan,
        shard_redundancy=shard_redundancy)
    if self.prefix_cache:
      # the chunked suffix prefill must attend over exactly the padded
      # extent the full prefill uses — that is the bit-exactness contract
      self.layout.set_prompt_capacity(self.prompt_capacity)
      self._prefix_chunk = self.layout.block

    # virtual-clock serving (workload harness): compute and host-tier
    # transfers consume simulated time; overlap mode lets IN_FLIGHT
    # transfers drain while resident requests decode.  clock=None is the
    # wall-clock engine, bit-identical to the pre-harness behavior.
    self.clock = clock
    self.fault_injector = fault_injector
    self.max_fetch_retries = max_fetch_retries
    self.max_decode_retries = max_decode_retries
    #: rid -> virtual completion time of its in-flight host->device fetch
    self._transfer_ready: dict = {}

    # SLO enforcement + graceful degradation (PR 9): opt-in — with
    # slo_enforce=False the engine is bit-identical to the pre-PR9 loop
    self.slo_enforce = bool(slo_enforce)
    self._degradation = DegradationController()
    self.snapshot_dir = snapshot_dir

    # shard fault tolerance (PR 10): per-shard decode heartbeat watchdog.
    # Runs on unsharded engines too (shards=1): a confirmed "shard 0" death
    # there is a whole-pool loss and every resident request is recovered.
    self.shard_health = ssh.ShardHealth(
        self.shard_plan.size if plan_active else 1,
        confirm_after=shard_confirm_after)

    self.stats = self._new_stats()
    self._lengths = np.zeros((max_batch,), np.int32)
    self._cur = np.zeros((max_batch,), np.int32)
    self._slots: List[Optional[RequestHandle]] = [None] * max_batch
    self._queue: collections.deque = collections.deque()
    self._next_rid = 0
    self._step_no = 0

    # crash-safe restart: revive the prefix cache from the latest snapshot
    # so the restarted engine serves warm prefix hits instead of cold ones
    if self.snapshot_dir and self.prefix_cache:
      latest = ckpt_lib.latest_step(self.snapshot_dir)
      if latest is not None:
        try:
          tree, extra = ckpt_lib.load_raw(self.snapshot_dir, latest)
        except ckpt_lib.CheckpointCorruption as exc:
          # refuse the snapshot loudly, pool untouched: a cold prefix cache
          # is correct (just slower); a bit-rotted one decodes garbage
          warnings.warn(
              f"prefix-cache snapshot step {latest} in {self.snapshot_dir} "
              f"refused, starting cold: {exc}", RuntimeWarning,
              stacklevel=2)
        else:
          self.stats.restored_prefix_blocks = self.layout.prefix_restore(
              tree, extra)

  # -------------------------------------------------------------------------
  # public API
  # -------------------------------------------------------------------------

  def _new_stats(self) -> EngineStats:
    plan = self.shard_plan
    return EngineStats(
        max_batch=self.max_batch,
        mesh_shards=plan.size if plan is not None else 1,
        mesh_mode=plan.mode if plan is not None else "none")

  def kv_bytes(self) -> dict:
    """Stats-json `kv_bytes` section: the codecs shaping KV storage plus
    what the layout's live arrays actually occupy — the packed-codec
    capacity claim measured on allocated buffers, not modeled."""
    info = dict(spill_codec=self.cfg.spill_codec,
                kv_resident_codec=self.cfg.kv_resident_codec)
    if hasattr(self.layout, "bytes"):
      info.update(self.layout.bytes(active_slots=self.active_count))
    return info

  def mesh_info(self) -> dict:
    """Stats-json `mesh` section: the resolved plan plus what each shard
    actually holds (pool bytes split sharded/replicated)."""
    plan = self.shard_plan
    if plan is None:
      return dict(axis=ssh.MODEL_AXIS, mode="none", shards=1,
                  devices=[str(jax.devices()[0])], bit_identical=True)
    info = plan.describe()
    if hasattr(self.layout, "storage"):
      info["per_shard"] = ssh.per_shard_bytes(plan, self.layout.storage)
    return info

  def reset_stats(self) -> None:
    """Fresh counters (e.g. after a warmup drain so latency percentiles
    measure steady-state steps).  Fields mirroring the layout's cumulative
    ledger (spill/fetch bytes, modeled PCIe time, forked blocks) are
    re-synced immediately and stay cumulative over the engine's life —
    event *counts* restart at zero."""
    self.stats = self._new_stats()
    self._sync_transfer_stats()
    self._sync_prefix_stats()

  def submit(self, prompt: Sequence[int], max_new_tokens: int = 16, *,
             deadline_s: Optional[float] = None, tenant: str = "default",
             priority: int = 0) -> RequestHandle:
    prompt = np.asarray(prompt, np.int32).reshape(-1)
    if not 0 < prompt.shape[0] <= self.prompt_capacity:
      raise ValueError(
          f"prompt length {prompt.shape[0]} not in (0, {self.prompt_capacity}]")
    if max_new_tokens < 1:
      raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
    if prompt.shape[0] + max_new_tokens > self.context_len:
      raise ValueError("prompt + max_new_tokens exceeds context capacity")
    if not self.layout.fits(prompt.shape[0] + max_new_tokens,
                            prompt.shape[0]):
      raise ValueError(
          f"request needs more KV blocks than the whole pool holds "
          f"({self.layout!r}); raise num_blocks or shorten the request")
    req = RequestHandle(rid=self._next_rid, prompt=prompt,
                        max_new_tokens=max_new_tokens,
                        submitted_step=self._step_no,
                        deadline_s=deadline_s, tenant=tenant,
                        priority=priority)
    if self.clock is not None and req.submit_s is None:
      req.submit_s = self.clock.now
    self._next_rid += 1
    self._queue.append(req)
    return req

  @property
  def has_work(self) -> bool:
    return bool(self._queue) or any(r is not None for r in self._slots)

  @property
  def active_count(self) -> int:
    return sum(r is not None for r in self._slots)

  @property
  def active_requests(self) -> List[Tuple[int, RequestHandle]]:
    """(slot, request) pairs currently decoding — scheduler's read view."""
    return [(s, r) for s, r in enumerate(self._slots) if r is not None]

  @property
  def queue_view(self) -> Tuple[RequestHandle, ...]:
    """Waiting requests in queue order — scheduler's read view."""
    return tuple(self._queue)

  def admissible(self, req: RequestHandle) -> bool:
    """Can this queued request be admitted right now?  Prefix-cache aware:
    a request whose prompt prefix is cached needs only its unshared suffix
    blocks, which `can_admit` alone would overestimate.  Schedulers gate on
    this instead of reaching into the layout."""
    total = req.prompt_len + req.max_new_tokens
    if req.spilled:
      return (self.layout.can_fetch(req.rid, total)
              and self._transfer_ready_ok(req.rid))
    if self.prefix_cache:
      plan = self.layout.prefix_plan(req.prompt, total)
      return plan["need"] <= self.layout.free_blocks
    return self.layout.can_admit(req.prompt_len, total)

  @property
  def fetch_depth(self) -> int:
    """How many host->device fetches may be materializing at once: 1 on a
    wall-clock engine (the PR 3 one-step hint), 2 under an overlapping
    virtual clock (double-buffered: one transfer finalizing while the next
    drains behind it), 0 in serialized-fallback mode (every transfer is
    charged at the admit that needs it — the bit-identity oracle)."""
    if self.clock is None:
      return 1
    return 2 if self.clock.overlap else 0

  @property
  def transfers_in_flight(self) -> Tuple[int, ...]:
    """Rids whose fetch transfer has started but not been finalized."""
    return tuple(self._transfer_ready)

  def step(self) -> List[RequestHandle]:
    """Admit queued requests into free slots, run one batched decode step,
    and return the requests that finished this step."""
    self.stats.queue_depth_samples.append(len(self._queue))
    self._shard_fault_gate()
    finished = self._enforce_slo() if self.slo_enforce else []
    finished.extend(self._admit())
    if self.active_count == 0:
      self._step_no += 1
      self.stats.steps += 1
      self._sync_clock_stats()
      return finished

    # every active row grows by one token this step; secure its block first
    # (may preempt-and-requeue under the paged scheduler, or shed expired
    # lowest-priority work under SLO enforcement)
    self._ensure_blocks(finished)
    if self.active_count == 0:            # everything preempted back to queue
      self._step_no += 1
      self.stats.steps += 1
      self._sync_clock_stats()
      return finished

    self._decode_fault_gate()
    t0 = time.perf_counter()
    logits = self.layout.decode(self.params, self._cur, self._lengths)
    # np.asarray blocks on the device result: the sample spans launch->sync
    next_tok = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
    self.stats.decode_step_s.append(time.perf_counter() - t0)
    if self.clock is not None:
      self.clock.advance(self.clock.decode_step_s)
    self.stats.decode_steps += 1
    self.stats.busy_slot_steps += self.active_count
    self.stats.wasted_slot_steps += self.max_batch - self.active_count

    for slot, req in enumerate(self._slots):
      if req is None:
        continue
      # the token we just fed (cur) is now cached at position lengths[slot]
      self._lengths[slot] += 1
      tok = int(next_tok[slot])
      req.tokens.append(tok)
      self._cur[slot] = tok
      if (len(req.tokens) >= req.max_new_tokens
          or int(self._lengths[slot]) + 1 >= self.context_len):
        finished.append(self._finish(slot, req))
      else:
        # ring-reuse: hand back blocks the policy's own masking retired
        self.stats.blocks_reclaimed += self.layout.reclaim(
            slot, int(self._lengths[slot]))
    self._mirror_sync()
    self._fetch_ahead()
    self._step_no += 1
    self.stats.steps += 1
    self._sync_clock_stats()
    return finished

  def run_to_completion(self, max_steps: int = 10_000) -> List[RequestHandle]:
    """Drive `step()` until queue and slots drain; returns finish order."""
    done: List[RequestHandle] = []
    steps = 0
    while self.has_work:
      done.extend(self.step())
      steps += 1
      if steps > max_steps:
        raise RuntimeError(f"engine did not drain within {max_steps} steps")
    return done

  # -------------------------------------------------------------------------
  # internals
  # -------------------------------------------------------------------------

  def _admit(self) -> List[RequestHandle]:
    """Prefill (fresh) or fetch (spilled) scheduler-picked requests into
    free slots.  If the engine is idle yet nothing is admissible, the only
    thing holding the pool is the prefix cache itself — evict its coldest
    entries until admission unblocks (liveness over cache retention)."""
    finished = self._admit_pass()
    if (self.prefix_cache and not finished and self.active_count == 0
        and self._queue):
      evicted = False
      while True:
        # fifo/sjf pick without gating on admissibility, so check the
        # picked request itself — pick() is None is not the only stall
        idx = self.scheduler.pick(self._queue, self)
        if idx is not None and self.admissible(self._queue[idx]):
          break
        if not self.layout.prefix_evict_one():
          break
        evicted = True
      if evicted:
        finished.extend(self._admit_pass())
    # overlap-mode liveness: if nothing is running and the only admissible
    # work is behind an in-flight transfer, time must jump to the earliest
    # completion — otherwise an idle engine would spin at a frozen clock
    if (self.clock is not None and not self.active_count and self._queue
        and self._transfer_ready):
      self.clock.stall_until(min(self._transfer_ready.values()))
      finished.extend(self._admit_pass())
    return finished

  def _admit_pass(self) -> List[RequestHandle]:
    finished = []
    free_slots = [s for s, r in enumerate(self._slots) if r is None]
    while free_slots and self._queue:
      idx = self.scheduler.pick(self._queue, self)
      if idx is None:
        break
      req = self._queue[idx]
      if req.spilled:
        # swap-in: the request's KV survived on the host tier; restore it
        # and resume decoding exactly where the swap-out left off
        if not self.layout.can_fetch(req.rid,
                                     req.prompt_len + req.max_new_tokens):
          break                     # wait for running requests to free blocks
        if not self._transfer_ready_ok(req.rid):
          break                     # transfer still draining; decode goes on
        fate = self._fetch_fault(req)
        if fate is not None:
          # the transfer "failed": roll any IN_FLIGHT blocks back to the
          # host tier and either retry from the queue tail (transient) or
          # drop the request after bounded retries — never crash the step
          del self._queue[idx]
          self.layout.abort_prefetch(req.rid)
          self._transfer_ready.pop(req.rid, None)
          self.stats.fetch_failures += 1
          if fate == "drop":
            self.layout.drop_spilled(req.rid)
            req.failed = True
            req.done = True
            req.finished_step = self._step_no
            if self.clock is not None:
              req.finish_s = self.clock.now
            self.stats.failed_requests += 1
            finished.append(req)
          else:
            self._queue.append(req)
          self._sync_transfer_stats()
          continue
        del self._queue[idx]
        slot = free_slots.pop(0)
        ready = self._transfer_ready.pop(req.rid, None)
        ledger = getattr(self.layout, "ledger", None)
        before = ledger.total_bytes if ledger is not None else 0
        try:
          self.layout.fetch(req.rid, slot)
        except tiersmod.SpillPageCorruption:
          # the host copy is damaged: drop it and requeue for a recompute
          # prefill — greedy decoding regenerates identical tokens
          self._recover_corrupt(req)
          self._queue.append(req)
          free_slots.insert(0, slot)
          continue
        if self.clock is not None:
          if ready is not None:
            self.clock.stall_until(ready)   # no-op: readiness gated above
          elif ledger is not None:
            # no fetch-ahead happened: the transfer serializes right here
            moved = ledger.total_bytes - before
            self.clock.stall_until(
                self.clock.start_transfer(ledger.transfer_s(moved)))
        req.spilled = False
        req.slot = slot
        req.admitted_step = self._step_no
        self._slots[slot] = req
        self._lengths[slot] = req.resume_len
        self._cur[slot] = req.resume_cur
        self.stats.admits += 1
        self.stats.fetches += 1
        self._sync_transfer_stats()
        continue
      total = req.prompt_len + req.max_new_tokens
      plan = None
      if self.prefix_cache:
        # touch=True: this is the real admission — refresh matched entries'
        # LRU recency (scheduler probes are read-only)
        plan = self.layout.prefix_plan(req.prompt, total, touch=True)
        if plan["need"] > self.layout.free_blocks:
          break                     # wait for running requests to free blocks
      elif not self.layout.can_admit(req.prompt_len, total):
        break                       # wait for running requests to free blocks
      del self._queue[idx]
      slot = free_slots.pop(0)
      if req.submitted_step is not None:
        self.stats.queue_wait_steps.append(
            self._step_no - req.submitted_step)
      if self.clock is not None and req.admit_s is None:
        req.admit_s = self.clock.now   # queueing delay ends; prefill starts
      first = self._prefill_into(slot, req, plan)
      req.slot = slot
      req.admitted_step = self._step_no
      req.tokens.append(first)
      self._slots[slot] = req
      self._lengths[slot] = req.prompt_len
      self._cur[slot] = first
      self.stats.admits += 1
      self._sync_prefix_stats()
      if len(req.tokens) >= req.max_new_tokens:
        finished.append(self._finish(slot, req))
        free_slots.insert(0, slot)
    return finished

  def _prefill_into(self, slot: int, req: RequestHandle,
                    plan: Optional[dict]) -> int:
    """Build the slot's KV for this prompt along the cheapest correct path:
    a whole-prompt snapshot (zero prefill), a shared chain + suffix-only
    chunked prefill, or the ordinary full prefill.  Returns the first
    greedy token; bit-identical across all three paths by construction."""
    p_len = req.prompt_len
    if plan is not None and plan["kind"] == "full":
      entry = plan["entry"]
      self.layout.admit_from_full(slot, entry)
      self.stats.prefix_hits += 1
      self.stats.prefix_full_hits += 1
      self.stats.prefix_hit_tokens += p_len
      self.layout.prefix_index.record_hit(p_len, full=True)
      self._charge_prefill(req, 0)      # snapshot hit: zero compute
      return int(entry.first_token)
    if plan is not None and plan["kind"] == "chain":
      matched = plan["matched_tokens"]
      self.layout.admit_shared(slot, plan["match"], p_len)
      first = self._prefill_suffix(slot, req, matched)
      self.stats.prefix_hits += 1
      self.stats.prefix_hit_tokens += matched
      self.stats.prefill_tokens += p_len - matched
      self.layout.prefix_index.record_hit(matched)
      self.layout.prefix_publish(slot, req.prompt, first)
      self._charge_prefill(req, p_len - matched)
      return first
    padded = np.zeros((1, self.prompt_capacity), np.int32)
    padded[0, :p_len] = req.prompt
    logits, slot_cache = self._prefill(
        self.params, jnp.asarray(padded), jnp.asarray([p_len], jnp.int32))
    self.layout.admit(slot, slot_cache, p_len)
    first = int(np.asarray(jnp.argmax(logits[0], axis=-1)))
    self.stats.prefill_tokens += p_len
    if self.prefix_cache:
      self.layout.prefix_publish(slot, req.prompt, first)
    self._charge_prefill(req, p_len)
    return first

  def _charge_prefill(self, req: RequestHandle, computed_tokens: int) -> None:
    """Spend virtual time on the tokens this admission actually computed
    (zero for a snapshot hit, the suffix for a chain hit) and stamp the
    request's first-token time — TTFT ends here."""
    if self.clock is None:
      return
    self.clock.advance(computed_tokens * self.clock.prefill_token_s)
    if req.first_token_s is None:
      req.first_token_s = self.clock.now

  def _prefill_suffix(self, slot: int, req: RequestHandle, start: int) -> int:
    """Suffix-only prefill: run the uncached prompt tail [start, prompt_len)
    through fixed-shape chunks against the slot's resident prefix KV.  One
    compile total (chunk shape is constant), any suffix length."""
    chunk = self._prefix_chunk
    p_len = req.prompt_len
    last_logits, last_start = None, start
    pos = start
    while pos < p_len:
      toks = np.zeros((1, chunk), np.int32)
      avail = req.prompt[pos:min(pos + chunk, p_len)]
      toks[0, :len(avail)] = avail
      last_logits = self.layout.prefill_chunk(self.params, slot, toks, pos)
      last_start = pos
      pos += chunk
    row = p_len - 1 - last_start
    return int(np.asarray(jnp.argmax(last_logits[0, row], axis=-1)))

  def _sync_prefix_stats(self) -> None:
    if not self.prefix_cache:
      return
    self.stats.forked_blocks = self.layout.forked_blocks
    by = self.layout.bytes(active_slots=self.active_count)
    self.stats.dedup_bytes = max(self.stats.dedup_bytes, by["dedup_bytes"])

  def clear_prefix_cache(self) -> int:
    """Drop every published prefix (frees the index's block holds)."""
    return self.layout.prefix_clear() if self.prefix_cache else 0

  def _ensure_blocks(self, finished: Optional[List[RequestHandle]] = None
                     ) -> None:
    """Grow every active slot's block table to hold this step's token,
    preempting (scheduler permitting) when the pool runs dry.  An injected
    allocator-exhaustion spike transiently reserves blocks, forcing the
    same spill/preempt/shed machinery a genuinely tight pool exercises —
    the reserve is never actually allocated, so it can never leak."""
    reserve = 0
    inj = self.fault_injector
    if inj is not None and hasattr(inj, "alloc_spike"):
      reserve = inj.alloc_spike(self._step_no)
      if reserve:
        self.stats.alloc_spikes += 1
    while True:
      growers = [(slot, self.layout.need_blocks(slot, int(ln) + 1))
                 for slot, ln in enumerate(self._lengths)
                 if self._slots[slot] is not None]
      total_need = sum(n for _, n in growers)
      if total_need <= max(self.layout.free_blocks - reserve, 0):
        for slot, need in growers:
          if need and not self.layout.ensure(
              slot, int(self._lengths[slot]) + 1):
            raise AssertionError("pool accounting drifted during growth")
        return
      if self.prefix_cache and self.layout.prefix_evict_one():
        continue      # prefer dropping cold cached prefixes over victims
      if self.slo_enforce and finished is not None:
        # shed the lowest-priority deadline-missed active request before
        # stalling or preempting everyone: its tokens can no longer count
        # toward goodput, so its blocks are the cheapest relief available
        shed = self._shed_expired_active(finished)
        if shed:
          continue
      victim = self.scheduler.on_exhausted(self)
      if victim is None:
        raise RuntimeError(
            f"KV block pool exhausted (need {total_need}, free "
            f"{self.layout.free_blocks}) and scheduler "
            f"{self.scheduler.name!r} cannot preempt; use --scheduler "
            f"paged/tiered or a larger --num-blocks")
      if self.scheduler.spills and self.layout.can_spill(victim):
        self._swap_out(victim)
      else:
        # host tier full (or single-tier layout): recompute preemption
        self._preempt(victim)

  def _swap_out(self, slot: int) -> None:
    """Swap preemption: the victim's KV moves to the host tier through the
    policy's spill codecs; its generated tokens are kept and decoding
    resumes from the same position after a later fetch."""
    req = self._slots[slot]
    assert req is not None, f"swapping out empty slot {slot}"
    req.resume_len = int(self._lengths[slot])
    req.resume_cur = int(self._cur[slot])
    ledger = getattr(self.layout, "ledger", None)
    before = ledger.total_bytes if ledger is not None else 0
    self.layout.spill(slot, req.rid, req.resume_len)
    inj = self.fault_injector
    if (inj is not None and hasattr(inj, "should_corrupt_spill")
        and inj.should_corrupt_spill(req.rid, req.spill_count)):
      # damage the page now; detection happens at fetch via the frame
      # checksum, recovery via recompute-prefill (_recover_corrupt)
      self.layout.corrupt_spilled(req.rid)
    if self.clock is not None and ledger is not None:
      # the spill occupies the link (overlapped with decode, or a stall in
      # serialized mode); the device blocks are free either way — nothing
      # waits on a spill's completion
      self.clock.start_transfer(
          ledger.transfer_s(ledger.total_bytes - before))
    req.spilled = True
    req.slot = None
    req.spill_count += 1
    self._slots[slot] = None
    self._lengths[slot] = 0
    self._cur[slot] = 0
    self._queue.appendleft(req)
    self.stats.spills += 1
    self._sync_transfer_stats()

  def _fetch_ahead(self) -> None:
    """Start materializing upcoming spilled requests' blocks (IN_FLIGHT) so
    their admits only finalize.  Wall-clock engines keep the PR 3 one-step
    hint; under an overlapping virtual clock this is a double-buffered
    async stage — up to `fetch_depth` transfers drain on the modeled PCIe
    link while decode proceeds on resident requests, each completing at a
    deadline drawn from `TransferLedger.transfer_s`."""
    if self.clock is None:
      rid = self.scheduler.fetch_ahead(self)
      if rid is not None and self._prefetch_checked(rid):
        self.stats.prefetches += 1
        self._sync_transfer_stats()
      return
    depth = self.fetch_depth
    if depth == 0:
      return                        # serialized fallback: no async stage
    ledger = getattr(self.layout, "ledger", None)
    if ledger is None:
      return                        # single-tier layout: nothing to fetch
    for rid in self.scheduler.fetch_ahead_many(self, depth):
      if len(self._transfer_ready) >= depth:
        break
      if rid in self._transfer_ready:
        continue
      before = ledger.total_bytes
      if self._prefetch_checked(rid):
        self._transfer_ready[rid] = self.clock.start_transfer(
            ledger.transfer_s(ledger.total_bytes - before))
        self.stats.prefetches += 1
    self._sync_transfer_stats()

  def _prefetch_checked(self, rid: int) -> bool:
    """`layout.prefetch` with corrupted-page recovery: on a checksum
    mismatch the host copy is dropped and the (still queued) request is
    reset for a recompute prefill.  Returns False — no transfer started."""
    try:
      return self.layout.prefetch(rid)
    except tiersmod.SpillPageCorruption:
      req = next((r for r in self._queue if r.rid == rid), None)
      if req is not None:
        self._recover_corrupt(req)
      return False

  def _transfer_ready_ok(self, rid: int) -> bool:
    """May this spilled request finalize its fetch now?  True unless an
    overlapped transfer for it is still draining on the link."""
    if self.clock is None or not self.clock.overlap:
      return True
    ready = self._transfer_ready.get(rid)
    return ready is None or ready <= self.clock.now + 1e-12

  def _fetch_fault(self, req: RequestHandle) -> Optional[str]:
    """Consult the fault injector about this fetch attempt: None (proceed),
    'retry' (transient fault, requeue), or 'drop' (retries exhausted)."""
    if self.fault_injector is None:
      return None
    try:
      self.fault_injector.check_fetch(req.rid, req.fetch_failures)
    except fault_tolerance.SimulatedFailure:
      req.fetch_failures += 1
      if req.fetch_failures > self.max_fetch_retries:
        return "drop"
      return "retry"
    return None

  def _recover_corrupt(self, req: RequestHandle) -> None:
    """Recover a request whose spilled page failed its checksum: the host
    copy is unrecoverable, so drop it (freeing both tiers) and reset the
    handle for a recompute prefill from the prompt — under greedy decoding
    the regenerated tokens are bit-identical to the lost ones."""
    self.layout.abort_prefetch(req.rid)       # no-op unless IN_FLIGHT
    self._transfer_ready.pop(req.rid, None)
    self.layout.drop_spilled(req.rid)
    req.spilled = False
    req.tokens = []
    req.resume_len = 0
    req.resume_cur = 0
    req.admit_s = None
    req.first_token_s = None
    req.preempt_count += 1
    self.stats.corrupt_pages += 1
    self._sync_transfer_stats()

  def _decode_fault_gate(self) -> None:
    """Transient decode-step fault injection with bounded retry/backoff:
    each failed attempt burns one decode step of virtual time (the retry's
    cost) and re-draws; past `max_decode_retries` the fault is treated as
    persistent and surfaces."""
    inj = self.fault_injector
    if inj is None or not hasattr(inj, "check_decode"):
      return
    attempt = 0
    while inj.check_decode(self._step_no, attempt):
      attempt += 1
      self.stats.decode_faults += 1
      if self.clock is not None:
        self.clock.advance(self.clock.decode_step_s)   # retry backoff
      if attempt > self.max_decode_retries:
        raise fault_tolerance.SimulatedFailure(
            f"decode step {self._step_no} failed "
            f"{attempt} consecutive attempts")

  # -- shard fault tolerance (PR 10) -----------------------------------------

  def _shard_fault_gate(self) -> None:
    """One watchdog heartbeat round per engine step.

    The injector's shard surfaces fire first (a stalled shard misses this
    round and costs the synchronous mesh one step of virtual time; a lost
    shard stops beating permanently), then `ShardHealth.record` confirms
    deaths after `confirm_after` consecutive misses and the engine runs
    the recovery path for each.
    """
    inj = self.fault_injector
    health = self.shard_health
    if inj is not None:
      if hasattr(inj, "shard_stall"):
        s = inj.shard_stall(self._step_no, health.shards)
        if s is not None:
          health.mark_stalled(s)
          self.stats.shard_stalls += 1
          if self.clock is not None:
            # a synchronous mesh decodes at the pace of its slowest shard:
            # one straggler charges everyone one extra step
            self.clock.advance(self.clock.decode_step_s)
      if hasattr(inj, "shard_loss"):
        s = inj.shard_loss(self._step_no, health.shards)
        if s is not None:
          health.mark_lost(s)
    dead = health.record()
    self.stats.shard_heartbeats = list(health.beats)
    if dead:
      self._recover_shard_loss(dead)

  def _recover_shard_loss(self, dead: List[int]) -> None:
    """Confirmed shard death: drain, damage, replan, recover.

    1. Drain — every overlapped fetch rolls back to SPILLED (its transfer
       may have involved the dead shard).
    2. Damage model — heads mode shards a kv-head slice of *every* pool
       block, so a dead shard voids all resident data (the storage is
       scrubbed to make recovery falsifiable); seq and none replicate
       storage, so survivors keep full copies and only the plan changes.
    3. Replan — `ShardPlan.replan(survivors)` re-partitions over the
       surviving subset and the layout re-places storage + re-binds its
       decode programs; params re-commit to the survivor submesh.
    4. Recover — with data lost, each active slot restores from its host
       mirror (checksum-verified) or resets for a recompute prefill;
       spilled requests whose pinned shared blocks were damaged recompute
       too.  Requests are recovered, never aborted.
    """
    plan = self.shard_plan
    self.stats.shard_losses += len(dead)
    self.stats.dead_shards.extend(int(s) for s in dead)
    for rid in list(self._transfer_ready):
      self.layout.abort_prefetch(rid)
    self._transfer_ready.clear()
    self._sync_transfer_stats()
    lost_data = plan is None or not plan.active or plan.mode == "heads"
    if lost_data and hasattr(self.layout, "damage_storage"):
      self.layout.damage_storage()
    n_after = 1
    if plan is not None and plan.active:
      survivors = [i for i in range(plan.size) if i not in set(dead)]
      new_plan = plan.replan(survivors)
      self.layout.replan(new_plan)
      self.shard_plan = new_plan
      # the replicated network must re-commit to the survivor submesh —
      # GSPMD would otherwise see params placed on a dead device, and an
      # inactive fallback plan still re-places storage, so prefill outputs
      # must land on the same submesh
      self.params = ssh.replicate(self.params, new_plan)
      self.stats.mesh_shards = new_plan.size
      self.stats.mesh_mode = new_plan.mode
      self.stats.shard_replans += 1
      n_after = new_plan.size if new_plan.active else 1
    # the watchdog re-bases on the new plan's shard indices (a replanned
    # mesh numbers its shards from zero; stale lost marks must not
    # re-confirm against the survivors)
    self.shard_health = ssh.ShardHealth(
        n_after, confirm_after=self.shard_health.confirm_after)
    if lost_data:
      self._recover_lost_data()

  def _recover_lost_data(self) -> None:
    """Rebuild every resident request after whole-pool data damage."""
    if self.prefix_cache:
      # index-held blocks have no owning request to recompute them; the
      # cache rebuilds warm as recovered requests re-publish
      self.layout.prefix_clear()
    restored_blocks: set = set()
    recompute: List[RequestHandle] = []
    mirrored = getattr(self.layout, "mirror", None) is not None
    ledger = getattr(self.layout, "ledger", None)
    for slot, req in self.active_requests:
      rec = None
      if mirrored:
        try:
          rec = self.layout.mirror_restore(slot)
        except tiersmod.SpillPageCorruption:
          rec = None                  # damaged mirror page: fall back
      self.stats.shard_recovered_requests += 1
      if rec is not None:
        restored_blocks.update(rec.device_block_ids)
        self.stats.shard_mirror_restores += 1
        if self.clock is not None and ledger is not None:
          # the restore transfer blocks the slot's next decode step
          self.clock.stall_until(
              self.clock.start_transfer(ledger.transfer_s(rec.nbytes)))
        continue
      # recompute path: release the slot and reset the handle — greedy
      # decoding regenerates the identical tokens on re-admission
      req.tokens = []
      req.slot = None
      req.admitted_step = None
      req.admit_s = None
      req.first_token_s = None
      req.preempt_count += 1
      self.layout.release(slot)
      self._slots[slot] = None
      self._lengths[slot] = 0
      self._cur[slot] = 0
      self.stats.preempts += 1
      recompute.append(req)
    # spilled requests: their payloads live on the host tier (safe), but
    # pinned shared-prefix blocks sit in the damaged device pool — resume
    # only when every pin was mirror-restored, else recompute
    if hasattr(self.layout, "spill_pins"):
      for req in self._queue:
        if not req.spilled:
          continue
        pins = set(self.layout.spill_pins(req.rid))
        if pins and not pins <= restored_blocks:
          self.layout.abort_prefetch(req.rid)
          self.layout.drop_spilled(req.rid)
          req.spilled = False
          req.tokens = []
          req.resume_len = 0
          req.resume_cur = 0
          req.admit_s = None
          req.first_token_s = None
          req.preempt_count += 1
          self.stats.shard_recovered_requests += 1
    if recompute:
      ordered = list(self.scheduler.shard_recovery_requeue(self, recompute))
      for req in reversed(ordered):
        self._queue.appendleft(req)
    self._sync_transfer_stats()

  def _mirror_sync(self) -> None:
    """Write-through refresh of every active slot's host mirror.  Mirror
    writes ride a dedicated host path overlapped with the next decode step,
    so no virtual time is charged; restores are what stall (and are charged
    at `_recover_lost_data`)."""
    if getattr(self.layout, "mirror", None) is None:
      return
    for slot, req in self.active_requests:
      self.layout.mirror_sync(slot, req.rid, int(self._lengths[slot]))

  def shard_health_info(self) -> dict:
    """Stats-json `shard_health` section: watchdog state, recovery
    counters, and the host mirror's footprint."""
    info = self.shard_health.as_dict()
    info.update(
        redundancy=getattr(self.layout, "shard_redundancy", "none"),
        losses=self.stats.shard_losses,
        stalls=self.stats.shard_stalls,
        replans=self.stats.shard_replans,
        mirror_restores=self.stats.shard_mirror_restores,
        recovered_requests=self.stats.shard_recovered_requests,
        dead_shards=list(self.stats.dead_shards),
        mesh_shards=self.stats.mesh_shards,
        mesh_mode=self.stats.mesh_mode)
    mirror = getattr(self.layout, "mirror", None)
    if mirror is not None:
      info["mirror"] = mirror.as_dict()
    return info

  # -- SLO enforcement + graceful degradation --------------------------------

  def _enforce_slo(self) -> List[RequestHandle]:
    """Deadline admission control, run once per step before admits: update
    the degradation state machine, shed queued requests that already missed
    their deadline (their tokens can never count toward goodput), and under
    SHEDDING also those that provably cannot make it even at full speed."""
    finished: List[RequestHandle] = []
    if self.clock is None:
      return finished
    total = max(self.layout.num_blocks, 1) if hasattr(
        self.layout, "num_blocks") else 1
    free_frac = self.layout.free_blocks / total if hasattr(
        self.layout, "free_blocks") else 1.0
    trans = self._degradation.observe(free_frac, len(self._queue))
    if trans is not None:
      self.stats.degradation_state = trans[1]
      if len(self.stats.degradation_transitions) < 256:
        self.stats.degradation_transitions.append(dict(
            step=self._step_no, virtual_s=round(self.clock.now, 6),
            old=trans[0], new=trans[1],
            free_frac=round(free_frac, 4), queue_depth=len(self._queue)))
    state = self._degradation.state
    if state == "PRESSURED" and self.prefix_cache:
      # progressive degradation: give back one cold cached prefix per
      # pressured step instead of waiting for hard exhaustion
      self.layout.prefix_evict_one()
    now = self.clock.now
    for req in [r for r in self._queue if r.deadline_s is not None]:
      doomed = now >= req.deadline_s
      if not doomed and state == "SHEDDING":
        # lower bound: every remaining token costs at least one decode
        # step — if even that misses the deadline, the request is doomed
        doomed = (now + req.max_new_tokens * self.clock.decode_step_s
                  > req.deadline_s)
      if doomed:
        self._queue.remove(req)
        finished.append(self._cancel_queued(req))
    return finished

  def _cancel_queued(self, req: RequestHandle) -> RequestHandle:
    """Cleanly cancel a queued request: reclaim any in-flight transfer,
    host-tier pages, and shared-prefix pins it holds, then mark it shed."""
    if req.spilled:
      self.layout.abort_prefetch(req.rid)
      self._transfer_ready.pop(req.rid, None)
      self.layout.drop_spilled(req.rid)
      req.spilled = False
      self._sync_transfer_stats()
    req.shed = True
    req.done = True
    req.finished_step = self._step_no
    if self.clock is not None:
      req.finish_s = self.clock.now
    self.stats.shed_requests += 1
    return req

  def _shed_expired_active(self, finished: List[RequestHandle]) -> bool:
    """Under pool pressure, cancel the lowest-priority *active* request
    whose deadline already passed (its remaining tokens are worthless);
    frees its blocks instead of spilling/preempting still-viable work."""
    if self.clock is None:
      return False
    now = self.clock.now
    expired = [(r.priority, -(r.admitted_step or 0), s, r)
               for s, r in self.active_requests
               if r.deadline_s is not None and now >= r.deadline_s]
    if not expired:
      return False
    expired.sort(key=lambda t: (t[0], t[1], t[2]))
    _, _, slot, req = expired[0]
    self.layout.release(slot)
    self._slots[slot] = None
    self._lengths[slot] = 0
    self._cur[slot] = 0
    req.slot = None
    req.shed = True
    req.done = True
    req.finished_step = self._step_no
    req.finish_s = now
    self.stats.shed_requests += 1
    self.stats.pressure_sheds += 1
    finished.append(req)
    return True

  # -- crash-safe snapshot/restore -------------------------------------------

  def save_snapshot(self, step: int = 0) -> Optional[str]:
    """Persist the prefix cache (trie + pinned block contents) through
    `checkpoint/ckpt.py` so a restarted engine serves warm prefix hits.
    Returns the checkpoint directory, or None when there is nothing to
    snapshot (no snapshot_dir or prefix cache disabled)."""
    if not (self.snapshot_dir and self.prefix_cache):
      return None
    tree, extra = self.layout.prefix_snapshot()
    return ckpt_lib.save(self.snapshot_dir, step, tree, extra=extra)

  def _sync_transfer_stats(self) -> None:
    ledger = getattr(self.layout, "ledger", None)
    if ledger is not None:
      self.stats.spill_bytes = ledger.spill_bytes
      self.stats.fetch_bytes = ledger.fetch_bytes
      self.stats.modeled_pcie_s = ledger.modeled_pcie_s
      self.stats.fetch_aborts = ledger.fetch_aborts

  def _sync_clock_stats(self) -> None:
    c = self.clock
    if c is None:
      return
    self.stats.virtual_s = c.now
    self.stats.compute_s = c.compute_s
    self.stats.transfer_stall_s = c.transfer_stall_s
    self.stats.idle_s = c.idle_s
    self.stats.link_busy_s = c.link_busy_s

  def _preempt(self, slot: int) -> None:
    """Recompute preemption: release the slot, requeue the request; greedy
    decoding regenerates its tokens identically on re-admission."""
    req = self._slots[slot]
    assert req is not None, f"preempting empty slot {slot}"
    req.tokens = []
    req.slot = None
    req.admitted_step = None
    req.admit_s = None               # re-admission re-measures queueing
    req.first_token_s = None         # regenerated tokens re-stamp TTFT
    req.preempt_count += 1
    self.layout.release(slot)
    self._slots[slot] = None
    self._lengths[slot] = 0
    self._cur[slot] = 0
    self._queue.appendleft(req)
    self.stats.preempts += 1

  def _finish(self, slot: int, req: RequestHandle) -> RequestHandle:
    req.done = True
    req.finished_step = self._step_no
    if self.clock is not None:
      req.finish_s = self.clock.now
    self.layout.release(slot)
    self._slots[slot] = None
    self._lengths[slot] = 0
    self._cur[slot] = 0
    self.stats.finished += 1
    return req
