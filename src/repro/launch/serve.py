"""End-to-end serving driver: batched prefill -> cache policy -> decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
      --prompt-len 128 --gen 32 --batch 4 --cache-policy pq

The KV-cache method is selected by registry key (`--cache-policy`): `exact`,
`pq` (AQPIM, default), `skvq`, `snapkv`, `streamingllm`, `pqcache` — the
paper's Fig. 10 sweep surface.  With `pq` this exercises the full AQPIM
inference path (paper Fig. 3a): prefill computes exact attention AND builds
the compressed cache (importance-weighted windowed clustering, hidden behind
prefill); the decode loop appends tokens by PQ-encoding ring-buffer
evictions and attends directly on compressed data.

`--engine` runs the same architecture through the continuous-batching
`ServeEngine` instead: staggered prompt lengths admitted into one batch,
finishing at different steps.  Engine storage and admission are pluggable:
`--cache-layout {contiguous,paged,tiered}` picks the physical KV layout
(paged = fixed-size token blocks from a shared pool,
`--kv-block-size`/`--num-blocks`; tiered adds a host spill tier,
`--host-blocks`/`--spill-codec`) and `--scheduler {fifo,sjf,paged,tiered}`
the admission policy (`paged` preempts-and-recomputes on pool exhaustion;
`tiered` spills the LRU-coldest request's KV to the host tier instead and
fetches it back later).  Per-run occupancy/waste/preempt/spill counters
print from `engine.stats`; `--stats-json PATH` dumps them machine-readably
(plus `layout_bytes` and the tier-boundary `transfer` ledger) so CI and
benches can assert on them.

`--workload N` switches to the trace-driven harness (launch/workload.py):
N seeded requests arrive over a virtual clock (`--arrival poisson|bursty|
trace`, `--arrival-rate`, `--burstiness`, `--trace-file`), each carrying an
SLO (`--slo-ttft`/`--slo-tpot`); host-tier transfers overlap decode through
the double-buffered fetch stage (or serialize with `--no-overlap` — same
greedy tokens either way), `--fetch-fail-rate` injects host-tier fetch
faults the engine must survive, and the run reports TTFT/TPOT percentiles,
goodput, and compute/transfer/idle stall attribution instead of wall-clock
throughput.  Deterministic end to end: two runs with one seed produce
identical token streams and reports.

Robustness knobs (PR 9): `--slo-enforce` turns per-request deadlines into
admission control — doomed queued work is shed, the degradation
state machine (NORMAL -> PRESSURED -> SHEDDING) records in the stats —
and pairs with `--scheduler slo` (priority-then-EDF).  `--fault-kind
{fetch,corrupt-spill,alloc-exhaustion,decode-transient} --fault-rate P`
injects seeded faults on one surface through a `FaultPlan`
(runtime/fault_tolerance.py); the engine must recover without leaking
blocks or corrupting survivors' tokens.  `--snapshot-dir DIR` restores the
prefix cache from the latest snapshot at startup (crash-safe warm
restart) and `--save-snapshot` persists it after the run.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.timing import Stopwatch, latency_percentiles_ms
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.core import cache_registry, decode_dispatch, tiers
from repro.kernels import packing
from repro.launch import scheduler as scheduler_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh
from repro.parallel import sharding as shd
from repro.runtime import fault_tolerance as ft


@dataclasses.dataclass
class ServeRun:
  arch: str
  reduced: bool = True
  batch: int = 4
  prompt_len: int = 128
  gen: int = 32
  cache_policy: str = "pq"
  decode_kernel: str = "auto"      # core/decode_dispatch registry key
  measure_latency: bool = True     # run the extra synced decode pass for
                                   # p50/p99 (costs ~one more prefill+decode;
                                   # tests that only want tokens turn it off)
  pq: bool = True                  # legacy knob: False downgrades the default
                                   # "pq" policy to "exact" (no effect on other
                                   # explicitly chosen policies)
  warmup: bool = True              # compile outside the timed sections
  seed: int = 0
  greedy: bool = True
  mesh: Any = None

  def run(self):
    cfg = get_arch(self.arch, reduced=self.reduced)
    cfg = dataclasses.replace(cfg, cache_policy=self.cache_policy,
                              decode_kernel=self.decode_kernel)
    if not self.pq:
      cfg = dataclasses.replace(cfg, pq_enabled=False)
    context = self.prompt_len + self.gen
    mesh = self.mesh or make_local_mesh()
    shape = ShapeConfig("serve", context, self.batch, "decode")
    progs = steps_lib.build_programs(cfg, shape, mesh, donate=False)
    model = progs.model

    key = jax.random.PRNGKey(self.seed)
    params = jax.jit(
        model.init,
        out_shardings=shd.make_shardings(progs.param_specs, mesh))(key)
    prompts = jax.random.randint(
        key, (self.batch, self.prompt_len), 0, cfg.vocab_size)
    modal = None
    if cfg.frontend == "audio_frames":
      modal = jnp.zeros((self.batch, context, cfg.d_model), cfg.dtype)
    elif cfg.frontend == "vision_patches":
      modal = jnp.zeros((self.batch, cfg.n_modal_tokens, cfg.d_model),
                        cfg.dtype)

    with mesh:
      prefill = jax.jit(model.prefill)
      m_pref = modal[:, :self.prompt_len] if (
          modal is not None and cfg.frontend == "audio_frames") else modal
      step = jax.jit(model.decode_step)
      if self.warmup:
        # trace+compile outside the stopwatches so timings measure execution
        logits_w, cache_w = prefill(params, prompts, m_pref)
        jax.block_until_ready(step(
            params, jnp.argmax(logits_w, -1).astype(jnp.int32), cache_w,
            jnp.full((self.batch,), self.prompt_len, jnp.int32),
            modal[:, :1] if modal is not None
            and cfg.frontend == "audio_frames" else modal))

      with Stopwatch() as sw_prefill:
        logits, cache = prefill(params, prompts, m_pref)
        sw_prefill.wait_for(logits)

      def step_inputs(i):
        """Per-step (lengths, modal slice) — ONE definition, so the timed
        throughput loop and the latency pass drive the identical program."""
        lengths = jnp.full((self.batch,), self.prompt_len + i, jnp.int32)
        m_step = (modal[:, self.prompt_len + i:self.prompt_len + i + 1]
                  if modal is not None and cfg.frontend == "audio_frames"
                  else modal)
        return lengths, m_step

      tokens = [jnp.argmax(logits, -1).astype(jnp.int32)]
      with Stopwatch() as sw_decode:
        for i in range(self.gen):
          lengths, m_step = step_inputs(i)
          logits, cache = step(params, tokens[-1], cache, lengths, m_step)
          tokens.append(jnp.argmax(logits, -1).astype(jnp.int32))
        sw_decode.wait_for(tokens[-1])

      # per-step latency distribution: a second decode pass with a sync per
      # step, so the throughput loop above keeps its async dispatch overlap
      # while p50/p99 measure real launch->result step times.  Opt-out for
      # callers that only want tokens (it costs another prefill+decode).
      step_s = []
      if self.measure_latency:
        import time as _time
        logits_l, cache_l = prefill(params, prompts, m_pref)
        tok_l = jnp.argmax(logits_l, -1).astype(jnp.int32)
        for i in range(self.gen):
          lengths, m_step = step_inputs(i)
          t0 = _time.perf_counter()
          logits_l, cache_l = step(params, tok_l, cache_l, lengths, m_step)
          tok_l = jnp.argmax(logits_l, -1).astype(jnp.int32)
          jax.block_until_ready(tok_l)
          step_s.append(_time.perf_counter() - t0)

    lat = latency_percentiles_ms(step_s)
    out = jnp.stack(tokens[:-1], axis=1)
    policy_name = cfg.resolved_cache_policy() if not cfg.attn_free else "none"
    # record what actually ran, not the request: 'auto' resolves per
    # backend, and a policy without a kernel implementation runs xla
    # whatever was asked for
    kernel_key = (model.cache_policy.effective_decode_kernel
                  if model.cache_policy is not None else "xla")
    return {
        "tokens": out,
        "prefill_s": sw_prefill.seconds,
        "decode_s": sw_decode.seconds,
        "tok_per_s": self.batch * self.gen / max(sw_decode.seconds, 1e-9),
        "decode_step_p50_ms": lat["p50_ms"],
        "decode_step_p99_ms": lat["p99_ms"],
        "cache_policy": policy_name,
        "decode_kernel": kernel_key,
        "pq": policy_name == "pq",
    }


def build_engine(args, clock=None, fault_injector=None):
  """Construct the ServeEngine exactly as the CLI flags describe it (kept
  separate so tests can assert every flag reaches the engine/config).
  `clock`/`fault_injector` are the workload harness's virtual clock and
  fetch-fault injector (None for the wall-clock demo paths)."""
  from repro.launch.engine import ServeEngine
  cfg = get_arch(args.arch, reduced=args.reduced)
  # host_blocks passes through as-is: an explicit --host-blocks 0 (no host
  # tier, recompute fallback only) is distinct from None (layout default)
  cfg = dataclasses.replace(cfg, cache_policy=args.cache_policy,
                            cache_layout=args.cache_layout,
                            scheduler=args.scheduler,
                            kv_block_size=args.kv_block_size,
                            host_blocks=args.host_blocks,
                            spill_codec=args.spill_codec,
                            kv_resident_codec=args.kv_resident_codec,
                            prefix_cache=args.prefix_cache,
                            prefix_cache_blocks=args.prefix_cache_blocks,
                            decode_kernel=args.decode_kernel)
  context = args.prompt_len + args.gen
  engine = ServeEngine(cfg, context_len=context, max_batch=args.batch,
                       prompt_capacity=args.prompt_len,
                       num_blocks=args.num_blocks, clock=clock,
                       fault_injector=fault_injector,
                       mesh_model=getattr(args, "mesh_model", None),
                       slo_enforce=getattr(args, "slo_enforce", False),
                       snapshot_dir=getattr(args, "snapshot_dir", None),
                       shard_redundancy=getattr(args, "shard_redundancy",
                                                "none"))
  if getattr(args, "pcie_gbps", None):
    ledger = getattr(engine.layout, "ledger", None)
    if ledger is not None:
      ledger.pcie_gbps = args.pcie_gbps
  return engine


def dump_stats_json(engine, path: str, extra: Any = None) -> None:
  """Machine-readable run record: EngineStats.as_dict() + the layout's true
  footprint + (tiered) the tier-boundary transfer ledger.  `extra` merges
  additional top-level sections (the workload harness adds its SLO report
  under the "workload" key)."""
  payload = engine.stats.as_dict()
  if extra:
    payload.update(extra)
  payload["layout"] = engine.layout.name
  payload["scheduler"] = engine.scheduler.name
  payload["decode_kernel"] = (
      engine.model.cache_policy.effective_decode_kernel
      if engine.model.cache_policy is not None else "xla")
  payload["layout_bytes"] = engine.layout.bytes(
      active_slots=engine.active_count)
  payload["kv_bytes"] = engine.kv_bytes()
  if hasattr(engine.layout, "decode_traffic"):
    payload["decode_traffic"] = engine.layout.decode_traffic
  ledger = getattr(engine.layout, "ledger", None)
  if ledger is not None:
    payload["transfer"] = ledger.as_dict()
  payload["mesh"] = engine.mesh_info()
  if hasattr(engine, "shard_health_info"):
    payload["shard_health"] = engine.shard_health_info()
  index = getattr(engine.layout, "prefix_index", None)
  if index is not None:
    payload["prefix_cache"] = dict(
        budget_blocks=index.budget_blocks, held_blocks=index.held_blocks,
        chain_nodes=index.chain_nodes, full_entries=index.full_entries,
        hits=index.hits, full_hits=index.full_hits,
        hit_tokens=index.hit_tokens, evicted_blocks=index.evicted_blocks)
  write_json_atomic(path, payload)


def write_json_atomic(path: str, payload: Any) -> None:
  """Write JSON via a sibling temp file + `os.replace`, so a crash (or a
  concurrent reader — CI tails these files) never observes a torn record:
  the path either holds the previous complete document or the new one."""
  tmp = f"{path}.tmp.{os.getpid()}"
  with open(tmp, "w") as f:
    json.dump(payload, f, indent=2)
    f.write("\n")
    f.flush()
    os.fsync(f.fileno())
  os.replace(tmp, path)


def run_engine_demo(args) -> None:
  """Continuous batching: mixed prompt lengths, staggered finishes."""
  engine = build_engine(args)
  cfg = engine.cfg
  context = args.prompt_len + args.gen
  key = jax.random.PRNGKey(0)
  # drain one throwaway request so the three jit compiles land outside the
  # timed section (same reason ServeRun has warmup) — it must ask for >= 2
  # tokens, else it finishes at admission and never compiles the decode step
  warm_len = min(8, args.prompt_len, max(1, context - 2))
  engine.submit([1] * warm_len, max_new_tokens=min(2, context - warm_len))
  engine.run_to_completion()
  # the warmup drain just paid the trace+compile cost; drop its samples so
  # the printed/dumped decode-latency percentiles are steady-state steps
  engine.reset_stats()
  floor = min(8, args.prompt_len)
  rng_lens = [max(floor, args.prompt_len - 17 * i)
              for i in range(args.batch + 2)]
  max_new = max(1, min(args.gen, max(2, args.gen // 2)))
  for i, ln in enumerate(rng_lens):
    prompt = jax.random.randint(jax.random.fold_in(key, i), (ln,), 0,
                                cfg.vocab_size)
    engine.submit(list(map(int, prompt)), max_new_tokens=max_new)
  with Stopwatch() as sw:
    done = engine.run_to_completion()
  n_tok = sum(len(r.tokens) for r in done)
  kernel_key = (engine.model.cache_policy.effective_decode_kernel
                if engine.model.cache_policy is not None else "xla")
  print(f"engine: {len(done)} requests, {n_tok} tokens in {sw.seconds:.2f}s "
        f"({n_tok / max(sw.seconds, 1e-9):.1f} tok/s) "
        f"[layout={args.cache_layout} scheduler={args.scheduler} "
        f"kernel={kernel_key}"
        f"{' block-native' if getattr(engine.layout, 'block_native', False) else ''}]")
  if hasattr(engine.layout, "decode_traffic"):
    tm = engine.layout.decode_traffic
    print(f"decode traffic (peak/step): {tm['decode_path']} — dense "
          f"materialized {tm['dense_materialized_bytes_per_step']} B, "
          f"block reads {tm['block_read_bytes_per_step']} B, row writes "
          f"{tm['row_write_bytes_per_step']} B")
  if engine.shard_plan is not None and engine.shard_plan.active:
    mi = engine.mesh_info()
    ps = mi.get("per_shard", {})
    print(f"mesh: {mi['shards']}-way over '{mi['axis']}' ({mi['mode']} "
          f"mode, bit_identical={mi['bit_identical']}), "
          f"{ps.get('bytes_per_shard', 0)} B pool/shard of "
          f"{ps.get('total_bytes', 0)} B total")
  print(f"engine stats: {engine.stats.summary()}")
  by = engine.layout.bytes(active_slots=engine.active_count)
  if by["kind"] in ("paged", "tiered"):
    print(f"kv memory: peak {by['peak_blocks']}/{by['num_blocks']} blocks "
          f"x {by['block_bytes']} B (+{by['resident_bytes_per_slot']} B/slot "
          f"resident), pool capacity {by['capacity_bytes']} B")
    if by["kind"] == "tiered":
      print(f"host tier: {by['host_allocated_blocks']}/{by['host_blocks']} "
            f"blocks holding {by['spilled_requests']} spilled requests "
            f"({by['spilled_payload_bytes']} B)")
      print(f"transfer: {engine.layout.ledger.summary()}")
    if args.prefix_cache:
      idx = engine.layout.prefix_index
      print(f"prefix cache: {idx.held_blocks}/{idx.budget_blocks} blocks "
            f"held ({idx.chain_nodes} chain nodes, {idx.full_entries} full "
            f"entries), {idx.hits} hits ({idx.hit_tokens} tokens), "
            f"{by['forked_blocks']} cow-forks, {by['dedup_bytes']} B "
            f"deduped now")
  else:
    print(f"kv memory: {by['total_bytes']} B contiguous "
          f"({by['per_slot_bytes']} B/slot x {args.batch} slots)")
  for r in done:
    print(f"  rid={r.rid} prompt_len={r.prompt_len} admitted@{r.admitted_step}"
          f" finished@{r.finished_step} preempts={r.preempt_count} "
          f"spills={r.spill_count} tokens={r.tokens[:8]}")
  if args.stats_json:
    dump_stats_json(engine, args.stats_json)
    print(f"stats written to {args.stats_json}")


def workload_spec_from_args(args):
  """Translate the --workload/--arrival/--slo-* flag family into a
  `WorkloadSpec` (kept separate so tests can assert the plumbing)."""
  from repro.launch import slo as slo_lib
  from repro.launch import workload as workload_lib
  slo = slo_lib.SLOSpec(ttft_s=args.slo_ttft, tpot_s=args.slo_tpot)
  p_lo = max(1, args.prompt_len // 2)
  g_lo = max(1, args.gen // 2)
  tenants = []
  for i in range(max(1, args.tenants)):
    # tenant 0 of a multi-tenant mix shares a prompt prefix (the traffic
    # pattern the prefix cache exists for) and carries priority 1, so the
    # SLO scheduler protects it when overload forces shedding
    shared = p_lo // 2 if (i == 0 and args.tenants > 1) else 0
    prio = 1 if (i == 0 and args.tenants > 1) else 0
    tenants.append(workload_lib.TenantSpec(
        name=f"t{i}", prompt_len=(p_lo, args.prompt_len),
        max_new_tokens=(g_lo, args.gen), shared_prefix_len=shared, slo=slo,
        priority=prio))
  return workload_lib.WorkloadSpec(
      arrival=args.arrival, rate=args.arrival_rate,
      burstiness=args.burstiness, n_requests=args.workload,
      seed=args.workload_seed, tenants=tuple(tenants),
      trace_path=args.trace_file, fetch_fail_rate=args.fetch_fail_rate,
      fetch_fail_seed=args.workload_seed)


def run_workload_demo(args) -> None:
  """Trace-driven serving under the virtual clock: seeded arrivals feed the
  engine, transfers overlap decode (or serialize with --no-overlap), and
  the run reports SLO metrics instead of wall-clock throughput."""
  import warnings

  from repro.launch import slo as slo_lib
  from repro.launch import workload as workload_lib
  from repro.runtime.fault_tolerance import FaultPlan
  from repro.runtime.fault_tolerance import FetchFaultInjector
  from repro.runtime.fault_tolerance import make_fault_plan
  spec = workload_spec_from_args(args)
  clock = workload_lib.VirtualClock(overlap=not args.no_overlap)
  injector = None
  if getattr(args, "fault_kind", None):
    if spec.fetch_fail_rate > 0:
      raise SystemExit("--fault-kind conflicts with --fetch-fail-rate "
                       "(pick one injection surface spec)")
    injector = make_fault_plan(args.fault_kind, args.fault_rate,
                               seed=spec.fetch_fail_seed)
  elif spec.fetch_fail_rate > 0:
    warnings.warn(
        "--fetch-fail-rate is deprecated; use --fault-kind fetch "
        "--fault-rate R (the seeded multi-surface FaultPlan path)",
        DeprecationWarning, stacklevel=2)
    injector = FetchFaultInjector(fail_rate=spec.fetch_fail_rate,
                                  seed=spec.fetch_fail_seed)
  loss_rate = getattr(args, "shard_fault_loss", 0.0) or 0.0
  stall_rate = getattr(args, "shard_fault_stall", 0.0) or 0.0
  if loss_rate > 0 or stall_rate > 0:
    if injector is None:
      injector = FaultPlan(seed=spec.fetch_fail_seed)
    elif not isinstance(injector, FaultPlan):
      raise SystemExit(
          "--shard-fault-* needs the FaultPlan surfaces; replace "
          "--fetch-fail-rate with --fault-kind fetch --fault-rate")
    injector.shard_loss_rate = loss_rate
    injector.shard_stall_rate = stall_rate
  engine = build_engine(args, clock=clock, fault_injector=injector)
  driver = workload_lib.WorkloadDriver(engine, spec)
  result = driver.run()
  mode = "serialized" if args.no_overlap else "overlapped"
  print(f"workload: {spec.arrival} arrivals at {spec.rate}/s, "
        f"{len(driver.requests)} requests, {mode} spill/fetch "
        f"[layout={args.cache_layout} scheduler={args.scheduler} "
        f"policy={args.cache_policy}]")
  print(f"slo: {slo_lib.summary(result.report)}")
  print(f"engine stats: {engine.stats.summary()}")
  if getattr(args, "slo_enforce", False):
    print(f"admission control: {engine.stats.shed_requests} shed, "
          f"final state {engine.stats.degradation_state}, "
          f"{len(engine.stats.degradation_transitions)} transitions")
  if injector is not None and hasattr(injector, "by_surface"):
    print(f"fault plan: {injector.injected} injected {dict(injector.by_surface)}")
  if engine.stats.shard_losses or engine.stats.shard_stalls:
    print(f"shard health: {engine.shard_health_info()}")
  if getattr(args, "save_snapshot", False):
    saved = engine.save_snapshot(step=engine.stats.steps)
    if saved:
      print(f"prefix snapshot saved to {saved}")
    else:
      print("prefix snapshot skipped (needs --snapshot-dir + --prefix-cache)")
  if args.stats_json:
    dump_stats_json(engine, args.stats_json,
                    extra={"workload": dict(
                        result.report, arrival=spec.arrival, rate=spec.rate,
                        seed=spec.seed, overlap=not args.no_overlap)})
    print(f"stats written to {args.stats_json}")


def make_parser() -> argparse.ArgumentParser:
  ap = argparse.ArgumentParser(description=__doc__)
  ap.add_argument("--arch", default="tinyllama-1.1b")
  ap.add_argument("--reduced", action="store_true")
  ap.add_argument("--batch", type=int, default=4)
  ap.add_argument("--prompt-len", type=int, default=128)
  ap.add_argument("--gen", type=int, default=32)
  ap.add_argument("--cache-policy", default="pq",
                  choices=cache_registry.names())
  ap.add_argument("--cache-layout", default="contiguous",
                  choices=cache_registry.layout_names(),
                  help="physical KV storage (engine mode): contiguous slabs, "
                       "paged token blocks, or tiered (device + host pools "
                       "with compressed spill/fetch)")
  ap.add_argument("--scheduler", default="fifo",
                  choices=scheduler_lib.names(),
                  help="engine admission policy (paged requires "
                       "--cache-layout paged/tiered; tiered requires "
                       "--cache-layout tiered)")
  ap.add_argument("--decode-kernel", default="auto",
                  choices=decode_dispatch.names(),
                  help="decode attention implementation: xla (pure-JAX "
                       "reference), pallas (Mosaic kernels, TPU only), "
                       "pallas-interpret (kernels via the interpreter, runs "
                       "anywhere), auto (pallas on TPU, xla elsewhere).  "
                       "With paged/tiered layouts a pallas dispatch decodes "
                       "block-table-native: no dense gather/scatter round "
                       "trip")
  ap.add_argument("--kv-block-size", type=int, default=16,
                  help="paged-layout token-block granularity")
  ap.add_argument("--num-blocks", type=int, default=None,
                  help="paged-layout device pool size (default: batch * "
                       "capacity/block, i.e. contiguous-equivalent)")
  ap.add_argument("--host-blocks", type=int, default=None,
                  help="tiered-layout host (tier 1) pool size in blocks "
                       "(default: 4x the device pool)")
  # choices come from the registries so an unknown key fails at argparse
  # with the valid set listed, not layers later at CacheSpec validation
  ap.add_argument("--spill-codec", default="raw",
                  choices=tuple(sorted(tiers.SPILL_CODECS)),
                  help="tiered-layout exact-KV spill codec (core.tiers "
                       "registry; q4/q8 are GGUF-style packed groups); PQ "
                       "code rows always spill verbatim (they are the "
                       "compressed form)")
  ap.add_argument("--kv-resident-codec", default="none",
                  choices=tuple(packing.RESIDENT_CODECS),
                  help="exact-policy resident KV store: none keeps dense "
                       "floats; q4/q8 store sub-byte packed pages "
                       "(kernels/packing.py) decoded in-kernel — ~0.19x "
                       "the fp32 footprint at q4")
  ap.add_argument("--prefix-cache", action="store_true",
                  help="share prompt-prefix KV blocks across requests "
                       "(copy-on-write block tables + suffix-only prefill; "
                       "requires --cache-layout paged/tiered, token-exact "
                       "under greedy decoding)")
  ap.add_argument("--prefix-cache-blocks", type=int, default=None,
                  help="device blocks the prefix index may pin "
                       "(refcount+LRU budget; default: half the pool)")
  ap.add_argument("--mesh-model", type=int, default=None, metavar="N",
                  help="shard the engine decode over an N-way mesh model "
                  "axis (kv heads when divisible, else split-K over the "
                  "sequence for the exact policy); pooled layouts only. "
                  "N must divide the device count — on CPU force devices "
                  "with XLA_FLAGS=--xla_force_host_platform_device_count")
  ap.add_argument("--stats-json", default=None, metavar="PATH",
                  help="engine mode: dump EngineStats.as_dict() + layout "
                       "footprint + transfer ledger as JSON")
  ap.add_argument("--no-pq", action="store_true",
                  help="legacy alias for --cache-policy exact")
  ap.add_argument("--engine", action="store_true",
                  help="run the continuous-batching ServeEngine demo")
  # ---- workload harness (trace-driven traffic under a virtual clock) ----
  ap.add_argument("--workload", type=int, default=None, metavar="N",
                  help="drive the engine with N seeded trace-generated "
                       "requests under a virtual clock (implies --engine); "
                       "reports TTFT/TPOT/goodput SLO metrics")
  ap.add_argument("--arrival", default="poisson",
                  choices=("poisson", "bursty", "trace"),
                  help="arrival process: poisson (exponential gaps), bursty "
                       "(Gamma gaps, cv^2=--burstiness), or trace (replay "
                       "--trace-file)")
  ap.add_argument("--arrival-rate", type=float, default=50.0,
                  help="mean arrivals per virtual second")
  ap.add_argument("--burstiness", type=float, default=4.0,
                  help="cv^2 of bursty interarrivals (1 = Poisson)")
  ap.add_argument("--trace-file", default=None, metavar="PATH",
                  help="JSON arrival trace for --arrival trace")
  ap.add_argument("--slo-ttft", type=float, default=0.5,
                  help="SLO: time-to-first-token budget (virtual seconds)")
  ap.add_argument("--slo-tpot", type=float, default=0.05,
                  help="SLO: per-output-token budget (virtual seconds)")
  ap.add_argument("--workload-seed", type=int, default=0,
                  help="seed for the workload trace and fault injection "
                       "(same seed = identical trace, byte for byte)")
  ap.add_argument("--tenants", type=int, default=1,
                  help="synthetic tenant count; tenant 0 of a multi-tenant "
                       "mix shares a prompt prefix")
  ap.add_argument("--no-overlap", action="store_true",
                  help="serialized spill/fetch fallback: every transfer "
                       "stalls the virtual clock (tokens must stay "
                       "bit-identical to overlapped mode)")
  ap.add_argument("--fetch-fail-rate", type=float, default=0.0,
                  help="inject host-tier fetch faults at this per-attempt "
                       "probability (engine retries with bounded backoff)")
  ap.add_argument("--slo-enforce", action="store_true",
                  help="enforce per-request deadlines as admission control: "
                       "shed doomed queued/expired work, run the NORMAL -> "
                       "PRESSURED -> SHEDDING degradation state machine "
                       "(pairs with --scheduler slo)")
  ap.add_argument("--fault-kind", default=None,
                  choices=tuple(ft.FAULT_KINDS),
                  help="seeded multi-surface fault injection (FaultPlan): "
                       "fetch failures, corrupted spill pages (checksum-"
                       "detected, recovered by recompute-prefill), allocator "
                       "exhaustion spikes, transient decode-step failures "
                       "(bounded retry/backoff), or mesh shard loss/stall "
                       "(watchdog-confirmed, degraded-mesh replan)")
  ap.add_argument("--fault-rate", type=float, default=0.1,
                  help="per-event probability for --fault-kind (seeded by "
                       "--workload-seed)")
  ap.add_argument("--shard-fault-loss", type=float, default=0.0,
                  metavar="RATE",
                  help="per-step probability of a seeded shard-loss fault "
                       "(kills one mesh shard; the watchdog confirms the "
                       "death and the engine replans the survivors).  "
                       "Composes with --fault-kind")
  ap.add_argument("--shard-fault-stall", type=float, default=0.0,
                  metavar="RATE",
                  help="per-step probability of a seeded shard-stall fault "
                       "(one shard misses its decode heartbeat; sustained "
                       "stalls escalate to a confirmed death)")
  ap.add_argument("--shard-redundancy", default="none",
                  choices=("none", "host-mirror"),
                  help="KV redundancy against shard loss: host-mirror keeps "
                       "a checksummed host-tier copy of every resident "
                       "request's pool pages (written through the spill "
                       "codecs) so a dead shard's blocks restore by fetch + "
                       "re-scatter; none falls back to recompute-prefill")
  ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                  help="crash-safe prefix-cache snapshots: restore the "
                       "latest snapshot in DIR at engine startup (warm "
                       "prefix hits after a restart; requires "
                       "--prefix-cache)")
  ap.add_argument("--save-snapshot", action="store_true",
                  help="persist the prefix cache to --snapshot-dir after "
                       "the workload run")
  ap.add_argument("--pcie-gbps", type=float, default=None,
                  help="override the modeled tier-boundary link bandwidth "
                       "(smaller = transfers dominate, stressing overlap)")
  return ap


def main():
  ap = make_parser()
  args = ap.parse_args()
  # --no-pq is an alias for --cache-policy exact; refuse a conflicting mix
  # rather than silently measuring the wrong policy
  if args.no_pq:
    if args.cache_policy not in ("pq", "exact"):
      ap.error(f"--no-pq conflicts with --cache-policy {args.cache_policy}")
    args.cache_policy = "exact"
  if args.workload is not None:
    args.engine = True               # the harness drives the engine
  if args.stats_json and not args.engine:
    ap.error("--stats-json requires --engine (EngineStats are engine-mode)")
  if args.arrival == "trace" and args.workload is not None \
      and not args.trace_file:
    ap.error("--arrival trace requires --trace-file")
  if args.save_snapshot and not args.snapshot_dir:
    ap.error("--save-snapshot requires --snapshot-dir")
  if args.fault_kind and args.workload is None:
    ap.error("--fault-kind requires --workload (fault plans drive the "
             "virtual-clock harness)")
  if (args.shard_fault_loss or args.shard_fault_stall) \
      and args.workload is None:
    ap.error("--shard-fault-* requires --workload (shard faults drive the "
             "virtual-clock harness)")

  if args.workload is not None:
    run_workload_demo(args)
    return
  if args.engine:
    run_engine_demo(args)
    return

  run = ServeRun(arch=args.arch, reduced=args.reduced, batch=args.batch,
                 prompt_len=args.prompt_len, gen=args.gen,
                 cache_policy=args.cache_policy,
                 decode_kernel=args.decode_kernel)
  res = run.run()
  print(f"arch={args.arch} policy={res['cache_policy']} "
        f"kernel={res['decode_kernel']} "
        f"prefill={res['prefill_s']:.2f}s decode={res['decode_s']:.2f}s "
        f"({res['tok_per_s']:.1f} tok/s, step p50 "
        f"{res['decode_step_p50_ms']:.2f} / p99 "
        f"{res['decode_step_p99_ms']:.2f} ms)")
  print("sample tokens:", res["tokens"][0, :16].tolist())


if __name__ == "__main__":
  main()
