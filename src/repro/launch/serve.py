"""End-to-end serving driver: batched prefill -> PQ compression -> decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
      --prompt-len 128 --gen 32 --batch 4

This exercises the full AQPIM inference path (paper Fig. 3a): prefill computes
exact attention AND builds the compressed cache (importance-weighted windowed
clustering, hidden behind prefill); the decode loop appends tokens by PQ-encoding
ring-buffer evictions and attends directly on compressed data.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh
from repro.parallel import sharding as shd


@dataclasses.dataclass
class ServeRun:
  arch: str
  reduced: bool = True
  batch: int = 4
  prompt_len: int = 128
  gen: int = 32
  pq: bool = True
  seed: int = 0
  greedy: bool = True
  mesh: Any = None

  def run(self):
    cfg = get_arch(self.arch, reduced=self.reduced)
    if not self.pq:
      cfg = dataclasses.replace(cfg, pq_enabled=False)
    context = self.prompt_len + self.gen
    mesh = self.mesh or make_local_mesh()
    shape = ShapeConfig("serve", context, self.batch, "decode")
    progs = steps_lib.build_programs(cfg, shape, mesh, donate=False)
    model = progs.model

    key = jax.random.PRNGKey(self.seed)
    params = jax.jit(
        model.init,
        out_shardings=shd.make_shardings(progs.param_specs, mesh))(key)
    prompts = jax.random.randint(
        key, (self.batch, self.prompt_len), 0, cfg.vocab_size)
    modal = None
    if cfg.frontend == "audio_frames":
      modal = jnp.zeros((self.batch, context, cfg.d_model), cfg.dtype)
    elif cfg.frontend == "vision_patches":
      modal = jnp.zeros((self.batch, cfg.n_modal_tokens, cfg.d_model),
                        cfg.dtype)

    with mesh:
      t0 = time.monotonic()
      prefill = jax.jit(model.prefill)
      m_pref = modal[:, :self.prompt_len] if (
          modal is not None and cfg.frontend == "audio_frames") else modal
      logits, cache = prefill(params, prompts, m_pref)
      logits.block_until_ready()
      t_prefill = time.monotonic() - t0

      # pad recurrent/kv caches built at prompt_len up to full context capacity
      cache = _pad_cache_to(model, cache, self.batch)

      step = jax.jit(model.decode_step)
      tokens = [jnp.argmax(logits, -1).astype(jnp.int32)]
      t1 = time.monotonic()
      for i in range(self.gen):
        length = jnp.asarray(self.prompt_len + i, jnp.int32)
        m_step = (modal[:, self.prompt_len + i:self.prompt_len + i + 1]
                  if modal is not None and cfg.frontend == "audio_frames"
                  else modal)
        logits, cache = step(params, tokens[-1], cache, length, m_step)
        tokens.append(jnp.argmax(logits, -1).astype(jnp.int32))
      jax.block_until_ready(tokens[-1])
      t_decode = time.monotonic() - t1

    out = jnp.stack(tokens[:-1], axis=1)
    return {
        "tokens": out,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tok_per_s": self.batch * self.gen / max(t_decode, 1e-9),
        "pq": cfg.pq_enabled and cfg.supports_pq,
    }


def _pad_cache_to(model, cache, batch):
  """Prefill builds caches at context capacity already (PQ) — exact caches are
  padded to the model's context_len by exact_cache_prefill; recurrent states
  carry no length.  Nothing to do today; hook kept for ring-resize variants."""
  return cache


def main():
  ap = argparse.ArgumentParser(description=__doc__)
  ap.add_argument("--arch", default="tinyllama-1.1b")
  ap.add_argument("--reduced", action="store_true")
  ap.add_argument("--batch", type=int, default=4)
  ap.add_argument("--prompt-len", type=int, default=128)
  ap.add_argument("--gen", type=int, default=32)
  ap.add_argument("--no-pq", action="store_true")
  args = ap.parse_args()

  run = ServeRun(arch=args.arch, reduced=args.reduced, batch=args.batch,
                 prompt_len=args.prompt_len, gen=args.gen, pq=not args.no_pq)
  res = run.run()
  print(f"arch={args.arch} pq={res['pq']} "
        f"prefill={res['prefill_s']:.2f}s decode={res['decode_s']:.2f}s "
        f"({res['tok_per_s']:.1f} tok/s)")
  print("sample tokens:", res["tokens"][0, :16].tolist())


if __name__ == "__main__":
  main()
