"""Trace-driven workload harness: arrival processes, tenant prompt mixes,
and the virtual-clock driver that feeds `ServeEngine.submit`.

The engine (launch/engine.py) answers "given these requests, what happens?";
this module answers "which requests, *when*?" — the missing half of the
paper's serving story.  LoL-PIM and PIM-AI (PAPERS.md) both evaluate
long-context PIM serving under arrival-driven load with latency SLOs; the
ROADMAP names the production traffic harness as an open item.  Three pieces:

- **Arrival processes**, a string-keyed registry mirroring the scheduler /
  layout registries (`workload.make_arrival("poisson", ...)`):

    | key       | interarrival model                                       |
    |-----------|----------------------------------------------------------|
    | `poisson` | exponential gaps at `rate` req/s (memoryless baseline)   |
    | `bursty`  | Gamma gaps, mean `1/rate`, cv^2 = `burstiness` — bursts  |
    |           | of back-to-back arrivals separated by long quiet gaps    |
    | `trace`   | replay absolute arrival times from a JSON trace file     |

- **Tenant mixes** (`TenantSpec`): each tenant has a sampling weight,
  prompt/generation length ranges, an optional shared prompt prefix (its
  requests exercise the prefix cache / COW paths), and an `SLOSpec`.
  `generate()` samples a full request trace from one seeded
  `np.random.default_rng` — no wallclock RNG anywhere, so a (spec, seed)
  pair IS the workload, byte-for-byte, across machines and CI runs.

- **`VirtualClock` + `WorkloadDriver`**: simulated time.  Decode steps and
  prefill tokens cost fixed virtual durations; host-tier transfers occupy a
  single modeled PCIe link (`TransferLedger.transfer_s`) that the engine
  either overlaps with decode (`overlap=True`: IN_FLIGHT blocks complete at
  the transfer deadline while resident requests keep decoding) or
  serializes against (`overlap=False`: every transfer stalls the clock —
  the PR 3 behavior, kept as the bit-identity oracle).  The driver submits
  arrivals when the clock reaches them, steps the engine, and folds each
  finished request into an `slo.RequestTiming` for `slo.build_report`.
"""
from __future__ import annotations

import dataclasses
import json
import zlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.launch import slo as slo_lib

# ---------------------------------------------------------------------------
# arrival-process registry
# ---------------------------------------------------------------------------

_ARRIVALS: Dict[str, Callable] = {}


def register_arrival(name: str) -> Callable:
  def deco(fn: Callable) -> Callable:
    if name in _ARRIVALS and _ARRIVALS[name] is not fn:
      raise ValueError(f"arrival process {name!r} already registered")
    _ARRIVALS[name] = fn
    return fn
  return deco


def get_arrival(name: str) -> Callable:
  try:
    return _ARRIVALS[name]
  except KeyError:
    raise KeyError(
        f"unknown arrival process {name!r}; available: {arrival_names()}"
    ) from None


def arrival_names() -> Tuple[str, ...]:
  return tuple(sorted(_ARRIVALS))


@register_arrival("poisson")
def poisson_arrivals(spec: "WorkloadSpec", rng: np.random.Generator
                     ) -> np.ndarray:
  """Memoryless arrivals: exponential interarrival gaps at `rate` req/s."""
  gaps = rng.exponential(1.0 / spec.rate, size=spec.n_requests)
  return np.cumsum(gaps)


@register_arrival("bursty")
def bursty_arrivals(spec: "WorkloadSpec", rng: np.random.Generator
                    ) -> np.ndarray:
  """Overdispersed arrivals: Gamma interarrival gaps with the same mean as
  the Poisson process (`1/rate`) but cv^2 = `burstiness` (> 1): most gaps
  are near zero (a burst), a few are long (the quiet tail).  burstiness=1
  degenerates to Poisson."""
  if spec.burstiness <= 0:
    raise ValueError(f"burstiness must be > 0, got {spec.burstiness}")
  shape = 1.0 / spec.burstiness
  scale = spec.burstiness / spec.rate
  gaps = rng.gamma(shape, scale, size=spec.n_requests)
  return np.cumsum(gaps)


@register_arrival("trace")
def trace_arrivals(spec: "WorkloadSpec", rng: np.random.Generator
                   ) -> np.ndarray:
  """Replay absolute arrival times from `spec.trace_path` (see load_trace).
  The file fixes `t` (and optionally per-request shapes); sampling for the
  unfixed fields still comes from the seeded rng in generate()."""
  del rng
  events = load_trace(spec.trace_path)
  return np.asarray([e["t"] for e in events], np.float64)


def load_trace(path: Optional[str]) -> List[dict]:
  """A trace file is JSON: either a list of events or {"events": [...]},
  each event `{"t": seconds, ...}` with optional `tenant`, `prompt_len`,
  `max_new_tokens`, and literal `prompt` (token list) overrides.  Events
  are sorted by `t`; times must be non-negative."""
  if not path:
    raise ValueError("arrival='trace' requires trace_path")
  with open(path) as f:
    data = json.load(f)
  events = data["events"] if isinstance(data, dict) else data
  out = []
  for e in events:
    t = float(e["t"])
    if t < 0:
      raise ValueError(f"trace arrival time must be >= 0, got {t}")
    out.append(dict(e, t=t))
  out.sort(key=lambda e: e["t"])
  return out


# ---------------------------------------------------------------------------
# workload specification
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TenantSpec:
  """One traffic class: sampling weight, length distributions, SLO.

  `shared_prefix_len > 0` gives every request from this tenant the same
  leading tokens (drawn once from a stream seeded by (workload seed, crc32
  of the tenant name) — stable across runs and across tenant-list order),
  which is what drives the prefix-cache / COW sharing paths under load.
  """
  name: str = "default"
  weight: float = 1.0
  prompt_len: Tuple[int, int] = (16, 48)       # inclusive range
  max_new_tokens: Tuple[int, int] = (4, 16)    # inclusive range
  shared_prefix_len: int = 0
  slo: slo_lib.SLOSpec = slo_lib.SLOSpec()
  priority: int = 0                # higher sheds later under SLO enforcement


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
  """Everything that defines a workload; (spec, seed) fully determines the
  request trace.  `fetch_fail_rate` is the fault-injection knob: the
  probability each host-tier fetch attempt fails (engine retries with
  bounded backoff; see `runtime.fault_tolerance.FetchFaultInjector`)."""
  arrival: str = "poisson"
  rate: float = 50.0                  # mean arrivals per virtual second
  burstiness: float = 4.0             # cv^2 of bursty interarrivals
  n_requests: int = 16
  seed: int = 0
  tenants: Tuple[TenantSpec, ...] = (TenantSpec(),)
  trace_path: Optional[str] = None
  fetch_fail_rate: float = 0.0
  fetch_fail_seed: int = 0


@dataclasses.dataclass(frozen=True)
class WorkloadRequest:
  """One generated request: when it arrives and what it asks for."""
  index: int
  arrival_s: float
  tenant: str
  tokens: Tuple[int, ...]
  max_new_tokens: int
  slo: slo_lib.SLOSpec
  priority: int = 0

  @property
  def prompt_len(self) -> int:
    return len(self.tokens)


def _shared_prefix(spec: WorkloadSpec, tenant: TenantSpec, vocab_size: int
                   ) -> np.ndarray:
  """The tenant's common leading tokens.  Seeded by (workload seed, crc32
  of the tenant name): stable across runs and independent of tenant-list
  order (python's hash() is salted per process — useless here)."""
  rng = np.random.default_rng(
      (spec.seed, zlib.crc32(tenant.name.encode("utf-8"))))
  return rng.integers(1, vocab_size, size=tenant.shared_prefix_len,
                      dtype=np.int64)


def generate(spec: WorkloadSpec, *, vocab_size: int, max_prompt_len: int,
             max_total_len: int) -> List[WorkloadRequest]:
  """Sample the full request trace for `spec`, clamped to engine capacity
  (`max_prompt_len` = prompt_capacity, `max_total_len` = context_len).
  One master rng seeded by `spec.seed` drives every draw in a fixed order,
  so the trace is reproducible byte-for-byte."""
  if spec.n_requests < 1:
    raise ValueError(f"n_requests must be >= 1, got {spec.n_requests}")
  if spec.rate <= 0:
    raise ValueError(f"rate must be > 0, got {spec.rate}")
  if not spec.tenants:
    raise ValueError("workload needs at least one tenant")
  rng = np.random.default_rng(spec.seed)
  arrivals = get_arrival(spec.arrival)(spec, rng)
  trace_events: List[dict] = []
  if spec.arrival == "trace":
    trace_events = load_trace(spec.trace_path)
  n = len(arrivals) if spec.arrival == "trace" else spec.n_requests

  tenants = {t.name: t for t in spec.tenants}
  weights = np.asarray([t.weight for t in spec.tenants], np.float64)
  if weights.sum() <= 0:
    raise ValueError("tenant weights must sum to > 0")
  weights = weights / weights.sum()
  prefixes = {t.name: _shared_prefix(spec, t, vocab_size)
              for t in spec.tenants if t.shared_prefix_len > 0}

  out: List[WorkloadRequest] = []
  for i in range(n):
    event = trace_events[i] if trace_events else {}
    if "tenant" in event:
      tenant = tenants[event["tenant"]]
    else:
      tenant = spec.tenants[int(rng.choice(len(spec.tenants), p=weights))]
    lo, hi = tenant.prompt_len
    p_len = int(event.get("prompt_len", rng.integers(lo, hi + 1)))
    p_len = max(1, min(p_len, max_prompt_len))
    lo, hi = tenant.max_new_tokens
    gen = int(event.get("max_new_tokens", rng.integers(lo, hi + 1)))
    gen = max(1, min(gen, max_total_len - p_len - 1))
    if "prompt" in event:
      toks = np.asarray(event["prompt"], np.int64)[:p_len]
    else:
      toks = rng.integers(1, vocab_size, size=p_len, dtype=np.int64)
      shared = prefixes.get(tenant.name)
      if shared is not None:
        k = min(len(shared), p_len)
        toks[:k] = shared[:k]
    out.append(WorkloadRequest(
        index=i, arrival_s=float(arrivals[i]), tenant=tenant.name,
        tokens=tuple(int(x) for x in toks), max_new_tokens=gen,
        slo=tenant.slo, priority=tenant.priority))
  out.sort(key=lambda w: (w.arrival_s, w.index))
  return out


# ---------------------------------------------------------------------------
# virtual clock
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class VirtualClock:
  """Deterministic simulated time with one modeled PCIe link.

  Compute (decode steps, prefill tokens) advances `now` by fixed budgets.
  Transfers occupy the link back-to-back from `link_free_at`; with
  `overlap=True` a transfer's completion is a *deadline* the engine waits
  on only when it needs the data (decode keeps running meanwhile), with
  `overlap=False` every transfer stalls the clock on the spot — the
  serialized fallback whose tokens the overlapped mode must match bit for
  bit.  The four accumulators are the stall-attribution the SLO report
  breaks a run's makespan into.
  """
  decode_step_s: float = 2e-3      # virtual cost of one batched decode step
  prefill_token_s: float = 2e-5    # virtual cost per prefilled prompt token
  overlap: bool = True
  now: float = 0.0
  link_free_at: float = 0.0
  compute_s: float = 0.0           # decode + prefill time
  transfer_stall_s: float = 0.0    # blocked waiting on the link
  idle_s: float = 0.0              # no work due (waiting for arrivals)
  link_busy_s: float = 0.0         # link occupancy (overlapped or not)

  def advance(self, dt: float) -> None:
    """Spend `dt` seconds of compute."""
    if dt < 0:
      raise ValueError(f"cannot advance by {dt}")
    self.now += dt
    self.compute_s += dt

  def start_transfer(self, duration_s: float) -> float:
    """Queue a transfer on the link; returns its completion time.  The link
    is serial: a transfer starts when the previous one drains.  In
    serialized mode the clock stalls here; in overlapped mode the caller
    holds the returned deadline and stalls only if it needs the data."""
    if duration_s < 0:
      raise ValueError(f"negative transfer duration {duration_s}")
    start = max(self.now, self.link_free_at)
    ready = start + duration_s
    self.link_free_at = ready
    self.link_busy_s += duration_s
    if not self.overlap:
      self.stall_until(ready)
    return ready

  def stall_until(self, t: float) -> None:
    """Block on a transfer deadline (attributed as transfer stall)."""
    if t > self.now:
      self.transfer_stall_s += t - self.now
      self.now = t

  def idle_until(self, t: float) -> None:
    """Sleep until the next arrival (attributed as idle, not stall)."""
    if t > self.now:
      self.idle_s += t - self.now
      self.now = t

  def as_dict(self) -> dict:
    return dict(now=self.now, compute_s=self.compute_s,
                transfer_stall_s=self.transfer_stall_s, idle_s=self.idle_s,
                link_busy_s=self.link_busy_s, overlap=self.overlap)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WorkloadResult:
  """One driven run: the SLO report plus everything needed to compare two
  runs (per-request greedy token streams keyed by workload index)."""
  report: dict
  records: List[slo_lib.RequestTiming]
  token_streams: Dict[int, Tuple[int, ...]]
  clock: VirtualClock
  failed_indices: Tuple[int, ...] = ()
  shed_indices: Tuple[int, ...] = ()


class WorkloadDriver:
  """Feeds a generated trace into a `ServeEngine` under its virtual clock.

  The engine must have been built with `clock=` (the driver refuses a
  wall-clock engine: without a clock there is no "when" for arrivals to
  happen at).  The loop: submit every request whose arrival time has come,
  idle the clock forward when the engine has nothing to do, step, and fold
  finished requests into `slo.RequestTiming` records.
  """

  def __init__(self, engine, spec: WorkloadSpec):
    if getattr(engine, "clock", None) is None:
      raise ValueError(
          "WorkloadDriver needs an engine built with clock=VirtualClock(...)")
    self.engine = engine
    self.spec = spec
    self.clock: VirtualClock = engine.clock
    self.requests = generate(
        spec, vocab_size=engine.cfg.vocab_size,
        max_prompt_len=engine.prompt_capacity,
        max_total_len=engine.context_len)

  def run(self, max_steps: int = 100_000) -> WorkloadResult:
    eng, clock = self.engine, self.clock
    pending = self.requests
    timings: Dict[int, slo_lib.RequestTiming] = {}
    rid_to_index: Dict[int, int] = {}
    records: List[slo_lib.RequestTiming] = []
    token_streams: Dict[int, Tuple[int, ...]] = {}
    failed: List[int] = []
    shed: List[int] = []
    i = 0
    steps = 0
    while i < len(pending) or eng.has_work:
      while i < len(pending) and pending[i].arrival_s <= clock.now + 1e-12:
        w = pending[i]
        deadline = w.slo.deadline_s(w.arrival_s, w.max_new_tokens)
        h = eng.submit(list(w.tokens), max_new_tokens=w.max_new_tokens,
                       deadline_s=deadline, tenant=w.tenant,
                       priority=w.priority)
        h.submit_s = w.arrival_s
        rid_to_index[h.rid] = w.index
        timings[h.rid] = slo_lib.RequestTiming(
            rid=h.rid, tenant=w.tenant, arrival_s=w.arrival_s,
            deadline_s=deadline, max_new_tokens=w.max_new_tokens)
        i += 1
      if not eng.has_work:
        clock.idle_until(pending[i].arrival_s)
        continue
      for h in eng.step():
        t = timings[h.rid]
        t.n_tokens = len(h.tokens)
        t.admit_s = h.admit_s
        t.first_token_s = h.first_token_s
        t.finish_s = h.finish_s
        t.failed = h.failed
        t.shed = h.shed
        records.append(t)
        idx = rid_to_index[h.rid]
        token_streams[idx] = tuple(h.tokens)
        if h.failed:
          failed.append(idx)
        if h.shed:
          shed.append(idx)
      steps += 1
      if steps > max_steps:
        raise RuntimeError(
            f"workload did not drain within {max_steps} steps "
            f"({len(records)}/{len(pending)} finished)")
    records.sort(key=lambda t: rid_to_index[t.rid])
    report = slo_lib.build_report(records, clock)
    return WorkloadResult(report=report, records=records,
                          token_streams=token_streams, clock=clock,
                          failed_indices=tuple(sorted(failed)),
                          shed_indices=tuple(sorted(shed)))
