"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --reduced \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/run0

Wires together: config registry -> Model -> sharding rules -> pjit train_step ->
deterministic data pipeline -> AdamW -> async checkpointing -> fault-tolerant
supervisor (restart-from-latest on failure).
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data import pipeline as data_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.runtime import fault_tolerance as ft


@dataclasses.dataclass
class TrainRun:
  """A configured, restartable training run."""
  arch: str
  reduced: bool = True
  steps: int = 100
  batch: int = 8
  seq: int = 256
  lr: float = 3e-4
  ckpt_dir: Optional[str] = None
  ckpt_every: int = 50
  compress_grads: bool = False
  seed: int = 0
  mesh: Any = None
  log_every: int = 10

  def build(self):
    cfg = get_arch(self.arch, reduced=self.reduced)
    mesh = self.mesh or make_local_mesh()
    shape = ShapeConfig("custom_train", self.seq, self.batch, "train")
    opt_cfg = adamw.OptConfig(
        lr=self.lr, warmup_steps=max(self.steps // 20, 5),
        total_steps=self.steps, compress_grads=self.compress_grads)
    progs = steps_lib.build_programs(cfg, shape, mesh, opt_cfg=opt_cfg)
    dcfg = data_lib.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=self.seq,
        global_batch=self.batch, seed=self.seed)
    return cfg, mesh, progs, opt_cfg, dcfg

  def run(self, injector: Optional[ft.FailureInjector] = None):
    cfg, mesh, progs, opt_cfg, dcfg = self.build()
    da = shd.data_axes(mesh)
    n_data = 1
    for a in da:
      n_data *= mesh.shape[a]
    bspec = P(da, None) if self.batch % n_data == 0 else P(None, None)
    losses = []

    def init_state():
      params = jax.jit(
          progs.model.init,
          out_shardings=shd.make_shardings(progs.param_specs, mesh)
      )(jax.random.PRNGKey(self.seed))
      opt_state = adamw.init(opt_cfg, params)
      return {"params": params, "opt": opt_state}

    def step_fn(state, step):
      batch = data_lib.make_batch(dcfg, step, mesh, bspec)
      if cfg.frontend == "audio_frames":
        batch["modal"] = jnp.zeros(
            (self.batch, self.seq, cfg.d_model), cfg.dtype)
      elif cfg.frontend == "vision_patches":
        batch["modal"] = jnp.zeros(
            (self.batch, cfg.n_modal_tokens, cfg.d_model), cfg.dtype)
      params, opt, metrics = progs.fn(state["params"], state["opt"], batch)
      loss = float(metrics["loss"])
      losses.append(loss)
      if step % self.log_every == 0:
        print(f"step {step:5d}  loss {loss:.4f}  "
              f"lr {float(metrics['lr']):.2e}  "
              f"gnorm {float(metrics['grad_norm']):.3f}")
      return {"params": params, "opt": opt}

    with mesh:
      if self.ckpt_dir:
        state, report = ft.run_with_restarts(
            total_steps=self.steps, ckpt_dir=self.ckpt_dir,
            ckpt_every=self.ckpt_every, init_state_fn=init_state,
            step_fn=step_fn, injector=injector)
        return state, losses, report
      state = init_state()
      for step in range(self.steps):
        state = step_fn(state, step)
      return state, losses, None


def main():
  ap = argparse.ArgumentParser(description=__doc__)
  ap.add_argument("--arch", default="tinyllama-1.1b")
  ap.add_argument("--reduced", action="store_true")
  ap.add_argument("--steps", type=int, default=100)
  ap.add_argument("--batch", type=int, default=8)
  ap.add_argument("--seq", type=int, default=256)
  ap.add_argument("--lr", type=float, default=3e-4)
  ap.add_argument("--ckpt-dir", default=None)
  ap.add_argument("--ckpt-every", type=int, default=50)
  ap.add_argument("--compress-grads", action="store_true")
  args = ap.parse_args()

  run = TrainRun(
      arch=args.arch, reduced=args.reduced, steps=args.steps,
      batch=args.batch, seq=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir,
      ckpt_every=args.ckpt_every, compress_grads=args.compress_grads)
  t0 = time.monotonic()
  _, losses, report = run.run()
  dt = time.monotonic() - t0
  print(f"\ndone: {args.steps} steps in {dt:.1f}s; "
        f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
  if report:
    print(f"restarts={report.restarts} stragglers={report.straggler_steps}")


if __name__ == "__main__":
  main()
