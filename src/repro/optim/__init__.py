"""Optimizers (no optax in env): AdamW + schedule + grad compression."""
from repro.optim import adamw

__all__ = ["adamw"]
