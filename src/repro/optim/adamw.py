"""AdamW with cosine schedule, global-norm clipping, bf16-param/f32-master
training, and optional int8 gradient compression with error feedback.

No optax in this environment — this is a from-scratch, pjit-friendly optimizer:
state is a pytree mirroring params, update is pure, and every leaf keeps the
param's sharding (moments inherit specs via parallel.sharding.opt_pspecs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common import Array, PyTree


@dataclasses.dataclass(frozen=True)
class OptConfig:
  lr: float = 3e-4
  warmup_steps: int = 100
  total_steps: int = 10000
  min_lr_ratio: float = 0.1
  b1: float = 0.9
  b2: float = 0.95
  eps: float = 1e-8
  weight_decay: float = 0.1
  clip_norm: float = 1.0
  master_f32: bool = True        # keep f32 master weights for bf16 params
  compress_grads: bool = False   # int8 + error-feedback gradient compression


class OptState(NamedTuple):
  step: Array
  mu: PyTree
  nu: PyTree
  master: Optional[PyTree]
  error: Optional[PyTree]        # error-feedback residual (compression)


def schedule(cfg: OptConfig, step: Array) -> Array:
  """Linear warmup -> cosine decay to min_lr_ratio."""
  step = step.astype(jnp.float32)
  warm = step / jnp.maximum(cfg.warmup_steps, 1)
  t = jnp.clip((step - cfg.warmup_steps)
               / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
  cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
      1 + jnp.cos(jnp.pi * t))
  return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(cfg: OptConfig, params: PyTree) -> OptState:
  zeros = jax.tree_util.tree_map(
      lambda p: jnp.zeros(p.shape, jnp.float32), params)
  master = None
  if cfg.master_f32:
    # explicit copy: astype is a no-op for f32 params and donation must never
    # see the same buffer twice (params + master)
    master = jax.tree_util.tree_map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
  error = None
  if cfg.compress_grads:
    error = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
  return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                  nu=jax.tree_util.tree_map(jnp.copy, zeros),
                  master=master, error=error)


def global_norm(tree: PyTree) -> Array:
  leaves = jax.tree_util.tree_leaves(tree)
  return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                      for l in leaves))


def _compress_int8(g: Array) -> Tuple[Array, Array]:
  """Per-tensor symmetric int8 quantization (the compressed wire format)."""
  scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
  q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
  return q, scale


def _decompress_int8(q: Array, scale: Array) -> Array:
  return q.astype(jnp.float32) * scale


def apply_compression(grads: PyTree, error: PyTree) -> Tuple[PyTree, PyTree]:
  """Error-feedback int8 compression: g' = Q(g + e); e' = (g + e) - g'.

  In a real deployment Q(g) is what crosses the DP all-reduce links (4x fewer
  bytes than f32); here the quantize/dequantize round-trip exercises the exact
  numerics and the residual state machinery.
  """
  def one(g, e):
    total = g.astype(jnp.float32) + e
    q, s = _compress_int8(total)
    deq = _decompress_int8(q, s)
    return deq, total - deq
  flat = jax.tree_util.tree_map(one, grads, error)
  new_grads = jax.tree_util.tree_map(lambda t: t[0], flat,
                                     is_leaf=lambda x: isinstance(x, tuple))
  new_error = jax.tree_util.tree_map(lambda t: t[1], flat,
                                     is_leaf=lambda x: isinstance(x, tuple))
  return new_grads, new_error


def update(cfg: OptConfig, state: OptState, params: PyTree, grads: PyTree
           ) -> Tuple[PyTree, OptState, Dict[str, Array]]:
  """One AdamW step.  Returns (new_params, new_state, metrics)."""
  grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)

  error = state.error
  if cfg.compress_grads and error is not None:
    grads, error = apply_compression(grads, error)

  gnorm = global_norm(grads)
  clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
  grads = jax.tree_util.tree_map(lambda g: g * clip, grads)

  step = state.step + 1
  lr = schedule(cfg, step)
  b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
  b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

  ref = state.master if state.master is not None else params

  def one(p, m, v, g):
    m_new = cfg.b1 * m + (1 - cfg.b1) * g
    v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
    upd = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
    p32 = p.astype(jnp.float32)
    p_new = p32 - lr * (upd + cfg.weight_decay * p32)
    return p_new, m_new, v_new

  out = jax.tree_util.tree_map(one, ref, state.mu, state.nu, grads)
  p_new = jax.tree_util.tree_map(
      lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
  mu = jax.tree_util.tree_map(
      lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
  nu = jax.tree_util.tree_map(
      lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))

  if state.master is not None:
    master = p_new
    params_out = jax.tree_util.tree_map(
        lambda p_old, p32: p32.astype(p_old.dtype), params, p_new)
  else:
    master = None
    params_out = jax.tree_util.tree_map(
        lambda p_old, p32: p32.astype(p_old.dtype), params, p_new)

  new_state = OptState(step=step, mu=mu, nu=nu, master=master, error=error)
  return params_out, new_state, {"grad_norm": gnorm, "lr": lr}
