"""Shared type aliases and small utilities used across the repro framework."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any
Params = Mapping[str, Any]
PRNGKey = jax.Array
Shape = Sequence[int]
DType = Any


def pytree_size_bytes(tree: PyTree) -> int:
  """Total bytes of all array leaves (ShapeDtypeStructs included)."""
  leaves = jax.tree_util.tree_leaves(tree)
  total = 0
  for leaf in leaves:
    if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
      n = 1
      for s in leaf.shape:
        n *= int(s)
      total += n * jnp.dtype(leaf.dtype).itemsize
  return total


def pytree_param_count(tree: PyTree) -> int:
  leaves = jax.tree_util.tree_leaves(tree)
  total = 0
  for leaf in leaves:
    if hasattr(leaf, "shape"):
      n = 1
      for s in leaf.shape:
        n *= int(s)
      total += n
  return total


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
  """Roofline constants for the target accelerator (TPU v5e-like)."""
  name: str = "tpu-v5e"
  peak_flops_bf16: float = 197e12   # per chip, FLOP/s
  hbm_bw: float = 819e9             # bytes/s per chip
  ici_bw: float = 50e9              # bytes/s per link
  hbm_capacity: float = 16e9        # bytes per chip
  vmem_capacity: float = 128e6      # bytes per core


V5E = HardwareSpec()


def cdiv(a: int, b: int) -> int:
  return -(-a // b)


def round_up(a: int, b: int) -> int:
  return cdiv(a, b) * b
