"""Small reusable timers shared by the serve driver and the benchmark harness.

`Stopwatch` wraps a block and (optionally) a `block_until_ready` target so
async-dispatched JAX work is actually counted; `time_us` is the classic
warmup-then-average microbenchmark loop.
"""
from __future__ import annotations

import time
from typing import Callable

import jax


class Stopwatch:
  """Context manager measuring wall time of a block.

      with Stopwatch() as sw:
        out = fn(x)
        sw.wait_for(out)          # block on async dispatch before stopping
      print(sw.seconds)
  """

  def __init__(self):
    self.seconds = 0.0
    self._t0 = 0.0

  def __enter__(self) -> "Stopwatch":
    self._t0 = time.monotonic()
    return self

  def wait_for(self, tree) -> None:
    jax.block_until_ready(tree)

  def __exit__(self, *exc) -> bool:
    self.seconds = time.monotonic() - self._t0
    return False


def latency_percentiles_ms(step_seconds) -> dict:
  """Per-step latency percentiles over raw wall-clock samples (seconds).

  The one place the p50/p99 definition lives: the serve driver, the engine
  stats, and therefore the bench records + CI regression guard all report
  percentiles computed exactly the same way.
  """
  samples = list(step_seconds)
  if not samples:
    return dict(steps=0, p50_ms=None, p99_ms=None, mean_ms=None)
  import numpy as np
  a = np.asarray(samples, np.float64) * 1e3
  return dict(steps=int(a.size),
              p50_ms=round(float(np.percentile(a, 50)), 4),
              p99_ms=round(float(np.percentile(a, 99)), 4),
              mean_ms=round(float(a.mean()), 4))


def time_us(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
  """Average wall-clock microseconds per call (after warmup compiles)."""
  for _ in range(warmup):
    jax.block_until_ready(fn(*args))
  t0 = time.perf_counter()
  for _ in range(iters):
    jax.block_until_ready(fn(*args))
  return (time.perf_counter() - t0) / iters * 1e6
