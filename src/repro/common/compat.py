"""jax version-compat helpers shared by launch drivers and tests."""
from __future__ import annotations


def normalize_cost_analysis(cost) -> dict:
  """jax<0.5 `compiled.cost_analysis()` returns one dict per device; newer
  releases return the dict directly.  Always hand back a dict."""
  if isinstance(cost, (list, tuple)):
    return cost[0] if cost else {}
  return cost or {}
