from repro.common.types import (
    Array,
    DType,
    HardwareSpec,
    Params,
    PRNGKey,
    PyTree,
    Shape,
    V5E,
    cdiv,
    pytree_param_count,
    pytree_size_bytes,
    round_up,
)

__all__ = [
    "Array",
    "DType",
    "HardwareSpec",
    "Params",
    "PRNGKey",
    "PyTree",
    "Shape",
    "V5E",
    "cdiv",
    "pytree_param_count",
    "pytree_size_bytes",
    "round_up",
]
